"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (recurrent, O(1) decode state).
[arXiv:2405.04517; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                # xLSTM blocks carry their own up/down projections
    vocab_size=50_304,
    head_dim=512,
    slstm_every=8,         # every 8th block is sLSTM (7:1 mLSTM:sLSTM)
    subquadratic=True,
)
