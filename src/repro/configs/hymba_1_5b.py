"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer; sliding
window attention except 3 global layers; SSM state 16.
[arXiv:2411.13676; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32_001,
    head_dim=64,
    ssm_state=16,
    sliding_window=1024,
    layer_pattern="hymba",
    global_layers=(0, 15, 31),   # full-attention layers; rest sliding-window
    subquadratic=True,
)
