"""The paper's own 'architecture': filtered-ANN engine configurations for
the four evaluation datasets (Table 1)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class AnnConfig:
    name: str
    n: int
    dim: int
    filter_kinds: tuple
    n_lists: int = 0        # 0 -> sqrt(N)
    k: int = 10


ANN_CONFIGS = {
    "arxiv": AnnConfig("arxiv", 2_140_000, 384, ("mixed", "label", "range")),
    "wolt": AnnConfig("wolt", 1_720_000, 512, ("range",)),
    "glove200": AnnConfig("glove200", 1_180_000, 200, ("range",)),
    "sift": AnnConfig("sift", 1_000_000, 128, ("range",)),
}
