"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_ff=9216,
    vocab_size=256_000,
    head_dim=256,
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    layer_pattern="local_global",
    embed_scale=True,
    post_norms=True,
    tie_embeddings=True,
    # alternating local layers bound the KV working set; global layers are
    # O(L) per decoded token -> long_500k decode is runnable (DESIGN.md §4)
    subquadratic=True,
)
