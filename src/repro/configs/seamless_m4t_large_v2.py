"""seamless-m4t-large-v2 [audio] — enc-dec transformer backbone; the speech
frontend is a stub supplying precomputed frame embeddings.
[arXiv:2308.11596; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,            # decoder layers
    n_enc_layers=24,        # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    head_dim=64,
    frontend="audio",
    frontend_len=1024,      # precomputed speech frames per example (stub)
)
