"""internvl2-76b [vlm] — InternViT frontend (stub) + InternLM2 backbone.
[arXiv:2404.16821; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    head_dim=128,
    frontend="vision",
    frontend_len=256,   # precomputed patch embeddings per image (stub)
)
