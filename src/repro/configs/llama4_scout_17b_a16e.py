"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert, early
fusion. [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,             # per-expert FFN width
    vocab_size=202_048,
    head_dim=128,
    n_experts=16,
    top_k_experts=1,
    moe_shared_expert=True,
)
