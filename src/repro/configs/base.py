"""Model configuration system: one frozen dataclass drives every family.

Each assigned architecture gets a ``configs/<id>.py`` exporting ``CONFIG``
with the exact public numbers; ``reduced()`` derives the small same-family
variant used by CPU smoke tests.  ``repro.configs.get_config(name)`` is the
registry entry point used by ``--arch``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "SHAPES", "ShapeSpec"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads

    # attention features
    qk_norm: bool = False                   # qwen3
    attn_softcap: float = 0.0               # gemma2 (50.0)
    final_softcap: float = 0.0              # gemma2 (30.0)
    sliding_window: int = 0                 # local-attention window
    layer_pattern: str = "global"           # "global" | "local_global" | "hymba"
    global_layers: Tuple[int, ...] = ()     # full-attn layers for hymba pattern
    rope_theta: float = 10_000.0
    embed_scale: bool = False               # gemma2 multiplies embeds by sqrt(d)
    post_norms: bool = False                # gemma2 sandwich (post-block) norms

    # MoE
    n_experts: int = 0
    top_k_experts: int = 0
    moe_shared_expert: bool = False         # llama4 shared expert
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    slstm_every: int = 0                    # xlstm: every Nth block is sLSTM

    # encoder-decoder
    n_enc_layers: int = 0

    # modality frontend stubs
    frontend: str = "none"                  # none | vision | audio
    frontend_len: int = 0                   # patch/frame positions per example

    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    kv_cache_int8: bool = False             # quantised KV cache (serving)
    subquadratic: bool = False              # supports long_500k decode

    # ------------------------------------------------------------------
    @property
    def dh(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def n_params(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS roofline term)."""
        d, dh = self.d_model, self.dh
        attn = d * dh * self.n_heads + 2 * d * dh * self.n_kv_heads + dh * self.n_heads * d
        if self.family == "ssm":
            # xLSTM block: qkv projections + gates + up/down proj (factor 2)
            per_layer = 3 * d * d + 4 * d + 2 * d * 2 * d
        elif self.family == "hybrid":
            di = 2 * d
            ssm = d * 2 * di + di * self.ssm_conv + di * (2 * self.ssm_state + 2) + di * d
            per_layer = attn + ssm + 3 * d * self.d_ff
        elif self.is_moe:
            ff = 3 * d * self.d_ff * self.n_experts + d * self.n_experts
            if self.moe_shared_expert:
                ff += 3 * d * self.d_ff
            per_layer = attn + ff
        else:
            per_layer = attn + 3 * d * self.d_ff
        total = self.n_layers * per_layer + self.vocab_size * d
        if self.is_encdec:
            total += self.n_enc_layers * (attn + 2 * d * self.d_ff) + self.n_enc_layers * attn
        if not self.tie_embeddings:
            total += self.vocab_size * d
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        dense_ff = 3 * d * self.d_ff
        active_ff = dense_ff * self.top_k_experts + (dense_ff if self.moe_shared_expert else 0)
        full_ff = 3 * d * self.d_ff * self.n_experts + (
            dense_ff if self.moe_shared_expert else 0
        )
        return int(self.n_params() - self.n_layers * (full_ff - active_ff))

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Same-family small config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2),
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k_experts=min(self.top_k_experts, 2) if self.top_k_experts else 0,
            # no capacity dropping at smoke scale: keeps prefill (S-1 tokens)
            # and teacher-forced forward (S tokens) bit-comparable
            capacity_factor=8.0 if self.n_experts else self.capacity_factor,
            sliding_window=64 if self.sliding_window else 0,
            frontend_len=16 if self.frontend_len else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            slstm_every=min(self.slstm_every, 2) if self.slstm_every else 0,
            global_layers=tuple(g for g in self.global_layers if g < 2),
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
