"""Config registry: ``get_config("<arch-id>")`` resolves --arch flags."""
from .base import ModelConfig, SHAPES, ShapeSpec

from .gemma2_2b import CONFIG as _gemma2_2b
from .qwen3_14b import CONFIG as _qwen3_14b
from .qwen3_32b import CONFIG as _qwen3_32b
from .deepseek_67b import CONFIG as _deepseek_67b
from .internvl2_76b import CONFIG as _internvl2_76b
from .seamless_m4t_large_v2 import CONFIG as _seamless
from .xlstm_1_3b import CONFIG as _xlstm
from .olmoe_1b_7b import CONFIG as _olmoe
from .llama4_scout_17b_a16e import CONFIG as _llama4
from .hymba_1_5b import CONFIG as _hymba

REGISTRY = {
    c.name: c
    for c in [
        _gemma2_2b,
        _qwen3_14b,
        _qwen3_32b,
        _deepseek_67b,
        _internvl2_76b,
        _seamless,
        _xlstm,
        _olmoe,
        _llama4,
        _hymba,
    ]
}

ARCH_IDS = sorted(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    return REGISTRY[name]


__all__ = ["ModelConfig", "SHAPES", "ShapeSpec", "REGISTRY", "ARCH_IDS", "get_config"]
