"""olmoe-1b-7b [moe] — 64 experts, top-8 routing. [arXiv:2409.02060; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,             # per-expert FFN width
    vocab_size=50_304,
    head_dim=128,
    n_experts=64,
    top_k_experts=8,
    qk_norm=True,
)
