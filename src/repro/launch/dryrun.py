import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Do not move them.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with ShapeDtypeStruct inputs only (no allocation).

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k

Per cell it records: memory_analysis (proves fit), cost_analysis (FLOPs /
bytes for §Roofline), the collective-bytes breakdown parsed from the
optimized HLO, and the derived roofline terms.  Results go to
``results/dryrun/<arch>__<shape>__<mesh>.json`` and the sweep is resumable
(--skip-existing).
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, SHAPES, get_config
from ..dist.sharding import batch_sharding, cache_sharding, data_axes, param_sharding
from ..models.model import Model
from ..train.optimizer import AdamWConfig
from ..train.train_step import init_train_state, make_train_step
from .analytics import analytic_cost
from .mesh import make_production_mesh
from .roofline import analyse

RESULTS_DIR = os.path.join("results", "dryrun")


def _should_skip(arch: str, shape: str) -> Optional[str]:
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return "pure full-attention arch: long_500k needs sub-quadratic attention (DESIGN.md §4)"
    return None


def _grad_accum(cfg, shape) -> int:
    """Microbatch count for the train cells: bounds the per-microbatch
    activation footprint (saved layer-scan carries scale with B_local; MoE
    dispatch buffers (B, E, C, D) scale the same way)."""
    if shape.kind != "train":
        return 1
    if cfg.d_model >= 8192 or cfg.is_moe:
        return 4
    if cfg.d_model >= 5120 or cfg.family == "ssm":
        return 2
    return 1


def _model_flops(cfg, shape) -> float:
    """Useful FLOPs: 6*N*D train (fwd+bwd), 2*N*D inference fwd."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def _replicated(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               mesh_shape=None, kv_int8: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_int8=True)
    shape = SHAPES[shape_name]
    if mesh_shape is not None:
        from .mesh import make_custom_mesh

        mesh = make_custom_mesh(*mesh_shape)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    fsdp = data_axes(mesh)
    n_data = int(np.prod([mesh.shape[a] for a in fsdp]))
    batch_shardable = shape.global_batch % n_data == 0 and shape.global_batch >= n_data
    hints = {
        "batch": fsdp if batch_shardable else None,
        "model": "model",
    }
    model = Model(cfg, hints=hints)
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            state_shape = jax.eval_shape(
                lambda: init_train_state(model, jax.random.PRNGKey(0))
            )
            p_shard = param_sharding(mesh, state_shape.params)
            state_shard = type(state_shape)(
                params=p_shard,
                opt=type(state_shape.opt)(
                    step=NamedSharding(mesh, P()),
                    m=param_sharding(mesh, state_shape.opt.m),
                    v=param_sharding(mesh, state_shape.opt.v),
                ),
            )
            batch_spec = model.input_specs(shape)["batch"]
            b_shard = batch_sharding(mesh, batch_spec, shape.global_batch)
            accum = _grad_accum(cfg, shape)
            step = make_train_step(model, AdamWConfig(), grad_accum=accum)
            metrics_spec = (
                {"loss": 0, "grad_norm": 0, "lr_scale": 0}
                if accum > 1
                else {"loss": 0, "grad_norm": 0, "lr_scale": 0, "ce": 0, "aux": 0, "tokens": 0}
            )
            jitted = jax.jit(
                step,
                in_shardings=(state_shard, b_shard),
                out_shardings=(state_shard, _replicated(mesh, metrics_spec)),
            )
            lowered = jitted.lower(state_shape, batch_spec)

        elif shape.kind == "prefill":
            params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            p_shard = param_sharding(mesh, params_shape)
            batch_spec = model.input_specs(shape)["batch"]
            b_shard = batch_sharding(mesh, batch_spec, shape.global_batch)
            max_len = shape.seq_len + (cfg.frontend_len if cfg.family == "vlm" else 0)

            def prefill(params, batch):
                return model.prefill(params, batch, max_len)

            cache_shape = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, max_len)
            )
            c_shard = cache_sharding(mesh, cache_shape, shape.global_batch)
            jitted = jax.jit(
                prefill,
                in_shardings=(p_shard, b_shard),
                out_shardings=(NamedSharding(mesh, P()), c_shard),
            )
            lowered = jitted.lower(params_shape, batch_spec)

        else:  # decode
            params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            p_shard = param_sharding(mesh, params_shape)
            specs = model.input_specs(shape)
            cache_spec, tok_spec, len_spec = (
                specs["cache"], specs["tokens"], specs["lengths"],
            )
            c_shard = cache_sharding(mesh, cache_spec, shape.global_batch)
            fsdp = data_axes(mesh)
            n_data = int(np.prod([mesh.shape[a] for a in fsdp]))
            tl = (
                NamedSharding(mesh, P(fsdp))
                if shape.global_batch % n_data == 0 and shape.global_batch >= n_data
                else NamedSharding(mesh, P())
            )
            jitted = jax.jit(
                model.decode_step,
                in_shardings=(p_shard, c_shard, tl, tl),
                out_shardings=(NamedSharding(mesh, P()), c_shard),
            )
            lowered = jitted.lower(params_shape, cache_spec, tok_spec, len_spec)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        mf = _model_flops(cfg, shape)
        n_model = mesh.shape["model"]
        ac = analytic_cost(cfg, shape, n_data=chips // n_model, n_model=n_model)
        terms = analyse(cost, hlo, chips, model_flops=mf, analytic=ac)

        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        }
        result = {
            "arch": arch,
            "shape": shape_name,
            "mesh": (
                f"{mesh_shape[0]}x{mesh_shape[1]}" if mesh_shape
                else ("2x16x16" if multi_pod else "16x16")
            ),
            "chips": chips,
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory_analysis": mem_d,
            "cost_flops": cost.get("flops", 0.0),
            "cost_bytes": cost.get("bytes accessed", 0.0),
            "roofline": terms.to_dict(),
            "n_params": cfg.n_params(),
            "n_active_params": cfg.n_active_params(),
            "model_flops": mf,
        }
        print(
            f"[{arch} x {shape_name} x {result['mesh']}] OK "
            f"lower {t_lower:.1f}s compile {t_compile:.1f}s | "
            f"flops {cost.get('flops', 0):.3g} bytes {cost.get('bytes accessed', 0):.3g} | "
            f"coll {terms.coll_bytes:.3g}B | bottleneck {terms.bottleneck} | "
            f"temp {mem_d['temp_bytes']/2**30:.2f} GiB/dev"
        )
        return result


def run_cell(arch, shape_name, multi_pod, out_dir, skip_existing=True,
             mesh_shape=None, kv_int8=False):
    mesh_tag = (
        f"{mesh_shape[0]}x{mesh_shape[1]}" if mesh_shape
        else ("2x16x16" if multi_pod else "16x16")
    )
    if kv_int8:
        mesh_tag += "_kvint8"
    fn = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    if skip_existing and os.path.exists(fn):
        print(f"[{arch} x {shape_name} x {mesh_tag}] cached")
        return json.load(open(fn))
    reason = _should_skip(arch, shape_name)
    if reason:
        result = {
            "arch": arch, "shape": shape_name, "mesh": mesh_tag,
            "status": "skipped", "reason": reason,
        }
        print(f"[{arch} x {shape_name} x {mesh_tag}] SKIP: {reason}")
    else:
        try:
            result = lower_cell(arch, shape_name, multi_pod,
                                mesh_shape=mesh_shape, kv_int8=kv_int8)
        except Exception as e:  # noqa — record the failure, keep sweeping
            result = {
                "arch": arch, "shape": shape_name, "mesh": mesh_tag,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
            print(f"[{arch} x {shape_name} x {mesh_tag}] ERROR: {e}")
    os.makedirs(out_dir, exist_ok=True)
    with open(fn, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--mesh-shape", default=None,
                    help="custom DATAxMODEL single-pod mesh, e.g. 32x8")
    ap.add_argument("--kv-int8", action="store_true",
                    help="quantised int8 KV cache (serving hillclimb)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--no-skip-existing", action="store_true")
    args = ap.parse_args()
    mesh_shape = (
        tuple(int(x) for x in args.mesh_shape.split("x")) if args.mesh_shape else None
    )

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes if mesh_shape is None else [False]:
                r = run_cell(arch, shape, mp, args.out,
                             skip_existing=not args.no_skip_existing,
                             mesh_shape=mesh_shape, kv_int8=args.kv_int8)
                if r.get("status") == "error":
                    n_fail += 1
    print(f"dry-run sweep done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
