"""Analytic cost model: trip-count-exact FLOPs / HBM / collective bytes.

WHY THIS EXISTS: XLA's ``compiled.cost_analysis()`` counts while-loop bodies
ONCE (verified experimentally — a lax.scan of 2 vs 8 matmuls reports the same
flops), and every model here scans its layers (and flash-attention scans its
chunks), so cost_analysis under-reports by ~the layer count.  The dry-run
records BOTH: cost_analysis + HLO-parsed collectives (per-iteration
corroboration) and this analytic model (trip-count-corrected totals used for
the §Roofline terms).

Conventions: FLOPs are 2·m·n·k per matmul; traffic model constants are
documented inline; everything is derived from the config + shape + mesh
factorisation (n_data x n_model).  All outputs GLOBAL (sum over chips) except
``coll_bytes_per_dev`` which is the per-device payload (what the link sees).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from ..configs.base import ModelConfig, ShapeSpec

__all__ = ["analytic_cost", "AnalyticCost"]

BF16 = 2
F32 = 4


def _eff_attended(s: int, w: int) -> float:
    """Sum over query positions of attended width, causal with window w."""
    if w >= s:
        return s * (s + 1) / 2.0
    return w * s - w * (w - 1) / 2.0


def _per_layer_windows(cfg: ModelConfig, s: int):
    if cfg.layer_pattern == "local_global":
        return [cfg.sliding_window if i % 2 == 0 else s for i in range(cfg.n_layers)]
    if cfg.layer_pattern == "hymba":
        return [
            s if i in cfg.global_layers else cfg.sliding_window
            for i in range(cfg.n_layers)
        ]
    return [s] * cfg.n_layers


def _proj_flops_per_token(cfg: ModelConfig) -> float:
    """Per-layer projection (non-attention-score) matmul flops per token."""
    d, dh, h, kv, f = cfg.d_model, cfg.dh, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    hdh, kvdh = h * dh, kv * dh
    attn = 2 * d * hdh + 2 * 2 * d * kvdh + 2 * hdh * d
    if cfg.family == "ssm":
        # mLSTM-ish block: q/k/v/gate + out projections + cell update
        cell = 6 * dh * d          # 6·dh² per head × H = 6·dh·(H·dh)=6·dh·D
        return 10 * d * hdh + cell
    if cfg.family == "hybrid":
        di, n, r = d, cfg.ssm_state, max(1, d // 16)
        mamba = 2 * d * 2 * di + 4 * di * r + 2 * di * 2 * n + 6 * di * n + 2 * di * d
        return attn + mamba + 6 * d * f
    if cfg.is_moe:
        ff = 2 * d * cfg.n_experts + 6 * d * f * cfg.top_k_experts
        if cfg.moe_shared_expert:
            ff += 6 * d * f
        return attn + ff
    return attn + 6 * d * f


@dataclasses.dataclass
class AnalyticCost:
    flops: float                 # global
    hbm_bytes: float             # global (sum of per-device traffic)
    coll_bytes_per_dev: float    # payload bytes through one chip's links
    detail: Dict[str, float]

    def to_dict(self):
        return dataclasses.asdict(self)


def analytic_cost(
    cfg: ModelConfig, shape: ShapeSpec, n_data: int, n_model: int
) -> AnalyticCost:
    chips = n_data * n_model
    b, s = shape.global_batch, shape.seq_len
    d, dh, h, kv, v = cfg.d_model, cfg.dh, cfg.n_heads, cfg.n_kv_heads, cfg.vocab_size
    L = cfg.n_layers
    n_params = cfg.n_params()
    n_active = cfg.n_active_params()
    kvdh = kv * dh
    t_global = b * s
    t_loc = t_global / n_data    # tokens per data shard

    proj_tok = _proj_flops_per_token(cfg)

    # ----- FLOPs ------------------------------------------------------
    if shape.kind in ("train", "prefill"):
        attn_fl = 0.0
        if cfg.family != "ssm":
            for w in _per_layer_windows(cfg, s):
                attn_fl += 4 * b * h * dh * _eff_attended(s, w)
        enc_fl = 0.0
        if cfg.is_encdec:
            fe = cfg.frontend_len
            enc_fl = cfg.n_enc_layers * (
                b * fe * (2 * d * h * dh * 2 + 2 * 2 * d * kvdh + 4 * d * cfg.d_ff)
                + 4 * b * h * dh * fe * fe      # bidirectional scores
            )
            # decoder cross-attention scores
            attn_fl += L * 4 * b * h * dh * fe * s
        layer_fl = L * proj_tok * t_global + attn_fl + enc_fl
        head_fl = 2 * d * v * t_global
        if shape.kind == "train":
            # layers: fwd + 2·bwd + 1·remat-refwd = 4x ; head/loss: 3x
            flops = 4 * layer_fl + 3 * head_fl
        else:
            flops = layer_fl + head_fl
    else:  # decode: one token per sequence
        attn_fl = 0.0
        if cfg.family != "ssm":
            for w in _per_layer_windows(cfg, s):
                attn_fl += 4 * b * h * dh * min(s, w)
        if cfg.is_encdec:
            attn_fl += L * 4 * b * h * dh * cfg.frontend_len
        flops = L * proj_tok * b + attn_fl + 2 * d * v * b

    # ----- HBM traffic (per device, then x chips) ---------------------
    p_bf16 = n_params * BF16
    if shape.kind == "train":
        # gathered bf16 weights written+read on every device, 3 passes
        # (fwd, remat, bwd); TP keeps 1/n_model of each tensor per device.
        w_traffic = 3 * 2 * p_bf16 / n_model
        # master/opt update on the owned shard only (read p,m,v + write p,m,v)
        opt_traffic = 24 * n_params / chips + 8 * n_params / chips  # + grad f32 rw
        # activations: ~20 residual-stream touches per layer (fwd+bwd+remat)
        act = 20 * L * t_loc * d * BF16
        # flash-attention KV streaming: K+V re-read once per query chunk
        chunk = 512
        kv_stream = 0.0
        if cfg.family != "ssm":
            n_chunks = max(1, s // chunk)
            for w in _per_layer_windows(cfg, s):
                eff = min(w, s)
                kv_stream += 3 * (b / n_data) * n_chunks * eff * kvdh * 2 * BF16
        logits_traffic = 4 * t_loc * (v / n_model) * F32 * 3  # fwd w, bwd r/w x3 passes
        per_dev = w_traffic + opt_traffic + act + kv_stream + logits_traffic
    elif shape.kind == "prefill":
        w_traffic = 2 * p_bf16 / n_model
        act = 8 * L * t_loc * d * BF16
        chunk = 512
        kv_stream = 0.0
        if cfg.family != "ssm":
            n_chunks = max(1, s // chunk)
            for w in _per_layer_windows(cfg, s):
                kv_stream += 1.5 * (b / n_data) * n_chunks * min(w, s) * kvdh * 2 * BF16
        cache_write = 2 * L * t_loc * kvdh * BF16
        per_dev = w_traffic + act + kv_stream + cache_write + 2 * t_loc * (v / n_model) * F32
    else:  # decode
        b_loc = b / n_data if b >= n_data else b
        # weights: every device reads the gathered bf16 copy once per step
        w_traffic = 2 * p_bf16 / n_model
        cache_rw = 0.0
        if cfg.family != "ssm":
            # cache sequence dim is sharded over `model` (batch-sharded case)
            # or over the data axes (B < n_data) — dist/sharding.cache_sharding
            seq_shard = n_model if b >= n_data else n_data
            # int8 cache halves the bytes (+2/dh f32 scale overhead)
            kv_bytes = (1 + 4.0 / dh) if cfg.kv_cache_int8 else BF16
            for w in _per_layer_windows(cfg, s):
                span = min(w, s)
                span_loc = span / seq_shard
                cache_rw += 2 * b_loc * kv * span_loc * dh * kv_bytes
        if cfg.family in ("ssm", "hybrid"):
            # recurrent states read+write
            if cfg.family == "ssm":
                cache_rw += 2 * L * b_loc * h * dh * dh * F32
            else:
                cache_rw += 2 * L * b_loc * d * cfg.ssm_state * F32
        per_dev = w_traffic + cache_rw + b_loc * d * L * 10 * BF16
    hbm = per_dev * chips

    # ----- collective bytes per device ---------------------------------
    if shape.kind == "train":
        # fsdp all-gather x3 + grad reduce-scatter (over data axes), TP dim
        # excluded from gather size; ring factor (n-1)/n ~ 1
        ag = 3 * p_bf16 / n_model
        rs = n_params * F32 / n_model
        # TP all-reduce: 2 per layer per pass (attn out + ffn out), 3 passes,
        # ring all-reduce moves 2x payload.  MoE layers replace the FFN
        # all-reduce with the expert all-to-all -> only 1 AR/layer.
        ar_per_layer = 1 if cfg.is_moe else 2
        tp_ar = 3 * ar_per_layer * 2 * L * t_loc * d * BF16 if n_model > 1 else 0.0
        a2a = 0.0
        if cfg.is_moe:
            a2a = 2 * 2 * 2 * L * t_loc * d * BF16   # dispatch+combine, fwd+bwd
        coll = ag + rs + tp_ar + a2a
    elif shape.kind == "prefill":
        ag = p_bf16 / n_model
        tp_ar = 2 * 2 * L * t_loc * d * BF16 if n_model > 1 else 0.0
        a2a = 2 * 2 * L * t_loc * d * BF16 if cfg.is_moe else 0.0
        coll = ag + tp_ar + a2a
    else:
        b_loc = b / n_data if b >= n_data else b
        ag = p_bf16 / n_model                       # weight gather per step
        tp_ar = 2 * 2 * L * b_loc * d * BF16 if n_model > 1 else 0.0
        a2a = 2 * 2 * L * b_loc * d * BF16 if cfg.is_moe else 0.0
        # sequence-parallel cache (B < n_data): softmax partial reductions
        seq_ar = 2 * L * b * h * 4 * F32 if b < n_data else 0.0
        coll = ag + tp_ar + a2a + seq_ar

    detail = {
        "proj_flops_per_token_per_layer": proj_tok,
        "n_params": float(n_params),
        "n_active_params": float(n_active),
        "tokens": float(t_global if shape.kind != "decode" else b),
    }
    return AnalyticCost(
        flops=float(flops), hbm_bytes=float(hbm),
        coll_bytes_per_dev=float(coll), detail=detail,
    )
