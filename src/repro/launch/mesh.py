"""Production mesh construction (DESIGN.md §5).

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "partition_params"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_custom_mesh(data: int, model: int):
    """Single-pod mesh with a custom (data, model) factorisation — the
    hillclimb lever for rebalancing TP-collective vs FSDP-gather traffic
    (e.g. MoE train cells prefer (32, 8) over (16, 16); EXPERIMENTS.md §Perf)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_local_mesh():
    """Degenerate mesh over however many devices exist (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def partition_params(mesh, params):
    """NamedShardings for a param pytree on ``mesh``.

    Thin entry point over ``repro.dist.sharding.param_spec`` rules so the
    launch drivers have one partitioning call next to mesh construction
    (imported lazily: building a mesh must stay importable before jax
    device init — see module docstring).
    """
    from ..dist.sharding import param_sharding

    return param_sharding(mesh, params)
