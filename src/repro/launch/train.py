"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --steps 50 \
        --reduced --ckpt-dir /tmp/ckpt

Wires together: config registry -> model -> sharded train step (pjit) ->
deterministic data pipeline -> checkpointing (async, atomic, auto-resume) ->
fault hooks (heartbeat + straggler monitors).  On this CPU container use
``--reduced`` (smoke-size model, local mesh); the same driver drives the
production mesh on a real pod.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, get_config
from ..data.pipeline import TokenPipeline
from ..dist.fault import HeartbeatMonitor, StragglerMitigator
from ..dist.sharding import batch_sharding, data_axes, param_sharding
from ..models.model import Model
from ..train.optimizer import AdamWConfig
from ..train.train_step import TrainState, init_train_state, make_train_step
from ..ckpt.checkpoint import Checkpointer
from .mesh import make_local_mesh, make_production_mesh


def make_sharded_train_step(model, mesh, state_shape, global_batch, batch_spec,
                            opt_cfg=AdamWConfig()):
    p_shard = param_sharding(mesh, state_shape.params)
    state_shard = TrainState(
        params=p_shard,
        opt=type(state_shape.opt)(
            step=NamedSharding(mesh, P()),
            m=param_sharding(mesh, state_shape.opt.m),
            v=param_sharding(mesh, state_shape.opt.v),
        ),
    )
    b_shard = batch_sharding(mesh, batch_spec, global_batch)
    step = make_train_step(model, opt_cfg)
    return (
        jax.jit(step, in_shardings=(state_shard, b_shard)),
        state_shard,
        b_shard,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true", help="smoke-size model")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_production_mesh() if args.production_mesh else make_local_mesh()
    fsdp = data_axes(mesh)
    model = Model(cfg, hints={"batch": fsdp, "model": "model"}
                  if args.production_mesh else None)

    pipe = TokenPipeline(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch, frontend=cfg.frontend,
        frontend_len=cfg.frontend_len, d_model=cfg.d_model,
    )
    batch0 = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}

    with mesh:
        state_shape = jax.eval_shape(
            lambda: init_train_state(model, jax.random.PRNGKey(0))
        )
        sharded_step, state_shard, b_shard = make_sharded_train_step(
            model, mesh, state_shape, args.batch,
            jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch0),
            AdamWConfig(lr=args.lr),
        )
        ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
        start = 0
        if ckpt and ckpt.latest_step() is not None:
            start = ckpt.latest_step()
            print(f"resuming from checkpoint step {start}")
            state = ckpt.restore(start, state_shape, shardings=state_shard)
        else:
            state = init_train_state(model, jax.random.PRNGKey(0))

        hb = HeartbeatMonitor(n_hosts=jax.process_count())
        straggler = StragglerMitigator(n_hosts=jax.process_count())
        losses = []
        for step_i in range(start, args.steps):
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step_i).items()}
            state, metrics = sharded_step(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            hb.beat(jax.process_index())
            straggler.record(jax.process_index(), dt)
            for ev in hb.check(step_i) + straggler.check(step_i):
                print(f"  !! fault event: {ev}")
            if step_i % 5 == 0 or step_i == args.steps - 1:
                print(f"step {step_i:4d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} {dt*1e3:7.1f} ms")
            if ckpt and (step_i + 1) % args.ckpt_every == 0:
                ckpt.save_async(step_i + 1, state)
        if ckpt:
            ckpt.wait()
        if losses:
            print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")
        else:
            print(f"nothing to do: resumed at step {start} >= {args.steps}")
        return losses


if __name__ == "__main__":
    main()
