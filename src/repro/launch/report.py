"""Render the roofline/dry-run tables for EXPERIMENTS.md from the recorded
results JSONs.

    PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun_opt] \
        [--baseline results/dryrun_baseline]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(d: str) -> List[dict]:
    return [json.load(open(f)) for f in sorted(glob.glob(os.path.join(d, "*.json")))]


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def roofline_table(rows: List[dict], mesh="16x16") -> str:
    out = [
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck | "
        "MODEL_FLOPS | useful | temp GiB/dev | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") == "skipped" and r["mesh"] == mesh:
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | *skipped* | — | — | — | — |"
            )
            continue
        if r.get("status") != "ok" or r["mesh"] != mesh:
            continue
        t = r["roofline"]
        mem = r["memory_analysis"]["temp_bytes"] / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | **{t['bottleneck']}** | {t['model_flops']:.3g} "
            f"| {t['useful_ratio']:.2f} | {mem:.2f} | "
            f"{'yes' if mem < 16 else 'NO'} |"
        )
    return "\n".join(out)


def dryrun_table(rows: List[dict]) -> str:
    out = [
        "| arch | shape | mesh | status | HLO flops/dev | HLO bytes/dev | "
        "collectives (parsed once-through) | temp GiB/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | skip: "
                f"{r['reason'][:60]}… | | | | | |"
            )
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | | | | | |")
            continue
        det = r["roofline"].get("coll_detail") or {}
        n_coll = det.get("count", 0)
        mem = r["memory_analysis"]["temp_bytes"] / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['cost_flops']:.3g} | {r['cost_bytes']:.3g} | {n_coll} ops / "
            f"{det.get('parsed_coll_bytes_once', 0)/2**20:.0f} MiB | {mem:.2f} | "
            f"{r.get('compile_s', 0):.1f} |"
        )
    return "\n".join(out)


def before_after(base: List[dict], opt: List[dict]) -> str:
    bidx = {(r["arch"], r["shape"], r["mesh"]): r for r in base if r.get("status") == "ok"}
    out = [
        "| cell | metric | baseline | optimized | Δ |",
        "|---|---|---|---|---|",
    ]
    for r in opt:
        if r.get("status") != "ok" or r["mesh"] != "16x16":
            continue
        key = (r["arch"], r["shape"], r["mesh"])
        b = bidx.get(key)
        if not b:
            continue
        mb = b["memory_analysis"]["temp_bytes"] / 2**30
        mo = r["memory_analysis"]["temp_bytes"] / 2**30
        dom_b = max(b["roofline"]["compute_s"], b["roofline"]["memory_s"],
                    b["roofline"]["collective_s"])
        dom_o = max(r["roofline"]["compute_s"], r["roofline"]["memory_s"],
                    r["roofline"]["collective_s"])
        if abs(mb - mo) / max(mb, 1e-9) > 0.05 or abs(dom_b - dom_o) / max(dom_b, 1e-9) > 0.05:
            out.append(
                f"| {r['arch']}·{r['shape']} | temp GiB / dominant-term s | "
                f"{mb:.1f} / {dom_b:.3f} | {mo:.1f} / {dom_o:.3f} | "
                f"{(1-mo/max(mb,1e-9))*100:+.0f}% mem, {(1-dom_o/max(dom_b,1e-9))*100:+.0f}% time |"
            )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun_opt")
    ap.add_argument("--baseline", default="results/dryrun_baseline")
    ap.add_argument("--mode", default="all", choices=["roofline", "dryrun", "diff", "all"])
    args = ap.parse_args()
    rows = load(args.dir)
    if args.mode in ("roofline", "all"):
        print("### Roofline (single pod, 16x16)\n")
        print(roofline_table(rows))
    if args.mode in ("dryrun", "all"):
        print("\n### Dry-run record (both meshes)\n")
        print(dryrun_table(rows))
    if args.mode in ("diff", "all") and os.path.isdir(args.baseline):
        print("\n### Before/after (baseline -> optimized)\n")
        print(before_after(load(args.baseline), rows))


if __name__ == "__main__":
    main()
