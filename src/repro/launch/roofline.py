"""Roofline-term derivation from a compiled dry-run artefact.

    compute   = HLO_FLOPs       / (chips x peak_FLOPs)
    memory    = HLO_bytes       / (chips x HBM_bw)
    collective= collective_bytes/ (chips x link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``.  Collective bytes
are NOT in cost_analysis: we parse the optimized HLO text and sum operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.

Hardware constants (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

__all__ = ["RooflineTerms", "analyse", "collective_bytes", "HW"]

HW = {
    "peak_flops": 197e12,   # bf16 per chip
    "hbm_bw": 819e9,        # bytes/s per chip
    "link_bw": 50e9,        # bytes/s per ICI link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

# e.g. "bf16[128,4096,5120]{2,1,0}" — capture dtype + dims (layout ignored)
_SHAPE_RE = re.compile(r"(\w+?)\[([0-9,]*)\](?:\{[0-9,a-zA-Z:()#_\s]*\})?")
_COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# instruction line: "%name = <shape(s)> <op>(...)", shapes may be tuples with
# layout annotations
_INSTR_RE = re.compile(
    r"=\s*(\(?[\w\[\]\{\},:#()\s]*?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of OUTPUT operand sizes per collective op kind in an HLO module.

    CAVEAT (recorded in EXPERIMENTS.md): ops inside while-loop bodies (layer
    scans) are counted ONCE, exactly like ``cost_analysis`` counts their
    flops once — the analytic model in launch/analytics.py supplies the
    trip-count-corrected totals; this parse corroborates op *kinds* and
    per-iteration payloads."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s or "(" not in s:
            continue
        m = _INSTR_RE.search(s)
        if m:
            out[m.group(2)] += _shape_bytes(m.group(1))
            out["count"] += 1
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # total HLO flops (all chips)
    hbm_bytes: float             # total HLO bytes accessed (all chips)
    coll_bytes: float            # total collective payload bytes (all chips)
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float = 0.0     # 6*N*D useful flops
    useful_ratio: float = 0.0    # model_flops / HLO flops
    coll_detail: Optional[Dict[str, int]] = None

    def to_dict(self):
        d = dataclasses.asdict(self)
        return d


def analyse(
    cost: Dict[str, float],
    hlo_text: str,
    chips: int,
    model_flops: float = 0.0,
    ici_links: int = 4,
    analytic=None,
) -> RooflineTerms:
    """Derive the three roofline terms.

    Primary source is the ``analytic`` cost model (launch/analytics.py) —
    XLA's cost_analysis counts while-loop (layer-scan) bodies once, so its
    raw numbers under-report by ~n_layers; they are still recorded for
    corroboration.  ``analytic`` carries GLOBAL flops / hbm bytes and
    per-device collective bytes."""
    xla_flops = float(cost.get("flops", 0.0))
    xla_hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    parsed_cbytes = float(sum(v for k, v in coll.items() if k != "count"))

    if analytic is not None:
        flops = analytic.flops              # global
        hbm = analytic.hbm_bytes            # global
        cbytes = analytic.coll_bytes_per_dev
    else:
        flops = xla_flops * chips
        hbm = xla_hbm * chips
        cbytes = parsed_cbytes

    compute_s = flops / (chips * HW["peak_flops"])
    memory_s = hbm / (chips * HW["hbm_bw"])
    # each chip drives `ici_links` links; payload crosses once per hop
    collective_s = cbytes / (HW["link_bw"] * ici_links)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / flops if flops else 0.0
    coll["xla_flops_per_dev"] = xla_flops
    coll["xla_bytes_per_dev"] = xla_hbm
    coll["parsed_coll_bytes_once"] = parsed_cbytes
    return RooflineTerms(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=cbytes,
        chips=chips,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=useful,
        coll_detail=coll,
    )
