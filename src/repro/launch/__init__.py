# NOTE: dryrun is intentionally NOT imported here — importing it sets
# XLA_FLAGS (512 host devices) which must only happen for the dry-run entry
# point, never for tests/benchmarks.
from .mesh import make_production_mesh, make_local_mesh
from . import roofline

__all__ = ["make_production_mesh", "make_local_mesh", "roofline"]
