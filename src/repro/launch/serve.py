"""Serving driver: batched generation with a (reduced) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \
        --requests 8 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models.model import Model
from ..serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens,
        )
        for i in range(args.requests)
    ]
    eng = ServeEngine(model, params, batch_slots=args.slots,
                      max_len=args.prompt_len + args.new_tokens + 8)
    t0 = time.time()
    results = eng.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    print(f"served {len(reqs)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    for uid in sorted(results)[:3]:
        print(f"  req {uid}: {results[uid][:8]}...")
    return results


if __name__ == "__main__":
    main()
