"""Serving drivers: LM generation and the trace-driven ANN runtime.

    # batched LM generation with a (reduced) model
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch qwen3-14b \
        --reduced --requests 8 --new-tokens 16

    # deadline-aware filtered-ANN serving: replay an arrival trace through
    # the continuous micro-batcher (vs a naive per-request loop) and print
    # the telemetry snapshot
    PYTHONPATH=src python -m repro.launch.serve --mode ann-trace \
        --corpus 20000 --requests 400 --rate 2000 --trace poisson --shards 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..configs import get_config
from ..models.model import Model
from ..serve.engine import Request, ServeEngine


def run_lm(args) -> dict:
    cfg = get_config(args.arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens,
        )
        for i in range(args.requests)
    ]
    eng = ServeEngine(model, params, batch_slots=args.slots,
                      max_len=args.prompt_len + args.new_tokens + 8)
    t0 = time.time()
    results = eng.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    print(f"served {len(reqs)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    for uid in sorted(results)[:3]:
        print(f"  req {uid}: {results[uid][:8]}...")
    return results


def run_ann_trace(args) -> dict:
    """Build a fixture corpus + engine, replay a seeded arrival trace through
    the runtime (optionally sharded, optionally with the planner feedback
    loop), and compare against the naive per-request loop."""
    from ..core import EngineConfig, FilteredANNEngine
    from ..core.trainer import gen_queries
    from ..data import make_dataset
    from ..obs import (
        RecallProbe, Tracer, publish_kernel_budget, publish_kernel_dispatch,
        span_summary,
    )
    from ..runtime import (
        FeedbackConfig, OnlineFeedback, OnlineRuntime, SchedulerConfig, make_trace,
    )
    from ..serve import ShardedANNEngine

    ds = make_dataset(args.dataset, scale=str(args.corpus), seed=args.seed)
    print(f"corpus: {args.dataset} n={ds.vectors.shape[0]} d={ds.vectors.shape[1]}")
    eng = FilteredANNEngine(ds.vectors, ds.cat, ds.num,
                            EngineConfig(seed=args.seed)).build()
    tq, tp, _ = gen_queries(ds.vectors, ds.cat, ds.num, args.fit_queries,
                            kinds=ds.filter_kinds, seed=args.seed + 1)
    eng.fit(tq, tp, k=args.k)
    qs, preds, _ = gen_queries(ds.vectors, ds.cat, ds.num, args.pool,
                               kinds=ds.filter_kinds, sel_range=(0.01, 0.4),
                               seed=args.seed + 2)
    if args.explain:
        # print ExecutionPlan trees for sample pool predicates (plus one
        # synthetic DNF so the per-disjunct shape shows) and exit
        from ..core import Or

        samples = list(preds[:3])
        if len(preds) >= 2:
            samples.append(Or((preds[0], preds[1])))
        for p in samples:
            print(f"\n{p}")
            print(eng.explain(p, k=args.k))
        return {}
    trace = make_trace(args.trace, qs, list(preds), args.requests, args.rate,
                       k=args.k, seed=args.seed + 3)

    backend = ShardedANNEngine(eng, n_shards=args.shards) if args.shards > 1 else eng
    feedback = None
    if args.feedback:
        feedback = OnlineFeedback(eng, FeedbackConfig(
            sample_rate=args.sample_rate, seed=args.seed))
    tracer = Tracer()
    probe = RecallProbe(rate=args.probe_rate, seed=args.seed) \
        if args.probe_rate > 0 else None
    runtime = OnlineRuntime(
        backend,
        SchedulerConfig(max_batch=args.max_batch, max_wait=args.max_wait),
        feedback=feedback,
        tracer=tracer,
        probe=probe,
    )
    report = runtime.run_trace(trace)
    snap = report.telemetry.snapshot(backend)

    # naive per-request loop on the same requests, for the throughput frame
    t0 = time.perf_counter()
    for r in trace:
        backend.query(r.query, r.pred, r.k)
    naive_wall = time.perf_counter() - t0

    wall = snap["wall"]["exec_s"]
    print(f"\ntrace: {trace.kind} rate={trace.rate:.0f}qps "
          f"requests={len(trace)} shards={args.shards}")
    print(f"runtime exec wall {wall:.2f}s ({len(trace)/wall:.0f} qps)  |  "
          f"naive loop {naive_wall:.2f}s ({len(trace)/naive_wall:.0f} qps)  |  "
          f"speedup {naive_wall/max(wall, 1e-9):.2f}x")
    if feedback is not None:
        snap["feedback"] = feedback.stats()
        feedback.publish(report.telemetry.registry)
    if probe is not None:
        snap["probe"] = probe.estimates()
        probe.publish(report.telemetry.registry)
    # kernel-side observability rides the same registry the runtime
    # counters live in: one export surface for the whole serving stack
    publish_kernel_dispatch(report.telemetry.registry)
    publish_kernel_budget(report.telemetry.registry)
    snap["span_summary"] = span_summary(tracer)
    if args.trace_out:
        tracer.write_jsonl(args.trace_out)
        print(f"wrote {sum(1 for _ in tracer.spans())} spans to {args.trace_out}")
    print(json.dumps(snap, indent=2, default=float))
    return snap


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "ann-trace"), default="lm")
    # lm mode
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    # shared / ann-trace mode
    ap.add_argument("--requests", type=int, default=None,
                    help="lm: 8, ann-trace: 400")
    ap.add_argument("--dataset", default="arxiv")
    ap.add_argument("--corpus", type=int, default=20_000)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--trace", choices=("poisson", "bursty"), default="poisson")
    ap.add_argument("--rate", type=float, default=2000.0, help="virtual qps")
    ap.add_argument("--pool", type=int, default=24, help="distinct predicates")
    ap.add_argument("--fit-queries", type=int, default=40)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait", type=float, default=0.005)
    ap.add_argument("--feedback", action="store_true",
                    help="enable the online planner feedback loop")
    ap.add_argument("--sample-rate", type=float, default=0.1)
    ap.add_argument("--probe-rate", type=float, default=0.0,
                    help="live recall-probe sampling rate (0 disables)")
    ap.add_argument("--explain", action="store_true",
                    help="print ExecutionPlan trees for sample pool "
                         "predicates (incl. a DNF) and exit, no trace replay")
    ap.add_argument("--trace-out", default=None,
                    help="write the span tree as JSONL to this path")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.requests is None:
        args.requests = 8 if args.mode == "lm" else 400
    if args.mode == "lm":
        return run_lm(args)
    return run_ann_trace(args)


if __name__ == "__main__":
    main()
