"""AdamW in pure JAX (optax is unavailable offline).

States are pytrees mirroring the params; everything fp32 (params are fp32
masters, forward casts to bf16).  Supports global-norm clipping, decoupled
weight decay, and linear-warmup + cosine schedules (in schedule.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def adamw_update(
    params, grads, state: AdamWState, cfg: AdamWConfig, lr_scale: jax.Array
) -> Tuple[Any, AdamWState, jax.Array]:
    """One AdamW step.  Returns (new_params, new_state, grad_norm)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)) + 1e-16
    )
    scale = jnp.minimum(1.0, cfg.clip_norm / gnorm) if cfg.clip_norm > 0 else 1.0
    grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    m = jax.tree.map(lambda a, g: cfg.b1 * a + (1 - cfg.b1) * g, state.m, grads)
    v = jax.tree.map(lambda a, g: cfg.b2 * a + (1 - cfg.b2) * g * g, state.v, grads)
    lr = cfg.lr * lr_scale

    def upd(p, mm, vv):
        mh = mm / b1c
        vh = vv / b2c
        return (
            p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        ).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step=step, m=m, v=v), gnorm
