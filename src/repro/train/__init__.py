from .optimizer import AdamWConfig, adamw_init, adamw_update
from .train_step import TrainState, make_train_step, init_train_state
from . import schedule

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update",
    "TrainState", "make_train_step", "init_train_state",
    "schedule",
]
