"""Training step: loss -> grads -> AdamW, with microbatch accumulation,
remat (inside the model's layer scan), and mixed precision (fp32 masters,
bf16 compute — the cast happens in the model's forward).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.model import Model
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update
from . import schedule as schedules

__all__ = ["TrainState", "make_train_step", "init_train_state"]


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(model: Model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw_init(params))


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig = AdamWConfig(),
    schedule: Callable = schedules.warmup_cosine,
    grad_accum: int = 1,
):
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``grad_accum > 1`` splits the batch into microbatches along axis 0 and
    accumulates grads in fp32 via lax.scan (constant memory in #microbatches).
    """

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(state.params, mb)
                return (
                    jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g),
                    l_acc + l,
                ), None

            mbs = jax.tree.map(
                lambda a: a.reshape(grad_accum, a.shape[0] // grad_accum, *a.shape[1:]),
                batch,
            )
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (grads, loss), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum
            metrics = {}
        lr_scale = schedule(state.opt.step)
        params, opt, gnorm = adamw_update(state.params, grads, state.opt, opt_cfg, lr_scale)
        out = {"loss": loss, "grad_norm": gnorm, "lr_scale": lr_scale}
        out.update({k: v for k, v in (metrics or {}).items()})
        return TrainState(params=params, opt=opt), out

    return train_step
