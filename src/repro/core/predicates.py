"""Predicate IR for filtered ANN queries.

A filtered ANN query is ``(Q, P, k)`` (paper §3).  ``P`` is a predicate over
the metadata record attached to each vector.  The paper supports:

* single-label equality            ``color = green``
* conjunctions of labels           ``color = green AND type = shoes``
* numeric range                    ``age > 20 AND age < 25``
* unions of ranges on ONE attr     ``(20 < age < 25) OR age < 10``
* mixed label + range              ``color = green AND price < 30``

Metadata layout (columnar, fixed dtypes so everything vectorises):

* categorical attributes -> int32 codes, array ``cat``  of shape (N, A_cat)
* numeric attributes     -> float32,     array ``num``  of shape (N, A_num)

Evaluation returns a boolean mask of shape (N,).  Masks — not compacted
index lists — are the TPU-native filtered-search currency (DESIGN.md §2);
the numpy path additionally offers ``nonzero`` compaction for the CPU
pre-filter executor.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

__all__ = [
    "LabelEq",
    "RangePred",
    "Predicate",
    "label_ids",
    "NULL_CODE",
]

# Code used for "attribute missing" in categorical columns.
NULL_CODE = -1


@dataclasses.dataclass(frozen=True)
class LabelEq:
    """``attr == code`` over a categorical attribute."""

    attr: int  # categorical attribute index
    code: int  # value code within that attribute's dictionary

    def eval(self, cat: np.ndarray, num: np.ndarray) -> np.ndarray:
        return cat[:, self.attr] == self.code


@dataclasses.dataclass(frozen=True)
class RangePred:
    """Union of half-open intervals ``lo <= x < hi`` over ONE numeric attribute.

    ``intervals`` is a tuple of (lo, hi) pairs; the union is the full query
    range (paper §3.2.2: multi-range predicates are unions over the same
    attribute).  A single interval is the common case.
    """

    attr: int  # numeric attribute index
    intervals: Tuple[Tuple[float, float], ...]

    def __post_init__(self):
        ivs = tuple(sorted((float(lo), float(hi)) for lo, hi in self.intervals))
        object.__setattr__(self, "intervals", ivs)

    @property
    def total_width(self) -> float:
        return float(sum(hi - lo for lo, hi in self.intervals))

    @property
    def midpoint(self) -> float:
        los = min(lo for lo, _ in self.intervals)
        his = max(hi for _, hi in self.intervals)
        return 0.5 * (los + his)

    def eval(self, cat: np.ndarray, num: np.ndarray) -> np.ndarray:
        x = num[:, self.attr]
        m = np.zeros(x.shape[0], dtype=bool)
        for lo, hi in self.intervals:
            m |= (x >= lo) & (x < hi)
        return m


@dataclasses.dataclass(frozen=True)
class Predicate:
    """Conjunction of label predicates and range predicates (the paper's
    predicate class).  ``labels`` AND ``ranges`` must all hold."""

    labels: Tuple[LabelEq, ...] = ()
    ranges: Tuple[RangePred, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "labels", tuple(self.labels))
        object.__setattr__(self, "ranges", tuple(self.ranges))

    # ---- classification used by the selectivity-estimator router ----
    @property
    def n_labels(self) -> int:
        return len(self.labels)

    @property
    def n_ranges(self) -> int:
        return len(self.ranges)

    @property
    def kind(self) -> str:
        if self.n_ranges == 0:
            return "label"
        if self.n_labels == 0:
            return "range"
        return "mixed"

    # ---- evaluation -------------------------------------------------
    def eval(self, cat: np.ndarray, num: np.ndarray) -> np.ndarray:
        n = cat.shape[0] if cat.size else num.shape[0]
        m = np.ones(n, dtype=bool)
        for p in self.labels:
            m &= p.eval(cat, num)
        for p in self.ranges:
            m &= p.eval(cat, num)
        return m

    def selectivity(self, cat: np.ndarray, num: np.ndarray) -> float:
        """Ground-truth selectivity (fraction of points passing)."""
        return float(self.eval(cat, num).mean())

    def __str__(self) -> str:  # debugging sugar
        parts = [f"c{p.attr}={p.code}" for p in self.labels]
        for r in self.ranges:
            parts.append(
                "n%d in %s" % (r.attr, "|".join(f"[{lo:.3g},{hi:.3g})" for lo, hi in r.intervals))
            )
        return " AND ".join(parts) if parts else "TRUE"


def label_ids(pred: Predicate, cat_offsets: Sequence[int]) -> List[int]:
    """Map each LabelEq to a *global* label id: ``offset[attr] + code``.

    Global label ids index the flattened label space used by the frequency
    dictionary / co-occurrence matrix in :mod:`repro.core.stats`.
    """
    return [cat_offsets[p.attr] + p.code for p in pred.labels]
