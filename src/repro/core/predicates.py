"""Predicate IR for filtered ANN queries.

A filtered ANN query is ``(Q, P, k)`` (paper §3).  ``P`` is a predicate over
the metadata record attached to each vector.  The paper supports:

* single-label equality            ``color = green``
* conjunctions of labels           ``color = green AND type = shoes``
* numeric range                    ``age > 20 AND age < 25``
* unions of ranges on ONE attr     ``(20 < age < 25) OR age < 10``
* mixed label + range              ``color = green AND price < 30``

Beyond the paper, the IR is closed under disjunction and leaf negation in
**disjunctive normal form**: :class:`Or` is a union of conjunctions
(:class:`Predicate`), and each conjunction may carry negated leaves
(:class:`Not` over a ``LabelEq``/``RangePred``).  The original conjunctive
:class:`Predicate` is the degenerate one-term DNF and remains valid
everywhere unchanged.  ``repro.filter`` compiles any of these shapes to a
packed bitmap with exact popcount selectivity.

Metadata layout (columnar, fixed dtypes so everything vectorises):

* categorical attributes -> int32 codes, array ``cat``  of shape (N, A_cat)
* numeric attributes     -> float32,     array ``num``  of shape (N, A_num)

Evaluation returns a boolean mask of shape (N,).  Masks — not compacted
index lists — are the TPU-native filtered-search currency (DESIGN.md §2);
the numpy path additionally offers ``nonzero`` compaction for the CPU
pre-filter executor.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Sequence, Tuple, Union

import numpy as np

__all__ = [
    "LabelEq",
    "RangePred",
    "Not",
    "Predicate",
    "Or",
    "AnyPredicate",
    "iter_leaves",
    "label_ids",
    "NULL_CODE",
]

# Code used for "attribute missing" in categorical columns.
NULL_CODE = -1


def _n_rows(cat: np.ndarray, num: np.ndarray) -> int:
    """Corpus row count from the metadata arrays, robust to degenerate
    shapes: zero-attribute corpora arrive as (N, 0) — whose ``size`` is 0
    even though N > 0 — and no-attribute corpora may arrive as empty 1-D
    arrays.  Prefer the first 2-D operand's leading dim."""
    if cat.ndim >= 2:
        return cat.shape[0]
    if num.ndim >= 2:
        return num.shape[0]
    return max(
        cat.shape[0] if cat.ndim == 1 else 0,
        num.shape[0] if num.ndim == 1 else 0,
    )


@dataclasses.dataclass(frozen=True)
class LabelEq:
    """``attr == code`` over a categorical attribute."""

    attr: int  # categorical attribute index
    code: int  # value code within that attribute's dictionary

    def eval(self, cat: np.ndarray, num: np.ndarray) -> np.ndarray:
        return cat[:, self.attr] == self.code


@dataclasses.dataclass(frozen=True)
class RangePred:
    """Union of half-open intervals ``lo <= x < hi`` over ONE numeric attribute.

    ``intervals`` is a tuple of (lo, hi) pairs; the union is the full query
    range (paper §3.2.2: multi-range predicates are unions over the same
    attribute).  A single interval is the common case.  Construction
    canonicalises: empty intervals (hi <= lo) are dropped and
    overlapping/adjacent intervals merge, so ``total_width`` (a planner and
    selectivity feature) measures the true covered width — e.g.
    ``((0, 10), (5, 15))`` is stored as ``((0, 15),)`` with width 15, not 20.
    """

    attr: int  # numeric attribute index
    intervals: Tuple[Tuple[float, float], ...]

    def __post_init__(self):
        ivs = sorted(
            (float(lo), float(hi)) for lo, hi in self.intervals if float(hi) > float(lo)
        )
        merged: List[Tuple[float, float]] = []
        for lo, hi in ivs:
            if merged and lo <= merged[-1][1]:  # overlap or adjacency: one span
                merged[-1] = (merged[-1][0], max(merged[-1][1], hi))
            else:
                merged.append((lo, hi))
        object.__setattr__(self, "intervals", tuple(merged))

    @property
    def total_width(self) -> float:
        return float(sum(hi - lo for lo, hi in self.intervals))

    @property
    def midpoint(self) -> float:
        if not self.intervals:
            return 0.0
        los = min(lo for lo, _ in self.intervals)
        his = max(hi for _, hi in self.intervals)
        return 0.5 * (los + his)

    def eval(self, cat: np.ndarray, num: np.ndarray) -> np.ndarray:
        x = num[:, self.attr]
        m = np.zeros(x.shape[0], dtype=bool)
        for lo, hi in self.intervals:
            m |= (x >= lo) & (x < hi)
        return m


@dataclasses.dataclass(frozen=True)
class Not:
    """Negated leaf: ``NOT (attr == code)`` or ``NOT (x in ranges)``.

    Negation is restricted to leaves — combined with :class:`Predicate`
    (AND) and :class:`Or` (union of ANDs) this is exactly DNF, which is the
    class the bitmap compiler handles with one ANDNOT per negated leaf.
    """

    term: Union[LabelEq, RangePred]

    def eval(self, cat: np.ndarray, num: np.ndarray) -> np.ndarray:
        return ~self.term.eval(cat, num)


@dataclasses.dataclass(frozen=True)
class Predicate:
    """Conjunction of label predicates, range predicates and negated leaves
    (the paper's predicate class, extended with leaf negation).  ``labels``
    AND ``ranges`` AND ``nots`` must all hold."""

    labels: Tuple[LabelEq, ...] = ()
    ranges: Tuple[RangePred, ...] = ()
    nots: Tuple[Not, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "labels", tuple(self.labels))
        object.__setattr__(self, "ranges", tuple(self.ranges))
        object.__setattr__(self, "nots", tuple(self.nots))

    # ---- classification used by the selectivity-estimator router ----
    @property
    def n_labels(self) -> int:
        return len(self.labels) + sum(
            1 for p in self.nots if isinstance(p.term, LabelEq)
        )

    @property
    def n_ranges(self) -> int:
        return len(self.ranges) + sum(
            1 for p in self.nots if isinstance(p.term, RangePred)
        )

    @property
    def kind(self) -> str:
        if self.n_ranges == 0:
            return "label"
        if self.n_labels == 0:
            return "range"
        return "mixed"

    # ---- evaluation -------------------------------------------------
    def eval(self, cat: np.ndarray, num: np.ndarray) -> np.ndarray:
        m = np.ones(_n_rows(cat, num), dtype=bool)
        for p in self.labels:
            m &= p.eval(cat, num)
        for p in self.ranges:
            m &= p.eval(cat, num)
        for p in self.nots:
            m &= p.eval(cat, num)
        return m

    def selectivity(self, cat: np.ndarray, num: np.ndarray) -> float:
        """Ground-truth selectivity (fraction of points passing); 0.0 on an
        empty corpus (no points, so no passing fraction to speak of)."""
        m = self.eval(cat, num)
        return float(m.mean()) if m.size else 0.0

    def __str__(self) -> str:  # debugging sugar
        parts = [f"c{p.attr}={p.code}" for p in self.labels]
        for r in self.ranges:
            parts.append(
                "n%d in %s" % (r.attr, "|".join(f"[{lo:.3g},{hi:.3g})" for lo, hi in r.intervals))
            )
        for p in self.nots:
            t = p.term
            if isinstance(t, LabelEq):
                parts.append(f"NOT c{t.attr}={t.code}")
            else:
                parts.append(
                    "NOT n%d in %s"
                    % (t.attr, "|".join(f"[{lo:.3g},{hi:.3g})" for lo, hi in t.intervals))
                )
        return " AND ".join(parts) if parts else "TRUE"


def _coerce_term(t) -> Predicate:
    if isinstance(t, Predicate):
        return t
    if isinstance(t, LabelEq):
        return Predicate(labels=(t,))
    if isinstance(t, RangePred):
        return Predicate(ranges=(t,))
    if isinstance(t, Not):
        return Predicate(nots=(t,))
    raise TypeError(f"Or term must be a Predicate or leaf, got {type(t).__name__}")


@dataclasses.dataclass(frozen=True)
class Or:
    """Disjunction of conjunctions — DNF over ``LabelEq``/``RangePred``
    leaves.  Bare leaves coerce to single-leaf conjunctions, so
    ``Or((LabelEq(0, 1), pred))`` reads naturally.  ``Or(())`` is FALSE."""

    terms: Tuple[Predicate, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "terms", tuple(_coerce_term(t) for t in self.terms))

    @property
    def n_terms(self) -> int:
        return len(self.terms)

    @property
    def kind(self) -> str:
        kinds = {t.kind for t in self.terms}
        return kinds.pop() if len(kinds) == 1 else "mixed"

    def eval(self, cat: np.ndarray, num: np.ndarray) -> np.ndarray:
        m = np.zeros(_n_rows(cat, num), dtype=bool)
        for t in self.terms:
            m |= t.eval(cat, num)
        return m

    def selectivity(self, cat: np.ndarray, num: np.ndarray) -> float:
        m = self.eval(cat, num)
        return float(m.mean()) if m.size else 0.0

    def __str__(self) -> str:
        return " OR ".join(f"({t})" for t in self.terms) if self.terms else "FALSE"


# Anything the engine/executors accept as "a predicate".
AnyPredicate = Union[Predicate, Or]


def iter_leaves(pred: AnyPredicate) -> Iterator[Union[LabelEq, RangePred]]:
    """Every leaf in the DNF, negated or not (coverage checks, compilers)."""
    terms = pred.terms if isinstance(pred, Or) else (pred,)
    for t in terms:
        yield from t.labels
        yield from t.ranges
        for n in t.nots:
            yield n.term


def label_ids(pred: Predicate, cat_offsets: Sequence[int]) -> List[int]:
    """Map each (positive) LabelEq to a *global* label id:
    ``offset[attr] + code``.

    Global label ids index the flattened label space used by the frequency
    dictionary / co-occurrence matrix in :mod:`repro.core.stats`.
    """
    return [cat_offsets[p.attr] + p.code for p in pred.labels]
