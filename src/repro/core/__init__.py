from .predicates import LabelEq, Predicate, RangePred, Not, Or, AnyPredicate, iter_leaves, NULL_CODE
from .stats import DatasetStats
from .corpus import CompactionPolicy, LiveCorpus
from .selectivity import SelEstimate, SelectivityEstimator
from .planner import CorePlanner, PlannerFeatures, PRE_FILTER, POST_FILTER, INDEXED_PRE
from .plan import ClausePlan, ExecutionPlan, NO_ROUTE, STRATEGY_NAMES, format_plan
from .executors import (
    PreFilterExec, IndexedPreFilterExec, PostFilterExec,
    SearchResult, recall_at_k,
)
from .engine import (
    FilteredANNEngine, EngineConfig, PlannedResult, QueryResult, CorpusShard,
    QueryLabel,
)
from .trainer import gen_queries, gen_predicate
from .gbm import GradientBoostingRegressor

__all__ = [
    "LabelEq", "Predicate", "RangePred", "Not", "Or", "AnyPredicate",
    "iter_leaves", "NULL_CODE",
    "DatasetStats", "SelEstimate", "SelectivityEstimator",
    "CompactionPolicy", "LiveCorpus",
    "CorePlanner", "PlannerFeatures", "PRE_FILTER", "POST_FILTER", "INDEXED_PRE",
    "ClausePlan", "ExecutionPlan", "NO_ROUTE", "STRATEGY_NAMES", "format_plan",
    "PreFilterExec", "IndexedPreFilterExec", "PostFilterExec",
    "SearchResult", "recall_at_k",
    "FilteredANNEngine", "EngineConfig", "PlannedResult", "QueryResult",
    "CorpusShard", "QueryLabel",
    "gen_queries", "gen_predicate",
    "GradientBoostingRegressor",
]
