from .predicates import LabelEq, Predicate, RangePred, Not, Or, AnyPredicate, iter_leaves, NULL_CODE
from .stats import DatasetStats
from .corpus import CompactionPolicy, LiveCorpus
from .selectivity import SelectivityEstimator
from .planner import CorePlanner, PlannerFeatures, PRE_FILTER, POST_FILTER, INDEXED_PRE
from .executors import (
    PreFilterExec, IndexedPreFilterExec, PostFilterExec,
    SearchResult, recall_at_k,
)
from .engine import FilteredANNEngine, EngineConfig, PlannedResult, CorpusShard, QueryLabel
from .trainer import gen_queries, gen_predicate
from .gbm import GradientBoostingRegressor

__all__ = [
    "LabelEq", "Predicate", "RangePred", "Not", "Or", "AnyPredicate",
    "iter_leaves", "NULL_CODE",
    "DatasetStats", "SelectivityEstimator",
    "CompactionPolicy", "LiveCorpus",
    "CorePlanner", "PlannerFeatures", "PRE_FILTER", "POST_FILTER", "INDEXED_PRE",
    "PreFilterExec", "IndexedPreFilterExec", "PostFilterExec",
    "SearchResult", "recall_at_k",
    "FilteredANNEngine", "EngineConfig", "PlannedResult", "CorpusShard", "QueryLabel",
    "gen_queries", "gen_predicate",
    "GradientBoostingRegressor",
]
