"""Structured query plans: per-disjunct DNF planning behind ``ExecutionPlan``.

The planner used to speak in raw ints — a single ``decision`` plus a
positional ``(backend, knob)`` pair threaded through every layer.  That
representation cannot express the per-disjunct plans the paper's §3 planner
wants for DNF predicates: ``Or((a, b))`` may be cheapest with clause ``a``
pre-filtered (tiny exact mask) while clause ``b`` post-filters through a
routed IVF backend.  This module is the structured replacement:

* :class:`ClausePlan` — the plan for ONE conjunctive disjunct: its canonical
  clause key, the §3.2 strategy decision, the resolved ``(backend, knob)``
  execution class, the selectivity estimate it was planned under, and the
  routing-head class index (``NO_ROUTE`` when routing is off / non-post).
* :class:`ExecutionPlan` — an ordered tuple of clause plans plus a merge
  spec.  ``merge == "none"`` is the classic whole-predicate plan (one
  clause, bit-identical to the legacy path); ``merge == "union"`` means the
  clauses execute independently as ordinary decision groups and their
  top-k lists are merged with cross-clause de-duplication
  (:func:`repro.dist.collectives.merge_topk_unique`).

Clause plans are keyed by :func:`repro.filter.cache.canonical_key` of their
disjunct, NOT by term position: ``Or`` predicates that differ only in term
order share a plan-cache entry, so execution must align concrete terms to
clause plans via the key.

The legacy read-back surface (``decision`` / ``backend`` / ``knob`` /
``route``) survives as properties so downstream consumers (telemetry,
scheduler service model, fleet fair-share) keep working: a multi-clause
plan reports its *dominant* clause decision (the clause with the largest
estimated selectivity — the one that bounds service time) and the synthetic
``("dnf", "")`` backend class.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .planner import INDEXED_PRE, POST_FILTER, PRE_FILTER

STRATEGY_NAMES = {PRE_FILTER: "pre", POST_FILTER: "post", INDEXED_PRE: "ipre"}

#: routing-head sentinel: the row was not (or could not be) routed to a
#: concrete backend class.
NO_ROUTE = -1


def default_route_name(decision: int) -> Tuple[str, str]:
    """Backend/knob pair implied by a decision when routing is off."""
    if decision == POST_FILTER:
        return "ivf", "adapt"
    return "flat", "exact"


@dataclasses.dataclass(frozen=True)
class ClausePlan:
    """Plan for one conjunctive disjunct of a (possibly DNF) predicate."""

    clause_key: Tuple          # canonical_key of the disjunct
    decision: int              # PRE_FILTER / POST_FILTER / INDEXED_PRE
    backend: str               # resolved execution class, e.g. "ivf"
    knob: str                  # e.g. "adapt", "exact", an IVF nprobe tier
    est: float                 # estimated selectivity the plan was made under
    route: int = NO_ROUTE      # routing-head class index, NO_ROUTE if unrouted
    sel_exact: bool = False    # estimate came from a covering bitmap popcount


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A small tree: clause plans + how to combine their results.

    ``merge``:
      * ``"none"``  — single whole-predicate clause; execute directly.
      * ``"union"`` — per-disjunct DNF: execute each clause as its own
        decision-group row, then merge the per-clause top-k lists with
        cross-clause de-duplication (a row matching two disjuncts appears
        once, at its best distance).
    """

    clauses: Tuple[ClausePlan, ...]
    est: float                 # whole-predicate selectivity estimate
    sel_exact: bool            # whole-predicate estimate is exact
    merge: str = "none"        # "none" | "union"

    @property
    def n_clauses(self) -> int:
        return len(self.clauses)

    @property
    def is_dnf(self) -> bool:
        return self.merge == "union"

    def _dominant(self) -> ClausePlan:
        return max(self.clauses, key=lambda c: c.est)

    # legacy read-back surface ------------------------------------------------
    @property
    def decision(self) -> int:
        """Single-clause: that clause's decision.  DNF: the dominant
        (largest-est) clause's decision — the one that bounds service time."""
        if not self.clauses:
            return PRE_FILTER
        if len(self.clauses) == 1:
            return self.clauses[0].decision
        return self._dominant().decision

    @property
    def backend(self) -> str:
        if self.is_dnf:
            return "dnf"
        return self.clauses[0].backend if self.clauses else ""

    @property
    def knob(self) -> str:
        if self.is_dnf:
            return ""
        return self.clauses[0].knob if self.clauses else ""

    @property
    def route(self) -> int:
        if self.is_dnf or not self.clauses:
            return NO_ROUTE
        return self.clauses[0].route

    @property
    def strategy(self) -> str:
        """Name used in result rows / telemetry: "pre"/"post"/"ipre"/"dnf"."""
        return "dnf" if self.is_dnf else STRATEGY_NAMES[self.decision]


def clause_predicates(pred, plan: ExecutionPlan) -> List:
    """Concrete sub-predicates aligned with ``plan.clauses``.

    For ``merge == "none"`` this is just ``[pred]``.  For a DNF plan the
    clauses were planned over the *unique* disjuncts in first-occurrence
    order; terms are matched back by canonical key because ``Or`` terms that
    hash to the same plan-cache entry may be ordered differently."""
    from ..filter.cache import canonical_key

    if plan.merge == "none":
        return [pred]
    by_key = {}
    for t in getattr(pred, "terms", ()):
        by_key.setdefault(canonical_key(t), t)
    return [by_key[c.clause_key] for c in plan.clauses]


def expand_for_execution(preds: Sequence, plans: Sequence[ExecutionPlan]):
    """Flatten per-row plans into per-clause execution rows.

    Returns ``(exp_rows, exp_preds, decisions, ests, routes, row_map)`` where
    ``exp_rows[j]`` is the original batch row clause ``j`` belongs to (index
    the query matrix with it) and ``row_map[i]`` lists the expanded rows that
    must be collapsed back into original row ``i``.  Single-clause rows
    expand to themselves, so a batch with no DNF plans round-trips as the
    identity (same preds, same decisions — the legacy fast path)."""
    exp_rows: List[int] = []
    exp_preds: List = []
    decisions: List[int] = []
    ests: List[float] = []
    routes: List[int] = []
    row_map: List[List[int]] = []
    for i, (pred, plan) in enumerate(zip(preds, plans)):
        cps = clause_predicates(pred, plan)
        rows = []
        for cp, cl in zip(cps, plan.clauses):
            rows.append(len(exp_preds))
            exp_rows.append(i)
            exp_preds.append(cp)
            decisions.append(cl.decision)
            ests.append(cl.est)
            routes.append(cl.route)
        row_map.append(rows)
    return (np.asarray(exp_rows, np.int64), exp_preds,
            np.asarray(decisions, np.int32), np.asarray(ests, np.float64),
            np.asarray(routes, np.int32), row_map)


def collapse_clause_results(d: np.ndarray, ids: np.ndarray,
                            rounds: np.ndarray, row_map: List[List[int]],
                            k: int):
    """Collapse expanded per-clause rows back to one row per original query.

    Multi-clause rows merge their clause top-k lists with cross-clause
    de-duplication; single-clause rows pass through untouched.  Rows whose
    clause lists share ids keep each id once at its best (lowest-key)
    occurrence, so the exact tier reproduces the whole-predicate union-mask
    scan bit-for-bit."""
    from ..dist.collectives import merge_topk_unique

    if all(len(rows) == 1 for rows in row_map):
        return d, ids, rounds
    b = len(row_map)
    out_d = np.full((b, k), np.inf, np.float32)
    out_i = np.full((b, k), -1, np.int32)
    out_r = np.zeros(b, dtype=rounds.dtype if rounds is not None else np.int32)
    # group multi-clause rows by clause count so each group merges in one
    # vectorised merge_topk_unique call
    groups: dict = {}
    for i, rows in enumerate(row_map):
        if len(rows) == 1:
            out_d[i], out_i[i] = d[rows[0]], ids[rows[0]]
            out_r[i] = rounds[rows[0]]
        elif rows:
            groups.setdefault(len(rows), []).append(i)
        # len(rows) == 0: empty Or — stays at the all-padding row
    for c, members in groups.items():
        dd = np.stack([d[row_map[i]] for i in members], axis=1)    # (c, m, k)
        ii = np.stack([ids[row_map[i]] for i in members], axis=1)
        md, mi = merge_topk_unique(dd, ii, k)
        out_d[members], out_i[members] = md, mi
        out_r[members] = [int(rounds[row_map[i]].max()) for i in members]
    return out_d, out_i, out_r


def format_plan(plan: ExecutionPlan, pred=None) -> str:
    """Render a plan as a small tree — ``engine.explain`` / ``--explain``."""
    head = (f"ExecutionPlan merge={plan.merge} clauses={plan.n_clauses} "
            f"est={plan.est:.4f}{' (exact)' if plan.sel_exact else ''}")
    cps: Optional[List] = None
    if pred is not None:
        try:
            cps = clause_predicates(pred, plan)
        except (KeyError, ImportError):
            cps = None
    lines = [head]
    for j, cl in enumerate(plan.clauses):
        branch = "└─" if j == len(plan.clauses) - 1 else "├─"
        what = f" {cps[j]}" if cps is not None else ""
        route = f" route={cl.route}" if cl.route != NO_ROUTE else ""
        lines.append(
            f"{branch} clause[{j}]{what} -> {STRATEGY_NAMES[cl.decision]} "
            f"backend={cl.backend}:{cl.knob} est={cl.est:.4f}"
            f"{' (exact)' if cl.sel_exact else ''}{route}")
    return "\n".join(lines)


__all__ = [
    "PRE_FILTER", "POST_FILTER", "INDEXED_PRE", "STRATEGY_NAMES", "NO_ROUTE",
    "ClausePlan", "ExecutionPlan", "clause_predicates", "collapse_clause_results",
    "default_route_name", "expand_for_execution", "format_plan",
]
