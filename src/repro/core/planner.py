"""Core planner: learned execution-strategy classifier (paper §3.3).

A two-hidden-layer MLP (widths 64 and 32, ReLU, softmax) maps query+dataset
features to a binary decision: PRE_FILTER (0) vs POST_FILTER (1).  Trained
with Adam (lr 1e-3), batch size 200, up to 500 epochs, L2 regularisation and
early stopping; the L2 strength is grid-searched with cross-validated
ROC-AUC as the objective (paper's "small grid search").

On top of the learned 2-way head, :meth:`CorePlanner.decide` is **3-way**:
queries the head routes to pre-filtering are promoted to INDEXED_PRE (2)
when the predicate is covered by the corpus's attribute index (the
``sel_is_exact`` feature) — a cost-heuristic calibration rather than a
retrained head, because covered bitmap evaluation (O(N/32) word ops per
leaf, ~free on a predicate-cache hit) strictly dominates the O(N·leaves)
columnar scan that plain pre-filtering pays, while the downstream top-k is
identical.  The pre-vs-post boundary the head learned is untouched.

Pure JAX (no flax/optax available offline): params are a pytree dict, the
update step is jit-compiled, inference is one fused matmul chain — the
"minimal inference overhead" property the paper claims.

**Routing head** (the (plan, backend, knob) extension): rows the plan head
sends to post-filtering may additionally be routed to one of the engine's
registered (backend, knob-tier) classes.  The router is a deterministic
multinomial softmax regression trained by :meth:`CorePlanner.fit_routing` on
§3.1 utility-race argmax labels — kept OUTSIDE the jitted MLP pytree so (a)
legacy 2-way behaviour is bit-unchanged when no routing head is fitted, and
(b) planner checkpoints written before the routing head load and serve
plan-only (``state_dict``/``load_state`` treat the ``route`` subtree as
optional).  Routing class names travel through checkpoints as a fixed-width
uint8 byte matrix because the checkpointer converts every leaf with
``jnp.asarray`` (unicode arrays would fail there).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .predicates import Predicate
from .stats import DatasetStats
from .util import next_pow2

__all__ = [
    "CorePlanner", "PlannerFeatures",
    "PRE_FILTER", "POST_FILTER", "INDEXED_PRE",
    "roc_auc",
]

PRE_FILTER = 0
POST_FILTER = 1
INDEXED_PRE = 2     # pre-filter via the bitmap attribute index (repro.filter)

_HIDDEN = (64, 32)   # paper §3.3
_EPOCHS = 500
_BATCH = 200
_LR = 1e-3
_PATIENCE = 15

# routing head: full-batch GD softmax regression, fixed iteration count —
# deterministic by construction (no jit, float64 accumulation)
_ROUTE_ITERS = 400
_ROUTE_LR = 0.5
_ROUTE_L2 = 1e-3


def _encode_names(names: Sequence[str]) -> np.ndarray:
    """Class names -> fixed-width uint8 matrix (checkpoint-safe: survives
    ``jnp.asarray`` where unicode dtypes would not)."""
    bs = [n.encode("utf-8") for n in names]
    width = max(len(b) for b in bs) if bs else 1
    out = np.zeros((len(bs), width), np.uint8)
    for i, b in enumerate(bs):
        out[i, : len(b)] = np.frombuffer(b, np.uint8)
    return out


def _decode_names(arr: np.ndarray) -> Tuple[str, ...]:
    a = np.asarray(arr, np.uint8)
    return tuple(bytes(row).rstrip(b"\x00").decode("utf-8") for row in a)


def roc_auc(y_true: np.ndarray, scores: np.ndarray) -> float:
    """ROC-AUC via the rank statistic (Mann-Whitney U)."""
    y_true = np.asarray(y_true)
    scores = np.asarray(scores, dtype=np.float64)
    pos = scores[y_true == 1]
    neg = scores[y_true == 0]
    if pos.size == 0 or neg.size == 0:
        return 0.5
    order = np.argsort(np.concatenate([pos, neg]), kind="mergesort")
    ranks = np.empty(order.size)
    ranks[order] = np.arange(1, order.size + 1)
    # midranks for ties
    allv = np.concatenate([pos, neg])
    sorted_v = np.sort(allv)
    uniq, start = np.unique(sorted_v, return_index=True)
    for i, v in enumerate(uniq):
        end = start[i + 1] if i + 1 < uniq.size else sorted_v.size
        tie_rows = allv == v
        ranks[tie_rows] = 0.5 * (start[i] + 1 + end)
    r_pos = ranks[: pos.size].sum()
    u = r_pos - pos.size * (pos.size + 1) / 2.0
    return float(u / (pos.size * neg.size))


# ----------------------------------------------------------------------
# feature construction
# ----------------------------------------------------------------------
@dataclasses.dataclass
class PlannerFeatures:
    """Feature extractor: dataset stats + per-query predicate info."""

    stats: DatasetStats

    N_FEATURES = 10
    SEL_COL = 3          # estimated selectivity
    SEL_EXACT_COL = 9    # 1.0 when the estimate is an exact index popcount

    def vector(self, pred: Predicate, est_sel: float, k: int,
               sel_exact: bool = False) -> np.ndarray:
        st = self.stats
        kind_onehot = {"label": (1, 0, 0), "range": (0, 1, 0), "mixed": (0, 0, 1)}[pred.kind]
        return np.array(
            [
                np.log10(max(st.n, 1)),          # corpus size
                st.dim / 1000.0,                 # dimensionality
                st.dist_measure,                 # vector-distribution measure
                est_sel,                         # estimated selectivity
                np.log10(est_sel + 1e-6),        # log-scale selectivity
                np.log2(max(k, 1)),              # requested k
                *kind_onehot,                    # predicate type
                float(sel_exact),                # exact index-backed selectivity?
            ],
            dtype=np.float32,
        )

    _KIND_COL = {"label": 6, "range": 7, "mixed": 8}

    def matrix(self, preds: Sequence[Predicate], est_sels: np.ndarray, k: int,
               sel_exact: Optional[np.ndarray] = None) -> np.ndarray:
        """Batched :meth:`vector`: one (B, F) matrix, row i == vector(preds[i]).

        Dataset-level features broadcast; selectivity features compute in
        float64 before the float32 cast, matching the scalar path exactly.
        """
        b = len(preds)
        st = self.stats
        es = np.asarray(est_sels, np.float64)
        f = np.zeros((b, self.N_FEATURES), np.float32)
        f[:, 0] = np.log10(max(st.n, 1))
        f[:, 1] = st.dim / 1000.0
        f[:, 2] = st.dist_measure
        f[:, 3] = es
        f[:, 4] = np.log10(es + 1e-6)
        f[:, 5] = np.log2(max(k, 1))
        for i, p in enumerate(preds):
            f[i, self._KIND_COL[p.kind]] = 1.0
        if sel_exact is not None:
            f[:, self.SEL_EXACT_COL] = np.asarray(sel_exact, np.float32)
        return f


# ----------------------------------------------------------------------
# the MLP
# ----------------------------------------------------------------------
def _init_params(key: jax.Array, n_features: int) -> Dict[str, jax.Array]:
    k1, k2, k3 = jax.random.split(key, 3)
    h1, h2 = _HIDDEN

    def glorot(k, fan_in, fan_out):
        s = jnp.sqrt(2.0 / (fan_in + fan_out))
        return jax.random.normal(k, (fan_in, fan_out), jnp.float32) * s

    return {
        "w1": glorot(k1, n_features, h1), "b1": jnp.zeros(h1),
        "w2": glorot(k2, h1, h2), "b2": jnp.zeros(h2),
        "w3": glorot(k3, h2, 2), "b3": jnp.zeros(2),
    }


def _logits(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    return h @ params["w3"] + params["b3"]


def _loss(params, x, y, l2):
    lg = _logits(params, x)
    ce = -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(y.shape[0]), y])
    reg = sum(jnp.sum(p**2) for n, p in params.items() if n.startswith("w"))
    return ce + l2 * reg


@partial(jax.jit, static_argnames=())
def _adam_step(params, opt_state, x, y, l2, lr, step):
    """One Adam update (b1=.9, b2=.999)."""
    grads = jax.grad(_loss)(params, x, y, l2)
    m, v = opt_state
    m = jax.tree.map(lambda a, g: 0.9 * a + 0.1 * g, m, grads)
    v = jax.tree.map(lambda a, g: 0.999 * a + 0.001 * g * g, v, grads)
    mh = jax.tree.map(lambda a: a / (1 - 0.9**step), m)
    vh = jax.tree.map(lambda a: a / (1 - 0.999**step), v)
    params = jax.tree.map(lambda p, a, b: p - lr * a / (jnp.sqrt(b) + 1e-8), params, mh, vh)
    return params, (m, v)


class CorePlanner:
    """Binary execution-strategy classifier."""

    def __init__(self, n_features: int = PlannerFeatures.N_FEATURES, seed: int = 0):
        self.n_features = n_features
        # The learned head sees every feature EXCEPT the sel_is_exact flag,
        # which only drives the indexed-pre promotion in :meth:`decide`.
        # Keeping it out of the MLP (a) guarantees the promotion can never
        # shift the learned pre-vs-post boundary and (b) avoids feeding the
        # net a column that is constant on fully-indexed corpora (whose
        # near-zero std would explode under feature normalisation the moment
        # an uncovered predicate arrives).
        self._head_cols = [
            i for i in range(n_features) if i != PlannerFeatures.SEL_EXACT_COL
        ]
        self.n_head = len(self._head_cols)
        self.seed = seed
        self.params: Optional[Dict[str, jax.Array]] = None
        self.mu = np.zeros(self.n_head, np.float32)
        self.sigma = np.ones(self.n_head, np.float32)
        self.best_l2_: float = 1e-4
        self.val_auc_: float = 0.5
        # bumped by fit(): decisions change when the head retrains in place,
        # so anything memoising decisions (the engine's PlanCache) keys its
        # validity on this generation (mirrors SelectivityEstimator.generation)
        self.generation = 0
        # routing head (fit_routing): None until trained — plan-only serving
        self._route: Optional[Dict[str, np.ndarray]] = None
        self._route_classes: Optional[Tuple[str, ...]] = None
        self._predict_jit = jax.jit(lambda p, x: jax.nn.softmax(_logits(p, x))[:, 1])

    # ------------------------------------------------------------------
    def _train_once(self, x, y, l2, seed, val_x=None, val_y=None):
        key = jax.random.PRNGKey(seed)
        params = _init_params(key, self.n_head)
        m = jax.tree.map(jnp.zeros_like, params)
        v = jax.tree.map(jnp.zeros_like, params)
        opt_state = (m, v)
        n = x.shape[0]
        rng = np.random.default_rng(seed)
        best_metric, best_params, bad, step = -np.inf, params, 0, 0
        xj, yj = jnp.asarray(x), jnp.asarray(y)
        for epoch in range(_EPOCHS):
            perm = rng.permutation(n)
            for s in range(0, n, _BATCH):
                idx = perm[s : s + _BATCH]
                step += 1
                params, opt_state = _adam_step(
                    params, opt_state, xj[idx], yj[idx], l2, _LR, step
                )
            if val_x is not None and val_x.shape[0]:
                scores = np.asarray(self._predict_jit(params, jnp.asarray(val_x)))
                metric = roc_auc(val_y, scores)
            else:
                metric = -float(_loss(params, xj, yj, 0.0))
            if metric > best_metric + 1e-5:
                best_metric, best_params, bad = metric, params, 0
            else:
                bad += 1
                if bad >= _PATIENCE:
                    break
        return best_params, best_metric

    def fit(
        self,
        features: np.ndarray,
        labels: np.ndarray,
        l2_grid: Sequence[float] = (1e-4, 1e-3),
        n_folds: int = 2,
    ) -> "CorePlanner":
        x = np.asarray(features, np.float32)[:, self._head_cols]
        y = np.asarray(labels, np.int32)
        self.mu = x.mean(0)
        self.sigma = x.std(0) + 1e-6
        xn = (x - self.mu) / self.sigma

        # small grid search over L2 with k-fold CV, ROC-AUC objective
        n = xn.shape[0]
        if n >= 3 * n_folds and len(set(y.tolist())) > 1:
            folds = np.arange(n) % n_folds
            rng = np.random.default_rng(self.seed)
            folds = folds[rng.permutation(n)]
            best_auc, best_l2 = -np.inf, l2_grid[0]
            for l2 in l2_grid:
                aucs = []
                for f in range(n_folds):
                    tr, va = folds != f, folds == f
                    if y[va].min() == y[va].max():
                        continue
                    p, auc = self._train_once(xn[tr], y[tr], l2, self.seed + f, xn[va], y[va])
                    aucs.append(auc)
                mean_auc = float(np.mean(aucs)) if aucs else -np.inf
                if mean_auc > best_auc:
                    best_auc, best_l2 = mean_auc, l2
            self.best_l2_, self.val_auc_ = best_l2, best_auc
        # final fit on all data with the selected L2 (held-out slice for early
        # stop).  The holdout must leave a non-empty train split: with n <= 4
        # examples max(4, n//10) would swallow everything and _train_once
        # would run on zero rows (NaN loss) — skip the holdout instead.
        n_val = max(4, n // 10)
        if n_val >= n:
            n_val = 0
        perm = np.random.default_rng(self.seed).permutation(n)
        va, tr = perm[:n_val], perm[n_val:]
        val_ok = n_val > 0 and len(set(y[va].tolist())) > 1
        self.params, _ = self._train_once(
            xn[tr], y[tr], self.best_l2_, self.seed,
            xn[va] if val_ok else None, y[va] if val_ok else None,
        )
        self.generation += 1
        return self

    # ------------------------------------------------------------------
    def predict_proba(self, features: np.ndarray) -> np.ndarray:
        """P(post-filter is the better strategy) per query.

        Accepts (F,) or (B, F); one jit dispatch either way.  The batch axis
        pads to the next power of two so serving sees O(log B) compiled
        shapes, not one per batch size.
        """
        assert self.params is not None, "planner not trained"
        x = np.atleast_2d(features).astype(np.float32)[:, self._head_cols]
        x = (x - self.mu) / self.sigma
        b = x.shape[0]
        bp = next_pow2(b)
        if bp != b:
            x = np.concatenate([x, np.zeros((bp - b, x.shape[1]), np.float32)])
        return np.asarray(self._predict_jit(self.params, jnp.asarray(x)))[:b]

    def decide(self, features: np.ndarray) -> np.ndarray:
        """3-way decision per query row: 0 = pre-filter (columnar scan),
        1 = post-filter, 2 = indexed pre-filter.

        The learned head stays 2-way (it was trained on pre-vs-post utility
        labels); the third plan is a cost-heuristic promotion on top: a row
        the head sends to pre-filtering runs INDEXED_PRE whenever its
        predicate is index-covered (``sel_is_exact`` feature set).  The
        calibration is the word-parallelism argument — a covered bitmap
        combine costs ~N/32 word ops per leaf (amortised to ~0 on a
        predicate-cache hit) versus the scan's ~N element compares per leaf,
        and both plans then run the identical subset top-k — so coverage
        alone decides, and the promotion can never flip pre vs post."""
        x = np.atleast_2d(np.asarray(features, np.float32))
        base = (self.predict_proba(x) >= 0.5).astype(np.int32)
        if x.shape[1] <= PlannerFeatures.SEL_EXACT_COL:
            return base                      # legacy feature layout: 2-way only
        promote = (base == PRE_FILTER) & (
            x[:, PlannerFeatures.SEL_EXACT_COL] >= 0.5
        )
        return np.where(promote, INDEXED_PRE, base).astype(np.int32)

    # ------------------------------------------------------------------
    # routing head: (backend, knob-tier) class on top of the plan decision
    # ------------------------------------------------------------------
    @property
    def route_classes(self) -> Optional[Tuple[str, ...]]:
        """The (backend:tier) class names the routing head was fitted over,
        or None when no routing head exists.  The engine only applies
        routing when these match its own BackendSet's class enumeration."""
        return self._route_classes

    def fit_routing(
        self,
        features: np.ndarray,
        route_labels: np.ndarray,
        class_names: Sequence[str],
        iters: int = _ROUTE_ITERS,
        lr: float = _ROUTE_LR,
        l2: float = _ROUTE_L2,
    ) -> "CorePlanner":
        """Fit the routing head on §3.1 utility-race argmax labels.

        ``route_labels`` are class indices into ``class_names``; rows with a
        negative label (no race ran) are ignored.  Unlike the plan head this
        uses ALL features including sel_is_exact — exactness of the
        selectivity estimate is informative for backend choice.  Plain
        full-batch float64 gradient descent with a fixed iteration count:
        bit-deterministic for a given (features, labels, class_names).
        """
        x = np.atleast_2d(np.asarray(features, np.float64))
        y = np.asarray(route_labels, np.int64).reshape(-1)
        keep = y >= 0
        x, y = x[keep], y[keep]
        n_classes = len(class_names)
        if x.shape[0] == 0 or n_classes == 0:
            return self
        mu = x.mean(0)
        sigma = x.std(0) + 1e-6
        xn = (x - mu) / sigma
        n, f = xn.shape
        w = np.zeros((f, n_classes), np.float64)
        b = np.zeros(n_classes, np.float64)
        onehot = np.zeros((n, n_classes), np.float64)
        onehot[np.arange(n), y] = 1.0
        for _ in range(iters):
            logits = xn @ w + b
            logits -= logits.max(axis=1, keepdims=True)
            p = np.exp(logits)
            p /= p.sum(axis=1, keepdims=True)
            g = (p - onehot) / n
            w -= lr * (xn.T @ g + l2 * w)
            b -= lr * g.sum(0)
        self._route = {
            "w": w.astype(np.float32),
            "b": b.astype(np.float32),
            "mu": mu.astype(np.float32),
            "sigma": sigma.astype(np.float32),
        }
        self._route_classes = tuple(class_names)
        self.generation += 1          # cached (plan, route) decisions are stale
        return self

    def route(self, features: np.ndarray) -> Optional[np.ndarray]:
        """Routing class index per row, or None when no head is fitted.
        Deterministic argmax (first index wins ties)."""
        if self._route is None:
            return None
        x = np.atleast_2d(np.asarray(features, np.float32)).astype(np.float64)
        r = self._route
        xn = (x - r["mu"].astype(np.float64)) / r["sigma"].astype(np.float64)
        logits = xn @ r["w"].astype(np.float64) + r["b"].astype(np.float64)
        return np.argmax(logits, axis=1).astype(np.int32)

    # ------------------------------------------------------------------
    # checkpoint state (numeric-leaf pytree, Checkpointer-compatible)
    # ------------------------------------------------------------------
    def state_dict(self) -> Dict:
        """Numeric-only pytree of the trained planner.  The ``route``
        subtree exists only when a routing head was fitted, so checkpoints
        written before the routing head existed stay loadable."""
        assert self.params is not None, "planner not trained"
        state: Dict = {
            "params": {k: np.asarray(v) for k, v in self.params.items()},
            "mu": np.asarray(self.mu),
            "sigma": np.asarray(self.sigma),
            "meta": np.asarray([self.n_features, self.seed], np.int32),
        }
        if self._route is not None:
            state["route"] = {
                **{k: np.asarray(v) for k, v in self._route.items()},
                "classes": _encode_names(self._route_classes or ()),
            }
        return state

    def load_state(self, state: Dict) -> "CorePlanner":
        """Inverse of :meth:`state_dict`; accepts jax or numpy leaves (the
        Checkpointer restores jax arrays).  A state without a ``route``
        subtree loads as a plan-only planner (default-backend serving)."""
        self.params = {k: jnp.asarray(v) for k, v in state["params"].items()}
        self.mu = np.asarray(state["mu"], np.float32)
        self.sigma = np.asarray(state["sigma"], np.float32)
        route = state.get("route")
        if route is not None:
            self._route = {
                k: np.asarray(route[k], np.float32)
                for k in ("w", "b", "mu", "sigma")
            }
            self._route_classes = _decode_names(np.asarray(route["classes"]))
        else:
            self._route = None
            self._route_classes = None
        self.generation += 1
        return self
