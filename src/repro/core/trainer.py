"""Training-data preparation: controlled-selectivity query generation
(paper §3.1: "queries with controlled selectivity ... from 1% to 25%").

Predicates are constructed from the data itself so target selectivities are
achievable:

* range    — pick a numeric attribute, a random anchor quantile, and the
             window of the empirical CDF whose mass equals the target.
* label    — seed a data point, take 1-3 of its labels (conjunction is then
             guaranteed non-empty); target selectivity guides how many
             conjuncts to keep.
* mixed    — label(s) from a seed point + a range over a numeric attribute
             centred on the seed's value, widened to hit the target.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from .predicates import LabelEq, Predicate, RangePred

__all__ = ["gen_queries", "gen_predicate"]


def _range_for_target(
    x_sorted: np.ndarray, target: float, rng: np.random.Generator
) -> Tuple[float, float]:
    """Empirical-CDF window of mass ``target`` at a random anchor."""
    n = x_sorted.size
    w = max(1, int(round(target * n)))
    lo_i = int(rng.integers(0, max(1, n - w)))
    hi_i = min(n - 1, lo_i + w)
    lo = float(x_sorted[lo_i])
    hi = float(x_sorted[hi_i])
    if hi <= lo:
        hi = lo + 1e-6
    return lo, hi


def gen_predicate(
    cat: np.ndarray,
    num: np.ndarray,
    target_sel: float,
    kind: str,
    rng: np.random.Generator,
    sorted_num: Optional[List[np.ndarray]] = None,
    multi_range_prob: float = 0.2,
) -> Predicate:
    a_cat = cat.shape[1] if cat.size else 0
    a_num = num.shape[1] if num.size else 0
    if sorted_num is None:
        sorted_num = [np.sort(num[:, j]) for j in range(a_num)]

    if kind == "range":
        attr = int(rng.integers(a_num))
        if rng.random() < multi_range_prob:
            # union of two disjoint ranges over the same attribute (§3.2.2)
            lo1, hi1 = _range_for_target(sorted_num[attr], target_sel / 2, rng)
            lo2, hi2 = _range_for_target(sorted_num[attr], target_sel / 2, rng)
            if lo2 < hi1 and lo1 < hi2:   # overlapped -> merge into one
                ivs = ((min(lo1, lo2), max(hi1, hi2)),)
            else:
                ivs = ((lo1, hi1), (lo2, hi2))
            return Predicate(ranges=(RangePred(attr, ivs),))
        lo, hi = _range_for_target(sorted_num[attr], target_sel, rng)
        return Predicate(ranges=(RangePred(attr, ((lo, hi),)),))

    # label / mixed: anchor on a random data point so conjunctions are
    # guaranteed satisfiable.
    seed_row = int(rng.integers(cat.shape[0]))
    n_lbl = 1 if kind == "mixed" else int(rng.integers(1, min(3, a_cat) + 1))
    attrs = rng.choice(a_cat, size=n_lbl, replace=False)
    labels = tuple(
        LabelEq(int(a), int(cat[seed_row, a])) for a in attrs if cat[seed_row, a] >= 0
    )
    if kind == "label":
        return Predicate(labels=labels)

    # mixed: add a range centred on the seed's numeric value sized for target
    attr = int(rng.integers(a_num))
    xs = sorted_num[attr]
    seed_v = float(num[seed_row, attr])
    pos = int(np.searchsorted(xs, seed_v))
    w = max(1, int(round(target_sel * xs.size)))
    lo_i = max(0, pos - w // 2)
    hi_i = min(xs.size - 1, lo_i + w)
    lo, hi = float(xs[lo_i]), float(xs[hi_i])
    if hi <= lo:
        hi = lo + 1e-6
    return Predicate(labels=labels, ranges=(RangePred(attr, ((lo, hi),)),))


def gen_queries(
    vectors: np.ndarray,
    cat: np.ndarray,
    num: np.ndarray,
    n_queries: int,
    kinds: Sequence[str] = ("range",),
    sel_range: Tuple[float, float] = (0.01, 0.25),
    noise: float = 0.05,
    seed: int = 0,
) -> Tuple[np.ndarray, List[Predicate], np.ndarray]:
    """Returns (query_vectors (Q,d), predicates, true_selectivities (Q,)).

    Query vectors are perturbed corpus points (the standard filtered-ANN
    query model); predicates hit selectivities sampled log-uniformly in
    ``sel_range``; queries whose predicate came out empty are resampled.
    """
    rng = np.random.default_rng(seed)
    a_num = num.shape[1] if num.size else 0
    sorted_num = [np.sort(num[:, j]) for j in range(a_num)]
    qs, preds, sels = [], [], []
    scale = float(np.std(vectors)) * noise
    while len(preds) < n_queries:
        kind = kinds[int(rng.integers(len(kinds)))]
        t = float(np.exp(rng.uniform(np.log(sel_range[0]), np.log(sel_range[1]))))
        p = gen_predicate(cat, num, t, kind, rng, sorted_num)
        true = p.selectivity(cat, num)
        if true <= 0:
            continue
        row = int(rng.integers(vectors.shape[0]))
        q = vectors[row] + rng.normal(0, scale, size=vectors.shape[1]).astype(np.float32)
        qs.append(q)
        preds.append(p)
        sels.append(true)
    return np.stack(qs).astype(np.float32), preds, np.asarray(sels)
