"""FilteredANNEngine — the public API tying the paper's pieces together.

Workflow (paper Fig. 1): query -> selectivity estimator -> core planner ->
selected executor -> results.  The engine owns the dataset statistics, the
global IVF index (post-filter backend), the estimator, the planner, and the
executors; ``fit()`` runs the paper's §3.1 training-data preparation.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# NOTE: repro.filter is imported lazily inside build_stats/shard_corpus —
# filter's compiler depends on core.predicates, so a module-level import
# here would make `import repro.filter` (filter-first order) hit a
# partially-initialised package.
from ..index.flat import l2_topk
from ..index.ivf import IVFIndex
from ..index.registry import BackendSet
from ..obs.trace import NULL_TRACER
from .corpus import CompactionPolicy, LiveCorpus
from .executors import (
    IndexedPreFilterExec,
    PostFilterExec,
    PreFilterExec,
    SearchResult,
    recall_at_k,
)
from .plan import (
    ClausePlan,
    ExecutionPlan,
    NO_ROUTE,
    STRATEGY_NAMES,
    clause_predicates,
    collapse_clause_results,
    default_route_name,
    expand_for_execution,
    format_plan,
)
from .planner import CorePlanner, PlannerFeatures, INDEXED_PRE, POST_FILTER, PRE_FILTER
from .predicates import AnyPredicate, Or
from .selectivity import SelEstimate, SelectivityEstimator
from .stats import DatasetStats

__all__ = ["FilteredANNEngine", "EngineConfig", "PlannedResult", "QueryResult",
           "CorpusShard", "PlanCache", "QueryLabel", "ExecutionPlan",
           "ClausePlan"]

# legacy spelling, kept for downstream imports (serve, tests)
_default_route_name = default_route_name


@dataclasses.dataclass
class EngineConfig:
    n_lists: Optional[int] = None      # IVF lists (default sqrt(N))
    sample_frac: float = 0.02          # stats sample (paper: 1-5 %)
    alpha0: int = 4                    # initial post-filter expansion
    nprobe0: int = 8
    seed: int = 0
    default_k: int = 10                # warmed-up k for the jit'd searches
    attr_index: bool = True            # build the bitmap/range attribute index
    range_buckets: int = 128           # filter.ranges.DEFAULT_BUCKETS
    pred_cache_size: int = 256         # compiled-predicate LRU entries
    plan_cache_size: int = 1024        # memoised (predicate, k) plan entries
    # registered ANN backends to race/route over (repro.index.registry
    # names).  None keeps the legacy plan-only engine: no BackendSet is
    # built, the decision space stays (pre, post, ipre), and every code
    # path is bit-identical to before the routing extension existed.
    backends: Optional[Tuple[str, ...]] = None
    # recall@k a (backend, knob) class must hit on a training query before
    # utility gets a say in the routing label; below it, max-recall wins.
    route_recall_target: float = 0.9
    # live-corpus compaction thresholds (see core.corpus.CompactionPolicy):
    # churn past any of these makes needs_compaction()/maybe_compact() fold
    # segment + tombstones into a rebuilt index
    max_tombstone_frac: float = 0.20
    max_segment_frac: float = 0.20
    max_list_drift: float = 1.75


@dataclasses.dataclass
class PlannedResult:
    """One served query: the executed :class:`SearchResult` plus the
    structured :class:`ExecutionPlan` it ran under.  The historical scalar
    surface (``est_selectivity`` / ``decision``) reads through to the plan."""

    result: SearchResult
    plan: ExecutionPlan
    plan_overhead: float               # seconds spent estimating + deciding

    @property
    def est_selectivity(self) -> float:
        return self.plan.est

    @property
    def decision(self) -> int:
        return self.plan.decision


#: public alias — "the thing a query returns" in docs and the API snapshot
QueryResult = PlannedResult


@dataclasses.dataclass
class QueryLabel:
    """Outcome of one §3.1 utility race (see :meth:`label_query`).

    ``route`` is the argmax (backend, knob-tier) class when a BackendSet was
    raced, else -1; ``route_utils`` holds the per-class utilities.  For DNF
    predicates ``clauses`` carries one :class:`QueryLabel` per unique
    conjunctive disjunct (first-occurrence order) — the per-clause
    decomposition the planner and feedback loop train on."""

    label: int                         # PRE_FILTER or POST_FILTER
    true_sel: float
    u_pre: float
    u_post: float
    route: int = -1
    route_utils: Optional[np.ndarray] = None
    clauses: Optional[Tuple["QueryLabel", ...]] = None


def _kernel_snapshot() -> Tuple[dict, dict]:
    """Current (dispatch counts, dispatch wall) of the process-global kernel
    ledger — an execute span annotates the DELTA across its body, so the
    span carries exactly its own dispatches."""
    from ..kernels import ops

    return ops.dispatch_counts(), ops.dispatch_wall()


def _annotate_kernel_delta(tracer, counts0: dict, wall0: dict) -> None:
    """Attach per-kernel dispatch deltas since ``counts0``/``wall0`` to the
    open span: counts on the deterministic ledger (``kernel_<name>`` attrs),
    wall seconds on the real ledger (``kernel:<name>`` wall_detail keys —
    what ``span_summary`` ranks against ``launch/roofline.py``)."""
    from ..kernels import ops

    for name, n in ops.dispatch_counts().items():
        d = n - counts0.get(name, 0)
        if d:
            tracer.annotate(**{f"kernel_{name}": d})
    for name, s in ops.dispatch_wall().items():
        dw = s - wall0.get(name, 0.0)
        if dw > 0.0:
            tracer.add_wall(f"kernel:{name}", dw)


def package_results(
    d: np.ndarray,
    ids: np.ndarray,
    rounds: np.ndarray,
    plans: Sequence[ExecutionPlan],
    share: float,
    plan_share: float,
) -> List[PlannedResult]:
    """Wrap batched (B, k) arrays into per-row PlannedResults — one
    packaging convention for the flat and sharded batch paths (``share`` is
    the batch wall time split evenly across rows, plan overhead included).
    The strategy / backend / knob labels on each row come from its
    :class:`ExecutionPlan` (DNF rows report the synthetic ``dnf`` class)."""
    out = []
    for j, plan in enumerate(plans):
        out.append(PlannedResult(
            SearchResult(d[j : j + 1], ids[j : j + 1], share,
                         plan.strategy,
                         n_expansions=int(rounds[j]),
                         backend=plan.backend, knob=plan.knob),
            plan, plan_share,
        ))
    return out


def _execute_grouped(
    pre_exec: PreFilterExec,
    ipre_exec: Optional[IndexedPreFilterExec],
    post_exec: PostFilterExec,
    queries: np.ndarray,
    preds: Sequence[AnyPredicate],
    k: int,
    decisions: np.ndarray,
    ests: np.ndarray,
    routes: Optional[np.ndarray] = None,
    backend_set: Optional[BackendSet] = None,
    tracer=None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Decision-grouped batch execution — the ONE implementation behind both
    the flat (`FilteredANNEngine.batch_query`) and sharded
    (`CorpusShard.search_batch`) paths.

    The two pre-filter groups (scan-masked and bitmap-masked) each evaluate
    every distinct predicate's mask once and run one fused masked top-k over
    all queries sharing it; un-routed post-filter rows run one row-faithful
    batched IVF search.  With ``routes``/``backend_set``, post-filter rows
    carrying a routing class >= 0 group by (class, predicate): each group
    evaluates its predicate mask once (through the bitmap index when
    covered) and runs ONE ``search_masked`` call on the routed backend —
    the (decision, backend, knob) extension of PR 2's decision grouping.
    Returns ``(dists (B, k), ids (B, k) local, expansion_rounds (B,))``.
    """
    tr = tracer if tracer is not None else NULL_TRACER
    b = len(preds)
    out_d = np.full((b, k), np.inf, np.float32)
    out_i = np.full((b, k), -1, np.int32)
    rounds = np.zeros(b, np.int64)
    for decision, ex in ((PRE_FILTER, pre_exec), (INDEXED_PRE, ipre_exec or pre_exec)):
        groups: dict = {}
        for i in range(b):
            if decisions[i] == decision:
                groups.setdefault(preds[i], []).append(i)
        for pred, rows in groups.items():
            bk, knob = _default_route_name(decision)
            with tr.span("group", decision=STRATEGY_NAMES[decision],
                         backend=bk, knob=knob, n_rows=len(rows)):
                # split of ex.search(): mask once, then the fused masked
                # top-k — bit-identical (search() is exactly this pair),
                # but the mask stays visible for the candidate-count attr
                t0 = time.perf_counter()
                m = ex.candidate_mask(pred)
                res = ex.search_masked(queries[rows], m, k, t0=t0)
                if tr.enabled:
                    tr.annotate(n_candidates=int(m.sum()))
                out_d[rows], out_i[rows] = res.dists, res.ids
    routed = routes is not None and backend_set is not None
    post_rows = [
        i for i in range(b)
        if decisions[i] == POST_FILTER and not (routed and routes[i] >= 0)
    ]
    if post_rows:
        with tr.span("group", decision="post", backend="ivf", knob="adapt",
                     n_rows=len(post_rows)):
            d, ids, rnd = post_exec.search_rows(
                queries[post_rows], [preds[i] for i in post_rows], k,
                [float(ests[i]) for i in post_rows],
            )
            out_d[post_rows], out_i[post_rows] = d, ids
            rounds[post_rows] = rnd
            if tr.enabled:
                tr.annotate(expansion_rounds=int(np.asarray(rnd).sum()))
    if routed:
        groups = {}
        for i in range(b):
            if decisions[i] == POST_FILTER and routes[i] >= 0:
                groups.setdefault((int(routes[i]), preds[i]), []).append(i)
        mask_ex = ipre_exec or pre_exec
        masks: dict = {}
        for (ci, pred), rows in groups.items():
            bk, knob = backend_set.classes()[ci]
            with tr.span("group", decision="post", backend=str(bk),
                         knob=str(knob), n_rows=len(rows)):
                if pred not in masks:
                    masks[pred] = mask_ex.candidate_mask(pred)
                d, ids = backend_set.search_class(ci, queries[rows], masks[pred], k)
                if tr.enabled:
                    tr.annotate(n_candidates=int(masks[pred].sum()))
                out_d[rows], out_i[rows] = d[:, :k], ids[:, :k]
    return out_d, out_i, rounds


def _live_execute_grouped(
    pre_exec: PreFilterExec,
    ipre_exec: Optional[IndexedPreFilterExec],
    post_exec: PostFilterExec,
    queries: np.ndarray,
    preds: Sequence[AnyPredicate],
    k: int,
    decisions: np.ndarray,
    ests: np.ndarray,
    live: LiveCorpus,
    routes: Optional[np.ndarray] = None,
    backend_set: Optional[BackendSet] = None,
    tracer=None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Tombstone/segment-composing twin of ``_execute_grouped`` — the one
    batch executor every query takes once the corpus mutated.

    Per decision group the base corpus answers exactly as before, except
    every candidate mask is ANDed with the live bitmap (tombstoned rows can
    never surface) and masks from the extended attribute index (length
    ``n_total``) are sliced back to the base rows the executors hold.  The
    append segment is searched by a plain masked scan (it stays small
    between compactions) through the SAME ``PreFilterExec`` kernel path the
    base uses, and the two parts merge with ``merge_topk`` — base part
    stacked first, so the composite column tie-break keeps handle order,
    which is the bit-equality invariant against a fresh build of the
    equivalent post-mutation corpus (handle -> compacted-position maps are
    monotone).
    """
    from ..dist.collectives import merge_topk

    tr = tracer if tracer is not None else NULL_TRACER
    b = len(preds)
    out_d = np.full((b, k), np.inf, np.float32)
    out_i = np.full((b, k), -1, np.int32)
    rounds = np.zeros(b, np.int64)
    alive = live.alive_mask()
    base_n = live.base_n
    alive_base, alive_seg = alive[:base_n], alive[base_n:]
    seg_exec = (
        PreFilterExec(live.seg_vectors(), live.seg_cat(), live.seg_num())
        if live.seg_n else None
    )
    seg_masks: dict = {}

    def seg_mask(pred) -> np.ndarray:
        if pred not in seg_masks:
            seg_masks[pred] = pred.eval(live.seg_cat(), live.seg_num()) & alive_seg
        return seg_masks[pred]

    def finish(rows, pred, bd, bi):
        if seg_exec is not None and seg_mask(pred).any():
            res = seg_exec.search_masked(queries[rows], seg_mask(pred), k)
            si = np.where(res.ids >= 0, res.ids + base_n, -1).astype(np.int32)
            bd, bi = merge_topk(np.stack([bd, res.dists]), np.stack([bi, si]), k)
        out_d[rows], out_i[rows] = bd, bi

    for decision, ex in ((PRE_FILTER, pre_exec), (INDEXED_PRE, ipre_exec or pre_exec)):
        groups: dict = {}
        for i in range(b):
            if decisions[i] == decision:
                groups.setdefault(preds[i], []).append(i)
        for pred, rows in groups.items():
            bk, knob = _default_route_name(decision)
            with tr.span("group", decision=STRATEGY_NAMES[decision],
                         backend=bk, knob=knob, n_rows=len(rows), live=True):
                m = ex.candidate_mask(pred)
                mm = m[:base_n] & alive_base
                res = ex.search_masked(queries[rows], mm, k)
                if tr.enabled:
                    tr.annotate(n_candidates=int(mm.sum()))
                finish(rows, pred, res.dists, res.ids)
    routed = routes is not None and backend_set is not None
    post_rows = [
        i for i in range(b)
        if decisions[i] == POST_FILTER and not (routed and routes[i] >= 0)
    ]
    if post_rows:
        with tr.span("group", decision="post", backend="ivf", knob="adapt",
                     n_rows=len(post_rows), live=True):
            d, ids, rnd = post_exec.search_rows(
                queries[post_rows], [preds[i] for i in post_rows], k,
                [float(ests[i]) for i in post_rows], alive=alive_base,
            )
            rounds[post_rows] = rnd
            groups = {}
            for j, i in enumerate(post_rows):
                groups.setdefault(preds[i], []).append(j)
            for pred, js in groups.items():
                finish([post_rows[j] for j in js], pred, d[js], ids[js])
            if tr.enabled:
                tr.annotate(expansion_rounds=int(np.asarray(rnd).sum()))
    if routed:
        groups = {}
        for i in range(b):
            if decisions[i] == POST_FILTER and routes[i] >= 0:
                groups.setdefault((int(routes[i]), preds[i]), []).append(i)
        mask_ex = ipre_exec or pre_exec
        base_masks: dict = {}
        for (ci, pred), rows in groups.items():
            bk, knob = backend_set.classes()[ci]
            with tr.span("group", decision="post", backend=str(bk),
                         knob=str(knob), n_rows=len(rows), live=True):
                if pred not in base_masks:
                    base_masks[pred] = mask_ex.candidate_mask(pred)[:base_n] & alive_base
                d, ids = backend_set.search_class(ci, queries[rows], base_masks[pred], k)
                if tr.enabled:
                    tr.annotate(n_candidates=int(base_masks[pred].sum()))
                finish(rows, pred, d[:, :k], ids[:, :k])
    return out_d, out_i, rounds


class PlanCache:
    """LRU memo of ``(canonical predicate key, k) -> ExecutionPlan``.

    Serving traffic repeats predicates constantly; planning the same
    predicate is pure — the decision depends only on predicate + dataset
    statistics + the current planner head — so repeats can skip the
    estimator and the MLP dispatch entirely.  Invalidation is tied to the
    things a cached plan DOES depend on, via :meth:`validate_epoch`
    against ``(planner_version, planner.generation,
    estimator.generation)`` on every lookup: a planner swap, a planner or
    estimator refit — even one invoked directly on ``engine.planner`` /
    ``engine.estimator`` — empties the memo before it can serve a stale
    plan.
    """

    def __init__(self, capacity: int = 1024):
        assert capacity >= 1
        self.capacity = capacity
        self._store: "OrderedDict[Tuple, ExecutionPlan]" = OrderedDict()
        self.epoch: Tuple = ()        # engine._plan_epoch() the memo is valid under
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def validate_epoch(self, epoch: Tuple) -> None:
        """Drop every entry if the (planner head, estimator, corpus
        generation) tuple the cached plans were computed under has changed —
        catches direct ``estimator.fit()`` calls that bypass the engine's
        own clear hooks, and (since the corpus generation joined the epoch)
        any live-corpus mutation, whose tombstones/appends change exact
        selectivities.  Epoch-mismatch drops are counted separately from
        capacity evictions so mutation-driven churn is observable in
        ``stats()``."""
        if epoch != self.epoch:
            if self.epoch:
                self.invalidations += 1
            self._store.clear()
            self.epoch = epoch

    def __len__(self) -> int:
        return len(self._store)

    def get(self, key) -> Optional[ExecutionPlan]:
        hit = self._store.get(key)
        if hit is None:
            self.misses += 1
            return None
        self.hits += 1
        self._store.move_to_end(key)
        return hit

    def put(self, key, value: ExecutionPlan) -> None:
        self._store[key] = value
        if len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._store.clear()

    def stats(self) -> Dict[str, int]:
        return {
            "size": len(self._store), "capacity": self.capacity,
            "hits": self.hits, "misses": self.misses, "evictions": self.evictions,
            "invalidations": self.invalidations,
        }


@dataclasses.dataclass
class CorpusShard:
    """One partition of the corpus with its own pre-/post-filter executors.

    Produced by :meth:`FilteredANNEngine.shard_corpus`.  Executors operate
    on shard-local row numbers; :meth:`search` maps results back to global
    ids so shard outputs merge directly (``repro.dist.collectives.merge_topk``).
    Each shard carries its OWN attribute index + predicate cache (bitmaps
    are positional, so they cannot be sliced from the global index).
    """

    shard_id: int
    ids: np.ndarray                    # (n_local,) global row ids
    pre_exec: PreFilterExec
    post_exec: PostFilterExec
    ipre_exec: Optional[IndexedPreFilterExec] = None
    backend_set: Optional[BackendSet] = None   # per-shard backend instances
    live: Optional[LiveCorpus] = None          # created on first mutation

    # ------------------------------------------------------------------
    def ensure_live(self) -> LiveCorpus:
        if self.live is None:
            self.live = LiveCorpus(self.pre_exec.vectors,
                                   self.pre_exec.cat, self.pre_exec.num)
        return self.live

    def upsert_local(
        self,
        vectors: np.ndarray,
        cat: np.ndarray,
        num: np.ndarray,
        global_ids: np.ndarray,
        local_ids: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """Append rows to this shard's live view and extend the local ->
        global map (``global_ids``, one per row, assigned by the sharded
        engine's placement rule).  ``local_ids`` tombstones replaced LOCAL
        handles first.  Returns the new local handles."""
        live = self.ensure_live()
        c = np.atleast_2d(np.asarray(cat))
        m = np.atleast_2d(np.asarray(num))
        handles = live.upsert(vectors, c, m, ids=local_ids)
        if self.ipre_exec is not None:
            self.ipre_exec.index.extend(c, m)
            self.ipre_exec.cache.invalidate()
        self.ids = np.concatenate(
            [self.ids, np.asarray(global_ids, self.ids.dtype)]
        )
        return handles

    def delete_local(self, local_ids: np.ndarray) -> np.ndarray:
        """Tombstone shard-local handles; returns the newly dead ones."""
        return self.ensure_live().delete(local_ids)

    def search(
        self,
        q: np.ndarray,
        pred: AnyPredicate,
        k: int,
        decision: int,
        est_selectivity: Optional[float] = None,
        route: int = NO_ROUTE,
    ) -> SearchResult:
        """Run the planned executor on this shard; returns GLOBAL ids.
        ``route >= 0`` sends a post-filter row to that (backend, knob-tier)
        class of the shard's BackendSet instead of the lazy post path."""
        if self.live is not None and self.live.dirty:
            t0 = time.perf_counter()
            decisions = np.array([decision], np.int32)
            routes_arr = np.array([route], np.int32)
            d, ids, rounds = _live_execute_grouped(
                self.pre_exec, self.ipre_exec, self.post_exec,
                q, [pred], k, decisions,
                np.array([0.0 if est_selectivity is None else est_selectivity]),
                self.live, routes=routes_arr, backend_set=self.backend_set,
            )
            res = SearchResult(d, ids, time.perf_counter() - t0,
                               STRATEGY_NAMES[decision],
                               n_expansions=int(rounds[0]))
            if route >= 0 and decision == POST_FILTER and self.backend_set is not None:
                res.backend, res.knob = self.backend_set.classes()[route]
            res.ids = self._to_global(res.ids)
            return res
        if decision == INDEXED_PRE:
            res = (self.ipre_exec or self.pre_exec).search(q, pred, k)
        elif decision == PRE_FILTER:
            res = self.pre_exec.search(q, pred, k)
        elif route >= 0 and self.backend_set is not None:
            t0 = time.perf_counter()
            mask = (self.ipre_exec or self.pre_exec).candidate_mask(pred)
            d, ids = self.backend_set.search_class(route, q, mask, k)
            bk, knob = self.backend_set.classes()[route]
            res = SearchResult(d, ids, time.perf_counter() - t0, "post",
                               backend=bk, knob=knob)
        else:
            res = self.post_exec.search(q, pred, k, est_selectivity=est_selectivity)
        res.ids = self._to_global(res.ids)
        return res

    def _to_global(self, ids: np.ndarray) -> np.ndarray:
        valid = ids >= 0
        return np.where(valid, self.ids[np.maximum(ids, 0)], -1).astype(np.int32)

    def search_batch(
        self,
        queries: np.ndarray,
        preds: Sequence[AnyPredicate],
        k: int,
        decisions: np.ndarray,
        ests: np.ndarray,
        routes: Optional[np.ndarray] = None,
        tracer=None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Run a whole planned batch on this shard (decision-grouped, same
        shared ``_execute_grouped`` core as
        :meth:`FilteredANNEngine.batch_query`).  Returns
        ``(dists (B, k), ids (B, k) GLOBAL, expansion_rounds (B,))`` ready to
        stack across shards for one batched ``merge_topk``."""
        if self.live is not None and self.live.dirty:
            out_d, out_i, rounds = _live_execute_grouped(
                self.pre_exec, self.ipre_exec, self.post_exec,
                queries, preds, k, decisions, ests, self.live,
                routes=routes, backend_set=self.backend_set, tracer=tracer,
            )
        else:
            out_d, out_i, rounds = _execute_grouped(
                self.pre_exec, self.ipre_exec, self.post_exec,
                queries, preds, k, decisions, ests,
                routes=routes, backend_set=self.backend_set, tracer=tracer,
            )
        return out_d, self._to_global(out_i), rounds


class FilteredANNEngine:
    def __init__(
        self,
        vectors: np.ndarray,
        cat: np.ndarray,
        num: np.ndarray,
        config: EngineConfig = EngineConfig(),
    ):
        self.vectors = np.ascontiguousarray(vectors, np.float32)
        self.cat, self.num = cat, num
        self.config = config
        self.build_time_: dict = {}

    # ------------------------------------------------------------------
    def build_stats(self) -> "FilteredANNEngine":
        """Planning-only build: statistics, estimator, planner, features.

        Skips the global IVF index, local executors, and jit warmup — all
        a sharded deployment pays for but never uses (every query runs on
        per-shard executors from :meth:`shard_corpus`).  Enough for
        :meth:`plan` and :meth:`shard_corpus`; :meth:`fit` and the
        unsharded :meth:`query` need the full :meth:`build`.
        """
        t0 = time.perf_counter()
        self.dataset_stats = DatasetStats.build(
            self.vectors, self.cat, self.num,
            sample_frac=self.config.sample_frac, seed=self.config.seed,
        )
        t1 = time.perf_counter()
        # bitmap/range attribute index + shared compiled-predicate cache:
        # the estimator's exact fast path and the indexed pre-filter
        # executor compile each predicate once between them
        from ..filter import AttributeIndex, PredicateCache
        from ..filter.cache import canonical_key

        self.attr_index = (
            AttributeIndex.build(self.cat, self.num, self.config.range_buckets)
            if self.config.attr_index else None
        )
        self.pred_cache = PredicateCache(self.config.pred_cache_size)
        # memoised plans for repeat predicates (pure in predicate + stats +
        # planner head; cleared on fit/swap_planner)
        self.plan_cache = PlanCache(self.config.plan_cache_size)
        self._plan_key = canonical_key
        self.planner_version = 0
        t2 = time.perf_counter()
        self.estimator = SelectivityEstimator(
            self.dataset_stats, index=self.attr_index, cache=self.pred_cache
        )
        self.planner = CorePlanner(seed=self.config.seed)
        self.feat = PlannerFeatures(self.dataset_stats)
        self.backend_set: Optional[BackendSet] = None   # built by build()
        # live-corpus mutation layer: every upsert/delete flows through
        # self.live; the estimator composes its tombstones into the exact
        # fast path, and corpus_generation joins the plan epoch so memoised
        # plans from a previous corpus version invalidate on lookup.
        # (corpus_generation is engine-level and monotone ACROSS compactions
        # — a fresh LiveCorpus restarts its own generation at 0.)
        self.live = LiveCorpus(self.vectors, self.cat, self.num)
        self.estimator.live = self.live
        self.corpus_generation = 0
        self.n_compactions = 0
        self.compaction_policy = CompactionPolicy(
            max_tombstone_frac=self.config.max_tombstone_frac,
            max_segment_frac=self.config.max_segment_frac,
            max_list_drift=self.config.max_list_drift,
        )
        # observability: the no-op tracer by default, an installed one kept
        # across compaction rebuilds (compact() re-runs build_stats) the
        # same way the trained heads survive
        self.tracer = getattr(self, "tracer", NULL_TRACER)
        self.build_time_["stats"] = t1 - t0
        self.build_time_["attr_index"] = t2 - t1
        return self

    def build(self) -> "FilteredANNEngine":
        """Offline phase: statistics + global index (paper Table 2 costs)."""
        self.build_stats()
        t1 = time.perf_counter()
        self.ivf = IVFIndex(self.vectors, self.config.n_lists, seed=self.config.seed).build()
        t2 = time.perf_counter()
        self.pre_exec = PreFilterExec(self.vectors, self.cat, self.num)
        self.ipre_exec = IndexedPreFilterExec(
            self.vectors, self.cat, self.num, self.attr_index, self.pred_cache
        )
        self.post_exec = PostFilterExec(
            self.ivf, self.cat, self.num,
            alpha0=self.config.alpha0, nprobe0=self.config.nprobe0,
        )
        if self.config.backends:
            t_b = time.perf_counter()
            self.backend_set = BackendSet.build(
                self.vectors, self.config.backends, seed=self.config.seed
            )
            self.build_time_["backends"] = time.perf_counter() - t_b
        # warm the jit'd pre-filter bucket shapes: per-query utility timings
        # (planner training labels, §3.1) must not include one-off XLA
        # compiles — a cold bucket inflates T_search by ~100x and mislabels
        # the query
        self._warm_buckets(self.config.default_k)
        t3 = time.perf_counter()
        self.build_time_.update({"ivf": t2 - t1, "warmup": t3 - t2})
        return self

    def _warm_buckets(self, k: int):
        from ..index.flat import l2_topk

        n, d = self.vectors.shape
        # the pre-filter executor pads query batches to pow2 with floor 8,
        # so (8, p) is the shape every small-batch (and per-query) search hits
        q = np.zeros((8, d), np.float32)
        p = 16
        while p <= 2 * n:
            sub = np.zeros((min(p, 1 << 24), d), np.float32)
            m = np.ones(sub.shape[0], bool)
            l2_topk(q, sub, min(k, sub.shape[0]), m)
            p *= 2
        q1 = np.zeros((1, d), np.float32)
        l2_topk(q1, self.vectors, k)                      # ground-truth shape
        l2_topk(q1, self.vectors, k, np.ones(n, bool))
        # the large-passing-set branch runs the masked top-k over the FULL
        # corpus with the pow2-padded (floor 8) query batch — warm it too
        l2_topk(q, self.vectors, k, np.ones(n, bool))

    # ------------------------------------------------------------------
    def label_query(self, q: np.ndarray, pred: AnyPredicate, k: int = 10,
                    ) -> QueryLabel:
        """Paper §3.1 utility labelling — the ONE definition shared by the
        offline :meth:`fit` loop, the online feedback loop's shadow
        labeller, and the benchmarks' oracle: run BOTH strategies against
        the exact masked top-k and pick the winner by utility
        U = recall@k / T_search.

        With a built BackendSet, every registered (backend, knob-tier)
        class is raced under the same rule (mask evaluation charged to each
        contender, since routed execution pays it at serve time); the
        winning class — highest utility among classes whose measured recall
        meets ``config.route_recall_target``, max-recall when none do —
        becomes the routing label and its utility competes as the post-side
        champion, so a backend that beats BOTH the exact scan and the lazy
        post path wins the plan decision too.

        DNF predicates additionally race every unique conjunctive disjunct
        on its own (``QueryLabel.clauses``, first-occurrence order — the
        same enumeration the per-disjunct planner uses), so planner /
        estimator / routing training sees clause-level rows for DNF
        traffic while the whole-predicate label stays available."""
        q = np.atleast_2d(q)
        clauses = None
        if isinstance(pred, Or):
            seen, cls = set(), []
            for t in pred.terms:
                key = self._plan_key(t)
                if key in seen:
                    continue
                seen.add(key)
                cls.append(self.label_query(q, t, k))
            clauses = tuple(cls)
        t_m0 = time.perf_counter()
        mask = pred.eval(self.cat, self.num)
        live = getattr(self, "live", None)
        live_dirty = live is not None and live.dirty
        alive_base = live.alive_mask()[: live.base_n] if live_dirty else None
        if live_dirty:
            # race strategies over the same live candidate set: tombstones
            # compose into mask, ground truth, and the post path alike (the
            # segment sits out the race — both contenders skip it equally)
            mask = mask & alive_base
        t_mask = time.perf_counter() - t_m0
        true_sel = float(mask.mean())
        _, ti = l2_topk(q, self.vectors, k, mask)             # exact ground truth
        ti = np.asarray(ti)
        if live_dirty:
            r_pre = self.pre_exec.search_masked(q, mask, k)
            r_pre.elapsed += t_mask          # charge mask eval, like search()
        else:
            r_pre = self.pre_exec.search(q, pred, k)
        r_post = self.post_exec.search(q, pred, k, est_selectivity=true_sel,
                                       alive=alive_base)
        u_pre = recall_at_k(r_pre.ids, ti) / max(r_pre.elapsed, 1e-7)
        u_post = recall_at_k(r_post.ids, ti) / max(r_post.elapsed, 1e-7)
        route, route_utils = NO_ROUTE, None
        if self.backend_set is not None:
            classes = self.backend_set.classes()
            n_c = len(classes)
            route_utils = np.zeros(n_c, np.float64)
            recalls = np.zeros(n_c, np.float64)
            for ci in range(n_c):
                t0 = time.perf_counter()
                _, ids = self.backend_set.search_class(ci, q, mask, k)
                dt = time.perf_counter() - t0 + t_mask
                recalls[ci] = recall_at_k(ids, ti)
                route_utils[ci] = recalls[ci] / max(dt, 1e-7)
            # Constrained pick (Faiss-autotune style): utility only decides
            # among classes meeting the recall target.  A raw utility argmax
            # lets wall-clock noise during fit route queries to a fast
            # low-recall tier, collapsing served recall run-to-run.
            ok = recalls >= self.config.route_recall_target
            if ok.any():
                route = int(np.argmax(np.where(ok, route_utils, -1.0)))
            else:
                route = int(np.argmax(recalls + 1e-9 * route_utils))
            u_post = max(u_post, float(route_utils[route]))
        label = PRE_FILTER if u_pre >= u_post else POST_FILTER
        return QueryLabel(label, true_sel, u_pre, u_post, route, route_utils,
                          clauses=clauses)

    def fit(
        self,
        train_queries: Sequence[np.ndarray],
        train_preds: Sequence[AnyPredicate],
        k: int = 10,
        verbose: bool = False,
    ) -> "FilteredANNEngine":
        """Paper §3.1: execute both strategies per training query, label by
        utility U = recall@k / T_search, train estimator GBM + planner MLP.

        DNF training queries decompose: the planner, routing head, and
        estimator GBM only ever decide/serve *conjunctions* (the per-disjunct
        planner plans each clause of an ``Or`` independently), so an ``Or``
        contributes one training row per unique disjunct — features of the
        disjunct, label/route from its own §3.1 race — instead of one
        whole-predicate row the heads could never act on."""
        t0 = time.perf_counter()
        fit_preds, labels, true_sels, route_labels = [], [], [], []
        for q, pred in zip(train_queries, train_preds):
            lab = self.label_query(q, pred, k)
            if verbose:
                print(f"  {pred}: sel={lab.true_sel:.4f} "
                      f"U_pre={lab.u_pre:.1f} U_post={lab.u_post:.1f}")
            if lab.clauses:
                seen: set = set()
                uniq = [t for t in pred.terms
                        if not (self._plan_key(t) in seen
                                or seen.add(self._plan_key(t)))]
                for t, cl in zip(uniq, lab.clauses):
                    fit_preds.append(t)
                    labels.append(cl.label)
                    true_sels.append(cl.true_sel)
                    route_labels.append(cl.route)
            else:
                fit_preds.append(pred)
                labels.append(lab.label)
                true_sels.append(lab.true_sel)
                route_labels.append(lab.route)
        # selectivity estimator GBM trains on the same (clause) rows
        self.estimator.fit(fit_preds, true_sels)
        # re-extract features with the trained estimator so train/test match
        feats = []
        for p in fit_preds:
            se = self.estimator.estimate(p)
            feats.append(self.feat.vector(p, se.sel, k, se.is_exact))
        self.planner.fit(np.stack(feats), np.asarray(labels))
        if self.backend_set is not None:
            # routing head on the same features: argmax-utility class labels
            self.planner.fit_routing(
                np.stack(feats), np.asarray(route_labels),
                self.backend_set.class_names(),
            )
        # warm the single-query predict shape: the first live query must not
        # pay the (1, F) jit compile (~150 ms) inside its latency budget
        self.planner.decide(feats[0])
        # estimator AND head both changed: memoised plans are stale
        self.plan_cache.clear()
        self.planner_version += 1
        self.build_time_["fit"] = time.perf_counter() - t0
        return self

    def swap_planner(self, planner: CorePlanner) -> "FilteredANNEngine":
        """Atomically install a refit planner head (the online feedback
        loop's hook).  Clears the plan cache — memoised decisions belong to
        the old head — and pre-warms the new head's (1, F) predict shape so
        the first live query after a swap pays no jit compile."""
        self.planner = planner
        self.plan_cache.clear()
        self.planner_version += 1
        if planner.params is not None:
            planner.decide(np.zeros(planner.n_features, np.float32))
        return self

    def set_tracer(self, tracer) -> "FilteredANNEngine":
        """Install an :class:`repro.obs.trace.Tracer` on every serving path
        (``None`` restores the no-op default)."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        return self

    @staticmethod
    def _hit_ratio(hits: int, misses: int) -> float:
        total = hits + misses
        return round(hits / total, 6) if total else 0.0

    def stats(self) -> dict:
        """Public serving-counter accessor: predicate-cache hit/miss/eviction
        stats, plan-cache stats, and the planner head version — previously
        only reachable by poking engine internals.  (Dataset statistics
        live on ``self.dataset_stats``.)

        ``kernel_dispatch`` mirrors the process-global per-kernel dispatch
        counts (``repro.kernels.ops``) — cumulative across every engine in
        the process, so tests diff it around the call under measurement —
        and ``cache_hit_ratio`` summarises the three serving caches."""
        out: dict = {"planner_version": getattr(self, "planner_version", 0)}
        ratios: dict = {}
        pred_cache = getattr(self, "pred_cache", None)
        if pred_cache is not None:
            s = pred_cache.stats()
            out["pred_cache"] = s
            ratios["pred_cache"] = self._hit_ratio(s["hits"], s["misses"])
            ratios["mask_tier"] = self._hit_ratio(s["mask_hits"], s["mask_misses"])
        plan_cache = getattr(self, "plan_cache", None)
        if plan_cache is not None:
            s = plan_cache.stats()
            out["plan_cache"] = s
            ratios["plan_cache"] = self._hit_ratio(s["hits"], s["misses"])
        out["cache_hit_ratio"] = ratios
        from ..kernels import ops as _kops

        out["kernel_dispatch"] = _kops.dispatch_counts()
        out["corpus_generation"] = getattr(self, "corpus_generation", 0)
        out["n_compactions"] = getattr(self, "n_compactions", 0)
        live = getattr(self, "live", None)
        if live is not None:
            out["live"] = live.stats()
        return out

    # ------------------------------------------------------------------
    # live-corpus mutations
    # ------------------------------------------------------------------
    def upsert(
        self,
        vectors: np.ndarray,
        cat: np.ndarray,
        num: np.ndarray,
        ids: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """Stream rows into the live corpus; returns their (stable, never
        reused) handles.  ``ids`` replaces existing handles: old rows are
        tombstoned, new versions appended under fresh handles.

        Incremental refresh instead of rebuild: label bitmaps extend and
        stay exact; the equi-depth range index goes stale (fails closed out
        of ``covers()``, so range predicates demote to the scan path and
        estimated selectivity); dataset statistics fold the delta in;
        compiled-predicate entries invalidate (their word count is stale);
        the plan epoch bumps so memoised plans re-plan on next lookup."""
        v = np.atleast_2d(np.asarray(vectors, np.float32))
        c = np.atleast_2d(np.asarray(cat))
        m = np.atleast_2d(np.asarray(num))
        tr = getattr(self, "tracer", NULL_TRACER)
        with tr.span("write", op="upsert", n_rows=int(v.shape[0])):
            removed_cat = removed_num = None
            if ids is not None:
                old = np.unique(np.asarray(ids, np.int64))
                old = old[~self.live.is_deleted(old)]
                if old.size:      # attrs of the rows about to be tombstoned
                    removed_cat, removed_num = self.live.row_attrs(old)
            handles = self.live.upsert(v, c, m, ids=ids)
            if self.attr_index is not None:
                self.attr_index.extend(c, m)
                self.pred_cache.invalidate()
            self.dataset_stats.apply_delta(
                added_cat=c, added_num=m,
                removed_cat=removed_cat, removed_num=removed_num,
            )
            ivf = getattr(self, "ivf", None)
            if ivf is not None:   # keep the drift trigger's assignments current
                self.live.assign_new(ivf.centroids)
            self.corpus_generation += 1
            tr.annotate(corpus_generation=self.corpus_generation)
        return handles

    def delete(self, ids: np.ndarray) -> np.ndarray:
        """Tombstone handles (idempotent); returns the newly dead ones.
        No index structure is rewritten — the tombstone bitmap composes
        into every candidate mask, backend call, and exact-selectivity
        popcount at query time — but statistics fold the removal in and
        the plan epoch bumps."""
        tr = getattr(self, "tracer", NULL_TRACER)
        with tr.span("write", op="delete"):
            fresh = self.live.delete(ids)
            if fresh.size:
                rc, rn = self.live.row_attrs(fresh)
                self.dataset_stats.apply_delta(removed_cat=rc, removed_num=rn)
            self.corpus_generation += 1
            tr.annotate(n_dead=int(fresh.size),
                        corpus_generation=self.corpus_generation)
        return fresh

    def list_drift(self) -> float:
        """IVF list-balance drift if the segment were folded in: max list
        count (base + incrementally assigned segment rows) over the
        build-time max.  1.0 when there is nothing to fold."""
        ivf = getattr(self, "ivf", None)
        live = getattr(self, "live", None)
        if ivf is None or live is None or not live.seg_n:
            return 1.0
        assign = live.assign_new(ivf.centroids)
        counts = ivf.list_counts + np.bincount(assign, minlength=ivf.n_lists)
        return float(counts.max() / max(int(ivf.list_counts.max()), 1))

    def needs_compaction(self) -> bool:
        return self.compaction_policy.due(
            self.live.tombstone_frac, self.live.segment_frac, self.list_drift()
        )

    def maybe_compact(self) -> Optional[np.ndarray]:
        """Compact iff churn crossed a :class:`CompactionPolicy` threshold;
        returns the handle -> new-position id_map, or None if not due."""
        live = getattr(self, "live", None)
        if live is not None and live.dirty and self.needs_compaction():
            return self.compact()
        return None

    def compact(self) -> np.ndarray:
        """Fold segment + tombstones into a rebuilt engine, in place.

        Live rows land in handle order (monotone map), the full build
        pipeline reruns over the folded arrays, and the trained planner /
        estimator heads survive the rebuild (only corpus-derived state is
        re-derived).  Generation counters bump so every cache invalidates.
        Returns ``id_map``: old handle -> new position (-1 for dead)."""
        t0 = time.perf_counter()
        tr = getattr(self, "tracer", NULL_TRACER)
        with tr.span("compact"):
            vectors, cat, num, id_map = self.live.compacted()
            planner, head_version = self.planner, self.planner_version
            est_model, est_gen = self.estimator.model, self.estimator.generation
            gen, n_comp = self.corpus_generation, self.n_compactions
            full = getattr(self, "pre_exec", None) is not None
            self.vectors, self.cat, self.num = vectors, cat, num
            if full:
                self.build()
            else:
                self.build_stats()  # planning-only engines stay planning-only
            self.planner = planner
            self.planner_version = head_version + 1
            self.estimator.model = est_model
            self.estimator.generation = est_gen + 1
            self.corpus_generation = gen + 1
            self.n_compactions = n_comp + 1
            tr.annotate(n_rows=int(vectors.shape[0]),
                        n_compactions=self.n_compactions,
                        corpus_generation=self.corpus_generation)
        self.build_time_["compaction"] = time.perf_counter() - t0
        return id_map

    def mutation_state(self) -> dict:
        """Array-only pytree of the mutable corpus state — what
        ``repro.ckpt.Checkpointer`` snapshots between compactions."""
        return self.live.state_tree()

    def load_mutation_state(self, tree) -> "FilteredANNEngine":
        """Restore a :meth:`mutation_state` snapshot onto a freshly built
        engine over the SAME base corpus.  Replays through the public
        upsert/delete APIs, so the attribute index, statistics deltas,
        caches, and generations all end consistent with having taken the
        writes live."""
        base_n = int(np.asarray(tree["base_n"]))
        if base_n != self.live.base_n or self.live.dirty:
            raise ValueError(
                "load_mutation_state needs a clean engine built over the "
                "same base corpus"
            )
        sv = np.asarray(tree["seg_vectors"])
        if sv.shape[0]:
            self.upsert(sv, np.asarray(tree["seg_cat"]),
                        np.asarray(tree["seg_num"]))
        from ..filter.bitmap import expand_words

        tomb = np.asarray(tree["tomb"], np.uint32)
        dead = np.nonzero(expand_words(tomb, self.live.n_total))[0]
        if dead.size:
            self.delete(dead)
        return self

    # ------------------------------------------------------------------
    def make_plan(self, pred: AnyPredicate, k: int = 10,
                  ) -> Tuple[ExecutionPlan, float]:
        """Plan one predicate into a structured :class:`ExecutionPlan`,
        without executing.

        Conjunctions get a single-clause plan (bit-identical decisions to
        the historical scalar path).  ``Or`` predicates plan *per disjunct*:
        each unique conjunctive clause gets its own decision / routing
        class, and the plan's ``"union"`` merge spec tells execution to run
        the clauses as ordinary decision groups and merge with cross-clause
        de-duplication.  The plan depends only on predicate and dataset
        statistics — not on which corpus rows are local — so a sharded
        deployment plans ONCE and broadcasts it to every shard.  Repeat
        predicates hit the plan cache and skip both the estimator and the
        MLP dispatch (same plan by purity, just cheaper).  Returns
        ``(plan, plan_overhead_s)``."""
        t0 = time.perf_counter()
        tr = getattr(self, "tracer", NULL_TRACER)
        with tr.span("plan", k=int(k)):
            self.plan_cache.validate_epoch(self._plan_epoch())
            key = (self._plan_key(pred), int(k))
            hit = self.plan_cache.get(key)
            if hit is not None:
                tr.annotate(plan_cache="hit", decision=hit.strategy,
                            route=int(hit.route), n_clauses=hit.n_clauses)
                return hit, time.perf_counter() - t0
            plan = self._plan_cold(pred, k)
            self.plan_cache.put(key, plan)
            tr.annotate(plan_cache="miss", decision=plan.strategy,
                        route=int(plan.route), n_clauses=plan.n_clauses)
        return plan, time.perf_counter() - t0

    def explain(self, pred: AnyPredicate, k: int = 10) -> str:
        """Pretty-print the :class:`ExecutionPlan` for ``(pred, k)`` without
        executing — one line per clause with decision, backend class, and
        the selectivity estimate the choice was made under."""
        plan, _ = self.make_plan(pred, k)
        return format_plan(plan, pred)

    def plan(self, pred: AnyPredicate, k: int = 10) -> Tuple[float, int, float]:
        """Scalar spelling of :meth:`make_plan`: returns
        ``(est_selectivity, decision, plan_overhead_s)``.  For DNF plans the
        decision is the dominant clause's (see ``ExecutionPlan.decision``)."""
        plan, overhead = self.make_plan(pred, k)
        return plan.est, plan.decision, overhead

    def plan_ex(self, pred: AnyPredicate, k: int = 10) -> Tuple[float, int, int, float]:
        """:meth:`plan` plus the routing class: returns
        ``(est_selectivity, decision, route, plan_overhead_s)`` where
        ``route`` is the (backend, knob-tier) class index for post-filter
        rows when the routing head is active, else ``NO_ROUTE``."""
        plan, overhead = self.make_plan(pred, k)
        return plan.est, plan.decision, plan.route, overhead

    def _class_names(self) -> Optional[Tuple[str, ...]]:
        """This engine's (backend, knob-tier) class enumeration.  Derived
        from the built BackendSet when present, else from the configured
        backend roster (knob grids are static per backend class, so a
        planning-only ``build_stats`` engine — the sharded deployment's
        planner — enumerates the same classes its shards build)."""
        bs = getattr(self, "backend_set", None)
        if bs is not None:
            return bs.class_names()
        if self.config.backends:
            from ..index.registry import _REGISTRY
            return tuple(
                f"{nm}:{tier.name}"
                for nm in self.config.backends
                for tier in _REGISTRY[nm](seed=0).knob_grid()
            )
        return None

    def _routing_active(self) -> bool:
        """Routing applies only when the planner's routing head was fitted
        over EXACTLY this engine's (backend, knob-tier) class enumeration —
        a head trained under a different backend roster (e.g. restored from
        a checkpoint of another deployment) is ignored, not misapplied."""
        expected = self._class_names()
        if expected is None:
            return False
        rc = self.planner.route_classes
        return rc is not None and rc == expected

    def _plan_epoch(self) -> Tuple[int, int, int, int]:
        """What a cached plan is valid under: the installed head
        (``planner_version``, bumped by fit/swap_planner), that head's own
        fit generation, the estimator's fit generation — the latter two
        catch direct ``eng.planner.fit()`` / ``eng.estimator.fit()`` calls
        that retrain in place without going through the engine's hooks —
        and the corpus generation, which every live upsert/delete/compaction
        bumps (mutations change exact selectivities, hence plans)."""
        return (self.planner_version, self.planner.generation,
                self.estimator.generation,
                getattr(self, "corpus_generation", 0))

    def _route_pair(self, decision: int, route: int) -> Tuple[str, str]:
        """Resolve a (decision, routing class) pair to its (backend, knob)
        execution class — routed post rows name their BackendSet class, all
        other rows the default class implied by the decision."""
        if decision == POST_FILTER and route >= 0:
            bs = getattr(self, "backend_set", None)
            if bs is not None:
                return bs.classes()[route]
            names = self._class_names()
            if names is not None and route < len(names):
                bk, _, knob = names[route].partition(":")
                return bk, knob
        return default_route_name(decision)

    def _decide_clauses(self, preds: Sequence, ests: np.ndarray,
                        exact: np.ndarray, k: int
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """One feature matrix + one planner dispatch over conjunction rows:
        returns per-row ``(decisions, routes)``."""
        fm = self.feat.matrix(list(preds), ests, k, exact)
        if self.planner.params:
            decisions = self.planner.decide(fm).astype(np.int32)
        else:
            # untrained fallback mirrors the planner's cost heuristic: the
            # selectivity threshold picks pre vs post, coverage upgrades
            # pre to the indexed variant
            decisions = np.where(ests < 0.05, PRE_FILTER, POST_FILTER).astype(np.int32)
            decisions = np.where(
                (decisions == PRE_FILTER) & exact, INDEXED_PRE, decisions
            ).astype(np.int32)
        routes = np.full(len(preds), NO_ROUTE, np.int32)
        if self._routing_active():
            r = self.planner.route(fm)
            if r is not None:
                routes = np.where(decisions == POST_FILTER, r, NO_ROUTE).astype(np.int32)
        return decisions, routes

    def _single_plan(self, pred, est: float, exact: bool, decision: int,
                     route: int) -> ExecutionPlan:
        bk, knob = self._route_pair(decision, route)
        cl = ClausePlan(self._plan_key(pred), int(decision), bk, knob,
                        float(est), int(route), bool(exact))
        return ExecutionPlan((cl,), float(est), bool(exact), "none")

    def _plan_dnf(self, pred: Or, k: int, se: SelEstimate) -> ExecutionPlan:
        """Per-disjunct planning: each unique conjunctive clause of the DNF
        is decided and routed independently (one batched head dispatch over
        the clause feature rows), producing a ``"union"``-merge plan."""
        tr = getattr(self, "tracer", NULL_TRACER)
        seen: set = set()
        terms, ests = [], []
        for t, ce in zip(pred.terms, se.per_clause):
            key = self._plan_key(t)
            if key in seen:
                continue
            seen.add(key)
            terms.append(t)
            ests.append(ce)
        if not terms:                       # empty Or: matches nothing
            return ExecutionPlan((), 0.0, True, "union")
        sels = np.asarray([c.sel for c in ests], np.float64)
        exact = np.asarray([c.is_exact for c in ests], bool)
        decisions, routes = self._decide_clauses(terms, sels, exact, k)
        clauses = []
        for j, t in enumerate(terms):
            bk, knob = self._route_pair(int(decisions[j]), int(routes[j]))
            clauses.append(ClausePlan(
                self._plan_key(t), int(decisions[j]), bk, knob,
                float(sels[j]), int(routes[j]), bool(exact[j])))
            if tr.enabled:
                with tr.span("clause", index=j,
                             decision=STRATEGY_NAMES[int(decisions[j])],
                             backend=bk, knob=knob, route=int(routes[j])):
                    tr.annotate(est=round(float(sels[j]), 6),
                                exact=bool(exact[j]))
        return ExecutionPlan(tuple(clauses), float(se.sel),
                             bool(se.is_exact), "union")

    def _plan_cold(self, pred: AnyPredicate, k: int) -> ExecutionPlan:
        tr = getattr(self, "tracer", NULL_TRACER)
        with tr.span("predicate_compile"):
            pc = getattr(self, "pred_cache", None)
            m0 = pc.misses if pc is not None else 0
            se = self.estimator.estimate(pred)
            if tr.enabled:
                tr.annotate(estimator="exact" if se.is_exact else "gbm")
                if pc is not None:
                    miss = pc.misses - m0
                    n_words = (self.vectors.shape[0] + 31) // 32
                    tr.annotate(pred_cache="miss" if miss else "hit",
                                bitmap_words=miss * n_words)
        if isinstance(pred, Or):
            return self._plan_dnf(pred, k, se)
        fv = self.feat.vector(pred, se.sel, k, se.is_exact)
        if self.planner.params:
            decision = int(self.planner.decide(fv)[0])
        else:
            decision = PRE_FILTER if se.sel < 0.05 else POST_FILTER
            if decision == PRE_FILTER and se.is_exact:
                decision = INDEXED_PRE
        route = NO_ROUTE
        if decision == POST_FILTER and self._routing_active():
            r = self.planner.route(fv)
            if r is not None:
                route = int(r[0])
        return self._single_plan(pred, se.sel, se.is_exact, decision, route)

    def make_plan_batch(
        self, preds: Sequence[AnyPredicate], k: int = 10
    ) -> Tuple[List[ExecutionPlan], float]:
        """Batched :meth:`make_plan`: one selectivity pass, one (rows, F)
        feature matrix over every conjunction AND every DNF clause in the
        batch, ONE planner jit dispatch instead of B.

        Returns ``(plans (B,), plan_overhead_s)`` where the overhead covers
        the whole batch.  Rows whose (predicate, k) was planned before
        resolve from the plan cache; only the misses pay the estimator pass
        and the MLP dispatch.
        """
        t0 = time.perf_counter()
        tr = getattr(self, "tracer", NULL_TRACER)
        b = len(preds)
        with tr.span("plan", n_preds=b, k=int(k)):
            self.plan_cache.validate_epoch(self._plan_epoch())
            plans: List[Optional[ExecutionPlan]] = [None] * b
            keys = [(self._plan_key(p), int(k)) for p in preds]
            miss = []
            for i, key in enumerate(keys):
                hit = self.plan_cache.get(key)
                if hit is None:
                    miss.append(i)
                else:
                    plans[i] = hit
            if miss:
                sub = [preds[i] for i in miss]
                with tr.span("predicate_compile", n_preds=len(miss)):
                    pc = getattr(self, "pred_cache", None)
                    m0 = pc.misses if pc is not None else 0
                    ses = self.estimator.estimate_batch(sub)
                    if tr.enabled:
                        n_ex = sum(s.is_exact for s in ses)
                        tr.annotate(estimator_exact=int(n_ex),
                                    estimator_gbm=len(miss) - int(n_ex))
                        if pc is not None:
                            md = pc.misses - m0
                            n_words = (self.vectors.shape[0] + 31) // 32
                            tr.annotate(pred_cache_misses=md,
                                        bitmap_words=md * n_words)
                # pool every decidable row — conjunctions as themselves, DNF
                # rows as their unique clauses — into ONE head dispatch
                spec_pred, spec_est, spec_exact = [], [], []
                spec_owner: List[Tuple[int, bool]] = []   # (miss slot, is_clause)
                for j, (p, se) in enumerate(zip(sub, ses)):
                    if isinstance(p, Or):
                        seen: set = set()
                        for t, ce in zip(p.terms, se.per_clause):
                            tk = self._plan_key(t)
                            if tk in seen:
                                continue
                            seen.add(tk)
                            spec_pred.append(t)
                            spec_est.append(ce.sel)
                            spec_exact.append(ce.is_exact)
                            spec_owner.append((j, True))
                    else:
                        spec_pred.append(p)
                        spec_est.append(se.sel)
                        spec_exact.append(se.is_exact)
                        spec_owner.append((j, False))
                if spec_pred:
                    decisions, routes = self._decide_clauses(
                        spec_pred, np.asarray(spec_est, np.float64),
                        np.asarray(spec_exact, bool), k)
                else:
                    decisions = routes = np.zeros(0, np.int32)
                by_owner: Dict[int, List[int]] = {}
                for r, (j, _) in enumerate(spec_owner):
                    by_owner.setdefault(j, []).append(r)
                n_dnf = 0
                for j, (p, se) in enumerate(zip(sub, ses)):
                    rows = by_owner.get(j, [])
                    if isinstance(p, Or):
                        n_dnf += 1
                        clauses = tuple(
                            ClausePlan(
                                self._plan_key(spec_pred[r]),
                                int(decisions[r]),
                                *self._route_pair(int(decisions[r]), int(routes[r])),
                                float(spec_est[r]), int(routes[r]),
                                bool(spec_exact[r]))
                            for r in rows)
                        plans[miss[j]] = ExecutionPlan(
                            clauses, float(se.sel), bool(se.is_exact), "union")
                    else:
                        r = rows[0]
                        plans[miss[j]] = self._single_plan(
                            p, se.sel, se.is_exact,
                            int(decisions[r]), int(routes[r]))
                    self.plan_cache.put(keys[miss[j]], plans[miss[j]])
                if tr.enabled and n_dnf:
                    tr.annotate(n_dnf=n_dnf)
            tr.annotate(plan_cache_hits=b - len(miss),
                        plan_cache_misses=len(miss))
        return plans, time.perf_counter() - t0

    def plan_batch(
        self, preds: Sequence[AnyPredicate], k: int = 10
    ) -> Tuple[np.ndarray, np.ndarray, float]:
        """Scalar spelling of :meth:`make_plan_batch`: returns
        ``(est_selectivities (B,), decisions (B,), plan_overhead_s)``."""
        plans, overhead = self.make_plan_batch(preds, k)
        return (np.asarray([p.est for p in plans], np.float64),
                np.asarray([p.decision for p in plans], np.int32), overhead)

    def plan_batch_ex(
        self, preds: Sequence[AnyPredicate], k: int = 10
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, float]:
        """Batched :meth:`plan_ex`: additionally returns per-row routing
        classes (``NO_ROUTE`` for non-post rows or when routing is off)."""
        plans, overhead = self.make_plan_batch(preds, k)
        return (np.asarray([p.est for p in plans], np.float64),
                np.asarray([p.decision for p in plans], np.int32),
                np.asarray([p.route for p in plans], np.int32), overhead)

    def shard_corpus(self, n_shards: int, n_lists: Optional[int] = None) -> List[CorpusShard]:
        """Partition the corpus into ``n_shards`` contiguous shards, each with
        its own pre-filter executor and post-filter IVF index.

        This is the hook the distribution layer builds on: shards map 1:1
        onto data-axis hosts, every shard answers the same planned query
        over its rows, and the per-shard top-k results merge exactly
        (``repro.dist.collectives.merge_topk``).  Per-shard IVF lists
        default to sqrt(n_local) as in the global build, clamped to the
        shard's row count; empty shards (more shards than rows) are
        dropped rather than built.
        """
        assert n_shards >= 1
        from ..filter import AttributeIndex, PredicateCache

        parts = np.array_split(np.arange(self.vectors.shape[0]), n_shards)
        shards = []
        for s, ids in enumerate(parts):
            if ids.size == 0:
                continue
            v = np.ascontiguousarray(self.vectors[ids])
            c, m = self.cat[ids], self.num[ids]
            lists = min(n_lists or max(1, int(np.sqrt(ids.size))), ids.size)
            ivf = IVFIndex(v, lists, seed=self.config.seed + s).build()
            # per-shard attribute index + cache: bitmaps address shard-local
            # row positions, so each shard compiles its own
            ipre = None
            if self.config.attr_index:
                ipre = IndexedPreFilterExec(
                    v, c, m,
                    AttributeIndex.build(c, m, self.config.range_buckets),
                    PredicateCache(self.config.pred_cache_size),
                )
            # per-shard backend instances: backends index shard-local row
            # positions, so (like the attribute index) each shard builds its
            # own from its slice of the corpus
            bset = None
            if self.config.backends:
                bset = BackendSet.build(v, self.config.backends,
                                        seed=self.config.seed + s)
            shards.append(CorpusShard(
                shard_id=s,
                ids=ids,
                pre_exec=PreFilterExec(v, c, m),
                post_exec=PostFilterExec(
                    ivf, c, m,
                    alpha0=self.config.alpha0, nprobe0=self.config.nprobe0,
                ),
                ipre_exec=ipre,
                backend_set=bset,
            ))
        return shards

    # ------------------------------------------------------------------
    def query(self, q: np.ndarray, pred: AnyPredicate, k: int = 10) -> PlannedResult:
        """Plan + execute one filtered ANN query."""
        q = np.atleast_2d(q)
        plan, plan_overhead = self.make_plan(pred, k)
        tr = getattr(self, "tracer", NULL_TRACER)
        live = getattr(self, "live", None)
        dirty = live is not None and live.dirty
        if plan.is_dnf:
            return self._query_dnf(q, pred, k, plan, plan_overhead)
        est, decision, route = plan.est, plan.decision, plan.route
        if dirty:
            # mutated corpus: the tombstone/segment-composing executor
            t0 = time.perf_counter()
            decisions = np.array([decision], np.int32)
            routes = np.array([route], np.int32)
            with tr.span("execute", n_queries=1, k=int(k), live=True):
                kc0, kw0 = _kernel_snapshot() if tr.enabled else ({}, {})
                d, ids, rounds = _live_execute_grouped(
                    self.pre_exec, self.ipre_exec, self.post_exec,
                    q, [pred], k, decisions, np.array([est]), live,
                    routes=routes, backend_set=self.backend_set, tracer=tr,
                )
                if tr.enabled:
                    _annotate_kernel_delta(tr, kc0, kw0)
            share = time.perf_counter() - t0 + plan_overhead
            return package_results(d, ids, rounds, [plan], share,
                                   plan_overhead)[0]
        with tr.span("execute", n_queries=1, k=int(k), live=False,
                     decision=STRATEGY_NAMES[decision]):
            kc0, kw0 = _kernel_snapshot() if tr.enabled else ({}, {})
            if decision == INDEXED_PRE:
                res = self.ipre_exec.search(q, pred, k)
            elif decision == PRE_FILTER:
                res = self.pre_exec.search(q, pred, k)
            elif route >= 0 and self.backend_set is not None:
                # routed: mask once (bitmap-indexed when covered), then the
                # chosen backend's masked search at the chosen knob tier
                t0 = time.perf_counter()
                mask = self.ipre_exec.candidate_mask(pred)
                d, ids = self.backend_set.search_class(route, q, mask, k)
                res = SearchResult(d, ids, time.perf_counter() - t0, "post")
            else:
                # the estimate also *parameterises* the chosen executor
                res = self.post_exec.search(q, pred, k, est_selectivity=est)
            if tr.enabled:
                _annotate_kernel_delta(tr, kc0, kw0)
        if not res.backend:
            res.backend, res.knob = plan.backend, plan.knob
        res.elapsed += plan_overhead   # end-to-end includes planning (paper §4.1)
        return PlannedResult(res, plan, plan_overhead)

    def _query_dnf(self, q: np.ndarray, pred: AnyPredicate, k: int,
                   plan: ExecutionPlan, plan_overhead: float) -> PlannedResult:
        """Per-disjunct execution of one DNF query: the clauses run as
        ordinary decision-group rows through the shared batch executor, then
        merge with cross-clause de-duplication."""
        tr = getattr(self, "tracer", NULL_TRACER)
        live = getattr(self, "live", None)
        dirty = live is not None and live.dirty
        exp_rows, exp_preds, decisions, ests, routes, row_map = (
            expand_for_execution([pred], [plan]))
        t0 = time.perf_counter()
        with tr.span("execute", n_queries=1, k=int(k), live=dirty,
                     decision="dnf", n_clauses=plan.n_clauses):
            kc0, kw0 = _kernel_snapshot() if tr.enabled else ({}, {})
            qq = q[exp_rows]
            if dirty:
                d, ids, rounds = _live_execute_grouped(
                    self.pre_exec, self.ipre_exec, self.post_exec,
                    qq, exp_preds, k, decisions, ests, live,
                    routes=routes, backend_set=self.backend_set, tracer=tr,
                )
            else:
                d, ids, rounds = _execute_grouped(
                    self.pre_exec, self.ipre_exec, self.post_exec,
                    qq, exp_preds, k, decisions, ests,
                    routes=routes, backend_set=self.backend_set, tracer=tr,
                )
            d, ids, rounds = collapse_clause_results(d, ids, rounds, row_map, k)
            if tr.enabled:
                _annotate_kernel_delta(tr, kc0, kw0)
        share = time.perf_counter() - t0 + plan_overhead
        return package_results(d, ids, rounds, [plan], share, plan_overhead)[0]

    def batch_query(
        self, queries: np.ndarray, preds: Sequence[AnyPredicate], k: int = 10
    ) -> List[PlannedResult]:
        """Batched plan -> group-by-decision -> execute.

        Plans the whole batch in one pass (:meth:`plan_batch`), then runs the
        shared decision-grouped executor (``_execute_grouped``): the
        pre-filter group evaluates each distinct predicate's mask ONCE and
        runs one fused masked top-k over all queries sharing it; the
        post-filter group runs one row-faithful batched IVF search with
        vectorised candidate filtering.  Results are identical to B
        independent :meth:`query` calls (same executors, same per-row
        parameters), only with the per-query Python/jit dispatch overhead
        amortised; per-result ``elapsed`` is the batch wall time split
        evenly across rows.
        """
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        b = len(preds)
        plans, plan_overhead = self.make_plan_batch(preds, k)
        plan_share = plan_overhead / max(b, 1)
        exp_rows, exp_preds, decisions, ests, routes, row_map = (
            expand_for_execution(preds, plans))
        # no DNF rows: the expansion is the identity and execution below is
        # bit-identical to the historical whole-predicate batch path
        identity = len(exp_preds) == b and all(len(m) == 1 for m in row_map)
        xq = queries if identity else queries[exp_rows]
        t0 = time.perf_counter()
        live = getattr(self, "live", None)
        tr = getattr(self, "tracer", NULL_TRACER)
        with tr.span("execute", n_queries=b, k=int(k),
                     live=bool(live is not None and live.dirty)):
            kc0, kw0 = _kernel_snapshot() if tr.enabled else ({}, {})
            if live is not None and live.dirty:
                d, ids, rounds = _live_execute_grouped(
                    self.pre_exec, self.ipre_exec, self.post_exec,
                    xq, exp_preds, k, decisions, ests, live,
                    routes=routes, backend_set=self.backend_set, tracer=tr,
                )
            else:
                d, ids, rounds = _execute_grouped(
                    self.pre_exec, self.ipre_exec, self.post_exec,
                    xq, exp_preds, k, decisions, ests,
                    routes=routes, backend_set=self.backend_set, tracer=tr,
                )
            if not identity:
                d, ids, rounds = collapse_clause_results(
                    d, ids, rounds, row_map, k)
            if tr.enabled:
                _annotate_kernel_delta(tr, kc0, kw0)
        share = (time.perf_counter() - t0) / max(b, 1) + plan_share
        return package_results(d, ids, rounds, plans, share, plan_share)

    # ------------------------------------------------------------------
    def ground_truth(self, q: np.ndarray, pred: AnyPredicate, k: int = 10) -> np.ndarray:
        q = np.atleast_2d(q)
        mask = pred.eval(self.cat, self.num)
        live = getattr(self, "live", None)
        if live is not None and live.dirty:
            # exact truth over the LIVE rows: tombstones compose out of the
            # base mask, the segment scans exactly, parts merge with the
            # same handle-order tie-break the serving path uses
            from ..dist.collectives import merge_topk

            alive = live.alive_mask()
            mask = mask & alive[: live.base_n]
            b = q.shape[0]
            if mask.any():
                bd, bi = l2_topk(q, self.vectors, k, mask)
                bd, bi = np.asarray(bd), np.asarray(bi)
            else:
                bd = np.full((b, k), np.inf, np.float32)
                bi = np.full((b, k), -1, np.int32)
            sm = (pred.eval(live.seg_cat(), live.seg_num())
                  & alive[live.base_n:]) if live.seg_n else np.zeros(0, bool)
            if sm.any():
                kk = min(k, live.seg_n)
                sd, si = l2_topk(q, live.seg_vectors(), kk, sm)
                sd, si = np.asarray(sd), np.asarray(si)
                si = np.where(si >= 0, si + live.base_n, -1).astype(np.int32)
                _, bi = merge_topk([bd, sd], [bi, si], k)
            return bi
        _, ti = l2_topk(q, self.vectors, k, mask)
        return np.asarray(ti)
