"""FilteredANNEngine — the public API tying the paper's pieces together.

Workflow (paper Fig. 1): query -> selectivity estimator -> core planner ->
selected executor -> results.  The engine owns the dataset statistics, the
global IVF index (post-filter backend), the estimator, the planner, and the
executors; ``fit()`` runs the paper's §3.1 training-data preparation.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# NOTE: repro.filter is imported lazily inside build_stats/shard_corpus —
# filter's compiler depends on core.predicates, so a module-level import
# here would make `import repro.filter` (filter-first order) hit a
# partially-initialised package.
from ..index.flat import l2_topk
from ..index.ivf import IVFIndex
from ..index.registry import BackendSet
from ..obs.trace import NULL_TRACER
from .corpus import CompactionPolicy, LiveCorpus
from .executors import (
    IndexedPreFilterExec,
    PostFilterExec,
    PreFilterExec,
    SearchResult,
    recall_at_k,
)
from .planner import CorePlanner, PlannerFeatures, INDEXED_PRE, POST_FILTER, PRE_FILTER
from .predicates import AnyPredicate
from .selectivity import SelectivityEstimator
from .stats import DatasetStats

__all__ = ["FilteredANNEngine", "EngineConfig", "PlannedResult", "CorpusShard",
           "PlanCache", "QueryLabel"]


@dataclasses.dataclass
class EngineConfig:
    n_lists: Optional[int] = None      # IVF lists (default sqrt(N))
    sample_frac: float = 0.02          # stats sample (paper: 1-5 %)
    alpha0: int = 4                    # initial post-filter expansion
    nprobe0: int = 8
    seed: int = 0
    default_k: int = 10                # warmed-up k for the jit'd searches
    attr_index: bool = True            # build the bitmap/range attribute index
    range_buckets: int = 128           # filter.ranges.DEFAULT_BUCKETS
    pred_cache_size: int = 256         # compiled-predicate LRU entries
    plan_cache_size: int = 1024        # memoised (predicate, k) plan entries
    # registered ANN backends to race/route over (repro.index.registry
    # names).  None keeps the legacy plan-only engine: no BackendSet is
    # built, the decision space stays (pre, post, ipre), and every code
    # path is bit-identical to before the routing extension existed.
    backends: Optional[Tuple[str, ...]] = None
    # recall@k a (backend, knob) class must hit on a training query before
    # utility gets a say in the routing label; below it, max-recall wins.
    route_recall_target: float = 0.9
    # live-corpus compaction thresholds (see core.corpus.CompactionPolicy):
    # churn past any of these makes needs_compaction()/maybe_compact() fold
    # segment + tombstones into a rebuilt index
    max_tombstone_frac: float = 0.20
    max_segment_frac: float = 0.20
    max_list_drift: float = 1.75


@dataclasses.dataclass
class PlannedResult:
    result: SearchResult
    est_selectivity: float
    decision: int                      # PRE_FILTER / POST_FILTER / INDEXED_PRE
    plan_overhead: float               # seconds spent estimating + deciding


@dataclasses.dataclass
class QueryLabel:
    """Outcome of one §3.1 utility race (see :meth:`label_query`).

    ``route`` is the argmax (backend, knob-tier) class when a BackendSet was
    raced, else -1; ``route_utils`` holds the per-class utilities."""

    label: int                         # PRE_FILTER or POST_FILTER
    true_sel: float
    u_pre: float
    u_post: float
    route: int = -1
    route_utils: Optional[np.ndarray] = None

    def __iter__(self):
        # legacy tuple unpacking: label, true_sel, u_pre, u_post
        return iter((self.label, self.true_sel, self.u_pre, self.u_post))


STRATEGY_NAMES = {PRE_FILTER: "pre", POST_FILTER: "post", INDEXED_PRE: "ipre"}

# route value meaning "no routed backend": execute POST rows on the legacy
# lazy α-doubling post-filter path (bit-identical to the pre-routing engine)
NO_ROUTE = -1


def _default_route_name(decision: int) -> Tuple[str, str]:
    """(backend, knob) labels for un-routed rows: both pre-filter plans are
    exact masked scans, the legacy post path is the adaptive IVF executor."""
    if decision == POST_FILTER:
        return "ivf", "adapt"
    return "flat", "exact"


def _kernel_snapshot() -> Tuple[dict, dict]:
    """Current (dispatch counts, dispatch wall) of the process-global kernel
    ledger — an execute span annotates the DELTA across its body, so the
    span carries exactly its own dispatches."""
    from ..kernels import ops

    return ops.dispatch_counts(), ops.dispatch_wall()


def _annotate_kernel_delta(tracer, counts0: dict, wall0: dict) -> None:
    """Attach per-kernel dispatch deltas since ``counts0``/``wall0`` to the
    open span: counts on the deterministic ledger (``kernel_<name>`` attrs),
    wall seconds on the real ledger (``kernel:<name>`` wall_detail keys —
    what ``span_summary`` ranks against ``launch/roofline.py``)."""
    from ..kernels import ops

    for name, n in ops.dispatch_counts().items():
        d = n - counts0.get(name, 0)
        if d:
            tracer.annotate(**{f"kernel_{name}": d})
    for name, s in ops.dispatch_wall().items():
        dw = s - wall0.get(name, 0.0)
        if dw > 0.0:
            tracer.add_wall(f"kernel:{name}", dw)


def package_results(
    d: np.ndarray,
    ids: np.ndarray,
    rounds: np.ndarray,
    ests: np.ndarray,
    decisions: np.ndarray,
    share: float,
    plan_share: float,
    route_names: Optional[Sequence[Optional[Tuple[str, str]]]] = None,
) -> List[PlannedResult]:
    """Wrap batched (B, k) arrays into per-row PlannedResults — one
    packaging convention for the flat and sharded batch paths (``share`` is
    the batch wall time split evenly across rows, plan overhead included).
    ``route_names[j]`` is the routed (backend, knob-tier) pair for row j or
    None for un-routed rows (default naming by decision)."""
    out = []
    for j in range(len(ests)):
        dec = int(decisions[j])
        if route_names is not None and route_names[j] is not None:
            bk, knob = route_names[j]
        else:
            bk, knob = _default_route_name(dec)
        out.append(PlannedResult(
            SearchResult(d[j : j + 1], ids[j : j + 1], share,
                         STRATEGY_NAMES[dec],
                         n_expansions=int(rounds[j]),
                         backend=bk, knob=knob),
            float(ests[j]), dec, plan_share,
        ))
    return out


def _execute_grouped(
    pre_exec: PreFilterExec,
    ipre_exec: Optional[IndexedPreFilterExec],
    post_exec: PostFilterExec,
    queries: np.ndarray,
    preds: Sequence[AnyPredicate],
    k: int,
    decisions: np.ndarray,
    ests: np.ndarray,
    routes: Optional[np.ndarray] = None,
    backend_set: Optional[BackendSet] = None,
    tracer=None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Decision-grouped batch execution — the ONE implementation behind both
    the flat (`FilteredANNEngine.batch_query`) and sharded
    (`CorpusShard.search_batch`) paths.

    The two pre-filter groups (scan-masked and bitmap-masked) each evaluate
    every distinct predicate's mask once and run one fused masked top-k over
    all queries sharing it; un-routed post-filter rows run one row-faithful
    batched IVF search.  With ``routes``/``backend_set``, post-filter rows
    carrying a routing class >= 0 group by (class, predicate): each group
    evaluates its predicate mask once (through the bitmap index when
    covered) and runs ONE ``search_masked`` call on the routed backend —
    the (decision, backend, knob) extension of PR 2's decision grouping.
    Returns ``(dists (B, k), ids (B, k) local, expansion_rounds (B,))``.
    """
    tr = tracer if tracer is not None else NULL_TRACER
    b = len(preds)
    out_d = np.full((b, k), np.inf, np.float32)
    out_i = np.full((b, k), -1, np.int32)
    rounds = np.zeros(b, np.int64)
    for decision, ex in ((PRE_FILTER, pre_exec), (INDEXED_PRE, ipre_exec or pre_exec)):
        groups: dict = {}
        for i in range(b):
            if decisions[i] == decision:
                groups.setdefault(preds[i], []).append(i)
        for pred, rows in groups.items():
            bk, knob = _default_route_name(decision)
            with tr.span("group", decision=STRATEGY_NAMES[decision],
                         backend=bk, knob=knob, n_rows=len(rows)):
                # split of ex.search(): mask once, then the fused masked
                # top-k — bit-identical (search() is exactly this pair),
                # but the mask stays visible for the candidate-count attr
                t0 = time.perf_counter()
                m = ex.candidate_mask(pred)
                res = ex.search_masked(queries[rows], m, k, t0=t0)
                if tr.enabled:
                    tr.annotate(n_candidates=int(m.sum()))
                out_d[rows], out_i[rows] = res.dists, res.ids
    routed = routes is not None and backend_set is not None
    post_rows = [
        i for i in range(b)
        if decisions[i] == POST_FILTER and not (routed and routes[i] >= 0)
    ]
    if post_rows:
        with tr.span("group", decision="post", backend="ivf", knob="adapt",
                     n_rows=len(post_rows)):
            d, ids, rnd = post_exec.search_rows(
                queries[post_rows], [preds[i] for i in post_rows], k,
                [float(ests[i]) for i in post_rows],
            )
            out_d[post_rows], out_i[post_rows] = d, ids
            rounds[post_rows] = rnd
            if tr.enabled:
                tr.annotate(expansion_rounds=int(np.asarray(rnd).sum()))
    if routed:
        groups = {}
        for i in range(b):
            if decisions[i] == POST_FILTER and routes[i] >= 0:
                groups.setdefault((int(routes[i]), preds[i]), []).append(i)
        mask_ex = ipre_exec or pre_exec
        masks: dict = {}
        for (ci, pred), rows in groups.items():
            bk, knob = backend_set.classes()[ci]
            with tr.span("group", decision="post", backend=str(bk),
                         knob=str(knob), n_rows=len(rows)):
                if pred not in masks:
                    masks[pred] = mask_ex.candidate_mask(pred)
                d, ids = backend_set.search_class(ci, queries[rows], masks[pred], k)
                if tr.enabled:
                    tr.annotate(n_candidates=int(masks[pred].sum()))
                out_d[rows], out_i[rows] = d[:, :k], ids[:, :k]
    return out_d, out_i, rounds


def _live_execute_grouped(
    pre_exec: PreFilterExec,
    ipre_exec: Optional[IndexedPreFilterExec],
    post_exec: PostFilterExec,
    queries: np.ndarray,
    preds: Sequence[AnyPredicate],
    k: int,
    decisions: np.ndarray,
    ests: np.ndarray,
    live: LiveCorpus,
    routes: Optional[np.ndarray] = None,
    backend_set: Optional[BackendSet] = None,
    tracer=None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Tombstone/segment-composing twin of ``_execute_grouped`` — the one
    batch executor every query takes once the corpus mutated.

    Per decision group the base corpus answers exactly as before, except
    every candidate mask is ANDed with the live bitmap (tombstoned rows can
    never surface) and masks from the extended attribute index (length
    ``n_total``) are sliced back to the base rows the executors hold.  The
    append segment is searched by a plain masked scan (it stays small
    between compactions) through the SAME ``PreFilterExec`` kernel path the
    base uses, and the two parts merge with ``merge_topk`` — base part
    stacked first, so the composite column tie-break keeps handle order,
    which is the bit-equality invariant against a fresh build of the
    equivalent post-mutation corpus (handle -> compacted-position maps are
    monotone).
    """
    from ..dist.collectives import merge_topk

    tr = tracer if tracer is not None else NULL_TRACER
    b = len(preds)
    out_d = np.full((b, k), np.inf, np.float32)
    out_i = np.full((b, k), -1, np.int32)
    rounds = np.zeros(b, np.int64)
    alive = live.alive_mask()
    base_n = live.base_n
    alive_base, alive_seg = alive[:base_n], alive[base_n:]
    seg_exec = (
        PreFilterExec(live.seg_vectors(), live.seg_cat(), live.seg_num())
        if live.seg_n else None
    )
    seg_masks: dict = {}

    def seg_mask(pred) -> np.ndarray:
        if pred not in seg_masks:
            seg_masks[pred] = pred.eval(live.seg_cat(), live.seg_num()) & alive_seg
        return seg_masks[pred]

    def finish(rows, pred, bd, bi):
        if seg_exec is not None and seg_mask(pred).any():
            res = seg_exec.search_masked(queries[rows], seg_mask(pred), k)
            si = np.where(res.ids >= 0, res.ids + base_n, -1).astype(np.int32)
            bd, bi = merge_topk(np.stack([bd, res.dists]), np.stack([bi, si]), k)
        out_d[rows], out_i[rows] = bd, bi

    for decision, ex in ((PRE_FILTER, pre_exec), (INDEXED_PRE, ipre_exec or pre_exec)):
        groups: dict = {}
        for i in range(b):
            if decisions[i] == decision:
                groups.setdefault(preds[i], []).append(i)
        for pred, rows in groups.items():
            bk, knob = _default_route_name(decision)
            with tr.span("group", decision=STRATEGY_NAMES[decision],
                         backend=bk, knob=knob, n_rows=len(rows), live=True):
                m = ex.candidate_mask(pred)
                mm = m[:base_n] & alive_base
                res = ex.search_masked(queries[rows], mm, k)
                if tr.enabled:
                    tr.annotate(n_candidates=int(mm.sum()))
                finish(rows, pred, res.dists, res.ids)
    routed = routes is not None and backend_set is not None
    post_rows = [
        i for i in range(b)
        if decisions[i] == POST_FILTER and not (routed and routes[i] >= 0)
    ]
    if post_rows:
        with tr.span("group", decision="post", backend="ivf", knob="adapt",
                     n_rows=len(post_rows), live=True):
            d, ids, rnd = post_exec.search_rows(
                queries[post_rows], [preds[i] for i in post_rows], k,
                [float(ests[i]) for i in post_rows], alive=alive_base,
            )
            rounds[post_rows] = rnd
            groups = {}
            for j, i in enumerate(post_rows):
                groups.setdefault(preds[i], []).append(j)
            for pred, js in groups.items():
                finish([post_rows[j] for j in js], pred, d[js], ids[js])
            if tr.enabled:
                tr.annotate(expansion_rounds=int(np.asarray(rnd).sum()))
    if routed:
        groups = {}
        for i in range(b):
            if decisions[i] == POST_FILTER and routes[i] >= 0:
                groups.setdefault((int(routes[i]), preds[i]), []).append(i)
        mask_ex = ipre_exec or pre_exec
        base_masks: dict = {}
        for (ci, pred), rows in groups.items():
            bk, knob = backend_set.classes()[ci]
            with tr.span("group", decision="post", backend=str(bk),
                         knob=str(knob), n_rows=len(rows), live=True):
                if pred not in base_masks:
                    base_masks[pred] = mask_ex.candidate_mask(pred)[:base_n] & alive_base
                d, ids = backend_set.search_class(ci, queries[rows], base_masks[pred], k)
                if tr.enabled:
                    tr.annotate(n_candidates=int(base_masks[pred].sum()))
                finish(rows, pred, d[:, :k], ids[:, :k])
    return out_d, out_i, rounds


class PlanCache:
    """LRU memo of ``(canonical predicate key, k) -> (est, decision, route)``.

    Serving traffic repeats predicates constantly; planning the same
    predicate is pure — the decision depends only on predicate + dataset
    statistics + the current planner head — so repeats can skip the
    estimator and the MLP dispatch entirely.  Invalidation is tied to the
    things a cached plan DOES depend on, via :meth:`validate_epoch`
    against ``(planner_version, planner.generation,
    estimator.generation)`` on every lookup: a planner swap, a planner or
    estimator refit — even one invoked directly on ``engine.planner`` /
    ``engine.estimator`` — empties the memo before it can serve a stale
    plan.
    """

    def __init__(self, capacity: int = 1024):
        assert capacity >= 1
        self.capacity = capacity
        self._store: "OrderedDict[Tuple, Tuple[float, int, int]]" = OrderedDict()
        self.epoch: Tuple = ()        # engine._plan_epoch() the memo is valid under
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def validate_epoch(self, epoch: Tuple) -> None:
        """Drop every entry if the (planner head, estimator, corpus
        generation) tuple the cached plans were computed under has changed —
        catches direct ``estimator.fit()`` calls that bypass the engine's
        own clear hooks, and (since the corpus generation joined the epoch)
        any live-corpus mutation, whose tombstones/appends change exact
        selectivities.  Epoch-mismatch drops are counted separately from
        capacity evictions so mutation-driven churn is observable in
        ``stats()``."""
        if epoch != self.epoch:
            if self.epoch:
                self.invalidations += 1
            self._store.clear()
            self.epoch = epoch

    def __len__(self) -> int:
        return len(self._store)

    def get(self, key) -> Optional[Tuple[float, int, int]]:
        hit = self._store.get(key)
        if hit is None:
            self.misses += 1
            return None
        self.hits += 1
        self._store.move_to_end(key)
        return hit

    def put(self, key, value: Tuple[float, int, int]) -> None:
        self._store[key] = value
        if len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._store.clear()

    def stats(self) -> Dict[str, int]:
        return {
            "size": len(self._store), "capacity": self.capacity,
            "hits": self.hits, "misses": self.misses, "evictions": self.evictions,
            "invalidations": self.invalidations,
        }


@dataclasses.dataclass
class CorpusShard:
    """One partition of the corpus with its own pre-/post-filter executors.

    Produced by :meth:`FilteredANNEngine.shard_corpus`.  Executors operate
    on shard-local row numbers; :meth:`search` maps results back to global
    ids so shard outputs merge directly (``repro.dist.collectives.merge_topk``).
    Each shard carries its OWN attribute index + predicate cache (bitmaps
    are positional, so they cannot be sliced from the global index).
    """

    shard_id: int
    ids: np.ndarray                    # (n_local,) global row ids
    pre_exec: PreFilterExec
    post_exec: PostFilterExec
    ipre_exec: Optional[IndexedPreFilterExec] = None
    backend_set: Optional[BackendSet] = None   # per-shard backend instances
    live: Optional[LiveCorpus] = None          # created on first mutation

    # ------------------------------------------------------------------
    def ensure_live(self) -> LiveCorpus:
        if self.live is None:
            self.live = LiveCorpus(self.pre_exec.vectors,
                                   self.pre_exec.cat, self.pre_exec.num)
        return self.live

    def upsert_local(
        self,
        vectors: np.ndarray,
        cat: np.ndarray,
        num: np.ndarray,
        global_ids: np.ndarray,
        local_ids: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """Append rows to this shard's live view and extend the local ->
        global map (``global_ids``, one per row, assigned by the sharded
        engine's placement rule).  ``local_ids`` tombstones replaced LOCAL
        handles first.  Returns the new local handles."""
        live = self.ensure_live()
        c = np.atleast_2d(np.asarray(cat))
        m = np.atleast_2d(np.asarray(num))
        handles = live.upsert(vectors, c, m, ids=local_ids)
        if self.ipre_exec is not None:
            self.ipre_exec.index.extend(c, m)
            self.ipre_exec.cache.invalidate()
        self.ids = np.concatenate(
            [self.ids, np.asarray(global_ids, self.ids.dtype)]
        )
        return handles

    def delete_local(self, local_ids: np.ndarray) -> np.ndarray:
        """Tombstone shard-local handles; returns the newly dead ones."""
        return self.ensure_live().delete(local_ids)

    def search(
        self,
        q: np.ndarray,
        pred: AnyPredicate,
        k: int,
        decision: int,
        est_selectivity: Optional[float] = None,
        route: int = NO_ROUTE,
    ) -> SearchResult:
        """Run the planned executor on this shard; returns GLOBAL ids.
        ``route >= 0`` sends a post-filter row to that (backend, knob-tier)
        class of the shard's BackendSet instead of the lazy post path."""
        if self.live is not None and self.live.dirty:
            t0 = time.perf_counter()
            decisions = np.array([decision], np.int32)
            routes_arr = np.array([route], np.int32)
            d, ids, rounds = _live_execute_grouped(
                self.pre_exec, self.ipre_exec, self.post_exec,
                q, [pred], k, decisions,
                np.array([0.0 if est_selectivity is None else est_selectivity]),
                self.live, routes=routes_arr, backend_set=self.backend_set,
            )
            res = SearchResult(d, ids, time.perf_counter() - t0,
                               STRATEGY_NAMES[decision],
                               n_expansions=int(rounds[0]))
            if route >= 0 and decision == POST_FILTER and self.backend_set is not None:
                res.backend, res.knob = self.backend_set.classes()[route]
            res.ids = self._to_global(res.ids)
            return res
        if decision == INDEXED_PRE:
            res = (self.ipre_exec or self.pre_exec).search(q, pred, k)
        elif decision == PRE_FILTER:
            res = self.pre_exec.search(q, pred, k)
        elif route >= 0 and self.backend_set is not None:
            t0 = time.perf_counter()
            mask = (self.ipre_exec or self.pre_exec).candidate_mask(pred)
            d, ids = self.backend_set.search_class(route, q, mask, k)
            bk, knob = self.backend_set.classes()[route]
            res = SearchResult(d, ids, time.perf_counter() - t0, "post",
                               backend=bk, knob=knob)
        else:
            res = self.post_exec.search(q, pred, k, est_selectivity=est_selectivity)
        res.ids = self._to_global(res.ids)
        return res

    def _to_global(self, ids: np.ndarray) -> np.ndarray:
        valid = ids >= 0
        return np.where(valid, self.ids[np.maximum(ids, 0)], -1).astype(np.int32)

    def search_batch(
        self,
        queries: np.ndarray,
        preds: Sequence[AnyPredicate],
        k: int,
        decisions: np.ndarray,
        ests: np.ndarray,
        routes: Optional[np.ndarray] = None,
        tracer=None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Run a whole planned batch on this shard (decision-grouped, same
        shared ``_execute_grouped`` core as
        :meth:`FilteredANNEngine.batch_query`).  Returns
        ``(dists (B, k), ids (B, k) GLOBAL, expansion_rounds (B,))`` ready to
        stack across shards for one batched ``merge_topk``."""
        if self.live is not None and self.live.dirty:
            out_d, out_i, rounds = _live_execute_grouped(
                self.pre_exec, self.ipre_exec, self.post_exec,
                queries, preds, k, decisions, ests, self.live,
                routes=routes, backend_set=self.backend_set, tracer=tracer,
            )
        else:
            out_d, out_i, rounds = _execute_grouped(
                self.pre_exec, self.ipre_exec, self.post_exec,
                queries, preds, k, decisions, ests,
                routes=routes, backend_set=self.backend_set, tracer=tracer,
            )
        return out_d, self._to_global(out_i), rounds


class FilteredANNEngine:
    def __init__(
        self,
        vectors: np.ndarray,
        cat: np.ndarray,
        num: np.ndarray,
        config: EngineConfig = EngineConfig(),
    ):
        self.vectors = np.ascontiguousarray(vectors, np.float32)
        self.cat, self.num = cat, num
        self.config = config
        self.build_time_: dict = {}

    # ------------------------------------------------------------------
    def build_stats(self) -> "FilteredANNEngine":
        """Planning-only build: statistics, estimator, planner, features.

        Skips the global IVF index, local executors, and jit warmup — all
        a sharded deployment pays for but never uses (every query runs on
        per-shard executors from :meth:`shard_corpus`).  Enough for
        :meth:`plan` and :meth:`shard_corpus`; :meth:`fit` and the
        unsharded :meth:`query` need the full :meth:`build`.
        """
        t0 = time.perf_counter()
        self.dataset_stats = DatasetStats.build(
            self.vectors, self.cat, self.num,
            sample_frac=self.config.sample_frac, seed=self.config.seed,
        )
        t1 = time.perf_counter()
        # bitmap/range attribute index + shared compiled-predicate cache:
        # the estimator's exact fast path and the indexed pre-filter
        # executor compile each predicate once between them
        from ..filter import AttributeIndex, PredicateCache
        from ..filter.cache import canonical_key

        self.attr_index = (
            AttributeIndex.build(self.cat, self.num, self.config.range_buckets)
            if self.config.attr_index else None
        )
        self.pred_cache = PredicateCache(self.config.pred_cache_size)
        # memoised plans for repeat predicates (pure in predicate + stats +
        # planner head; cleared on fit/swap_planner)
        self.plan_cache = PlanCache(self.config.plan_cache_size)
        self._plan_key = canonical_key
        self.planner_version = 0
        t2 = time.perf_counter()
        self.estimator = SelectivityEstimator(
            self.dataset_stats, index=self.attr_index, cache=self.pred_cache
        )
        self.planner = CorePlanner(seed=self.config.seed)
        self.feat = PlannerFeatures(self.dataset_stats)
        self.backend_set: Optional[BackendSet] = None   # built by build()
        # live-corpus mutation layer: every upsert/delete flows through
        # self.live; the estimator composes its tombstones into the exact
        # fast path, and corpus_generation joins the plan epoch so memoised
        # plans from a previous corpus version invalidate on lookup.
        # (corpus_generation is engine-level and monotone ACROSS compactions
        # — a fresh LiveCorpus restarts its own generation at 0.)
        self.live = LiveCorpus(self.vectors, self.cat, self.num)
        self.estimator.live = self.live
        self.corpus_generation = 0
        self.n_compactions = 0
        self.compaction_policy = CompactionPolicy(
            max_tombstone_frac=self.config.max_tombstone_frac,
            max_segment_frac=self.config.max_segment_frac,
            max_list_drift=self.config.max_list_drift,
        )
        # observability: the no-op tracer by default, an installed one kept
        # across compaction rebuilds (compact() re-runs build_stats) the
        # same way the trained heads survive
        self.tracer = getattr(self, "tracer", NULL_TRACER)
        self.build_time_["stats"] = t1 - t0
        self.build_time_["attr_index"] = t2 - t1
        return self

    def build(self) -> "FilteredANNEngine":
        """Offline phase: statistics + global index (paper Table 2 costs)."""
        self.build_stats()
        t1 = time.perf_counter()
        self.ivf = IVFIndex(self.vectors, self.config.n_lists, seed=self.config.seed).build()
        t2 = time.perf_counter()
        self.pre_exec = PreFilterExec(self.vectors, self.cat, self.num)
        self.ipre_exec = IndexedPreFilterExec(
            self.vectors, self.cat, self.num, self.attr_index, self.pred_cache
        )
        self.post_exec = PostFilterExec(
            self.ivf, self.cat, self.num,
            alpha0=self.config.alpha0, nprobe0=self.config.nprobe0,
        )
        if self.config.backends:
            t_b = time.perf_counter()
            self.backend_set = BackendSet.build(
                self.vectors, self.config.backends, seed=self.config.seed
            )
            self.build_time_["backends"] = time.perf_counter() - t_b
        # warm the jit'd pre-filter bucket shapes: per-query utility timings
        # (planner training labels, §3.1) must not include one-off XLA
        # compiles — a cold bucket inflates T_search by ~100x and mislabels
        # the query
        self._warm_buckets(self.config.default_k)
        t3 = time.perf_counter()
        self.build_time_.update({"ivf": t2 - t1, "warmup": t3 - t2})
        return self

    def _warm_buckets(self, k: int):
        from ..index.flat import l2_topk

        n, d = self.vectors.shape
        # the pre-filter executor pads query batches to pow2 with floor 8,
        # so (8, p) is the shape every small-batch (and per-query) search hits
        q = np.zeros((8, d), np.float32)
        p = 16
        while p <= 2 * n:
            sub = np.zeros((min(p, 1 << 24), d), np.float32)
            m = np.ones(sub.shape[0], bool)
            l2_topk(q, sub, min(k, sub.shape[0]), m)
            p *= 2
        q1 = np.zeros((1, d), np.float32)
        l2_topk(q1, self.vectors, k)                      # ground-truth shape
        l2_topk(q1, self.vectors, k, np.ones(n, bool))
        # the large-passing-set branch runs the masked top-k over the FULL
        # corpus with the pow2-padded (floor 8) query batch — warm it too
        l2_topk(q, self.vectors, k, np.ones(n, bool))

    # ------------------------------------------------------------------
    def label_query(self, q: np.ndarray, pred: AnyPredicate, k: int = 10,
                    ) -> QueryLabel:
        """Paper §3.1 utility labelling — the ONE definition shared by the
        offline :meth:`fit` loop, the online feedback loop's shadow
        labeller, and the benchmarks' oracle: run BOTH strategies against
        the exact masked top-k and pick the winner by utility
        U = recall@k / T_search.

        With a built BackendSet, every registered (backend, knob-tier)
        class is raced under the same rule (mask evaluation charged to each
        contender, since routed execution pays it at serve time); the
        winning class — highest utility among classes whose measured recall
        meets ``config.route_recall_target``, max-recall when none do —
        becomes the routing label and its utility competes as the post-side
        champion, so a backend that beats BOTH the exact scan and the lazy
        post path wins the plan decision too.  Returns a
        :class:`QueryLabel` (legacy 4-tuple unpacking still works)."""
        q = np.atleast_2d(q)
        t_m0 = time.perf_counter()
        mask = pred.eval(self.cat, self.num)
        live = getattr(self, "live", None)
        live_dirty = live is not None and live.dirty
        alive_base = live.alive_mask()[: live.base_n] if live_dirty else None
        if live_dirty:
            # race strategies over the same live candidate set: tombstones
            # compose into mask, ground truth, and the post path alike (the
            # segment sits out the race — both contenders skip it equally)
            mask = mask & alive_base
        t_mask = time.perf_counter() - t_m0
        true_sel = float(mask.mean())
        _, ti = l2_topk(q, self.vectors, k, mask)             # exact ground truth
        ti = np.asarray(ti)
        if live_dirty:
            r_pre = self.pre_exec.search_masked(q, mask, k)
            r_pre.elapsed += t_mask          # charge mask eval, like search()
        else:
            r_pre = self.pre_exec.search(q, pred, k)
        r_post = self.post_exec.search(q, pred, k, est_selectivity=true_sel,
                                       alive=alive_base)
        u_pre = recall_at_k(r_pre.ids, ti) / max(r_pre.elapsed, 1e-7)
        u_post = recall_at_k(r_post.ids, ti) / max(r_post.elapsed, 1e-7)
        route, route_utils = NO_ROUTE, None
        if self.backend_set is not None:
            classes = self.backend_set.classes()
            n_c = len(classes)
            route_utils = np.zeros(n_c, np.float64)
            recalls = np.zeros(n_c, np.float64)
            for ci in range(n_c):
                t0 = time.perf_counter()
                _, ids = self.backend_set.search_class(ci, q, mask, k)
                dt = time.perf_counter() - t0 + t_mask
                recalls[ci] = recall_at_k(ids, ti)
                route_utils[ci] = recalls[ci] / max(dt, 1e-7)
            # Constrained pick (Faiss-autotune style): utility only decides
            # among classes meeting the recall target.  A raw utility argmax
            # lets wall-clock noise during fit route queries to a fast
            # low-recall tier, collapsing served recall run-to-run.
            ok = recalls >= self.config.route_recall_target
            if ok.any():
                route = int(np.argmax(np.where(ok, route_utils, -1.0)))
            else:
                route = int(np.argmax(recalls + 1e-9 * route_utils))
            u_post = max(u_post, float(route_utils[route]))
        label = PRE_FILTER if u_pre >= u_post else POST_FILTER
        return QueryLabel(label, true_sel, u_pre, u_post, route, route_utils)

    def fit(
        self,
        train_queries: Sequence[np.ndarray],
        train_preds: Sequence[AnyPredicate],
        k: int = 10,
        verbose: bool = False,
    ) -> "FilteredANNEngine":
        """Paper §3.1: execute both strategies per training query, label by
        utility U = recall@k / T_search, train estimator GBM + planner MLP."""
        t0 = time.perf_counter()
        labels, true_sels, route_labels = [], [], []
        for q, pred in zip(train_queries, train_preds):
            lab = self.label_query(q, pred, k)
            labels.append(lab.label)
            true_sels.append(lab.true_sel)
            route_labels.append(lab.route)
            if verbose:
                print(f"  {pred}: sel={lab.true_sel:.4f} "
                      f"U_pre={lab.u_pre:.1f} U_post={lab.u_post:.1f}")
        # selectivity estimator GBM trains on the same queries (paper §3.1)
        self.estimator.fit(list(train_preds), true_sels)
        # re-extract features with the trained estimator so train/test match
        feats = []
        for p in train_preds:
            est, ex = self.estimator.estimate_ex(p)
            feats.append(self.feat.vector(p, est, k, ex))
        self.planner.fit(np.stack(feats), np.asarray(labels))
        if self.backend_set is not None:
            # routing head on the same features: argmax-utility class labels
            self.planner.fit_routing(
                np.stack(feats), np.asarray(route_labels),
                self.backend_set.class_names(),
            )
        # warm the single-query predict shape: the first live query must not
        # pay the (1, F) jit compile (~150 ms) inside its latency budget
        self.planner.decide(feats[0])
        # estimator AND head both changed: memoised plans are stale
        self.plan_cache.clear()
        self.planner_version += 1
        self.build_time_["fit"] = time.perf_counter() - t0
        return self

    def swap_planner(self, planner: CorePlanner) -> "FilteredANNEngine":
        """Atomically install a refit planner head (the online feedback
        loop's hook).  Clears the plan cache — memoised decisions belong to
        the old head — and pre-warms the new head's (1, F) predict shape so
        the first live query after a swap pays no jit compile."""
        self.planner = planner
        self.plan_cache.clear()
        self.planner_version += 1
        if planner.params is not None:
            planner.decide(np.zeros(planner.n_features, np.float32))
        return self

    def set_tracer(self, tracer) -> "FilteredANNEngine":
        """Install an :class:`repro.obs.trace.Tracer` on every serving path
        (``None`` restores the no-op default)."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        return self

    @staticmethod
    def _hit_ratio(hits: int, misses: int) -> float:
        total = hits + misses
        return round(hits / total, 6) if total else 0.0

    def stats(self) -> dict:
        """Public serving-counter accessor: predicate-cache hit/miss/eviction
        stats, plan-cache stats, and the planner head version — previously
        only reachable by poking engine internals.  (Dataset statistics
        live on ``self.dataset_stats``.)

        ``kernel_dispatch`` mirrors the process-global per-kernel dispatch
        counts (``repro.kernels.ops``) — cumulative across every engine in
        the process, so tests diff it around the call under measurement —
        and ``cache_hit_ratio`` summarises the three serving caches."""
        out: dict = {"planner_version": getattr(self, "planner_version", 0)}
        ratios: dict = {}
        pred_cache = getattr(self, "pred_cache", None)
        if pred_cache is not None:
            s = pred_cache.stats()
            out["pred_cache"] = s
            ratios["pred_cache"] = self._hit_ratio(s["hits"], s["misses"])
            ratios["mask_tier"] = self._hit_ratio(s["mask_hits"], s["mask_misses"])
        plan_cache = getattr(self, "plan_cache", None)
        if plan_cache is not None:
            s = plan_cache.stats()
            out["plan_cache"] = s
            ratios["plan_cache"] = self._hit_ratio(s["hits"], s["misses"])
        out["cache_hit_ratio"] = ratios
        from ..kernels import ops as _kops

        out["kernel_dispatch"] = _kops.dispatch_counts()
        out["corpus_generation"] = getattr(self, "corpus_generation", 0)
        out["n_compactions"] = getattr(self, "n_compactions", 0)
        live = getattr(self, "live", None)
        if live is not None:
            out["live"] = live.stats()
        return out

    # ------------------------------------------------------------------
    # live-corpus mutations
    # ------------------------------------------------------------------
    def upsert(
        self,
        vectors: np.ndarray,
        cat: np.ndarray,
        num: np.ndarray,
        ids: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """Stream rows into the live corpus; returns their (stable, never
        reused) handles.  ``ids`` replaces existing handles: old rows are
        tombstoned, new versions appended under fresh handles.

        Incremental refresh instead of rebuild: label bitmaps extend and
        stay exact; the equi-depth range index goes stale (fails closed out
        of ``covers()``, so range predicates demote to the scan path and
        estimated selectivity); dataset statistics fold the delta in;
        compiled-predicate entries invalidate (their word count is stale);
        the plan epoch bumps so memoised plans re-plan on next lookup."""
        v = np.atleast_2d(np.asarray(vectors, np.float32))
        c = np.atleast_2d(np.asarray(cat))
        m = np.atleast_2d(np.asarray(num))
        tr = getattr(self, "tracer", NULL_TRACER)
        with tr.span("write", op="upsert", n_rows=int(v.shape[0])):
            removed_cat = removed_num = None
            if ids is not None:
                old = np.unique(np.asarray(ids, np.int64))
                old = old[~self.live.is_deleted(old)]
                if old.size:      # attrs of the rows about to be tombstoned
                    removed_cat, removed_num = self.live.row_attrs(old)
            handles = self.live.upsert(v, c, m, ids=ids)
            if self.attr_index is not None:
                self.attr_index.extend(c, m)
                self.pred_cache.invalidate()
            self.dataset_stats.apply_delta(
                added_cat=c, added_num=m,
                removed_cat=removed_cat, removed_num=removed_num,
            )
            ivf = getattr(self, "ivf", None)
            if ivf is not None:   # keep the drift trigger's assignments current
                self.live.assign_new(ivf.centroids)
            self.corpus_generation += 1
            tr.annotate(corpus_generation=self.corpus_generation)
        return handles

    def delete(self, ids: np.ndarray) -> np.ndarray:
        """Tombstone handles (idempotent); returns the newly dead ones.
        No index structure is rewritten — the tombstone bitmap composes
        into every candidate mask, backend call, and exact-selectivity
        popcount at query time — but statistics fold the removal in and
        the plan epoch bumps."""
        tr = getattr(self, "tracer", NULL_TRACER)
        with tr.span("write", op="delete"):
            fresh = self.live.delete(ids)
            if fresh.size:
                rc, rn = self.live.row_attrs(fresh)
                self.dataset_stats.apply_delta(removed_cat=rc, removed_num=rn)
            self.corpus_generation += 1
            tr.annotate(n_dead=int(fresh.size),
                        corpus_generation=self.corpus_generation)
        return fresh

    def list_drift(self) -> float:
        """IVF list-balance drift if the segment were folded in: max list
        count (base + incrementally assigned segment rows) over the
        build-time max.  1.0 when there is nothing to fold."""
        ivf = getattr(self, "ivf", None)
        live = getattr(self, "live", None)
        if ivf is None or live is None or not live.seg_n:
            return 1.0
        assign = live.assign_new(ivf.centroids)
        counts = ivf.list_counts + np.bincount(assign, minlength=ivf.n_lists)
        return float(counts.max() / max(int(ivf.list_counts.max()), 1))

    def needs_compaction(self) -> bool:
        return self.compaction_policy.due(
            self.live.tombstone_frac, self.live.segment_frac, self.list_drift()
        )

    def maybe_compact(self) -> Optional[np.ndarray]:
        """Compact iff churn crossed a :class:`CompactionPolicy` threshold;
        returns the handle -> new-position id_map, or None if not due."""
        live = getattr(self, "live", None)
        if live is not None and live.dirty and self.needs_compaction():
            return self.compact()
        return None

    def compact(self) -> np.ndarray:
        """Fold segment + tombstones into a rebuilt engine, in place.

        Live rows land in handle order (monotone map), the full build
        pipeline reruns over the folded arrays, and the trained planner /
        estimator heads survive the rebuild (only corpus-derived state is
        re-derived).  Generation counters bump so every cache invalidates.
        Returns ``id_map``: old handle -> new position (-1 for dead)."""
        t0 = time.perf_counter()
        tr = getattr(self, "tracer", NULL_TRACER)
        with tr.span("compact"):
            vectors, cat, num, id_map = self.live.compacted()
            planner, head_version = self.planner, self.planner_version
            est_model, est_gen = self.estimator.model, self.estimator.generation
            gen, n_comp = self.corpus_generation, self.n_compactions
            full = getattr(self, "pre_exec", None) is not None
            self.vectors, self.cat, self.num = vectors, cat, num
            if full:
                self.build()
            else:
                self.build_stats()  # planning-only engines stay planning-only
            self.planner = planner
            self.planner_version = head_version + 1
            self.estimator.model = est_model
            self.estimator.generation = est_gen + 1
            self.corpus_generation = gen + 1
            self.n_compactions = n_comp + 1
            tr.annotate(n_rows=int(vectors.shape[0]),
                        n_compactions=self.n_compactions,
                        corpus_generation=self.corpus_generation)
        self.build_time_["compaction"] = time.perf_counter() - t0
        return id_map

    def mutation_state(self) -> dict:
        """Array-only pytree of the mutable corpus state — what
        ``repro.ckpt.Checkpointer`` snapshots between compactions."""
        return self.live.state_tree()

    def load_mutation_state(self, tree) -> "FilteredANNEngine":
        """Restore a :meth:`mutation_state` snapshot onto a freshly built
        engine over the SAME base corpus.  Replays through the public
        upsert/delete APIs, so the attribute index, statistics deltas,
        caches, and generations all end consistent with having taken the
        writes live."""
        base_n = int(np.asarray(tree["base_n"]))
        if base_n != self.live.base_n or self.live.dirty:
            raise ValueError(
                "load_mutation_state needs a clean engine built over the "
                "same base corpus"
            )
        sv = np.asarray(tree["seg_vectors"])
        if sv.shape[0]:
            self.upsert(sv, np.asarray(tree["seg_cat"]),
                        np.asarray(tree["seg_num"]))
        from ..filter.bitmap import expand_words

        tomb = np.asarray(tree["tomb"], np.uint32)
        dead = np.nonzero(expand_words(tomb, self.live.n_total))[0]
        if dead.size:
            self.delete(dead)
        return self

    # ------------------------------------------------------------------
    def plan(self, pred: AnyPredicate, k: int = 10) -> Tuple[float, int, float]:
        """Estimate selectivity + pick a strategy, without executing.

        Returns ``(est_selectivity, decision, plan_overhead_s)``; decisions
        are 3-way (pre / post / indexed-pre — index-covered predicates get
        the exact popcount selectivity AND the bitmap-masked executor).
        The plan depends only on predicate and dataset statistics — not on
        which corpus rows are local — so a sharded deployment plans ONCE and
        broadcasts the decision to every shard (serve.ShardedANNEngine).
        Repeat predicates hit the plan cache and skip both the estimator
        and the MLP dispatch (same values by purity, just cheaper).
        """
        est, decision, _route, overhead = self.plan_ex(pred, k)
        return est, decision, overhead

    def plan_ex(self, pred: AnyPredicate, k: int = 10) -> Tuple[float, int, int, float]:
        """:meth:`plan` plus the routing class: returns
        ``(est_selectivity, decision, route, plan_overhead_s)`` where
        ``route`` is the (backend, knob-tier) class index for post-filter
        rows when the routing head is active, else ``NO_ROUTE``."""
        t0 = time.perf_counter()
        tr = getattr(self, "tracer", NULL_TRACER)
        with tr.span("plan", k=int(k)):
            self.plan_cache.validate_epoch(self._plan_epoch())
            key = (self._plan_key(pred), int(k))
            hit = self.plan_cache.get(key)
            if hit is not None:
                tr.annotate(plan_cache="hit",
                            decision=STRATEGY_NAMES[int(hit[1])],
                            route=int(hit[2]))
                return hit[0], hit[1], hit[2], time.perf_counter() - t0
            est, decision, route = self._plan_cold(pred, k)
            self.plan_cache.put(key, (est, decision, route))
            tr.annotate(plan_cache="miss",
                        decision=STRATEGY_NAMES[int(decision)],
                        route=int(route))
        return est, decision, route, time.perf_counter() - t0

    def _class_names(self) -> Optional[Tuple[str, ...]]:
        """This engine's (backend, knob-tier) class enumeration.  Derived
        from the built BackendSet when present, else from the configured
        backend roster (knob grids are static per backend class, so a
        planning-only ``build_stats`` engine — the sharded deployment's
        planner — enumerates the same classes its shards build)."""
        bs = getattr(self, "backend_set", None)
        if bs is not None:
            return bs.class_names()
        if self.config.backends:
            from ..index.registry import _REGISTRY
            return tuple(
                f"{nm}:{tier.name}"
                for nm in self.config.backends
                for tier in _REGISTRY[nm](seed=0).knob_grid()
            )
        return None

    def _routing_active(self) -> bool:
        """Routing applies only when the planner's routing head was fitted
        over EXACTLY this engine's (backend, knob-tier) class enumeration —
        a head trained under a different backend roster (e.g. restored from
        a checkpoint of another deployment) is ignored, not misapplied."""
        expected = self._class_names()
        if expected is None:
            return False
        rc = self.planner.route_classes
        return rc is not None and rc == expected

    def _plan_epoch(self) -> Tuple[int, int, int, int]:
        """What a cached plan is valid under: the installed head
        (``planner_version``, bumped by fit/swap_planner), that head's own
        fit generation, the estimator's fit generation — the latter two
        catch direct ``eng.planner.fit()`` / ``eng.estimator.fit()`` calls
        that retrain in place without going through the engine's hooks —
        and the corpus generation, which every live upsert/delete/compaction
        bumps (mutations change exact selectivities, hence plans)."""
        return (self.planner_version, self.planner.generation,
                self.estimator.generation,
                getattr(self, "corpus_generation", 0))

    def _plan_cold(self, pred: AnyPredicate, k: int) -> Tuple[float, int, int]:
        tr = getattr(self, "tracer", NULL_TRACER)
        with tr.span("predicate_compile"):
            pc = getattr(self, "pred_cache", None)
            m0 = pc.misses if pc is not None else 0
            est, exact = self.estimator.estimate_ex(pred)
            if tr.enabled:
                tr.annotate(estimator="exact" if exact else "gbm")
                if pc is not None:
                    miss = pc.misses - m0
                    n_words = (self.vectors.shape[0] + 31) // 32
                    tr.annotate(pred_cache="miss" if miss else "hit",
                                bitmap_words=miss * n_words)
        fv = self.feat.vector(pred, est, k, exact)
        if self.planner.params:
            decision = int(self.planner.decide(fv)[0])
        else:
            # untrained fallback mirrors the planner's cost heuristic: the
            # selectivity threshold picks pre vs post, coverage upgrades
            # pre to the indexed variant
            decision = PRE_FILTER if est < 0.05 else POST_FILTER
            if decision == PRE_FILTER and exact:
                decision = INDEXED_PRE
        route = NO_ROUTE
        if decision == POST_FILTER and self._routing_active():
            r = self.planner.route(fv)
            if r is not None:
                route = int(r[0])
        return est, decision, route

    def plan_batch(
        self, preds: Sequence[AnyPredicate], k: int = 10
    ) -> Tuple[np.ndarray, np.ndarray, float]:
        """Batched :meth:`plan`: one selectivity pass, one (B, F) feature
        matrix, ONE planner jit dispatch instead of B.

        Returns ``(est_selectivities (B,), decisions (B,), plan_overhead_s)``
        where the overhead covers the whole batch.  Rows whose (predicate,
        k) was planned before resolve from the plan cache; only the misses
        pay the estimator pass and the MLP dispatch.
        """
        ests, decisions, _routes, overhead = self.plan_batch_ex(preds, k)
        return ests, decisions, overhead

    def plan_batch_ex(
        self, preds: Sequence[AnyPredicate], k: int = 10
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, float]:
        """Batched :meth:`plan_ex`: additionally returns per-row routing
        classes (``NO_ROUTE`` for non-post rows or when routing is off)."""
        t0 = time.perf_counter()
        tr = getattr(self, "tracer", NULL_TRACER)
        b = len(preds)
        with tr.span("plan", n_preds=b, k=int(k)):
            self.plan_cache.validate_epoch(self._plan_epoch())
            ests = np.zeros(b, np.float64)
            decisions = np.zeros(b, np.int32)
            routes = np.full(b, NO_ROUTE, np.int32)
            keys = [(self._plan_key(p), int(k)) for p in preds]
            miss = []
            for i, key in enumerate(keys):
                hit = self.plan_cache.get(key)
                if hit is None:
                    miss.append(i)
                else:
                    ests[i], decisions[i], routes[i] = hit
            if miss:
                sub = [preds[i] for i in miss]
                with tr.span("predicate_compile", n_preds=len(miss)):
                    pc = getattr(self, "pred_cache", None)
                    m0 = pc.misses if pc is not None else 0
                    m_ests, m_exact = self.estimator.estimate_batch_ex(sub)
                    if tr.enabled:
                        tr.annotate(
                            estimator_exact=int(np.asarray(m_exact).sum()),
                            estimator_gbm=len(miss) - int(np.asarray(m_exact).sum()),
                        )
                        if pc is not None:
                            md = pc.misses - m0
                            n_words = (self.vectors.shape[0] + 31) // 32
                            tr.annotate(pred_cache_misses=md,
                                        bitmap_words=md * n_words)
                fm = self.feat.matrix(sub, m_ests, k, m_exact)
                if self.planner.params:
                    m_dec = self.planner.decide(fm).astype(np.int32)
                else:
                    m_dec = np.where(m_ests < 0.05, PRE_FILTER, POST_FILTER).astype(np.int32)
                    m_dec = np.where(
                        (m_dec == PRE_FILTER) & m_exact, INDEXED_PRE, m_dec
                    ).astype(np.int32)
                m_routes = np.full(len(miss), NO_ROUTE, np.int32)
                if self._routing_active():
                    r = self.planner.route(fm)
                    if r is not None:
                        m_routes = np.where(m_dec == POST_FILTER, r, NO_ROUTE).astype(np.int32)
                for j, i in enumerate(miss):
                    ests[i], decisions[i], routes[i] = (
                        float(m_ests[j]), int(m_dec[j]), int(m_routes[j])
                    )
                    self.plan_cache.put(
                        keys[i], (float(m_ests[j]), int(m_dec[j]), int(m_routes[j]))
                    )
            tr.annotate(plan_cache_hits=b - len(miss),
                        plan_cache_misses=len(miss))
        return ests, decisions, routes, time.perf_counter() - t0

    def shard_corpus(self, n_shards: int, n_lists: Optional[int] = None) -> List[CorpusShard]:
        """Partition the corpus into ``n_shards`` contiguous shards, each with
        its own pre-filter executor and post-filter IVF index.

        This is the hook the distribution layer builds on: shards map 1:1
        onto data-axis hosts, every shard answers the same planned query
        over its rows, and the per-shard top-k results merge exactly
        (``repro.dist.collectives.merge_topk``).  Per-shard IVF lists
        default to sqrt(n_local) as in the global build, clamped to the
        shard's row count; empty shards (more shards than rows) are
        dropped rather than built.
        """
        assert n_shards >= 1
        from ..filter import AttributeIndex, PredicateCache

        parts = np.array_split(np.arange(self.vectors.shape[0]), n_shards)
        shards = []
        for s, ids in enumerate(parts):
            if ids.size == 0:
                continue
            v = np.ascontiguousarray(self.vectors[ids])
            c, m = self.cat[ids], self.num[ids]
            lists = min(n_lists or max(1, int(np.sqrt(ids.size))), ids.size)
            ivf = IVFIndex(v, lists, seed=self.config.seed + s).build()
            # per-shard attribute index + cache: bitmaps address shard-local
            # row positions, so each shard compiles its own
            ipre = None
            if self.config.attr_index:
                ipre = IndexedPreFilterExec(
                    v, c, m,
                    AttributeIndex.build(c, m, self.config.range_buckets),
                    PredicateCache(self.config.pred_cache_size),
                )
            # per-shard backend instances: backends index shard-local row
            # positions, so (like the attribute index) each shard builds its
            # own from its slice of the corpus
            bset = None
            if self.config.backends:
                bset = BackendSet.build(v, self.config.backends,
                                        seed=self.config.seed + s)
            shards.append(CorpusShard(
                shard_id=s,
                ids=ids,
                pre_exec=PreFilterExec(v, c, m),
                post_exec=PostFilterExec(
                    ivf, c, m,
                    alpha0=self.config.alpha0, nprobe0=self.config.nprobe0,
                ),
                ipre_exec=ipre,
                backend_set=bset,
            ))
        return shards

    # ------------------------------------------------------------------
    def query(self, q: np.ndarray, pred: AnyPredicate, k: int = 10) -> PlannedResult:
        """Plan + execute one filtered ANN query."""
        q = np.atleast_2d(q)
        est, decision, route, plan_overhead = self.plan_ex(pred, k)
        tr = getattr(self, "tracer", NULL_TRACER)
        live = getattr(self, "live", None)
        if live is not None and live.dirty:
            # mutated corpus: the tombstone/segment-composing executor
            t0 = time.perf_counter()
            decisions = np.array([decision], np.int32)
            routes = np.array([route], np.int32)
            with tr.span("execute", n_queries=1, k=int(k), live=True):
                kc0, kw0 = _kernel_snapshot() if tr.enabled else ({}, {})
                d, ids, rounds = _live_execute_grouped(
                    self.pre_exec, self.ipre_exec, self.post_exec,
                    q, [pred], k, decisions, np.array([est]), live,
                    routes=routes, backend_set=self.backend_set, tracer=tr,
                )
                if tr.enabled:
                    _annotate_kernel_delta(tr, kc0, kw0)
            share = time.perf_counter() - t0 + plan_overhead
            return package_results(
                d, ids, rounds, np.array([est]), decisions, share,
                plan_overhead, route_names=self._route_names(decisions, routes),
            )[0]
        with tr.span("execute", n_queries=1, k=int(k), live=False,
                     decision=STRATEGY_NAMES[decision]):
            kc0, kw0 = _kernel_snapshot() if tr.enabled else ({}, {})
            if decision == INDEXED_PRE:
                res = self.ipre_exec.search(q, pred, k)
            elif decision == PRE_FILTER:
                res = self.pre_exec.search(q, pred, k)
            elif route >= 0 and self.backend_set is not None:
                # routed: mask once (bitmap-indexed when covered), then the
                # chosen backend's masked search at the chosen knob tier
                t0 = time.perf_counter()
                mask = self.ipre_exec.candidate_mask(pred)
                d, ids = self.backend_set.search_class(route, q, mask, k)
                res = SearchResult(d, ids, time.perf_counter() - t0, "post")
            else:
                # the estimate also *parameterises* the chosen executor
                res = self.post_exec.search(q, pred, k, est_selectivity=est)
            if tr.enabled:
                _annotate_kernel_delta(tr, kc0, kw0)
        if not res.backend:
            if decision == POST_FILTER and route >= 0 and self.backend_set is not None:
                res.backend, res.knob = self.backend_set.classes()[route]
            else:
                res.backend, res.knob = _default_route_name(decision)
        res.elapsed += plan_overhead   # end-to-end includes planning (paper §4.1)
        return PlannedResult(res, est, decision, plan_overhead)

    def _route_names(
        self, decisions: np.ndarray, routes: np.ndarray
    ) -> Optional[List[Optional[Tuple[str, str]]]]:
        """Per-row (backend, knob) labels for routed rows, None elsewhere."""
        if getattr(self, "backend_set", None) is None:
            return None
        classes = self.backend_set.classes()
        return [
            classes[int(routes[j])]
            if decisions[j] == POST_FILTER and routes[j] >= 0 else None
            for j in range(len(routes))
        ]

    def batch_query(
        self, queries: np.ndarray, preds: Sequence[AnyPredicate], k: int = 10
    ) -> List[PlannedResult]:
        """Batched plan -> group-by-decision -> execute.

        Plans the whole batch in one pass (:meth:`plan_batch`), then runs the
        shared decision-grouped executor (``_execute_grouped``): the
        pre-filter group evaluates each distinct predicate's mask ONCE and
        runs one fused masked top-k over all queries sharing it; the
        post-filter group runs one row-faithful batched IVF search with
        vectorised candidate filtering.  Results are identical to B
        independent :meth:`query` calls (same executors, same per-row
        parameters), only with the per-query Python/jit dispatch overhead
        amortised; per-result ``elapsed`` is the batch wall time split
        evenly across rows.
        """
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        b = len(preds)
        ests, decisions, routes, plan_overhead = self.plan_batch_ex(preds, k)
        plan_share = plan_overhead / max(b, 1)
        t0 = time.perf_counter()
        live = getattr(self, "live", None)
        tr = getattr(self, "tracer", NULL_TRACER)
        with tr.span("execute", n_queries=b, k=int(k),
                     live=bool(live is not None and live.dirty)):
            kc0, kw0 = _kernel_snapshot() if tr.enabled else ({}, {})
            if live is not None and live.dirty:
                d, ids, rounds = _live_execute_grouped(
                    self.pre_exec, self.ipre_exec, self.post_exec,
                    queries, preds, k, decisions, ests, live,
                    routes=routes, backend_set=self.backend_set, tracer=tr,
                )
            else:
                d, ids, rounds = _execute_grouped(
                    self.pre_exec, self.ipre_exec, self.post_exec,
                    queries, preds, k, decisions, ests,
                    routes=routes, backend_set=self.backend_set, tracer=tr,
                )
            if tr.enabled:
                _annotate_kernel_delta(tr, kc0, kw0)
        share = (time.perf_counter() - t0) / max(b, 1) + plan_share
        return package_results(d, ids, rounds, ests, decisions, share, plan_share,
                               route_names=self._route_names(decisions, routes))

    # ------------------------------------------------------------------
    def ground_truth(self, q: np.ndarray, pred: AnyPredicate, k: int = 10) -> np.ndarray:
        q = np.atleast_2d(q)
        mask = pred.eval(self.cat, self.num)
        live = getattr(self, "live", None)
        if live is not None and live.dirty:
            # exact truth over the LIVE rows: tombstones compose out of the
            # base mask, the segment scans exactly, parts merge with the
            # same handle-order tie-break the serving path uses
            from ..dist.collectives import merge_topk

            alive = live.alive_mask()
            mask = mask & alive[: live.base_n]
            b = q.shape[0]
            if mask.any():
                bd, bi = l2_topk(q, self.vectors, k, mask)
                bd, bi = np.asarray(bd), np.asarray(bi)
            else:
                bd = np.full((b, k), np.inf, np.float32)
                bi = np.full((b, k), -1, np.int32)
            sm = (pred.eval(live.seg_cat(), live.seg_num())
                  & alive[live.base_n:]) if live.seg_n else np.zeros(0, bool)
            if sm.any():
                kk = min(k, live.seg_n)
                sd, si = l2_topk(q, live.seg_vectors(), kk, sm)
                sd, si = np.asarray(sd), np.asarray(si)
                si = np.where(si >= 0, si + live.base_n, -1).astype(np.int32)
                _, bi = merge_topk([bd, sd], [bi, si], k)
            return bi
        _, ti = l2_topk(q, self.vectors, k, mask)
        return np.asarray(ti)
