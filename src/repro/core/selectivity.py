"""Selectivity estimation (paper §3.2, plus the exact index fast path).

Routing:

* index-covered predicate        -> EXACT popcount selectivity from the
                                    compiled bitmap (repro.filter); no model,
                                    no histogram — the estimate IS the truth,
                                    and the planner features record it as
                                    ``sel_is_exact``.
* pure range predicate           -> histogram estimate only (no model)
* single label                   -> exact frequency-dictionary lookup
* two-label conjunction          -> exact 2-D co-occurrence lookup
* >=3 labels, or mixed label+range -> GBM over lightweight features, with
  range features short-circuited to zero for label-only predicates.
* DNF (``Or``)                   -> per-clause estimates for every
  conjunctive disjunct (each routed through the rules above), plus a
  whole-predicate value: the exact popcount when the index covers the
  DNF, else the independence union ``1 - prod(1 - s_t)``.
* negated leaves without an index -> positive-part estimate scaled by
  ``prod(1 - s_leaf)`` under independence.

The public surface is one pair of methods — :meth:`estimate` and
:meth:`estimate_batch` — returning :class:`SelEstimate` records carrying
the estimate, the exactness flag, and (for ``Or``) the per-clause
breakdown the per-disjunct planner consumes.  The historical
``estimate_ex`` / ``estimate_batch_ex`` tuple spellings survive as thin
deprecated aliases for one release.

Feature vector fed to the GBM (paper §3.2.1 + §3.2.3):
  0: independence-assumption selectivity           (product of marginals)
  1: mean pairwise joint selectivity of label pairs
  2: min  pairwise joint selectivity of label pairs (an upper bound on truth)
  3: mean PMI over label pairs
  4: number of labels
  5: histogram selectivity of the range predicates (product over attrs)
  6: total width of range spans (normalised per attribute domain)
  7: midpoint of range spans (normalised)
  8: sum of label-range pairwise joint selectivities
"""
from __future__ import annotations

import dataclasses
from itertools import combinations
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .gbm import GradientBoostingRegressor
from .predicates import LabelEq, Or, Predicate, label_ids
from .stats import DatasetStats

__all__ = ["SelEstimate", "SelectivityEstimator", "N_FEATURES"]

N_FEATURES = 9


@dataclasses.dataclass(frozen=True)
class SelEstimate:
    """One selectivity estimate.

    ``sel``        — estimated (or exact) fraction of corpus rows matching.
    ``is_exact``   — True only on the index-covered popcount path, where the
                     value is ground truth rather than an estimate.
    ``per_clause`` — for ``Or`` predicates, one :class:`SelEstimate` per term
                     (aligned with ``pred.terms``, duplicates included); None
                     for conjunctions.
    """

    sel: float
    is_exact: bool = False
    per_clause: Optional[Tuple["SelEstimate", ...]] = None

    def __float__(self) -> float:
        return self.sel


class SelectivityEstimator:
    """Estimates predicate selectivity from precomputed dataset statistics,
    with an exact bitmap-index fast path when an ``AttributeIndex`` (and
    optionally a shared ``PredicateCache``) is attached."""

    def __init__(self, stats: DatasetStats, index=None, cache=None):
        self.stats = stats
        self.index = index          # Optional[repro.filter.AttributeIndex]
        self.cache = cache          # Optional[repro.filter.PredicateCache]
        self.model: Optional[GradientBoostingRegressor] = None
        # bumped by fit(): estimates change when the GBM retrains, so
        # anything memoising estimates (the engine's PlanCache) keys its
        # validity on this generation
        self.generation = 0
        # Optional[repro.core.corpus.LiveCorpus], attached by the engine.
        # When the live corpus carries tombstones, the exact fast path
        # composes them (ANDNOT) into the popcount so "exact" stays exact
        # over the LIVE rows, not the build-time corpus.
        self.live = None

    # ------------------------------------------------------------------
    def features(self, pred: Predicate) -> np.ndarray:
        """Lightweight feature vector for the GBM (paper §3.2.1/§3.2.3)."""
        st = self.stats
        lbls = label_ids(pred, st.cat_offsets)
        f = np.zeros(N_FEATURES, dtype=np.float64)

        # label features
        f[0] = st.independence_sel(pred)
        pairs = list(combinations(lbls, 2))
        if pairs:
            joints = [st.pair_joint_sel(a, b) for a, b in pairs]
            pmis = [st.pmi(a, b) for a, b in pairs]
            f[1] = float(np.mean(joints))
            f[2] = float(np.min(joints))
            f[3] = float(np.mean(pmis))
        elif lbls:
            s = st.single_label_sel(lbls[0])
            f[1] = f[2] = s
        f[4] = float(len(lbls))

        # range features (short-circuited to zero when no ranges, paper §3.2.1)
        if pred.ranges:
            rsel = 1.0
            width = mid = 0.0
            for r in pred.ranges:
                rsel *= st.range_sel(r)
                h = st.hists[r.attr]
                dom = max(h.hi - h.lo, 1e-12)
                width += r.total_width / dom
                mid += (r.midpoint - h.lo) / dom
            f[5] = rsel
            f[6] = width / len(pred.ranges)
            f[7] = mid / len(pred.ranges)
            f[8] = float(
                sum(st.label_range_joint(l, r) for l in lbls for r in pred.ranges)
            )
        return f

    # ------------------------------------------------------------------
    def fit(self, preds: Sequence[Predicate], true_sel: Sequence[float]) -> "SelectivityEstimator":
        """Train the GBM refinement on (predicate, ground-truth selectivity)
        pairs — in the paper these ground truths come from the same training
        queries used for the planner, measured on the sampled subset.

        The GBM only ever *serves* conjunctive predicates — ``Or`` shapes
        decompose per clause in :meth:`estimate`, and the engine's ``fit``
        decomposes DNF training traffic into (disjunct, clause-truth) pairs
        before calling here — so any ``Or`` entry still in the pool is
        skipped rather than crashing feature extraction."""
        pairs = [
            (p, s) for p, s in zip(preds, true_sel) if isinstance(p, Predicate)
        ]
        if not pairs:
            return self
        x = np.stack([self.features(p) for p, _ in pairs])
        y = np.asarray([s for _, s in pairs], dtype=np.float64)
        # Predict in logit space for stability near 0.
        eps = 1e-6
        z = np.log((y + eps) / (1 - y + eps))
        self.model = GradientBoostingRegressor().fit(x, z)
        self.generation += 1
        return self

    # ------------------------------------------------------------------
    def _exact_sel(self, pred) -> float:
        """Exact selectivity from the compiled bitmap's popcount; shares the
        engine-wide predicate cache so plan-then-execute compiles once.

        Under a live corpus with deletes, the stored bitmap still has
        tombstoned rows' bits set (deletes never rewrite the index);
        exactness is preserved by composing the tombstone words out here:
        ``popcount(words ANDNOT tomb) / live_count``."""
        compiled = (self.cache.get_or_compile(pred, self.index)
                    if self.cache is not None else self.index.compile(pred))
        live = self.live
        if live is not None and live.n_deleted:
            from ..filter.bitmap import popcount_words, word_andnot

            tomb = live.tomb[: compiled.words.size]
            alive = popcount_words(
                word_andnot(compiled.words, tomb, compiled.n))
            denom = live.live_count if compiled.n == live.n_total else max(
                compiled.n - live.n_deleted, 1)
            return alive / denom if denom else 0.0
        return compiled.selectivity

    def _leaf_sel(self, term) -> float:
        """Marginal selectivity of one leaf (for independence corrections)."""
        st = self.stats
        if isinstance(term, LabelEq):
            # out-of-dictionary codes match nothing; the card bound also
            # stops a too-large code aliasing into the NEXT attribute's
            # global-id span
            if not (0 <= term.attr < len(st.cat_cards)):
                return 0.0
            if not (0 <= term.code < st.cat_cards[term.attr]):
                return 0.0
            return st.single_label_sel(st.cat_offsets[term.attr] + term.code)
        return st.range_sel(term)

    def _route(self, pred):
        """Shared routing for conjunctions: returns an ``("exact", s)``
        index-backed truth, a direct ``("value", s)`` estimate, or
        ``("gbm", features)`` when the predicate needs the model (so a
        batch can pool its GBM rows into one predict).  ``Or`` predicates
        never reach here — :meth:`estimate` decomposes them per clause."""
        st = self.stats

        # exact fast path: an index that covers every leaf answers with a
        # popcount — bypassing histograms and the GBM entirely
        if self.index is not None and self.index.covers(pred):
            return "exact", self._exact_sel(pred)

        if pred.nots:
            # negated leaves scale the positive part under independence
            pos = Predicate(labels=pred.labels, ranges=pred.ranges)
            s = self.estimate(pos).sel
            for nt in pred.nots:
                s *= 1.0 - self._leaf_sel(nt.term)
            return "value", float(np.clip(s, 0.0, 1.0))

        lbls = label_ids(pred, st.cat_offsets)

        if pred.kind == "range":
            # Pure range: histograms are enough, no model (paper §3.2.2).
            s = 1.0
            for r in pred.ranges:
                s *= st.range_sel(r)
            return "value", float(np.clip(s, 0.0, 1.0))

        if pred.kind == "label":
            if len(lbls) == 1:
                return "value", st.single_label_sel(lbls[0])        # exact lookup
            if len(lbls) == 2:
                return "value", st.pair_joint_sel(lbls[0], lbls[1]) # exact matrix

        # >=3 labels or mixed: GBM refinement (falls back to independence
        # estimate if the model was never fit).
        if self.model is None:
            return "value", float(np.clip(st.independence_sel(pred), 0.0, 1.0))
        return "gbm", self.features(pred)

    def _sigmoid(self, z) -> np.ndarray:
        return np.clip(1.0 / (1.0 + np.exp(-z)), 0.0, 1.0)

    def estimate(self, pred) -> SelEstimate:
        """Estimate one predicate.

        ``Or`` predicates decompose: every conjunctive disjunct is estimated
        independently (``per_clause``, aligned with ``pred.terms``) and the
        whole-predicate value is the exact union popcount when the index
        covers the DNF, else the independence union ``1 - prod(1 - s_t)``.
        """
        if isinstance(pred, Or):
            per = tuple(self.estimate(t) for t in pred.terms)
            if self.index is not None and self.index.covers(pred):
                return SelEstimate(self._exact_sel(pred), True, per)
            s = 1.0
            for c in per:
                s *= 1.0 - c.sel
            return SelEstimate(float(np.clip(1.0 - s, 0.0, 1.0)), False, per)
        kind, payload = self._route(pred)
        if kind == "exact":
            return SelEstimate(float(payload), True)
        if kind == "value":
            return SelEstimate(float(payload), False)
        z = float(self.model.predict(payload[None, :])[0])
        return SelEstimate(float(self._sigmoid(z)), False)

    def estimate_batch(self, preds: Sequence) -> List[SelEstimate]:
        """Vectorised :meth:`estimate` over a batch of predicates.

        Conjunction GBM routes share ONE ``model.predict`` over a stacked
        (B_gbm, F) feature matrix; ``Or`` rows decompose recursively.
        Per-row tree traversal is row-independent, so results are identical
        to B independent :meth:`estimate` calls.
        """
        out: List[Optional[SelEstimate]] = [None] * len(preds)
        gbm_rows, gbm_idx = [], []
        for i, pred in enumerate(preds):
            if isinstance(pred, Or):
                out[i] = self.estimate(pred)
                continue
            kind, payload = self._route(pred)
            if kind == "exact":
                out[i] = SelEstimate(float(payload), True)
            elif kind == "value":
                out[i] = SelEstimate(float(payload), False)
            else:
                gbm_rows.append(payload)
                gbm_idx.append(i)
        if gbm_rows:
            z = self.model.predict(np.stack(gbm_rows))
            for i, s in zip(gbm_idx, self._sigmoid(z)):
                out[i] = SelEstimate(float(s), False)
        return out

    # -- deprecated tuple spellings (one release; prefer estimate/_batch) --
    def estimate_ex(self, pred) -> Tuple[float, bool]:
        """Deprecated: use :meth:`estimate` (returns :class:`SelEstimate`)."""
        se = self.estimate(pred)
        return se.sel, se.is_exact

    def estimate_batch_ex(self, preds: Sequence) -> Tuple[np.ndarray, np.ndarray]:
        """Deprecated: use :meth:`estimate_batch`."""
        ses = self.estimate_batch(preds)
        return (np.asarray([s.sel for s in ses], np.float64),
                np.asarray([s.is_exact for s in ses], bool))
