"""Gradient-boosted regression trees, pure numpy.

The paper trains "a Gradient Boosting Model ... with 300 estimators, maximum
depth 4, and a learning rate of 0.05" (§3.2.1) to refine multi-label / mixed
selectivity estimates.  sklearn is unavailable in this offline container, so
this is a from-scratch least-squares GBM: quantile-candidate splits, depth-
limited CART regression trees, shrinkage.

Feature matrices here are tiny (thousands of rows x ~10 columns), so exact
vectorised split scans are fast enough; no histogram binning subtleties
needed.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

__all__ = ["GradientBoostingRegressor", "RegressionTree"]


@dataclasses.dataclass
class _Node:
    feature: int = -1          # -1 => leaf
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    value: float = 0.0


class RegressionTree:
    """CART regression tree with squared-error splits."""

    def __init__(self, max_depth: int = 4, min_samples_leaf: int = 4, n_thresholds: int = 32):
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.n_thresholds = n_thresholds
        self.nodes: List[_Node] = []

    # ------------------------------------------------------------------
    def fit(self, x: np.ndarray, y: np.ndarray) -> "RegressionTree":
        self.nodes = []
        self._grow(x, y, depth=0)
        return self

    def _grow(self, x: np.ndarray, y: np.ndarray, depth: int) -> int:
        idx = len(self.nodes)
        self.nodes.append(_Node(value=float(y.mean()) if y.size else 0.0))
        if depth >= self.max_depth or y.size < 2 * self.min_samples_leaf or np.ptp(y) == 0:
            return idx
        feat, thr = self._best_split(x, y)
        if feat < 0:
            return idx
        mask = x[:, feat] <= thr
        left = self._grow(x[mask], y[mask], depth + 1)
        right = self._grow(x[~mask], y[~mask], depth + 1)
        node = self.nodes[idx]
        node.feature, node.threshold, node.left, node.right = feat, thr, left, right
        return idx

    def _best_split(self, x: np.ndarray, y: np.ndarray):
        n, d = x.shape
        best_gain, best = 0.0, (-1, 0.0)
        total_sum, total_cnt = y.sum(), n
        parent_sse_term = total_sum * total_sum / total_cnt
        for f in range(d):
            col = x[:, f]
            # Candidate thresholds at quantiles of the column.
            qs = np.unique(np.quantile(col, np.linspace(0.02, 0.98, self.n_thresholds)))
            if qs.size == 0:
                continue
            # For each candidate, split stats via vectorised comparison.
            le = col[None, :] <= qs[:, None]               # (T, n)
            cnt_l = le.sum(1).astype(np.float64)           # (T,)
            sum_l = (le * y[None, :]).sum(1)
            cnt_r = total_cnt - cnt_l
            sum_r = total_sum - sum_l
            ok = (cnt_l >= self.min_samples_leaf) & (cnt_r >= self.min_samples_leaf)
            if not ok.any():
                continue
            with np.errstate(divide="ignore", invalid="ignore"):
                gain = sum_l * sum_l / cnt_l + sum_r * sum_r / cnt_r - parent_sse_term
            gain = np.where(ok, gain, -np.inf)
            t = int(np.argmax(gain))
            if gain[t] > best_gain:
                best_gain, best = float(gain[t]), (f, float(qs[t]))
        return best

    # ------------------------------------------------------------------
    def predict(self, x: np.ndarray) -> np.ndarray:
        if not self.nodes:
            return np.zeros(x.shape[0])
        out = np.empty(x.shape[0], dtype=np.float64)
        # Iterative traversal per point; trees are tiny (depth<=4 => <=31 nodes)
        # and batches small, so a simple frontier walk is fine.
        stack = [(0, np.arange(x.shape[0]))]
        while stack:
            node_idx, rows = stack.pop()
            node = self.nodes[node_idx]
            if node.feature < 0:
                out[rows] = node.value
                continue
            mask = x[rows, node.feature] <= node.threshold
            stack.append((node.left, rows[mask]))
            stack.append((node.right, rows[~mask]))
        return out


class GradientBoostingRegressor:
    """Least-squares GBM with shrinkage (paper config: 300/4/0.05)."""

    def __init__(
        self,
        n_estimators: int = 300,
        max_depth: int = 4,
        learning_rate: float = 0.05,
        min_samples_leaf: int = 4,
        early_stopping_rounds: Optional[int] = 25,
        validation_fraction: float = 0.1,
        seed: int = 0,
    ):
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.learning_rate = learning_rate
        self.min_samples_leaf = min_samples_leaf
        self.early_stopping_rounds = early_stopping_rounds
        self.validation_fraction = validation_fraction
        self.seed = seed
        self.base_: float = 0.0
        self.trees_: List[RegressionTree] = []

    def fit(self, x: np.ndarray, y: np.ndarray) -> "GradientBoostingRegressor":
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        rng = np.random.default_rng(self.seed)
        n = x.shape[0]
        self.trees_ = []
        self.base_ = float(y.mean()) if n else 0.0

        # hold-out for early stopping
        use_es = self.early_stopping_rounds is not None and n >= 50
        if use_es:
            perm = rng.permutation(n)
            n_val = max(8, int(self.validation_fraction * n))
            val_idx, tr_idx = perm[:n_val], perm[n_val:]
            xt, yt, xv, yv = x[tr_idx], y[tr_idx], x[val_idx], y[val_idx]
        else:
            xt, yt = x, y
            xv = yv = None

        f_tr = np.full(yt.shape, self.base_)
        f_val = np.full(yv.shape, self.base_) if use_es else None
        best_val, best_len, rounds_bad = np.inf, 0, 0

        for _ in range(self.n_estimators):
            resid = yt - f_tr
            tree = RegressionTree(self.max_depth, self.min_samples_leaf).fit(xt, resid)
            self.trees_.append(tree)
            f_tr += self.learning_rate * tree.predict(xt)
            if use_es:
                f_val += self.learning_rate * tree.predict(xv)
                val_mse = float(((yv - f_val) ** 2).mean())
                if val_mse < best_val - 1e-12:
                    best_val, best_len, rounds_bad = val_mse, len(self.trees_), 0
                else:
                    rounds_bad += 1
                    if rounds_bad >= self.early_stopping_rounds:
                        self.trees_ = self.trees_[:best_len]
                        break
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, dtype=np.float64)
        out = np.full(x.shape[0], self.base_, dtype=np.float64)
        for tree in self.trees_:
            out += self.learning_rate * tree.predict(x)
        return out
