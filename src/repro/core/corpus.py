"""Live-corpus mutation layer: append segment + packed tombstones.

Everything above this module (stats, attribute indexes, ANN backends,
executors) is built once over a frozen array of rows.  ``LiveCorpus`` is
what lets the engine take writes anyway, without rebuilding per mutation:

* **deletes** set a bit in a packed uint32 tombstone bitmap
  (``repro.filter.bitmap`` word layout, tail bits clear).  The bitmap is
  ANDNOT-composed into every candidate mask at search time, so built
  structures never observe a deleted row.
* **upserts** append rows to a side segment.  Built structures keep
  serving the base rows; the segment is exact-scanned (it stays small
  between compactions) and merged into every result by the same
  composite-key top-k merge the sharded path uses.  Upserting an existing
  id tombstones the old row and appends the new version — an id never
  mutates in place, which is what keeps compiled bitmaps and IVF layouts
  valid between compactions.
* **row handles** are stable: base rows keep their build-time positions
  ``[0, base_n)``; segment rows get ``base_n, base_n+1, ...`` in insertion
  order.  Compaction folds live rows back into one array *in handle
  order*, so the handle -> compacted-position map (``compacted()``) is
  monotone — composite ``(dist_bits, position)`` tie-breaks order results
  identically before and after compaction, the bit-equality invariant the
  mutation tests pin.

Every mutation bumps ``generation``; the engine folds it into its plan
epoch so ``PlanCache``/``PredicateCache`` entries computed against a
previous corpus version invalidate on next lookup.

``assign_new`` incrementally coarse-assigns fresh segment rows to an
existing set of IVF centroids (one small GEMM per upsert batch) — the
engine's list-balance drift trigger reads these assignments to decide
when background compaction should fold the segment into a rebuilt index.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["LiveCorpus", "CompactionPolicy"]


@dataclasses.dataclass
class CompactionPolicy:
    """When churn crosses any threshold, the engine folds segment +
    tombstones into a rebuilt index (``FilteredANNEngine.maybe_compact``)."""

    max_tombstone_frac: float = 0.20   # dead fraction of all rows
    max_segment_frac: float = 0.20     # segment rows / base rows
    max_list_drift: float = 1.75       # IVF max-list imbalance vs build time

    def due(self, tombstone_frac: float, segment_frac: float,
            list_drift: float = 1.0) -> bool:
        return (tombstone_frac >= self.max_tombstone_frac
                or segment_frac >= self.max_segment_frac
                or list_drift >= self.max_list_drift)


def _pad_words(words: np.ndarray, nw: int) -> np.ndarray:
    return np.pad(words, (0, nw - words.size)) if words.size < nw else words


class LiveCorpus:
    """Mutable view over a frozen base corpus: base + segment + tombstones."""

    def __init__(self, vectors: np.ndarray, cat: np.ndarray, num: np.ndarray):
        # NOTE: repro.filter.bitmap is imported lazily inside methods —
        # importing repro.filter at module scope would cycle through
        # repro.core's own package init (see the note in core/engine.py).
        self.base_vectors = np.ascontiguousarray(vectors, np.float32)
        self.base_cat = np.asarray(cat)
        self.base_num = np.asarray(num)
        self.base_n = int(self.base_vectors.shape[0])
        self.dim = int(self.base_vectors.shape[1])
        self._seg_v: List[np.ndarray] = []
        self._seg_c: List[np.ndarray] = []
        self._seg_m: List[np.ndarray] = []
        self.seg_n = 0
        from ..filter.bitmap import empty_words

        self.tomb = empty_words(self.base_n)    # packed, grows with the segment
        self.n_deleted = 0
        self.generation = 0
        self.n_upserted = 0                     # lifetime row-op counters
        # incremental coarse assignment of segment rows (filled by assign_new)
        self.seg_assign = np.empty(0, np.int32)
        self._cache: dict = {}                  # memoised concat views / masks

    # ------------------------------------------------------------------
    @property
    def n_total(self) -> int:
        return self.base_n + self.seg_n

    @property
    def live_count(self) -> int:
        return self.n_total - self.n_deleted

    @property
    def tombstone_frac(self) -> float:
        return self.n_deleted / self.n_total if self.n_total else 0.0

    @property
    def segment_frac(self) -> float:
        return self.seg_n / self.base_n if self.base_n else 0.0

    @property
    def dirty(self) -> bool:
        """True once any mutation happened — the engine's signal to route
        queries through the tombstone/segment-composing path."""
        return self.seg_n > 0 or self.n_deleted > 0

    # ------------------------------------------------------------------
    def _invalidate_views(self) -> None:
        self._cache.clear()

    def seg_vectors(self) -> np.ndarray:
        if "sv" not in self._cache:
            self._cache["sv"] = (
                np.concatenate(self._seg_v) if self._seg_v
                else np.empty((0, self.dim), np.float32)
            )
        return self._cache["sv"]

    def seg_cat(self) -> np.ndarray:
        if "sc" not in self._cache:
            self._cache["sc"] = (
                np.concatenate(self._seg_c) if self._seg_c
                else self.base_cat[:0]
            )
        return self._cache["sc"]

    def seg_num(self) -> np.ndarray:
        if "sm" not in self._cache:
            self._cache["sm"] = (
                np.concatenate(self._seg_m) if self._seg_m
                else self.base_num[:0]
            )
        return self._cache["sm"]

    def alive_words(self) -> np.ndarray:
        """Packed bitmap of live rows over ``n_total`` (NOT tombstoned)."""
        from ..filter.bitmap import full_words, word_andnot

        if "aw" not in self._cache:
            self._cache["aw"] = word_andnot(
                full_words(self.n_total), self.tomb, self.n_total
            )
        return self._cache["aw"]

    def alive_mask(self) -> np.ndarray:
        """(n_total,) bool mask of live rows, memoised until the next
        mutation — the mask every live search composes with."""
        from ..filter.bitmap import expand_words

        if "am" not in self._cache:
            self._cache["am"] = expand_words(self.alive_words(), self.n_total)
        return self._cache["am"]

    def is_deleted(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        return (self.tomb[ids >> 5] >> (ids & 31).astype(np.uint32)) & 1 == 1

    def row_attrs(self, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(cat rows, num rows) for any mix of base and segment handles —
        gathered per part, no full-corpus concatenation."""
        ids = np.asarray(ids, np.int64)
        in_base = ids < self.base_n
        cat = np.empty((ids.size,) + self.base_cat.shape[1:], self.base_cat.dtype)
        num = np.empty((ids.size,) + self.base_num.shape[1:], self.base_num.dtype)
        cat[in_base] = self.base_cat[ids[in_base]]
        num[in_base] = self.base_num[ids[in_base]]
        if (~in_base).any():
            cat[~in_base] = self.seg_cat()[ids[~in_base] - self.base_n]
            num[~in_base] = self.seg_num()[ids[~in_base] - self.base_n]
        return cat, num

    # ------------------------------------------------------------------
    def upsert(self, vectors: np.ndarray, cat: np.ndarray, num: np.ndarray,
               ids: Optional[np.ndarray] = None) -> np.ndarray:
        """Append rows; returns their new handles.  ``ids`` (optional, one
        per row) are existing handles being replaced — they are tombstoned
        first, so an upsert-of-existing is delete-old + insert-new under
        fresh handles (handles are never reused)."""
        v = np.ascontiguousarray(np.atleast_2d(np.asarray(vectors, np.float32)))
        c = np.atleast_2d(np.asarray(cat))
        m = np.atleast_2d(np.asarray(num))
        rows = v.shape[0]
        if not (c.shape[0] == rows and m.shape[0] == rows):
            raise ValueError("vectors/cat/num row counts disagree")
        if ids is not None:
            self.delete(ids, _bump=False)
        handles = np.arange(self.n_total, self.n_total + rows, dtype=np.int64)
        self._seg_v.append(v)
        self._seg_c.append(c)
        self._seg_m.append(m)
        self.seg_n += rows
        self.n_upserted += rows
        from ..filter.bitmap import n_words

        self.tomb = _pad_words(self.tomb, n_words(self.n_total))
        self.generation += 1
        self._invalidate_views()
        return handles

    def delete(self, ids: np.ndarray, _bump: bool = True) -> np.ndarray:
        """Tombstone handles; idempotent.  Returns the handles that were
        live before this call (the newly dead — what stats deltas need)."""
        ids = np.unique(np.asarray(ids, np.int64))
        if ids.size and (ids[0] < 0 or ids[-1] >= self.n_total):
            raise IndexError(f"delete ids out of range [0, {self.n_total})")
        fresh = ids[~self.is_deleted(ids)] if ids.size else ids
        if fresh.size:
            np.bitwise_or.at(
                self.tomb, fresh >> 5,
                np.uint32(1) << (fresh & 31).astype(np.uint32),
            )
            self.n_deleted += int(fresh.size)
            self._invalidate_views()
        if _bump:
            self.generation += 1
        return fresh

    # ------------------------------------------------------------------
    def assign_new(self, centroids: np.ndarray) -> np.ndarray:
        """Incremental IVF coarse assignment: segment rows not yet assigned
        get their nearest centroid (one small GEMM), previous assignments
        are kept.  Returns the full (seg_n,) assignment array."""
        done = self.seg_assign.size
        if done < self.seg_n:
            fresh = self.seg_vectors()[done:]
            c = np.asarray(centroids, np.float32)
            d2 = ((fresh**2).sum(1)[:, None] - 2.0 * fresh @ c.T
                  + (c**2).sum(1)[None, :])
            self.seg_assign = np.concatenate(
                [self.seg_assign, np.argmin(d2, axis=1).astype(np.int32)]
            )
        return self.seg_assign

    # ------------------------------------------------------------------
    def compacted(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Fold segment + tombstones: live rows in handle order.

        Returns ``(vectors, cat, num, id_map)`` where ``id_map[handle]`` is
        the row's position in the folded arrays, or -1 for tombstoned
        handles.  The map is monotone over live handles, so exact searches
        tie-break identically against a fresh build of the folded corpus.
        """
        alive = self.alive_mask()
        keep = np.nonzero(alive)[0]
        vectors = np.concatenate([self.base_vectors, self.seg_vectors()])[keep]
        cat = np.concatenate([self.base_cat, self.seg_cat()])[keep] \
            if self.seg_n else self.base_cat[keep]
        num = np.concatenate([self.base_num, self.seg_num()])[keep] \
            if self.seg_n else self.base_num[keep]
        id_map = np.full(self.n_total, -1, np.int64)
        id_map[keep] = np.arange(keep.size)
        return np.ascontiguousarray(vectors), cat, num, id_map

    # ------------------------------------------------------------------
    def state_tree(self) -> dict:
        """Array-only snapshot of the mutable state (checkpointable as a
        pytree through ``repro.ckpt.Checkpointer``)."""
        return {
            "base_n": np.asarray(self.base_n, np.int64),
            "generation": np.asarray(self.generation, np.int64),
            "tomb": self.tomb.copy(),
            "seg_vectors": self.seg_vectors().copy(),
            "seg_cat": self.seg_cat().copy(),
            "seg_num": self.seg_num().copy(),
        }

    def stats(self) -> dict:
        return {
            "n_total": self.n_total,
            "live_count": self.live_count,
            "seg_rows": self.seg_n,
            "tombstone_frac": round(self.tombstone_frac, 6),
            "segment_frac": round(self.segment_frac, 6),
            "generation": self.generation,
            "dirty": self.dirty,
        }
