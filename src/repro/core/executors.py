"""Execution strategies for filtered ANN queries (paper §4.1 Methods).

* :class:`PreFilterExec`        — filter first, brute-force exact KNN over
  the passing subset (the paper implements pre-filtering with brute force;
  §4.1).  The predicate mask comes from an O(N·leaves) columnar scan.
* :class:`IndexedPreFilterExec` — the same exact subset top-k, but the mask
  comes from the bitmap attribute index (``repro.filter``): compiled DNF
  bitmaps, LRU-cached across serving traffic, expanded to the bool mask the
  kernels consume.  Identical results to :class:`PreFilterExec` by
  construction (same mask, same execution core), minus the scan.
* :class:`PostFilterExec`       — search the global IVF index for α·k
  candidates, filter, and double α (and widen nprobe) until ≥ k valid
  results survive.

ACORN (and any other registered ANN backend) is reached through the backend
registry (``repro.index.registry``) rather than a bespoke executor: routed
rows compute the candidate mask once and call the backend's uniform
``search_masked`` surface.

All return ``SearchResult`` with global ids (-1 padded), squared-L2
distances, wall time, and strategy bookkeeping used to label planner
training data.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Tuple

import numpy as np

from ..index.ivf import IVFIndex
from ..kernels.ops import fused_masked_topk
from .predicates import AnyPredicate
from .util import next_pow2

__all__ = [
    "SearchResult",
    "PreFilterExec",
    "IndexedPreFilterExec",
    "PostFilterExec",
    "recall_at_k",
]


@dataclasses.dataclass
class SearchResult:
    dists: np.ndarray      # (B, k)
    ids: np.ndarray        # (B, k), -1 padded
    elapsed: float         # end-to-end seconds (filter + search + expansion)
    strategy: str
    n_expansions: int = 0  # post-filter α-doubling rounds
    backend: str = ""      # routed backend name ("" until packaging fills it)
    knob: str = ""         # routed knob-tier name


def recall_at_k(result_ids: np.ndarray, truth_ids: np.ndarray) -> float:
    """Mean fraction of ground-truth neighbours recovered (recall@k)."""
    b, k = truth_ids.shape
    hits = 0
    denom = 0
    for i in range(b):
        t = set(int(x) for x in truth_ids[i] if x >= 0)
        if not t:
            continue
        r = set(int(x) for x in result_ids[i] if x >= 0)
        hits += len(t & r)
        denom += len(t)
    return hits / denom if denom else 1.0


class PreFilterExec:
    """Filter -> brute-force KNN over the subset (100 % recall).

    The mask-to-top-k core (:meth:`search_masked`) is shared with
    :class:`IndexedPreFilterExec` — the two strategies differ ONLY in how
    the candidate mask is produced (columnar scan vs compiled bitmap), so
    their results are identical by construction.
    """

    strategy_name = "pre"
    # Above this passing fraction, gathering the subset costs more than it
    # saves: run the fused masked top-k over the FULL corpus instead (no
    # copy, and the warmed full-corpus shape) — the "bitmap-masked fused
    # top-k" large-set path.  Below it, gather + pow2-padded subset scan.
    FULL_SCAN_FRAC = 0.25

    def __init__(self, vectors: np.ndarray, cat: np.ndarray, num: np.ndarray):
        self.vectors = np.ascontiguousarray(vectors, np.float32)
        self.cat, self.num = cat, num

    def candidate_mask(self, pred: AnyPredicate) -> np.ndarray:
        """(N,) bool predicate mask — the columnar scan."""
        return pred.eval(self.cat, self.num)

    def search(self, queries: np.ndarray, pred: AnyPredicate, k: int) -> SearchResult:
        t0 = time.perf_counter()
        mask = self.candidate_mask(pred)
        return self.search_masked(queries, mask, k, t0=t0)

    def search_masked(
        self, queries: np.ndarray, mask: np.ndarray, k: int,
        t0: Optional[float] = None,
    ) -> SearchResult:
        """Exact subset top-k under a precomputed candidate mask."""
        if t0 is None:
            t0 = time.perf_counter()
        b = queries.shape[0]
        n = self.vectors.shape[0]
        n_pass = int(mask.sum())
        if n_pass == 0:
            return SearchResult(
                np.full((b, k), np.inf, np.float32),
                np.full((b, k), -1, np.int32),
                time.perf_counter() - t0,
                self.strategy_name,
            )
        bp = next_pow2(b, floor=8)
        qp = np.zeros((bp, self.vectors.shape[1]), np.float32)
        qp[:b] = np.asarray(queries, np.float32)
        kk = min(k, n_pass)
        if n_pass > self.FULL_SCAN_FRAC * n:
            # large passing set: masked fused top-k over the whole corpus —
            # ids come back global already
            d, gids = fused_masked_topk(qp, self.vectors, mask, kk)
            d, gids = np.asarray(d)[:b], np.asarray(gids)[:b]
            ids = np.full((b, k), -1, np.int32)
            dist = np.full((b, k), np.inf, np.float32)
            valid = gids >= 0
            ids[:, :kk] = np.where(valid, gids, -1)
            dist[:, :kk] = np.where(valid, d, np.inf)
            return SearchResult(dist, ids, time.perf_counter() - t0, self.strategy_name)
        # small passing set: gather the compacted subset, padded to the next
        # power of two so the jit'd top-k sees O(log N) distinct shapes, not
        # one per query (otherwise recompilation time pollutes the utility
        # labels the planner learns from).  The query batch pads the same way
        # (floor 8): the batched serving path stacks all queries sharing a
        # predicate into ONE fused call, and pow2 query shapes keep the
        # compile set O(log B) — with the floor making single-query and
        # small-group calls share one shape (identical per-row results by
        # construction).
        idx = np.nonzero(mask)[0]
        p = next_pow2(n_pass, floor=16)
        sub = np.zeros((p, self.vectors.shape[1]), np.float32)
        sub[:n_pass] = self.vectors[idx]
        valid_rows = np.zeros(p, bool)
        valid_rows[:n_pass] = True
        d, local = fused_masked_topk(qp, sub, valid_rows, kk)
        d, local = np.asarray(d)[:b], np.asarray(local)[:b]
        ids = np.full((b, k), -1, np.int32)
        dist = np.full((b, k), np.inf, np.float32)
        valid = local >= 0
        ids[:, :kk] = np.where(valid, idx[np.minimum(np.maximum(local, 0), n_pass - 1)], -1)
        dist[:, :kk] = np.where(valid, d, np.inf)
        return SearchResult(dist, ids, time.perf_counter() - t0, self.strategy_name)


class IndexedPreFilterExec(PreFilterExec):
    """Pre-filtering with the candidate mask answered by the bitmap
    attribute index instead of a columnar scan (``repro.filter``).

    The compiled-bitmap cache is shared with the engine's selectivity
    estimator, so a predicate that was planned (exact popcount selectivity)
    executes from the same compilation; repeated serving predicates skip
    compilation AND mask expansion (both cached).  Predicates whose leaves
    reference unindexed attributes fall back to the scan — same answer,
    scan price.
    """

    strategy_name = "ipre"

    def __init__(self, vectors: np.ndarray, cat: np.ndarray, num: np.ndarray,
                 index, cache):
        super().__init__(vectors, cat, num)
        self.index = index          # repro.filter.AttributeIndex
        self.cache = cache          # repro.filter.PredicateCache

    def candidate_mask(self, pred: AnyPredicate) -> np.ndarray:
        if self.index is not None and self.index.covers(pred):
            # two-tier cache: compiled words (capacity) + a smaller LRU of
            # expanded masks (mask_capacity), so repeat predicates skip both
            # compilation and expansion without pinning a mask per entry
            return self.cache.mask(pred, self.index)
        return pred.eval(self.cat, self.num)


class PostFilterExec:
    """Global-index ANN -> filter -> α-doubling expansion (paper §4.1(2))."""

    def __init__(
        self,
        index: IVFIndex,
        cat: np.ndarray,
        num: np.ndarray,
        alpha0: int = 4,
        nprobe0: int = 8,
        max_rounds: int = 8,
    ):
        self.index = index
        self.cat, self.num = cat, num
        self.alpha0, self.nprobe0, self.max_rounds = alpha0, nprobe0, max_rounds

    def initial_params(self, k: int, est_selectivity: Optional[float] = None) -> Tuple[int, int]:
        """Initial ``(candidate budget, nprobe)`` for one query.

        ``est_selectivity`` (from the planner's estimator) sizes BOTH knobs:
        to surface ~alpha0*k predicate-passing candidates the scan must cover
        ~alpha0*k/selectivity corpus points, so nprobe ~ alpha0*k*L/(sel*N)
        AND the candidate request itself must be ~alpha0*k/sel — a budget of
        only alpha0*k at low selectivity loses most candidates to the filter
        and pays extra doubling rounds (or recall at the round cap).  Both
        values round up to powers of two so a batch of queries collapses into
        a handful of shared (budget, nprobe) groups — the grouping the
        batched executor exploits.
        """
        n, n_lists = self.index.n, self.index.n_lists
        want = self.alpha0 * k
        nprobe = self.nprobe0
        if est_selectivity is not None and est_selectivity > 0:
            want_points = self.alpha0 * k / est_selectivity
            nprobe_sel = int(np.ceil(want_points * n_lists / n))
            nprobe = int(np.clip(nprobe_sel, self.nprobe0, n_lists))
            want = max(want, int(np.ceil(want_points)))
        return min(next_pow2(want), n), min(next_pow2(nprobe), n_lists)

    def search(
        self,
        queries: np.ndarray,
        pred: AnyPredicate,
        k: int,
        est_selectivity: Optional[float] = None,
        alive: Optional[np.ndarray] = None,
    ) -> SearchResult:
        """Single-predicate entry point; delegates to the row-faithful batched
        core so the per-query and batched serving paths share one
        implementation (and therefore return identical ids)."""
        t0 = time.perf_counter()
        q = np.asarray(queries, np.float32)
        b = q.shape[0]
        out_d, out_i, rounds = self.search_rows(
            q, [pred] * b, k, [est_selectivity] * b, alive=alive)
        n_exp = int(rounds.max()) if rounds.size else 0
        return SearchResult(out_d, out_i, time.perf_counter() - t0, "post", n_exp)

    def search_rows(
        self,
        q: np.ndarray,
        preds: Sequence[AnyPredicate],
        k: int,
        ests: Sequence[Optional[float]],
        alive: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Row-faithful batched post-filter search (per-row predicates).

        ``alive``, when given, is a bool mask over the INDEX's rows; a
        retrieved candidate whose bit is clear (tombstoned under a live
        corpus) is filtered exactly like a predicate miss, so it both drops
        from the results and still counts against the α budget — the same
        accounting a predicate-failing candidate gets.

        Every row runs exactly the (budget, nprobe) doubling schedule a
        dedicated ``search`` call would run — rows whose current parameters
        coincide share one IVF dispatch, and candidate filtering is a single
        vectorised predicate evaluation per distinct predicate instead of a
        Python loop over rows.  Because ``IVFIndex.search`` is row-independent,
        batched results are identical to B independent calls, only cheaper.
        Returns ``(dists (B, k), ids (B, k), expansion_rounds (B,))``.
        """
        b = q.shape[0]
        n, n_lists = self.index.n, self.index.n_lists
        params = [self.initial_params(k, e) for e in ests]
        want = np.array([w for w, _ in params], np.int64)
        nprobe = np.array([p for _, p in params], np.int64)
        rounds = np.zeros(b, np.int64)
        out_d = np.full((b, k), np.inf, np.float32)
        out_i = np.full((b, k), -1, np.int32)
        # a row pays at most max_rounds IVF dispatches: the initial search
        # plus up to max_rounds - 1 doubling rounds
        pending = np.arange(b) if self.max_rounds > 0 else np.empty(0, np.int64)
        # predicates evaluated lazily on retrieved candidates only
        while pending.size:
            groups: dict = {}
            for qi in pending:
                groups.setdefault((int(want[qi]), int(nprobe[qi])), []).append(int(qi))
            for (w, npb), rows_l in groups.items():
                rows = np.asarray(rows_l)
                d, ids = self.index.search(q[rows], w, nprobe=npb)
                # one predicate evaluation per distinct predicate in the group
                keep = np.zeros(ids.shape, bool)
                bypred: dict = {}
                for j, qi in enumerate(rows_l):
                    bypred.setdefault(preds[qi], []).append(j)
                for p, js in bypred.items():
                    flat = ids[js].reshape(-1)
                    pos = flat >= 0
                    kp = np.zeros(flat.size, bool)
                    if pos.any():
                        kp[pos] = p.eval(self.cat[flat[pos]], self.num[flat[pos]])
                        if alive is not None:
                            kp[pos] &= alive[flat[pos]]
                    keep[js] = kp.reshape(len(js), -1)
                # first k passing candidates per row, in distance order: a
                # stable argsort of ~keep floats passing slots to the front
                # without reordering among themselves
                kk = min(k, ids.shape[1])
                order = np.argsort(~keep, axis=1, kind="stable")[:, :kk]
                sel_i = np.take_along_axis(ids, order, axis=1)
                sel_d = np.take_along_axis(d, order, axis=1)
                sel_keep = np.take_along_axis(keep, order, axis=1)
                blk_i = np.full((rows.size, k), -1, np.int32)
                blk_d = np.full((rows.size, k), np.inf, np.float32)
                blk_i[:, :kk] = np.where(sel_keep, sel_i, -1)
                blk_d[:, :kk] = np.where(sel_keep, sel_d, np.inf)
                out_i[rows] = blk_i
                out_d[rows] = blk_d
            got = (out_i[pending] >= 0).sum(1)
            exhausted = (want[pending] >= n) & (nprobe[pending] >= n_lists)
            more = (got < k) & ~exhausted & (rounds[pending] + 1 < self.max_rounds)
            pending = pending[more]
            if pending.size:
                want[pending] = np.minimum(want[pending] * 2, n)   # paper: double α
                nprobe[pending] = np.minimum(nprobe[pending] * 2, n_lists)
                rounds[pending] += 1
        return out_d, out_i, rounds
