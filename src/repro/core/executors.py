"""Execution strategies for filtered ANN queries (paper §4.1 Methods).

* :class:`PreFilterExec`  — filter first, brute-force exact KNN over the
  passing subset (the paper implements pre-filtering with brute force; §4.1).
* :class:`PostFilterExec` — search the global IVF index for α·k candidates,
  filter, and double α (and widen nprobe) until ≥ k valid results survive.
* :class:`AcornExec`      — ACORN-1: filter *during* graph traversal.

All return ``SearchResult`` with global ids (-1 padded), squared-L2
distances, wall time, and strategy bookkeeping used to label planner
training data.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from ..index.acorn import AcornIndex
from ..index.flat import l2_topk
from ..index.ivf import IVFIndex
from .predicates import Predicate

__all__ = ["SearchResult", "PreFilterExec", "PostFilterExec", "AcornExec", "recall_at_k"]


@dataclasses.dataclass
class SearchResult:
    dists: np.ndarray      # (B, k)
    ids: np.ndarray        # (B, k), -1 padded
    elapsed: float         # end-to-end seconds (filter + search + expansion)
    strategy: str
    n_expansions: int = 0  # post-filter α-doubling rounds


def recall_at_k(result_ids: np.ndarray, truth_ids: np.ndarray) -> float:
    """Mean fraction of ground-truth neighbours recovered (recall@k)."""
    b, k = truth_ids.shape
    hits = 0
    denom = 0
    for i in range(b):
        t = set(int(x) for x in truth_ids[i] if x >= 0)
        if not t:
            continue
        r = set(int(x) for x in result_ids[i] if x >= 0)
        hits += len(t & r)
        denom += len(t)
    return hits / denom if denom else 1.0


class PreFilterExec:
    """Filter -> brute-force KNN over the subset (100 % recall)."""

    def __init__(self, vectors: np.ndarray, cat: np.ndarray, num: np.ndarray):
        self.vectors = np.ascontiguousarray(vectors, np.float32)
        self.cat, self.num = cat, num

    def search(self, queries: np.ndarray, pred: Predicate, k: int) -> SearchResult:
        t0 = time.perf_counter()
        mask = pred.eval(self.cat, self.num)
        idx = np.nonzero(mask)[0]
        b = queries.shape[0]
        if idx.size == 0:
            return SearchResult(
                np.full((b, k), np.inf, np.float32),
                np.full((b, k), -1, np.int32),
                time.perf_counter() - t0,
                "pre",
            )
        # pad the compacted subset to the next power of two so the jit'd
        # top-k sees O(log N) distinct shapes, not one per query (otherwise
        # recompilation time pollutes the utility labels the planner learns
        # from)
        n_pass = idx.size
        p = 1 << max(0, int(np.ceil(np.log2(max(n_pass, 16)))))
        sub = np.zeros((p, self.vectors.shape[1]), np.float32)
        sub[:n_pass] = self.vectors[idx]
        valid_rows = np.zeros(p, bool)
        valid_rows[:n_pass] = True
        kk = min(k, n_pass)
        d, local = l2_topk(np.asarray(queries, np.float32), sub, kk, valid_rows)
        d, local = np.asarray(d), np.asarray(local)
        ids = np.full((b, k), -1, np.int32)
        dist = np.full((b, k), np.inf, np.float32)
        valid = local >= 0
        ids[:, :kk] = np.where(valid, idx[np.minimum(np.maximum(local, 0), n_pass - 1)], -1)
        dist[:, :kk] = np.where(valid, d, np.inf)
        return SearchResult(dist, ids, time.perf_counter() - t0, "pre")


class PostFilterExec:
    """Global-index ANN -> filter -> α-doubling expansion (paper §4.1(2))."""

    def __init__(
        self,
        index: IVFIndex,
        cat: np.ndarray,
        num: np.ndarray,
        alpha0: int = 4,
        nprobe0: int = 8,
        max_rounds: int = 8,
    ):
        self.index = index
        self.cat, self.num = cat, num
        self.alpha0, self.nprobe0, self.max_rounds = alpha0, nprobe0, max_rounds

    def search(
        self,
        queries: np.ndarray,
        pred: Predicate,
        k: int,
        est_selectivity: Optional[float] = None,
    ) -> SearchResult:
        """``est_selectivity`` (from the planner's estimator) sizes the
        initial probe width: to surface ~alpha*k predicate-passing candidates
        the scan must cover ~alpha*k/selectivity corpus points, i.e.
        nprobe ~ alpha*k*L/(sel*N).  Without it the executor starts at the
        static default and pays extra doubling rounds — or worse, stops at k
        *valid but not top-k* results (recall loss, the paper's §1 point)."""
        t0 = time.perf_counter()
        q = np.asarray(queries, np.float32)
        b = q.shape[0]
        alpha, nprobe = self.alpha0, self.nprobe0
        if est_selectivity is not None and est_selectivity > 0:
            want_points = self.alpha0 * k / est_selectivity
            nprobe_sel = int(np.ceil(want_points * self.index.n_lists / self.index.n))
            nprobe = int(np.clip(nprobe_sel, self.nprobe0, self.index.n_lists))
        rounds = 0
        out_d = np.full((b, k), np.inf, np.float32)
        out_i = np.full((b, k), -1, np.int32)
        pending = np.arange(b)
        # predicate evaluated lazily on retrieved candidates only
        while pending.size and rounds < self.max_rounds:
            want = min(alpha * k, self.index.n)
            d, ids = self.index.search(q[pending], want, nprobe=nprobe)
            for row, qi in enumerate(pending):
                valid = ids[row] >= 0
                cand = ids[row][valid]
                cd = d[row][valid]
                if cand.size:
                    keep = pred.eval(self.cat[cand], self.num[cand])
                    cand, cd = cand[keep], cd[keep]
                kk = min(k, cand.size)
                out_i[qi, :kk] = cand[:kk]
                out_d[qi, :kk] = cd[:kk]
                out_i[qi, kk:] = -1
                out_d[qi, kk:] = np.inf
            got = (out_i[pending] >= 0).sum(1)
            exhausted = alpha * k >= self.index.n and nprobe >= self.index.n_lists
            pending = pending[got < k] if not exhausted else np.empty(0, np.int64)
            if pending.size:
                alpha *= 2                      # paper: iteratively double α
                nprobe = min(nprobe * 2, self.index.n_lists)
                rounds += 1
        return SearchResult(out_d, out_i, time.perf_counter() - t0, "post", rounds)


class AcornExec:
    """ACORN-1 baseline: predicate-aware graph traversal."""

    def __init__(self, index: AcornIndex, cat: np.ndarray, num: np.ndarray, ef: int = 64):
        self.index = index
        self.cat, self.num = cat, num
        self.ef = ef

    def search(self, queries: np.ndarray, pred: Predicate, k: int) -> SearchResult:
        t0 = time.perf_counter()
        mask = pred.eval(self.cat, self.num)
        d, ids = self.index.search(np.asarray(queries, np.float32), k, ef=self.ef, mask=mask)
        return SearchResult(d, ids, time.perf_counter() - t0, "acorn")
