"""Dataset statistics backing the selectivity estimator (paper §3.2).

Precomputed at index-build time:

* per-label frequency dictionary          (exact, full dataset)
* 2-D label co-occurrence matrix          (exact, full dataset)
* per-numeric-attribute histograms        (1,024 equi-width bins, full dataset)
* label-range co-occurrence               (per-label conditional histograms,
                                           computed on the 1-5 % sample)
* PMI between label pairs                 (derived from the matrices above)

Labels live in a flattened *global id* space: categorical attribute ``a``
with cardinality ``C_a`` owns ids ``[offset_a, offset_a + C_a)``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .predicates import Predicate, RangePred, label_ids

__all__ = ["DatasetStats", "HIST_BINS"]

# Paper §3.2.2: "using 1,024 histogram bins accurately captures the
# distribution of range predicates".
HIST_BINS = 1024
# Conditional (label-range) histograms are built on the sample; coarser bins.
COND_HIST_BINS = 64


@dataclasses.dataclass
class Histogram:
    """Equi-width histogram with fractional boundary-bin interpolation."""

    lo: float
    hi: float
    counts: np.ndarray  # (bins,), float64
    total: float        # number of points histogrammed

    @property
    def bins(self) -> int:
        return int(self.counts.shape[0])

    @property
    def width(self) -> float:
        return (self.hi - self.lo) / self.bins

    def range_mass(self, lo: float, hi: float) -> float:
        """Estimated COUNT of points in [lo, hi): sums fully covered bins and
        takes the covered fraction of partially overlapped boundary bins
        (uniform-within-bin assumption, paper §3.2.2)."""
        lo = max(lo, self.lo)
        hi = min(hi, self.hi)
        if hi <= lo or self.total == 0 or self.width <= 0:
            return 0.0
        # Continuous bin coordinates of the query range.
        a = (lo - self.lo) / self.width
        b = (hi - self.lo) / self.width
        ia, ib = int(np.floor(a)), int(np.ceil(b))
        ia = max(ia, 0)
        ib = min(ib, self.bins)
        mass = 0.0
        for i in range(ia, ib):
            # Overlap of [a, b) with bin [i, i+1), as a fraction of the bin.
            frac = min(b, i + 1.0) - max(a, float(i))
            if frac > 0:
                mass += float(self.counts[i]) * min(frac, 1.0)
        return mass

    def selectivity(self, intervals: Sequence[Tuple[float, float]]) -> float:
        """Selectivity of a union of disjoint intervals over this attribute."""
        if self.total == 0:
            return 0.0
        return float(sum(self.range_mass(lo, hi) for lo, hi in intervals) / self.total)

    @staticmethod
    def build(x: np.ndarray, bins: int = HIST_BINS) -> "Histogram":
        x = np.asarray(x, dtype=np.float64)
        lo, hi = float(x.min()), float(x.max())
        if hi <= lo:
            hi = lo + 1.0
        counts, _ = np.histogram(x, bins=bins, range=(lo, hi))
        return Histogram(lo=lo, hi=hi, counts=counts.astype(np.float64), total=float(x.size))


@dataclasses.dataclass
class DatasetStats:
    """All precomputed statistics for one dataset."""

    n: int                       # corpus size
    dim: int                     # vector dimensionality
    cat_cards: Tuple[int, ...]   # cardinality per categorical attribute
    cat_offsets: Tuple[int, ...] # global-label-id offsets per attribute
    n_labels: int                # total labels across attributes

    label_freq: np.ndarray       # (n_labels,) exact frequency (fraction of N)
    cooc: np.ndarray             # (n_labels, n_labels) joint frequency (fraction)
    hists: List[Histogram]       # per numeric attribute, full dataset
    # label-range co-occurrence: cond_hists[lbl][num_attr] -> Histogram of that
    # numeric attribute over sample points carrying label ``lbl``.
    cond_hists: List[List[Optional[Histogram]]]
    sample_idx: np.ndarray       # indices of the 1-5 % sample
    dist_measure: float          # vector-distribution feature for the planner
    sample_frac: float

    # ------------------------------------------------------------------
    @staticmethod
    def build(
        vectors: np.ndarray,
        cat: np.ndarray,
        num: np.ndarray,
        sample_frac: float = 0.02,
        seed: int = 0,
    ) -> "DatasetStats":
        """Build all statistics.  ``sample_frac`` follows the paper's 1-5 %
        sampling for multi-label interaction statistics."""
        rng = np.random.default_rng(seed)
        n = vectors.shape[0]
        a_cat = cat.shape[1] if cat.size else 0
        a_num = num.shape[1] if num.size else 0

        cards = tuple(int(cat[:, a].max()) + 1 if n else 0 for a in range(a_cat))
        offsets, off = [], 0
        for c in cards:
            offsets.append(off)
            off += c
        n_labels = off

        # --- exact label frequencies (full dataset) -------------------
        freq = np.zeros(n_labels, dtype=np.float64)
        onehot_cols = []
        for a in range(a_cat):
            codes = cat[:, a]
            valid = codes >= 0
            bc = np.bincount(codes[valid], minlength=cards[a]).astype(np.float64)
            freq[offsets[a] : offsets[a] + cards[a]] = bc / n
            onehot_cols.append((codes, valid, a))

        # --- 2-D co-occurrence matrix (full dataset, exact) -----------
        # Built as G^T G / n with G the (n, n_labels) one-hot indicator.
        # For our label-space sizes (<= few thousand) this is cheap.
        cooc = np.zeros((n_labels, n_labels), dtype=np.float64)
        if n_labels:
            g = np.zeros((n, n_labels), dtype=np.float32)
            for a in range(a_cat):
                codes = cat[:, a]
                valid = codes >= 0
                g[np.nonzero(valid)[0], offsets[a] + codes[valid]] = 1.0
            cooc = (g.T @ g).astype(np.float64) / n

        # --- numeric histograms (full dataset) ------------------------
        hists = [Histogram.build(num[:, j], HIST_BINS) for j in range(a_num)]

        # --- 1-5 % sample + label-range conditional histograms --------
        n_sample = max(1, int(round(sample_frac * n)))
        sample_idx = rng.choice(n, size=n_sample, replace=False)
        cond: List[List[Optional[Histogram]]] = [[None] * a_num for _ in range(n_labels)]
        if n_labels and a_num:
            s_cat, s_num = cat[sample_idx], num[sample_idx]
            for a in range(a_cat):
                codes = s_cat[:, a]
                for code in range(cards[a]):
                    rows = codes == code
                    if rows.sum() < 4:  # too few sample points to histogram
                        continue
                    lbl = offsets[a] + code
                    for j in range(a_num):
                        h = Histogram.build(s_num[rows, j], COND_HIST_BINS)
                        # rescale "total" so range_mass/selectivity stays the
                        # conditional P(range | label); but keep joint scale
                        # available through label_range_joint() below.
                        cond[lbl][j] = h

        # --- vector distribution measure -------------------------------
        # Mean pairwise distance over a small sample, normalised by sqrt(dim):
        # a scale-free "spread" feature for the planner (paper: "vector
        # distribution measure").
        m = min(1024, n)
        sub = vectors[rng.choice(n, size=m, replace=False)].astype(np.float64)
        centred = sub - sub.mean(0)
        dist_measure = float(np.sqrt((centred**2).sum(1).mean()) / np.sqrt(vectors.shape[1]))

        return DatasetStats(
            n=n,
            dim=int(vectors.shape[1]),
            cat_cards=cards,
            cat_offsets=tuple(offsets),
            n_labels=n_labels,
            label_freq=freq,
            cooc=cooc,
            hists=hists,
            cond_hists=cond,
            sample_idx=sample_idx,
            dist_measure=dist_measure,
            sample_frac=float(sample_frac),
        )

    # ------------------------------------------------------------------
    # live-corpus incremental refresh
    # ------------------------------------------------------------------
    def apply_delta(
        self,
        added_cat: Optional[np.ndarray] = None,
        added_num: Optional[np.ndarray] = None,
        removed_cat: Optional[np.ndarray] = None,
        removed_num: Optional[np.ndarray] = None,
    ) -> "DatasetStats":
        """Fold a mutation batch into the full-dataset statistics without a
        rebuild: counts behind ``label_freq``/``cooc``/``hists`` add the
        appended rows and subtract the tombstoned rows, then renormalise
        over the new live count.

        Approximation boundaries (these are planner *estimates*; exactness
        stays the attribute index's job): codes outside the build-time
        cardinality can't be represented in the flattened label space and
        are dropped until a compaction rebuild widens it; histogram bin
        edges are frozen, so values outside the build-time ``[lo, hi)``
        adjust ``total`` but no bin; the sample-based conditional
        histograms are left as built.
        """
        a_cat = len(self.cat_cards)
        a_num = len(self.hists)

        def _counts_delta(rows_cat, sign):
            if rows_cat is None or rows_cat.shape[0] == 0:
                return 0
            rows_cat = np.atleast_2d(rows_cat)
            g = np.zeros((rows_cat.shape[0], self.n_labels), np.float32)
            for a in range(a_cat):
                codes = rows_cat[:, a]
                ok = (codes >= 0) & (codes < self.cat_cards[a])
                bc = np.bincount(codes[ok], minlength=self.cat_cards[a])
                lo = self.cat_offsets[a]
                self._label_counts[lo:lo + self.cat_cards[a]] += sign * bc
                g[np.nonzero(ok)[0], lo + codes[ok]] = 1.0
            if self.n_labels:
                self._cooc_counts += sign * (g.T @ g).astype(np.float64)
            return rows_cat.shape[0]

        def _hist_delta(rows_num, sign):
            if rows_num is None or rows_num.shape[0] == 0:
                return
            rows_num = np.atleast_2d(rows_num)
            for j in range(a_num):
                h = self.hists[j]
                c, _ = np.histogram(rows_num[:, j], bins=h.bins,
                                    range=(h.lo, h.hi))
                h.counts += sign * c
                np.maximum(h.counts, 0.0, out=h.counts)
                h.total = max(h.total + sign * rows_num.shape[0], 0.0)

        if not hasattr(self, "_label_counts"):
            self._label_counts = self.label_freq * self.n
            self._cooc_counts = self.cooc * self.n
        n_add = _counts_delta(added_cat, +1)
        n_rem = _counts_delta(removed_cat, -1)
        _hist_delta(added_num, +1)
        _hist_delta(removed_num, -1)
        self.n = max(self.n + n_add - n_rem, 0)
        np.maximum(self._label_counts, 0.0, out=self._label_counts)
        np.maximum(self._cooc_counts, 0.0, out=self._cooc_counts)
        denom = max(self.n, 1)
        self.label_freq = self._label_counts / denom
        self.cooc = self._cooc_counts / denom
        return self

    # ------------------------------------------------------------------
    # lookups used by the estimator
    # ------------------------------------------------------------------
    def single_label_sel(self, lbl: int) -> float:
        return float(self.label_freq[lbl])

    def pair_joint_sel(self, l1: int, l2: int) -> float:
        return float(self.cooc[l1, l2])

    def pmi(self, l1: int, l2: int, eps: float = 1e-12) -> float:
        """Pointwise mutual information between two labels (paper §3.2.1)."""
        pxy = self.cooc[l1, l2]
        px, py = self.label_freq[l1], self.label_freq[l2]
        return float(np.log((pxy + eps) / (px * py + eps)))

    def range_sel(self, r: RangePred) -> float:
        return self.hists[r.attr].selectivity(r.intervals)

    def label_range_joint(self, lbl: int, r: RangePred) -> float:
        """Joint selectivity P(label AND range) from the label-range
        co-occurrence statistics (conditional hist x label marginal)."""
        h = self.cond_hists[lbl][r.attr] if self.n_labels else None
        if h is None:
            # fall back to independence
            return self.single_label_sel(lbl) * self.range_sel(r)
        return h.selectivity(r.intervals) * self.single_label_sel(lbl)

    def independence_sel(self, pred: Predicate) -> float:
        """Selectivity assuming all conjuncts independent (negated leaves
        contribute their complement's marginal)."""
        s = 1.0
        for lbl in label_ids(pred, self.cat_offsets):
            s *= self.single_label_sel(lbl)
        for r in pred.ranges:
            s *= self.range_sel(r)
        for nt in pred.nots:
            if isinstance(nt.term, RangePred):
                s *= 1.0 - self.range_sel(nt.term)
            elif (0 <= nt.term.attr < len(self.cat_cards)
                  and 0 <= nt.term.code < self.cat_cards[nt.term.attr]):
                s *= 1.0 - self.single_label_sel(self.cat_offsets[nt.term.attr] + nt.term.code)
            # else: the label matches nothing, so its negation has
            # selectivity 1 — no factor
        return s
