"""Small shared numeric utilities for the core pipeline."""
from __future__ import annotations

import numpy as np

__all__ = ["next_pow2"]


def next_pow2(x: int, floor: int = 1) -> int:
    """Smallest power of two >= max(x, floor, 1).

    The pow2 rounding discipline is load-bearing in two places: jit'd shapes
    (subset/batch padding keeps the compile cache O(log N) x O(log B)) and
    batched execution grouping (post-filter budgets collapse into a handful
    of shared IVF dispatches).  One definition keeps every site agreeing.
    """
    x = max(int(x), int(floor), 1)
    return 1 << int(np.ceil(np.log2(x)))
