"""Unified model assembly for all assigned architectures.

One functional ``Model`` API drives training, prefill and decode for every
family (dense / moe / vlm / encdec / ssm / hybrid):

    model = Model(cfg)
    params = model.init(key)
    loss, metrics = model.loss(params, batch)
    logits, cache = model.prefill(params, batch)
    logits, cache = model.decode_step(params, cache, tokens, lengths)

Layers are stacked and scanned (compile-time is O(1) in depth); attention
locality (gemma2 local/global alternation, hymba sliding-window + 3 global
layers) is expressed as a *per-layer window array* scanned alongside the
params, so one uniform scan covers every pattern.  xLSTM's 7:1 mLSTM:sLSTM
interleave is a scan over groups (no lax.cond — keeps cost_analysis exact).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from . import ssm
from .layers import (
    AttnParams,
    attn_out,
    attn_qkv,
    decode_attention_xla,
    dequantize_kv,
    flash_attention,
    mlp,
    mlp_init,
    moe_ffn,
    moe_init,
    quantize_kv,
    rms_norm,
    rope,
    softcap,
)

GLOBAL_WINDOW = 2_000_000_000  # "window" value meaning full attention


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


# ======================================================================
# parameter init
# ======================================================================
def _dense_layer_init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "ln1": jnp.zeros(cfg.d_model),
        "ln2": jnp.zeros(cfg.d_model),
        "attn": AttnParams.init(ks[0], cfg),
        "ffn": moe_init(ks[1], cfg) if cfg.is_moe else mlp_init(ks[1], cfg.d_model, cfg.d_ff),
    }
    if cfg.post_norms:
        p["ln1b"] = jnp.zeros(cfg.d_model)
        p["ln2b"] = jnp.zeros(cfg.d_model)
    return p


def _hybrid_layer_init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros(cfg.d_model),
        "ln2": jnp.zeros(cfg.d_model),
        "attn": AttnParams.init(ks[0], cfg),
        "mamba": ssm.mamba_init(ks[1], cfg),
        "ffn": mlp_init(ks[2], cfg.d_model, cfg.d_ff),
    }


def _encdec_dec_layer_init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros(cfg.d_model),
        "ln_x": jnp.zeros(cfg.d_model),
        "ln2": jnp.zeros(cfg.d_model),
        "attn": AttnParams.init(ks[0], cfg),
        "xattn": AttnParams.init(ks[1], cfg),
        "ffn": mlp_init(ks[2], cfg.d_model, cfg.d_ff),
    }


def _windows(cfg: ModelConfig, n_layers: int) -> jnp.ndarray:
    """Per-layer attention window (GLOBAL_WINDOW = full attention)."""
    if cfg.layer_pattern == "local_global":
        w = [cfg.sliding_window if i % 2 == 0 else GLOBAL_WINDOW for i in range(n_layers)]
    elif cfg.layer_pattern == "hymba":
        w = [
            GLOBAL_WINDOW if i in cfg.global_layers else cfg.sliding_window
            for i in range(n_layers)
        ]
    else:
        w = [GLOBAL_WINDOW] * n_layers
    return jnp.asarray(w, jnp.int32)


# ======================================================================
# the Model
# ======================================================================
@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    # sharding hints: {"batch": <axis or tuple>, "model": <axis>} — set by the
    # distributed launchers; None disables constraints (single-device tests).
    hints: Optional[Dict[str, Any]] = None

    def _hint(self, x: jax.Array, *names: Optional[str]) -> jax.Array:
        """with_sharding_constraint(x, P(...)) when hints are active.  names
        are per-dim logical axes ("batch"/"model"/None); GSPMD loses batch
        sharding inside chunked-attention loop bodies without these."""
        if not self.hints:
            return x
        from jax.sharding import PartitionSpec as P

        spec = P(*[self.hints.get(n) if n else None for n in names])
        return jax.lax.with_sharding_constraint(x, spec)

    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> Dict[str, Any]:
        cfg = self.cfg
        k_embed, k_layers, k_head, k_enc = jax.random.split(key, 4)
        params: Dict[str, Any] = {
            "embed": jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model), jnp.float32)
            * cfg.d_model ** -0.5,
            "final_ln": jnp.zeros(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = (
                jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size), jnp.float32)
                * cfg.d_model ** -0.5
            )

        if cfg.family == "ssm":
            params["blocks"] = self._init_xlstm(k_layers)
        else:
            init_one = {
                "dense": _dense_layer_init,
                "moe": _dense_layer_init,
                "vlm": _dense_layer_init,
                "hybrid": _hybrid_layer_init,
                "encdec": _encdec_dec_layer_init,
            }[cfg.family]
            keys = jax.random.split(k_layers, cfg.n_layers)
            params["layers"] = jax.vmap(lambda k: init_one(k, cfg))(keys)

        if cfg.is_encdec:
            ekeys = jax.random.split(k_enc, cfg.n_enc_layers)
            enc_cfg = dataclasses.replace(cfg, n_experts=0)
            params["enc_layers"] = jax.vmap(
                lambda k: _dense_layer_init(k, enc_cfg)
            )(ekeys)
            params["enc_final_ln"] = jnp.zeros(cfg.d_model)
        return params

    def _init_xlstm(self, key) -> dict:
        cfg = self.cfg
        every = max(cfg.slstm_every, 1)
        n_groups, rem = divmod(cfg.n_layers, every)
        assert rem == 0, "ssm family requires n_layers % slstm_every == 0"
        gkeys = jax.random.split(key, n_groups)

        def group_init(k):
            k_s, k_m = jax.random.split(k)
            mkeys = jax.random.split(k_m, every - 1)
            return {
                "slstm": ssm.slstm_init(k_s, cfg),
                "slstm_ln": jnp.zeros(cfg.d_model),
                "mlstm": jax.vmap(lambda kk: ssm.mlstm_init(kk, cfg))(mkeys),
                "mlstm_ln": jnp.zeros((every - 1, cfg.d_model)),
            }

        return jax.vmap(group_init)(gkeys)

    # ==================================================================
    # shared pieces
    # ==================================================================
    def _embed(self, params, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = params["embed"].astype(_dtype(cfg))[tokens]
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        return x

    def _logits(self, params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        head = (
            params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        ).astype(x.dtype)
        logits = (x @ head).astype(jnp.float32)
        if logits.ndim == 3:
            logits = self._hint(logits, "batch", None, "model")
        return softcap(logits, cfg.final_softcap)

    # ==================================================================
    # sequence forward (train / prefill), per family
    # ==================================================================
    def _attn_block(self, lp, x, w, positions, kv_ext=None):
        """Self-attention sub-block with residual.  kv_ext: (k, v) override
        for cross-attention."""
        cfg = self.cfg
        h = rms_norm(x, lp["ln1" if kv_ext is None else "ln_x"], cfg.norm_eps)
        ap = lp["attn" if kv_ext is None else "xattn"]
        if kv_ext is None:
            q, k, v = attn_qkv(ap, h, cfg, positions)
            q = self._hint(q, "batch", None, None, None, None)
            k = self._hint(k, "batch", None, None, None)
            v = self._hint(v, "batch", None, None, None)
            o = flash_attention(
                q, k, v, causal=True, window=w, attn_softcap=cfg.attn_softcap
            )
            o = self._hint(o, "batch", None, None, None, None)
        else:
            b, s, _ = h.shape
            kvh, g, dh = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.dh
            q = (h @ ap["wq"].astype(h.dtype)).reshape(b, s, kvh, g, dh)
            k, v = kv_ext
            o = flash_attention(q, k, v, causal=False, window=None)
        o = attn_out(ap, o, cfg)
        if cfg.post_norms and kv_ext is None:
            o = rms_norm(o, lp["ln1b"], cfg.norm_eps)
        return x + o

    def _ffn_block(self, lp, x, aux):
        cfg = self.cfg
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            f, a = moe_ffn(lp["ffn"], h, cfg)
            aux = aux + a
        else:
            f = mlp(lp["ffn"], h)
        if cfg.post_norms:
            f = rms_norm(f, lp["ln2b"], cfg.norm_eps)
        return x + f, aux

    def _decoder_forward(self, params, x, positions, enc_kv=None):
        """Scan over decoder layers.  x: (B,S,D) embeddings."""
        cfg = self.cfg
        windows = _windows(cfg, cfg.n_layers)

        if cfg.family == "ssm":
            return self._xlstm_forward(params, x)

        # enter the scan with the carry D-sharded so the saved per-layer
        # residual stack (L, B, S, D) matches the in-scan exit hint
        x = self._hint(x, "batch", None, "model")

        def body(carry, xs):
            x, aux = carry
            lp, w = xs
            x = self._attn_block(lp, x, w, positions)
            if cfg.family == "hybrid":
                h = rms_norm(x, lp["ln1"], cfg.norm_eps)
                m_out, _ = ssm.mamba_seq(lp["mamba"], h, cfg)
                x = x + m_out
            if enc_kv is not None:
                x = self._attn_block(lp, x, None, positions, kv_ext=enc_kv)
            x, aux = self._ffn_block(lp, x, aux)
            # carry leaves the step D-sharded over `model`: the scan's saved
            # per-layer residuals (L, B, S, D) shrink by the TP degree
            # (sequence-parallel-style); the next layer re-gathers at qkv.
            x = self._hint(x, "batch", None, "model")
            return (x, aux), None

        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), _ = jax.lax.scan(body, (x, 0.0), (params["layers"], windows))
        return x, aux

    def _xlstm_forward(self, params, x):
        cfg = self.cfg

        def group_body(carry, gp):
            x, aux = carry
            h, _ = ssm.slstm_seq(
                gp["slstm"], rms_norm(x, gp["slstm_ln"], cfg.norm_eps), cfg
            )
            x = x + h

            def m_body(xc, mp):
                lp, ln = mp
                h, _ = ssm.mlstm_seq(lp, rms_norm(xc, ln, cfg.norm_eps), cfg)
                return xc + h, None

            x, _ = jax.lax.scan(m_body, x, (gp["mlstm"], gp["mlstm_ln"]))
            return (x, aux), None

        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable
        )
        (x, aux), _ = jax.lax.scan(group_body, (x, 0.0), params["blocks"])
        return x, aux

    def _encoder_forward(self, params, frames):
        """Bidirectional encoder over stub frame embeddings (B,F,D)."""
        cfg = self.cfg
        positions = jnp.arange(frames.shape[1])[None, :]

        def body(x, lp):
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = attn_qkv(lp["attn"], h, cfg, positions)
            o = flash_attention(q, k, v, causal=False, window=None)
            x = x + attn_out(lp["attn"], o, cfg)
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            return x + mlp(lp["ffn"], h), None

        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, frames.astype(_dtype(cfg)), params["enc_layers"])
        return rms_norm(x, params["enc_final_ln"], cfg.norm_eps)

    # ==================================================================
    # public: forward / loss
    # ==================================================================
    def _hidden(self, params, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, jax.Array]:
        """Final hidden states over the token positions (pre-logits)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        n_prefix = 0

        if cfg.family == "vlm":
            patches = batch["patches"].astype(x.dtype)     # (B,P,D) stub embeds
            x = jnp.concatenate([patches, x], axis=1)
            n_prefix = patches.shape[1]
        x = self._hint(x, "batch", None, None)

        positions = jnp.arange(x.shape[1])[None, :]

        enc_kv = None
        if cfg.is_encdec:
            enc_out = self._encoder_forward(params, batch["frames"])
            # cross-attention keys/values from encoder output (shared across
            # decoder layers — backbone simplification, see DESIGN.md)
            b, f, _ = enc_out.shape
            kvh, dh = cfg.n_kv_heads, cfg.dh
            lp0 = jax.tree.map(lambda a: a[0], params["layers"])
            k = (enc_out @ lp0["xattn"]["wk"].astype(x.dtype)).reshape(b, f, kvh, dh)
            v = (enc_out @ lp0["xattn"]["wv"].astype(x.dtype)).reshape(b, f, kvh, dh)
            enc_kv = (k, v)

        x, aux = self._decoder_forward(params, x, positions, enc_kv=enc_kv)
        if n_prefix:
            x = x[:, n_prefix:]
        return x, aux

    def forward(self, params, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, jax.Array]:
        """Teacher-forced logits over the token positions.  Returns
        (logits (B,S,V) fp32, aux_loss)."""
        x, aux = self._hidden(params, batch)
        return self._logits(params, x), aux

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Chunked cross-entropy: the (B,S,V) fp32 logits tensor never
        materialises — CE is computed per sequence chunk with remat, which at
        256k-vocab saves ~4 full (T,V) fp32 buffers."""
        cfg = self.cfg
        x, aux = self._hidden(params, batch)
        labels = batch["labels"]
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        head = (
            params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        ).astype(x.dtype)

        b, s, d = x.shape
        ch = min(512, s)
        pad = (-s) % ch
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        n_chunks = x.shape[1] // ch
        xs = jnp.moveaxis(x.reshape(b, n_chunks, ch, d), 1, 0)
        ls = jnp.moveaxis(labels.reshape(b, n_chunks, ch), 1, 0)

        def chunk_ce(carry, inp):
            xc, lc = inp                                   # (B,ch,D), (B,ch)
            logits = (xc @ head).astype(jnp.float32)
            logits = self._hint(logits, "batch", None, "model")
            logits = softcap(logits, cfg.final_softcap)
            valid = lc >= 0
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(
                logits, jnp.maximum(lc, 0)[..., None], axis=-1
            )[..., 0]
            ce = jnp.where(valid, lse - ll, 0.0)
            return (carry[0] + ce.sum(), carry[1] + valid.sum()), None

        chunk_ce = jax.checkpoint(
            chunk_ce, policy=jax.checkpoint_policies.nothing_saveable
        )
        (ce_sum, n_tok), _ = jax.lax.scan(chunk_ce, (0.0, 0), (xs, ls))
        n_tok = jnp.maximum(n_tok, 1)
        loss = ce_sum / n_tok
        total = loss + 0.01 * aux
        return total, {"ce": loss, "aux": aux, "tokens": n_tok}

    # ==================================================================
    # serving: cache init / prefill / decode
    # ==================================================================
    def init_cache(self, b: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        dt = _dtype(cfg)
        kvh, dh, L = cfg.n_kv_heads, cfg.dh, cfg.n_layers
        cache: Dict[str, Any] = {}
        if cfg.family != "ssm":
            cdt = jnp.int8 if cfg.kv_cache_int8 else dt
            cache["k"] = jnp.zeros((L, b, kvh, max_len, dh), cdt)
            cache["v"] = jnp.zeros((L, b, kvh, max_len, dh), cdt)
            if cfg.kv_cache_int8:
                # per-(position, head) scales — 2/dh relative overhead
                cache["k_scale"] = jnp.zeros((L, b, kvh, max_len), jnp.float32)
                cache["v_scale"] = jnp.zeros((L, b, kvh, max_len), jnp.float32)
        if cfg.family == "hybrid":
            st = ssm.mamba_state(b, cfg, jnp.float32)
            cache["ssm_h"] = jnp.zeros((L,) + st["h"].shape, jnp.float32)
            cache["ssm_conv"] = jnp.zeros((L,) + st["conv"].shape, jnp.float32)
        if cfg.family == "ssm":
            every = max(cfg.slstm_every, 1)
            g = cfg.n_layers // every
            s_st = ssm.slstm_state(b, cfg)
            m_st = ssm.mlstm_state(b, cfg)
            cache["slstm"] = jax.tree.map(
                lambda a: jnp.zeros((g,) + a.shape, a.dtype), s_st
            )
            cache["mlstm"] = jax.tree.map(
                lambda a: jnp.zeros((g, every - 1) + a.shape, a.dtype), m_st
            )
            # stabiliser states start at -1e30, not 0
            cache["slstm"]["m"] = jnp.full_like(cache["slstm"]["m"], -1e30)
            cache["mlstm"]["m"] = jnp.full_like(cache["mlstm"]["m"], -1e30)
        if cfg.is_encdec:
            cache["xk"] = jnp.zeros((b, cfg.frontend_len, kvh, dh), dt)
            cache["xv"] = jnp.zeros((b, cfg.frontend_len, kvh, dh), dt)
        return cache

    @property
    def supports_ragged_prefill(self) -> bool:
        """Whether unequal-length prompt batching is EXACT for this family.

        Attention families are: causal masking isolates each row's last real
        position from its pad tail.  Recurrent families (ssm, hybrid) fold
        pad steps into carried slstm/mlstm/mamba state, so they must be
        served equal-length — the single source of truth ServeEngine checks.
        """
        return self.cfg.family not in ("ssm", "hybrid")

    def _last_hidden(self, x, lengths, n_prefix: int = 0):
        """Hidden state at each row's LAST REAL position.

        ``lengths`` (B,) is the per-row prompt length; in a padded batch the
        max-length position is a pad slot for shorter rows, so logits must be
        gathered at ``n_prefix + lengths - 1`` per row.  ``lengths=None``
        keeps the equal-length fast path (last column)."""
        if lengths is None:
            return x[:, -1:, :]
        pos = n_prefix + jnp.maximum(lengths, 1) - 1
        return jnp.take_along_axis(x, pos[:, None, None], axis=1)

    def prefill(self, params, batch, max_len: int, lengths: Optional[jax.Array] = None):
        """Run the prompt through the model, returning (last-token logits,
        populated cache).  For encdec the 'prompt' is the encoder input.

        ``lengths`` (B,) enables exact unequal-length batching for attention
        families: causal masking keeps each row's hidden state at position
        ``lengths-1`` independent of the pad tail, and pad kv-cache entries
        lie beyond the decode-time length mask (each is overwritten before it
        enters the attention window).  Recurrent families (ssm, hybrid —
        anything carrying slstm/mlstm/mamba state) still fold pad steps into
        that state — serve equal-length batches there (ServeEngine enforces
        this)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache = self.init_cache(b, max_len)
        x = self._embed(params, tokens)
        n_prefix = 0
        if cfg.family == "vlm":
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
            n_prefix = batch["patches"].shape[1]
        x = self._hint(x, "batch", None, None)
        positions = jnp.arange(x.shape[1])[None, :]

        enc_kv = None
        if cfg.is_encdec:
            enc_out = self._encoder_forward(params, batch["frames"])
            bb, f, _ = enc_out.shape
            kvh, dh = cfg.n_kv_heads, cfg.dh
            lp0 = jax.tree.map(lambda a: a[0], params["layers"])
            xk = (enc_out @ lp0["xattn"]["wk"].astype(x.dtype)).reshape(bb, f, kvh, dh)
            xv = (enc_out @ lp0["xattn"]["wv"].astype(x.dtype)).reshape(bb, f, kvh, dh)
            cache["xk"], cache["xv"] = xk, xv
            enc_kv = (xk, xv)

        if cfg.family == "ssm":
            x, states = self._xlstm_prefill(params, x)
            cache.update(states)
            logits = self._logits(params, self._last_hidden(x, lengths, n_prefix))[:, 0]
            return logits, cache

        windows = _windows(cfg, cfg.n_layers)
        seq_len = x.shape[1]

        def body(carry, xs):
            x = carry
            lp, w = xs
            cfg_ = self.cfg
            x = self._hint(x, "batch", None, None)
            h = rms_norm(x, lp["ln1"], cfg_.norm_eps)
            q, k, v = attn_qkv(lp["attn"], h, cfg_, positions)
            q = self._hint(q, "batch", None, None, None, None)
            k = self._hint(k, "batch", None, None, None)
            v = self._hint(v, "batch", None, None, None)
            o = flash_attention(q, k, v, causal=True, window=w,
                                attn_softcap=cfg_.attn_softcap)
            o = self._hint(o, "batch", None, None, None, None)
            o_p = attn_out(lp["attn"], o, cfg_)
            if cfg_.post_norms:
                o_p = rms_norm(o_p, lp["ln1b"], cfg_.norm_eps)
            x = x + o_p
            new_states = {}
            if cfg_.family == "hybrid":
                hh = rms_norm(x, lp["ln1"], cfg_.norm_eps)
                m_out, m_state = ssm.mamba_seq(lp["mamba"], hh, cfg_)
                x = x + m_out
                new_states = {"ssm_h": m_state["h"], "ssm_conv": m_state["conv"]}
            if enc_kv is not None:
                x = self._attn_block(lp, x, None, positions, kv_ext=enc_kv)
            x, _ = self._ffn_block(lp, x, 0.0)
            # cache layout (B, KV, S, dh)
            kc = jnp.moveaxis(k, 1, 2)
            vc = jnp.moveaxis(v, 1, 2)
            out = {"k": kc, "v": vc, **new_states}
            if cfg.kv_cache_int8:
                out["k"], out["k_scale"] = quantize_kv(kc)
                out["v"], out["v_scale"] = quantize_kv(vc)
            return x, out

        x, per_layer = jax.lax.scan(body, x, (params["layers"], windows))
        pad = max_len - seq_len
        cache["k"] = jnp.pad(per_layer["k"], ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        cache["v"] = jnp.pad(per_layer["v"], ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        if cfg.kv_cache_int8:
            cache["k_scale"] = jnp.pad(
                per_layer["k_scale"], ((0, 0), (0, 0), (0, 0), (0, pad))
            )
            cache["v_scale"] = jnp.pad(
                per_layer["v_scale"], ((0, 0), (0, 0), (0, 0), (0, pad))
            )
        if cfg.family == "hybrid":
            cache["ssm_h"] = per_layer["ssm_h"]
            cache["ssm_conv"] = per_layer["ssm_conv"]
        logits = self._logits(params, self._last_hidden(x, lengths, n_prefix))[:, 0]
        return logits, cache

    def _xlstm_prefill(self, params, x):
        cfg = self.cfg

        def group_body(x, gp):
            h, s_state = ssm.slstm_seq(
                gp["slstm"], rms_norm(x, gp["slstm_ln"], cfg.norm_eps), cfg
            )
            x = x + h

            def m_body(xc, mp):
                lp, ln = mp
                h, m_state = ssm.mlstm_seq(lp, rms_norm(xc, ln, cfg.norm_eps), cfg)
                return xc + h, m_state

            x, m_states = jax.lax.scan(m_body, x, (gp["mlstm"], gp["mlstm_ln"]))
            return x, {"slstm": s_state, "mlstm": m_states}

        x, states = jax.lax.scan(group_body, x, params["blocks"])
        return x, states

    # ------------------------------------------------------------------
    def decode_step(self, params, cache, tokens: jax.Array, lengths: jax.Array):
        """One decode step.  tokens: (B,) int32; lengths: (B,) — current cache
        fill (the new token is written at ``lengths``).  Returns
        (logits (B,V), updated cache)."""
        cfg = self.cfg
        b = tokens.shape[0]
        x = self._embed(params, tokens[:, None])           # (B,1,D)
        positions = lengths[:, None]

        if cfg.family == "ssm":
            return self._xlstm_decode(params, cache, x)

        windows = _windows(cfg, cfg.n_layers)
        kvh, g, dh = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.dh
        enc_kv = (cache["xk"], cache["xv"]) if cfg.is_encdec else None

        def body(x, xs):
            if cfg.kv_cache_int8 and cfg.family == "hybrid":
                lp, w, kc, vc, ksc, vsc, ssm_h, ssm_conv = xs
            elif cfg.kv_cache_int8:
                lp, w, kc, vc, ksc, vsc = xs
            elif cfg.family == "hybrid":
                lp, w, kc, vc, ssm_h, ssm_conv = xs
            else:
                lp, w, kc, vc = xs
            x = self._hint(x, "batch", None, None)
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = attn_qkv(lp["attn"], h, cfg, positions)
            # write the new kv at position `lengths` (per-sequence)
            if cfg.kv_cache_int8:
                kq, ks_new = quantize_kv(k[:, 0])
                vq, vs_new = quantize_kv(v[:, 0])
                kc = kc.at[jnp.arange(b), :, lengths, :].set(kq)
                vc = vc.at[jnp.arange(b), :, lengths, :].set(vq)
                ksc = ksc.at[jnp.arange(b), :, lengths].set(ks_new)
                vsc = vsc.at[jnp.arange(b), :, lengths].set(vs_new)
                k_att = dequantize_kv(kc, ksc).astype(x.dtype)
                v_att = dequantize_kv(vc, vsc).astype(x.dtype)
            else:
                kc = kc.at[jnp.arange(b), :, lengths, :].set(k[:, 0])
                vc = vc.at[jnp.arange(b), :, lengths, :].set(v[:, 0])
                k_att, v_att = kc, vc
            o = decode_attention_xla(
                q[:, 0], k_att, v_att, lengths + 1,
                window=w, attn_softcap=cfg.attn_softcap,
            )[:, None]
            o_p = attn_out(lp["attn"], o.reshape(b, 1, kvh, g, dh), cfg)
            if cfg.post_norms:
                o_p = rms_norm(o_p, lp["ln1b"], cfg.norm_eps)
            x = x + o_p
            out_extra = {}
            if cfg.family == "hybrid":
                hh = rms_norm(x, lp["ln1"], cfg.norm_eps)
                m_out, m_state = ssm.mamba_step(
                    lp["mamba"], hh[:, 0], cfg, {"h": ssm_h, "conv": ssm_conv}
                )
                x = x + m_out[:, None]
                out_extra = {"ssm_h": m_state["h"], "ssm_conv": m_state["conv"]}
            if enc_kv is not None:
                x = self._attn_block(lp, x, None, positions, kv_ext=enc_kv)
            x, _ = self._ffn_block(lp, x, 0.0)
            out = {"k": kc, "v": vc, **out_extra}
            if cfg.kv_cache_int8:
                out["k_scale"], out["v_scale"] = ksc, vsc
            return x, out

        xs = [params["layers"], windows, cache["k"], cache["v"]]
        if cfg.kv_cache_int8:
            xs += [cache["k_scale"], cache["v_scale"]]
        if cfg.family == "hybrid":
            xs += [cache["ssm_h"], cache["ssm_conv"]]
        x, updated = jax.lax.scan(body, x, tuple(xs))
        cache = dict(cache)
        for key in updated:
            cache[key] = updated[key]
        logits = self._logits(params, x)[:, 0]
        return logits, cache

    def _xlstm_decode(self, params, cache, x):
        cfg = self.cfg

        def group_body(x, xs):
            gp, s_state, m_states = xs
            h, s_new = ssm.slstm_step(
                gp["slstm"], rms_norm(x, gp["slstm_ln"], cfg.norm_eps)[:, 0], cfg, s_state
            )
            x = x + h[:, None]

            def m_body(xc, mp):
                lp, ln, st = mp
                h, st_new = ssm.mlstm_step(
                    lp, rms_norm(xc, ln, cfg.norm_eps)[:, 0], cfg, st
                )
                return xc + h[:, None], st_new

            x, m_new = jax.lax.scan(m_body, x, (gp["mlstm"], gp["mlstm_ln"], m_states))
            return x, {"slstm": s_new, "mlstm": m_new}

        x, new_states = jax.lax.scan(
            group_body, x, (params["blocks"], cache["slstm"], cache["mlstm"])
        )
        cache = dict(cache)
        cache["slstm"], cache["mlstm"] = new_states["slstm"], new_states["mlstm"]
        logits = self._logits(params, x)[:, 0]
        return logits, cache

    # ==================================================================
    # input specs for the dry-run (no allocation)
    # ==================================================================
    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every input of the step function
        matching this shape cell (train -> loss/train_step inputs; prefill ->
        prompt batch; decode -> token + cache)."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = _dtype(cfg)
        sd = jax.ShapeDtypeStruct

        if shape.kind == "train":
            batch = {"tokens": sd((b, s), i32), "labels": sd((b, s), i32)}
            if cfg.family == "vlm":
                batch["patches"] = sd((b, cfg.frontend_len, cfg.d_model), dt)
            if cfg.is_encdec:
                batch["frames"] = sd((b, cfg.frontend_len, cfg.d_model), dt)
            return {"batch": batch}

        if shape.kind == "prefill":
            batch = {"tokens": sd((b, s), i32)}
            if cfg.family == "vlm":
                batch["patches"] = sd((b, cfg.frontend_len, cfg.d_model), dt)
            if cfg.is_encdec:
                batch["frames"] = sd((b, cfg.frontend_len, cfg.d_model), dt)
            return {"batch": batch}

        # decode: one token against a cache of size seq_len
        cache_spec = jax.eval_shape(lambda: self.init_cache(b, s))
        return {
            "cache": cache_spec,
            "tokens": sd((b,), i32),
            "lengths": sd((b,), i32),
        }
