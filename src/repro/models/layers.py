"""Shared transformer building blocks (pure JAX, functional).

Conventions:
* params are pytrees of fp32 master arrays; compute casts to cfg dtype;
* activations: (B, S, D); attention heads grouped GQA-style (KV, G, dh)
  with G = n_heads // n_kv_heads;
* flash-style attention: lax.scan over query chunks with blockwise softmax —
  the (S, S) score matrix never materialises (required for the 32k shapes).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig

NEG = -2.0e38


# ----------------------------------------------------------------------
# basics
# ----------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    # variance reduction in f32; the normalisation itself stays in the input
    # dtype — a full f32 copy of the residual stream here would become the
    # layer-scan's saved carry (observed: XLA stacks the f32 convert).
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale.astype(x.dtype))


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq           # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


def quantize_kv(x: jax.Array):
    """Per-vector symmetric int8 quantisation over the head dim.
    x: (..., dh) -> (int8 (..., dh), f32 scale (...)).  Halves the KV-cache
    HBM traffic that bounds long-context decode (EXPERIMENTS.md §Perf H3)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


# ----------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------
def flash_attention(
    q: jax.Array,            # (B, Sq, KV, G, dh)
    k: jax.Array,            # (B, Sk, KV, dh)
    v: jax.Array,            # (B, Sk, KV, dh)
    *,
    q_offset: int = 0,       # absolute position of q[0] (for prefix caches)
    causal: bool = True,
    window=None,             # None = full; int/traced scalar = sliding window
    attn_softcap: float = 0.0,
    chunk: int = 128,
) -> jax.Array:
    """Memory-bounded attention: scan over query chunks; scores per chunk are
    (B, KV, G, chunk, Sk).  Returns (B, Sq, KV, G, dh).

    chunk=128: at 64 heads / 4k context the fp32 score block is
    B_loc * H * chunk * S * 4B — 512-wide chunks cost 8.6 GiB/device on the
    production mesh (observed), 128-wide cost 2.1 GiB."""
    b, sq, kv, g, dh = q.shape
    sk = k.shape[1]
    scale = dh ** -0.5
    chunk = min(chunk, sq)
    pad = (-sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    n_chunks = q.shape[1] // chunk
    qs = jnp.moveaxis(q.reshape(b, n_chunks, chunk, kv, g, dh), 1, 0)
    k_pos = jnp.arange(sk)

    def one_chunk(ci, qc):
        # qc: (B, chunk, KV, G, dh)
        scores = jnp.einsum(
            "bqkgd,bskd->bkgqs", qc.astype(jnp.float32), k.astype(jnp.float32)
        ) * scale
        scores = softcap(scores, attn_softcap)
        q_pos = q_offset + ci * chunk + jnp.arange(chunk)
        m = jnp.ones((chunk, sk), bool)
        if causal:
            m &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:  # dynamic: window may be a per-layer scanned scalar
            m &= k_pos[None, :] > q_pos[:, None] - window
        scores = jnp.where(m[None, None, None, :, :], scores, NEG)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
        return out.astype(q.dtype)

    # remat the chunk body: without it, backward saves the (S, S) softmax
    # weights across all chunks — exactly the matrix flash attention exists
    # to avoid.  Recompute costs ~1 extra score matmul per chunk.
    chunk_fn = jax.checkpoint(
        lambda args: one_chunk(*args),
        policy=jax.checkpoint_policies.nothing_saveable,
    )
    outs = jax.lax.map(chunk_fn, (jnp.arange(n_chunks), qs))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq + pad, kv, g, dh)
    return out[:, :sq]


def decode_attention_xla(
    q: jax.Array,        # (B, KV, G, dh) one new token
    k_cache: jax.Array,  # (B, KV, S, dh)
    v_cache: jax.Array,  # (B, KV, S, dh)
    length: jax.Array,   # (B,) — number of valid cache positions INCLUDING new
    *,
    window=None,
    attn_softcap: float = 0.0,
) -> jax.Array:
    """Single-token attention over the KV cache (XLA path; the Pallas
    flash-decode kernel in repro.kernels implements the same contract)."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum(
        "bkgd,bksd->bkgs", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    scores = softcap(scores, attn_softcap)
    s = k_cache.shape[2]
    pos = jnp.arange(s)[None, :]
    valid = pos < length[:, None]
    if window is not None:
        valid &= pos >= (length[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, NEG)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgs,bksd->bkgd", w, v_cache.astype(jnp.float32)).astype(q.dtype)


@dataclasses.dataclass
class AttnParams:
    """Attention weights for one layer (shapes fixed by the config)."""

    @staticmethod
    def init(key, cfg: ModelConfig) -> dict:
        d, dh, h, kvh = cfg.d_model, cfg.dh, cfg.n_heads, cfg.n_kv_heads
        ks = jax.random.split(key, 4)
        s = d ** -0.5
        p = {
            "wq": jax.random.normal(ks[0], (d, h * dh), jnp.float32) * s,
            "wk": jax.random.normal(ks[1], (d, kvh * dh), jnp.float32) * s,
            "wv": jax.random.normal(ks[2], (d, kvh * dh), jnp.float32) * s,
            "wo": jax.random.normal(ks[3], (h * dh, d), jnp.float32) * (h * dh) ** -0.5,
        }
        if cfg.qk_norm:
            p["q_norm"] = jnp.zeros(dh)
            p["k_norm"] = jnp.zeros(dh)
        return p


def attn_qkv(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    """Project + qk-norm + rope.  x: (B,S,D) -> q (B,S,KV,G,dh), k/v (B,S,KV,dh)."""
    b, s, _ = x.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    g = h // kvh
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, s, h, dh)
    k = (x @ p["wk"].astype(dt)).reshape(b, s, kvh, dh)
    v = (x @ p["wv"].astype(dt)).reshape(b, s, kvh, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q.reshape(b, s, kvh, g, dh), k, v


def attn_out(p: dict, o: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, s = o.shape[:2]
    return o.reshape(b, s, cfg.n_heads * cfg.dh) @ p["wo"].astype(o.dtype)


# ----------------------------------------------------------------------
# feed-forward
# ----------------------------------------------------------------------
def mlp_init(key, d: int, f: int) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(ks[0], (d, f), jnp.float32) * d ** -0.5,
        "w_up": jax.random.normal(ks[1], (d, f), jnp.float32) * d ** -0.5,
        "w_down": jax.random.normal(ks[2], (f, d), jnp.float32) * f ** -0.5,
    }


def mlp(p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    return h @ p["w_down"].astype(dt)


# ----------------------------------------------------------------------
# Mixture of Experts (sort-based dispatch with capacity)
# ----------------------------------------------------------------------
def moe_init(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * d ** -0.5,
        "w_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * d ** -0.5,
        "w_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * d ** -0.5,
        "w_down": jax.random.normal(ks[3], (e, f, d), jnp.float32) * f ** -0.5,
    }
    if cfg.moe_shared_expert:
        p["shared"] = mlp_init(ks[4], d, f)
    return p


def moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Token-choice top-k MoE with GROUPED (per-sequence) capacity dispatch.

    Dispatch is sort-based but vmapped over the batch dim: each row sorts its
    own S*K (expert, slot) assignments and packs them into a (E, C, D)
    buffer, so under pjit every step stays batch-sharded — a global argsort
    over B*S*K would force an all-gather of the whole token stream (observed:
    ~200 GiB/device before this change).  Capacity is per sequence
    (GShard-style groups).  Returns (output, aux_load_balance_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k_experts
    dt = x.dtype

    logits = (x @ p["router"].astype(dt)).astype(jnp.float32)      # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                           # (B, S, K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)   # renormalise

    # load-balance aux loss (Switch-style), computed globally
    density = jnp.zeros((e,)).at[topi.reshape(-1)].add(1.0) / (b * s * k)
    aux = e * jnp.sum(density * probs.mean((0, 1)))

    cap = int(max(1, cfg.capacity_factor * s * k / e))

    def dispatch_row(xr, er, wr):
        """xr: (S, D); er/wr: (S, K) -> (out (S, D))."""
        e_flat = er.reshape(-1)                                    # (S*K,)
        t_flat = jnp.repeat(jnp.arange(s), k)
        w_flat = wr.reshape(-1)
        order = jnp.argsort(e_flat)                                # row-local sort
        e_sort, t_sort, w_sort = e_flat[order], t_flat[order], w_flat[order]
        first = jnp.searchsorted(e_sort, e_sort, side="left")
        slot = jnp.arange(s * k) - first
        keep = slot < cap
        slot_c = jnp.minimum(slot, cap - 1)
        buf = jnp.zeros((e, cap, d), dt)
        buf = buf.at[e_sort, slot_c].add(
            jnp.where(keep[:, None], xr[t_sort], 0).astype(dt)
        )
        return buf, (e_sort, slot_c, t_sort, w_sort, keep)

    buf, (e_sort, slot_c, t_sort, w_sort, keep) = jax.vmap(
        dispatch_row
    )(x, topi, topv)                                               # buf: (B, E, C, D)

    # per-expert SwiGLU: batched dense einsums (MXU-friendly; E can shard)
    h = jax.nn.silu(
        jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(dt))
    ) * jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(dt))
    y_buf = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(dt))

    def combine_row(yb, es, sc, ts, ws, kp):
        y_slots = yb[es, sc] * (ws * kp)[:, None].astype(dt)       # (S*K, D)
        return jnp.zeros((s, d), dt).at[ts].add(y_slots)

    out = jax.vmap(combine_row)(y_buf, e_sort, slot_c, t_sort, w_sort, keep)
    if cfg.moe_shared_expert:
        out = out + mlp(p["shared"], x.reshape(b * s, d)).reshape(b, s, d)
    return out, aux
