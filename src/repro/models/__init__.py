from .model import Model
from . import layers, ssm

__all__ = ["Model", "layers", "ssm"]
