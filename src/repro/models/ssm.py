"""Recurrent sequence mixers: mLSTM / sLSTM (xLSTM) and Mamba-style S6.

All three expose a sequence form (``*_seq`` — lax.scan over time, used by
train/prefill) and a single-step form (``*_step`` — O(1) state update, used
by decode).  States are explicit pytrees so the serving cache can shard and
checkpoint them like KV caches.

These recurrences are the reason the ssm/hybrid architectures run the
long_500k decode cell: per-token cost is independent of context length.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


# ----------------------------------------------------------------------
# mLSTM (matrix memory, exponential gating with stabiliser)
# ----------------------------------------------------------------------
def mlstm_init(key, cfg: ModelConfig) -> dict:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.dh
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    return {
        "wq": jax.random.normal(ks[0], (d, h * dh), jnp.float32) * s,
        "wk": jax.random.normal(ks[1], (d, h * dh), jnp.float32) * s,
        "wv": jax.random.normal(ks[2], (d, h * dh), jnp.float32) * s,
        "wi": jax.random.normal(ks[3], (d, h), jnp.float32) * s,
        "wf": jax.random.normal(ks[4], (d, h), jnp.float32) * s,
        "w_gate": jax.random.normal(ks[5], (d, h * dh), jnp.float32) * s,
        "w_out": jax.random.normal(ks[6], (h * dh, d), jnp.float32) * (h * dh) ** -0.5,
    }


def mlstm_state(b: int, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, jax.Array]:
    h, dh = cfg.n_heads, cfg.dh
    return {
        "C": jnp.zeros((b, h, dh, dh), dtype),
        "n": jnp.zeros((b, h, dh), dtype),
        "m": jnp.full((b, h), -1e30, dtype),
    }


def _mlstm_cell(state, qkvif):
    """One time step.  q/k/v: (B,H,dh); i/f raw gates: (B,H)."""
    q, k, v, ir, fr = qkvif
    C, n, m = state["C"], state["n"], state["m"]
    dh = q.shape[-1]
    logf = jax.nn.log_sigmoid(fr)                       # stable forget in log space
    m_new = jnp.maximum(logf + m, ir)
    i_g = jnp.exp(ir - m_new)[..., None]                # (B,H,1)
    f_g = jnp.exp(logf + m - m_new)[..., None]
    k_s = k / (dh ** 0.5)
    C = f_g[..., None] * C + i_g[..., None] * (k_s[..., :, None] * v[..., None, :])
    n = f_g * n + i_g * k_s
    hnum = jnp.einsum("bhd,bhde->bhe", q, C)
    hden = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n))
    hden = jnp.maximum(hden, jnp.exp(-m_new))[..., None]
    h = hnum / hden
    return {"C": C, "n": n, "m": m_new}, h


MLSTM_CHUNK = 256


def _mlstm_chunk(state, qkvif, dh_scale):
    """Chunkwise-parallel mLSTM (stabilised): one chunk of T steps as dense
    matmuls instead of T sequential state updates.

    WHY: the per-step recurrence keeps a (dh x dh) matrix state per head per
    step alive for the backward pass — at 4k context that stacked residual
    was ~390 GiB/device (observed).  The chunkwise form touches the matrix
    state only at chunk boundaries; intra-chunk interactions become a masked
    (T, T) attention-like product that the MXU eats directly.

    q/k/v: (B,H,T,dh); ir/lf: (B,H,T) raw input gate / log-sigmoid forget.
    state: C (B,H,dh,dh), n (B,H,dh), m (B,H).
    """
    q, k, v, ir, lf = qkvif
    C0, n0, m0 = state["C"], state["n"], state["m"]
    t = q.shape[2]
    ks = k * dh_scale

    b_cum = jnp.cumsum(lf, axis=-1)                       # (B,H,T) inclusive
    # intra-chunk log-weights: logW[t,s] = b_t - b_s + i_s   (s <= t)
    logw = b_cum[..., :, None] - b_cum[..., None, :] + ir[..., None, :]
    tri = jnp.tril(jnp.ones((t, t), bool))
    logw = jnp.where(tri, logw, -jnp.inf)
    # inter-chunk decay: g_t = b_t + m0
    g = b_cum + m0[..., None]                             # (B,H,T)
    m_t = jnp.maximum(g, jnp.max(logw, axis=-1))          # stabiliser per step
    w = jnp.exp(logw - m_t[..., None])                    # (B,H,T,T)
    inter = jnp.exp(g - m_t)                              # (B,H,T)

    scores = jnp.einsum("bhtd,bhsd->bhts", q, ks)         # (B,H,T,T)
    h_num = jnp.einsum("bhts,bhsd->bhtd", w * scores, v)
    h_num += inter[..., None] * jnp.einsum("bhtd,bhde->bhte", q, C0)
    denom = jnp.einsum("bhts,bhts->bht", w, scores)
    denom += inter * jnp.einsum("bhtd,bhd->bht", q, n0)
    h = h_num / jnp.maximum(jnp.abs(denom), jnp.exp(-m_t))[..., None]

    # chunk-final state
    gT = b_cum[..., -1:] + m0[..., None]                  # (B,H,1)
    logwT = b_cum[..., -1:] - b_cum + ir                  # (B,H,T)
    m_new = jnp.maximum(gT[..., 0], jnp.max(logwT, axis=-1))
    wT = jnp.exp(logwT - m_new[..., None])                # (B,H,T)
    decay0 = jnp.exp(gT[..., 0] - m_new)                  # (B,H)
    C = decay0[..., None, None] * C0 + jnp.einsum(
        "bht,bhtd,bhte->bhde", wT, ks, v
    )
    n = decay0[..., None] * n0 + jnp.einsum("bht,bhtd->bhd", wT, ks)
    return {"C": C, "n": n, "m": m_new}, h


def mlstm_seq(p: dict, x: jax.Array, cfg: ModelConfig, state=None):
    """x: (B,S,D) -> (y (B,S,D), final state).  Chunkwise-parallel form."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.dh
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, s, h, dh).astype(jnp.float32)
    k = (x @ p["wk"].astype(dt)).reshape(b, s, h, dh).astype(jnp.float32)
    v = (x @ p["wv"].astype(dt)).reshape(b, s, h, dh).astype(jnp.float32)
    ir = (x @ p["wi"].astype(dt)).astype(jnp.float32)   # (B,S,H)
    fr = (x @ p["wf"].astype(dt)).astype(jnp.float32)
    if state is None:
        state = mlstm_state(b, cfg)

    ch = min(MLSTM_CHUNK, s)
    pad = (-s) % ch
    if pad:
        # i gate -inf -> padded steps contribute nothing; f raw +30 -> no decay
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ir = jnp.pad(ir, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        fr = jnp.pad(fr, ((0, 0), (0, pad), (0, 0)), constant_values=30.0)
    sp = q.shape[1]
    n_chunks = sp // ch

    def to_chunks(a):       # (B,S,H,...) -> (n_chunks, B, H, ch, ...)
        a = jnp.moveaxis(a, 2, 1)                            # (B,H,S,...)
        a = a.reshape(a.shape[0], a.shape[1], n_chunks, ch, *a.shape[3:])
        return jnp.moveaxis(a, 2, 0)

    lf = jax.nn.log_sigmoid(fr)
    xs = (to_chunks(q), to_chunks(k), to_chunks(v),
          to_chunks(ir[..., None])[..., 0], to_chunks(lf[..., None])[..., 0])

    chunk_fn = jax.checkpoint(
        lambda st, inp: _mlstm_chunk(st, inp, dh ** -0.5),
        policy=jax.checkpoint_policies.nothing_saveable,
    )
    state, hs = jax.lax.scan(chunk_fn, state, xs)       # hs: (n_chunks,B,H,ch,dh)
    y = jnp.moveaxis(hs, 0, 2).reshape(b, h, sp, dh)    # (B,H,S,dh)
    y = jnp.moveaxis(y, 1, 2)[:, :s].reshape(b, s, h * dh).astype(dt)
    y = y * jax.nn.silu(x @ p["w_gate"].astype(dt))
    return y @ p["w_out"].astype(dt), state


def mlstm_step(p: dict, x: jax.Array, cfg: ModelConfig, state):
    """x: (B,D) one token -> (y (B,D), state).  O(1) per-step cell (the
    chunkwise form and this cell share the same (C, n, m) state contract —
    validated in tests)."""
    b, d = x.shape
    h, dh = cfg.n_heads, cfg.dh
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, h, dh).astype(jnp.float32)
    k = (x @ p["wk"].astype(dt)).reshape(b, h, dh).astype(jnp.float32)
    v = (x @ p["wv"].astype(dt)).reshape(b, h, dh).astype(jnp.float32)
    ir = (x @ p["wi"].astype(dt)).astype(jnp.float32)
    fr = (x @ p["wf"].astype(dt)).astype(jnp.float32)
    state, hh = _mlstm_cell(state, (q, k, v, ir, fr))
    y = hh.reshape(b, h * dh).astype(dt)
    y = y * jax.nn.silu(x @ p["w_gate"].astype(dt))
    return y @ p["w_out"].astype(dt), state


# ----------------------------------------------------------------------
# sLSTM (scalar memory with recurrent hidden mixing, per head)
# ----------------------------------------------------------------------
def slstm_init(key, cfg: ModelConfig) -> dict:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.dh
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "wz": jax.random.normal(ks[0], (d, h * dh), jnp.float32) * s,
        "wi": jax.random.normal(ks[1], (d, h * dh), jnp.float32) * s,
        "wf": jax.random.normal(ks[2], (d, h * dh), jnp.float32) * s,
        "wo": jax.random.normal(ks[3], (d, h * dh), jnp.float32) * s,
        # recurrent block-diagonal mixing (per head)
        "r": jax.random.normal(ks[4], (h, dh, dh), jnp.float32) * dh ** -0.5,
        "w_out": jax.random.normal(ks[5], (h * dh, d), jnp.float32) * (h * dh) ** -0.5,
    }


def slstm_state(b: int, cfg: ModelConfig, dtype=jnp.float32):
    h, dh = cfg.n_heads, cfg.dh
    z = jnp.zeros((b, h, dh), dtype)
    return {"c": z, "n": z, "h": z, "m": jnp.full((b, h, dh), -1e30, dtype)}


def _slstm_cell(p_r, state, zifo):
    z_in, i_in, f_in, o_in = zifo                        # (B,H,dh) pre-activations
    c, n, hid, m = state["c"], state["n"], state["h"], state["m"]
    rec = jnp.einsum("bhd,hde->bhe", hid, p_r)
    z = jnp.tanh(z_in + rec)
    o = jax.nn.sigmoid(o_in + rec)
    logf = jax.nn.log_sigmoid(f_in + rec)
    i_raw = i_in + rec
    m_new = jnp.maximum(logf + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(logf + m - m_new)
    c = f_g * c + i_g * z
    n = f_g * n + i_g
    hid = o * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": hid, "m": m_new}, hid


def slstm_seq(p: dict, x: jax.Array, cfg: ModelConfig, state=None):
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.dh
    dt = x.dtype
    pre = [
        (x @ p[w].astype(dt)).reshape(b, s, h, dh).astype(jnp.float32)
        for w in ("wz", "wi", "wf", "wo")
    ]
    if state is None:
        state = slstm_state(b, cfg)
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in pre)
    cell = lambda st, v: _slstm_cell(p["r"], st, v)  # noqa: E731
    ch = 256
    if s % ch == 0 and s > ch:
        # time-chunked remat (see mamba_seq): bounds backward residuals
        nck = s // ch
        xs_c = tuple(v.reshape(nck, ch, *v.shape[1:]) for v in xs)
        chunk = jax.checkpoint(
            lambda st, inp: jax.lax.scan(cell, st, inp),
            policy=jax.checkpoint_policies.nothing_saveable,
        )
        state, hs = jax.lax.scan(chunk, state, xs_c)
        hs = hs.reshape(s, *hs.shape[2:])
    else:
        state, hs = jax.lax.scan(cell, state, xs)
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, h * dh).astype(dt)
    return y @ p["w_out"].astype(dt), state


def slstm_step(p: dict, x: jax.Array, cfg: ModelConfig, state):
    y, state = slstm_seq(p, x[:, None, :], cfg, state)
    return y[:, 0], state


# ----------------------------------------------------------------------
# Mamba-style selective SSM (S6) — the hymba parallel head
# ----------------------------------------------------------------------
def mamba_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = d            # inner dim of the parallel SSM path
    n = cfg.ssm_state
    r = max(1, d // 16)
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    return {
        "w_in": jax.random.normal(ks[0], (d, 2 * di), jnp.float32) * s,
        "conv": jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32) * 0.1,
        "w_dt1": jax.random.normal(ks[2], (di, r), jnp.float32) * di ** -0.5,
        "w_dt2": jax.random.normal(ks[3], (r, di), jnp.float32) * r ** -0.5,
        "dt_bias": jnp.zeros(di),
        "w_bc": jax.random.normal(ks[4], (di, 2 * n), jnp.float32) * di ** -0.5,
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones(di),
        "w_out": jax.random.normal(ks[6], (di, d), jnp.float32) * di ** -0.5,
    }


def mamba_state(b: int, cfg: ModelConfig, dtype=jnp.float32):
    di, n, kc = cfg.d_model, cfg.ssm_state, cfg.ssm_conv
    return {
        "h": jnp.zeros((b, di, n), dtype),
        "conv": jnp.zeros((b, kc - 1, di), dtype),   # trailing inputs for the conv
    }


def _causal_conv(x: jax.Array, w: jax.Array, prefix: jax.Array):
    """Depthwise causal conv. x: (B,S,Di), w: (K,Di), prefix: (B,K-1,Di)."""
    kc = w.shape[0]
    xp = jnp.concatenate([prefix, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(kc))
    new_prefix = xp[:, xp.shape[1] - (kc - 1) :, :] if kc > 1 else prefix
    return out, new_prefix


def mamba_seq(p: dict, x: jax.Array, cfg: ModelConfig, state=None):
    b, s, d = x.shape
    n = cfg.ssm_state
    dt_ = x.dtype
    if state is None:
        state = mamba_state(b, cfg)
    xz = x @ p["w_in"].astype(dt_)
    x_in, z = jnp.split(xz, 2, axis=-1)                   # (B,S,Di) each
    x_c, conv_state = _causal_conv(
        x_in.astype(jnp.float32), p["conv"], state["conv"].astype(jnp.float32)
    )
    x_c = jax.nn.silu(x_c)
    dt = jax.nn.softplus(x_c @ p["w_dt1"] @ p["w_dt2"] + p["dt_bias"])  # (B,S,Di)
    bc = x_c @ p["w_bc"]                                  # (B,S,2N)
    b_in, c_out = bc[..., :n], bc[..., n:]
    a = -jnp.exp(p["a_log"])                              # (Di, N)

    def step(h, inp):
        xt, dtt, bt, ct = inp                             # (B,Di),(B,Di),(B,N),(B,N)
        da = jnp.exp(dtt[..., None] * a)                  # (B,Di,N)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    xs = tuple(
        jnp.moveaxis(v, 1, 0) for v in (x_c, dt, b_in, c_out)
    )
    # time-chunked remat: the backward otherwise stacks the (B, Di, N) state
    # per step; chunk boundaries bound the saved residuals to S/CH states
    ch = 256
    if s % ch == 0 and s > ch:
        nck = s // ch
        xs_c = tuple(v.reshape(nck, ch, *v.shape[1:]) for v in xs)

        def chunk(hc, inp_c):
            return jax.lax.scan(step, hc, inp_c)

        chunk = jax.checkpoint(chunk, policy=jax.checkpoint_policies.nothing_saveable)
        h_final, ys = jax.lax.scan(chunk, state["h"].astype(jnp.float32), xs_c)
        ys = ys.reshape(s, *ys.shape[2:])
    else:
        h_final, ys = jax.lax.scan(step, state["h"].astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1) + p["d_skip"] * x_c        # (B,S,Di)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(dt_)
    out = y @ p["w_out"].astype(dt_)
    return out, {"h": h_final, "conv": conv_state}


def mamba_step(p: dict, x: jax.Array, cfg: ModelConfig, state):
    y, state = mamba_seq(p, x[:, None, :], cfg, state)
    return y[:, 0], state
