"""Checkpointing: sharded, atomic, async, resumable.

Layout:  <dir>/step_<N>/
            manifest.json        — step, leaf paths/shapes/dtypes, status
            <leaf-path>.npy      — one array per leaf (gathered)

* atomicity: written to ``step_<N>.tmp`` then os.rename'd — a crash leaves
  either the old or the new checkpoint, never a torn one;
* async: ``save_async`` snapshots to host memory on the caller's thread
  (device->host copy), then writes on a background thread so the train loop
  keeps stepping;
* retention: ``keep`` most-recent checkpoints;
* resume: ``latest_step`` + ``restore`` (optionally onto a *different* mesh —
  elastic restarts re-place the gathered arrays with the new sharding; see
  dist/elastic.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["Checkpointer"]


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True,
             meta: Optional[Dict[str, Any]] = None):
        """``meta`` is a small JSON-serialisable dict stored in the manifest
        alongside the leaves — e.g. a live-corpus generation counter, so a
        restored serving engine knows which corpus version the snapshot
        captured (:meth:`read_meta`)."""
        host = [(k, np.asarray(v)) for k, v in _flatten(tree)]
        if blocking:
            self._write(step, host, meta)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta))
            self._thread.start()

    def save_async(self, step: int, tree: Any,
                   meta: Optional[Dict[str, Any]] = None):
        self.save(step, tree, blocking=False, meta=meta)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host, meta: Optional[Dict[str, Any]] = None):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest: Dict[str, Any] = {"step": step, "leaves": {},
                                    "meta": meta or {}}
        for key, arr in host:
            fn = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][key] = {
                "file": fn,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # ------------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                    out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def read_meta(self, step: int) -> Dict[str, Any]:
        """Manifest ``meta`` dict for one step (``{}`` for checkpoints
        written before meta support)."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f).get("meta", {})

    def latest_meta(self) -> Dict[str, Any]:
        """``read_meta`` of the most recent checkpoint (``{}`` when the
        directory holds none) — how a fleet restores its manifest without
        tracking step numbers."""
        s = self.latest_step()
        return self.read_meta(s) if s is not None else {}

    def restore(
        self,
        step: int,
        target_tree: Any,
        shardings: Any = None,
    ) -> Any:
        """Restore into the structure of ``target_tree``.  If ``shardings``
        (a matching tree of NamedSharding) is given, arrays are placed with
        those shardings — this is the elastic-resume path (the saved mesh
        need not equal the restoring mesh)."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        keys = [k for k, _ in _flatten(target_tree)]
        missing = [k for k in keys if k not in manifest["leaves"]]
        if missing:
            raise ValueError(f"checkpoint missing leaves: {missing[:5]}")
        arrays = {
            k: np.load(os.path.join(d, manifest["leaves"][k]["file"])) for k in keys
        }
        shard_flat = _flatten(shardings) if shardings is not None else None
        leaves = []
        for i, k in enumerate(keys):
            a = arrays[k]
            if shard_flat is not None:
                leaves.append(jax.device_put(a, shard_flat[i][1]))
            else:
                leaves.append(jax.numpy.asarray(a))
        treedef = jax.tree_util.tree_structure(target_tree)
        return jax.tree_util.tree_unflatten(treedef, leaves)
