"""Elastic mesh replanning: recompute the mesh after losing hosts.

When the heartbeat monitor declares a host dead, the surviving job
restarts on fewer chips.  Model parallelism is pinned by the checkpoint's
weight shards (``model_parallel`` must divide every sharded dim the same
way), so only the data dimension absorbs the loss: ``replan_mesh`` keeps
the model axis and gives the remaining chips to data — 512 chips at
TP=16 is a (32, 16) mesh; lose a 32-chip host and it replans to
(30, 16).  Restore then lays existing checkpoint shards onto the new
mesh (``Checkpointer.restore(..., shardings=...)`` resharding on load).

``multi_pod`` preserves the physical pod axis (256 chips per pod) so ICI
vs DCI collectives keep their cost structure after the replan.
"""
from __future__ import annotations

from typing import Tuple

__all__ = ["replan_mesh", "POD_CHIPS"]

POD_CHIPS = 256          # one 16x16 pod


def replan_mesh(
    n_devices: int, model_parallel: int, multi_pod: bool = False
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Mesh (shape, axis_names) for ``n_devices`` at fixed model parallelism.

    Raises ``ValueError`` when the device count cannot host the pinned
    model axis (fewer chips than ``model_parallel``, or not divisible).
    """
    if model_parallel < 1:
        raise ValueError(f"model_parallel must be >= 1, got {model_parallel}")
    if n_devices < model_parallel:
        raise ValueError(
            f"{n_devices} devices cannot host model_parallel={model_parallel}"
        )
    if n_devices % model_parallel:
        raise ValueError(
            f"{n_devices} devices not divisible by model_parallel={model_parallel}"
        )
    if multi_pod:
        if n_devices % POD_CHIPS or POD_CHIPS % model_parallel:
            raise ValueError(
                f"multi_pod replan needs whole {POD_CHIPS}-chip pods that "
                f"fit model_parallel={model_parallel}; got {n_devices} devices"
            )
        pods = n_devices // POD_CHIPS
        return (pods, POD_CHIPS // model_parallel, model_parallel), (
            "pod", "data", "model",
        )
    return (n_devices // model_parallel, model_parallel), ("data", "model")
