"""repro.dist — the distribution layer (DESIGN.md §5).

Four small modules, one concern each:

* :mod:`~repro.dist.sharding`    — param-path -> PartitionSpec rules,
  plus pytree-level param/batch/cache sharding helpers for a mesh.
* :mod:`~repro.dist.collectives` — int8-compressed allreduce (with and
  without error feedback) and the exact top-k shard merge.
* :mod:`~repro.dist.fault`       — heartbeat + straggler monitors
  emitting :class:`FaultEvent` records for the launch driver.
* :mod:`~repro.dist.elastic`     — mesh replanning after host loss.

Importing this package also installs the ``jax.shard_map`` alias on jax
versions that only ship ``jax.experimental.shard_map``.
"""
from .collectives import (
    compressed_psum,
    merge_topk,
    psum_with_error_feedback,
    shard_map,
)
from .elastic import replan_mesh
from .fault import FaultEvent, HeartbeatMonitor, StragglerMitigator
from .sharding import (
    batch_sharding,
    cache_sharding,
    data_axes,
    param_sharding,
    param_spec,
)

__all__ = [
    "FaultEvent",
    "HeartbeatMonitor",
    "StragglerMitigator",
    "batch_sharding",
    "cache_sharding",
    "compressed_psum",
    "data_axes",
    "merge_topk",
    "merge_topk_unique",
    "param_sharding",
    "param_spec",
    "psum_with_error_feedback",
    "replan_mesh",
    "shard_map",
]
