"""Fault machinery: dead-host and straggler detection for the train loop.

Multi-host JAX has no built-in failure detector — a dead host hangs the
next collective.  The driver therefore runs two cheap host-side monitors
between steps and reacts (checkpoint + elastic replan, see
``dist.elastic``) *before* the hang:

* :class:`HeartbeatMonitor` — each host calls ``beat`` every step;
  ``check`` flags hosts whose last beat is older than ``timeout``.  A
  host is flagged **once** per death (no log spam while it stays down)
  and returns to the alive set if it beats again.
* :class:`StragglerMitigator` — tracks a per-host EMA of step wall time
  and flags hosts whose EMA exceeds ``threshold`` x the median of the
  other hosts (one-shot, like the heartbeat).  A consistent straggler
  gates every synchronous collective, so flagging at 2x is already late;
  ``min_observations`` suppresses cold-start noise (first steps include
  compilation).

Both emit :class:`FaultEvent` records consumed by the launch driver.
Detection is deliberately decoupled from mitigation: the monitors only
*observe*, the driver decides (re-mesh, drop host, alert).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

__all__ = ["FaultEvent", "HeartbeatMonitor", "StragglerMitigator"]


@dataclasses.dataclass
class FaultEvent:
    host: int
    step: int
    kind: str            # "dead_host" | "straggler"
    detail: str = ""

    def __str__(self) -> str:
        return f"[{self.kind}] host {self.host} at step {self.step}: {self.detail}"


class HeartbeatMonitor:
    """Dead-host detection from per-step heartbeats."""

    def __init__(self, n_hosts: int, timeout: float = 60.0):
        self.n_hosts = n_hosts
        self.timeout = timeout
        self._last: Dict[int, float] = {}
        self._flagged: set = set()

    def beat(self, host: int, now: Optional[float] = None) -> None:
        self._last[host] = time.monotonic() if now is None else now
        self._flagged.discard(host)          # a beating host is alive again

    @property
    def alive(self) -> List[int]:
        return [h for h in range(self.n_hosts) if h not in self._flagged]

    def check(self, step: int, now: Optional[float] = None) -> List[FaultEvent]:
        now = time.monotonic() if now is None else now
        events = []
        for h in range(self.n_hosts):
            if h in self._flagged:
                continue
            # a host that has NEVER beaten is baselined at its first check —
            # dead-from-startup hosts get flagged one timeout later instead
            # of being invisible forever
            age = now - self._last.setdefault(h, now)
            if age > self.timeout:
                self._flagged.add(h)
                events.append(FaultEvent(
                    h, step, "dead_host",
                    f"no heartbeat for {age:.1f}s (timeout {self.timeout:.1f}s)",
                ))
        return events


class StragglerMitigator:
    """Per-host step-time EMA with threshold-based one-shot flagging."""

    def __init__(
        self,
        n_hosts: int,
        threshold: float = 2.0,
        decay: float = 0.8,
        min_observations: int = 8,
    ):
        self.n_hosts = n_hosts
        self.threshold = threshold
        self.decay = decay
        self.min_observations = min_observations
        self._ema: Dict[int, float] = {}
        self._count: Dict[int, int] = {}
        self._flagged: set = set()

    def record(self, host: int, step_time: float) -> None:
        prev = self._ema.get(host)
        self._ema[host] = (
            step_time if prev is None
            else self.decay * prev + (1.0 - self.decay) * step_time
        )
        self._count[host] = self._count.get(host, 0) + 1

    def check(self, step: int) -> List[FaultEvent]:
        seen = [h for h in self._ema if self._count[h] >= self.min_observations]
        events = []
        for h in seen:
            if h in self._flagged:
                continue
            others = sorted(self._ema[o] for o in seen if o != h)
            if not others:
                continue
            ref = others[len(others) // 2]       # median of the other hosts
            if ref > 0 and self._ema[h] > self.threshold * ref:
                self._flagged.add(h)
                events.append(FaultEvent(
                    h, step, "straggler",
                    f"step-time EMA {self._ema[h]:.3f}s vs median {ref:.3f}s "
                    f"(threshold {self.threshold:.1f}x)",
                ))
        return events
