"""Compressed cross-shard collectives (int8 allreduce + top-k merge).

Gradient allreduce dominates step time once the mesh spans hosts, and the
payload is the full parameter footprint per step.  ``compressed_psum``
cuts the wire bytes ~4x by quantising each shard to int8 with one fp32
scale per shard before the collective:

    scale_i = max|x_i| / 127          (per shard i)
    q_i     = round(x_i / scale_i)    in [-127, 127], int8
    mean    = (1/n) * sum_i q_i * scale_i

The wire format is the int8 payload plus one scalar per shard (a ring
all-gather of int8 moves the same bytes as reduce-scatter + all-gather at
int8; a raw psum cannot sum values carrying different scales).  Here the
reduction is expressed as a ``psum`` of the locally *dequantised* payload
— identical arithmetic, and it lets shard_map's replication checker infer
the replicated output; a production kernel would move the int8 bytes.
The result is the *mean* over the axis (the gradient convention), not the
sum.

Plain quantisation biases training: the per-step rounding error
``e_i = x_i - q_i*scale_i`` (|e_i| <= scale_i/2) is lost each round.
``psum_with_error_feedback`` carries it instead (Seide et al. 2014;
Karimireddy et al. 2019 "EF-SGD"):

    c_t   = g_t + e_{t-1}        # add residual before quantising
    out_t = mean_i(Q(c_t))       # compressed reduce of the compensated grad
    e_t   = c_t - Q(c_t)         # local residual, carried to t+1

Telescoping: sum_t Q(c_t) = sum_t g_t + e_0 - e_T, so the accumulated
update converges to the exact mean at O(scale/T) — quantisation error is
deferred, never dropped, which is the property the optimizer needs.

All entry points are ``jax.shard_map``-compatible: call them from inside
a shard-mapped function with the mesh axis name.  ``merge_topk`` is the
host-side counterpart used by the sharded ANN query path: each corpus
shard returns its local top-k and the reduction is a concat + re-top-k
(exact, associative — merging shard-local top-k's loses nothing because
any global top-k element is in its own shard's top-k).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# jax<0.5 only ships shard_map under jax.experimental; alias the modern
# ``jax.shard_map`` spelling and re-export it so code that imports
# repro.dist never depends on jax-import order elsewhere in the process.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map

    jax.shard_map = shard_map

__all__ = ["compressed_psum", "psum_with_error_feedback", "merge_topk",
           "merge_topk_unique", "shard_map"]


def _quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-shard int8 quantisation: (q, scale), x ~= q * scale."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _reduced_mean(q: jax.Array, scale: jax.Array, axis_name: str) -> jax.Array:
    deq = q.astype(jnp.float32) * scale              # shard's int8 contribution
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return jax.lax.psum(deq, axis_name) / n


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-quantised allreduce-mean over ``axis_name``.

    Error is bounded by the largest shard's quantisation step:
    |out - mean| <= max_i(scale_i) / 2.  Use inside ``jax.shard_map``.
    """
    q, scale = _quantize_int8(x)
    return _reduced_mean(q, scale, axis_name)


def psum_with_error_feedback(
    g: jax.Array, err: jax.Array, axis_name: str
) -> Tuple[jax.Array, jax.Array]:
    """Compressed allreduce-mean with a carried quantisation residual.

    Returns ``(mean, new_err)``; feed ``new_err`` back on the next call so
    repeated reductions converge to the exact mean (see module docstring).
    The residual keeps a leading singleton shard axis so it round-trips
    through ``shard_map`` with ``out_specs=P(axis)`` unchanged.
    """
    comp = g + err
    q, scale = _quantize_int8(comp)
    new_err = comp - q.astype(jnp.float32) * scale   # includes clip error
    return _reduced_mean(q, scale, axis_name), new_err[None]


def merge_topk(
    dists: np.ndarray, ids: np.ndarray, k: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Merge per-shard top-k results into a global top-k.

    ``dists``/``ids``: (n_shards, B, k_i) with -1 ids / +inf dists padding
    invalid slots (ids are already global).  Returns (B, k) sorted by
    ascending distance, -1/inf padded — the same contract as
    ``index.flat.l2_topk``.
    """
    d = np.concatenate(list(dists), axis=1).astype(np.float32)   # (B, sum k_i)
    i = np.concatenate(list(ids), axis=1)
    if d.shape[1] < k:                       # fewer candidates than k: pad
        b, pad = d.shape[0], k - d.shape[1]
        d = np.concatenate([d, np.full((b, pad), np.inf, np.float32)], axis=1)
        i = np.concatenate([i, np.full((b, pad), -1, i.dtype)], axis=1)
    d = np.where(i < 0, np.inf, d)
    # canonical (distance-bits, column) composite key, like IVFIndex.search:
    # squared-L2 distances are non-negative, so the f32 bit pattern sorts
    # like the float and equal distances break ties by column (= shard
    # order) — deterministic at the k boundary even on tie-heavy corpora,
    # while argpartition keeps the merge o(C log C) as n_shards*k grows
    key = (
        np.ascontiguousarray(d).view(np.int32).astype(np.int64) << 32
    ) | np.arange(d.shape[1], dtype=np.int64)[None, :]
    if d.shape[1] > k:
        part = np.argpartition(key, k - 1, axis=1)[:, :k]
        inner = np.argsort(np.take_along_axis(key, part, axis=1), axis=1)
        order = np.take_along_axis(part, inner, axis=1)
    else:
        order = np.argsort(key, axis=1)[:, :k]
    rows = np.arange(d.shape[0])[:, None]
    out_d, out_i = d[rows, order], i[rows, order]
    out_i = np.where(np.isinf(out_d), -1, out_i).astype(np.int32)
    return out_d, out_i


_PAD_ID = np.int64(np.iinfo(np.int32).max)   # sorts after every real id


def merge_topk_unique(
    dists: np.ndarray, ids: np.ndarray, k: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Merge candidate lists into a global top-k with de-duplication.

    Same (n_lists, B, k_i) -> (B, k) contract as :func:`merge_topk`, with two
    differences that make it the per-disjunct DNF merge:

    * the composite key is ``(distance-bits, global id)`` — equal distances
      break ties by the *lowest id*, matching ``jax.lax.top_k``'s
      lowest-index-first rule on a whole-corpus scan, so an exact-tier
      per-clause union reproduces the whole-predicate scan bit-for-bit;
    * an id appearing in several lists (a row matching two disjuncts) is
      kept exactly once, at its best (lowest-key) occurrence — approximate
      tiers may score the same id differently per clause.
    """
    d = np.concatenate(list(dists), axis=1).astype(np.float32)   # (B, sum k_i)
    i = np.asarray(np.concatenate(list(ids), axis=1))
    if d.shape[1] < k:
        b, pad = d.shape[0], k - d.shape[1]
        d = np.concatenate([d, np.full((b, pad), np.inf, np.float32)], axis=1)
        i = np.concatenate([i, np.full((b, pad), -1, i.dtype)], axis=1)
    d = np.where(i < 0, np.inf, d)
    iid = np.where(i < 0, _PAD_ID, i.astype(np.int64))
    key = (
        np.ascontiguousarray(d).view(np.int32).astype(np.int64) << 32
    ) | iid
    # de-dup: sort each row by (id, key), mark every non-first occurrence of
    # an id, and neutralise those slots before the top-k selection
    order = np.lexsort((key, iid))                       # (B, C) along axis -1
    rows = np.arange(d.shape[0])[:, None]
    s_iid = iid[rows, order]
    dup_sorted = np.zeros_like(s_iid, dtype=bool)
    dup_sorted[:, 1:] = (s_iid[:, 1:] == s_iid[:, :-1]) & (s_iid[:, 1:] != _PAD_ID)
    dup = np.zeros_like(dup_sorted)
    dup[rows, order] = dup_sorted
    d = np.where(dup, np.inf, d)
    i = np.where(dup, -1, i)
    key = np.where(dup, np.iinfo(np.int64).max, key)
    if d.shape[1] > k:
        part = np.argpartition(key, k - 1, axis=1)[:, :k]
        inner = np.argsort(np.take_along_axis(key, part, axis=1), axis=1)
        sel = np.take_along_axis(part, inner, axis=1)
    else:
        sel = np.argsort(key, axis=1)[:, :k]
    out_d, out_i = d[rows, sel], i[rows, sel]
    out_i = np.where(np.isinf(out_d), -1, out_i).astype(np.int32)
    return out_d, out_i
