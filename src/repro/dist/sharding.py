"""Sharding rules: param path -> PartitionSpec (DESIGN.md §5).

One rule table drives every layer family.  Naming convention (matches the
param dicts built in ``models/model.py`` / ``models/layers.py``):

* **column-parallel** (``wq``/``wk``/``wv``/``wi``/``w_gate``/``w_up``/
  ``w_in``/``lm_head``): the *output* feature dim is sharded on the model
  axis, the *input* dim carries the FSDP (data-axes) shard — so the matmul
  ``x @ w`` needs no collective on its output and the weight is
  all-gathered along data only.
* **row-parallel** (``wo``/``w_down``/``w_out``): the *input* dim is
  sharded on the model axis (consuming the column-parallel activation
  shard directly), the output dim carries the FSDP shard; the matmul's
  partial sums are reduced by the layer's psum.
* **expert-parallel MoE** (same names, one extra leading expert dim): the
  expert dim takes the model axis (each model shard owns ``E/n_model``
  experts), the within-expert input dim takes the data axes, the output
  dim is replicated.
* **vocab-sharded embedding** (``embed``: ``(V, d)`` vocab on model;
  ``lm_head``: ``(d, V)`` is column-parallel, which puts vocab on model
  too — the two stay consistent under weight tying).
* **everything else** (norm scales/biases, conv kernels, SSM state
  projections we don't recognise) is replicated — small tensors where
  collective latency would dominate any memory win.

Leading *stacked-layer* dims (``layers/...`` params are vmapped over
depth; xLSTM ``blocks/mlstm/...`` adds a second group-interleave dim) are
never sharded: the layer scan indexes them sequentially.

``param_spec`` is the pure rule (unit-testable, mesh-free);
``param_sharding`` applies it to a whole param pytree on a concrete mesh
with a divisibility guard — any dim the mesh can't split evenly falls
back to replicated rather than erroring, so reduced/smoke configs run on
any device count.
"""
from __future__ import annotations

import math
from typing import Any, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "param_spec",
    "param_sharding",
    "batch_sharding",
    "cache_sharding",
    "data_axes",
]

# matmul weights by the convention above; anything else replicates
_COLUMN_PARALLEL = {"wq", "wk", "wv", "wi", "w_gate", "w_up", "w_in", "lm_head"}
_ROW_PARALLEL = {"wo", "w_down", "w_out"}
# subtrees whose leaves carry a leading stacked-layer dim (vmapped init)
_STACKED_ROOTS = {"layers", "blocks", "enc_layers"}


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All mesh axes that are not the tensor-parallel axis ("model").

    These jointly act as the FSDP/data-parallel dimension: batch sharding
    and the weight-shard dim of the param rules both use the full tuple,
    so a (pod, data, model) mesh shards over pod x data without any rule
    knowing how many data-like axes exist.
    """
    return tuple(a for a in mesh.axis_names if a != "model")


def param_spec(
    path: str,
    shape: Sequence[int],
    data_axes: Tuple[str, ...],
    model_axis: str,
    layer_axis: int,
) -> P:
    """PartitionSpec for one parameter.

    ``path`` is the "/"-joined tree path (only the final name is matched
    against the rule table), ``layer_axis`` is the number of leading
    stacked-layer dims to leave unsharded.
    """
    name = path.split("/")[-1]
    lead = (None,) * layer_axis
    rest = len(shape) - layer_axis

    if name == "embed" and layer_axis == 0 and rest == 2:
        return P(model_axis, None)                       # vocab-sharded
    if name in _COLUMN_PARALLEL:
        if rest == 2:
            return P(*lead, data_axes, model_axis)
        if rest == 3:                                    # MoE (E, in, out)
            return P(*lead, model_axis, data_axes, None)
    if name in _ROW_PARALLEL:
        if rest == 2:
            return P(*lead, model_axis, data_axes)
        if rest == 3:                                    # MoE (E, in, out)
            return P(*lead, model_axis, None, data_axes)
    return P(*(None,) * len(shape))                      # replicated


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return math.prod(mesh.shape[a] for a in entry)
    return mesh.shape[entry]


def _guard_divisible(mesh: Mesh, spec: P, shape: Sequence[int]) -> P:
    """Replace any spec entry whose mesh extent doesn't divide the dim."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        out.append(entry if dim % _axis_size(mesh, entry) == 0 else None)
    return P(*out)


def _path_str(key_path) -> str:
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _layer_axis_for(path: str) -> int:
    parts = path.split("/")
    if not parts or parts[0] not in _STACKED_ROOTS:
        return 0
    # xLSTM interleave: blocks/mlstm/* is stacked (groups, every-1, ...)
    if parts[0] == "blocks" and "mlstm" in parts[1:-1]:
        return 2
    return 1


def param_sharding(mesh: Mesh, params: Any) -> Any:
    """NamedSharding pytree for a param (or optimizer-moment) pytree."""
    d_axes = data_axes(mesh)

    def one(key_path, leaf):
        path = _path_str(key_path)
        spec = param_spec(path, leaf.shape, d_axes, "model", _layer_axis_for(path))
        return NamedSharding(mesh, _guard_divisible(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, params)


def batch_sharding(mesh: Mesh, batch: Any, global_batch: int) -> Any:
    """Shard the leading batch dim over the data axes when it divides."""
    d_axes = data_axes(mesh)
    n_data = math.prod(mesh.shape[a] for a in d_axes)
    ok = global_batch >= n_data and global_batch % n_data == 0

    def one(leaf):
        if ok and leaf.ndim >= 1 and leaf.shape[0] == global_batch:
            return NamedSharding(mesh, P(d_axes, *(None,) * (leaf.ndim - 1)))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, batch)


def cache_sharding(mesh: Mesh, cache: Any, global_batch: int) -> Any:
    """Decode-cache sharding: find the batch dim and shard it on data.

    Cache leaves are stacked over layers/groups first (``(L, B, ...)``;
    xLSTM mlstm states are ``(G, every-1, B, ...)``), so the batch dim is
    the first *leading* dim equal to ``global_batch`` rather than axis 0.
    Everything else (heads, positions, head_dim) is replicated — the
    decode attention kernel reads its own layer slice locally.
    """
    d_axes = data_axes(mesh)
    n_data = math.prod(mesh.shape[a] for a in d_axes)
    ok = global_batch >= n_data and global_batch % n_data == 0

    def one(leaf):
        if ok:
            for ax in range(min(3, leaf.ndim)):
                if leaf.shape[ax] == global_batch:
                    spec = [None] * leaf.ndim
                    spec[ax] = d_axes
                    return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, cache)
