"""Hierarchical query tracing under the PR 4 two-ledger discipline.

A :class:`Tracer` records a tree of :class:`Span` objects per serving
request/batch.  Every span carries TWO strictly separated ledgers:

* **deterministic** — the span's name, position in the tree, sequential
  ``span_id``, and ``attrs`` (cache hit/miss deltas, decision groups,
  candidate counts, kernel dispatch counts...).  All of these derive from
  the trace + engine state only, so the same trace + seed reproduces the
  span tree bit-for-bit (:meth:`Tracer.deterministic_tree` is what replay
  tests compare).
* **wall** — measured seconds (``wall_s`` for the span body,
  ``wall_detail`` for named sub-costs such as per-kernel time).  Real
  clocks never leak into attrs.

``NULL_TRACER`` is the default no-op wired into the engines: the serving
path pays one context-manager enter/exit per instrumented stage and
nothing else when tracing is off.  :func:`span_summary` aggregates a
recorded tracer into a per-stage wall ranking — the roofline-in-practice
view the Pallas-kernel push (ROADMAP open item 2) prioritises from.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, Iterator, List, Optional

__all__ = ["Span", "Tracer", "NULL_TRACER", "span_summary"]


def _clean(v: Any) -> Any:
    """Coerce attr values to plain JSON-stable Python scalars (numpy ints/
    floats carried into attrs would still be deterministic, but their repr
    is not portable across dtypes)."""
    if hasattr(v, "item") and not isinstance(v, (str, bytes)):
        try:
            return v.item()
        except (TypeError, ValueError):
            return str(v)
    if isinstance(v, (list, tuple)):
        return [_clean(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _clean(x) for k, x in v.items()}
    return v


@dataclasses.dataclass
class Span:
    name: str
    span_id: int
    parent_id: int                                    # -1 for roots
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    children: List["Span"] = dataclasses.field(default_factory=list)
    # real ledger — excluded from deterministic comparisons
    wall_s: float = 0.0
    wall_detail: Dict[str, float] = dataclasses.field(default_factory=dict)

    def deterministic(self) -> Dict[str, Any]:
        """The replay-comparable projection: structure + attrs, no wall."""
        return {
            "name": self.name,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "attrs": {k: self.attrs[k] for k in sorted(self.attrs)},
            "children": [c.deterministic() for c in self.children],
        }

    def walk(self) -> Iterator["Span"]:
        yield self
        for c in self.children:
            yield from c.walk()


class Tracer:
    """Collects a forest of spans; one instance per traced run."""

    enabled = True

    def __init__(self):
        self.roots: List[Span] = []
        self._stack: List[Span] = []
        self._next_id = 0

    # -- recording ------------------------------------------------------
    def span(self, name: str, **attrs) -> "_SpanContext":
        """Open a child span of the current one (a root when none is open);
        use as a context manager.  ``attrs`` must be deterministic values."""
        return _SpanContext(self, name, attrs)

    def annotate(self, **attrs) -> None:
        """Attach deterministic attributes to the innermost open span."""
        if self._stack:
            self._stack[-1].attrs.update({k: _clean(v) for k, v in attrs.items()})

    def add_wall(self, key: str, seconds: float) -> None:
        """Accumulate a named wall-clock sub-cost (real ledger only)."""
        if self._stack:
            d = self._stack[-1].wall_detail
            d[key] = d.get(key, 0.0) + float(seconds)

    def current(self) -> Optional[Span]:
        return self._stack[-1] if self._stack else None

    def reset(self) -> None:
        self.roots, self._stack, self._next_id = [], [], 0

    # -- reading --------------------------------------------------------
    def spans(self) -> Iterator[Span]:
        for r in self.roots:
            yield from r.walk()

    def deterministic_tree(self) -> List[Dict[str, Any]]:
        """The full forest on the deterministic ledger only — bit-identical
        across replays of the same trace + seed + engine state."""
        return [r.deterministic() for r in self.roots]

    def write_jsonl(self, path) -> None:
        """One JSON object per span, depth-first; deterministic fields
        first, wall clock under a separate ``wall`` key."""
        with open(path, "w") as f:
            for sp in self.spans():
                f.write(json.dumps({
                    "span_id": sp.span_id,
                    "parent_id": sp.parent_id,
                    "name": sp.name,
                    "attrs": {k: sp.attrs[k] for k in sorted(sp.attrs)},
                    "wall": {
                        "s": round(sp.wall_s, 9),
                        "detail": {k: round(v, 9)
                                   for k, v in sorted(sp.wall_detail.items())},
                    },
                }) + "\n")

    def span_summary(self) -> List[Dict[str, Any]]:
        return span_summary(self)


class _SpanContext:
    __slots__ = ("_tracer", "_name", "_attrs", "_span", "_t0")

    def __init__(self, tracer: Tracer, name: str, attrs: Dict[str, Any]):
        self._tracer, self._name, self._attrs = tracer, name, attrs

    def __enter__(self) -> Span:
        tr = self._tracer
        parent = tr._stack[-1] if tr._stack else None
        sp = Span(
            name=self._name,
            span_id=tr._next_id,
            parent_id=parent.span_id if parent is not None else -1,
            attrs={k: _clean(v) for k, v in self._attrs.items()},
        )
        tr._next_id += 1
        (parent.children if parent is not None else tr.roots).append(sp)
        tr._stack.append(sp)
        self._span = sp
        self._t0 = time.perf_counter()
        return sp

    def __exit__(self, *exc) -> bool:
        self._span.wall_s += time.perf_counter() - self._t0
        self._tracer._stack.pop()
        return False


class _NullSpanContext:
    """Shared no-op context: tracing off costs one enter/exit, no allocs."""

    __slots__ = ()

    def __enter__(self):
        return _NULL_SPAN

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = Span(name="", span_id=-1, parent_id=-1)
_NULL_CTX = _NullSpanContext()


class NullTracer(Tracer):
    """Do-nothing tracer — the engines' default, so instrumented code never
    branches on "is tracing on"."""

    enabled = False

    def span(self, name: str, **attrs):
        return _NULL_CTX

    def annotate(self, **attrs) -> None:
        pass

    def add_wall(self, key: str, seconds: float) -> None:
        pass


NULL_TRACER = NullTracer()


def span_summary(tracer: Tracer) -> List[Dict[str, Any]]:
    """Rank stages by wall time across a recorded tracer.

    One row per span name with ``count``, inclusive ``wall_s``, and
    exclusive ``self_s`` (inclusive minus children — the stage's own
    cost); per-kernel wall sub-costs recorded via ``add_wall`` surface as
    ``kernel:<name>`` pseudo-stages, so this ranking and
    ``launch/roofline.py`` score the same candidate list.  Sorted by
    ``self_s`` descending (ties broken by name for determinism of the
    row ORDER — the wall values themselves are the real ledger).
    """
    rows: Dict[str, Dict[str, Any]] = {}

    def bump(name: str, wall: float, self_s: float, count: int = 1) -> None:
        r = rows.setdefault(name, {"stage": name, "count": 0,
                                   "wall_s": 0.0, "self_s": 0.0})
        r["count"] += count
        r["wall_s"] += wall
        r["self_s"] += self_s

    for sp in tracer.spans():
        child_s = sum(c.wall_s for c in sp.children)
        bump(sp.name, sp.wall_s, max(sp.wall_s - child_s, 0.0))
        for key, s in sp.wall_detail.items():
            bump(key, s, s, count=0)
    out = sorted(rows.values(), key=lambda r: (-r["self_s"], r["stage"]))
    for r in out:
        r["wall_s"] = round(r["wall_s"], 6)
        r["self_s"] = round(r["self_s"], 6)
    return out
