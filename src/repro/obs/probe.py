"""Live recall probe: measured recall per (plan, backend, knob) class.

PR 5's routing head promises ``route_recall_target`` recall on routed
traffic, but nothing measured it on LIVE queries — labels come from the
offline fit distribution.  The probe closes that gap: a seeded fraction
of served requests is raced against the exact masked top-k oracle
(``FilteredANNEngine.ground_truth``, the same machinery ``label_query``
uses), and per-class online recall estimates accumulate with confidence
counts.

Sampling is **per-rid**: ``default_rng([seed, rid])`` decides each
request independently of arrival order or batch composition, so which
requests get probed — and therefore every probe counter — replays
bit-for-bit (the oracle race itself is deterministic: result ids and
ground-truth ids both are).  The wall cost of the oracle is real, which
is why the probe samples instead of racing everything.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import numpy as np

__all__ = ["RecallProbe"]


class RecallProbe:
    """Seeded-sampling online recall estimator.

    ``backend`` is anything with ``ground_truth(q, pred, k)`` or an
    ``engine`` attribute that has it (``ShardedANNEngine``); the runtime
    fills it in at ``run_trace`` time when left ``None``.  ``truth_fn``
    overrides the oracle entirely (tests inject known truths).
    """

    def __init__(self, backend=None, rate: float = 0.05, seed: int = 0,
                 truth_fn: Optional[Callable] = None):
        assert 0.0 <= rate <= 1.0
        self.backend = backend
        self.rate = float(rate)
        self.seed = int(seed)
        self.truth_fn = truth_fn
        self.n_seen = 0
        self.n_sampled = 0
        self._sum: Dict[str, float] = {}     # class key -> recall sum
        self._count: Dict[str, int] = {}     # class key -> samples

    # ------------------------------------------------------------------
    def should_sample(self, rid: int) -> bool:
        """Deterministic per-request coin flip, independent of order."""
        if self.rate >= 1.0:
            return True
        if self.rate <= 0.0:
            return False
        return bool(
            np.random.default_rng([self.seed, int(rid)]).random() < self.rate)

    @staticmethod
    def class_key(res) -> str:
        """(plan, backend, knob) key of a served PlannedResult — backends
        are always named by packaging (un-routed rows get the default
        (flat, exact)/(ivf, adapt) names)."""
        r = res.result
        return f"{r.strategy}/{r.backend}:{r.knob}"

    def _truth(self, query: np.ndarray, pred, k: int) -> np.ndarray:
        if self.truth_fn is not None:
            return np.asarray(self.truth_fn(query, pred, k))
        be = self.backend
        eng = getattr(be, "engine", be)      # sharded -> central engine
        return np.asarray(eng.ground_truth(query, pred, k))

    def observe(self, req, res) -> bool:
        """Called per served read request; returns True when it was probed.
        ``req`` is a RuntimeRequest, ``res`` its PlannedResult."""
        self.n_seen += 1
        if res is None or not self.should_sample(req.rid):
            return False
        from ..core.executors import recall_at_k

        truth = self._truth(np.atleast_2d(req.query), req.pred, req.k)
        rec = recall_at_k(res.result.ids, truth)
        key = self.class_key(res)
        self._sum[key] = self._sum.get(key, 0.0) + rec
        self._count[key] = self._count.get(key, 0) + 1
        self.n_sampled += 1
        return True

    # ------------------------------------------------------------------
    def estimates(self) -> Dict[str, Dict[str, Any]]:
        """Per-class ``{"recall": mean, "count": n}`` in sorted class
        order; fully deterministic under replay."""
        return {
            key: {"recall": round(self._sum[key] / self._count[key], 6),
                  "count": self._count[key]}
            for key in sorted(self._count)
        }

    def counters(self) -> Dict[str, Any]:
        """The probe's deterministic ledger (replay tests compare this)."""
        return {
            "rate": self.rate,
            "seed": self.seed,
            "n_seen": self.n_seen,
            "n_sampled": self.n_sampled,
            "classes": self.estimates(),
        }

    def publish(self, registry, **labels) -> None:
        """Export into a :class:`repro.obs.metrics.MetricsRegistry`."""
        registry.set_gauge("repro_probe_seen_total", self.n_seen, **labels)
        registry.set_gauge("repro_probe_sampled_total", self.n_sampled, **labels)
        for key, row in self.estimates().items():
            registry.set_gauge("repro_probe_recall", row["recall"],
                               cls=key, **labels)
            registry.set_gauge("repro_probe_samples", row["count"],
                               cls=key, **labels)

    def below(self, floor: float) -> Dict[str, float]:
        """Classes whose measured online recall sits under ``floor`` —
        the drift-guard hook (feed these to the feedback loop / alerts)."""
        return {
            key: row["recall"]
            for key, row in self.estimates().items()
            if row["recall"] < floor
        }
