"""repro.obs — observability for the serving path.

Three pieces, one discipline:

* :mod:`repro.obs.trace`   — hierarchical two-ledger spans: deterministic
  structure/attributes (replay-comparable) strictly separated from
  measured wall time.
* :mod:`repro.obs.metrics` — one :class:`MetricsRegistry` (counters /
  gauges / histograms with label sets, deterministic iteration) behind
  ``runtime.Telemetry``, ``fleet.FleetTelemetry``, and the engine's
  ``stats()`` publishers; Prometheus text exposition + JSON snapshot.
* :mod:`repro.obs.probe`   — a live recall probe racing a seeded sample
  of served queries against the exact masked top-k oracle, per
  (plan, backend, knob) class.
"""
from .metrics import (
    MetricsRegistry,
    publish_kernel_budget,
    publish_kernel_dispatch,
    publish_stats,
)
from .probe import RecallProbe
from .trace import NULL_TRACER, Span, Tracer, span_summary

__all__ = [
    "MetricsRegistry",
    "NULL_TRACER",
    "RecallProbe",
    "Span",
    "Tracer",
    "publish_kernel_budget",
    "publish_kernel_dispatch",
    "publish_stats",
    "span_summary",
]
