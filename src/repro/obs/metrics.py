"""One metrics registry for every serving layer.

Counters, gauges, and histograms with label sets; iteration order is
deterministic everywhere (metric names sorted, label sets sorted within a
metric), so two replays of the same trace produce byte-identical
snapshots and Prometheus expositions.  ``runtime.Telemetry`` and
``fleet.FleetTelemetry`` store their deterministic ledgers here (the
fleet shares ONE registry across tenants via a ``tenant`` label), and the
``publish_*`` helpers fold process-level sources — engine ``stats()``
dicts, kernel dispatch counters, the analytic kernel VMEM budget — into
the same namespace.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "MetricsRegistry",
    "publish_stats",
    "publish_kernel_dispatch",
    "publish_kernel_budget",
]

# seconds-scale latency buckets (virtual or wall)
DEFAULT_BUCKETS = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1, 1.0, 2.5,
)

LabelKey = Tuple[Tuple[str, str], ...]


def _fmt(v: float) -> str:
    """Prometheus sample value: integral floats print as ints, the rest as
    repr (shortest round-trip — deterministic for identical doubles)."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class MetricsRegistry:
    """Label-set metric store with deterministic iteration.

    Series are keyed by their sorted ``(label, value)`` tuple, so the same
    logical labels always address the same series regardless of call-site
    keyword order.
    """

    def __init__(self):
        # name -> {"kind", "help", "buckets"?, "series": {label_key: value}}
        self._metrics: Dict[str, Dict[str, Any]] = {}

    # -- recording ------------------------------------------------------
    @staticmethod
    def _key(labels: Dict[str, Any]) -> LabelKey:
        return tuple(sorted((str(k), str(v)) for k, v in labels.items()))

    def _metric(self, name: str, kind: str, help: str = "") -> Dict[str, Any]:
        m = self._metrics.get(name)
        if m is None:
            m = {"kind": kind, "help": help, "series": {}}
            self._metrics[name] = m
        elif m["kind"] != kind:
            raise ValueError(
                f"metric {name!r} already registered as {m['kind']}, not {kind}")
        if help and not m["help"]:
            m["help"] = help
        return m

    def inc(self, name: str, value: float = 1, help: str = "", **labels) -> None:
        """Add to a counter (``value=0`` pre-creates the series at zero, so
        fixed enumerations — plan names, SLO tiers — appear in snapshots
        before their first event)."""
        if value < 0:
            raise ValueError(f"counter {name!r} cannot decrease (got {value})")
        s = self._metric(name, "counter", help)["series"]
        key = self._key(labels)
        s[key] = s.get(key, 0) + value

    def set_gauge(self, name: str, value: float, help: str = "", **labels) -> None:
        s = self._metric(name, "gauge", help)["series"]
        s[self._key(labels)] = value

    def observe(self, name: str, value: float, help: str = "",
                buckets: Sequence[float] = DEFAULT_BUCKETS, **labels) -> None:
        m = self._metric(name, "histogram", help)
        m.setdefault("buckets", tuple(buckets))
        s = m["series"]
        key = self._key(labels)
        h = s.get(key)
        if h is None:
            h = {"count": 0, "sum": 0.0,
                 "bucket_counts": [0] * len(m["buckets"])}
            s[key] = h
        h["count"] += 1
        h["sum"] += float(value)
        for i, le in enumerate(m["buckets"]):
            if value <= le:
                h["bucket_counts"][i] += 1

    # -- reading --------------------------------------------------------
    def value(self, name: str, default: float = 0, **labels) -> float:
        m = self._metrics.get(name)
        if m is None:
            return default
        return m["series"].get(self._key(labels), default)

    def series(self, name: str, match: Optional[Dict[str, Any]] = None,
               ) -> List[Tuple[Dict[str, str], Any]]:
        """All (labels, value) pairs of a metric, sorted by label key;
        ``match`` filters to series whose labels contain every given pair."""
        m = self._metrics.get(name)
        if m is None:
            return []
        need = tuple(sorted((str(k), str(v)) for k, v in (match or {}).items()))
        out = []
        for key in sorted(m["series"]):
            if all(pair in key for pair in need):
                out.append((dict(key), m["series"][key]))
        return out

    def snapshot(self) -> Dict[str, Any]:
        """Deterministic JSON-able dump of every metric and series."""
        out: Dict[str, Any] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            series = []
            for key in sorted(m["series"]):
                v = m["series"][key]
                if m["kind"] == "histogram":
                    v = {"count": v["count"], "sum": v["sum"],
                         "buckets": {_fmt(le): c for le, c in
                                     zip(m["buckets"], v["bucket_counts"])}}
                series.append({"labels": dict(key), "value": v})
            out[name] = {"kind": m["kind"], "series": series}
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format, deterministically ordered."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m["help"]:
                lines.append(f"# HELP {name} {m['help']}")
            lines.append(f"# TYPE {name} {m['kind']}")
            for key in sorted(m["series"]):
                v = m["series"][key]
                if m["kind"] == "histogram":
                    cum = 0
                    for le, c in zip(m["buckets"], v["bucket_counts"]):
                        cum += c
                        lines.append(
                            f"{name}_bucket{self._labels(key, le=_fmt(le))} {cum}")
                    lines.append(
                        f"{name}_bucket{self._labels(key, le='+Inf')} {v['count']}")
                    lines.append(f"{name}_sum{self._labels(key)} {_fmt(v['sum'])}")
                    lines.append(f"{name}_count{self._labels(key)} {v['count']}")
                else:
                    lines.append(f"{name}{self._labels(key)} {_fmt(v)}")
        return "\n".join(lines) + ("\n" if lines else "")

    @staticmethod
    def _labels(key: LabelKey, **extra: str) -> str:
        pairs = list(key) + sorted(extra.items())
        if not pairs:
            return ""
        inner = ",".join(f'{k}="{_escape(v)}"' for k, v in pairs)
        return "{" + inner + "}"

    def clear(self) -> None:
        self._metrics.clear()


# ----------------------------------------------------------------------
# publishers: fold non-registry sources into the shared namespace
# ----------------------------------------------------------------------
def publish_stats(registry: MetricsRegistry, stats: Dict[str, Any],
                  prefix: str = "repro_engine", **labels) -> None:
    """Flatten a nested ``stats()`` dict into gauges: numeric leaves become
    ``<prefix>_<path.joined.by.underscores>``; non-numeric leaves skip."""
    def walk(path: str, node: Any) -> None:
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{path}_{k}" if path else str(k), node[k])
        elif isinstance(node, bool):
            registry.set_gauge(f"{prefix}_{path}", int(node), **labels)
        elif isinstance(node, (int, float)):
            registry.set_gauge(f"{prefix}_{path}", node, **labels)

    walk("", stats)


def publish_kernel_dispatch(registry: MetricsRegistry) -> None:
    """Mirror the process-global kernel dispatch counters/wall accumulated
    in ``repro.kernels.ops`` into gauges (gauges, not counters: the source
    is cumulative already)."""
    from ..kernels import ops

    for name, n in ops.dispatch_counts().items():
        registry.set_gauge("repro_kernel_dispatch_total", n, kernel=name)
    for name, s in ops.dispatch_wall().items():
        registry.set_gauge("repro_kernel_wall_seconds", s, kernel=name)


def publish_kernel_budget(registry: MetricsRegistry,
                          dims: Sequence[int] = (128, 256, 512)) -> None:
    """Register the analytic VMEM working set (``kernel_bench``'s fit
    check) so the obs snapshot carries the same per-kernel budget the
    roofline ranking uses."""
    from ..kernels.ops import vmem_working_set

    for d in dims:
        ws = vmem_working_set(d)
        k = f"masked_l2_d{d}"
        registry.set_gauge("repro_kernel_vmem_bytes", ws["total"], kernel=k)
        registry.set_gauge("repro_kernel_vmem_fits_16mib",
                           int(ws["fits_16MiB"]), kernel=k)
