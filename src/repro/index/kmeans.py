"""Mini-batch-free Lloyd k-means in JAX (used by IVF and graph construction).

jit-compiled; assignment is a dense distance matmul (MXU-friendly), update is
a segment-sum.  Empty clusters are re-seeded to the points currently farthest
from their centroid.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["kmeans", "assign"]


@partial(jax.jit, static_argnames=("chunk",))
def assign(x: jax.Array, centroids: jax.Array, chunk: int = 131072) -> jax.Array:
    """Nearest-centroid assignment, chunked over points."""
    n, d = x.shape
    c2 = jnp.sum(centroids * centroids, axis=1)

    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    xs = xp.reshape(-1, chunk, d)

    def step(_, xc):
        d2 = jnp.sum(xc * xc, 1, keepdims=True) + c2[None, :] - 2.0 * xc @ centroids.T
        return None, jnp.argmin(d2, axis=1).astype(jnp.int32)

    _, parts = jax.lax.scan(step, None, xs)
    return parts.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("k",), donate_argnums=())
def _lloyd_iter(x: jax.Array, centroids: jax.Array, k: int):
    a = assign(x, centroids)
    one = jnp.ones(x.shape[0], x.dtype)
    counts = jax.ops.segment_sum(one, a, num_segments=k)
    sums = jax.ops.segment_sum(x, a, num_segments=k)
    new_c = sums / jnp.maximum(counts, 1.0)[:, None]
    # re-seed empty clusters with the points farthest from their centroid
    d_own = jnp.sum((x - new_c[a]) ** 2, axis=1)
    far = jnp.argsort(-d_own)[:k]
    empty = counts < 1.0
    new_c = jnp.where(empty[:, None], x[far], new_c)
    return new_c, a


def kmeans(
    x: np.ndarray, k: int, iters: int = 10, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (centroids (k,d), assignment (n,))."""
    xj = jnp.asarray(x, jnp.float32)
    rng = np.random.default_rng(seed)
    init = xj[rng.choice(x.shape[0], size=k, replace=False)]
    c = init
    a = None
    for _ in range(iters):
        c, a = _lloyd_iter(xj, c, k)
    return np.asarray(c), np.asarray(a)
