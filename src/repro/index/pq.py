"""IVF-PQ index: coarse IVF lists + product-quantized codes + int8 ADC scan.

The memory story (the gateway to large corpora on fixed RAM): the scan
touches only

* the coarse centroids (L, d),
* per-subspace codebooks (M, n_codes, d/M),
* uint8 codes (N, M) in IVF-sorted order, and
* the id/offset layout —

so resident bytes per vector are ~M + 4 instead of 4·d.  The original
float32 vectors are kept ONLY for the optional exact re-rank of the top-R
ADC candidates and are charged separately (``rerank_bytes``): in a real
deployment that store lives on a slower tier (disk/host RAM) while the
structures ``memory_bytes()`` counts stay scan-resident.

Distance evaluation is asymmetric (ADC): per query, a (M, n_codes) table of
exact query-to-codeword squared distances is built once, quantized to uint8
(per-subspace base + one global scale — the FAISS-style fast-scan layout),
and candidate distances are integer lookup-table sums over the codes.  The
uint8 floor quantization only ever *under*-estimates: for any candidate

    0 <= decoded_distance - adc_distance() < M * scale

(the bound the Hypothesis property suite checks).  Exact re-rank then
rescores the top-R ADC survivors against the original vectors, so returned
distances are exact and the ADC approximation only decides *which* R
candidates get rescored.

Search is strictly per-row (one LUT per query, no cross-row arithmetic), so
results are bit-identical in any batch composition — the PR 2 discipline the
cross-backend conformance harness enforces — with the IVF-style composite
``(distance bits << 32) | candidate position`` sort keys making tie handling
deterministic too.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .kmeans import assign, kmeans

__all__ = ["IVFPQIndex"]


def _composite_topk(dist_key: np.ndarray, kk: int) -> np.ndarray:
    """Indices of the kk smallest int64 composite keys, ascending."""
    if dist_key.size <= kk:
        return np.argsort(dist_key, kind="stable")
    sel = np.argpartition(dist_key, kk - 1)[:kk]
    return sel[np.argsort(dist_key[sel], kind="stable")]


class IVFPQIndex:
    """Coarse IVF quantizer + per-subspace k-means codebooks + ADC scan."""

    def __init__(
        self,
        vectors: np.ndarray,
        n_lists: Optional[int] = None,
        m: Optional[int] = None,
        n_codes: int = 256,
        seed: int = 0,
        train_sample: int = 16384,
    ):
        self.vectors_np = np.ascontiguousarray(vectors, np.float32)
        self.n, self.dim = self.vectors_np.shape
        n = max(self.n, 1)
        self.n_lists = min(n_lists or max(8, int(np.sqrt(n))), n)
        # M subspaces: d/8 dims each by default (clamped so codes stay uint8
        # and every subspace is non-empty)
        self.m = min(m or max(1, self.dim // 8), max(self.dim, 1))
        self.dsub = int(np.ceil(self.dim / self.m)) if self.dim else 1
        self.n_codes = int(min(n_codes, 256, n))
        self.seed = seed
        self.train_sample = train_sample
        self.built = False

    # ------------------------------------------------------------------
    def _pad(self, x: np.ndarray) -> np.ndarray:
        """Zero-pad the feature axis to m * dsub (zeros contribute nothing
        to L2, so padded-space distances equal true distances)."""
        want = self.m * self.dsub
        if x.shape[1] == want:
            return x
        out = np.zeros((x.shape[0], want), np.float32)
        out[:, : x.shape[1]] = x
        return out

    def build(self, iters: int = 6) -> "IVFPQIndex":
        if self.n == 0:
            self.sorted_ids = np.empty(0, np.int32)
            self.codes = np.empty((0, self.m), np.uint8)
            self.centroids = np.zeros((0, self.dim), np.float32)
            self.codebooks = np.zeros((self.m, 1, self.dsub), np.float32)
            self.offsets = np.zeros(1, np.int64)
            self.radius_sq = np.zeros(self.m, np.float32)
            self.built = True
            return self
        # coarse quantizer: same sorted-list layout as IVFIndex
        c, a = kmeans(self.vectors_np, self.n_lists, iters=iters, seed=self.seed)
        self.centroids = c
        order = np.argsort(a, kind="stable")
        self.sorted_ids = order.astype(np.int32)
        counts = np.bincount(a, minlength=self.n_lists)
        self.offsets = np.zeros(self.n_lists + 1, np.int64)
        np.cumsum(counts, out=self.offsets[1:])
        # per-subspace codebooks trained on a fixed sample
        xp = self._pad(self.vectors_np)
        rng = np.random.default_rng(self.seed + 17)
        sample = (
            rng.choice(self.n, size=min(self.train_sample, self.n), replace=False)
            if self.n > self.train_sample else np.arange(self.n)
        )
        cbs = np.zeros((self.m, self.n_codes, self.dsub), np.float32)
        codes = np.zeros((self.n, self.m), np.uint8)
        self.radius_sq = np.zeros(self.m, np.float32)
        for j in range(self.m):
            sub = xp[:, j * self.dsub : (j + 1) * self.dsub]
            cb, _ = kmeans(sub[sample], self.n_codes, iters=iters, seed=self.seed + 1 + j)
            cbs[j] = cb
            code_j = np.asarray(assign(sub, cb))
            codes[:, j] = code_j.astype(np.uint8)
            # per-subspace quantization radius over the WHOLE corpus (the
            # encode/decode round-trip error bound the property suite checks)
            err = ((sub - cb[code_j]) ** 2).sum(1)
            self.radius_sq[j] = float(err.max()) if err.size else 0.0
        self.codebooks = cbs
        self.codes = codes[order]          # IVF-sorted, like sorted_vecs
        self.built = True
        return self

    # ------------------------------------------------------------------
    # encode / decode (property-test surface)
    # ------------------------------------------------------------------
    def encode(self, x: np.ndarray) -> np.ndarray:
        """(B, d) -> (B, M) uint8 nearest-codeword assignment."""
        assert self.built
        xp = self._pad(np.atleast_2d(np.asarray(x, np.float32)))
        out = np.zeros((xp.shape[0], self.m), np.uint8)
        for j in range(self.m):
            sub = xp[:, j * self.dsub : (j + 1) * self.dsub]
            d2 = ((sub[:, None, :] - self.codebooks[j][None]) ** 2).sum(-1)
            out[:, j] = np.argmin(d2, axis=1).astype(np.uint8)
        return out

    def decode(self, codes: np.ndarray) -> np.ndarray:
        """(B, M) uint8 -> (B, d) reconstructed vectors."""
        assert self.built
        codes = np.atleast_2d(codes)
        parts = [self.codebooks[j][codes[:, j]] for j in range(self.m)]
        return np.concatenate(parts, axis=1)[:, : self.dim].astype(np.float32)

    # ------------------------------------------------------------------
    # ADC machinery
    # ------------------------------------------------------------------
    def _lut(self, q: np.ndarray) -> Tuple[np.ndarray, np.ndarray, float]:
        """Exact (M, n_codes) query-to-codeword table + its uint8 form.

        Returns ``(lut8, base (M,), scale)`` with the floor-quantization
        invariant ``lut8*scale + base in (lut_f - scale, lut_f]``."""
        qs = self._pad(q[None])[0].reshape(self.m, self.dsub)
        lut_f = ((self.codebooks - qs[:, None, :]) ** 2).sum(-1)   # (M, n_codes)
        base = lut_f.min(axis=1)
        span = float((lut_f - base[:, None]).max())
        scale = max(span / 255.0, 1e-12)
        lut8 = np.minimum(
            np.floor((lut_f - base[:, None]) / scale), 255.0
        ).astype(np.uint8)
        return lut8, base, scale

    def adc_distances(self, q: np.ndarray, ids: np.ndarray) -> Tuple[np.ndarray, float]:
        """int8-LUT ADC distances for global ``ids`` plus the quantization
        error bound: ``0 <= decoded_exact - adc < bound`` per candidate."""
        assert self.built
        q = np.asarray(q, np.float32).reshape(-1)
        lut8, base, scale = self._lut(q)
        pos = np.argsort(self.sorted_ids, kind="stable")[np.asarray(ids, np.int64)]
        codes = self.codes[pos]                                    # (B, M)
        acc = lut8[np.arange(self.m)[None, :], codes].sum(1, dtype=np.int64)
        adc = acc.astype(np.float64) * scale + float(base.sum())
        return adc.astype(np.float32), self.m * scale

    # ------------------------------------------------------------------
    def search(
        self,
        queries: np.ndarray,
        k: int,
        nprobe: int = 8,
        rerank: int = 64,
        mask: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Masked ADC top-k with optional exact re-rank of the top-R.

        ``rerank=0`` returns raw ADC distances; ``rerank=R > 0`` rescores the
        R best ADC candidates against the original vectors (distances exact).
        Strictly per-row, so any batch composition returns identical rows.
        """
        assert self.built
        q = np.atleast_2d(np.asarray(queries, np.float32))
        b = q.shape[0]
        out_d = np.full((b, k), np.inf, np.float32)
        out_i = np.full((b, k), -1, np.int32)
        if self.n == 0:
            return out_d, out_i
        nprobe = min(nprobe, self.n_lists)
        for r in range(b):
            d, ids = self._search_one(q[r], k, nprobe, rerank, mask)
            out_d[r, : d.size], out_i[r, : ids.size] = d, ids
        return out_d, out_i

    def _search_one(self, q, k, nprobe, rerank, mask):
        # probe selection: nearest coarse lists, ties broken by list id
        qc = ((self.centroids - q[None]) ** 2).sum(1).astype(np.float32)
        key_c = (np.maximum(qc, 0.0).view(np.int32).astype(np.int64) << 32) | np.arange(
            self.n_lists, dtype=np.int64
        )
        probes = _composite_topk(key_c, nprobe)
        pos = np.concatenate(
            [np.arange(self.offsets[l], self.offsets[l + 1]) for l in probes]
        ) if probes.size else np.empty(0, np.int64)
        if pos.size == 0:
            return np.empty(0, np.float32), np.empty(0, np.int32)
        cand_ids = self.sorted_ids[pos]
        if mask is not None:
            keep = mask[cand_ids]
            pos, cand_ids = pos[keep], cand_ids[keep]
        if pos.size == 0:
            return np.empty(0, np.float32), np.empty(0, np.int32)
        # int8 ADC scan over the surviving candidates
        lut8, base, scale = self._lut(q)
        acc = lut8[np.arange(self.m)[None, :], self.codes[pos]].sum(1, dtype=np.int64)
        take = min(max(rerank, k) if rerank > 0 else k, pos.size)
        adc_key = (acc << 32) | np.arange(pos.size, dtype=np.int64)
        sel = _composite_topk(adc_key, take)
        sel_ids = cand_ids[sel]
        if rerank > 0:
            # exact re-rank against the original vectors; composite keys keep
            # equal-distance ordering independent of the candidate set size
            ex = ((self.vectors_np[sel_ids] - q[None]) ** 2).sum(1).astype(np.float32)
            ex = np.maximum(ex, 0.0)
            key = (ex.view(np.int32).astype(np.int64) << 32) | np.arange(
                ex.size, dtype=np.int64
            )
            order = _composite_topk(key, min(k, ex.size))
            return ex[order], sel_ids[order].astype(np.int32)
        adc = (acc[sel].astype(np.float64) * scale + float(base.sum())).astype(np.float32)
        kk = min(k, adc.size)
        return adc[:kk], sel_ids[:kk].astype(np.int32)

    # ------------------------------------------------------------------
    def memory_bytes(self) -> int:
        """Scan-resident bytes: codes + codebooks + coarse centroids + id
        layout.  The exact-re-rank vector store is ``rerank_bytes`` (slower
        tier in a real deployment; reported separately by the bench)."""
        assert self.built
        return int(
            self.codes.nbytes + self.codebooks.nbytes + self.centroids.nbytes
            + self.sorted_ids.nbytes + self.offsets.nbytes
        )

    @property
    def rerank_bytes(self) -> int:
        return int(self.vectors_np.nbytes)
