"""ACORN-1 baseline (Patel et al., 2024) — predicate-aware graph search.

ACORN builds a denser-than-usual proximity graph and, at query time, filters
neighbours by the predicate *during* traversal; ACORN-1 compensates for
filtered-out neighbours by expanding to 2-hop neighbourhoods when too few
1-hop neighbours pass.  This file keeps the baseline faithful in behaviour:

* construction: approximate KNN graph of fixed degree M (cluster-blocked
  exact KNN — dense matmuls, the TPU-friendly construction), deliberately
  *predicate-agnostic* like ACORN's single global graph;
* search: best-first beam search (ef candidates) where only predicate-passing
  nodes enter the result set, with on-demand 2-hop expansion.

Pointer-chasing traversal is the one paper component that does NOT map well
onto the MXU (DESIGN.md §2 "Assumptions changed"); the numpy implementation
here is the benchmark baseline, and ``search_jax`` provides a fixed-shape
`lax.while_loop` variant demonstrating the TPU-compatible formulation.
"""
from __future__ import annotations

import heapq
from typing import Optional, Tuple

import numpy as np

from .kmeans import kmeans

__all__ = ["AcornIndex"]


class AcornIndex:
    def __init__(self, vectors: np.ndarray, m: int = 24, seed: int = 0):
        self.vectors = np.ascontiguousarray(vectors, np.float32)
        self.n, self.dim = vectors.shape
        self.m = m
        self.seed = seed
        self.built = False

    # ------------------------------------------------------------------
    def build(self) -> "AcornIndex":
        """Approximate degree-M graph via cluster blocking: each point's
        short edges are its nearest neighbours among the members of its own
        and the 2 nearest sibling clusters; a reserved fraction of the degree
        budget goes to random long-range edges (navigable-small-world
        property — pure KNN graphs are not navigable from a far entry)."""
        n, m = self.n, self.m
        m_rand = max(2, m // 4)      # long-range edges per node
        m_knn = m - m_rand
        k_clusters = max(4, n // 1024)
        cent, asg = kmeans(self.vectors, k_clusters, iters=6, seed=self.seed)
        # nearest 3 clusters for each cluster (self + 2 siblings)
        cd = ((cent[:, None, :] - cent[None, :, :]) ** 2).sum(-1)
        np.fill_diagonal(cd, np.inf)
        sib = np.argsort(cd, axis=1)[:, :2]                      # (K, 2)
        members = [np.nonzero(asg == c)[0] for c in range(k_clusters)]
        nbrs = np.full((n, m), -1, np.int32)
        for c in range(k_clusters):
            own = members[c]
            if own.size == 0:
                continue
            cand = np.concatenate([own, members[sib[c, 0]], members[sib[c, 1]]])
            a = self.vectors[own]                                # (o, d)
            b = self.vectors[cand]                               # (c, d)
            d2 = (
                (a * a).sum(1, keepdims=True)
                + (b * b).sum(1)[None, :]
                - 2.0 * a @ b.T
            )
            # exclude self-edges
            self_pos = {int(x): j for j, x in enumerate(cand)}
            for i, p in enumerate(own):
                d2[i, self_pos[int(p)]] = np.inf
            take = min(m_knn, cand.size - 1)
            part = np.argpartition(d2, take - 1, axis=1)[:, :take]
            for i, p in enumerate(own):
                order = part[i][np.argsort(d2[i, part[i]])]
                nbrs[p, :take] = cand[order]
        # random long-range edges (uniform over the corpus)
        rng = np.random.default_rng(self.seed + 1)
        nbrs[:, m_knn:] = rng.integers(0, n, size=(n, m - m_knn), dtype=np.int64).astype(
            np.int32
        )
        self.neighbors = nbrs                                    # (N, M)
        # entry seeding: a fixed random sample scanned per query (plays the
        # role of HNSW's upper layers at negligible cost)
        self.seeds = rng.choice(n, size=min(64, n), replace=False).astype(np.int32)
        mean = self.vectors.mean(0)
        self.entry = int(np.argmin(((self.vectors - mean) ** 2).sum(1)))
        self.built = True
        return self

    # ------------------------------------------------------------------
    def search(
        self,
        queries: np.ndarray,
        k: int,
        ef: int = 64,
        mask: Optional[np.ndarray] = None,
        two_hop: bool = True,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Batched predicate-aware beam search.  mask (N,) bool or None."""
        assert self.built
        q = np.asarray(queries, np.float32)
        b = q.shape[0]
        out_d = np.full((b, k), np.inf, np.float32)
        out_i = np.full((b, k), -1, np.int32)
        for i in range(b):
            d, ids = self._search_one(q[i], k, ef, mask, two_hop)
            out_d[i, : len(ids)] = d
            out_i[i, : len(ids)] = ids
        return out_d, out_i

    def _search_one(self, q, k, ef, mask, two_hop):
        v = self.vectors
        visited = np.zeros(self.n, bool)

        def dist(ids):
            x = v[ids]
            return ((x - q) ** 2).sum(1)

        # entry seeding: best of the fixed seed sample (+ medoid)
        seed_ids = np.append(self.seeds, self.entry)
        sd = dist(seed_ids)
        entry = int(seed_ids[int(np.argmin(sd))])
        visited[entry] = True
        d0 = float(((v[entry] - q) ** 2).sum())
        # candidate heap (min by distance); result heap (max by distance)
        cand = [(d0, entry)]
        results = []  # (-d, id) only predicate-passing nodes
        if mask is None or mask[entry]:
            results.append((-d0, entry))

        while cand:
            d, u = heapq.heappop(cand)
            if len(results) >= ef and -results[0][0] < d:
                break
            # 1-hop neighbours
            nb = self.neighbors[u]
            nb = nb[nb >= 0]
            nb = nb[~visited[nb]]
            # ACORN-1: if filtering starves the frontier, expand 2-hop
            if two_hop and mask is not None and nb.size:
                passing = nb[mask[nb]]
                if passing.size < max(1, nb.size // 4):
                    hop2 = self.neighbors[nb].reshape(-1)
                    hop2 = hop2[hop2 >= 0]
                    hop2 = np.unique(hop2[~visited[hop2]])
                    nb = np.unique(np.concatenate([nb, hop2]))
            if nb.size == 0:
                continue
            visited[nb] = True
            dn = dist(nb)
            for dd, nn in zip(dn, nb):
                dd = float(dd)
                worst = -results[0][0] if len(results) >= ef else np.inf
                if dd < worst:
                    heapq.heappush(cand, (dd, int(nn)))
                    if mask is None or mask[nn]:
                        heapq.heappush(results, (-dd, int(nn)))
                        if len(results) > ef:
                            heapq.heappop(results)
        res = sorted([(-nd, i) for nd, i in results])[:k]
        return [r[0] for r in res], [r[1] for r in res]

    # ------------------------------------------------------------------
    def search_jax(self, queries, k: int, ef: int = 64, iters: int = 64, mask=None):
        """Fixed-shape TPU formulation: beam search as a bounded
        `lax.while_loop` over a (beam,) frontier with batched neighbour
        gathers.  Demonstrates the TPU-compatible form of graph traversal;
        recall is validated against the numpy implementation in tests."""
        import jax
        import jax.numpy as jnp

        v = jnp.asarray(self.vectors)
        nbrs = jnp.asarray(self.neighbors)
        n, m = self.n, self.m
        mask_j = jnp.ones(n, bool) if mask is None else jnp.asarray(mask)

        def one(qv):
            def dist(ids):
                x = v[jnp.maximum(ids, 0)]
                return jnp.where(ids >= 0, jnp.sum((x - qv) ** 2, 1), jnp.inf)

            seed_ids = jnp.asarray(np.append(self.seeds, self.entry))
            sd = dist(seed_ids)
            entry = seed_ids[jnp.argmin(sd)]
            beam_i = jnp.full((ef,), -1, jnp.int32).at[0].set(entry)
            beam_d = jnp.full((ef,), jnp.inf).at[0].set(jnp.min(sd))
            expanded = jnp.zeros((ef,), bool)

            def body(state):
                beam_i, beam_d, expanded, it = state
                # pick the nearest unexpanded beam entry
                sel_d = jnp.where(expanded, jnp.inf, beam_d)
                u_pos = jnp.argmin(sel_d)
                u = beam_i[u_pos]
                expanded = expanded.at[u_pos].set(True)
                nb = nbrs[jnp.maximum(u, 0)]                     # (M,)
                nb = jnp.where(u >= 0, nb, -1)
                nd = dist(nb)
                # drop ids already in beam (dedup by penalising matches)
                dup = (nb[:, None] == beam_i[None, :]).any(1)
                nd = jnp.where(dup, jnp.inf, nd)
                cat_i = jnp.concatenate([beam_i, nb])
                cat_d = jnp.concatenate([beam_d, nd])
                neg, pos = jax.lax.top_k(-cat_d, ef)
                keep_exp = jnp.concatenate([expanded, jnp.zeros((m,), bool)])[pos]
                return cat_i[pos], -neg, keep_exp, it + 1

            def cond(state):
                _, beam_d, expanded, it = state
                return (it < iters) & (~expanded & jnp.isfinite(beam_d)).any()

            beam_i, beam_d, _, _ = jax.lax.while_loop(
                cond, body, (beam_i, beam_d, expanded, 0)
            )
            ok = (beam_i >= 0) & mask_j[jnp.maximum(beam_i, 0)]
            beam_d = jnp.where(ok, beam_d, jnp.inf)
            neg, pos = jax.lax.top_k(-beam_d, k)
            return -neg, jnp.where(jnp.isinf(-neg), -1, beam_i[pos])

        import jax

        return jax.vmap(one)(jnp.asarray(queries, jnp.float32))
