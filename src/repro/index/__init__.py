from .flat import FlatIndex, l2_topk, chunked_masked_topk
from .ivf import IVFIndex
from .acorn import AcornIndex
from .kmeans import kmeans

__all__ = [
    "FlatIndex",
    "IVFIndex",
    "AcornIndex",
    "kmeans",
    "l2_topk",
    "chunked_masked_topk",
]
