from .flat import FlatIndex, l2_topk, chunked_masked_topk
from .ivf import IVFIndex
from .acorn import AcornIndex
from .pq import IVFPQIndex
from .kmeans import kmeans
from .registry import (
    DEFAULT_BACKENDS,
    BackendSet,
    KnobTier,
    LiveIndex,
    SearchBackend,
    backend_names,
    make_backend,
    register_backend,
    unregister_backend,
)

__all__ = [
    "FlatIndex",
    "IVFIndex",
    "AcornIndex",
    "IVFPQIndex",
    "kmeans",
    "l2_topk",
    "chunked_masked_topk",
    "BackendSet",
    "KnobTier",
    "LiveIndex",
    "SearchBackend",
    "DEFAULT_BACKENDS",
    "backend_names",
    "make_backend",
    "register_backend",
    "unregister_backend",
]
