"""Exact (brute-force) KNN with optional predicate masking.

This is the pre-filtering executor's search engine (paper §4.1 implements
pre-filtering with brute-force KNN) and the ground-truth oracle for recall
measurement.  On TPU the masked dense scan is the idiomatic form (DESIGN.md
§2); the fused Pallas kernel in :mod:`repro.kernels` implements the same
contract and is validated against :func:`l2_topk`.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["l2_topk", "chunked_masked_topk", "FlatIndex"]


@partial(jax.jit, static_argnames=("k",))
def l2_topk(
    queries: jax.Array,
    corpus: jax.Array,
    k: int,
    mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Exact top-k by squared L2 distance.

    queries: (B, d), corpus: (N, d), mask: optional (N,) bool — True = passes
    the predicate.  Returns (dists (B,k), idx (B,k)); masked-out entries get
    +inf distance and index -1.
    """
    q2 = jnp.sum(queries * queries, axis=1, keepdims=True)   # (B, 1)
    x2 = jnp.sum(corpus * corpus, axis=1)                    # (N,)
    d2 = q2 + x2[None, :] - 2.0 * queries @ corpus.T         # (B, N)
    d2 = jnp.maximum(d2, 0.0)
    if mask is not None:
        d2 = jnp.where(mask[None, :], d2, jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    dists = -neg
    idx = jnp.where(jnp.isinf(dists), -1, idx)
    return dists, idx


@partial(jax.jit, static_argnames=("k", "chunk"))
def chunked_masked_topk(
    queries: jax.Array,
    corpus: jax.Array,
    k: int,
    mask: Optional[jax.Array] = None,
    chunk: int = 65536,
) -> Tuple[jax.Array, jax.Array]:
    """Streaming variant: scans the corpus in chunks with a running top-k,
    never materialising the (B, N) distance matrix.  This is the XLA
    realisation of the Pallas kernel's loop structure, usable at corpus
    sizes where (B, N) would not fit."""
    n, d = corpus.shape
    b = queries.shape[0]
    pad = (-n) % chunk
    if pad:
        corpus = jnp.pad(corpus, ((0, pad), (0, 0)))
        mask_full = jnp.pad(
            mask if mask is not None else jnp.ones(n, bool), (0, pad), constant_values=False
        )
    else:
        mask_full = mask if mask is not None else jnp.ones(n, bool)
    n_chunks = corpus.shape[0] // chunk
    xs = corpus.reshape(n_chunks, chunk, d)
    ms = mask_full.reshape(n_chunks, chunk)
    q2 = jnp.sum(queries * queries, axis=1, keepdims=True)

    def step(carry, inp):
        best_d, best_i = carry                                # (B,k), (B,k)
        x, m, start = inp
        x2 = jnp.sum(x * x, axis=1)
        d2 = jnp.maximum(q2 + x2[None, :] - 2.0 * queries @ x.T, 0.0)
        d2 = jnp.where(m[None, :], d2, jnp.inf)
        ids = start + jnp.arange(chunk)
        cat_d = jnp.concatenate([best_d, d2], axis=1)
        cat_i = jnp.concatenate([best_i, jnp.broadcast_to(ids, (b, chunk))], axis=1)
        neg, pos = jax.lax.top_k(-cat_d, k)
        return (-neg, jnp.take_along_axis(cat_i, pos, axis=1)), None

    starts = jnp.arange(n_chunks) * chunk
    init = (jnp.full((b, k), jnp.inf), jnp.full((b, k), -1, jnp.int32))
    (best_d, best_i), _ = jax.lax.scan(step, init, (xs, ms, starts))
    best_i = jnp.where(jnp.isinf(best_d), -1, best_i)
    return best_d, best_i


class FlatIndex:
    """Thin object wrapper so executors share one index interface."""

    def __init__(self, vectors: np.ndarray):
        self.vectors = jnp.asarray(vectors, jnp.float32)
        self.n, self.dim = vectors.shape

    def build(self) -> "FlatIndex":
        return self  # nothing to build

    def search(self, queries, k: int, mask=None):
        q = jnp.asarray(queries, jnp.float32)
        if self.n * q.shape[0] <= 64_000_000:
            return l2_topk(q, self.vectors, k, None if mask is None else jnp.asarray(mask))
        return chunked_masked_topk(
            q, self.vectors, k, None if mask is None else jnp.asarray(mask)
        )
