"""Pluggable ANN-backend registry — the engine's (plan, backend, knob) space.

The planner is generic over backends (the paper's claim); this module makes
that concrete.  Every backend exposes one uniform surface:

* ``build(corpus)``                     — construct from an (N, d) float32 corpus
* ``search_masked(queries, mask, k, knobs)`` — masked top-k, mask applied
  DURING the search (no filtered-out id may ever surface)
* ``memory_bytes()``                    — scan-resident footprint
* ``knob_grid()``                       — declared :class:`KnobTier` list; each
  tier names a knob setting and the recall floor it promises

and must satisfy the cross-backend conformance harness
(``tests/backend_conformance.py``): recall floors at every declared tier,
bit-stable row independence in any batch composition (the PR 2 discipline),
mask/tombstone safety, empty/tiny/all-masked edges, and sharded ≡ unsharded
merge identity.  A fifth backend is one :func:`register_backend` call plus a
green conformance run.

Registered by default: ``flat`` (exact masked scan), ``ivf`` (IVF-Flat probe
scan), ``ivfpq`` (:class:`~repro.index.pq.IVFPQIndex`, int8 ADC + exact
re-rank), ``acorn`` (predicate-aware graph traversal).

Corpora below ``TINY_N`` points degenerate every approximate backend to the
exact masked scan: cluster structure is meaningless at that size and the
edge-case contract (every passing point returned when ``|masked| <= k``)
must hold for all backends.

:class:`BackendSet` is what the engine holds: one built instance per backend
with the flattened ``classes()`` enumeration ``[(backend, tier), ...]`` that
the planner's routing head indexes into.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, Mapping, Optional, Protocol, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .flat import l2_topk
from .ivf import IVFIndex
from .acorn import AcornIndex
from .pq import IVFPQIndex

__all__ = [
    "KnobTier",
    "SearchBackend",
    "BackendSet",
    "LiveIndex",
    "register_backend",
    "unregister_backend",
    "backend_names",
    "make_backend",
    "DEFAULT_BACKENDS",
    "TINY_N",
]

# below this corpus size every backend falls back to the exact masked scan
TINY_N = 64


@dataclass(frozen=True)
class KnobTier:
    """One named knob setting with the recall@10 floor it declares.

    The floor is a *contract*: the conformance harness measures masked
    recall@10 against the exact oracle at this tier and fails the backend if
    it undershoots.  The engine's routing classes are (backend, tier) pairs.
    """
    name: str
    knobs: Mapping[str, int] = field(default_factory=dict)
    recall_floor: float = 0.5


class SearchBackend(Protocol):
    """Uniform backend surface; see module docstring for the contract."""

    name: str

    def build(self, corpus: np.ndarray) -> "SearchBackend": ...

    def search_masked(
        self,
        queries: np.ndarray,
        mask: Optional[np.ndarray],
        k: int,
        knobs: Optional[Mapping[str, int]] = None,
    ) -> Tuple[np.ndarray, np.ndarray]: ...

    def memory_bytes(self) -> int: ...

    def knob_grid(self) -> Tuple[KnobTier, ...]: ...


# ----------------------------------------------------------------------
# shared helpers
# ----------------------------------------------------------------------
def _empty_result(b: int, k: int) -> Tuple[np.ndarray, np.ndarray]:
    return np.full((b, k), np.inf, np.float32), np.full((b, k), -1, np.int32)


def _exact_masked(
    vectors: np.ndarray, queries: np.ndarray, mask: Optional[np.ndarray], k: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact masked top-k in pure numpy with composite tie keys.  Every row
    is an independent broadcast/reduce, so results are batch-invariant by
    construction — the tiny-corpus fallback for all backends."""
    q = np.atleast_2d(np.asarray(queries, np.float32))
    b = q.shape[0]
    out_d, out_i = _empty_result(b, k)
    n = vectors.shape[0]
    if n == 0:
        return out_d, out_i
    d2 = ((q[:, None, :] - vectors[None]) ** 2).sum(-1).astype(np.float32)
    d2 = np.maximum(d2, 0.0)
    if mask is not None:
        d2 = np.where(np.asarray(mask, bool)[None, :], d2, np.inf)
    key = (d2.view(np.int32).astype(np.int64) << 32) | np.arange(n, dtype=np.int64)[None]
    kk = min(k, n)
    sel = np.argsort(key, axis=1, kind="stable")[:, :kk]
    sd = np.take_along_axis(d2, sel, axis=1)
    fin = np.isfinite(sd)
    out_d[:, :kk] = np.where(fin, sd, np.inf)
    out_i[:, :kk] = np.where(fin, sel.astype(np.int32), -1)
    return out_d, out_i


# ----------------------------------------------------------------------
# backend adapters
# ----------------------------------------------------------------------
class FlatBackend:
    """Exact masked scan — the recall ceiling and memory baseline."""

    name = "flat"

    def __init__(self, seed: int = 0):
        self.seed = seed

    def build(self, corpus: np.ndarray) -> "FlatBackend":
        self.vectors = np.ascontiguousarray(corpus, np.float32)
        self.n, self.dim = self.vectors.shape
        self._vecs_j = jnp.asarray(self.vectors) if self.n else None
        return self

    def search_masked(self, queries, mask, k, knobs=None):
        q = np.atleast_2d(np.asarray(queries, np.float32))
        b = q.shape[0]
        out_d, out_i = _empty_result(b, k)
        if self.n == 0:
            return out_d, out_i
        if self.n < TINY_N:
            return _exact_masked(self.vectors, q, mask, k)
        kk = min(k, self.n)
        mask_j = None if mask is None else jnp.asarray(np.asarray(mask, bool))
        # fixed (8, d) query blocks: the same GEMM shape for any batch size,
        # so each row's result is independent of its batch composition
        q8 = np.zeros((8, self.dim), np.float32)
        for s in range(0, b, 8):
            e = min(b, s + 8)
            q8[:] = 0.0
            q8[: e - s] = q[s:e]
            d_, i_ = l2_topk(jnp.asarray(q8), self._vecs_j, kk, mask_j)
            out_d[s:e, :kk] = np.asarray(d_)[: e - s]
            out_i[s:e, :kk] = np.asarray(i_)[: e - s]
        return out_d, out_i

    def memory_bytes(self) -> int:
        return int(self.vectors.nbytes)

    def knob_grid(self) -> Tuple[KnobTier, ...]:
        return (KnobTier("exact", {}, recall_floor=0.99),)


class IVFBackend:
    """IVF-Flat probe-list scan (wraps :class:`IVFIndex`)."""

    name = "ivf"

    def __init__(self, n_lists: Optional[int] = None, seed: int = 0):
        self.n_lists = n_lists
        self.seed = seed

    def build(self, corpus: np.ndarray) -> "IVFBackend":
        self.vectors = np.ascontiguousarray(corpus, np.float32)
        self.n = self.vectors.shape[0]
        self.index = (
            IVFIndex(self.vectors, n_lists=self.n_lists, seed=self.seed).build()
            if self.n >= TINY_N else None
        )
        return self

    def search_masked(self, queries, mask, k, knobs=None):
        q = np.atleast_2d(np.asarray(queries, np.float32))
        if self.index is None:
            return _exact_masked(self.vectors, q, mask, k)
        nprobe = int((knobs or {}).get("nprobe", 8))
        return self.index.search(q, k, nprobe=nprobe,
                                 mask=None if mask is None else np.asarray(mask, bool))

    def memory_bytes(self) -> int:
        if self.index is None:
            return int(self.vectors.nbytes)
        ix = self.index
        return int(ix.sorted_vecs.nbytes + ix.centroids.nbytes + ix.sorted_ids.nbytes
                   + ix.offsets.nbytes + ix.sorted_sq.nbytes + ix.padded_ids.nbytes)

    def knob_grid(self) -> Tuple[KnobTier, ...]:
        return (
            KnobTier("fast", {"nprobe": 8}, recall_floor=0.50),
            KnobTier("balanced", {"nprobe": 16}, recall_floor=0.70),
            KnobTier("precise", {"nprobe": 64}, recall_floor=0.90),
        )


class IVFPQBackend:
    """IVF-PQ int8 ADC scan with exact re-rank (wraps :class:`IVFPQIndex`)."""

    name = "ivfpq"

    def __init__(self, n_lists: Optional[int] = None, m: Optional[int] = None,
                 seed: int = 0):
        self.n_lists = n_lists
        self.m = m
        self.seed = seed

    def build(self, corpus: np.ndarray) -> "IVFPQBackend":
        self.vectors = np.ascontiguousarray(corpus, np.float32)
        self.n = self.vectors.shape[0]
        self.index = (
            IVFPQIndex(self.vectors, n_lists=self.n_lists, m=self.m,
                       seed=self.seed).build()
            if self.n >= TINY_N else None
        )
        return self

    def search_masked(self, queries, mask, k, knobs=None):
        q = np.atleast_2d(np.asarray(queries, np.float32))
        if self.index is None:
            return _exact_masked(self.vectors, q, mask, k)
        kn = knobs or {}
        return self.index.search(
            q, k,
            nprobe=int(kn.get("nprobe", 8)),
            rerank=int(kn.get("rerank", 64)),
            mask=None if mask is None else np.asarray(mask, bool),
        )

    def memory_bytes(self) -> int:
        if self.index is None:
            return int(self.vectors.nbytes)
        return self.index.memory_bytes()

    @property
    def rerank_bytes(self) -> int:
        return 0 if self.index is None else self.index.rerank_bytes

    def knob_grid(self) -> Tuple[KnobTier, ...]:
        return (
            KnobTier("fast", {"nprobe": 8, "rerank": 32}, recall_floor=0.45),
            KnobTier("precise", {"nprobe": 64, "rerank": 256}, recall_floor=0.80),
        )


class AcornBackend:
    """ACORN-1 predicate-aware graph traversal (wraps :class:`AcornIndex`)."""

    name = "acorn"

    def __init__(self, m: int = 24, seed: int = 0):
        self.m = m
        self.seed = seed

    def build(self, corpus: np.ndarray) -> "AcornBackend":
        self.vectors = np.ascontiguousarray(corpus, np.float32)
        self.n = self.vectors.shape[0]
        self.index = (
            AcornIndex(self.vectors, m=self.m, seed=self.seed).build()
            if self.n >= TINY_N else None
        )
        return self

    def search_masked(self, queries, mask, k, knobs=None):
        q = np.atleast_2d(np.asarray(queries, np.float32))
        if self.index is None:
            return _exact_masked(self.vectors, q, mask, k)
        ef = int((knobs or {}).get("ef", 64))
        return self.index.search(q, k, ef=ef,
                                 mask=None if mask is None else np.asarray(mask, bool))

    def memory_bytes(self) -> int:
        if self.index is None:
            return int(self.vectors.nbytes)
        ix = self.index
        return int(self.vectors.nbytes + ix.neighbors.nbytes + ix.seeds.nbytes)

    def knob_grid(self) -> Tuple[KnobTier, ...]:
        return (
            KnobTier("fast", {"ef": 64}, recall_floor=0.45),
            KnobTier("precise", {"ef": 160}, recall_floor=0.70),
        )


class LiveIndex:
    """Mutation-aware view over one BUILT backend: composes a
    :class:`~repro.core.corpus.LiveCorpus`'s tombstones into every mask and
    merges an exact scan of the append segment into the backend's base
    results — so any registered backend serves a mutated corpus without a
    rebuild.  Satisfies the same ``search_masked`` surface (and the same
    conformance contract: a tombstoned id can never surface; declared
    recall floors hold over the LIVE rows).

    The segment scan uses the same fused ``l2_topk`` kernel as the exact
    executors, keeping per-row distances bit-identical to what a fresh
    build over the compacted corpus would compute (the PR 2 discipline) —
    that is what makes compaction id-stable for exact tiers.
    """

    def __init__(self, base: SearchBackend, live):
        self.base = base
        self.live = live
        self.name = base.name

    def build(self, corpus: np.ndarray) -> "LiveIndex":
        self.base.build(corpus)
        return self

    def search_masked(self, queries, mask, k, knobs=None):
        q = np.atleast_2d(np.asarray(queries, np.float32))
        live = self.live
        base_n = live.base_n
        alive = live.alive_mask()
        if mask is None:
            bmask = alive[:base_n]
            smask = alive[base_n:]
        else:
            m = np.asarray(mask, bool)
            if m.size == live.n_total:
                bmask = m[:base_n] & alive[:base_n]
                smask = m[base_n:] & alive[base_n:]
            else:
                # base-length mask: the caller predates the segment, so
                # segment rows are filtered by liveness alone
                bmask = m & alive[:base_n]
                smask = alive[base_n:]
        bd, bi = self.base.search_masked(q, bmask, k, knobs=knobs)
        bd, bi = np.asarray(bd), np.asarray(bi)
        if live.seg_n and smask.any():
            from ..dist.collectives import merge_topk

            kk = min(k, live.seg_n)
            sd, si = l2_topk(q, live.seg_vectors(), kk, smask)
            sd, si = np.asarray(sd), np.asarray(si)
            si = np.where(si >= 0, si + base_n, -1).astype(np.int32)
            # base part first: merge_topk's column tie-break then preserves
            # handle order, the compaction bit-identity argument
            bd, bi = merge_topk([bd, sd], [bi, si], k)
        return bd, bi

    def memory_bytes(self) -> int:
        seg = self.live.seg_vectors().nbytes if self.live.seg_n else 0
        return int(self.base.memory_bytes() + seg + self.live.tomb.nbytes)

    def knob_grid(self) -> Tuple[KnobTier, ...]:
        return self.base.knob_grid()


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------
_REGISTRY: "OrderedDict[str, Callable[..., SearchBackend]]" = OrderedDict()


def register_backend(name: str, factory: Callable[..., SearchBackend],
                     overwrite: bool = False) -> None:
    """Register ``factory(seed=...) -> SearchBackend`` under ``name``."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered")
    _REGISTRY[name] = factory


def unregister_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def backend_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def make_backend(name: str, corpus: np.ndarray, seed: int = 0) -> SearchBackend:
    """Construct and build a registered backend over ``corpus``."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; registered: {backend_names()}")
    return _REGISTRY[name](seed=seed).build(np.asarray(corpus, np.float32))


register_backend("flat", FlatBackend)
register_backend("ivf", IVFBackend)
register_backend("ivfpq", IVFPQBackend)
register_backend("acorn", AcornBackend)

DEFAULT_BACKENDS: Tuple[str, ...] = ("flat", "ivf", "ivfpq", "acorn")


# ----------------------------------------------------------------------
# BackendSet — what the engine holds
# ----------------------------------------------------------------------
class BackendSet:
    """Built backend instances plus the flattened (backend, tier) routing
    classes the planner's routing head indexes into.  Class order is the
    registration order of backends crossed with each backend's declared
    tier order — deterministic, so a routing label is stable across runs."""

    def __init__(self, backends: "OrderedDict[str, SearchBackend]"):
        self.backends = backends
        self._classes: Tuple[Tuple[str, str], ...] = tuple(
            (bname, tier.name)
            for bname, b in backends.items()
            for tier in b.knob_grid()
        )
        self._knobs: Tuple[Mapping[str, int], ...] = tuple(
            tier.knobs
            for b in backends.values()
            for tier in b.knob_grid()
        )
        self._floors: Tuple[float, ...] = tuple(
            tier.recall_floor
            for b in backends.values()
            for tier in b.knob_grid()
        )

    @classmethod
    def build(cls, corpus: np.ndarray, names: Optional[Sequence[str]] = None,
              seed: int = 0) -> "BackendSet":
        names = tuple(names) if names else DEFAULT_BACKENDS
        built = OrderedDict(
            (nm, make_backend(nm, corpus, seed=seed)) for nm in names
        )
        return cls(built)

    def classes(self) -> Tuple[Tuple[str, str], ...]:
        return self._classes

    def class_names(self) -> Tuple[str, ...]:
        return tuple(f"{b}:{t}" for b, t in self._classes)

    def recall_floor(self, ci: int) -> float:
        return self._floors[ci]

    def search_class(self, ci: int, queries: np.ndarray,
                     mask: Optional[np.ndarray], k: int):
        bname, _ = self._classes[ci]
        from ..kernels.ops import record_dispatch

        t0 = time.perf_counter()
        out = self.backends[bname].search_masked(queries, mask, k,
                                                 knobs=self._knobs[ci])
        record_dispatch(f"backend_{bname}", time.perf_counter() - t0)
        return out

    def memory_bytes(self) -> Dict[str, int]:
        return {nm: b.memory_bytes() for nm, b in self.backends.items()}
