"""IVF-Flat index — the global index behind the post-filtering executor.

The paper's post-filtering uses "a global ANN index built at initialization";
on TPU the idiomatic global index is IVF (probe-list scans are dense matmuls;
graph indexes serialise the MXU — DESIGN.md §2).  Two search paths share one
semantics:

* ``search``     — numpy/JAX hybrid, contiguous sorted lists, fast on CPU;
  used by benchmarks.
* ``search_jax`` — fully jit-able padded-list path (vmap over queries), the
  TPU-target form used in the distributed engine and the dry-run.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kmeans import kmeans

__all__ = ["IVFIndex"]


class IVFIndex:
    def __init__(self, vectors: np.ndarray, n_lists: Optional[int] = None, seed: int = 0):
        self.vectors_np = np.asarray(vectors, np.float32)
        self.n, self.dim = vectors.shape
        # clamp to the corpus size: kmeans cannot seed more centroids than
        # points (tiny corpora/shards otherwise crash the build)
        self.n_lists = min(n_lists or max(16, int(np.sqrt(self.n))), self.n)
        self.seed = seed
        self.built = False

    # ------------------------------------------------------------------
    def build(self, iters: int = 8) -> "IVFIndex":
        c, a = kmeans(self.vectors_np, self.n_lists, iters=iters, seed=self.seed)
        self.centroids = c                                   # (L, d)
        order = np.argsort(a, kind="stable")
        self.sorted_ids = order.astype(np.int32)             # (N,)
        self.sorted_vecs = self.vectors_np[order]            # (N, d) contiguous per list
        counts = np.bincount(a, minlength=self.n_lists)
        self.list_counts = counts.astype(np.int64)           # (L,)
        self.offsets = np.zeros(self.n_lists + 1, np.int64)
        np.cumsum(counts, out=self.offsets[1:])
        # per-row squared norms of the sorted layout: the batched search
        # computes distances in dot form (q2 + x2 - 2qx, one BLAS call per
        # row) instead of per-row difference loops
        self.sorted_sq = np.einsum(
            "nd,nd->n", self.sorted_vecs, self.sorted_vecs
        ).astype(np.float32)
        # padded layout for the jit path
        self.max_list = int(counts.max())
        padded = np.full((self.n_lists, self.max_list), -1, np.int32)
        for l in range(self.n_lists):
            seg = self.sorted_ids[self.offsets[l] : self.offsets[l + 1]]
            padded[l, : seg.size] = seg
        self.padded_ids = padded
        self._centroids_j = jnp.asarray(c)
        self._vecs_j = jnp.asarray(self.vectors_np)
        self._padded_j = jnp.asarray(padded)
        self.built = True
        return self

    # ------------------------------------------------------------------
    # CPU benchmark path: contiguous gathered blocks
    # ------------------------------------------------------------------
    def search(
        self,
        queries: np.ndarray,
        k: int,
        nprobe: int = 8,
        mask: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (dists (B,k), ids (B,k)); unfilled slots have id -1/inf.
        ``mask`` (N,) restricts results to passing points (applied DURING the
        scan — this is what post-filtering calls with mask=None and what the
        engine's fused path uses directly).

        Vectorised across rows: ragged probe segments expand into one
        right-padded (B, C) candidate matrix; query-candidate dot products
        come from one GEMM per probed LIST (each list is a fixed contiguous
        slice of the sorted layout — no per-row candidate gather), shared by
        all rows probing that list; then dot-form distance assembly against
        precomputed ``sorted_sq`` and one batched argpartition.

        Per-row results are IDENTICAL whether a row is searched alone or
        inside any batch — the invariant the batched serving path's
        exactness guarantee rests on.  The per-list GEMM keeps this despite
        BLAS: the left operand is the same memory every time, and the query
        block is padded to a multiple of 8 columns, where sgemm's per-column
        reduction is independent of column position and count (the N=1
        sgemv path, which IS numerically different, is never taken).
        """
        assert self.built
        q = np.asarray(queries, np.float32)
        b = q.shape[0]
        nprobe = min(nprobe, self.n_lists)
        # bound the (B, C) candidate workspace (~33 bytes/lane across the
        # index/valid/dots/distance/key arrays): row results are
        # composition-independent (see below), so chunking the batch is
        # exact, and the per-row transient stays O(nprobe * max_list)
        worst_c = nprobe * self.max_list
        if b > 1 and b * worst_c > 8_000_000:
            chunk = max(1, 8_000_000 // max(worst_c, 1))
            parts = [
                self.search(q[s : s + chunk], k, nprobe=nprobe, mask=mask)
                for s in range(0, b, chunk)
            ]
            return (
                np.concatenate([p[0] for p in parts]),
                np.concatenate([p[1] for p in parts]),
            )
        # dispatch ledger for the obs layer: one record per actual scan
        # (chunked calls record in the leaves, not the splitting parent)
        from ..kernels.ops import record_dispatch

        t0 = time.perf_counter()
        # query -> centroid distances.  Same fixed-shape GEMM discipline as
        # the list scans below — every call is (L, d) @ (d, 8) regardless of
        # batch size, so probe selection is batch-invariant too.
        dots_c = np.empty((b, self.n_lists), np.float32)
        qcols_c = np.zeros((q.shape[1], 8), np.float32)
        for s in range(0, b, 8):
            e = min(b, s + 8)
            qcols_c[:] = 0.0
            qcols_c[:, : e - s] = q[s:e].T
            dots_c[s:e] = (self.centroids @ qcols_c).T[: e - s]
        qc = (
            (q * q).sum(1, keepdims=True)
            + (self.centroids * self.centroids).sum(1)[None, :]
            - 2.0 * dots_c
        )
        probes = np.argpartition(qc, nprobe - 1, axis=1)[:, :nprobe]    # (B, nprobe)
        out_d = np.full((b, k), np.inf, np.float32)
        out_i = np.full((b, k), -1, np.int32)
        counts = self.list_counts[probes]                               # (B, nprobe)
        totals = counts.sum(1)                                          # (B,)
        c = int(totals.max()) if b else 0
        if c == 0:
            record_dispatch("ivf_search", time.perf_counter() - t0)
            return out_d, out_i
        # ragged probe segments -> right-padded (B, C) sorted-row indices,
        # preserving per-row segment order (flat repeat/cumsum construction,
        # O(total candidates) memory)
        counts_flat = counts.ravel()
        t = int(counts_flat.sum())
        seg_rep = np.repeat(np.arange(counts_flat.size), counts_flat)
        pos_in_seg = np.arange(t) - np.repeat(
            np.cumsum(counts_flat) - counts_flat, counts_flat
        )
        cand_flat = self.offsets[probes].ravel()[seg_rep] + pos_in_seg
        row_of = np.repeat(np.arange(b), totals)
        pos_in_row = np.arange(t) - np.repeat(np.cumsum(totals) - totals, totals)
        rows_idx = np.zeros((b, c), np.int64)
        valid = np.zeros((b, c), bool)
        rows_idx[row_of, pos_in_row] = cand_flat
        valid[row_of, pos_in_row] = True
        ids = self.sorted_ids[rows_idx]                                 # (B, C)
        if mask is not None:
            valid &= mask[ids]
        # one GEMM per probed list, shared by every row probing it
        seg_start = np.cumsum(counts, axis=1) - counts                  # (B, nprobe)
        by_list: dict = {}
        for r in range(b):
            for s in range(nprobe):
                by_list.setdefault(int(probes[r, s]), []).append((r, s))
        dots = np.empty((b, c), np.float32)
        qcols = np.zeros((q.shape[1], 8), np.float32)
        for l, pairs in by_list.items():
            lo, hi = self.offsets[l], self.offsets[l + 1]
            if hi <= lo:
                continue
            a_l = self.sorted_vecs[lo:hi]                               # fixed view
            # every GEMM is exactly (len_l, d) @ (d, 8): a fixed shape per
            # list regardless of how many rows probe it, because sgemm
            # results are column-stable within one shape but NOT across
            # different column counts
            for c0 in range(0, len(pairs), 8):
                grp = pairs[c0 : c0 + 8]
                qcols[:] = 0.0
                qcols[:, : len(grp)] = q[[r for r, _ in grp]].T
                d_l = a_l @ qcols                                       # (len_l, 8)
                for j, (r, s) in enumerate(grp):
                    p0 = seg_start[r, s]
                    dots[r, p0 : p0 + (hi - lo)] = d_l[:, j]
        q2 = (q * q).sum(1)
        # padded lanes of `dots` are uninitialised (masked out below) — they
        # may hold garbage that overflows in the arithmetic; that's expected
        with np.errstate(over="ignore", invalid="ignore"):
            d2 = self.sorted_sq[rows_idx] + q2[:, None] - 2.0 * dots
        d2 = np.where(valid, np.maximum(d2, 0.0), np.inf)
        # canonical top-k: compose (distance bits, candidate position) into
        # one int64 key.  Non-negative f32 bit patterns sort like the floats,
        # so equal distances break ties by position — making BOTH the
        # boundary pick and the within-tie order independent of the row's
        # padded width (which varies with batch composition; distances tie
        # often on integer-valued corpora)
        key = (d2.view(np.int32).astype(np.int64) << 32) | np.arange(
            c, dtype=np.int64
        )[None, :]
        kk = min(k, c)
        sel = np.argpartition(key, kk - 1, axis=1)[:, :kk]
        order = np.argsort(np.take_along_axis(key, sel, axis=1), axis=1)
        sel = np.take_along_axis(sel, order, axis=1)
        sd = np.take_along_axis(d2, sel, axis=1)
        si = np.take_along_axis(ids, sel, axis=1)
        fin = np.isfinite(sd)
        out_d[:, :kk] = np.where(fin, sd, np.inf)
        out_i[:, :kk] = np.where(fin, si, -1)
        record_dispatch("ivf_search", time.perf_counter() - t0)
        return out_d, out_i

    # ------------------------------------------------------------------
    # TPU-target path: fixed shapes, jit + vmap
    # ------------------------------------------------------------------
    @partial(jax.jit, static_argnames=("self", "k", "nprobe"))
    def search_jax(
        self,
        queries: jax.Array,
        k: int,
        nprobe: int = 8,
        mask: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        assert self.built
        nprobe = min(nprobe, self.n_lists)
        c = self._centroids_j
        x = self._vecs_j
        q2 = jnp.sum(queries**2, axis=1, keepdims=True)
        qc = q2 + jnp.sum(c**2, 1)[None, :] - 2.0 * queries @ c.T
        _, probes = jax.lax.top_k(-qc, nprobe)              # (B, nprobe)

        def per_query(qv, pl):
            ids = self._padded_j[pl].reshape(-1)            # (nprobe*max_list,)
            valid = ids >= 0
            cand = x[jnp.maximum(ids, 0)]                   # (C, d)
            d2 = jnp.sum((cand - qv[None, :]) ** 2, axis=1)
            if mask is not None:
                valid = valid & mask[jnp.maximum(ids, 0)]
            d2 = jnp.where(valid, d2, jnp.inf)
            neg, pos = jax.lax.top_k(-d2, k)
            return -neg, jnp.where(jnp.isinf(-neg), -1, ids[pos])

        return jax.vmap(per_query)(queries, probes)
