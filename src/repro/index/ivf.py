"""IVF-Flat index — the global index behind the post-filtering executor.

The paper's post-filtering uses "a global ANN index built at initialization";
on TPU the idiomatic global index is IVF (probe-list scans are dense matmuls;
graph indexes serialise the MXU — DESIGN.md §2).  Two search paths share one
semantics:

* ``search``     — numpy/JAX hybrid, contiguous sorted lists, fast on CPU;
  used by benchmarks.
* ``search_jax`` — fully jit-able padded-list path (vmap over queries), the
  TPU-target form used in the distributed engine and the dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kmeans import kmeans

__all__ = ["IVFIndex"]


class IVFIndex:
    def __init__(self, vectors: np.ndarray, n_lists: Optional[int] = None, seed: int = 0):
        self.vectors_np = np.asarray(vectors, np.float32)
        self.n, self.dim = vectors.shape
        # clamp to the corpus size: kmeans cannot seed more centroids than
        # points (tiny corpora/shards otherwise crash the build)
        self.n_lists = min(n_lists or max(16, int(np.sqrt(self.n))), self.n)
        self.seed = seed
        self.built = False

    # ------------------------------------------------------------------
    def build(self, iters: int = 8) -> "IVFIndex":
        c, a = kmeans(self.vectors_np, self.n_lists, iters=iters, seed=self.seed)
        self.centroids = c                                   # (L, d)
        order = np.argsort(a, kind="stable")
        self.sorted_ids = order.astype(np.int32)             # (N,)
        self.sorted_vecs = self.vectors_np[order]            # (N, d) contiguous per list
        counts = np.bincount(a, minlength=self.n_lists)
        self.offsets = np.zeros(self.n_lists + 1, np.int64)
        np.cumsum(counts, out=self.offsets[1:])
        # padded layout for the jit path
        self.max_list = int(counts.max())
        padded = np.full((self.n_lists, self.max_list), -1, np.int32)
        for l in range(self.n_lists):
            seg = self.sorted_ids[self.offsets[l] : self.offsets[l + 1]]
            padded[l, : seg.size] = seg
        self.padded_ids = padded
        self._centroids_j = jnp.asarray(c)
        self._vecs_j = jnp.asarray(self.vectors_np)
        self._padded_j = jnp.asarray(padded)
        self.built = True
        return self

    # ------------------------------------------------------------------
    # CPU benchmark path: contiguous gathered blocks
    # ------------------------------------------------------------------
    def search(
        self,
        queries: np.ndarray,
        k: int,
        nprobe: int = 8,
        mask: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (dists (B,k), ids (B,k)); unfilled slots have id -1/inf.
        ``mask`` (N,) restricts results to passing points (applied DURING the
        scan — this is what post-filtering calls with mask=None and what the
        engine's fused path uses directly)."""
        assert self.built
        q = np.asarray(queries, np.float32)
        b = q.shape[0]
        nprobe = min(nprobe, self.n_lists)
        # query -> centroid distances (batch matmul)
        qc = (
            (q * q).sum(1, keepdims=True)
            + (self.centroids * self.centroids).sum(1)[None, :]
            - 2.0 * q @ self.centroids.T
        )
        probes = np.argpartition(qc, nprobe - 1, axis=1)[:, :nprobe]    # (B, nprobe)
        out_d = np.full((b, k), np.inf, np.float32)
        out_i = np.full((b, k), -1, np.int32)
        for i in range(b):
            segs = [
                np.arange(self.offsets[l], self.offsets[l + 1]) for l in probes[i]
            ]
            rows = np.concatenate(segs) if segs else np.empty(0, np.int64)
            if rows.size == 0:
                continue
            ids = self.sorted_ids[rows]
            if mask is not None:
                keep = mask[ids]
                rows, ids = rows[keep], ids[keep]
                if ids.size == 0:
                    continue
            cand = self.sorted_vecs[rows]
            d2 = ((cand - q[i]) ** 2).sum(1)
            kk = min(k, d2.size)
            sel = np.argpartition(d2, kk - 1)[:kk]
            order = sel[np.argsort(d2[sel])]
            out_d[i, :kk] = d2[order]
            out_i[i, :kk] = ids[order]
        return out_d, out_i

    # ------------------------------------------------------------------
    # TPU-target path: fixed shapes, jit + vmap
    # ------------------------------------------------------------------
    @partial(jax.jit, static_argnames=("self", "k", "nprobe"))
    def search_jax(
        self,
        queries: jax.Array,
        k: int,
        nprobe: int = 8,
        mask: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        assert self.built
        nprobe = min(nprobe, self.n_lists)
        c = self._centroids_j
        x = self._vecs_j
        q2 = jnp.sum(queries**2, axis=1, keepdims=True)
        qc = q2 + jnp.sum(c**2, 1)[None, :] - 2.0 * queries @ c.T
        _, probes = jax.lax.top_k(-qc, nprobe)              # (B, nprobe)

        def per_query(qv, pl):
            ids = self._padded_j[pl].reshape(-1)            # (nprobe*max_list,)
            valid = ids >= 0
            cand = x[jnp.maximum(ids, 0)]                   # (C, d)
            d2 = jnp.sum((cand - qv[None, :]) ** 2, axis=1)
            if mask is not None:
                valid = valid & mask[jnp.maximum(ids, 0)]
            d2 = jnp.where(valid, d2, jnp.inf)
            neg, pos = jax.lax.top_k(-d2, k)
            return -neg, jnp.where(jnp.isinf(-neg), -1, ids[pos])

        return jax.vmap(per_query)(queries, probes)
