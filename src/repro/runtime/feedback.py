"""Online planner feedback: log sampled outcomes, refit, guard, swap.

Closes the paper's learning loop from offline fit to online adaptation:
the offline planner (§3.1) is trained once on a synthetic workload, but
plan win-rates shift with the live query distribution — so the runtime
samples a fraction of served traffic, shadow-executes BOTH strategies to
get a ground-truth win label (same utility labelling as
``FilteredANNEngine.fit``: U = recall@k / T_search against the exact
masked top-k), and periodically refits a candidate ``CorePlanner`` from
the accumulated log.

The **drift guard** makes the swap safe: the log is split into a train
slice and a holdout, the candidate trains on the slice, and it only
replaces the serving head if its holdout ROC-AUC does not regress the
current head's AUC on the same holdout (``auc_slack`` tolerance).  A
refit gone wrong — too few examples, degenerate labels, noisy timings —
keeps the old head and tries again later.

The labeller is pluggable (``labeler=``) so tests can drive the loop with
a deterministic oracle; the default shadow labeller measures real wall
time, which is the one intentionally nondeterministic input in the
runtime (virtual-time scheduling and result ids stay replayable — the
replay tests run with feedback disabled).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from ..core.engine import FilteredANNEngine, PlannedResult, QueryLabel, NO_ROUTE
from ..core.planner import CorePlanner, roc_auc
from ..core.predicates import Or
from .queue import RuntimeRequest

__all__ = ["FeedbackConfig", "LogEntry", "OnlineFeedback"]


@dataclasses.dataclass
class FeedbackConfig:
    sample_rate: float = 0.1    # fraction of traffic shadow-labelled
    refit_every: int = 64       # new sampled examples between refit attempts
    min_examples: int = 32      # never refit on less than this
    holdout_frac: float = 0.25  # drift-guard holdout share of the log
    auc_slack: float = 0.0      # candidate may be at most this much worse
    max_log: int = 4096         # sliding window: oldest entries age out
    seed: int = 0


@dataclasses.dataclass
class LogEntry:
    """One sampled observation: what the paper's §3.1 labeller produces,
    collected online instead of from a synthetic workload."""

    features: np.ndarray        # planner feature vector at observe time
    decision: int               # what the serving planner chose
    label: int                  # ground-truth winner (PRE_FILTER/POST_FILTER)
    latency: float              # latency the SERVED strategy actually paid (s)
    route: int = NO_ROUTE       # best (backend, knob) class when routing is on


class OnlineFeedback:
    """Sampled observe -> log -> guarded refit loop around an engine.

    ``engine`` must be a fully ``build()``-and-ideally-``fit()`` flat
    :class:`FilteredANNEngine` (shadow labelling runs its pre/post
    executors; for a sharded deployment pass ``sharded.engine`` — planning
    is central, so the refit benefits every shard).
    """

    def __init__(self, engine: FilteredANNEngine, config: Optional[FeedbackConfig] = None,
                 labeler: Optional[Callable[[RuntimeRequest], int]] = None):
        if not hasattr(engine, "pre_exec"):
            raise ValueError(
                "OnlineFeedback needs a fully built engine (build(), not "
                "build_stats()): shadow labelling runs both executors"
            )
        self.engine = engine
        self.config = config or FeedbackConfig()
        self.labeler = labeler or self._shadow_label
        self.rng = np.random.default_rng(self.config.seed)
        self.log: List[LogEntry] = []
        self.n_observed = 0
        self.n_sampled = 0
        self.n_refits = 0
        self.n_swaps = 0
        self._since_refit = 0

    # ------------------------------------------------------------------
    def _shadow_label(self, req: RuntimeRequest):
        """Paper §3.1 labelling, online — delegates to the engine's shared
        :meth:`FilteredANNEngine.label_query` (the SAME rule the offline
        ``fit`` loop uses, so online and offline labels cannot drift).
        Returns the full :class:`QueryLabel` — for DNF requests it carries
        the per-clause races the clause-level log rows are built from."""
        return self.engine.label_query(req.query, req.pred, req.k)

    def observe(self, req: RuntimeRequest, res: PlannedResult) -> bool:
        """Called per served request; returns True when it was sampled into
        the log.  Sampling is seeded — which requests get shadow-labelled
        is replayable even though the measured labels are not.

        DNF requests log one clause-level row per unique disjunct (clause
        features, the ClausePlan's decision, the clause's own §3.1 race
        label/route) — the planner head only ever decides conjunctions, so
        whole-``Or`` rows would train it on features it never serves."""
        self.n_observed += 1
        if self.rng.random() >= self.config.sample_rate:
            return False
        labelled = self.labeler(req)
        lat = float(res.result.elapsed)
        if (isinstance(labelled, QueryLabel) and labelled.clauses
                and isinstance(req.pred, Or)):
            self._log_clauses(req, res, labelled, lat)
        else:
            # pluggable labelers may return a bare int (plan label only), a
            # (label, route) pair, or a QueryLabel
            if isinstance(labelled, QueryLabel):
                label, route = labelled.label, labelled.route
            elif isinstance(labelled, tuple):
                label, route = labelled
            else:
                label, route = labelled, NO_ROUTE
            se = self.engine.estimator.estimate(req.pred)
            fv = self.engine.feat.vector(req.pred, se.sel, req.k, se.is_exact)
            # the logged latency is what the SERVED strategy paid (its share
            # of the executed batch), not the shadow race's winner time
            self.log.append(LogEntry(fv, res.decision, int(label), lat,
                                     route=int(route)))
        if len(self.log) > self.config.max_log:
            self.log = self.log[-self.config.max_log:]
        self.n_sampled += 1
        self._since_refit += 1
        return True

    def _log_clauses(self, req: RuntimeRequest, res: PlannedResult,
                     ql: QueryLabel, lat: float) -> None:
        """One log row per unique disjunct of a DNF request.  Clause plans
        are matched by canonical key (term order varies across logically
        equal predicates); the row's latency is the whole request's share —
        clause-level timing is not observable from a merged result."""
        plan = getattr(res, "plan", None)
        by_key = ({c.clause_key: c for c in plan.clauses}
                  if plan is not None else {})
        se = self.engine.estimator.estimate(req.pred)
        seen: set = set()
        ci = 0
        for t, ce in zip(req.pred.terms, se.per_clause):
            key = self.engine._plan_key(t)
            if key in seen:
                continue
            seen.add(key)
            cl = ql.clauses[ci]
            ci += 1
            cp = by_key.get(key)
            dec = cp.decision if cp is not None else res.decision
            fv = self.engine.feat.vector(t, ce.sel, req.k, ce.is_exact)
            self.log.append(LogEntry(fv, int(dec), int(cl.label), lat,
                                     route=int(cl.route)))

    # ------------------------------------------------------------------
    def maybe_refit(self) -> bool:
        """Refit when enough new samples accumulated; returns True iff the
        candidate head was swapped in."""
        cfg = self.config
        if self._since_refit < cfg.refit_every or len(self.log) < cfg.min_examples:
            return False
        self._since_refit = 0
        return self.refit()

    def refit(self) -> bool:
        """One guarded refit attempt from the current log."""
        cfg = self.config
        x = np.stack([e.features for e in self.log])
        y = np.asarray([e.label for e in self.log], np.int32)
        self.n_refits += 1
        n = len(y)
        # deterministic holdout: seeded by (config seed, refit ordinal) so
        # successive refits don't always hold out the same rows
        perm = np.random.default_rng(cfg.seed + 7919 * self.n_refits).permutation(n)
        n_hold = max(1, int(round(cfg.holdout_frac * n)))
        hold, train = perm[:n_hold], perm[n_hold:]
        if (len(set(y[train].tolist())) < 2 or len(set(y[hold].tolist())) < 2):
            return False          # degenerate split: nothing to learn/guard
        candidate = CorePlanner(
            n_features=x.shape[1], seed=cfg.seed + self.n_refits
        ).fit(x[train], y[train])
        # routing head rides along: when the engine carries a backend roster
        # and the log holds routed labels, the candidate learns the
        # (backend, knob) head from the SAME train slice (guarded by the
        # same plan-AUC swap decision — routing never swaps independently)
        backend_set = getattr(self.engine, "backend_set", None)
        if backend_set is not None:
            routes = np.asarray([e.route for e in self.log], np.int32)
            if (routes[train] >= 0).sum() >= 2:
                candidate.fit_routing(x[train], routes[train],
                                      backend_set.class_names())
        cand_auc = roc_auc(y[hold], candidate.predict_proba(x[hold]))
        current = self.engine.planner
        if current.params is not None:
            curr_auc = roc_auc(y[hold], current.predict_proba(x[hold]))
        else:
            curr_auc = -np.inf    # untrained fallback head: any fit beats it
        if cand_auc < curr_auc - cfg.auc_slack:
            return False          # drift guard: the new head regressed
        self.engine.swap_planner(candidate)
        self.n_swaps += 1
        return True

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "observed": self.n_observed,
            "sampled": self.n_sampled,
            "log_size": len(self.log),
            "refits": self.n_refits,
            "swaps": self.n_swaps,
        }

    def publish(self, registry, **labels) -> None:
        """Export :meth:`stats` into a
        :class:`repro.obs.metrics.MetricsRegistry` as
        ``repro_feedback_*`` gauges."""
        for key, v in self.stats().items():
            registry.set_gauge(f"repro_feedback_{key}", v, **labels)
