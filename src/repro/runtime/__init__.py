"""repro.runtime — deadline-aware online serving runtime.

The asynchronous/streaming layer over the batched plan->execute pipeline:
arrival traces with per-request SLO deadlines (``queue``), a continuous
micro-batcher that drains them into decision-grouped batches under a
max-wait/max-batch/deadline-pressure policy (``scheduler``), per-request
and cache telemetry with a snapshot API (``telemetry``), and the online
planner feedback loop that refits ``CorePlanner`` from sampled live
outcomes behind a holdout-AUC drift guard (``feedback``).

Timing is VIRTUAL (discrete-event simulation over a deterministic cost
model) while execution is real — so a trace replays bit-for-bit (same
trace + seed => identical batch compositions, result ids, telemetry
counters) and still measures genuine engine throughput.
"""
from .queue import (
    SLO_TIERS,
    ArrivalTrace,
    RequestQueue,
    RuntimeRequest,
    TenantTraceSpec,
    bursty_trace,
    make_trace,
    multi_tenant_trace,
    poisson_trace,
)
from .scheduler import OnlineRuntime, RuntimeReport, SchedulerConfig, ServiceModel
from .telemetry import Telemetry
from .feedback import FeedbackConfig, LogEntry, OnlineFeedback

__all__ = [
    "SLO_TIERS",
    "RuntimeRequest",
    "ArrivalTrace",
    "RequestQueue",
    "poisson_trace",
    "bursty_trace",
    "make_trace",
    "TenantTraceSpec",
    "multi_tenant_trace",
    "SchedulerConfig",
    "ServiceModel",
    "OnlineRuntime",
    "RuntimeReport",
    "Telemetry",
    "FeedbackConfig",
    "LogEntry",
    "OnlineFeedback",
]
