"""Simulated-clock request queue + arrival-trace generators.

The serving runtime is a discrete-event simulation over a VIRTUAL clock:
requests carry arrival timestamps and absolute deadlines, the scheduler
(`runtime/scheduler.py`) advances time deterministically, and service
durations come from a deterministic cost model.  Real engine execution
still happens (result ids are real), but nothing about *when* things
happen depends on wall-clock measurement — which is what makes a trace
replayable bit-for-bit: same trace + seed => identical batch compositions,
result ids, and telemetry counters.

Trace generators (all seeded):

* :func:`poisson_trace` — memoryless arrivals at a target rate, the
  steady-traffic baseline.
* :func:`bursty_trace`  — on/off modulated Poisson (bursts of
  ``burst_factor`` x the base rate), the flash-crowd shape.

Both draw predicates Zipf-distributed from a pool (a few hot filters
dominate — the regime the predicate cache and the batched pre-filter
group are designed for) and assign SLO tiers by a mix ratio; a tier maps
to a relative deadline (``SLO_TIERS``).

Multi-tenant streams (:func:`multi_tenant_trace`) interleave one seeded
per-tenant sub-trace per :class:`TenantTraceSpec` — each tenant keeps its
own shape, rate, tier mix, and (for the noisy-neighbor profile) burst
parameters — into ONE arrival stream with dense global rids, so the fleet
scheduler replays exactly like the single-tenant runtime does.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.predicates import AnyPredicate

__all__ = [
    "SLO_TIERS",
    "RuntimeRequest",
    "ArrivalTrace",
    "RequestQueue",
    "TenantTraceSpec",
    "poisson_trace",
    "bursty_trace",
    "multi_tenant_trace",
    "make_trace",
]

# tier -> relative deadline in virtual seconds (arrival + deadline budget).
# Calibrated against ServiceModel's default costs: a full 64-batch serves in
# ~20 virtual ms, so "interactive" can only be met by early/small flushes —
# exactly the preemption behaviour the deadline-aware scheduler exists for.
SLO_TIERS: Dict[str, float] = {
    "interactive": 0.02,
    "standard": 0.10,
    "batch": 1.00,
}


@dataclasses.dataclass
class RuntimeRequest:
    """One in-flight filtered-ANN request in the serving runtime.

    ``op`` distinguishes reads ("query") from live-corpus writes ("upsert"
    / "delete"); writes carry their rows in ``payload`` (upsert:
    ``(vectors, cat, num)``; delete: ``(ids,)``) and a ``None`` query/pred.
    One queue serves both — writes are ordinary prioritised requests, so
    batch composition (and therefore replay) stays deterministic."""

    rid: int                      # unique, dense, trace order
    t_arrival: float              # virtual seconds
    query: Optional[np.ndarray]   # (d,) float32; None for writes
    pred: Optional[AnyPredicate]  # None for writes
    k: int
    tier: str = "standard"
    deadline: float = np.inf      # ABSOLUTE virtual time
    op: str = "query"             # "query" | "upsert" | "delete"
    payload: Optional[tuple] = None
    tenant: str = ""              # owning collection (fleet serving); ""
                                  # means the single-tenant runtime

    @property
    def priority(self):
        """Queue ordering key: tightest deadline first, FIFO within a
        deadline, rid as the total tie-break (determinism)."""
        return (self.deadline, self.t_arrival, self.rid)


@dataclasses.dataclass
class ArrivalTrace:
    """A replayable arrival stream: requests sorted by ``t_arrival``."""

    requests: List[RuntimeRequest]
    kind: str                      # "poisson" | "bursty"
    rate: float                    # mean arrival rate (virtual qps)
    seed: int

    def __len__(self) -> int:
        return len(self.requests)

    def __iter__(self):
        return iter(self.requests)


class RequestQueue:
    """Pending-request pool with deadline-aware draining.

    Tiny on purpose: queues hold at most a few hundred requests between
    flushes, so a plain list + sort-on-pop is both fast enough and — unlike
    a heap with incidental tie handling — *obviously* deterministic, which
    the replay guarantee leans on.
    """

    def __init__(self):
        self._items: List[RuntimeRequest] = []

    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)

    def push(self, req: RuntimeRequest) -> None:
        self._items.append(req)

    @property
    def oldest_arrival(self) -> float:
        return min(r.t_arrival for r in self._items)

    @property
    def tightest_deadline(self) -> float:
        return min(r.deadline for r in self._items)

    def pop(self, n: int) -> List[RuntimeRequest]:
        """Remove and return the ``n`` highest-priority requests (tightest
        deadline first) — tight-SLO arrivals jump the whole queue."""
        self._items.sort(key=lambda r: r.priority)
        batch, self._items = self._items[:n], self._items[n:]
        return batch


# ----------------------------------------------------------------------
# trace generators
# ----------------------------------------------------------------------
def _check_fracs(write_frac: float, upsert_frac: float) -> None:
    """Trace-generator construction guard: a probability outside [0, 1]
    silently degenerates the write mix (numpy comparisons just saturate),
    so reject it loudly instead of emitting an unusable trace."""
    if not 0.0 <= write_frac <= 1.0:
        raise ValueError(f"write_frac must be in [0, 1], got {write_frac}")
    if not 0.0 <= upsert_frac <= 1.0:
        raise ValueError(f"upsert_frac must be in [0, 1], got {upsert_frac}")


def _assemble(
    arrivals: np.ndarray,
    queries: np.ndarray,
    preds: Sequence[AnyPredicate],
    k: int,
    tier_mix: Dict[str, float],
    zipf_a: float,
    rng: np.random.Generator,
    write_frac: float = 0.0,
    write_corpus: Optional[tuple] = None,
    delete_pool: Optional[np.ndarray] = None,
    upsert_frac: float = 0.5,
) -> List[RuntimeRequest]:
    n = arrivals.size
    # Zipf over the predicate pool: rank-r filter drawn with p ~ 1/r^a
    ranks = np.arange(1, len(preds) + 1, dtype=np.float64)
    p_pred = 1.0 / ranks**zipf_a
    p_pred /= p_pred.sum()
    pred_idx = rng.choice(len(preds), size=n, p=p_pred)
    q_idx = rng.integers(0, queries.shape[0], size=n)
    tiers = list(tier_mix)
    p_tier = np.asarray([tier_mix[t] for t in tiers], np.float64)
    p_tier /= p_tier.sum()
    tier_idx = rng.choice(len(tiers), size=n, p=p_tier)
    # interleaved writes: each slot flips write with prob write_frac, then
    # upsert vs delete with prob upsert_frac — all from the SAME seeded rng
    # as the read stream, so a (seed, write_frac) pair is fully replayable.
    is_write = (rng.random(n) < write_frac) if write_frac > 0 else np.zeros(n, bool)
    is_upsert = rng.random(n) < upsert_frac if write_frac > 0 else None
    wv = wc = wm = None
    if write_corpus is not None:
        wv, wc, wm = (np.atleast_2d(np.asarray(a)) for a in write_corpus)
    up_i = del_i = 0
    reqs = []
    for i in range(n):
        t = float(arrivals[i])
        if is_write[i]:
            # upsert when rows remain (cycling), else delete; fall back to
            # the other kind (or a plain query) when a source is missing
            do_up = bool(is_upsert[i]) if wv is not None else False
            if not do_up and (delete_pool is None or not len(delete_pool)):
                do_up = wv is not None
            if do_up:
                j = up_i % len(wv)
                up_i += 1
                payload = (wv[j:j + 1], wc[j:j + 1], wm[j:j + 1])
                op = "upsert"
            elif delete_pool is not None and len(delete_pool):
                did = int(delete_pool[del_i % len(delete_pool)])
                del_i += 1
                payload = (np.asarray([did], np.int64),)
                op = "delete"
            else:
                payload, op = None, "query"
            if op != "query":
                reqs.append(RuntimeRequest(
                    rid=i, t_arrival=t, query=None, pred=None, k=k,
                    tier="batch", deadline=t + SLO_TIERS["batch"],
                    op=op, payload=payload,
                ))
                continue
        tier = tiers[int(tier_idx[i])]
        reqs.append(RuntimeRequest(
            rid=i, t_arrival=t,
            query=queries[q_idx[i]], pred=preds[pred_idx[i]], k=k,
            tier=tier, deadline=t + SLO_TIERS[tier],
        ))
    return reqs


_DEFAULT_MIX = {"interactive": 0.2, "standard": 0.6, "batch": 0.2}


def poisson_trace(
    queries: np.ndarray,
    preds: Sequence[AnyPredicate],
    n_requests: int,
    rate: float,
    k: int = 10,
    tier_mix: Optional[Dict[str, float]] = None,
    zipf_a: float = 1.2,
    seed: int = 0,
    write_frac: float = 0.0,
    write_corpus: Optional[tuple] = None,
    delete_pool: Optional[np.ndarray] = None,
    upsert_frac: float = 0.5,
) -> ArrivalTrace:
    """Memoryless arrivals: exponential inter-arrival gaps at ``rate`` qps.

    ``write_frac > 0`` interleaves live-corpus writes into the stream:
    upserts draw rows (cycling) from ``write_corpus = (vectors, cat, num)``,
    deletes cycle through ``delete_pool`` handles."""
    _check_fracs(write_frac, upsert_frac)
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    reqs = _assemble(arrivals, queries, preds, k, tier_mix or _DEFAULT_MIX,
                     zipf_a, rng, write_frac=write_frac,
                     write_corpus=write_corpus, delete_pool=delete_pool,
                     upsert_frac=upsert_frac)
    return ArrivalTrace(reqs, "poisson", rate, seed)


def bursty_trace(
    queries: np.ndarray,
    preds: Sequence[AnyPredicate],
    n_requests: int,
    rate: float,
    burst_factor: float = 8.0,
    burst_frac: float = 0.25,
    cycle: float = 0.25,
    k: int = 10,
    tier_mix: Optional[Dict[str, float]] = None,
    zipf_a: float = 1.2,
    seed: int = 0,
    write_frac: float = 0.0,
    write_corpus: Optional[tuple] = None,
    delete_pool: Optional[np.ndarray] = None,
    upsert_frac: float = 0.5,
) -> ArrivalTrace:
    """On/off modulated Poisson with mean rate ``rate``: a fraction
    ``burst_frac`` of each ``cycle`` runs at ``burst_factor`` x the off-rate
    (off-rate solved so the time-average stays ``rate``) — the flash-crowd
    shape that stresses queueing and deadline misses."""
    _check_fracs(write_frac, upsert_frac)
    rng = np.random.default_rng(seed)
    # rate_off * (1 - f + f * factor) = rate
    rate_off = rate / (1.0 - burst_frac + burst_frac * burst_factor)
    rate_on = rate_off * burst_factor
    arrivals = np.empty(n_requests)
    t = 0.0
    for i in range(n_requests):
        in_burst = (t % cycle) < burst_frac * cycle
        r = rate_on if in_burst else rate_off
        t += float(rng.exponential(1.0 / r))
        arrivals[i] = t
    reqs = _assemble(arrivals, queries, preds, k, tier_mix or _DEFAULT_MIX,
                     zipf_a, rng, write_frac=write_frac,
                     write_corpus=write_corpus, delete_pool=delete_pool,
                     upsert_frac=upsert_frac)
    return ArrivalTrace(reqs, "bursty", rate, seed)


def make_trace(kind: str, *args, **kwargs) -> ArrivalTrace:
    """Dispatch by shape name — what the CLI driver and benchmarks use."""
    gen = {"poisson": poisson_trace, "bursty": bursty_trace}.get(kind)
    if gen is None:
        raise ValueError(f"unknown trace kind {kind!r} (poisson|bursty)")
    return gen(*args, **kwargs)


# ----------------------------------------------------------------------
# multi-tenant traces
# ----------------------------------------------------------------------
@dataclasses.dataclass
class TenantTraceSpec:
    """One tenant's slice of a multi-tenant arrival stream.

    ``kind="bursty"`` with a large ``burst_factor`` is the configurable
    noisy-neighbor profile: the tenant idles near its off-rate and slams
    ``burst_factor``x that rate for ``burst_frac`` of every ``cycle``
    (see :func:`bursty_trace` — the time-average stays ``rate``)."""

    tenant: str
    queries: np.ndarray
    preds: Sequence[AnyPredicate]
    n_requests: int
    rate: float                              # mean virtual qps for this tenant
    kind: str = "poisson"                    # "poisson" | "bursty"
    k: int = 10
    tier_mix: Optional[Dict[str, float]] = None
    zipf_a: float = 1.2
    burst_factor: float = 8.0                # bursty-only knobs
    burst_frac: float = 0.25
    cycle: float = 0.25


def multi_tenant_trace(
    specs: Sequence[TenantTraceSpec], seed: int = 0
) -> ArrivalTrace:
    """Interleave one seeded sub-trace per tenant into a single stream.

    Each spec generates through the ordinary single-tenant generators with
    its own derived seed (``seed + 1009 * index`` — stable under replay,
    distinct across tenants), every request is tagged with its tenant
    name, and the merged stream is re-numbered with dense global rids in
    ``(t_arrival, spec order, local rid)`` order so the scheduler's
    rid-based tie-breaks stay total and deterministic."""
    if not specs:
        raise ValueError("multi_tenant_trace needs at least one TenantTraceSpec")
    names = [s.tenant for s in specs]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names in specs: {names}")
    tagged = []
    for si, spec in enumerate(specs):
        kw = dict(k=spec.k, tier_mix=spec.tier_mix, zipf_a=spec.zipf_a,
                  seed=seed + 1009 * si)
        if spec.kind == "bursty":
            kw.update(burst_factor=spec.burst_factor,
                      burst_frac=spec.burst_frac, cycle=spec.cycle)
        sub = make_trace(spec.kind, spec.queries, spec.preds,
                         spec.n_requests, spec.rate, **kw)
        for r in sub:
            tagged.append((r.t_arrival, si, r.rid, r))
    tagged.sort(key=lambda x: x[:3])
    reqs = [
        dataclasses.replace(r, rid=rid, tenant=specs[si].tenant)
        for rid, (_, si, _, r) in enumerate(tagged)
    ]
    total_rate = float(sum(s.rate for s in specs))
    return ArrivalTrace(reqs, "multi", total_rate, seed)
