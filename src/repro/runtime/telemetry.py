"""Serving-runtime telemetry: per-request, per-batch, and cache counters.

Two strictly separated ledgers:

* **deterministic** — everything derived from virtual time and executed
  results: completion counts, plan-choice mix, batch-size histogram,
  deadline hits/misses per SLO tier, virtual latency quantiles, the
  fill-rate recall proxy, and the engine's predicate/plan cache counters
  (surfaced through ``backend.stats()``).  Same trace + seed reproduces
  these bit-for-bit (`tests/test_runtime.py`).
* **wall** — measured execution wall time (throughput accounting for the
  benchmarks).  Real clocks are never folded into the deterministic
  ledger.

``snapshot()`` returns both; ``counters()`` returns only the deterministic
part, which is what the replay tests compare.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from ..core.engine import PlannedResult, STRATEGY_NAMES
from .queue import RuntimeRequest

__all__ = ["Telemetry"]


def _quantiles(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0}
    a = np.asarray(xs, np.float64)
    return {
        "p50": float(np.quantile(a, 0.50)),
        "p99": float(np.quantile(a, 0.99)),
        "mean": float(a.mean()),
        "max": float(a.max()),
    }


class Telemetry:
    """Accumulates runtime observations; ``snapshot()`` is the public API."""

    def __init__(self):
        self.n_completed = 0
        self.n_batches = 0
        self.plan_counts: Dict[str, int] = {n: 0 for n in STRATEGY_NAMES.values()}
        # backend-mix: routed (backend:knob) execution counts — strategy
        # name stands in for rows executed before routing existed
        self.backend_counts: Dict[str, int] = {}
        self.batch_sizes: Dict[int, int] = {}
        self.deadline_met: Dict[str, int] = {}
        self.deadline_missed: Dict[str, int] = {}
        self.deadline_flushes = 0           # batches flushed by SLO pressure
        self._lat: Dict[str, List[float]] = {}   # virtual latency per tier
        self._queue_wait: List[float] = []       # virtual arrival -> flush
        self._fill: List[float] = []             # recall proxy: k-slots filled
        self._expansions: List[int] = []         # post-filter effort
        # live-corpus write ledger (deterministic: counts derive from the
        # trace composition, compactions from the backend's churn policy)
        self.n_upserts = 0
        self.n_deletes = 0
        self.n_compactions = 0
        self.wall_exec_s = 0.0                   # measured (NOT deterministic)

    # ------------------------------------------------------------------
    def record_batch(self, reqs: List[RuntimeRequest], results: List[PlannedResult],
                     t_flush: float, t_complete: float,
                     deadline_flush: bool = False) -> None:
        """One executed micro-batch: per-request latency/deadline/plan
        accounting in VIRTUAL time plus batch-level counters."""
        self.n_batches += 1
        self.batch_sizes[len(reqs)] = self.batch_sizes.get(len(reqs), 0) + 1
        if deadline_flush:
            self.deadline_flushes += 1
        for req, res in zip(reqs, results):
            self.n_completed += 1
            self.plan_counts[STRATEGY_NAMES[res.decision]] += 1
            bk = getattr(res.result, "backend", "") or STRATEGY_NAMES[res.decision]
            knob = getattr(res.result, "knob", "")
            key = f"{bk}:{knob}" if knob else bk
            self.backend_counts[key] = self.backend_counts.get(key, 0) + 1
            lat = t_complete - req.t_arrival
            self._lat.setdefault(req.tier, []).append(lat)
            self._queue_wait.append(t_flush - req.t_arrival)
            bucket = self.deadline_met if t_complete <= req.deadline else self.deadline_missed
            bucket[req.tier] = bucket.get(req.tier, 0) + 1
            ids = res.result.ids
            self._fill.append(float((ids >= 0).sum()) / max(ids.size, 1))
            self._expansions.append(res.result.n_expansions)

    def record_wall(self, seconds: float) -> None:
        self.wall_exec_s += seconds

    def record_writes(self, n_upsert_rows: int, n_delete_rows: int,
                      n_compactions: int = 0) -> None:
        """Row counts from one batch's applied writes (virtual ledger)."""
        self.n_upserts += n_upsert_rows
        self.n_deletes += n_delete_rows
        self.n_compactions += n_compactions

    # ------------------------------------------------------------------
    def counters(self) -> Dict:
        """The deterministic ledger only (what replay tests compare)."""
        return {
            "n_completed": self.n_completed,
            "n_batches": self.n_batches,
            "plan_counts": dict(self.plan_counts),
            "backend_counts": dict(sorted(self.backend_counts.items())),
            "batch_sizes": dict(sorted(self.batch_sizes.items())),
            "deadline_met": dict(sorted(self.deadline_met.items())),
            "deadline_missed": dict(sorted(self.deadline_missed.items())),
            "deadline_flushes": self.deadline_flushes,
            "n_upserts": self.n_upserts,
            "n_deletes": self.n_deletes,
            "n_compactions": self.n_compactions,
            "fill_rate": round(float(np.mean(self._fill)) if self._fill else 0.0, 6),
            "mean_expansions": round(
                float(np.mean(self._expansions)) if self._expansions else 0.0, 6
            ),
        }

    def snapshot(self, backend=None) -> Dict:
        """Full state: deterministic counters + virtual latency quantiles
        (per tier and overall) + measured wall stats + the backend's cache
        counters when it exposes ``stats()`` (both engines do)."""
        all_lat = [x for xs in self._lat.values() for x in xs]
        out = dict(self.counters())
        out["latency_virtual"] = _quantiles(all_lat)
        out["latency_by_tier"] = {t: _quantiles(xs) for t, xs in sorted(self._lat.items())}
        out["queue_wait_virtual"] = _quantiles(self._queue_wait)
        out["wall"] = {
            "exec_s": self.wall_exec_s,
            "throughput_qps": (
                self.n_completed / self.wall_exec_s if self.wall_exec_s > 0 else 0.0
            ),
        }
        stats = getattr(backend, "stats", None)
        if callable(stats):
            out["engine"] = stats()
        return out
