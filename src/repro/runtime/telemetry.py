"""Serving-runtime telemetry: per-request, per-batch, and cache counters.

Two strictly separated ledgers:

* **deterministic** — everything derived from virtual time and executed
  results: completion counts, plan-choice mix, batch-size histogram,
  deadline hits/misses per SLO tier, virtual latency quantiles, the
  fill-rate recall proxy, and the engine's predicate/plan cache counters
  (surfaced through ``backend.stats()``).  Same trace + seed reproduces
  these bit-for-bit (`tests/test_runtime.py`).
* **wall** — measured execution wall time (throughput accounting for the
  benchmarks).  Real clocks are never folded into the deterministic
  ledger.

The counter ledger itself lives in a :class:`repro.obs.MetricsRegistry`
(``self.registry``) under stable metric names (``repro_requests_total``,
``repro_plan_total{plan=}``, ``repro_deadline_total{tier=,outcome=}``,
...), so the same numbers export as a Prometheus text page or JSON
snapshot with zero double-counting; a fleet shares ONE registry across
tenants via a ``tenant`` label.  The legacy field/``counters()`` shapes
are preserved exactly on top — replay tests compare them bit-for-bit.

``snapshot()`` returns both ledgers; ``counters()`` returns only the
deterministic part, which is what the replay tests compare.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from ..core.engine import PlannedResult, STRATEGY_NAMES
from ..obs.metrics import MetricsRegistry
from .queue import RuntimeRequest

__all__ = ["Telemetry"]


def _quantiles(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0}
    a = np.asarray(xs, np.float64)
    return {
        "p50": float(np.quantile(a, 0.50)),
        "p99": float(np.quantile(a, 0.99)),
        "mean": float(a.mean()),
        "max": float(a.max()),
    }


class Telemetry:
    """Accumulates runtime observations; ``snapshot()`` is the public API.

    ``registry`` lets several telemetries share one
    :class:`MetricsRegistry` (the fleet does, distinguishing tenants by
    ``labels={"tenant": name}``); by default each instance owns a fresh
    one.  Every legacy counter field (``plan_counts``, ``deadline_met``,
    ...) is a property reading back the registry, so the two views can
    never disagree.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 labels: Optional[Dict[str, str]] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.labels = dict(labels or {})
        # pre-create the fixed enumerations at zero so snapshots show the
        # full plan space before the first request lands
        self._inc("repro_requests_total", 0)
        self._inc("repro_batches_total", 0)
        self._inc("repro_deadline_flush_total", 0)
        self._inc("repro_compactions_total", 0)
        for n in (*STRATEGY_NAMES.values(), "dnf"):
            self._inc("repro_plan_total", 0, plan=n)
        for op in ("upsert", "delete"):
            self._inc("repro_writes_total", 0, op=op)
        # raw per-request VIRTUAL samples stay local: quantiles need the
        # actual values, not histogram buckets (still deterministic)
        self._lat: Dict[str, List[float]] = {}   # virtual latency per tier
        self._queue_wait: List[float] = []       # virtual arrival -> flush
        self._fill: List[float] = []             # recall proxy: k-slots filled
        self._expansions: List[int] = []         # post-filter effort
        self.wall_exec_s = 0.0                   # measured (NOT deterministic)

    # -- registry plumbing ---------------------------------------------
    def _inc(self, name: str, value: float = 1, **labels) -> None:
        self.registry.inc(name, value, **{**self.labels, **labels})

    def _value(self, name: str, **labels) -> float:
        return self.registry.value(name, 0, **{**self.labels, **labels})

    def _label_map(self, name: str, key: str, **match) -> Dict[str, int]:
        """``{series[key]: value}`` over this telemetry's series of a
        metric (scoped to ``self.labels`` — tenant isolation on a shared
        fleet registry)."""
        out: Dict[str, int] = {}
        for lbl, v in self.registry.series(name, match={**self.labels, **match}):
            out[lbl[key]] = int(v)
        return out

    # -- recording ------------------------------------------------------
    def record_batch(self, reqs: List[RuntimeRequest], results: List[PlannedResult],
                     t_flush: float, t_complete: float,
                     deadline_flush: bool = False) -> None:
        """One executed micro-batch: per-request latency/deadline/plan
        accounting in VIRTUAL time plus batch-level counters."""
        self._inc("repro_batches_total")
        self._inc("repro_batch_size_total", size=len(reqs))
        if deadline_flush:
            self._inc("repro_deadline_flush_total")
        for req, res in zip(reqs, results):
            self._inc("repro_requests_total")
            # plan-mix: per-disjunct DNF plans count under their own "dnf"
            # dimension, not the dominant clause's strategy
            plan = getattr(res, "plan", None)
            plan_name = (plan.strategy if plan is not None
                         else STRATEGY_NAMES[res.decision])
            self._inc("repro_plan_total", plan=plan_name)
            # backend-mix: routed (backend:knob) execution counts — strategy
            # name stands in for rows executed before routing existed
            bk = getattr(res.result, "backend", "") or plan_name
            knob = getattr(res.result, "knob", "")
            self._inc("repro_route_total",
                      route=f"{bk}:{knob}" if knob else bk)
            lat = t_complete - req.t_arrival
            self._lat.setdefault(req.tier, []).append(lat)
            self.registry.observe("repro_latency_virtual_seconds", lat,
                                  tier=req.tier, **self.labels)
            self._queue_wait.append(t_flush - req.t_arrival)
            outcome = "met" if t_complete <= req.deadline else "missed"
            self._inc("repro_deadline_total", tier=req.tier, outcome=outcome)
            ids = res.result.ids
            self._fill.append(float((ids >= 0).sum()) / max(ids.size, 1))
            self._expansions.append(res.result.n_expansions)

    def record_wall(self, seconds: float) -> None:
        self.wall_exec_s += seconds

    def record_writes(self, n_upsert_rows: int, n_delete_rows: int,
                      n_compactions: int = 0) -> None:
        """Row counts from one batch's applied writes (virtual ledger)."""
        self._inc("repro_writes_total", n_upsert_rows, op="upsert")
        self._inc("repro_writes_total", n_delete_rows, op="delete")
        self._inc("repro_compactions_total", n_compactions)

    # -- legacy field compat (read back from the registry) --------------
    @property
    def n_completed(self) -> int:
        return int(self._value("repro_requests_total"))

    @property
    def n_batches(self) -> int:
        return int(self._value("repro_batches_total"))

    @property
    def plan_counts(self) -> Dict[str, int]:
        m = self._label_map("repro_plan_total", "plan")
        return {n: m.get(n, 0) for n in (*STRATEGY_NAMES.values(), "dnf")}

    @property
    def backend_counts(self) -> Dict[str, int]:
        return self._label_map("repro_route_total", "route")

    @property
    def batch_sizes(self) -> Dict[int, int]:
        m = self._label_map("repro_batch_size_total", "size")
        return {int(s): c for s, c in m.items()}

    @property
    def deadline_met(self) -> Dict[str, int]:
        return self._label_map("repro_deadline_total", "tier", outcome="met")

    @property
    def deadline_missed(self) -> Dict[str, int]:
        return self._label_map("repro_deadline_total", "tier", outcome="missed")

    @property
    def deadline_flushes(self) -> int:
        return int(self._value("repro_deadline_flush_total"))

    @property
    def n_upserts(self) -> int:
        return int(self._value("repro_writes_total", op="upsert"))

    @property
    def n_deletes(self) -> int:
        return int(self._value("repro_writes_total", op="delete"))

    @property
    def n_compactions(self) -> int:
        return int(self._value("repro_compactions_total"))

    # ------------------------------------------------------------------
    def counters(self) -> Dict:
        """The deterministic ledger only (what replay tests compare)."""
        return {
            "n_completed": self.n_completed,
            "n_batches": self.n_batches,
            "plan_counts": dict(self.plan_counts),
            "backend_counts": dict(sorted(self.backend_counts.items())),
            "batch_sizes": dict(sorted(self.batch_sizes.items())),
            "deadline_met": dict(sorted(self.deadline_met.items())),
            "deadline_missed": dict(sorted(self.deadline_missed.items())),
            "deadline_flushes": self.deadline_flushes,
            "n_upserts": self.n_upserts,
            "n_deletes": self.n_deletes,
            "n_compactions": self.n_compactions,
            "fill_rate": round(float(np.mean(self._fill)) if self._fill else 0.0, 6),
            "mean_expansions": round(
                float(np.mean(self._expansions)) if self._expansions else 0.0, 6
            ),
        }

    def snapshot(self, backend=None) -> Dict:
        """Full state: deterministic counters + virtual latency quantiles
        (per tier and overall) + measured wall stats + the backend's cache
        counters when it exposes ``stats()`` (both engines do)."""
        all_lat = [x for xs in self._lat.values() for x in xs]
        out = dict(self.counters())
        out["latency_virtual"] = _quantiles(all_lat)
        out["latency_by_tier"] = {t: _quantiles(xs) for t, xs in sorted(self._lat.items())}
        out["queue_wait_virtual"] = _quantiles(self._queue_wait)
        out["wall"] = {
            "exec_s": self.wall_exec_s,
            "throughput_qps": (
                self.n_completed / self.wall_exec_s if self.wall_exec_s > 0 else 0.0
            ),
        }
        stats = getattr(backend, "stats", None)
        if callable(stats):
            out["engine"] = stats()
        return out
