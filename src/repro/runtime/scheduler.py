"""Continuous micro-batcher over the batched plan->execute pipeline.

``OnlineRuntime`` drives a discrete-event loop in VIRTUAL time:

1. admit arrivals from the trace into the :class:`RequestQueue`;
2. form a micro-batch when a flush trigger fires — batch full
   (``max_batch``), oldest request waited ``max_wait``, or **deadline
   pressure**: the tightest pending deadline leaves no slack for the
   estimated service time, so tight-SLO requests preempt batch formation
   instead of waiting out ``max_wait`` behind bulk traffic;
3. execute the batch for real through ``backend.batch_query`` (the
   decision-grouped pipeline from ``core/engine.py``: one plan pass, one
   mask eval + fused top-k per distinct pre-filter predicate, shared IVF
   dispatches for the post group; query/batch axes pow2-padded inside the
   executors, so ``max_batch`` is required to be a power of two and the
   compile-shape set stays O(log B));
4. charge a deterministic virtual service time (:class:`ServiceModel`)
   against a serially-busy server, record telemetry, feed sampled
   outcomes to the planner feedback loop.

The split between real execution and virtual timing is the replay
guarantee: result ids are produced by the actual engine, but batch
composition and every latency/deadline statistic derive only from the
trace and the cost model — never from measured wall time — so the same
trace + seed reproduces the run bit-for-bit.  Measured wall time is
still tracked (``Telemetry.record_wall``) for throughput benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from ..core.engine import PlannedResult
from ..core.planner import INDEXED_PRE, POST_FILTER, PRE_FILTER
from .queue import ArrivalTrace, RequestQueue
from .telemetry import Telemetry

__all__ = ["SchedulerConfig", "ServiceModel", "OnlineRuntime", "RuntimeReport"]


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 64        # pow2: the pipeline pads batches to pow2 anyway
    max_wait: float = 0.005    # virtual s the oldest request may age unflushed
    slo_slack: float = 0.0     # extra virtual s reserved when checking deadlines

    def __post_init__(self):
        assert self.max_batch >= 1 and (self.max_batch & (self.max_batch - 1)) == 0, \
            "max_batch must be a power of two (the executors pad to pow2)"
        assert self.max_wait >= 0.0


@dataclasses.dataclass
class ServiceModel:
    """Deterministic virtual service-time model for one micro-batch.

    ``dispatch`` is the fixed per-batch cost (planning + kernel launch);
    ``per_row`` charges each request by its planned decision (indexed
    pre-filtering is the cheapest path, the post-filter IVF probe sits in
    between, the columnar-scan pre-filter is the dearest).  The defaults
    are shaped like the measured 100k-fixture costs but deliberately
    FIXED constants: calibrating them from live measurements would leak
    wall-clock noise into batch composition and break replay.
    """

    dispatch: float = 2e-3
    per_row: Dict[int, float] = dataclasses.field(default_factory=lambda: {
        PRE_FILTER: 4e-4, POST_FILTER: 3e-4, INDEXED_PRE: 1.5e-4,
    })
    # live-corpus write costs (virtual s): per upserted/deleted row plus a
    # flat charge when a compaction (index rebuild) triggers inside a batch
    upsert_row: float = 2.5e-4
    delete_row: float = 1e-4
    compaction: float = 5e-2

    def time(self, decisions, n_upsert_rows: int = 0, n_delete_rows: int = 0,
             n_compactions: int = 0) -> float:
        return (self.dispatch
                + float(sum(self.per_row[int(d)] for d in decisions))
                + n_upsert_rows * self.upsert_row
                + n_delete_rows * self.delete_row
                + n_compactions * self.compaction)

    def estimate(self, n_rows: int) -> float:
        """Pessimistic pre-execution estimate (decisions unknown yet) —
        what the deadline-pressure trigger budgets with."""
        return self.dispatch + n_rows * max(self.per_row.values())


@dataclasses.dataclass
class RuntimeReport:
    """Everything a trace replay produced, keyed for determinism checks."""

    results: Dict[int, PlannedResult]          # rid -> planned result
    batches: List[List[int]]                   # flush-order batch compositions
    telemetry: Telemetry

    def ids(self, rid: int) -> np.ndarray:
        return self.results[rid].result.ids[0]


class OnlineRuntime:
    """Deadline-aware continuous micro-batching over a query backend.

    ``backend`` is anything with ``batch_query(queries, preds, k) ->
    List[PlannedResult]`` — the flat :class:`FilteredANNEngine` or the
    sharded :class:`ShardedANNEngine` fan-out.  ``feedback`` (optional) is
    an :class:`OnlineFeedback` loop observing sampled outcomes and
    refitting the planner between batches.  ``tracer`` (optional,
    :class:`repro.obs.Tracer`) is installed on the backend for the run:
    each flushed micro-batch opens a root ``batch`` span over the
    backend's plan/execute/write spans.  ``probe`` (optional,
    :class:`repro.obs.RecallProbe`) races a seeded sample of served reads
    against the exact oracle; its backend defaults to this runtime's.
    """

    def __init__(self, backend, config: Optional[SchedulerConfig] = None,
                 service: Optional[ServiceModel] = None, feedback=None,
                 tracer=None, probe=None):
        self.backend = backend
        self.config = config or SchedulerConfig()
        self.service = service or ServiceModel()
        self.feedback = feedback
        self.tracer = tracer
        self.probe = probe
        if probe is not None and probe.backend is None:
            probe.backend = backend

    # ------------------------------------------------------------------
    def _next_flush(self, queue: RequestQueue, now: float):
        """(t_flush, deadline_pressure): the earliest virtual time a flush
        trigger fires for the current queue, evaluated deterministically."""
        cfg = self.config
        t_wait = queue.oldest_arrival + cfg.max_wait
        t_slo = queue.tightest_deadline - self.service.estimate(
            min(len(queue), cfg.max_batch)) - cfg.slo_slack
        return max(now, min(t_wait, t_slo)), t_slo <= t_wait

    def run_trace(self, trace: ArrivalTrace, telemetry: Optional[Telemetry] = None,
                  ) -> RuntimeReport:
        """Replay one arrival trace to completion."""
        from ..obs.trace import NULL_TRACER

        cfg = self.config
        tel = telemetry or Telemetry()
        tr = self.tracer if self.tracer is not None else NULL_TRACER
        if self.tracer is not None and hasattr(self.backend, "set_tracer"):
            self.backend.set_tracer(self.tracer)
        queue = RequestQueue()
        reqs = sorted(trace.requests, key=lambda r: (r.t_arrival, r.rid))
        results: Dict[int, PlannedResult] = {}
        batches: List[List[int]] = []
        i = 0
        now = 0.0          # virtual clock
        busy_until = 0.0   # server is serial: next batch starts after this
        n = len(reqs)
        while i < n or queue:
            if not queue:
                now = max(now, reqs[i].t_arrival)
            while i < n and reqs[i].t_arrival <= now:
                queue.push(reqs[i])
                i += 1
            # the server frees at busy_until; nothing can flush before that
            now = max(now, busy_until) if queue else now
            while i < n and reqs[i].t_arrival <= now:
                queue.push(reqs[i])
                i += 1
            deadline_flush = False
            if len(queue) < cfg.max_batch:
                t_flush, pressure = self._next_flush(queue, now)
                t_next = reqs[i].t_arrival if i < n else np.inf
                if t_next <= t_flush:
                    # an arrival lands before any trigger: admit it first
                    now = max(now, t_next)
                    continue
                now, deadline_flush = t_flush, pressure
            batch = queue.pop(cfg.max_batch)
            rids = [r.rid for r in batch]
            batches.append(rids)
            # writes apply BEFORE this batch's reads (rid order within the
            # batch — deterministic), so a read flushed alongside a delete
            # already sees the tombstone; compaction runs through the
            # backend's own churn policy, never on a wall clock
            writes = sorted((r for r in batch if r.op != "query"),
                            key=lambda r: r.rid)
            reads = [r for r in batch if r.op == "query"]
            n_up = n_del = n_comp = 0
            w0 = time.perf_counter()
            res: List[Optional[PlannedResult]] = [None] * len(reads)
            with tr.span("batch", n_reads=len(reads), n_writes=len(writes),
                         deadline_flush=bool(deadline_flush)):
                for r in writes:
                    if r.op == "upsert":
                        self.backend.upsert(*r.payload)
                        n_up += len(r.payload[0])
                    else:
                        self.backend.delete(*r.payload)
                        n_del += len(r.payload[0])
                if writes and self.backend.maybe_compact() is not None:
                    n_comp = 1
                if reads:
                    q = np.stack([r.query for r in reads]).astype(np.float32)
                    # the trace generators emit one k per trace; grouping by
                    # k here keeps mixed-k traces correct without
                    # complicating composition
                    by_k: Dict[int, List[int]] = {}
                    for j, r in enumerate(reads):
                        by_k.setdefault(r.k, []).append(j)
                    for k, rows in by_k.items():
                        out = self.backend.batch_query(
                            q[rows], [reads[j].pred for j in rows], k)
                        for j, r in zip(rows, out):
                            res[j] = r
            tel.record_wall(time.perf_counter() - w0)
            service = self.service.time(
                [r.decision for r in res],
                n_upsert_rows=n_up, n_delete_rows=n_del, n_compactions=n_comp,
            )
            t_complete = now + service
            busy_until = t_complete
            if writes:
                tel.record_writes(n_up, n_del, n_comp)
            if reads:
                tel.record_batch(reads, res, now, t_complete, deadline_flush)
            for r_req, r_res in zip(reads, res):
                results[r_req.rid] = r_res
            if self.probe is not None:
                # oracle races run OUTSIDE the batch span: probing is
                # observability overhead, not serving work
                for r_req, r_res in zip(reads, res):
                    self.probe.observe(r_req, r_res)
            if self.feedback is not None:
                for r_req, r_res in zip(reads, res):
                    self.feedback.observe(r_req, r_res)
                self.feedback.maybe_refit()
        return RuntimeReport(results, batches, tel)
