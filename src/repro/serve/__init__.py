from .engine import ServeEngine, Request
from .retrieval import RetrievalAugmentedServer

__all__ = ["ServeEngine", "Request", "RetrievalAugmentedServer"]
