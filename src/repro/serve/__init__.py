from .engine import ServeEngine, Request, ShardedANNEngine
from .retrieval import RetrievalAugmentedServer

__all__ = ["ServeEngine", "Request", "ShardedANNEngine", "RetrievalAugmentedServer"]
