"""Batched serving engine with continuous-batching-lite + sharded ANN path.

A fixed-size decode batch of slots; finished sequences are swapped for
queued requests between steps (the decode step itself is one jit'd program,
so slot replacement costs one host round-trip — the standard continuous
batching trade-off).  Greedy sampling (argmax) keeps the examples
deterministic; temperature sampling is a flag.

``ShardedANNEngine`` is the serving-side face of the distribution layer
(``repro.dist``): the filtered-ANN corpus is partitioned across the data
axis via ``FilteredANNEngine.shard_corpus``, each shard runs the SAME
planned strategy over its rows, and per-shard top-k results are merged
exactly with ``repro.dist.collectives.merge_topk``.  Planning happens once
per query (selectivity + strategy depend on dataset statistics, not on
row placement), so plan overhead does not grow with the shard count.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.engine import FilteredANNEngine, PlannedResult, package_results
from ..core.executors import SearchResult
from ..core.predicates import AnyPredicate
from ..dist.collectives import merge_topk
from ..models.model import Model
from ..obs.trace import NULL_TRACER

__all__ = ["Request", "ServeEngine", "ShardedANNEngine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, batch_slots: int = 8, max_len: int = 512,
                 eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, b, lens: model.prefill(p, b, max_len, lengths=lens)
        )

    # ------------------------------------------------------------------
    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve all requests to completion; returns uid -> generated ids."""
        queue = list(requests)
        results: Dict[int, List[int]] = {}
        while queue:
            batch = queue[: self.slots]
            queue = queue[self.slots :]
            self._serve_batch(batch)
            for r in batch:
                results[r.uid] = r.out_tokens
        return results

    def _serve_batch(self, batch: List[Request]):
        b = len(batch)
        # left-align prompts into one padded matrix for a single prefill; the
        # model gathers each row's logits at its true last position (plens-1),
        # so unequal-length prompts decode exactly as batch=1 runs (pad-slot
        # kv entries sit beyond each row's length mask and are overwritten as
        # decode advances)
        plens = np.array([len(r.prompt) for r in batch], np.int32)
        # models that carry recurrent prefill state fold pad steps into it,
        # so unequal-length batching is NOT exact for them — refuse rather
        # than silently diverge from batch=1 runs (the model declares this
        # via Model.supports_ragged_prefill, keeping the family knowledge
        # where the state lives)
        ragged_ok = getattr(self.model, "supports_ragged_prefill", True)
        if not ragged_ok and len(set(plens.tolist())) > 1:
            raise ValueError(
                "this model carries recurrent prefill state, which pad "
                "tokens pollute: serve equal-length prompt batches "
                f"(got lengths {sorted(set(plens.tolist()))})"
            )
        s = int(plens.max())
        toks = np.zeros((b, s), np.int32)
        for i, r in enumerate(batch):
            toks[i, : plens[i]] = r.prompt
        lengths = jnp.asarray(plens)
        logits, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)}, lengths
        )
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        host = np.asarray(next_tok)
        for i, r in enumerate(batch):
            t = int(host[i])
            r.out_tokens = [t]
            if (self.eos_id is not None and t == self.eos_id) or r.max_new_tokens <= 1:
                r.done = True
        max_new = max(r.max_new_tokens for r in batch)
        for _ in range(max_new - 1):
            if all(r.done for r in batch):
                break  # every slot hit EOS/its budget: stop paying decode steps
            logits, cache = self._decode(self.params, cache, next_tok, lengths)
            lengths = lengths + 1
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            host = np.asarray(next_tok)
            for i, r in enumerate(batch):
                if len(r.out_tokens) < r.max_new_tokens and not r.done:
                    t = int(host[i])
                    r.out_tokens.append(t)
                    if self.eos_id is not None and t == self.eos_id:
                        r.done = True
                if len(r.out_tokens) >= r.max_new_tokens:
                    r.done = True
        for r in batch:
            r.done = True


class ShardedANNEngine:
    """Sharded filtered-ANN query path: plan once, fan out, merge top-k.

    Wraps a :class:`FilteredANNEngine` with at least ``build_stats()`` run
    (a sharded deployment doesn't need the global index that ``build()``
    additionally constructs; ``fit()`` for a trained planner does).  The
    corpus is partitioned into ``n_shards`` contiguous shards (defaulting
    to the device count — one shard per data-axis slot); each query is

    1. planned centrally (selectivity estimate + pre/post decision),
    2. executed on every shard with the decided strategy (both executor
       kinds run per-shard via the ``shard_corpus`` hook),
    3. merged: shard-local top-k lists concat + re-top-k, which is exact
       because any global top-k element is in its own shard's top-k.
    """

    def __init__(self, engine: FilteredANNEngine, n_shards: Optional[int] = None,
                 n_lists: Optional[int] = None):
        self.engine = engine
        self.n_shards = n_shards or max(1, len(jax.devices()))
        self._n_lists = n_lists
        self.shards = engine.shard_corpus(self.n_shards, n_lists=n_lists)
        self.tracer = NULL_TRACER
        self._build_locators()

    def set_tracer(self, tracer) -> None:
        """Install a :class:`repro.obs.Tracer` on the fan-out AND the
        central engine (planning/write spans come from the latter)."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.engine.set_tracer(tracer)

    # ------------------------------------------------------------------
    def _build_locators(self) -> None:
        """Global handle -> (owning shard, shard-local handle).  Positions
        within ``shard.ids`` ARE the local handles (``upsert_local`` appends
        to both arrays in lockstep and deletes never remove entries), so the
        locator is just the inverse of the per-shard id lists."""
        n_total = self.engine.live.n_total
        self._loc_shard = np.full(n_total, -1, np.int32)
        self._loc_pos = np.full(n_total, -1, np.int64)
        for si, s in enumerate(self.shards):
            self._loc_shard[s.ids] = si
            self._loc_pos[s.ids] = np.arange(len(s.ids), dtype=np.int64)

    def _grow_locators(self, n_total: int) -> None:
        pad = n_total - len(self._loc_shard)
        if pad > 0:
            self._loc_shard = np.concatenate(
                [self._loc_shard, np.full(pad, -1, np.int32)])
            self._loc_pos = np.concatenate(
                [self._loc_pos, np.full(pad, -1, np.int64)])

    def _delete_on_shards(self, gids: np.ndarray) -> None:
        gids = np.asarray(gids, np.int64).ravel()
        gids = gids[(gids >= 0) & (gids < len(self._loc_shard))]
        for si, s in enumerate(self.shards):
            sel = gids[self._loc_shard[gids] == si]
            if sel.size:
                s.delete_local(self._loc_pos[sel])

    # ------------------------------------------------------------------
    def upsert(self, vectors: np.ndarray, cat: np.ndarray,
               num: np.ndarray, ids: Optional[np.ndarray] = None) -> np.ndarray:
        """Insert (or replace, when ``ids`` is given) rows: the central
        engine assigns global handles and updates planning state; each new
        row is then placed on a shard (``handle % n_shards``) so shard-local
        search sees it immediately.  Returns the global handles."""
        v = np.atleast_2d(np.asarray(vectors, np.float32))
        c = np.atleast_2d(np.asarray(cat))
        m = np.atleast_2d(np.asarray(num))
        gids = self.engine.upsert(v, c, m, ids=ids)
        if ids is not None:
            # the central engine already tombstoned the replaced handles;
            # propagate to whichever shards own them (idempotent bit-set)
            self._delete_on_shards(np.asarray(ids))
        self._grow_locators(self.engine.live.n_total)
        owner = (gids % len(self.shards)).astype(np.int32)
        for si, s in enumerate(self.shards):
            rows = np.nonzero(owner == si)[0]
            if not rows.size:
                continue
            lh = s.upsert_local(v[rows], c[rows], m[rows],
                                global_ids=gids[rows])
            self._loc_shard[gids[rows]] = si
            self._loc_pos[gids[rows]] = lh
        return gids

    def delete(self, ids: np.ndarray) -> np.ndarray:
        """Tombstone global handles centrally AND on their owning shards;
        returns the handles that were newly deleted."""
        fresh = self.engine.delete(ids)
        self._delete_on_shards(fresh)
        return fresh

    def needs_compaction(self) -> bool:
        return self.engine.needs_compaction()

    def compact(self) -> np.ndarray:
        """Fold segment + tombstones into a rebuilt central engine, then
        re-shard the compacted corpus (old shard objects are dropped whole —
        per-shard live state is baked into the new partitions).  Returns the
        old-handle -> new-position ``id_map``."""
        id_map = self.engine.compact()
        self.shards = self.engine.shard_corpus(self.n_shards,
                                               n_lists=self._n_lists)
        self._build_locators()
        return id_map

    def maybe_compact(self) -> Optional[np.ndarray]:
        if self.engine.live.dirty and self.needs_compaction():
            return self.compact()
        return None

    def reshard(self, n_shards: int) -> "ShardedANNEngine":
        """Repartition a LIVE deployment onto ``n_shards`` shards in place —
        the elastic-autoscale hook (`repro.fleet.autoscale`) and the
        dead-shard recovery path (`dist.fault` + `dist.elastic.replan_mesh`
        decide the new count; this applies it).

        The central engine is the source of truth for every row, so the
        old shard objects are dropped whole: the base corpus re-partitions
        through ``shard_corpus``, segment rows (upserts since the last
        compaction) are re-placed under the same ``gid % n_shards`` owner
        rule the streaming path uses, tombstones re-apply through the
        rebuilt locator arrays, and queries keep merging exactly — any
        global top-k element is still in its owning shard's top-k
        regardless of the partition.  Deterministic: per-shard builds are
        seeded by shard index, so the same (corpus state, n_shards) pair
        always produces the same shards."""
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        live = self.engine.live
        self.n_shards = n_shards
        self.shards = self.engine.shard_corpus(n_shards, n_lists=self._n_lists)
        self._build_locators()          # covers base rows; segment rows next
        if live.seg_n:
            gids = np.arange(live.base_n, live.n_total, dtype=np.int64)
            v = np.atleast_2d(live.seg_vectors())
            c, m = np.atleast_2d(live.seg_cat()), np.atleast_2d(live.seg_num())
            self._grow_locators(live.n_total)
            owner = (gids % len(self.shards)).astype(np.int32)
            for si, s in enumerate(self.shards):
                rows = np.nonzero(owner == si)[0]
                if not rows.size:
                    continue
                lh = s.upsert_local(v[rows], c[rows], m[rows],
                                    global_ids=gids[rows])
                self._loc_shard[gids[rows]] = si
                self._loc_pos[gids[rows]] = lh
        if live.n_deleted:
            from ..filter.bitmap import expand_words

            dead = np.nonzero(expand_words(live.tomb, live.n_total))[0]
            self._delete_on_shards(dead)
        return self

    # ------------------------------------------------------------------
    def query(self, q: np.ndarray, pred: AnyPredicate, k: int = 10) -> PlannedResult:
        q = np.atleast_2d(q)
        tr = self.tracer
        plan, plan_overhead = self.engine.make_plan(pred, k)
        if plan.is_dnf:
            # per-disjunct: fan the expanded clause rows out as a batch —
            # the generic path already does exactly this for B == 1
            return self._fanout(q, [pred], k, [plan], plan_overhead)[0]
        est, decision, route = plan.est, plan.decision, plan.route
        t0 = time.perf_counter()
        with tr.span("shard_fanout", n_shards=len(self.shards), n_queries=1):
            per_shard = [s.search(q, pred, k, decision, est, route=route)
                         for s in self.shards]
        with tr.span("merge", n_shards=len(self.shards), k=int(k)):
            d, i = merge_topk(
                np.stack([r.dists for r in per_shard]),
                np.stack([r.ids for r in per_shard]),
                k,
            )
        elapsed = time.perf_counter() - t0 + plan_overhead
        res = SearchResult(
            d, i, elapsed, per_shard[0].strategy,
            n_expansions=max(r.n_expansions for r in per_shard),
            backend=per_shard[0].backend, knob=per_shard[0].knob,
        )
        if not res.backend:
            res.backend, res.knob = plan.backend, plan.knob
        return PlannedResult(res, plan, plan_overhead)

    def explain(self, pred: AnyPredicate, k: int = 10) -> str:
        """Pretty-print the central planner's :class:`ExecutionPlan` for
        ``(pred, k)`` without executing (plans are shard-independent)."""
        return self.engine.explain(pred, k)

    def batch_query(self, queries: np.ndarray, preds: Sequence[AnyPredicate],
                    k: int = 10) -> List[PlannedResult]:
        """Batched sharded path: plan the whole batch ONCE, fan the batch —
        not single queries — out to every shard (each shard runs its
        decision-grouped executors over all B rows), then merge all shards'
        (B, k) results with one batched ``merge_topk``.  DNF rows expand to
        one fan-out row per clause and collapse after the shard merge with
        cross-clause de-duplication.  Ids are identical to B independent
        :meth:`query` calls; per-result ``elapsed`` is the fan-out+merge
        wall time split evenly across rows."""
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        plans, plan_overhead = self.engine.make_plan_batch(preds, k)
        return self._fanout(queries, preds, k, plans, plan_overhead)

    def _fanout(self, queries: np.ndarray, preds: Sequence[AnyPredicate],
                k: int, plans, plan_overhead: float) -> List[PlannedResult]:
        from ..core.plan import collapse_clause_results, expand_for_execution

        b = len(preds)
        plan_share = plan_overhead / max(b, 1)
        exp_rows, exp_preds, decisions, ests, routes, row_map = (
            expand_for_execution(preds, plans))
        identity = len(exp_preds) == b and all(len(m) == 1 for m in row_map)
        xq = queries if identity else queries[exp_rows]
        tr = self.tracer
        t0 = time.perf_counter()
        per_shard = []
        with tr.span("shard_fanout", n_shards=len(self.shards),
                     n_queries=len(exp_preds)):
            for si, s in enumerate(self.shards):
                with tr.span("shard", shard=si):
                    per_shard.append(
                        s.search_batch(xq, exp_preds, k, decisions, ests,
                                       routes=routes, tracer=tr))
        with tr.span("merge", n_shards=len(self.shards), k=int(k)):
            d, i = merge_topk(
                np.stack([r[0] for r in per_shard]),
                np.stack([r[1] for r in per_shard]),
                k,
            )
            rounds = np.max(np.stack([r[2] for r in per_shard]), axis=0)
            if not identity:
                d, i, rounds = collapse_clause_results(d, i, rounds, row_map, k)
        share = (time.perf_counter() - t0) / max(b, 1) + plan_share
        return package_results(d, i, rounds, plans, share, plan_share)

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Central engine counters (plan cache, estimator-side predicate
        cache) plus the per-shard predicate caches aggregated — each shard
        compiles its own bitmaps, so hit rates are summed across shards."""
        out = self.engine.stats()
        agg = {"hits": 0, "misses": 0, "evictions": 0, "size": 0,
               "invalidations": 0}
        n_caches = 0
        for s in self.shards:
            cache = getattr(s.ipre_exec, "cache", None) if s.ipre_exec else None
            if cache is None:
                continue
            n_caches += 1
            cs = cache.stats()
            for key in agg:
                agg[key] += cs[key]
        if n_caches:
            agg["n_shards"] = n_caches
            out["shard_pred_cache"] = agg
        return out

    def runtime(self, config=None, service=None, feedback=None, tracer=None,
                probe=None):
        """Runtime-backed serving entrypoint: a deadline-aware
        :class:`repro.runtime.OnlineRuntime` micro-batching onto this
        sharded engine's ``batch_query`` fan-out.  Lazy import keeps
        ``repro.serve`` importable without the runtime layer and avoids a
        package cycle."""
        from ..runtime import OnlineRuntime

        return OnlineRuntime(self, config=config, service=service,
                             feedback=feedback, tracer=tracer, probe=probe)
