"""Batched serving engine with continuous-batching-lite.

A fixed-size decode batch of slots; finished sequences are swapped for
queued requests between steps (the decode step itself is one jit'd program,
so slot replacement costs one host round-trip — the standard continuous
batching trade-off).  Greedy sampling (argmax) keeps the examples
deterministic; temperature sampling is a flag.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.model import Model

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, batch_slots: int = 8, max_len: int = 512,
                 eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))

    # ------------------------------------------------------------------
    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve all requests to completion; returns uid -> generated ids."""
        queue = list(requests)
        results: Dict[int, List[int]] = {}
        while queue:
            batch = queue[: self.slots]
            queue = queue[self.slots :]
            self._serve_batch(batch)
            for r in batch:
                results[r.uid] = r.out_tokens
        return results

    def _serve_batch(self, batch: List[Request]):
        b = len(batch)
        # right-align prompts into one padded matrix for a single prefill
        plens = np.array([len(r.prompt) for r in batch], np.int32)
        s = int(plens.max())
        toks = np.zeros((b, s), np.int32)
        for i, r in enumerate(batch):
            toks[i, : plens[i]] = r.prompt  # left-aligned; lengths mask the rest
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        # NOTE: single prefill assumes equal lengths for exactness; per-slot
        # lengths are honoured during decode via the lengths vector.
        lengths = jnp.asarray(plens)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for r, t in zip(batch, np.asarray(next_tok)):
            r.out_tokens = [int(t)]
        max_new = max(r.max_new_tokens for r in batch)
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, cache, next_tok, lengths)
            lengths = lengths + 1
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            host = np.asarray(next_tok)
            for i, r in enumerate(batch):
                if len(r.out_tokens) < r.max_new_tokens and not r.done:
                    t = int(host[i])
                    r.out_tokens.append(t)
                    if self.eos_id is not None and t == self.eos_id:
                        r.done = True
        for r in batch:
            r.done = True
