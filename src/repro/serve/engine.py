"""Batched serving engine with continuous-batching-lite + sharded ANN path.

A fixed-size decode batch of slots; finished sequences are swapped for
queued requests between steps (the decode step itself is one jit'd program,
so slot replacement costs one host round-trip — the standard continuous
batching trade-off).  Greedy sampling (argmax) keeps the examples
deterministic; temperature sampling is a flag.

``ShardedANNEngine`` is the serving-side face of the distribution layer
(``repro.dist``): the filtered-ANN corpus is partitioned across the data
axis via ``FilteredANNEngine.shard_corpus``, each shard runs the SAME
planned strategy over its rows, and per-shard top-k results are merged
exactly with ``repro.dist.collectives.merge_topk``.  Planning happens once
per query (selectivity + strategy depend on dataset statistics, not on
row placement), so plan overhead does not grow with the shard count.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.engine import FilteredANNEngine, PlannedResult
from ..core.executors import SearchResult
from ..core.predicates import Predicate
from ..dist.collectives import merge_topk
from ..models.model import Model

__all__ = ["Request", "ServeEngine", "ShardedANNEngine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, batch_slots: int = 8, max_len: int = 512,
                 eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))

    # ------------------------------------------------------------------
    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve all requests to completion; returns uid -> generated ids."""
        queue = list(requests)
        results: Dict[int, List[int]] = {}
        while queue:
            batch = queue[: self.slots]
            queue = queue[self.slots :]
            self._serve_batch(batch)
            for r in batch:
                results[r.uid] = r.out_tokens
        return results

    def _serve_batch(self, batch: List[Request]):
        b = len(batch)
        # right-align prompts into one padded matrix for a single prefill
        plens = np.array([len(r.prompt) for r in batch], np.int32)
        s = int(plens.max())
        toks = np.zeros((b, s), np.int32)
        for i, r in enumerate(batch):
            toks[i, : plens[i]] = r.prompt  # left-aligned; lengths mask the rest
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        # NOTE: single prefill assumes equal lengths for exactness; per-slot
        # lengths are honoured during decode via the lengths vector.
        lengths = jnp.asarray(plens)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for r, t in zip(batch, np.asarray(next_tok)):
            r.out_tokens = [int(t)]
        max_new = max(r.max_new_tokens for r in batch)
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, cache, next_tok, lengths)
            lengths = lengths + 1
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            host = np.asarray(next_tok)
            for i, r in enumerate(batch):
                if len(r.out_tokens) < r.max_new_tokens and not r.done:
                    t = int(host[i])
                    r.out_tokens.append(t)
                    if self.eos_id is not None and t == self.eos_id:
                        r.done = True
        for r in batch:
            r.done = True


class ShardedANNEngine:
    """Sharded filtered-ANN query path: plan once, fan out, merge top-k.

    Wraps a :class:`FilteredANNEngine` with at least ``build_stats()`` run
    (a sharded deployment doesn't need the global index that ``build()``
    additionally constructs; ``fit()`` for a trained planner does).  The
    corpus is partitioned into ``n_shards`` contiguous shards (defaulting
    to the device count — one shard per data-axis slot); each query is

    1. planned centrally (selectivity estimate + pre/post decision),
    2. executed on every shard with the decided strategy (both executor
       kinds run per-shard via the ``shard_corpus`` hook),
    3. merged: shard-local top-k lists concat + re-top-k, which is exact
       because any global top-k element is in its own shard's top-k.
    """

    def __init__(self, engine: FilteredANNEngine, n_shards: Optional[int] = None,
                 n_lists: Optional[int] = None):
        self.engine = engine
        self.n_shards = n_shards or max(1, len(jax.devices()))
        self.shards = engine.shard_corpus(self.n_shards, n_lists=n_lists)

    # ------------------------------------------------------------------
    def query(self, q: np.ndarray, pred: Predicate, k: int = 10) -> PlannedResult:
        q = np.atleast_2d(q)
        est, decision, plan_overhead = self.engine.plan(pred, k)
        t0 = time.perf_counter()
        per_shard = [s.search(q, pred, k, decision, est) for s in self.shards]
        d, i = merge_topk(
            np.stack([r.dists for r in per_shard]),
            np.stack([r.ids for r in per_shard]),
            k,
        )
        elapsed = time.perf_counter() - t0 + plan_overhead
        res = SearchResult(
            d, i, elapsed, per_shard[0].strategy,
            n_expansions=max(r.n_expansions for r in per_shard),
        )
        return PlannedResult(res, est, decision, plan_overhead)

    def batch_query(self, queries: np.ndarray, preds, k: int = 10):
        return [self.query(queries[i], preds[i], k) for i in range(len(preds))]
