"""RAG integration: the paper's filtered-ANN engine in the serving loop.

This is where the two halves of the framework meet (DESIGN.md §4): the LM
fleet produces query embeddings; each retrieval call is a *filtered* ANN
query (e.g. "similar docs, but only year >= 2020") planned per-query by the
learned planner.

``RetrievalAugmentedServer`` wraps a small LM: it embeds the prompt (mean of
final hidden states through the embedding projection), issues a filtered ANN
query against the corpus, and (in a real system) would splice retrieved
context into the prompt.  Here we return the retrieved ids alongside the
generation so examples/benchmarks can check end-to-end behaviour.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engine import FilteredANNEngine
from ..core.predicates import AnyPredicate
from ..models.model import Model

__all__ = ["RetrievalAugmentedServer"]


class RetrievalAugmentedServer:
    def __init__(self, model: Model, params, ann: FilteredANNEngine,
                 embed_dim: Optional[int] = None):
        self.model = model
        self.params = params
        self.ann = ann
        d_corpus = ann.vectors.shape[1]
        key = jax.random.PRNGKey(0)
        # projection from model space to corpus embedding space (in a real
        # deployment this is the trained embedding head)
        self.proj = jax.random.normal(
            key, (model.cfg.d_model, d_corpus), jnp.float32
        ) * model.cfg.d_model ** -0.5
        self._embed = jax.jit(self._embed_fn)

    def _embed_fn(self, params, tokens):
        x, _ = self.model._hidden(params, {"tokens": tokens})
        pooled = x.mean(axis=1).astype(jnp.float32)        # (B, D)
        e = pooled @ self.proj
        return e / jnp.maximum(jnp.linalg.norm(e, axis=-1, keepdims=True), 1e-6)

    # ------------------------------------------------------------------
    def retrieve(self, tokens: np.ndarray, pred: AnyPredicate, k: int = 5):
        """tokens: (B, S) -> list of PlannedResult per row.  Accepts the
        full DNF predicate class (``Or``/``Not``), same as the engines."""
        q = np.asarray(self._embed(self.params, jnp.asarray(tokens)))
        # scale query into corpus space (corpus vectors are not normalised)
        scale = float(np.linalg.norm(self.ann.vectors, axis=1).mean())
        q = q * scale
        return [self.ann.query(q[i], pred, k) for i in range(q.shape[0])]
