"""Pure-jnp oracles for the Pallas kernels (the correctness contracts)."""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["masked_l2_topk_ref", "decode_attention_ref"]

BIG = jnp.float32(3.4e38)  # stand-in for +inf that survives arithmetic


@partial(jax.jit, static_argnames=("k",))
def masked_l2_topk_ref(
    queries: jax.Array,  # (B, d) f32
    corpus: jax.Array,   # (N, d) f32
    mask: jax.Array,     # (N,) bool / {0,1}
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Exact masked top-k by squared L2.  Masked-out -> dist BIG, id -1."""
    q2 = jnp.sum(queries * queries, axis=1, keepdims=True)
    x2 = jnp.sum(corpus * corpus, axis=1)
    d2 = jnp.maximum(q2 + x2[None, :] - 2.0 * queries @ corpus.T, 0.0)
    d2 = jnp.where(mask.astype(bool)[None, :], d2, BIG)
    neg, idx = jax.lax.top_k(-d2, k)
    d = -neg
    return d, jnp.where(d >= BIG, -1, idx)


@partial(jax.jit, static_argnames=())
def decode_attention_ref(
    q: jax.Array,        # (B, KV, GQ, dh)  one new token, grouped heads
    k_cache: jax.Array,  # (B, KV, S, dh)
    v_cache: jax.Array,  # (B, KV, S, dh)
    length: jax.Array,   # (B,) valid KV length per sequence
) -> jax.Array:
    """GQA decode attention over a (padded) KV cache; returns (B, KV, GQ, dh)."""
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(q.dtype)
    scores = jnp.einsum("bkgd,bksd->bkgs", q, k_cache) * scale
    s = k_cache.shape[2]
    pos = jnp.arange(s)[None, None, None, :]
    valid = pos < length[:, None, None, None]
    scores = jnp.where(valid, scores, -BIG)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgs,bksd->bkgd", w, v_cache)
