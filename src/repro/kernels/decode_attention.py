"""Flash-decode GQA attention Pallas kernel (one new token vs. a long KV).

The decode_32k / long_500k serving cells are bound by exactly this op: the
entire KV cache must stream HBM->VMEM once per decoded token, so the kernel's
job is to (a) touch each KV byte exactly once and (b) keep the online-softmax
state (running max, denominator, weighted accumulator) resident in VMEM.

Layout: q (B, KV, GQ, dh) one token of GQ=HQ/KV grouped query heads per KV
head; caches (B, KV, S, dh).  Grid: (B, KV, S_tiles) — S minor so the
softmax state persists across the KV sweep for one (batch, kv-head).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .masked_l2 import pl_scratch

__all__ = ["decode_attention_kernel", "TS"]

TS = 512  # KV tile length
NEG = -3.4e38  # python float: jnp constants would be captured consts in pallas


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, n_tiles: int):
    """q_ref: (1, 1, GQ, dh); k/v_ref: (1, 1, TS, dh); o_ref: (1, 1, GQ, dh)
    scratch: m (GQ, 128) running max, l (GQ, 128) denominator, acc (GQ, dh)."""
    s_idx = pl.program_id(2)
    b_idx = pl.program_id(0)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    q = q_ref[0, 0]                                   # (GQ, dh) — this (b, kv) block
    k = k_ref[0, 0]                                   # (TS, dh)
    v = v_ref[0, 0]                                   # (TS, dh)
    gq, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (GQ, TS)
    pos = s_idx * TS + jax.lax.broadcasted_iota(jnp.int32, (gq, TS), 1)
    valid = pos < len_ref[b_idx, 0]
    scores = jnp.where(valid, scores, NEG)

    m_prev = m_ref[:, 0]                              # (GQ,)
    m_new = jnp.maximum(m_prev, scores.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)                   # rescale old state
    p = jnp.exp(scores - m_new[:, None])              # (GQ, TS)
    l_new = l_ref[:, 0] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(s_idx == n_tiles - 1)
    def _flush():
        l = l_ref[:, 0]
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]


def decode_attention_kernel(
    q: jax.Array,        # (B, KV, GQ, dh) f32
    k_cache: jax.Array,  # (B, KV, S, dh) f32, S % TS == 0
    v_cache: jax.Array,  # (B, KV, S, dh) f32
    length: jax.Array,   # (B,) int32
    *,
    interpret: bool = False,
) -> jax.Array:
    b, kv, gq, dh = q.shape
    s = k_cache.shape[2]
    assert s % TS == 0, s
    grid = (b, kv, s // TS)
    kernel = functools.partial(_kernel, n_tiles=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, 1), lambda i, j, t: (0, 0)),                # lengths
            pl.BlockSpec((1, 1, gq, dh), lambda i, j, t: (i, j, 0, 0)),  # q stays
            pl.BlockSpec((1, 1, TS, dh), lambda i, j, t: (i, j, t, 0)),
            pl.BlockSpec((1, 1, TS, dh), lambda i, j, t: (i, j, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, gq, dh), lambda i, j, t: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, gq, dh), jnp.float32),
        scratch_shapes=[
            pl_scratch((gq, 128), jnp.float32),
            pl_scratch((gq, 128), jnp.float32),
            pl_scratch((gq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(length.reshape(b, 1).astype(jnp.int32), q, k_cache, v_cache)
