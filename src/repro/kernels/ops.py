"""jit'd public wrappers around the Pallas kernels.

Handle padding/alignment, choose interpret mode off-TPU, and expose the same
signature as the :mod:`repro.kernels.ref` oracles.  ``interpret=None`` means
"auto": compiled on TPU backends, interpret elsewhere (this CPU container).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .decode_attention import TS, decode_attention_kernel
from .masked_l2 import KPAD, TN, TQ, masked_l2_topk_kernel

__all__ = ["masked_l2_topk", "decode_attention", "fused_masked_topk"]


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@partial(jax.jit, static_argnames=("k", "interpret"))
def masked_l2_topk(
    queries: jax.Array,  # (B, d)
    corpus: jax.Array,   # (N, d)
    mask: jax.Array,     # (N,) bool
    k: int,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused masked brute-force top-k. Matches masked_l2_topk_ref."""
    assert k <= KPAD, f"k={k} exceeds kernel buffer {KPAD}"
    b, d = queries.shape
    n = corpus.shape[0]
    qp = _pad_to(_pad_to(queries.astype(jnp.float32), 0, TQ), 1, 128)
    xp = _pad_to(_pad_to(corpus.astype(jnp.float32), 0, TN), 1, 128)
    mp = _pad_to(mask.astype(jnp.float32)[:, None], 0, TN, value=0.0)
    out_d, out_i = masked_l2_topk_kernel(
        qp, xp, mp, interpret=_auto_interpret(interpret)
    )
    return out_d[:b, :k], out_i[:b, :k]


def fused_masked_topk(
    queries: jax.Array,  # (B, d)
    corpus: jax.Array,   # (N, d)
    mask: jax.Array,     # (N,) bool
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Serving-path entry for the fused masked brute-force top-k.

    Dispatches to the Pallas kernel on TPU (one VMEM-resident sweep, the
    batched pre-filter group's hot loop) and to the jit'd XLA ``l2_topk``
    elsewhere — same contract either way: (dists (B, k), ids (B, k)),
    masked-out/short rows padded with +inf / -1.  The XLA fallback shares
    the module-level jit cache with the engine's bucket warmup, which
    pre-compiles the width-8 query shape every per-query (and small-group)
    call hits; wider pow2 batch shapes (16, 32, ...) compile once on first
    use and are cached for the rest of the process.
    """
    if jax.default_backend() == "tpu" and k <= KPAD:
        return masked_l2_topk(queries, corpus, mask, k)
    from ..index.flat import l2_topk

    return l2_topk(queries, corpus, k, mask)


@partial(jax.jit, static_argnames=("interpret",))
def decode_attention(
    q: jax.Array,        # (B, KV, GQ, dh)
    k_cache: jax.Array,  # (B, KV, S, dh)
    v_cache: jax.Array,  # (B, KV, S, dh)
    length: jax.Array,   # (B,)
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Flash-decode GQA attention. Matches decode_attention_ref."""
    s = k_cache.shape[2]
    kp = _pad_to(k_cache.astype(jnp.float32), 2, TS)
    vp = _pad_to(v_cache.astype(jnp.float32), 2, TS)
    out = decode_attention_kernel(
        q.astype(jnp.float32), kp, vp, length, interpret=_auto_interpret(interpret)
    )
    return out
