"""jit'd public wrappers around the Pallas kernels.

Handle padding/alignment, choose interpret mode off-TPU, and expose the same
signature as the :mod:`repro.kernels.ref` oracles.  ``interpret=None`` means
"auto": compiled on TPU backends, interpret elsewhere (this CPU container).
"""
from __future__ import annotations

import time
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .decode_attention import TS, decode_attention_kernel
from .masked_l2 import KPAD, TN, TQ, masked_l2_topk_kernel

__all__ = [
    "masked_l2_topk", "decode_attention", "fused_masked_topk",
    "record_dispatch", "dispatch_counts", "dispatch_wall",
    "reset_dispatch_stats", "vmem_working_set",
]

# ----------------------------------------------------------------------
# process-global dispatch ledger — the obs layer's kernel counters.
# Counts live OUTSIDE the jit'd functions (a counter inside a traced
# function only runs at trace time), in the plain-Python wrappers that
# every serving dispatch goes through: ``fused_masked_topk`` here,
# ``IVFIndex.search`` and ``BackendSet.search_class`` at their call
# sites.  Counts are deterministic per trace; wall seconds are the real
# ledger (dispatch-call time — device sync happens at the caller's
# ``np.asarray``).
# ----------------------------------------------------------------------
_DISPATCH_COUNTS: Dict[str, int] = {}
_DISPATCH_WALL: Dict[str, float] = {}


def record_dispatch(name: str, seconds: float = 0.0) -> None:
    _DISPATCH_COUNTS[name] = _DISPATCH_COUNTS.get(name, 0) + 1
    _DISPATCH_WALL[name] = _DISPATCH_WALL.get(name, 0.0) + float(seconds)


def dispatch_counts() -> Dict[str, int]:
    return {k: _DISPATCH_COUNTS[k] for k in sorted(_DISPATCH_COUNTS)}


def dispatch_wall() -> Dict[str, float]:
    return {k: _DISPATCH_WALL[k] for k in sorted(_DISPATCH_WALL)}


def reset_dispatch_stats() -> None:
    _DISPATCH_COUNTS.clear()
    _DISPATCH_WALL.clear()


def vmem_working_set(d: int) -> dict:
    """Analytic bytes resident in VMEM for one (query-tile, corpus-tile)
    step of the fused masked top-k — the 16 MiB v5e fit check shared by
    ``benchmarks/kernel_bench.py`` and the obs snapshot
    (``repro.obs.metrics.publish_kernel_budget``)."""
    q_tile = TQ * d * 4
    x_tile = TN * d * 4
    mask = TN * 4
    dist_block = TQ * TN * 4
    topk_scratch = 2 * TQ * KPAD * 4
    total = q_tile + x_tile + mask + dist_block + topk_scratch
    return {
        "q_tile": q_tile, "x_tile": x_tile, "dist_block": dist_block,
        "scratch": topk_scratch, "total": total,
        "fits_16MiB": total < 16 * 2**20,
    }


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@partial(jax.jit, static_argnames=("k", "interpret"))
def masked_l2_topk(
    queries: jax.Array,  # (B, d)
    corpus: jax.Array,   # (N, d)
    mask: jax.Array,     # (N,) bool
    k: int,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused masked brute-force top-k. Matches masked_l2_topk_ref."""
    assert k <= KPAD, f"k={k} exceeds kernel buffer {KPAD}"
    b, d = queries.shape
    n = corpus.shape[0]
    qp = _pad_to(_pad_to(queries.astype(jnp.float32), 0, TQ), 1, 128)
    xp = _pad_to(_pad_to(corpus.astype(jnp.float32), 0, TN), 1, 128)
    mp = _pad_to(mask.astype(jnp.float32)[:, None], 0, TN, value=0.0)
    out_d, out_i = masked_l2_topk_kernel(
        qp, xp, mp, interpret=_auto_interpret(interpret)
    )
    return out_d[:b, :k], out_i[:b, :k]


def fused_masked_topk(
    queries: jax.Array,  # (B, d)
    corpus: jax.Array,   # (N, d)
    mask: jax.Array,     # (N,) bool
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Serving-path entry for the fused masked brute-force top-k.

    Dispatches to the Pallas kernel on TPU (one VMEM-resident sweep, the
    batched pre-filter group's hot loop) and to the jit'd XLA ``l2_topk``
    elsewhere — same contract either way: (dists (B, k), ids (B, k)),
    masked-out/short rows padded with +inf / -1.  The XLA fallback shares
    the module-level jit cache with the engine's bucket warmup, which
    pre-compiles the width-8 query shape every per-query (and small-group)
    call hits; wider pow2 batch shapes (16, 32, ...) compile once on first
    use and are cached for the rest of the process.
    """
    t0 = time.perf_counter()
    if jax.default_backend() == "tpu" and k <= KPAD:
        out = masked_l2_topk(queries, corpus, mask, k)
    else:
        from ..index.flat import l2_topk

        out = l2_topk(queries, corpus, k, mask)
    record_dispatch("fused_masked_topk", time.perf_counter() - t0)
    return out


@partial(jax.jit, static_argnames=("interpret",))
def decode_attention(
    q: jax.Array,        # (B, KV, GQ, dh)
    k_cache: jax.Array,  # (B, KV, S, dh)
    v_cache: jax.Array,  # (B, KV, S, dh)
    length: jax.Array,   # (B,)
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Flash-decode GQA attention. Matches decode_attention_ref."""
    s = k_cache.shape[2]
    kp = _pad_to(k_cache.astype(jnp.float32), 2, TS)
    vp = _pad_to(v_cache.astype(jnp.float32), 2, TS)
    out = decode_attention_kernel(
        q.astype(jnp.float32), kp, vp, length, interpret=_auto_interpret(interpret)
    )
    return out
