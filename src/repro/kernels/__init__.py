from .ops import masked_l2_topk, decode_attention
from .ref import masked_l2_topk_ref, decode_attention_ref

__all__ = [
    "masked_l2_topk",
    "decode_attention",
    "masked_l2_topk_ref",
    "decode_attention_ref",
]
