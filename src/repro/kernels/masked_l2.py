"""Fused masked-L2-distance + streaming top-k Pallas kernel.

The hot loop of both pre-filtering (masked brute-force scan) and IVF list
scans (DESIGN.md §2).  The naive formulation materialises the (B, N) distance
matrix in HBM — at B=256, N=1M that is 1 TB of traffic.  This kernel never
leaves VMEM: for each query tile it streams corpus tiles HBM->VMEM, computes
the distance block on the MXU, folds the predicate mask in as +BIG, and
maintains a running top-k in VMEM scratch; only (B, k) leaves the core.

Grid: (num_query_tiles, num_corpus_tiles) — corpus is the minor axis, so the
scratch accumulator persists across the corpus sweep of one query tile.

Block shapes (TPU v5e): query tile (128, d), corpus tile (512, d), d padded
to a multiple of 128 for MXU alignment; k padded to the 128-lane boundary.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["masked_l2_topk_kernel", "TQ", "TN", "KPAD"]

TQ = 128     # query tile (sublane-aligned)
TN = 512     # corpus tile
KPAD = 128   # top-k buffer width (lane-aligned)
BIG = 3.4e38  # python float: jnp constants would be captured consts in pallas


def _kernel(q_ref, x_ref, m_ref, od_ref, oi_ref, bd_ref, bi_ref, *, n_tiles: int):
    """q_ref: (TQ, d) — x_ref: (TN, d) — m_ref: (TN, 1) mask as f32 {0,1}
    od/oi: (TQ, KPAD) outputs — bd/bi: (TQ, KPAD) VMEM scratch."""
    j = pl.program_id(1)

    # reset the running top-k at the start of each corpus sweep
    @pl.when(j == 0)
    def _init():
        bd_ref[...] = jnp.full((TQ, KPAD), BIG, jnp.float32)
        bi_ref[...] = jnp.full((TQ, KPAD), -1, jnp.int32)

    q = q_ref[...]
    x = x_ref[...]
    m = m_ref[...]  # (TN, 1)

    # squared L2 via the MXU: ||q||^2 + ||x||^2 - 2 q.x
    q2 = jnp.sum(q * q, axis=1, keepdims=True)                    # (TQ, 1)
    x2 = jnp.sum(x * x, axis=1)                                   # (TN,)
    d2 = q2 + x2[None, :] - 2.0 * jnp.dot(
        q, x.T, preferred_element_type=jnp.float32
    )                                                             # (TQ, TN)
    d2 = jnp.maximum(d2, 0.0)
    d2 = jnp.where(m[:, 0][None, :] > 0, d2, BIG)                 # fold predicate in

    ids = j * TN + jax.lax.broadcasted_iota(jnp.int32, (TQ, TN), 1)

    # merge tile results into the running top-k
    cat_d = jnp.concatenate([bd_ref[...], d2], axis=1)            # (TQ, KPAD+TN)
    cat_i = jnp.concatenate([bi_ref[...], ids], axis=1)
    neg, pos = jax.lax.top_k(-cat_d, KPAD)
    bd_ref[...] = -neg
    bi_ref[...] = jnp.take_along_axis(cat_i, pos, axis=1)

    # flush on the last corpus tile
    @pl.when(j == n_tiles - 1)
    def _flush():
        d = bd_ref[...]
        od_ref[...] = d
        oi_ref[...] = jnp.where(d >= BIG, -1, bi_ref[...])


def masked_l2_topk_kernel(
    queries: jax.Array,  # (B, d) f32, B % TQ == 0
    corpus: jax.Array,   # (N, d) f32, N % TN == 0, d % 128 == 0
    mask: jax.Array,     # (N, 1) f32 {0,1}
    *,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Raw pallas_call; use :mod:`repro.kernels.ops` for the padded wrapper."""
    b, d = queries.shape
    n = corpus.shape[0]
    assert b % TQ == 0 and n % TN == 0, (b, n)
    grid = (b // TQ, n // TN)
    kernel = functools.partial(_kernel, n_tiles=grid[1])
    out_d, out_i = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TQ, d), lambda i, j: (i, 0)),      # query tile (stays)
            pl.BlockSpec((TN, d), lambda i, j: (j, 0)),      # corpus tile (streams)
            pl.BlockSpec((TN, 1), lambda i, j: (j, 0)),      # mask tile
        ],
        out_specs=[
            pl.BlockSpec((TQ, KPAD), lambda i, j: (i, 0)),
            pl.BlockSpec((TQ, KPAD), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, KPAD), jnp.float32),
            jax.ShapeDtypeStruct((b, KPAD), jnp.int32),
        ],
        scratch_shapes=[
            pl_scratch((TQ, KPAD), jnp.float32),
            pl_scratch((TQ, KPAD), jnp.int32),
        ],
        interpret=interpret,
    )(queries, corpus, mask)
    return out_d, out_i


def pl_scratch(shape, dtype):
    """VMEM scratch shape (TPU); plain scratch in interpret mode."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover - interpret-only environments
        return pl.MemorySpace.ANY(shape, dtype)
