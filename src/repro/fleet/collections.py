"""Named tenant collections: schema, engine, shards, manifest.

The redisvl idiom: a collection is declared by a small schema (index
name + typed fields + serving attributes), and the server owns the
engine objects behind it.  Here every :class:`TenantCollection` wraps
its OWN :class:`FilteredANNEngine` inside a :class:`ShardedANNEngine` —
so the predicate cache, plan cache, planner state, and live-corpus
generations are partitioned per tenant by construction (a noisy
tenant's cache churn cannot evict a quiet tenant's hot predicates), and
the autoscaler can repartition one tenant's shards without touching the
others.

:class:`Fleet` is the registry: create/drop/look up collections, track
the shared shard budget, snapshot every tenant's mutable state through
one ``repro.ckpt.Checkpointer`` step whose manifest ``meta`` records
per-tenant generations and shard assignments (the fleet manifest).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..core.engine import EngineConfig, FilteredANNEngine
from ..runtime.queue import SLO_TIERS
from ..serve.engine import ShardedANNEngine

__all__ = ["FieldSpec", "CollectionSchema", "TenantCollection", "Fleet"]


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """One typed attribute column: ``tag`` columns live in the categorical
    matrix, ``numeric`` columns in the numeric matrix (schema order maps
    to column order within each matrix)."""

    name: str
    kind: str                   # "tag" | "numeric"

    def __post_init__(self):
        if self.kind not in ("tag", "numeric"):
            raise ValueError(f"field kind must be tag|numeric, got {self.kind!r}")


@dataclasses.dataclass
class CollectionSchema:
    """Declarative description of one tenant collection.

    ``weight`` is the fair-share weight the deficit round-robin batcher
    honours; ``n_shards`` is the tenant's BASELINE shard assignment (the
    autoscaler moves the live count, ``Fleet.reset_shards`` returns to
    this); ``admit_rate``/``admit_burst`` configure the tenant's token
    bucket (None defers to the controller's defaults)."""

    name: str
    dim: int
    fields: Tuple[FieldSpec, ...] = ()
    slo_tier: str = "standard"
    weight: float = 1.0
    n_shards: int = 1
    admit_rate: Optional[float] = None
    admit_burst: Optional[float] = None
    seed: int = 0

    def __post_init__(self):
        if not self.name:
            raise ValueError("collection name must be non-empty")
        if self.dim < 1:
            raise ValueError(f"dim must be >= 1, got {self.dim}")
        if self.slo_tier not in SLO_TIERS:
            raise ValueError(
                f"unknown slo_tier {self.slo_tier!r} (one of {sorted(SLO_TIERS)})")
        if self.weight <= 0:
            raise ValueError(f"weight must be > 0, got {self.weight}")
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        self.fields = tuple(
            f if isinstance(f, FieldSpec) else FieldSpec(**f) for f in self.fields)

    # ------------------------------------------------------------------
    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CollectionSchema":
        """redisvl-style schema dict::

            {"index": {"name": "products", "slo_tier": "interactive"},
             "fields": [{"name": "embedding", "type": "vector",
                         "attrs": {"dims": 64}},
                        {"name": "brand", "type": "tag"},
                        {"name": "price", "type": "numeric"}]}

        The ``vector`` field supplies ``dim``; ``tag``/``numeric`` fields
        become :class:`FieldSpec` columns in declaration order."""
        index = dict(d.get("index", {}))
        dim = index.pop("dim", 0)
        fields: List[FieldSpec] = []
        for f in d.get("fields", ()):
            kind = f.get("type", f.get("kind"))
            if kind == "vector":
                dim = int(f.get("attrs", {}).get("dims", dim))
                continue
            fields.append(FieldSpec(f["name"], kind))
        return cls(dim=int(dim), fields=tuple(fields), **index)

    @property
    def tag_fields(self) -> Tuple[str, ...]:
        return tuple(f.name for f in self.fields if f.kind == "tag")

    @property
    def numeric_fields(self) -> Tuple[str, ...]:
        return tuple(f.name for f in self.fields if f.kind == "numeric")

    @property
    def deadline(self) -> float:
        return SLO_TIERS[self.slo_tier]

    def validate_rows(self, vectors: np.ndarray, cat: np.ndarray,
                      num: np.ndarray) -> None:
        """Corpus arrays must match the declared schema — width mismatches
        are caught at collection creation, not at first query."""
        v = np.atleast_2d(vectors)
        if v.shape[1] != self.dim:
            raise ValueError(
                f"{self.name}: vectors have dim {v.shape[1]}, schema says {self.dim}")
        if self.fields:
            c, m = np.atleast_2d(cat), np.atleast_2d(num)
            if c.shape[1] != len(self.tag_fields):
                raise ValueError(
                    f"{self.name}: {c.shape[1]} tag columns vs schema fields "
                    f"{self.tag_fields}")
            if m.shape[1] != len(self.numeric_fields):
                raise ValueError(
                    f"{self.name}: {m.shape[1]} numeric columns vs schema "
                    f"fields {self.numeric_fields}")


class TenantCollection:
    """One tenant: schema + engine + sharded serving face.

    The flat engine holds planning state and the live corpus; the
    :class:`ShardedANNEngine` wrapper is what serving traffic hits
    (plan once, fan out, exact merge) and what the autoscaler reshards."""

    def __init__(self, schema: CollectionSchema, engine: FilteredANNEngine,
                 backend: Optional[ShardedANNEngine] = None):
        self.schema = schema
        self.engine = engine
        self.backend = backend or ShardedANNEngine(engine, n_shards=schema.n_shards)

    # -- identity ------------------------------------------------------
    @property
    def name(self) -> str:
        return self.schema.name

    @property
    def slo_tier(self) -> str:
        return self.schema.slo_tier

    @property
    def weight(self) -> float:
        return self.schema.weight

    @property
    def n_shards(self) -> int:
        return len(self.backend.shards)

    # -- serving passthroughs ------------------------------------------
    def query(self, q, pred, k: int = 10):
        return self.backend.query(q, pred, k)

    def batch_query(self, queries, preds, k: int = 10):
        return self.backend.batch_query(queries, preds, k)

    def upsert(self, vectors, cat, num, ids=None):
        return self.backend.upsert(vectors, cat, num, ids=ids)

    def delete(self, ids):
        return self.backend.delete(ids)

    def maybe_compact(self):
        return self.backend.maybe_compact()

    def reshard(self, n_shards: int) -> "TenantCollection":
        self.backend.reshard(n_shards)
        return self

    # ------------------------------------------------------------------
    def manifest(self) -> Dict[str, Any]:
        """The per-tenant slice of the fleet manifest: which corpus
        version and planner head a snapshot captured, on how many shards."""
        return {
            "corpus_generation": int(getattr(self.engine, "corpus_generation", 0)),
            "planner_version": int(getattr(self.engine, "planner_version", 0)),
            "n_shards": self.n_shards,
            "slo_tier": self.slo_tier,
            "weight": self.weight,
            "n_total": int(self.engine.live.n_total),
            "live_count": int(self.engine.live.live_count),
        }

    def stats(self) -> Dict[str, Any]:
        out = self.backend.stats()
        out["schema"] = {
            "name": self.name, "dim": self.schema.dim,
            "slo_tier": self.slo_tier, "weight": self.weight,
            "n_shards": self.n_shards,
            "fields": [(f.name, f.kind) for f in self.schema.fields],
        }
        return out


class Fleet:
    """Registry of tenant collections sharing one machine's shard budget."""

    def __init__(self, total_shards: int = 8):
        if total_shards < 1:
            raise ValueError(f"total_shards must be >= 1, got {total_shards}")
        self.total_shards = total_shards
        self._cols: Dict[str, TenantCollection] = {}

    # -- registry ------------------------------------------------------
    def create(
        self,
        schema: CollectionSchema,
        vectors: np.ndarray,
        cat: np.ndarray,
        num: np.ndarray,
        config: Optional[EngineConfig] = None,
        train: Optional[Tuple[Sequence[np.ndarray], Sequence[Any]]] = None,
        k: int = 10,
    ) -> TenantCollection:
        """Build a tenant collection over its own corpus.  ``train`` is an
        optional ``(queries, predicates)`` pair for :meth:`FilteredANNEngine.fit`
        (the planner is per-tenant too — one tenant's workload never warps
        another's routing head)."""
        if schema.name in self._cols:
            raise ValueError(f"collection {schema.name!r} already exists")
        schema.validate_rows(vectors, cat, num)
        cfg = config or EngineConfig(seed=schema.seed)
        engine = FilteredANNEngine(vectors, cat, num, cfg).build()
        if train is not None:
            engine.fit(train[0], train[1], k=k)
        col = TenantCollection(schema, engine)
        if self.shards_in_use + col.n_shards > self.total_shards:
            raise ValueError(
                f"creating {schema.name!r} with {col.n_shards} shards exceeds "
                f"the fleet budget ({self.shards_in_use}/{self.total_shards} in use)")
        self._cols[schema.name] = col
        return col

    def add(self, col: TenantCollection) -> TenantCollection:
        """Register a pre-built collection (tests, restored fleets)."""
        if col.name in self._cols:
            raise ValueError(f"collection {col.name!r} already exists")
        self._cols[col.name] = col
        return col

    def drop(self, name: str) -> None:
        del self._cols[name]

    def __getitem__(self, name: str) -> TenantCollection:
        return self._cols[name]

    def __contains__(self, name: str) -> bool:
        return name in self._cols

    def __iter__(self) -> Iterator[TenantCollection]:
        return iter(self._cols.values())

    def __len__(self) -> int:
        return len(self._cols)

    def names(self) -> List[str]:
        """Creation-ordered tenant names — the fixed round-robin order the
        fair-share batcher and autoscaler iterate in (determinism)."""
        return list(self._cols)

    @property
    def shards_in_use(self) -> int:
        return sum(c.n_shards for c in self._cols.values())

    def reset_shards(self) -> None:
        """Return every tenant to its schema-baseline shard assignment —
        how a replay starts from the same placement the first run did."""
        for col in self._cols.values():
            if col.n_shards != col.schema.n_shards:
                col.reshard(col.schema.n_shards)

    # -- manifest + checkpointing --------------------------------------
    def manifest(self) -> Dict[str, Any]:
        return {"tenants": {n: c.manifest() for n, c in self._cols.items()},
                "total_shards": self.total_shards}

    def save(self, ckpt, step: int) -> None:
        """One checkpoint step for the whole fleet: every tenant's mutable
        corpus state as a nested pytree, the fleet manifest in ``meta``."""
        tree = {n: c.engine.mutation_state() for n, c in self._cols.items()}
        ckpt.save(step, tree, meta={"fleet": self.manifest()})

    def restore(self, ckpt, step: Optional[int] = None) -> Dict[str, Any]:
        """Restore mutation state onto freshly-built collections over the
        same base corpora (per-engine ``load_mutation_state`` semantics),
        then reshard each tenant to the manifest's assignment so shard
        locators see the replayed segment + tombstones.  Returns the
        restored fleet manifest."""
        step = ckpt.latest_step() if step is None else step
        if step is None:
            raise ValueError("no checkpoint steps to restore from")
        meta = ckpt.read_meta(step).get("fleet", {})
        tenants = meta.get("tenants", {})
        missing = [n for n in self._cols if n not in tenants]
        if missing:
            raise ValueError(f"checkpoint manifest missing tenants: {missing}")
        template = {n: c.engine.mutation_state() for n, c in self._cols.items()}
        tree = ckpt.restore(step, template)
        for n, col in self._cols.items():
            col.engine.load_mutation_state(
                {k: np.asarray(v) for k, v in tree[n].items()})
            col.reshard(int(tenants[n].get("n_shards", col.schema.n_shards)))
        return meta

    def stats(self) -> Dict[str, Any]:
        return {n: c.stats() for n, c in self._cols.items()}
