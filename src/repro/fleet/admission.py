"""Per-tenant admission control: token buckets in VIRTUAL time.

Load shedding beats load queueing when deadlines are tight: a request
that would wait out its SLO in the queue costs service capacity and
still misses.  Each tenant gets a token bucket sized to its budget
(``CollectionSchema.admit_rate``/``admit_burst`` or the controller
defaults); a query arriving with an empty bucket is REJECTED at
admission — it never enters a queue, never occupies a batch slot, and
the tenants inside their budget keep their deadlines.

Determinism: buckets refill from request ARRIVAL timestamps, not from
the scheduler's clock position, and the runtime admits requests in
(t_arrival, rid) order — so the admit/reject outcome for every rid is a
pure function of the trace, independent of batch formation.  Same trace
+ seed => the same rejects, every replay.

Writes are never shed: dropping an upsert/delete silently loses data,
so mutations always pass (they are batch-tier and cheap; backpressure
for writes is a compaction-policy concern, not an admission one).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = ["TokenBucket", "AdmissionController"]


@dataclasses.dataclass
class TokenBucket:
    """Classic leaky bucket on the virtual clock: ``rate`` tokens/s refill
    capped at ``burst``; one token per admitted query."""

    rate: float
    burst: float
    tokens: float = dataclasses.field(default=None)  # type: ignore[assignment]
    t_last: float = 0.0

    def __post_init__(self):
        if self.rate <= 0 or self.burst <= 0:
            raise ValueError(
                f"rate and burst must be > 0, got rate={self.rate} burst={self.burst}")
        if self.tokens is None:
            self.tokens = self.burst          # start full

    def try_take(self, now: float, cost: float = 1.0) -> bool:
        """Refill to ``now`` (monotone within a trace) and take ``cost``
        tokens if available."""
        if now > self.t_last:
            self.tokens = min(self.burst, self.tokens + (now - self.t_last) * self.rate)
            self.t_last = now
        if self.tokens >= cost:
            self.tokens -= cost
            return True
        return False

    def reset(self) -> None:
        self.tokens = self.burst
        self.t_last = 0.0


class AdmissionController:
    """Admit/reject gate over per-tenant :class:`TokenBucket` budgets.

    Tenants without a configured budget (and the single-tenant ``""``
    tag) are always admitted — admission is opt-in per schema, so a
    fleet can protect itself from one noisy tenant without rate-limiting
    anyone else."""

    def __init__(self, budgets: Dict[str, Tuple[float, float]]):
        self.buckets: Dict[str, TokenBucket] = {
            t: TokenBucket(rate, burst) for t, (rate, burst) in budgets.items()}
        self.admitted: Dict[str, int] = {}
        self.rejected: Dict[str, int] = {}

    @classmethod
    def for_fleet(cls, fleet, default_rate: Optional[float] = None,
                  default_burst: Optional[float] = None) -> "AdmissionController":
        """Budgets from the fleet's schemas: per-tenant ``admit_rate`` wins,
        else ``default_rate`` (None leaves that tenant un-gated); burst
        defaults to one virtual second of rate."""
        budgets: Dict[str, Tuple[float, float]] = {}
        for col in fleet:
            s = col.schema
            rate = s.admit_rate if s.admit_rate is not None else default_rate
            if rate is None:
                continue
            burst = s.admit_burst if s.admit_burst is not None else (
                default_burst if default_burst is not None else rate)
            budgets[s.name] = (float(rate), float(burst))
        return cls(budgets)

    # ------------------------------------------------------------------
    def admit(self, req) -> bool:
        """Gate one request at its arrival time.  Mutations always pass."""
        tenant = getattr(req, "tenant", "")
        bucket = self.buckets.get(tenant)
        if bucket is None or req.op != "query":
            self.admitted[tenant] = self.admitted.get(tenant, 0) + 1
            return True
        if bucket.try_take(req.t_arrival):
            self.admitted[tenant] = self.admitted.get(tenant, 0) + 1
            return True
        self.rejected[tenant] = self.rejected.get(tenant, 0) + 1
        return False

    def reset(self) -> None:
        """Fresh buckets + counters — called at the top of every trace run
        so replays start from identical admission state."""
        for b in self.buckets.values():
            b.reset()
        self.admitted.clear()
        self.rejected.clear()

    def counters(self) -> Dict[str, Dict[str, int]]:
        return {"admitted": dict(sorted(self.admitted.items())),
                "rejected": dict(sorted(self.rejected.items()))}
