"""Elastic per-tenant shard scaling driven by SLO pressure + fault monitors.

The router moves each tenant's shard assignment inside the fleet's
shared budget:

* **grow** — a tenant whose recent deadline miss-rate stays above
  ``grow_miss_rate`` borrows a shard (per-row service cost divides by
  the shard count — see ``fairshare.FleetServiceModel``), as long as
  the fleet budget has one free;
* **shrink** — a tenant coasting under ``shrink_miss_rate`` releases a
  shard back to the pool (never below ``min_shards``);
* **recover** — the ``dist.fault`` monitors watch per-shard liveness:
  a shard that stops heartbeating (``HeartbeatMonitor``) or whose
  synthetic step-time EMA flags it as a straggler
  (``StragglerMitigator``) is dropped by resharding the tenant onto the
  survivors — exact top-k merges are partition-independent, so results
  stay correct over the remaining shards.

Every new assignment is validated through ``dist.elastic.replan_mesh``
(one data-axis slot per shard, model axis pinned at 1) and recorded as
a :class:`ScaleEvent` stamped with the VIRTUAL clock — the monitors are
fed virtual time, so scale decisions replay bit-for-bit with the trace.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from ..dist.elastic import replan_mesh
from ..dist.fault import HeartbeatMonitor, StragglerMitigator

__all__ = ["AutoscaleConfig", "ScaleEvent", "FaultInjection", "FleetAutoscaler"]


@dataclasses.dataclass
class AutoscaleConfig:
    eval_every: float = 0.5          # virtual s between policy evaluations
    grow_miss_rate: float = 0.20     # window miss-rate that triggers a grow
    shrink_miss_rate: float = 0.02   # miss-rate below which a shard releases
    min_window: int = 16             # completions needed before a verdict
    cooldown: float = 1.0            # virtual s between scale events per tenant
    min_shards: int = 1
    heartbeat_timeout: float = 0.5   # virtual s without a beat => dead shard
    straggler_threshold: float = 2.0
    straggler_min_obs: int = 8

    def __post_init__(self):
        assert self.eval_every > 0 and self.cooldown >= 0
        assert 0.0 <= self.shrink_miss_rate <= self.grow_miss_rate <= 1.0
        assert self.min_shards >= 1


@dataclasses.dataclass(frozen=True)
class FaultInjection:
    """A scripted, virtual-clock-scheduled shard fault — how tests and
    benchmarks exercise the recovery path deterministically mid-trace
    (``FleetRuntime(faults=[...])`` applies each one when the virtual
    clock passes ``t``)."""

    t: float                         # virtual time the fault manifests
    tenant: str
    shard: int
    kind: str = "kill"               # "kill" | "slow"
    factor: float = 4.0              # slow-only: step-time inflation


@dataclasses.dataclass
class ScaleEvent:
    """One replay-deterministic scale decision, virtual-clock-stamped."""

    t: float                         # virtual time of the decision
    tenant: str
    action: str                      # "grow" | "shrink" | "recover"
    from_shards: int
    to_shards: int
    reason: str
    mesh: Tuple[int, ...]            # replanned (data, model) mesh shape

    def as_dict(self) -> dict:
        return {"t": round(self.t, 6), "tenant": self.tenant,
                "action": self.action, "from_shards": self.from_shards,
                "to_shards": self.to_shards, "reason": self.reason,
                "mesh": list(self.mesh)}


class _TenantState:
    """Per-tenant scaling state: SLO window + fault monitors."""

    def __init__(self, n_shards: int, cfg: AutoscaleConfig):
        self.met = 0
        self.missed = 0
        self.last_scale = -float("inf")
        self.last_bucket = -1
        self.killed: Set[int] = set()
        self.slow: Dict[int, float] = {}
        self.new_monitors(n_shards, cfg)

    def new_monitors(self, n_shards: int, cfg: AutoscaleConfig) -> None:
        """Fresh monitors after any reshard — shard identities are
        positional, so the old liveness state is meaningless."""
        self.heartbeat = HeartbeatMonitor(n_shards, timeout=cfg.heartbeat_timeout)
        self.straggler = StragglerMitigator(
            n_shards, threshold=cfg.straggler_threshold,
            min_observations=cfg.straggler_min_obs)
        self.killed.clear()
        self.slow.clear()

    def reset_window(self) -> None:
        self.met = 0
        self.missed = 0

    @property
    def window(self) -> int:
        return self.met + self.missed


class FleetAutoscaler:
    def __init__(self, fleet, config: Optional[AutoscaleConfig] = None,
                 telemetry=None):
        self.fleet = fleet
        self.config = config or AutoscaleConfig()
        self.telemetry = telemetry
        self.events: List[ScaleEvent] = []
        self._states: Dict[str, _TenantState] = {
            col.name: _TenantState(col.n_shards, self.config) for col in fleet}
        self._step = 0

    # -- fault-injection hooks (tests/benchmarks) ----------------------
    def kill_shard(self, tenant: str, shard: int) -> None:
        """Stop the shard's heartbeats — the monitor flags it one timeout
        later and :meth:`step` reshards onto the survivors."""
        self._states[tenant].killed.add(shard)

    def slow_shard(self, tenant: str, shard: int, factor: float = 4.0) -> None:
        """Inflate the shard's synthetic step time so the straggler EMA
        crosses the threshold after ``straggler_min_obs`` batches."""
        self._states[tenant].slow[shard] = factor

    # -- runtime feed --------------------------------------------------
    def observe(self, tenant: str, met: bool, now: float) -> None:
        st = self._states[tenant]
        if met:
            st.met += 1
        else:
            st.missed += 1

    def beat(self, tenant: str, now: float, step_time: float = 1e-3) -> None:
        """One serviced batch for ``tenant``: every live shard heartbeats
        and reports a (deterministic, synthetic) per-shard step time —
        killed shards stay silent, slowed shards report inflated times."""
        st = self._states[tenant]
        n = self.fleet[tenant].n_shards
        for si in range(n):
            if si in st.killed:
                continue
            st.heartbeat.beat(si, now)
            st.straggler.record(si, step_time * st.slow.get(si, 1.0))

    # -- policy --------------------------------------------------------
    def _apply(self, tenant: str, new_n: int, action: str, reason: str,
               now: float) -> ScaleEvent:
        col = self.fleet[tenant]
        st = self._states[tenant]
        old_n = col.n_shards
        mesh_shape, _ = replan_mesh(new_n, model_parallel=1)
        col.reshard(new_n)
        st.new_monitors(new_n, self.config)
        st.last_scale = now
        st.reset_window()
        ev = ScaleEvent(now, tenant, action, old_n, new_n, reason, mesh_shape)
        self.events.append(ev)
        if self.telemetry is not None:
            self.telemetry.record_scale(ev)
        return ev

    def step(self, now: float) -> List[ScaleEvent]:
        """Evaluate every tenant at ``now`` (virtual).  Fault recovery
        preempts the SLO policy: a tenant with flagged shards reshards
        onto the survivors immediately, cooldown or not."""
        cfg = self.config
        self._step += 1
        out: List[ScaleEvent] = []
        for name in self.fleet.names():
            col = self.fleet[name]
            st = self._states[name]
            faults = st.heartbeat.check(self._step, now) + st.straggler.check(self._step)
            if faults:
                n_bad = len({f.host for f in faults})
                new_n = max(cfg.min_shards, col.n_shards - n_bad)
                if new_n != col.n_shards:
                    out.append(self._apply(
                        name, new_n, "recover", str(faults[0]), now))
                    continue
            bucket = int(now // cfg.eval_every)
            if bucket <= st.last_bucket:
                continue
            st.last_bucket = bucket
            if st.window < cfg.min_window or now - st.last_scale < cfg.cooldown:
                st.reset_window()
                continue
            miss_rate = st.missed / st.window
            if (miss_rate >= cfg.grow_miss_rate
                    and self.fleet.shards_in_use < self.fleet.total_shards):
                out.append(self._apply(
                    name, col.n_shards + 1, "grow",
                    f"miss_rate {miss_rate:.3f} >= {cfg.grow_miss_rate}", now))
            elif (miss_rate <= cfg.shrink_miss_rate
                    and col.n_shards > cfg.min_shards):
                out.append(self._apply(
                    name, col.n_shards - 1, "shrink",
                    f"miss_rate {miss_rate:.3f} <= {cfg.shrink_miss_rate}", now))
            else:
                st.reset_window()
        return out
