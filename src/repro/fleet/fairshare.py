"""Fair-share micro-batching: the PR 4 event loop, fleet edition.

:class:`FleetRuntime` keeps the single-tenant discrete-event contract —
VIRTUAL clock, deterministic cost model, serial server, real engine
execution — and changes only who gets the batch slots:

* arrivals land in PER-TENANT queues (after the admission gate);
* a flush trigger (batch full / max-wait / deadline pressure) fires on
  the global state, exactly like ``OnlineRuntime``;
* batch slots are handed out by **deficit round-robin** over the tenant
  queues: each round every backlogged tenant earns ``weight`` credits
  and spends whole credits on queue slots, so over time tenants get
  batch share proportional to weight no matter how oversubscribed a
  noisy neighbor's queue is.  ``fair=False`` degrades to the shared
  single-queue baseline (tightest-deadline-first over ALL tenants) —
  the configuration the noisy-neighbor benchmark measures against;
* each tenant's slice of the batch executes on that tenant's OWN
  sharded engine, and its virtual service share divides by the
  tenant's live shard count (:class:`FleetServiceModel`) — which is
  what makes autoscaling effective in virtual time.

Everything that feeds batch composition — admission, DRR state,
deadlines, service times, autoscale decisions — derives from the trace
and deterministic models only, so the replay guarantee survives:
same multi-tenant trace + seed => identical per-tenant batch
compositions, result ids, and telemetry counters.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from ..core.engine import PlannedResult
from ..runtime.queue import ArrivalTrace, RequestQueue, RuntimeRequest
from ..runtime.scheduler import ServiceModel
from .admission import AdmissionController
from .autoscale import AutoscaleConfig, FaultInjection, FleetAutoscaler, ScaleEvent
from .collections import Fleet
from .telemetry import FleetTelemetry

__all__ = ["FleetConfig", "FleetServiceModel", "FleetRuntime", "FleetReport"]


@dataclasses.dataclass
class FleetConfig:
    max_batch: int = 64        # pow2: the per-tenant executors pad to pow2
    max_wait: float = 0.005    # virtual s the oldest request may age unflushed
    slo_slack: float = 0.0     # extra virtual s reserved when checking deadlines
    fair: bool = True          # False => shared-queue baseline (no isolation)

    def __post_init__(self):
        assert self.max_batch >= 1 and (self.max_batch & (self.max_batch - 1)) == 0, \
            "max_batch must be a power of two (the executors pad to pow2)"
        assert self.max_wait >= 0.0


@dataclasses.dataclass
class FleetServiceModel(ServiceModel):
    """The single-tenant cost model plus shard-parallel row service.

    A tenant's rows scan in parallel across its shards, so the per-row
    virtual cost divides by the tenant's live shard count; ``fanout``
    charges the per-shard dispatch + merge overhead so borrowing shards
    is never free.  Write costs stay undivided (a row lands on exactly
    one owning shard).  Fixed constants, like the base model: calibrating
    from wall time would break replay."""

    fanout: float = 1e-4       # per-shard overhead per tenant batch group

    def time_group(self, decisions, n_shards: int, n_upsert_rows: int = 0,
                   n_delete_rows: int = 0, n_compactions: int = 0) -> float:
        """One tenant's slice of a batch (NO dispatch — that is charged
        once per batch by the runtime)."""
        rows = float(sum(self.per_row[int(d)] for d in decisions))
        return (rows / max(n_shards, 1)
                + self.fanout * n_shards
                + n_upsert_rows * self.upsert_row
                + n_delete_rows * self.delete_row
                + n_compactions * self.compaction)

    def estimate_sharded(self, n_rows: int, n_shards: int) -> float:
        """Pessimistic pre-execution estimate for the deadline trigger."""
        return (self.dispatch
                + n_rows * max(self.per_row.values()) / max(n_shards, 1)
                + self.fanout * n_shards)


@dataclasses.dataclass
class FleetReport:
    """Everything a fleet trace replay produced, keyed for determinism
    checks: global batch compositions, per-rid results, rejected rids,
    and the fleet telemetry ledger (including scale events)."""

    results: Dict[int, PlannedResult]
    batches: List[List[int]]           # flush-order global-rid compositions
    rejected: List[int]                # rids shed at admission, arrival order
    telemetry: FleetTelemetry
    scale_events: List[ScaleEvent]

    def ids(self, rid: int) -> np.ndarray:
        return self.results[rid].result.ids[0]

    def slo_hit_rate(self, tenant: str) -> float:
        return self.telemetry.slo_hit_rate(tenant)


class FleetRuntime:
    """Deadline-aware fair-share micro-batching over a :class:`Fleet`.

    ``admission`` (optional) gates queries per tenant; ``autoscale``
    (optional :class:`AutoscaleConfig`) turns on the elastic router — a
    FRESH :class:`FleetAutoscaler` is built per run and the fleet's
    shard assignments reset to schema baselines at the top of every
    trace, so each replay starts from the same placement."""

    def __init__(self, fleet: Fleet, config: Optional[FleetConfig] = None,
                 service: Optional[FleetServiceModel] = None,
                 admission: Optional[AdmissionController] = None,
                 autoscale: Optional[AutoscaleConfig] = None,
                 faults: Optional[List[FaultInjection]] = None,
                 tracer=None):
        self.fleet = fleet
        self.config = config or FleetConfig()
        self.service = service or FleetServiceModel()
        self.admission = admission
        self.autoscale = autoscale
        self.faults = sorted(faults or [], key=lambda f: (f.t, f.tenant, f.shard))
        # optional repro.obs.Tracer: installed on every tenant backend for
        # the run; per flushed batch one root span with per-tenant children
        self.tracer = tracer

    # ------------------------------------------------------------------
    def _next_flush(self, queues: Dict[str, RequestQueue], now: float):
        """(t_flush, deadline_pressure) over the global queue state: the
        max-wait trigger tracks the oldest request anywhere; the deadline
        trigger budgets each tenant's tightest deadline against THAT
        tenant's sharded service estimate."""
        cfg = self.config
        t_wait = np.inf
        t_slo = np.inf
        for name in self.fleet.names():
            q = queues[name]
            if not q:
                continue
            t_wait = min(t_wait, q.oldest_arrival + cfg.max_wait)
            est = self.service.estimate_sharded(
                min(len(q), cfg.max_batch), self.fleet[name].n_shards)
            t_slo = min(t_slo, q.tightest_deadline - est - cfg.slo_slack)
        return max(now, min(t_wait, t_slo)), t_slo <= t_wait

    def _drr_batch(self, queues: Dict[str, RequestQueue],
                   deficit: Dict[str, float], max_batch: int,
                   ) -> List[RuntimeRequest]:
        """Deficit round-robin in fixed tenant order: every backlogged
        tenant earns ``weight`` credits per round and spends whole credits
        on slots; an emptied queue forfeits its credit (classic DRR — no
        banking idle time).  Fractional weights accumulate across rounds,
        so weight ratios hold exactly in the long run."""
        batch: List[RuntimeRequest] = []
        names = self.fleet.names()
        while len(batch) < max_batch and any(queues[n] for n in names):
            for name in names:
                q = queues[name]
                if not q:
                    deficit[name] = 0.0
                    continue
                deficit[name] += self.fleet[name].weight
                while deficit[name] >= 1.0 and q and len(batch) < max_batch:
                    batch.extend(q.pop(1))
                    deficit[name] -= 1.0
                if len(batch) >= max_batch:
                    break
        return batch

    def _shared_batch(self, queues: Dict[str, RequestQueue], max_batch: int,
                      ) -> List[RuntimeRequest]:
        """The no-isolation baseline: one global tightest-deadline-first
        pool, exactly what ``OnlineRuntime`` does with a single queue."""
        items: List[RuntimeRequest] = []
        for name in self.fleet.names():
            q = queues[name]
            items.extend(q.pop(len(q)))
        items.sort(key=lambda r: r.priority)
        batch, rest = items[:max_batch], items[max_batch:]
        for r in rest:
            queues[r.tenant].push(r)
        return batch

    # ------------------------------------------------------------------
    def run_trace(self, trace: ArrivalTrace,
                  telemetry: Optional[FleetTelemetry] = None) -> FleetReport:
        """Replay one multi-tenant arrival trace to completion."""
        from ..obs.trace import NULL_TRACER

        cfg = self.config
        tel = telemetry or FleetTelemetry()
        tr = self.tracer if self.tracer is not None else NULL_TRACER
        if self.tracer is not None:
            for nm in self.fleet.names():
                backend = self.fleet[nm].backend
                if hasattr(backend, "set_tracer"):
                    backend.set_tracer(self.tracer)
        self.fleet.reset_shards()
        if self.admission is not None:
            self.admission.reset()
        scaler = (FleetAutoscaler(self.fleet, self.autoscale, telemetry=tel)
                  if self.autoscale is not None else None)
        names = self.fleet.names()
        queues: Dict[str, RequestQueue] = {n: RequestQueue() for n in names}
        deficit: Dict[str, float] = {n: 0.0 for n in names}
        for n in names:
            tel.tenant(n)           # idle tenants still appear in the ledger
        reqs = sorted(trace.requests, key=lambda r: (r.t_arrival, r.rid))
        results: Dict[int, PlannedResult] = {}
        batches: List[List[int]] = []
        rejected: List[int] = []

        def pending() -> int:
            return sum(len(q) for q in queues.values())

        def push(r: RuntimeRequest) -> None:
            if r.tenant not in queues:
                raise KeyError(f"trace request for unknown tenant {r.tenant!r}")
            if self.admission is not None and not self.admission.admit(r):
                rejected.append(r.rid)
                tel.record_reject(r.tenant)
                return
            queues[r.tenant].push(r)

        i = 0
        fi = 0             # next scripted fault to apply
        now = 0.0          # virtual clock
        busy_until = 0.0   # server is serial: next batch starts after this
        n = len(reqs)
        while i < n or pending():
            if not pending():
                now = max(now, reqs[i].t_arrival)
            while i < n and reqs[i].t_arrival <= now:
                push(reqs[i])
                i += 1
            now = max(now, busy_until) if pending() else now
            while i < n and reqs[i].t_arrival <= now:
                push(reqs[i])
                i += 1
            if not pending():
                continue       # everything admitted so far was shed
            deadline_flush = False
            if pending() < cfg.max_batch:
                t_flush, pressure = self._next_flush(queues, now)
                t_next = reqs[i].t_arrival if i < n else np.inf
                if t_next <= t_flush:
                    now = max(now, t_next)
                    continue
                now, deadline_flush = t_flush, pressure
            batch = (self._drr_batch(queues, deficit, cfg.max_batch) if cfg.fair
                     else self._shared_batch(queues, cfg.max_batch))
            batches.append([r.rid for r in batch])
            # execute per tenant group, in fixed tenant order: writes
            # before reads (rid order), reads grouped by k — the same
            # contract OnlineRuntime keeps, now per tenant engine
            groups = [(nm, [r for r in batch if r.tenant == nm]) for nm in names]
            service = self.service.dispatch
            executed = []      # (tenant, reads, res, n_up, n_del, n_comp, group_s)
            w0 = time.perf_counter()
            with tr.span("batch", n_rows=len(batch),
                         deadline_flush=bool(deadline_flush)):
                for nm, greqs in groups:
                    if not greqs:
                        continue
                    col = self.fleet[nm]
                    writes = sorted((r for r in greqs if r.op != "query"),
                                    key=lambda r: r.rid)
                    reads = [r for r in greqs if r.op == "query"]
                    with tr.span("tenant_group", tenant=nm,
                                 n_reads=len(reads), n_writes=len(writes)):
                        n_up = n_del = n_comp = 0
                        for r in writes:
                            if r.op == "upsert":
                                col.upsert(*r.payload)
                                n_up += len(r.payload[0])
                            else:
                                col.delete(*r.payload)
                                n_del += len(r.payload[0])
                        if writes and col.maybe_compact() is not None:
                            n_comp = 1
                        res: List[Optional[PlannedResult]] = [None] * len(reads)
                        if reads:
                            q = np.stack([r.query for r in reads]).astype(np.float32)
                            by_k: Dict[int, List[int]] = {}
                            for j, r in enumerate(reads):
                                by_k.setdefault(r.k, []).append(j)
                            for k, rows in by_k.items():
                                out = col.batch_query(
                                    q[rows], [reads[j].pred for j in rows], k)
                                for j, r in zip(rows, out):
                                    res[j] = r
                    group_s = self.service.time_group(
                        [r.decision for r in res], col.n_shards,
                        n_upsert_rows=n_up, n_delete_rows=n_del,
                        n_compactions=n_comp)
                    service += group_s
                    executed.append((nm, writes, reads, res, n_up, n_del,
                                     n_comp, group_s))
            wall = time.perf_counter() - w0
            t_complete = now + service
            busy_until = t_complete
            for nm, writes, reads, res, n_up, n_del, n_comp, group_s in executed:
                gtel = tel.tenant(nm)
                gtel.record_wall(wall * (group_s / service if service else 0.0))
                if writes:
                    gtel.record_writes(n_up, n_del, n_comp)
                if reads:
                    gtel.record_batch(reads, res, now, t_complete, deadline_flush)
                for r_req, r_res in zip(reads, res):
                    results[r_req.rid] = r_res
                if scaler is not None:
                    for r in reads:
                        scaler.observe(nm, t_complete <= r.deadline, t_complete)
                    scaler.beat(nm, t_complete, step_time=group_s)
            if scaler is not None:
                # scripted faults manifest once the virtual clock passes
                # them — replay-deterministic by construction
                while fi < len(self.faults) and self.faults[fi].t <= t_complete:
                    f = self.faults[fi]
                    if f.kind == "kill":
                        scaler.kill_shard(f.tenant, f.shard)
                    else:
                        scaler.slow_shard(f.tenant, f.shard, f.factor)
                    fi += 1
                scaler.step(t_complete)
        return FleetReport(results, batches, rejected, tel,
                           scaler.events if scaler is not None else [])
