"""repro.fleet — multi-tenant fleet serving over shared hardware.

Scales the single-tenant ``repro.runtime`` to many named collections
(redisvl-style schemas) served from one process without letting a noisy
neighbor starve the quiet tenants:

* ``collections`` — :class:`CollectionSchema` / :class:`TenantCollection`
  / :class:`Fleet`: each tenant wraps its own ``FilteredANNEngine`` +
  ``ShardedANNEngine`` (predicate/plan caches therefore partition
  per-tenant for free), with an SLO tier, a fair-share weight, and a
  shard assignment; fleet manifests checkpoint per-tenant generations.
* ``admission`` — per-tenant token buckets refilled in VIRTUAL time;
  over-budget queries are rejected at arrival (deterministically, by
  rid) instead of queueing behind everyone else's deadline.
* ``fairshare`` — :class:`FleetRuntime`, the PR 4 discrete-event loop
  extended with per-tenant queues drained by deficit round-robin, so
  batch formation respects tenant weights while keeping the virtual/real
  replay guarantee (same trace + seed => identical batch compositions,
  result ids, telemetry counters).
* ``autoscale`` — grows/shrinks per-tenant shard assignments with
  ``dist.elastic.replan_mesh`` when sustained deadline misses cross
  thresholds, and recovers dead shards flagged by the ``dist.fault``
  monitors; every scale event is virtual-clock-stamped.
* ``telemetry`` — per-tenant plan/backend mix, SLO hit-rate, admission
  rejects, and scale events in one deterministic ledger.
"""
from .admission import AdmissionController, TokenBucket
from .autoscale import AutoscaleConfig, FaultInjection, FleetAutoscaler, ScaleEvent
from .collections import CollectionSchema, FieldSpec, Fleet, TenantCollection
from .fairshare import FleetConfig, FleetReport, FleetRuntime, FleetServiceModel
from .telemetry import FleetTelemetry

__all__ = [
    "FieldSpec",
    "CollectionSchema",
    "TenantCollection",
    "Fleet",
    "TokenBucket",
    "AdmissionController",
    "AutoscaleConfig",
    "ScaleEvent",
    "FaultInjection",
    "FleetAutoscaler",
    "FleetConfig",
    "FleetServiceModel",
    "FleetRuntime",
    "FleetReport",
    "FleetTelemetry",
]
