"""Fleet telemetry: one deterministic ledger across all tenants.

Wraps one per-tenant :class:`repro.runtime.Telemetry` (plan mix, backend
mix, deadline accounting, latency quantiles — everything the
single-tenant runtime already measures) and adds the fleet-level
signals: admission rejects per tenant, autoscale events
(virtual-clock-stamped), and the per-tenant SLO hit-rate the
noisy-neighbor benchmark gates on.  ``counters()`` is the deterministic
ledger replay tests compare; ``snapshot()`` adds quantiles, wall-clock
throughput, and each tenant engine's partitioned cache counters.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from ..obs.metrics import MetricsRegistry
from ..runtime.telemetry import Telemetry

__all__ = ["FleetTelemetry"]


class FleetTelemetry:
    """All tenants publish into ONE shared :class:`MetricsRegistry`: each
    per-tenant :class:`Telemetry` carries a ``tenant`` label, so one
    ``registry.prometheus_text()`` / ``registry.snapshot()`` call exports
    the whole fleet with tenant isolation intact."""

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tenants: Dict[str, Telemetry] = {}
        self.scale_events: List[dict] = []

    def tenant(self, name: str) -> Telemetry:
        if name not in self.tenants:
            self.tenants[name] = Telemetry(
                registry=self.registry, labels={"tenant": name})
        return self.tenants[name]

    # ------------------------------------------------------------------
    def record_reject(self, tenant: str) -> None:
        self.registry.inc("repro_admission_rejected_total", tenant=tenant)

    @property
    def rejects(self) -> Dict[str, int]:
        return {
            lbl["tenant"]: int(v)
            for lbl, v in self.registry.series("repro_admission_rejected_total")
        }

    def record_scale(self, event) -> None:
        """``event`` is an ``autoscale.ScaleEvent`` (or any dataclass with
        an ``as_dict()``) — stored as a plain dict so the ledger stays
        JSON-serialisable and comparable across replays."""
        self.scale_events.append(
            event.as_dict() if hasattr(event, "as_dict") else dict(event))

    # ------------------------------------------------------------------
    def slo_hit_rate(self, tenant: str) -> float:
        """met / (met + missed) across that tenant's completed queries;
        1.0 when nothing completed (vacuously on-SLO)."""
        tel = self.tenants.get(tenant)
        if tel is None:
            return 1.0
        met = sum(tel.deadline_met.values())
        missed = sum(tel.deadline_missed.values())
        return met / (met + missed) if met + missed else 1.0

    def counters(self) -> Dict:
        """The deterministic ledger only (what replay tests compare)."""
        return {
            "tenants": {n: t.counters() for n, t in sorted(self.tenants.items())},
            "rejects": dict(sorted(self.rejects.items())),
            "scale_events": list(self.scale_events),
            "slo_hit_rate": {n: round(self.slo_hit_rate(n), 6)
                             for n in sorted(self.tenants)},
        }

    def snapshot(self, fleet=None) -> Dict:
        """Counters + per-tenant quantiles/wall stats; when ``fleet`` is
        given, each tenant's engine counters ride along (the partitioned
        predicate/plan caches, live-corpus stats, shard count)."""
        out = dict(self.counters())
        out["tenant_detail"] = {}
        for n, tel in sorted(self.tenants.items()):
            backend = fleet[n].backend if fleet is not None and n in fleet else None
            out["tenant_detail"][n] = tel.snapshot(backend)
        return out

    def merged(self) -> Optional[Telemetry]:
        """Convenience: the busiest tenant's Telemetry (or None) — for
        call sites that want a representative single-tenant view."""
        if not self.tenants:
            return None
        return max(self.tenants.values(), key=lambda t: t.n_completed)
