from .vectors import make_dataset, DATASETS, VectorDataset

__all__ = ["make_dataset", "DATASETS", "VectorDataset"]
