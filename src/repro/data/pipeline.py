"""Deterministic synthetic token pipeline (shardable, restartable).

Real deployments plug a tokenised corpus in here; the contract the trainer
relies on is: (a) ``batch_at(step)`` is a pure function of (seed, step) so a
restarted/elastically-resized job regenerates identical batches, (b) hosts
can take disjoint shards by slicing the batch dim.

Sequences are Zipf-distributed token ids with a Markov bigram flavour so the
loss actually decreases during the example runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

__all__ = ["TokenPipeline"]


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend: str = "none"       # "vision"/"audio" -> adds stub embeddings
    frontend_len: int = 0
    d_model: int = 0

    def batch_at(self, step: int, host_slice: Optional[slice] = None) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.global_batch, self.seq_len
        # Zipf marginals + deterministic bigram drift -> learnable structure
        ranks = np.arange(1, self.vocab_size + 1)
        p = 1.0 / ranks**1.1
        p /= p.sum()
        base = rng.choice(self.vocab_size, size=(b, s + 1), p=p)
        drift = (np.cumsum(base, axis=1) % 7) == 0
        base[:, 1:] = np.where(drift[:, 1:], (base[:, :-1] + 1) % self.vocab_size, base[:, 1:])
        batch = {
            "tokens": base[:, :-1].astype(np.int32),
            "labels": base[:, 1:].astype(np.int32),
        }
        if self.frontend == "vision":
            batch["patches"] = rng.normal(
                0, 1, (b, self.frontend_len, self.d_model)
            ).astype(np.float32)
        elif self.frontend == "audio":
            batch["frames"] = rng.normal(
                0, 1, (b, self.frontend_len, self.d_model)
            ).astype(np.float32)
        if host_slice is not None:
            batch = {k: v[host_slice] for k, v in batch.items()}
        return batch
