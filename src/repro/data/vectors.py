"""Synthetic stand-ins for the paper's four datasets (Table 1).

The public datasets (ArXiv/Wolt via Qdrant, GloVe-200, SIFT-1M via
ann-benchmarks) are not downloadable in this offline container; these
generators match their dimensionality and metadata *shape*, with realistic
structure:

* vectors: Gaussian mixtures (clustered, like real embeddings), cluster ids
  correlated with categorical metadata (filters correlate with geometry in
  real filtered-ANN workloads);
* categorical attributes: Zipf-distributed codes;
* numeric attributes: lognormal ("price"-like) and Gaussian-mixture
  ("year"-like) marginals, partially correlated with cluster id.

Scale is configurable; benchmark default is reduced (CPU container), the
paper-scale row counts remain selectable with ``scale="full"``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

__all__ = ["VectorDataset", "make_dataset", "DATASETS"]


@dataclasses.dataclass
class VectorDataset:
    name: str
    vectors: np.ndarray     # (N, d) float32
    cat: np.ndarray         # (N, A_cat) int32 codes (-1 = missing)
    num: np.ndarray         # (N, A_num) float32
    filter_kinds: Tuple[str, ...]   # query kinds used in the paper's workload

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]


# name -> (paper_n, dim, filter kinds)   [paper Table 1]
DATASETS: Dict[str, Tuple[int, int, Tuple[str, ...]]] = {
    "arxiv": (2_140_000, 384, ("mixed", "label", "range")),
    "wolt": (1_720_000, 512, ("range",)),
    "glove200": (1_180_000, 200, ("range",)),
    "sift": (1_000_000, 128, ("range",)),
}

_REDUCED_N = {
    "arxiv": 120_000,
    "wolt": 100_000,
    "glove200": 100_000,
    "sift": 100_000,
}


def _mixture_vectors(
    rng: np.random.Generator, n: int, d: int, n_clusters: int
) -> Tuple[np.ndarray, np.ndarray]:
    centers = rng.normal(0, 1.0, size=(n_clusters, d)).astype(np.float32)
    weights = rng.dirichlet(np.full(n_clusters, 2.0))
    cluster = rng.choice(n_clusters, size=n, p=weights)
    spread = rng.uniform(0.25, 0.6, size=n_clusters).astype(np.float32)
    x = centers[cluster] + rng.normal(0, 1, size=(n, d)).astype(np.float32) * spread[
        cluster, None
    ]
    return x, cluster.astype(np.int32)


def _zipf_codes(
    rng: np.random.Generator, n: int, card: int, corr: np.ndarray, corr_strength: float
) -> np.ndarray:
    """Zipf-distributed codes, partially correlated with cluster id."""
    ranks = np.arange(1, card + 1, dtype=np.float64)
    p = (1.0 / ranks**1.1)
    p /= p.sum()
    base = rng.choice(card, size=n, p=p)
    from_cluster = corr % card
    take = rng.random(n) < corr_strength
    return np.where(take, from_cluster, base).astype(np.int32)


def make_dataset(name: str, scale: str = "reduced", seed: int = 0) -> VectorDataset:
    paper_n, d, kinds = DATASETS[name]
    n = paper_n if scale == "full" else (_REDUCED_N[name] if scale == "reduced" else int(scale))
    rng = np.random.default_rng(seed + hash(name) % 2**16)
    n_clusters = 64
    x, cluster = _mixture_vectors(rng, n, d, n_clusters)

    if name == "arxiv":
        # mixed metadata: category labels (Zipf, 40 codes), sub-topic (25),
        # license (5); numeric: year-like + citation-count-like.
        cat = np.stack(
            [
                _zipf_codes(rng, n, 40, cluster, 0.5),
                _zipf_codes(rng, n, 25, cluster, 0.3),
                _zipf_codes(rng, n, 5, cluster, 0.0),
            ],
            axis=1,
        )
        year = 1995 + (cluster % 8) * 3 + rng.normal(8, 6, n)
        cites = rng.lognormal(2.0, 1.5, n)
        num = np.stack([year, cites], axis=1).astype(np.float32)
    elif name == "wolt":
        # range-only workload on real-valued attrs: price-like lognormal,
        # delivery-time-like gamma; one incidental categorical kept for
        # completeness (not used by the range workload).
        cat = _zipf_codes(rng, n, 30, cluster, 0.4)[:, None]
        price = rng.lognormal(2.5, 0.7, n) + (cluster % 4) * 3.0
        minutes = rng.gamma(6.0, 5.0, n)
        rating = np.clip(rng.normal(8.2, 1.1, n), 1, 10)
        num = np.stack([price, minutes, rating], axis=1).astype(np.float32)
    else:  # glove200 / sift: synthetic numeric attributes (paper §4.1)
        cat = _zipf_codes(rng, n, 20, cluster, 0.3)[:, None]
        u = rng.normal(0, 1, n) + (cluster % 8) * 0.7
        v = rng.lognormal(1.0, 1.0, n)
        num = np.stack([u, v], axis=1).astype(np.float32)

    return VectorDataset(name=name, vectors=x, cat=cat, num=num, filter_kinds=kinds)
