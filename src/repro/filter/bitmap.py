"""Packed bitset primitives + per-label bitmap indexes.

A bitmap is a ``(ceil(N/32),)`` uint32 array; bit ``i`` of the corpus lives
at word ``i >> 5``, position ``i & 31`` (little-endian byte order within the
word, matching ``np.packbits(bitorder="little")`` viewed as uint32 on LE
hosts — the only hosts this repo targets).  All bitmaps maintain the
invariant that tail bits beyond ``n`` are zero, so popcounts and word-wise
combines never need an extra mask except after complement (``word_andnot``
re-clears the tail).

Why words and not bool masks: predicate evaluation over packed words touches
N/32 uint32s per leaf instead of N floats/ints per leaf — the 32x word
parallelism (plus cache locality) is where the indexed pre-filter's speedup
over scan-mask evaluation comes from.  Expansion back to a bool mask
(``expand_words``) is the bridge to the mask-native kernels
(``kernels.ops.fused_masked_topk``).
"""
from __future__ import annotations

from typing import List

import numpy as np

__all__ = [
    "WORD_BITS",
    "n_words",
    "pack_mask",
    "expand_words",
    "popcount_words",
    "words_from_ids",
    "full_words",
    "empty_words",
    "word_and",
    "word_or",
    "word_andnot",
    "clear_tail",
    "BitmapLabelIndex",
]

WORD_BITS = 32


def n_words(n: int) -> int:
    return (int(n) + WORD_BITS - 1) // WORD_BITS


def clear_tail(words: np.ndarray, n: int) -> np.ndarray:
    """Zero the bits beyond ``n`` in the last word (in place); returns words."""
    rem = n & (WORD_BITS - 1)
    if words.size and rem:
        words[-1] &= np.uint32((1 << rem) - 1)
    return words


def pack_mask(mask: np.ndarray) -> np.ndarray:
    """Bool mask (N,) -> packed uint32 words (tail bits zero)."""
    mask = np.asarray(mask, dtype=bool)
    nw = n_words(mask.size)
    by = np.packbits(mask, bitorder="little")
    if by.size < 4 * nw:
        by = np.pad(by, (0, 4 * nw - by.size))
    return by.view(np.uint32).copy()


def expand_words(words: np.ndarray, n: int) -> np.ndarray:
    """Packed words -> bool mask of length ``n``."""
    if n == 0:
        return np.zeros(0, dtype=bool)
    bits = np.unpackbits(words.view(np.uint8), count=n, bitorder="little")
    return bits.astype(bool)


if hasattr(np, "bitwise_count"):

    def popcount_words(words: np.ndarray) -> int:
        """Number of set bits (numpy >= 2: hardware popcount)."""
        return int(np.bitwise_count(words).sum())

else:  # numpy < 2 fallback: byte-wise lookup table
    _POPCNT8 = np.array([bin(i).count("1") for i in range(256)], dtype=np.uint16)

    def popcount_words(words: np.ndarray) -> int:
        """Number of set bits (LUT over the uint8 view)."""
        return int(_POPCNT8[words.view(np.uint8)].sum())


def words_from_ids(ids: np.ndarray, n: int) -> np.ndarray:
    """Packed bitmap with exactly the bits in ``ids`` (int row ids) set."""
    words = np.zeros(n_words(n), dtype=np.uint32)
    if ids.size:
        ids = np.asarray(ids, dtype=np.int64)
        np.bitwise_or.at(words, ids >> 5, np.uint32(1) << (ids & 31).astype(np.uint32))
    return words


def full_words(n: int) -> np.ndarray:
    words = np.full(n_words(n), np.uint32(0xFFFFFFFF), dtype=np.uint32)
    return clear_tail(words, n)


def empty_words(n: int) -> np.ndarray:
    return np.zeros(n_words(n), dtype=np.uint32)


def word_and(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return a & b


def word_or(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return a | b


def word_andnot(a: np.ndarray, b: np.ndarray, n: int) -> np.ndarray:
    """``a AND NOT b`` — the complement re-sets tail bits, so re-clear them."""
    return clear_tail(a & ~b, n)


# An attribute with more distinct codes than this is not bitmap-indexed
# (dense per-code bitmaps over an ID-like column would cost O(codes * N/8)
# bytes); the compiler reports it uncovered and executors fall back to the
# columnar scan for predicates touching it.
MAX_CODES_INDEXED = 4096


class BitmapLabelIndex:
    """Per-categorical-attribute, per-*present*-code packed bitmaps.

    ``bitmap(attr, code)`` answers ``cat[:, attr] == code`` in O(1) (a dict
    lookup), including ``code == NULL_CODE`` (missing-attribute rows get
    their own bitmap so negations and explicit NULL queries stay exact).
    Codes absent from the column return the empty bitmap — exactly what the
    columnar scan would produce.  Build is one argsort + one
    ``words_from_ids`` pass per attribute (O(N log N), independent of the
    code-space size — a sparse column with max code 10^6 costs the same as
    a dense one); attributes with more than :data:`MAX_CODES_INDEXED`
    distinct codes are left unindexed (see :meth:`indexed`).
    """

    def __init__(self, n: int, code_words: List[dict], indexed: List[bool]):
        self.n = n
        self._code_words = code_words      # per attr: {code: words}
        self._indexed = indexed

    @property
    def n_attrs(self) -> int:
        return len(self._code_words)

    def indexed(self, attr: int) -> bool:
        return self._indexed[attr]

    @staticmethod
    def build(cat: np.ndarray) -> "BitmapLabelIndex":
        cat = np.asarray(cat)
        n = cat.shape[0] if cat.ndim >= 2 else 0
        a_cat = cat.shape[1] if cat.ndim >= 2 else 0
        code_words: List[dict] = []
        indexed: List[bool] = []
        for a in range(a_cat):
            col = cat[:, a]
            order = np.argsort(col, kind="stable").astype(np.int64)
            sc = col[order]
            codes, starts = (np.unique(sc, return_index=True) if n
                             else (np.empty(0, col.dtype), np.empty(0, np.int64)))
            if codes.size > MAX_CODES_INDEXED:
                code_words.append({})
                indexed.append(False)
                continue
            bounds = np.append(starts, n)
            code_words.append({
                int(c): words_from_ids(order[starts[j]:bounds[j + 1]], n)
                for j, c in enumerate(codes)
            })
            indexed.append(True)
        return BitmapLabelIndex(n, code_words, indexed)

    def bitmap(self, attr: int, code: int) -> np.ndarray:
        w = self._code_words[attr].get(int(code))
        return w if w is not None else empty_words(self.n)

    # ------------------------------------------------------------------
    def extend(self, cat_new: np.ndarray) -> "BitmapLabelIndex":
        """Incrementally index appended rows (the live-corpus upsert path).

        Existing per-code bitmaps are zero-padded to the grown word count
        (appended rows don't carry old codes' bits), then the new rows'
        bits OR in per distinct code — O(codes · N/32 + rows) per batch,
        no rebuild.  An attribute whose distinct-code count crosses
        :data:`MAX_CODES_INDEXED` drops to unindexed (fail closed, same as
        at build time).  Deletes never come through here: tombstones are
        ANDNOT-composed at query time, so stored bitmaps stay exact.
        """
        cat_new = np.atleast_2d(np.asarray(cat_new))
        rows = cat_new.shape[0]
        if rows == 0:
            return self
        old_n, new_n = self.n, self.n + rows
        nw = n_words(new_n)
        for a in range(self.n_attrs):
            if not self._indexed[a]:
                continue
            d = self._code_words[a]
            for code in d:
                d[code] = (np.pad(d[code], (0, nw - d[code].size))
                           if d[code].size < nw else d[code])
            col = cat_new[:, a]
            for code in np.unique(col):
                ids = old_n + np.nonzero(col == code)[0]
                add = words_from_ids(ids, new_n)
                prev = d.get(int(code))
                d[int(code)] = add if prev is None else word_or(prev, add)
            if len(d) > MAX_CODES_INDEXED:
                self._code_words[a] = {}
                self._indexed[a] = False
        self.n = new_n
        return self
