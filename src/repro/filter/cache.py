"""LRU compiled-predicate cache keyed by canonicalised predicate.

Serving traffic repeats predicates constantly (the same storefront filter,
the same date window), and differently-constructed but logically identical
predicates should share one compilation: ``canonical_key`` normalises
conjunct/term order and duplicates, so
``Predicate(labels=(A, B))`` and ``Predicate(labels=(B, A, A))`` hit the
same cache line.  (``RangePred`` already canonicalises its intervals —
sorted, merged, empties dropped — at construction.)

One cache instance is shared between the selectivity estimator's exact fast
path and the indexed pre-filter executor, so a planned-then-executed query
compiles its bitmap exactly once; the compiled object also caches its bool
mask expansion, making repeat evaluations ~free.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Tuple

from ..core.predicates import AnyPredicate, LabelEq, Not, Or, Predicate, RangePred
from .compile import AttributeIndex, CompiledPredicate

__all__ = ["canonical_key", "PredicateCache"]


def canonical_key(pred) -> Tuple:
    """Order- and duplicate-insensitive structural key for any IR node."""
    if isinstance(pred, LabelEq):
        return ("L", int(pred.attr), int(pred.code))
    if isinstance(pred, RangePred):
        return ("R", int(pred.attr), pred.intervals)
    if isinstance(pred, Not):
        return ("N", canonical_key(pred.term))
    if isinstance(pred, Predicate):
        leaves = sorted(
            {canonical_key(p) for p in (*pred.labels, *pred.ranges, *pred.nots)}
        )
        return ("AND", tuple(leaves))
    if isinstance(pred, Or):
        return ("OR", tuple(sorted({canonical_key(t) for t in pred.terms})))
    raise TypeError(f"not a predicate IR node: {type(pred).__name__}")


class PredicateCache:
    """LRU map: canonical predicate key -> :class:`CompiledPredicate`.

    Packed words are cheap (N/8 bytes) and live for the full ``capacity``;
    expanded bool masks are 8x bigger, so only the ``mask_capacity`` most
    recently *executed* predicates keep theirs materialised (:meth:`mask`
    re-expands from the words on a mask-tier miss — O(N/8), still ~30x
    cheaper than a scan).  This bounds worst-case memory at
    ``capacity*N/8 + mask_capacity*N`` bytes instead of ``capacity*9N/8``.
    """

    def __init__(self, capacity: int = 256, mask_capacity: int = 64):
        assert capacity >= 1 and mask_capacity >= 1
        self.capacity = capacity
        self.mask_capacity = mask_capacity
        self._store: "OrderedDict[Tuple, CompiledPredicate]" = OrderedDict()
        self._masks: "OrderedDict[Tuple, object]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        # mask-tier split (subset of hits/misses above): the obs layer's
        # cache_hit_ratio gauge tracks the expanded-mask tier separately,
        # since a mask-tier miss still costs an O(N/8) re-expansion
        self.mask_hits = 0
        self.mask_misses = 0

    def __len__(self) -> int:
        return len(self._store)

    def get_or_compile(self, pred: AnyPredicate, index: AttributeIndex) -> CompiledPredicate:
        key = canonical_key(pred)
        hit = self._store.get(key)
        if hit is not None:
            self.hits += 1
            self._store.move_to_end(key)
            return hit
        self.misses += 1
        compiled = index.compile(pred)
        self._store[key] = compiled
        if len(self._store) > self.capacity:
            old_key, _ = self._store.popitem(last=False)
            self._masks.pop(old_key, None)
            self.evictions += 1
        return compiled

    def mask(self, pred: AnyPredicate, index: AttributeIndex):
        """Bool candidate mask for ``pred``, through both cache tiers —
        the executors' entry point."""
        from .bitmap import expand_words

        key = canonical_key(pred)
        m = self._masks.get(key)
        if m is None:
            self.mask_misses += 1
            c = self.get_or_compile(pred, index)
            m = expand_words(c.words, c.n)
            self._masks[key] = m
            if len(self._masks) > self.mask_capacity:
                self._masks.popitem(last=False)
        else:
            self._masks.move_to_end(key)
            self.hits += 1
            self.mask_hits += 1
        return m

    def invalidate(self) -> None:
        """Drop every compiled entry because the CORPUS changed under them
        (live-corpus upsert: stored words have the old row count).  Unlike
        :meth:`clear`, the hit/miss history survives and the invalidation
        is counted — mutation-driven churn must be observable in
        ``stats()`` (engine telemetry asserts on it)."""
        self._store.clear()
        self._masks.clear()
        self.invalidations += 1

    def stats(self) -> Dict[str, int]:
        return {
            "size": len(self._store),
            "capacity": self.capacity,
            "masks": len(self._masks),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
            "mask_hits": self.mask_hits,
            "mask_misses": self.mask_misses,
        }

    def clear(self) -> None:
        self._store.clear()
        self._masks.clear()
        self.hits = self.misses = self.evictions = 0
        self.mask_hits = self.mask_misses = 0
