"""Sorted-order + equi-depth bucket indexes answering interval predicates
as bitmaps — no O(N) columnar compare.

Per numeric attribute the index stores:

* ``order``  — the argsort permutation of the column,
* ``vals``   — the column sorted ascending, **kept in the column's own
  dtype**: the scan path evaluates ``x >= lo`` with Python-float bounds,
  which NumPy 2 weak promotion resolves in the COLUMN's dtype (the bound
  is rounded to float32 for float32 data).  ``interval_words`` therefore
  quantises each bound through that dtype before ``searchsorted``, so the
  index includes/excludes boundary rows exactly as the scan does,
* ``edges``  — B+1 equi-depth bucket boundaries in *position* space,
* ``bucket_words`` — a (B, W) uint32 matrix: bucket b's precomputed bitmap
  of the rows at sorted positions ``[edges[b], edges[b+1])``.

An interval ``[lo, hi)`` maps to the sorted-position slice
``[searchsorted(vals, lo, "left"), searchsorted(vals, hi, "left"))``; the
fully covered buckets OR together via one vectorised reduce over the
precomputed rows, and only the two partial boundary slices (at most one
bucket's worth of rows each) pack individually.  Total cost is
O(B · N/32 + N/B) words versus the scan's O(N) float compares.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from .bitmap import empty_words, n_words, word_or, words_from_ids

__all__ = ["RangeIndex", "DEFAULT_BUCKETS"]

DEFAULT_BUCKETS = 128


class RangeIndex:
    def __init__(self, n: int, orders: List[np.ndarray], vals: List[np.ndarray],
                 edges: List[np.ndarray], bucket_words: List[np.ndarray]):
        self.n = n
        self._orders = orders
        self._vals = vals
        self._edges = edges
        self._bucket_words = bucket_words
        # Staleness under a live corpus: sorted orders and equi-depth bucket
        # boundaries CANNOT be extended incrementally (an appended value
        # lands anywhere in the sorted permutation), so a mutated attribute
        # fails CLOSED — ``fresh()`` goes False, the attribute drops out of
        # ``AttributeIndex.covers()``, and executors fall back to the
        # columnar scan instead of answering from pre-mutation buckets.
        self._stale = [False] * len(orders)

    @property
    def n_attrs(self) -> int:
        return len(self._orders)

    def fresh(self, attr: int) -> bool:
        """False once the corpus mutated under this attribute's buckets —
        callers must not consult the pre-mutation index for it."""
        return not self._stale[attr]

    def mark_stale(self) -> None:
        """Invalidate every attribute (appended rows carry values for all
        numeric columns).  A compaction rebuilds the index fresh."""
        self._stale = [True] * len(self._orders)

    @staticmethod
    def build(num: np.ndarray, n_buckets: int = DEFAULT_BUCKETS) -> "RangeIndex":
        num = np.asarray(num)
        n = num.shape[0] if num.ndim >= 2 else 0
        a_num = num.shape[1] if num.ndim >= 2 else 0
        orders, vals, edges, bucket_words = [], [], [], []
        for j in range(a_num):
            col = num[:, j]
            order = np.argsort(col, kind="stable").astype(np.int64)
            sv = np.ascontiguousarray(col[order])   # column dtype preserved
            b = max(1, min(int(n_buckets), n)) if n else 1
            e = np.round(np.linspace(0, n, b + 1)).astype(np.int64)
            bw = np.zeros((b, n_words(n)), dtype=np.uint32)
            for i in range(b):
                bw[i] = words_from_ids(order[e[i]:e[i + 1]], n)
            orders.append(order)
            vals.append(sv)
            edges.append(e)
            bucket_words.append(bw)
        return RangeIndex(n, orders, vals, edges, bucket_words)

    # ------------------------------------------------------------------
    def _cut(self, attr: int, bound: float) -> int:
        """Sorted position of the first value >= ``bound``, with the bound
        quantised exactly as the columnar scan's comparison would see it
        (Python-float bounds weak-promote to the column dtype)."""
        sv = self._vals[attr]
        if np.issubdtype(sv.dtype, np.floating):
            with np.errstate(over="ignore"):   # out-of-range bound -> +-inf,
                bound = sv.dtype.type(bound)   # exactly what the scan's cast does
        return int(np.searchsorted(sv, bound, side="left"))

    def interval_words(self, attr: int, lo: float, hi: float) -> np.ndarray:
        """Bitmap of ``lo <= x < hi`` over attribute ``attr`` (exact)."""
        if self.n == 0:
            return empty_words(0)
        order = self._orders[attr]
        left = self._cut(attr, lo)
        right = self._cut(attr, hi)
        if right <= left:
            return empty_words(self.n)
        e = self._edges[attr]
        i0 = int(np.searchsorted(e, left, side="left"))    # first edge >= left
        i1 = int(np.searchsorted(e, right, side="right")) - 1  # last edge <= right
        if i0 < i1:
            # full buckets [i0, i1) OR'd in one vectorised reduce; only the
            # boundary slices (each at most one bucket of rows) pack fresh
            w = np.bitwise_or.reduce(self._bucket_words[attr][i0:i1], axis=0)
            partial = np.concatenate([order[left:e[i0]], order[e[i1]:right]])
        else:
            w = empty_words(self.n)
            partial = order[left:right]
        return word_or(w, words_from_ids(partial, self.n))

    def union_words(self, attr: int, intervals: Sequence[Tuple[float, float]]) -> np.ndarray:
        """Bitmap of a union of intervals over one attribute.  ``RangePred``
        construction merges overlaps, so the union is a plain OR."""
        w = empty_words(self.n)
        for lo, hi in intervals:
            w = word_or(w, self.interval_words(attr, lo, hi))
        return w
