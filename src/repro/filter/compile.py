"""DNF predicate -> packed-bitmap compiler with exact popcount selectivity.

``AttributeIndex`` bundles the per-label bitmap index (categorical
attributes) and the sorted-order/equi-depth range index (numeric
attributes) built once at corpus build/shard time.  ``compile()`` walks any
:class:`repro.core.predicates.AnyPredicate` in DNF:

* ``LabelEq``   -> stored per-code bitmap (AND into the conjunction),
* ``RangePred`` -> OR of searchsorted interval bitmaps (AND in),
* ``Not(leaf)`` -> ANDNOT of the leaf's bitmap,
* ``Predicate`` -> AND over its leaves (empty conjunction = all-ones: TRUE),
* ``Or``        -> OR over its compiled terms (no terms = all-zeros: FALSE).

The result carries the exact match count (``popcount``) — which is also the
exact selectivity the estimator's fast path serves — and expands lazily to
the bool mask the executors and kernels consume.  In serving, executors go
through ``PredicateCache.mask`` (a bounded second cache tier) rather than
:meth:`CompiledPredicate.mask`, so repeat predicates skip the expansion too
without pinning one mask per cached compilation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.predicates import AnyPredicate, LabelEq, Or, Predicate, RangePred, iter_leaves
from .bitmap import (
    BitmapLabelIndex,
    empty_words,
    expand_words,
    full_words,
    popcount_words,
    word_and,
    word_andnot,
    word_or,
)
from .ranges import DEFAULT_BUCKETS, RangeIndex

__all__ = ["CompiledPredicate", "AttributeIndex"]


@dataclasses.dataclass
class CompiledPredicate:
    """A predicate lowered to one packed bitmap over the corpus."""

    words: np.ndarray          # (ceil(n/32),) uint32, tail bits clear
    n: int                     # corpus rows
    popcount: int              # exact number of matching rows
    covered: bool              # True: the bitmap is exact (index covered all leaves)
    _mask: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)

    @property
    def selectivity(self) -> float:
        return self.popcount / self.n if self.n else 0.0

    def mask(self) -> np.ndarray:
        """Bool mask expansion, cached — a cache-hit predicate pays neither
        compilation nor expansion."""
        if self._mask is None:
            self._mask = expand_words(self.words, self.n)
        return self._mask


class AttributeIndex:
    """Bitmap + range indexes over one corpus's metadata columns."""

    def __init__(self, labels: BitmapLabelIndex, ranges: RangeIndex, n: int):
        self.labels = labels
        self.ranges = ranges
        self.n = n

    @staticmethod
    def build(cat: np.ndarray, num: np.ndarray,
              range_buckets: int = DEFAULT_BUCKETS) -> "AttributeIndex":
        labels = BitmapLabelIndex.build(cat)
        ranges = RangeIndex.build(num, n_buckets=range_buckets)
        return AttributeIndex(labels, ranges, max(labels.n, ranges.n))

    def extend(self, cat_new: np.ndarray, num_new: np.ndarray) -> "AttributeIndex":
        """Live-corpus refresh for appended rows: label bitmaps extend
        incrementally (stay covered and exact over the grown corpus); the
        equi-depth range index cannot, so its attributes go stale and drop
        out of :meth:`covers` until compaction rebuilds them.  The caller
        owns invalidating any compiled-predicate cache — stored bitmaps
        compiled before the extend have the old word count."""
        cat_new = np.atleast_2d(np.asarray(cat_new))
        rows = cat_new.shape[0]
        if rows == 0:
            return self
        self.labels.extend(cat_new)
        if self.ranges.n_attrs:
            self.ranges.mark_stale()
        self.n += rows
        return self

    # ------------------------------------------------------------------
    def _leaf_covered(self, leaf) -> bool:
        if isinstance(leaf, LabelEq):
            return 0 <= leaf.attr < self.labels.n_attrs and self.labels.indexed(leaf.attr)
        if isinstance(leaf, RangePred):
            # a stale (post-mutation) range attribute fails closed: the
            # predicate demotes to the scan path + estimated selectivity
            return (0 <= leaf.attr < self.ranges.n_attrs
                    and self.ranges.fresh(leaf.attr))
        return False

    def covers(self, pred: AnyPredicate) -> bool:
        """True when every leaf references an indexed attribute — i.e. the
        compiled bitmap (and its popcount selectivity) is exact."""
        return all(self._leaf_covered(leaf) for leaf in iter_leaves(pred))

    # ------------------------------------------------------------------
    def _leaf_words(self, leaf) -> np.ndarray:
        if isinstance(leaf, LabelEq):
            return self.labels.bitmap(leaf.attr, leaf.code)
        return self.ranges.union_words(leaf.attr, leaf.intervals)

    def _conj_words(self, pred: Predicate) -> np.ndarray:
        w = full_words(self.n)
        for leaf in (*pred.labels, *pred.ranges):
            w = word_and(w, self._leaf_words(leaf))
        for nt in pred.nots:
            w = word_andnot(w, self._leaf_words(nt.term), self.n)
        return w

    def compile(self, pred: AnyPredicate) -> CompiledPredicate:
        """Lower a DNF predicate to its bitmap.  Raises on uncovered leaves —
        callers gate on :meth:`covers` (the executor falls back to the
        columnar scan for uncovered predicates)."""
        if not self.covers(pred):
            raise ValueError(f"predicate references unindexed attributes: {pred}")
        if isinstance(pred, Or):
            w = empty_words(self.n)
            for t in pred.terms:
                w = word_or(w, self._conj_words(t))
        else:
            w = self._conj_words(pred)
        return CompiledPredicate(
            words=w, n=self.n, popcount=popcount_words(w), covered=True
        )
