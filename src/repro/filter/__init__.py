"""repro.filter — attribute indexes and the DNF predicate compiler.

The third leg of the query planner's stool: where pre-filtering scans the
metadata columns per query and post-filtering probes the vector index
first, the *indexed* pre-filter answers the predicate from precomputed
packed-bitset indexes (``bitmap`` for categorical labels, ``ranges`` for
numeric intervals), compiled per predicate (``compile``) and memoised
across serving traffic (``cache``).  Exact popcount selectivities fall out
for free and feed the planner's ``sel_is_exact`` fast path.
"""
from .bitmap import (
    BitmapLabelIndex,
    WORD_BITS,
    empty_words,
    expand_words,
    full_words,
    n_words,
    pack_mask,
    popcount_words,
    words_from_ids,
)
from .ranges import DEFAULT_BUCKETS, RangeIndex
from .compile import AttributeIndex, CompiledPredicate
from .cache import PredicateCache, canonical_key

__all__ = [
    "WORD_BITS",
    "n_words",
    "pack_mask",
    "expand_words",
    "popcount_words",
    "words_from_ids",
    "full_words",
    "empty_words",
    "BitmapLabelIndex",
    "RangeIndex",
    "DEFAULT_BUCKETS",
    "AttributeIndex",
    "CompiledPredicate",
    "PredicateCache",
    "canonical_key",
]
