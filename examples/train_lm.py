"""Train a ~100M-param LM for a few hundred steps (deliverable b's training
driver, CPU-sized).  Uses the same launch/train.py machinery as the
production mesh, with checkpoint/resume enabled.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="hymba-1.5b")
    args = ap.parse_args()
    train_main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--seq-len", "64", "--batch", "8",
        "--ckpt-dir", "/tmp/repro_train_ckpt", "--ckpt-every", "50",
        "--lr", "1e-3",
    ])
