"""Quickstart: learned query planning for filtered ANN in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import EngineConfig, FilteredANNEngine, recall_at_k
from repro.core.trainer import gen_queries
from repro.data import make_dataset

# 1. a corpus with metadata (SIFT-like stand-in, 20k vectors)
ds = make_dataset("sift", scale="20000", seed=0)
print(f"corpus: {ds.n} x {ds.dim}, cat attrs {ds.cat.shape[1]}, num attrs {ds.num.shape[1]}")

# 2. build the engine: statistics + global IVF index (offline)
eng = FilteredANNEngine(ds.vectors, ds.cat, ds.num, EngineConfig(seed=0)).build()
print(f"built: stats {eng.build_time_['stats']:.2f}s, ivf {eng.build_time_['ivf']:.2f}s")

# 3. train the planner: controlled-selectivity queries, both strategies
#    executed, labelled by utility U = recall / time (paper §3.1)
tq, tp, _ = gen_queries(ds.vectors, ds.cat, ds.num, 40, kinds=("range", "mixed"), seed=1)
eng.fit(tq, tp, k=10)
print(f"planner trained in {eng.build_time_['fit']:.2f}s "
      f"(cv AUC {eng.planner.val_auc_:.3f}, l2 {eng.planner.best_l2_})")

# 4. serve filtered queries — the planner picks pre- vs post-filtering
qs, preds, sels = gen_queries(ds.vectors, ds.cat, ds.num, 10, kinds=("range",), seed=7)
for i, p in enumerate(preds):
    out = eng.query(qs[i], p, k=10)
    truth = eng.ground_truth(qs[i], p, k=10)
    rec = recall_at_k(out.result.ids, truth)
    print(
        f"  sel={sels[i]:.3f} est={out.est_selectivity:.3f} "
        f"plan={['PRE ', 'POST', 'IPRE'][out.decision]} "
        f"recall@10={rec:.2f} {out.result.elapsed*1e3:6.1f} ms"
    )
