"""Filtered search deep-dive: all execution strategies side by side on one
workload, showing where each wins (the paper's Figure 2 story) — then DNF
predicates (Or / Not over the conjunctive leaves) planned per query through
the 3-way planner (pre / post / indexed-pre).

    PYTHONPATH=src python examples/filtered_search.py
"""
import time

import numpy as np

from repro.core import (
    EngineConfig, FilteredANNEngine, LabelEq, Not, Or, Predicate, RangePred,
    recall_at_k,
)
from repro.core.trainer import gen_queries
from repro.data import make_dataset
from repro.index import make_backend

K = 10
ds = make_dataset("glove200", scale="20000", seed=0)
eng = FilteredANNEngine(ds.vectors, ds.cat, ds.num, EngineConfig(seed=0)).build()
tq, tp, _ = gen_queries(ds.vectors, ds.cat, ds.num, 40, kinds=("range",), seed=1)
eng.fit(tq, tp, k=K)
print("building ACORN-1 graph baseline (via the backend registry)...")
t0 = time.perf_counter()
acorn = make_backend("acorn", ds.vectors, seed=0)
print(f"  acorn build {time.perf_counter()-t0:.1f}s "
      f"(planner build was {eng.build_time_['stats']+eng.build_time_['ivf']+eng.build_time_['fit']:.1f}s)")


class _AcornRes:
    """Adapter giving the registry backend the (ids, elapsed) result shape
    the side-by-side loop below expects."""

    def __init__(self, q, p):
        t0 = time.perf_counter()
        _, self.ids = acorn.search_masked(q, p.eval(ds.cat, ds.num), K,
                                          knobs={"ef": 64})
        self.elapsed = time.perf_counter() - t0

for lo, hi in [(0.01, 0.02), (0.08, 0.12), (0.25, 0.35)]:
    qs, preds, sels = gen_queries(
        ds.vectors, ds.cat, ds.num, 12, kinds=("range",), sel_range=(lo, hi), seed=3
    )
    stats = {m: [0.0, 0.0] for m in ("pre", "post", "acorn", "planner")}
    for i, p in enumerate(preds):
        truth = eng.ground_truth(qs[i], p, K)
        for mname, fn in [
            ("pre", lambda: eng.pre_exec.search(qs[i][None], p, K)),
            ("post", lambda: eng.post_exec.search(qs[i][None], p, K)),
            ("acorn", lambda: _AcornRes(qs[i][None], p)),
            ("planner", lambda: eng.query(qs[i], p, K).result),
        ]:
            res = fn()
            stats[mname][0] += recall_at_k(res.ids, truth)
            stats[mname][1] += res.elapsed
    n = len(preds)
    print(f"\nselectivity ~{np.mean(sels):.3f}:")
    for m, (r, t) in stats.items():
        print(f"  {m:8s} recall {r/n:.3f}  {t/n*1e3:7.2f} ms/query")

# ----------------------------------------------------------------------
# DNF predicates: unions of conjunctions, with negated leaves, planned
# per query.  The bitmap attribute index answers these exactly (popcount
# selectivity), so every covered query reports sel_is_exact and low-
# selectivity ones run the indexed pre-filter plan ("ipre").
# ----------------------------------------------------------------------
print("\nDNF predicates through the 3-way planner:")
x0, x1 = ds.num[:, 0], ds.num[:, 1]
q10, q25, q60, q75 = (float(np.quantile(x0, f)) for f in (0.10, 0.25, 0.60, 0.75))
dnf_preds = [
    # two disjoint windows on one attribute OR a label
    Or((
        Predicate(ranges=(RangePred(0, ((q10, q25), (q60, q75))),)),
        Predicate(labels=(LabelEq(0, 2),)),
    )),
    # a label conjunction OR a narrow range with a negated label
    Or((
        Predicate(labels=(LabelEq(0, 0),)),
        Predicate(ranges=(RangePred(1, ((float(np.quantile(x1, 0.45)),
                                         float(np.quantile(x1, 0.55))),)),),
                  nots=(Not(LabelEq(0, 1)),)),
    )),
    # wide union — high selectivity, should go post-filter
    Or((
        Predicate(ranges=(RangePred(0, ((q10, q75),)),)),
        Predicate(labels=(LabelEq(0, 1),)),
    )),
]
dq = np.stack([ds.vectors[i] for i in (1, 2, 3)])
for out, p in zip(eng.batch_query(dq, dnf_preds, k=K), dnf_preds):
    print(f"  plan={out.result.strategy:5s} sel={out.est_selectivity:.4f} "
          f"(exact popcount)  {p}")

# ----------------------------------------------------------------------
# Live-corpus churn: upserts make range statistics stale (sel_is_exact
# demotes — fail closed, never wrong), deletes stay exact via tombstone
# popcounts, and compaction restores full exactness.
# ----------------------------------------------------------------------
print("\nlive-corpus churn (watch sel_is_exact):")
rp = Predicate(ranges=(RangePred(0, ((q10, q25),)),))
se = eng.estimator.estimate(rp)
print(f"  clean corpus:    sel={se.sel:.4f} sel_is_exact={se.is_exact}")

rng = np.random.default_rng(0)
new_rows = rng.choice(len(ds.vectors), 50)
eng.upsert(ds.vectors[new_rows], ds.cat[new_rows], ds.num[new_rows])
se = eng.estimator.estimate(rp)
print(f"  after upsert:    sel={se.sel:.4f} sel_is_exact={se.is_exact} "
      "(range buckets stale -> demoted)")

lp = Predicate(labels=(LabelEq(0, 2),))
eng.delete(np.arange(30))
se = eng.estimator.estimate(lp)
print(f"  label pred:      sel={se.sel:.4f} sel_is_exact={se.is_exact} "
      "(bitmaps extend + tombstones compose: still exact)")

live = eng.stats()["live"]
print(f"  live view: {live['live_count']}/{live['n_total']} rows "
      f"(tombstones {live['tombstone_frac']:.2%}, "
      f"segment {live['segment_frac']:.2%})")

eng.compact()
se = eng.estimator.estimate(rp)
print(f"  after compact:   sel={se.sel:.4f} sel_is_exact={se.is_exact} "
      "(rebuilt: exact again)")

# ----------------------------------------------------------------------
# Multi-tenant fleet serving: two collections with different schemas and
# SLO tiers share one process.  A calm trace shows both tenants meeting
# their SLOs; then the analytics tenant turns noisy (8x bursts) and the
# quiet tenant's hit-rate survives only because fair-share batching +
# token-bucket admission isolate it — the shared-queue baseline collapses.
# ----------------------------------------------------------------------
print("\nmulti-tenant fleet (quiet SLO before/after a noisy burst):")
from repro.fleet import (AdmissionController, AutoscaleConfig,  # noqa: E402
                         CollectionSchema, Fleet, FleetConfig, FleetRuntime)
from repro.runtime import TenantTraceSpec, multi_tenant_trace  # noqa: E402

fleet = Fleet(total_shards=6)
tenant_cfg = {
    # name: (slo tier, baseline shards, admission qps budget)
    "checkout": ("interactive", 2, None),        # un-gated quiet tenant
    "analytics": ("batch", 1, 1800.0),           # budgeted bulk tenant
}
corpora = {}
for ti, (name, (tier, shards, budget)) in enumerate(tenant_cfg.items()):
    tds = make_dataset("arxiv", scale="4000", seed=ti)
    corpora[name] = gen_queries(tds.vectors, tds.cat, tds.num, 16,
                                kinds=tds.filter_kinds, seed=ti + 1)[:2]
    fleet.create(
        CollectionSchema(name=name, dim=tds.vectors.shape[1], slo_tier=tier,
                         n_shards=shards, admit_rate=budget,
                         admit_burst=500.0 if budget else None),
        tds.vectors, tds.cat, tds.num, config=EngineConfig(n_lists=16, seed=0),
    )

def _specs(noisy_rate, noisy_kind):
    return [
        TenantTraceSpec("checkout", *corpora["checkout"], n_requests=150,
                        rate=900.0, tier_mix={"standard": 1.0}),
        TenantTraceSpec("analytics", *corpora["analytics"], n_requests=600,
                        rate=noisy_rate, kind=noisy_kind,
                        tier_mix={"standard": 1.0}, burst_factor=8.0,
                        cycle=0.05),
    ]

calm = multi_tenant_trace(_specs(1200.0, "poisson"), seed=7)
burst = multi_tenant_trace(_specs(20000.0, "bursty"), seed=7)
isolated = FleetRuntime(fleet, FleetConfig(max_batch=32),
                        admission=AdmissionController.for_fleet(fleet),
                        autoscale=AutoscaleConfig(eval_every=0.05,
                                                  min_window=24, cooldown=0.05))
shared = FleetRuntime(fleet, FleetConfig(max_batch=32, fair=False))

r = isolated.run_trace(calm)
print(f"  calm trace:          checkout {r.slo_hit_rate('checkout'):.3f}  "
      f"analytics {r.slo_hit_rate('analytics'):.3f}")
r = shared.run_trace(burst)
print(f"  burst, shared queue: checkout {r.slo_hit_rate('checkout'):.3f}  "
      f"analytics {r.slo_hit_rate('analytics'):.3f}   <- noisy neighbor wins")
r = isolated.run_trace(burst)
print(f"  burst, fleet mode:   checkout {r.slo_hit_rate('checkout'):.3f}  "
      f"analytics {r.slo_hit_rate('analytics'):.3f}   "
      f"({len(r.rejected)} shed, "
      f"{[e.action for e in r.scale_events] or 'no scale events'})")
