"""Filtered search deep-dive: all four execution strategies side by side on
one workload, showing where each wins (the paper's Figure 2 story).

    PYTHONPATH=src python examples/filtered_search.py
"""
import time

import numpy as np

from repro.core import EngineConfig, FilteredANNEngine, recall_at_k
from repro.core.executors import AcornExec
from repro.core.trainer import gen_queries
from repro.data import make_dataset
from repro.index import AcornIndex

K = 10
ds = make_dataset("glove200", scale="20000", seed=0)
eng = FilteredANNEngine(ds.vectors, ds.cat, ds.num, EngineConfig(seed=0)).build()
tq, tp, _ = gen_queries(ds.vectors, ds.cat, ds.num, 40, kinds=("range",), seed=1)
eng.fit(tq, tp, k=K)
print("building ACORN-1 graph baseline...")
t0 = time.perf_counter()
acorn = AcornIndex(ds.vectors, m=24, seed=0).build()
print(f"  acorn build {time.perf_counter()-t0:.1f}s "
      f"(planner build was {eng.build_time_['stats']+eng.build_time_['ivf']+eng.build_time_['fit']:.1f}s)")
acorn_exec = AcornExec(acorn, ds.cat, ds.num, ef=64)

for lo, hi in [(0.01, 0.02), (0.08, 0.12), (0.25, 0.35)]:
    qs, preds, sels = gen_queries(
        ds.vectors, ds.cat, ds.num, 12, kinds=("range",), sel_range=(lo, hi), seed=3
    )
    stats = {m: [0.0, 0.0] for m in ("pre", "post", "acorn", "planner")}
    for i, p in enumerate(preds):
        truth = eng.ground_truth(qs[i], p, K)
        for mname, fn in [
            ("pre", lambda: eng.pre_exec.search(qs[i][None], p, K)),
            ("post", lambda: eng.post_exec.search(qs[i][None], p, K)),
            ("acorn", lambda: acorn_exec.search(qs[i][None], p, K)),
            ("planner", lambda: eng.query(qs[i], p, K).result),
        ]:
            res = fn()
            stats[mname][0] += recall_at_k(res.ids, truth)
            stats[mname][1] += res.elapsed
    n = len(preds)
    print(f"\nselectivity ~{np.mean(sels):.3f}:")
    for m, (r, t) in stats.items():
        print(f"  {m:8s} recall {r/n:.3f}  {t/n*1e3:7.2f} ms/query")
