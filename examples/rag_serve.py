"""End-to-end driver: serve a small LM with batched requests whose outputs
drive *filtered* ANN retrieval planned per-query by the learned planner
(the paper's engine as a first-class serving feature — DESIGN.md §4).

    PYTHONPATH=src python examples/rag_serve.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import EngineConfig, FilteredANNEngine, Predicate, RangePred
from repro.core.trainer import gen_queries
from repro.data import make_dataset
from repro.models import Model
from repro.serve import Request, ServeEngine, RetrievalAugmentedServer

# --- the LM fleet member (reduced gemma2 for the CPU container) ----------
cfg = get_config("gemma2-2b").reduced()
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

# --- the retrieval corpus + learned query planner ------------------------
ds = make_dataset("arxiv", scale="15000", seed=0)
ann = FilteredANNEngine(ds.vectors, ds.cat, ds.num, EngineConfig(seed=0)).build()
tq, tp, _ = gen_queries(ds.vectors, ds.cat, ds.num, 40, kinds=ds.filter_kinds, seed=1)
ann.fit(tq, tp, k=5)

# --- batched generation ---------------------------------------------------
rng = np.random.default_rng(0)
reqs = [
    Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
            max_new_tokens=8)
    for i in range(6)
]
eng = ServeEngine(model, params, batch_slots=3, max_len=64)
t0 = time.time()
results = eng.run(reqs)
print(f"generated {sum(len(v) for v in results.values())} tokens "
      f"for {len(reqs)} requests in {time.time()-t0:.2f}s")

# --- retrieval with a metadata filter, planned per query ------------------
rag = RetrievalAugmentedServer(model, params, ann)
year_lo = float(np.quantile(ds.num[:, 0], 0.6))
pred = Predicate(ranges=(RangePred(0, ((year_lo, float(ds.num[:, 0].max()) + 1),)),))
tokens = np.stack([r.prompt for r in reqs[:3]])
t0 = time.time()
planned = rag.retrieve(tokens, pred, k=5)
for i, out in enumerate(planned):
    print(
        f"req {i}: plan={['PRE', 'POST', 'IPRE'][out.decision]} "
        f"est_sel={out.est_selectivity:.3f} "
        f"retrieved={[int(x) for x in out.result.ids[0][:5]]} "
        f"({out.result.elapsed*1e3:.1f} ms)"
    )
print(f"retrieval wall time {time.time()-t0:.2f}s — every id satisfies the filter:",
      all(bool(pred.eval(ds.cat[out.result.ids[0][out.result.ids[0] >= 0]],
                         ds.num[out.result.ids[0][out.result.ids[0] >= 0]]).all())
          for out in planned))
