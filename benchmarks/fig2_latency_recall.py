"""Paper Figure 2: latency-recall trade-offs across datasets under varying
selectivity.

For each dataset and each average-selectivity bucket, runs the four methods
(pre-filtering reported separately, as in the paper) over a query batch and
reports mean recall@10 + mean end-to-end seconds per query.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import recall_at_k

from .common import DATASETS, K, eval_queries, get_fixture

SEL_BUCKETS = [(0.01, 0.02), (0.04, 0.06), (0.09, 0.12), (0.18, 0.22)]


def _run_method(fn, qs, preds, eng):
    recs, times = [], []
    for i, p in enumerate(preds):
        t0 = time.perf_counter()
        res = fn(qs[i], p)
        dt = time.perf_counter() - t0
        truth = eng.ground_truth(qs[i], p, K)
        recs.append(recall_at_k(res, truth))
        times.append(dt)
    return float(np.mean(recs)), float(np.mean(times))


def run(n_queries=25):
    rows = []
    for name in DATASETS:
        ds, eng, acorn, _ = get_fixture(name, with_acorn=True)

        def _acorn_search(q, p):
            # registry-style masked search: predicate mask evaluated inline,
            # applied DURING the graph traversal (charged to the method, as
            # the paper's ACORN baseline does)
            _, ids = acorn.search(q[None], K, ef=64, mask=p.eval(ds.cat, ds.num))
            return ids
        for lo, hi in SEL_BUCKETS:
            qs, preds, sels = eval_queries(ds, n=n_queries, sel_range=(lo, hi), seed=11)
            mid = float(np.mean(sels))

            r_post, t_post = _run_method(
                lambda q, p: eng.post_exec.search(q[None], p, K).ids, qs, preds, eng
            )
            r_pre, t_pre = _run_method(
                lambda q, p: eng.pre_exec.search(q[None], p, K).ids, qs, preds, eng
            )
            r_ac, t_ac = _run_method(_acorn_search, qs, preds, eng)
            r_lp, t_lp = _run_method(
                lambda q, p: eng.query(q, p, K).result.ids, qs, preds, eng
            )
            rows.append({
                "dataset": name, "avg_selectivity": round(mid, 4),
                "post_recall": round(r_post, 3), "post_s": round(t_post, 5),
                "pre_recall": round(r_pre, 3), "pre_s": round(t_pre, 5),
                "acorn_recall": round(r_ac, 3), "acorn_s": round(t_ac, 5),
                "planner_recall": round(r_lp, 3), "planner_s": round(t_lp, 5),
            })
            print(
                f"  {name} sel~{mid:.3f}: post {r_post:.2f}/{t_post*1e3:.1f}ms "
                f"pre {r_pre:.2f}/{t_pre*1e3:.1f}ms acorn {r_ac:.2f}/{t_ac*1e3:.1f}ms "
                f"PLANNER {r_lp:.2f}/{t_lp*1e3:.1f}ms"
            )
    return rows


def main():
    rows = run()
    print("dataset,avg_sel,method,recall,seconds")
    for r in rows:
        for m in ("post", "pre", "acorn", "planner"):
            print(f"{r['dataset']},{r['avg_selectivity']},{m},{r[m+'_recall']},{r[m+'_s']}")


if __name__ == "__main__":
    main()
