"""Microbenchmark: exact psum vs int8 compressed_psum (latency + error).

    PYTHONPATH=src python benchmarks/dist_bench.py [--sizes 1024,65536,...]

Per gradient size it reports, over the local device mesh:

* exact     — ``jax.lax.psum(x)/n`` inside shard_map (fp32 wire bytes)
* int8      — ``compressed_psum`` (int8 payload + one fp32 scale/shard)
* int8+ef   — ``psum_with_error_feedback``; the error column is the bias
  of the ACCUMULATED mean after 8 repeated reductions, which is what the
  optimizer sees — error feedback pushes it ~an order of magnitude below
  plain int8's one-shot error.

Latency on this CPU container measures dispatch + kernel cost only (a
single host has no real interconnect); the wire-bytes column is the
analytic 4x story.  Merge exactness for the sharded ANN path is covered
by ``tests/test_dist_serve.py``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import compressed_psum, psum_with_error_feedback


def _mesh1d():
    n = len(jax.devices())
    return jax.make_mesh((n,), ("d",)), n


def _timeit(fn, *args, reps=20):
    fn(*args)                                       # warm the jit cache
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def bench_size(size: int, reps: int = 20, rounds: int = 8):
    mesh, n = _mesh1d()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (n, size)).astype(np.float32))
    exact_mean = np.asarray(x).mean(0)

    f_exact = jax.jit(jax.shard_map(
        lambda v: jax.lax.psum(v[0], "d") / n,
        mesh=mesh, in_specs=P("d"), out_specs=P(),
    ))
    f_int8 = jax.jit(jax.shard_map(
        lambda v: compressed_psum(v[0], "d"),
        mesh=mesh, in_specs=P("d"), out_specs=P(),
    ))
    f_ef = jax.jit(jax.shard_map(
        lambda v, e: psum_with_error_feedback(v[0], e[0], "d"),
        mesh=mesh, in_specs=(P("d"), P("d")), out_specs=(P(), P("d")),
    ))

    zero_err = jnp.zeros((n, size), jnp.float32)
    # all three columns use the same methodology: queued dispatch, one
    # block_until_ready at the end (host transfers would otherwise dominate
    # and make error feedback look falsely expensive)
    t_exact = _timeit(f_exact, x, reps=reps)
    t_int8 = _timeit(f_int8, x, reps=reps)
    t_ef = _timeit(f_ef, x, zero_err, reps=reps)
    err_int8 = float(np.abs(np.asarray(f_int8(x)) - exact_mean).max())

    # accumulated-bias measurement (untimed): residual carried across rounds
    err = zero_err
    acc = np.zeros(size)
    for _ in range(rounds):
        out, err = f_ef(x, err)
        acc += np.asarray(out)
    err_ef = float(np.abs(acc / rounds - exact_mean).max())

    fp32_bytes, int8_bytes = 4 * size, size + 4
    return {
        "size": size,
        "t_exact_us": t_exact * 1e6,
        "t_int8_us": t_int8 * 1e6,
        "t_ef_us": t_ef * 1e6,
        "err_int8": err_int8,
        "err_ef_acc": err_ef,
        "wire_ratio": fp32_bytes / int8_bytes,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="1024,16384,262144,1048576")
    ap.add_argument("--reps", type=int, default=20)
    args = ap.parse_args(argv)

    print(f"devices: {len(jax.devices())}  ({jax.devices()[0].platform})")
    hdr = (f"{'size':>9} {'exact us':>9} {'int8 us':>9} {'int8+ef us':>10} "
           f"{'err int8':>10} {'err ef(acc8)':>12} {'wire x':>7}")
    print(hdr)
    rows = []
    for s in (int(x) for x in args.sizes.split(",")):
        r = bench_size(s, reps=args.reps)
        rows.append(r)
        print(f"{r['size']:>9} {r['t_exact_us']:>9.1f} {r['t_int8_us']:>9.1f} "
              f"{r['t_ef_us']:>10.1f} {r['err_int8']:>10.2e} "
              f"{r['err_ef_acc']:>12.2e} {r['wire_ratio']:>7.2f}")
    return rows


if __name__ == "__main__":
    main()
