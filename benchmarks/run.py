"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = mean end-to-end
query latency where applicable; derived = the headline derived metric).

    PYTHONPATH=src python -m benchmarks.run              # full suite
    REPRO_BENCH_SCALE=small python -m benchmarks.run     # (default)
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    t_start = time.time()
    print("name,us_per_call,derived")

    from . import table1_datasets
    for r in table1_datasets.run():
        print(f"table1_{r['dataset']},0,dim={r['dim']};n={r['bench_size']}")

    from . import table2_construction
    for r in table2_construction.run():
        print(
            f"table2_{r['dataset']},{int(r['learned_planner_s']*1e6)},"
            f"speedup_vs_acorn={r['speedup']}x"
        )

    from . import fig2_latency_recall
    for r in fig2_latency_recall.run():
        print(
            f"fig2_{r['dataset']}_sel{r['avg_selectivity']},"
            f"{int(r['planner_s']*1e6)},"
            f"planner_recall={r['planner_recall']};post_recall={r['post_recall']};"
            f"acorn_recall={r['acorn_recall']};acorn_us={int(r['acorn_s']*1e6)}"
        )

    from . import selectivity_accuracy
    for r in selectivity_accuracy.run():
        print(f"selectivity_{r['dataset']}_{r['kind']},0,mae={r['mae']}")

    from . import planner_accuracy
    for r in planner_accuracy.run():
        print(
            f"planner_{r['dataset']},0,auc={r['auc']};acc={r['accuracy']};"
            f"util_vs_oracle={r['utility_vs_oracle']}"
        )

    from . import ablation_gbm
    for r in ablation_gbm.run():
        print(
            f"ablation_gbm_{r['dataset']},0,"
            f"mae_gbm={r['mae_with_gbm']};mae_indep={r['mae_independence']}"
        )

    from . import kernel_bench
    for r in kernel_bench.run():
        print(f"kernel_{r['kernel']},{r['vmem_bytes']},fits={r['fits_16MiB']}")

    from . import filter_bench
    for r in filter_bench.run():
        print(
            f"filter_{r['tier']},{r['cached_us']},"
            f"speedup_cached={r['speedup_cached']}x;speedup_cold={r['speedup_cold']}x"
        )

    from . import backend_bench
    for r in backend_bench.run():
        print(f"backend_{r['config']},{r['mean_us']},recall={r['recall']}")

    from . import runtime_bench
    for r in runtime_bench.run():
        print(
            f"runtime_{r['name']},{r['p99_us']},"
            f"speedup={r['speedup']};deadline_hit={r['deadline_hit_rate']}"
        )

    from . import mutation_bench
    for r in mutation_bench.run():
        print(
            f"mutation_{r['name']},{r['mean_us']},"
            f"latency_ratio={r['ratio']};recall={r['recall']}"
        )

    from . import fleet_bench
    for r in fleet_bench.run():
        print(
            f"fleet_{r['name']},{r['wall_qps']},"
            f"quiet_slo={r['quiet_slo']};rejected={r['rejected']}"
        )

    print(f"# total bench wall time {time.time()-t_start:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
