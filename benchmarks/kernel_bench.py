"""Kernel micro-benchmarks: fused masked-L2 vs. reference (CPU interpret mode
measures correctness-path speed only; the BlockSpec structure targets TPU).

Also reports the analytic VMEM working set per tile so the kernel's fit can
be checked against the 16 MiB v5e VMEM budget without hardware.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import vmem_working_set
from repro.index.flat import l2_topk


def bench_xla_scan(n=65536, d=128, b=64, k=10, iters=3):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32))
    mask = jnp.asarray(rng.random(n) < 0.5)
    l2_topk(q, x, k, mask)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        l2_topk(q, x, k, mask)[0].block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    return dt


def run():
    rows = []
    for d in (128, 256, 512):
        ws = vmem_working_set(d)
        rows.append({
            "kernel": f"masked_l2_d{d}",
            "vmem_bytes": ws["total"],
            "fits_16MiB": ws["fits_16MiB"],
        })
    dt = bench_xla_scan()
    rows.append({"kernel": "masked_l2_xla_base_us", "vmem_bytes": round(dt * 1e6, 1),
                 "fits_16MiB": True})
    return rows


def main():
    print("kernel,vmem_bytes_or_us,fits_16MiB")
    for r in run():
        print(f"{r['kernel']},{r['vmem_bytes']},{r['fits_16MiB']}")


if __name__ == "__main__":
    main()
