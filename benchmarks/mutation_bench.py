"""Live-corpus mutation benchmark: churn cost, staleness, compaction.

Three measurements, written to ``BENCH_mutation.json`` at the repo root:

1. **Steady-state serving under churn** — starting from a clean build, the
   corpus is mutated to tombstone fractions of 2/5/10% (plus a ~2% append
   segment) and the batched query workload is re-timed at each level
   against the build-once baseline.  Acceptance target: latency ratio
   <= 1.3x the clean engine at <= 10% tombstones, with exact result
   equality against the live ground truth for exact plans.
2. **Write throughput** — rows/s through ``upsert`` and ``delete``
   (measured over the same churn burst) and the compaction wall time.
3. **Compaction equivalence** — post-compaction ground truth must equal
   the pre-compaction live ground truth translated through ``id_map``
   (the tentpole bit-equality invariant), and the served recall against
   live truth is reported before/after.

    PYTHONPATH=src python -m benchmarks.mutation_bench           # 100k fixture
    REPRO_BENCH_SCALE=5000 PYTHONPATH=src python -m benchmarks.mutation_bench
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

REPO_ROOT = Path(__file__).resolve().parent.parent
DATASET = "arxiv"
K = 10
TOMBSTONE_FRACS = (0.02, 0.05, 0.10)
SEG_FRAC = 0.02
LATENCY_RATIO_TARGET = 1.3


def _time_workload(eng, qs, preds, repeats=3):
    """Mean per-query latency of the batched path (best of ``repeats``)."""
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = eng.batch_query(qs, preds, k=K)
        best = min(best, time.perf_counter() - t0)
    return best / len(preds), res


def _live_recall(eng, qs, preds, res):
    """Recall of served ids against the engine's own live ground truth."""
    got = 0.0
    for q, p, pr in zip(qs, preds, res):
        truth = eng.ground_truth(q, p, k=K)[0]
        ts = set(int(t) for t in truth if t >= 0)
        if not ts:
            continue
        ids = pr.result.ids[0]
        got += len(ts & set(int(v) for v in ids if v >= 0)) / len(ts)
    return got / len(preds)


def main():
    from repro.core import EngineConfig, FilteredANNEngine

    from .common import corpus_n, eval_queries, get_fixture

    print(f"mutation_bench: {DATASET} n={corpus_n()}")
    ds, clean_eng, _, timings = get_fixture(DATASET)
    n = int(ds.vectors.shape[0])
    qs, preds, _ = eval_queries(ds, n=32, sel_range=(0.02, 0.3), seed=9)
    preds = list(preds)

    base_lat, base_res = _time_workload(clean_eng, qs, preds)
    base_recall = _live_recall(clean_eng, qs, preds, base_res)
    print(f"  clean baseline: {base_lat*1e3:.2f} ms/query  "
          f"recall@{K}={base_recall:.3f}")

    # a second engine takes the churn (the fixture engine must stay clean
    # for every other benchmark sharing the cache)
    live_eng = FilteredANNEngine(
        ds.vectors, ds.cat, ds.num,
        EngineConfig(seed=0, max_tombstone_frac=0.5, max_segment_frac=0.5),
    ).build()
    rng = np.random.default_rng(17)
    perm = rng.permutation(n)

    # one append burst up front (~SEG_FRAC of the corpus), timed
    n_seg = max(int(n * SEG_FRAC), 1)
    rows = rng.choice(n, n_seg)
    t0 = time.perf_counter()
    live_eng.upsert(ds.vectors[rows], ds.cat[rows], ds.num[rows])
    t_upsert = time.perf_counter() - t0

    out = {"n": n, "dataset": DATASET, "k": K,
           "base_latency_ms": round(base_lat * 1e3, 4),
           "base_recall": round(base_recall, 4),
           "levels": []}
    deleted = 0
    t_delete = 0.0
    all_ok = True
    for frac in TOMBSTONE_FRACS:
        target = int(frac * live_eng.live.n_total)
        kill = perm[deleted:target]
        t0 = time.perf_counter()
        live_eng.delete(kill)
        t_delete += time.perf_counter() - t0
        deleted = target
        lat, res = _time_workload(live_eng, qs, preds)
        rec = _live_recall(live_eng, qs, preds, res)
        ratio = lat / base_lat
        ok = ratio <= LATENCY_RATIO_TARGET
        all_ok = all_ok and ok
        row = {
            "tombstone_frac": frac,
            "segment_frac": round(live_eng.live.segment_frac, 4),
            "latency_ms": round(lat * 1e3, 4),
            "latency_ratio": round(ratio, 3),
            "recall": round(rec, 4),
            "ok": bool(ok),
        }
        out["levels"].append(row)
        print(f"  tombstones {frac:.0%}: {lat*1e3:.2f} ms/query "
              f"(ratio {ratio:.2f}x, recall {rec:.3f}) "
              f"{'PASS' if ok else 'FAIL'}")

    out["write_throughput"] = {
        "upsert_rows_per_s": round(n_seg / max(t_upsert, 1e-9), 1),
        "delete_rows_per_s": round(deleted / max(t_delete, 1e-9), 1),
    }
    print(f"  writes: {out['write_throughput']['upsert_rows_per_s']:.0f} "
          f"upserts/s  {out['write_throughput']['delete_rows_per_s']:.0f} "
          f"deletes/s")

    # compaction equivalence: live truth translates bit-exactly via id_map
    gt_live = np.stack([live_eng.ground_truth(q, p, k=K)[0]
                        for q, p in zip(qs, preds)])
    t0 = time.perf_counter()
    id_map = live_eng.compact()
    t_compact = time.perf_counter() - t0
    gt_post = np.stack([live_eng.ground_truth(q, p, k=K)[0]
                        for q, p in zip(qs, preds)])
    tr = np.where(gt_live >= 0, id_map[np.maximum(gt_live, 0)], -1)
    bit_equal = bool((tr == gt_post).all())
    lat_post, res_post = _time_workload(live_eng, qs, preds)
    out["compaction"] = {
        "seconds": round(t_compact, 3),
        "bit_equal_ground_truth": bit_equal,
        "post_latency_ratio": round(lat_post / base_lat, 3),
        "post_recall": round(_live_recall(live_eng, qs, preds, res_post), 4),
    }
    print(f"  compaction: {t_compact:.2f}s  ground-truth bit-equal via "
          f"id_map: {'PASS' if bit_equal else 'FAIL'}")
    out["steady_state_ok"] = bool(all_ok)
    print(f"steady-state latency <= {LATENCY_RATIO_TARGET}x at <=10% "
          f"tombstones: {'PASS' if all_ok else 'FAIL'}")

    # headline scale owns BENCH_mutation.json; other scales write a
    # scale-suffixed (gitignored) file so they can't clobber the committed
    # 100k record
    name = "BENCH_mutation.json" if n == 100_000 else f"BENCH_mutation_n{n}.json"
    path = REPO_ROOT / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {path}")
    return out


def run():
    """`benchmarks/run.py` adaptor: one row per churn level."""
    out = main()
    rows = [
        {
            "name": f"tombstones_{int(r['tombstone_frac']*100)}pct",
            "mean_us": int(r["latency_ms"] * 1e3),
            "ratio": r["latency_ratio"],
            "recall": r["recall"],
        }
        for r in out["levels"]
    ]
    rows.append({
        "name": "compaction",
        "mean_us": int(out["compaction"]["seconds"] * 1e6),
        "ratio": out["compaction"]["post_latency_ratio"],
        "recall": out["compaction"]["post_recall"],
    })
    return rows


if __name__ == "__main__":
    os.environ.setdefault("REPRO_BENCH_SCALE", "reduced")   # 100k fixture
    main()
