"""Predicate-evaluation benchmark: columnar scan-mask vs compiled bitmaps.

Measures, per selectivity tier and predicate shape:

* ``scan``   — naive columnar evaluation (``pred.eval``), what the plain
  pre-filter pays on every query;
* ``cold``   — first-touch bitmap compile + mask expansion through an empty
  cache (what a never-seen predicate pays on the indexed path);
* ``cached`` — the LRU-hit path (compiled bitmap + cached mask expansion),
  what repeated serving predicates pay.

Also replays a Zipf-repeating serving trace through the predicate cache to
report realistic hit rates, and writes everything to ``BENCH_filter.json``
at the repo root so the perf trajectory is recorded in-tree.

    PYTHONPATH=src python benchmarks/filter_bench.py          # N = 100k
    REPRO_FILTER_BENCH_N=30000 PYTHONPATH=src python benchmarks/filter_bench.py
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

from repro.core.trainer import gen_queries
from repro.data import make_dataset
from repro.filter import AttributeIndex, PredicateCache

REPO_ROOT = Path(__file__).resolve().parent.parent


def _resolve_n() -> int:
    """Corpus size: explicit REPRO_FILTER_BENCH_N wins, else the suite-wide
    REPRO_BENCH_SCALE with the same mapping every other suite uses
    (unset => "small" => 30k, matching `benchmarks/common.py`), so one
    `run.py` invocation benches every suite at one consistent scale.  The
    standalone `__main__` path defaults the env to "reduced" (100k) to
    preserve this script's historical headline scale."""
    env = os.environ.get("REPRO_FILTER_BENCH_N")
    if env:
        return int(env)
    scale = os.environ.get("REPRO_BENCH_SCALE", "small")
    if scale == "small":
        return 30_000
    if scale == "reduced":
        return 100_000
    return int(scale)


TIERS = {"low": (0.005, 0.02), "mid": (0.05, 0.15), "high": (0.25, 0.5)}
N_PREDS = 12          # predicates per tier
REPEATS = 7           # timing repeats (min taken)


def _best(fn, repeats=REPEATS) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_tier(name, preds, cat, num, index):
    scan, cold, cached = [], [], []
    for p in preds:
        scan.append(_best(lambda: p.eval(cat, num)))
        # cold: fresh cache every repeat -> compile + expand each time
        def _cold():
            PredicateCache(capacity=4).mask(p, index)
        cold.append(_best(_cold))
        # cached: warm once, then measure the two-tier hit path (what the
        # indexed executor pays on a repeat predicate)
        warm = PredicateCache(capacity=4)
        warm.mask(p, index)
        cached.append(_best(lambda: warm.mask(p, index)))
    scan_us = float(np.median(scan) * 1e6)
    cold_us = float(np.median(cold) * 1e6)
    cached_us = float(np.median(cached) * 1e6)
    row = {
        "tier": name,
        "n_preds": len(preds),
        "scan_us": round(scan_us, 2),
        "cold_compile_us": round(cold_us, 2),
        "cached_us": round(cached_us, 2),
        "speedup_cold": round(scan_us / max(cold_us, 1e-3), 2),
        "speedup_cached": round(scan_us / max(cached_us, 1e-3), 2),
    }
    print(
        f"  {name:8s} scan {scan_us:9.1f}us  cold {cold_us:9.1f}us "
        f"({row['speedup_cold']:6.2f}x)  cached {cached_us:7.1f}us "
        f"({row['speedup_cached']:6.2f}x)"
    )
    return row


def dnf_planning_section(ds, k=10, n_queries=12):
    """Per-disjunct ``ExecutionPlan`` vs the best whole-predicate plan on a
    DNF workload.

    The workload is engineered into the regime the tentpole targets: every
    clause is an exact single-label conjunction below the planner's
    pre-filter threshold (so each clause plans exact and gathers a small
    survivor subset), while their UNION crosses the pre-filter executor's
    full-scan fraction — the whole-predicate plan is forced to scan the
    entire corpus under the union mask.  The clauses overlap, so the
    cross-clause dedup merge is on the measured path.

    ``bit_identical`` (per-disjunct union == whole-predicate bitmap scan,
    every query) and the exact-tier recall are the gated metrics; the
    latency speedup is the committed headline."""
    from repro.core import (
        EngineConfig, FilteredANNEngine, LabelEq, Or, Predicate,
    )

    cat, num = ds.cat, ds.num
    cand = []
    for col in (0, 1):
        for v in np.unique(cat[:, col]):
            p = Predicate(labels=(LabelEq(col, int(v)),))
            s = p.selectivity(cat, num)
            if 0.01 < s <= 0.049:
                cand.append((s, p))
    cand.sort(key=lambda t: -t[0])
    chosen, union_sel = [], 0.0
    for _, p in cand:
        chosen.append(p)
        union_sel = Or(tuple(chosen)).selectivity(cat, num)
        if union_sel > 0.30:
            break
    dnf = Or(tuple(chosen))

    t0 = time.perf_counter()
    eng = FilteredANNEngine(ds.vectors, cat, num, EngineConfig(seed=0)).build()
    t_build = time.perf_counter() - t0
    plan, _ = eng.make_plan(dnf, k)
    assert plan.is_dnf and plan.n_clauses == len(chosen)
    exact_clauses = all(cl.decision in (0, 2) for cl in plan.clauses)

    rng = np.random.default_rng(3)
    queries = ds.vectors[rng.integers(ds.vectors.shape[0], size=n_queries)]
    eng.query(queries[0], dnf, k)                       # warm plan + bitmap
    eng.pre_exec.search(queries[0][None], dnf, k)
    t_dnf, t_pre, t_post, bit_identical, post_rec = [], [], [], True, []
    for q in queries:
        out = None
        def _dnf():
            nonlocal out
            out = eng.query(q, dnf, k)
        t_dnf.append(_best(_dnf, repeats=5))
        ref = None
        def _pre():
            nonlocal ref
            ref = eng.pre_exec.search(q[None], dnf, k)
        t_pre.append(_best(_pre, repeats=5))
        t_post.append(_best(
            lambda: eng.post_exec.search(q[None], dnf, k,
                                         est_selectivity=union_sel),
            repeats=5))
        bit_identical &= bool(np.array_equal(out.result.ids, ref.ids)
                              and np.array_equal(out.result.dists, ref.dists))
        post = eng.post_exec.search(q[None], dnf, k, est_selectivity=union_sel)
        truth = set(ref.ids[0][ref.ids[0] >= 0].tolist())
        got = set(post.ids[0][post.ids[0] >= 0].tolist())
        post_rec.append(len(truth & got) / max(len(truth), 1))

    dnf_us = float(np.median(t_dnf) * 1e6)
    pre_us = float(np.median(t_pre) * 1e6)
    post_us = float(np.median(t_post) * 1e6)
    row = {
        "n_clauses": len(chosen),
        "union_sel": round(float(union_sel), 4),
        "exact_clauses": bool(exact_clauses),
        "engine_build_s": round(t_build, 2),
        "dnf_us": round(dnf_us, 2),
        "whole_pre_us": round(pre_us, 2),
        "whole_post_us": round(post_us, 2),
        "whole_post_recall": round(float(np.mean(post_rec)), 4),
        "dnf_recall": 1.0 if bit_identical else 0.0,
        "speedup_vs_whole_pre": round(pre_us / max(dnf_us, 1e-3), 2),
        "bit_identical": bool(bit_identical),
    }
    print(
        f"  dnf_planning: {row['n_clauses']} clauses union={row['union_sel']} "
        f"per-disjunct {dnf_us:.0f}us vs whole-pre {pre_us:.0f}us "
        f"({row['speedup_vs_whole_pre']:.2f}x, bit_identical="
        f"{row['bit_identical']}) | whole-post {post_us:.0f}us "
        f"recall={row['whole_post_recall']:.3f}"
    )
    return row


def cache_trace(preds, index, n_requests=2000, capacity=64, seed=0):
    """Zipf-repeating serving trace: a few hot predicates dominate."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, len(preds) + 1, dtype=np.float64)
    prob = (1.0 / ranks**1.2)
    prob /= prob.sum()
    cache = PredicateCache(capacity=capacity)
    t0 = time.perf_counter()
    for i in rng.choice(len(preds), size=n_requests, p=prob):
        cache.mask(preds[i], index)
    elapsed = time.perf_counter() - t0
    s = cache.stats()
    s["requests"] = n_requests
    s["hit_rate"] = round(s["hits"] / n_requests, 4)
    s["us_per_request"] = round(elapsed / n_requests * 1e6, 2)
    print(f"  trace: {n_requests} reqs, hit rate {s['hit_rate']:.3f}, "
          f"{s['us_per_request']:.1f}us/req")
    return s


def main():
    n = _resolve_n()
    print(f"filter_bench: N={n} (arxiv-shaped metadata: 3 cat + 2 num attrs)")
    ds = make_dataset("arxiv", scale=str(n), seed=0)
    cat, num = ds.cat, ds.num

    t0 = time.perf_counter()
    index = AttributeIndex.build(cat, num)
    t_build = time.perf_counter() - t0
    print(f"  attribute index build: {t_build*1e3:.1f} ms")

    out = {"n": n, "dataset": "arxiv", "index_build_ms": round(t_build * 1e3, 2),
           "tiers": {}}

    # conjunctive tiers (the paper's predicate class — and the acceptance
    # criterion's "cached conjunctive predicates")
    for ti, (tier, sel_range) in enumerate(TIERS.items()):
        _, preds, _ = gen_queries(
            ds.vectors, cat, num, N_PREDS, kinds=("label", "mixed", "range"),
            sel_range=sel_range, seed=100 + ti,   # fixed: runs must be comparable
        )
        out["tiers"][tier] = bench_tier(tier, preds, cat, num, index)

    # DNF tier: unions of conjunctions (the new IR shape)
    from repro.core import Or
    _, t1, _ = gen_queries(ds.vectors, cat, num, N_PREDS, kinds=("label", "mixed"),
                           sel_range=(0.01, 0.1), seed=77)
    _, t2, _ = gen_queries(ds.vectors, cat, num, N_PREDS, kinds=("range", "mixed"),
                           sel_range=(0.01, 0.1), seed=78)
    dnf = [Or((a, b)) for a, b in zip(t1, t2)]
    out["tiers"]["dnf"] = bench_tier("dnf", dnf, cat, num, index)

    # per-disjunct execution planning vs the best whole-predicate plan
    out["dnf_planning"] = dnf_planning_section(ds)

    # serving-trace cache behaviour
    all_preds = []
    for tier, sel_range in TIERS.items():
        _, ps, _ = gen_queries(ds.vectors, cat, num, 40, kinds=("label", "mixed", "range"),
                               sel_range=sel_range, seed=91)
        all_preds += list(ps)
    out["cache_trace"] = cache_trace(all_preds, index)

    conj = [out["tiers"][t]["speedup_cached"] for t in TIERS]
    out["cached_conjunctive_speedup_min"] = min(conj)
    print(f"  min cached conjunctive speedup across tiers: {min(conj):.1f}x "
          f"(acceptance floor: 5x)")

    # the committed BENCH_filter.json records the 100k headline run; other
    # scales write a scale-suffixed (gitignored) file so a small-scale
    # `benchmarks/run.py` sweep can't clobber the recorded perf trajectory
    name = "BENCH_filter.json" if n == 100_000 else f"BENCH_filter_n{n}.json"
    path = REPO_ROOT / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"  wrote {path}")
    return out


def run():
    """`benchmarks/run.py` adaptor: one CSV-able row per tier."""
    out = main()
    return [
        {
            "tier": tier,
            "cached_us": row["cached_us"],
            "speedup_cached": row["speedup_cached"],
            "speedup_cold": row["speedup_cold"],
        }
        for tier, row in out["tiers"].items()
    ]


if __name__ == "__main__":
    os.environ.setdefault("REPRO_BENCH_SCALE", "reduced")   # 100k standalone
    main()
