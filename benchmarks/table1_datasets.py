"""Paper Table 1: dataset statistics (stand-in generators at bench scale)."""
from __future__ import annotations

from repro.data import DATASETS as PAPER_SIZES

from .common import DATASETS, corpus_n, get_fixture


def run():
    rows = []
    for name in DATASETS:
        ds, _, _, _ = get_fixture(name)
        paper_n, paper_d, kinds = PAPER_SIZES[name]
        rows.append({
            "dataset": name,
            "bench_size": ds.n,
            "paper_size": paper_n,
            "dim": ds.dim,
            "filter_kinds": "+".join(ds.filter_kinds),
            "cat_attrs": ds.cat.shape[1],
            "num_attrs": ds.num.shape[1],
        })
    return rows


def main():
    print("dataset,bench_size,paper_size,dim,filters,cat_attrs,num_attrs")
    for r in run():
        print(f"{r['dataset']},{r['bench_size']},{r['paper_size']},{r['dim']},"
              f"{r['filter_kinds']},{r['cat_attrs']},{r['num_attrs']}")


if __name__ == "__main__":
    main()
