"""Paper Table 2: construction time — ACORN-1 vs Learned Planner.

Learned-planner construction = dataset statistics + global IVF index +
training-data prep + model fits (exactly what the paper counts); ACORN-1 =
graph build.  Reports the speedup column like the paper.
"""
from __future__ import annotations

from .common import DATASETS, get_fixture


def run():
    rows = []
    for name in DATASETS:
        ds, eng, acorn, t = get_fixture(name, with_acorn=True)
        ours = t["build"] + t["fit"]
        rows.append({
            "dataset": name,
            "acorn_s": round(t["acorn"], 2),
            "learned_planner_s": round(ours, 2),
            "speedup": round(t["acorn"] / max(ours, 1e-9), 2),
        })
    return rows


def main():
    print("dataset,acorn_s,learned_planner_s,speedup")
    for r in run():
        print(f"{r['dataset']},{r['acorn_s']},{r['learned_planner_s']},{r['speedup']}")


if __name__ == "__main__":
    main()
