"""Selectivity-estimator accuracy (paper §3.2, implied evaluation).

Mean absolute error of the estimated vs. true selectivity, broken down by
predicate type (single-label / 2-label / multi-label / range / mixed).

The engine's estimator now resolves index-covered predicates EXACTLY
(bitmap popcount), so its MAE is ~0 by construction; the interesting
column is ``mae_model`` — the histogram/GBM path an index-less deployment
(or an uncovered predicate) would see.
"""
from __future__ import annotations

import numpy as np

from repro.core import LabelEq, Predicate, SelectivityEstimator
from repro.core.trainer import gen_queries

from .common import DATASETS, get_fixture, eval_queries


def run():
    rows = []
    for name in ("arxiv", "sift"):        # one mixed-metadata + one range set
        ds, eng, _, _ = get_fixture(name)
        est = eng.estimator                       # exact fast path (index)
        model_only = SelectivityEstimator(eng.dataset_stats)   # no index: model path
        model_only.model = est.model
        kinds = {"range": ("range",), "mixed": ("mixed",), "label": ("label",)}
        for kname, ks in kinds.items():
            if kname != "range" and ds.cat.shape[1] < 2:
                continue
            try:
                qs, preds, sels = gen_queries(
                    ds.vectors, ds.cat, ds.num, 30, kinds=ks, seed=23
                )
            except Exception:
                continue
            errs = [abs(est.estimate(p).sel - s) for p, s in zip(preds, sels)]
            errs_m = [abs(model_only.estimate(p).sel - s) for p, s in zip(preds, sels)]
            rows.append({
                "dataset": name, "kind": kname,
                "mae": round(float(np.mean(errs)), 4),
                "p90_err": round(float(np.quantile(errs, 0.9)), 4),
                "mae_model": round(float(np.mean(errs_m)), 4),
            })
    return rows


def main():
    print("dataset,kind,mae,p90_err,mae_model")
    for r in run():
        print(f"{r['dataset']},{r['kind']},{r['mae']},{r['p90_err']},{r['mae_model']}")


if __name__ == "__main__":
    main()
