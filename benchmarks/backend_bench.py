"""Backend-registry benchmark: static (backend, knob) configs vs the learned
(plan, backend, knob) router.

For every registered backend class the static baseline answers EVERY query
the same way (predicate mask + ``search_masked`` at that tier — what a
deployment pinned to one index does), while the routed engine plans per
query over the full decision space (pre / indexed-pre / post x backend x
knob).  Reports, per config: mean end-to-end latency, recall@10 against the
exact masked oracle, and the scan-resident memory footprint.

Headline claims recorded in ``BENCH_backend.json`` (committed at the 100k
scale, scale-suffixed + gitignored otherwise):

* the routed planner beats the best static single-backend config on mean
  latency among configs meeting the recall floor;
* IVF-PQ holds >= 4x less scan-resident memory than flat at >= 0.9
  recall@10 on the 100k corpus.

    PYTHONPATH=src python -m benchmarks.backend_bench            # N = 100k
    REPRO_BENCH_SCALE=5000 PYTHONPATH=src python -m benchmarks.backend_bench
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

from repro.core import EngineConfig, FilteredANNEngine, recall_at_k
from repro.core.trainer import gen_queries
from repro.data import make_dataset
from repro.index import DEFAULT_BACKENDS

REPO_ROOT = Path(__file__).resolve().parent.parent

K = 10
N_EVAL = 40            # evaluation queries
RECALL_FLOOR = 0.90    # the equal-recall bar for the latency comparison
REPEATS = 3            # timing repeats per config (min taken)


def _resolve_n() -> int:
    scale = os.environ.get("REPRO_BENCH_SCALE", "small")
    if scale == "small":
        return 30_000
    if scale == "reduced":
        return 100_000
    return int(scale)


def _recall(ids, truth):
    return float(np.mean([recall_at_k(i[None], t) for i, t in zip(ids, truth)]))


def bench_static(eng, backend_set, qs, preds, truth):
    """Every (backend, tier) class as a pinned config: per query, evaluate
    the predicate mask (charged — a pinned deployment pays it too) and run
    the masked search at that tier."""
    rows = []
    classes = backend_set.classes()
    for ci, (bname, tier) in enumerate(classes):
        best_t = float("inf")
        ids_all = None
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            ids_run = []
            for q, p in zip(qs, preds):
                mask = eng.ipre_exec.candidate_mask(p)
                _, ids = backend_set.search_class(ci, q[None], mask, K)
                ids_run.append(ids[0])
            best_t = min(best_t, time.perf_counter() - t0)
            ids_all = ids_run
        rec = _recall(ids_all, truth)
        rows.append({
            "config": f"{bname}:{tier}",
            "mean_us": round(best_t / len(qs) * 1e6, 1),
            "recall": round(rec, 4),
        })
        print(f"  static {bname}:{tier:9s} {rows[-1]['mean_us']:9.1f} us/q  "
              f"recall {rec:.3f}")
    return rows


def bench_routed(eng, qs, preds, truth):
    best_t = float("inf")
    ids_all = None
    for _ in range(REPEATS):
        eng.plan_cache.clear()
        t0 = time.perf_counter()
        outs = eng.batch_query(np.stack(qs), list(preds), k=K)
        best_t = min(best_t, time.perf_counter() - t0)
        ids_all = [o.result.ids[0] for o in outs]
    rec = _recall(ids_all, truth)
    mix = {}
    for o in outs:
        key = f"{o.result.strategy}/{o.result.backend}:{o.result.knob}"
        mix[key] = mix.get(key, 0) + 1
    row = {
        "config": "routed",
        "mean_us": round(best_t / len(qs) * 1e6, 1),
        "recall": round(rec, 4),
        "mix": dict(sorted(mix.items())),
    }
    print(f"  ROUTED {'':10s} {row['mean_us']:9.1f} us/q  recall {rec:.3f}  "
          f"mix={row['mix']}")
    return row


def main():
    n = _resolve_n()
    print(f"backend_bench: N={n} (arxiv), K={K}, {N_EVAL} eval queries")
    ds = make_dataset("arxiv", scale=str(n), seed=0)

    t0 = time.perf_counter()
    eng = FilteredANNEngine(
        ds.vectors, ds.cat, ds.num,
        EngineConfig(seed=0, backends=DEFAULT_BACKENDS),
    ).build()
    t_build = time.perf_counter() - t0
    tq, tp, _ = gen_queries(ds.vectors, ds.cat, ds.num, 48,
                            kinds=ds.filter_kinds, seed=1)
    t0 = time.perf_counter()
    eng.fit(tq, tp, k=K)
    t_fit = time.perf_counter() - t0
    print(f"  build {t_build:.1f}s (backends incl.)  fit+route {t_fit:.1f}s")

    qs, preds, sels = gen_queries(
        ds.vectors, ds.cat, ds.num, N_EVAL, kinds=ds.filter_kinds,
        sel_range=(0.01, 0.4), seed=7,
    )
    truth = [eng.ground_truth(q, p, K) for q, p in zip(qs, preds)]

    mem = eng.backend_set.memory_bytes()
    print("  memory_bytes:", {k: f"{v/1e6:.1f}MB" for k, v in mem.items()})

    static_rows = bench_static(eng, eng.backend_set, qs, preds, truth)
    routed_row = bench_routed(eng, qs, preds, truth)

    # headline 1: routed vs the best static config at EQUAL recall — a
    # pinned config only competes if it reaches the recall the routed
    # planner actually delivered.  The 0.01 tolerance absorbs run-to-run
    # recall jitter from XLA CPU's multi-threaded reduction order (near-tie
    # top-k membership shifts a row or two per run).
    bar = max(RECALL_FLOOR, routed_row["recall"] - 0.01)
    eligible = [r for r in static_rows if r["recall"] >= bar]
    best_static = min(eligible, key=lambda r: r["mean_us"]) if eligible else None
    speedup = (best_static["mean_us"] / routed_row["mean_us"]) if best_static else None
    # headline 2: IVF-PQ memory reduction vs flat at >= 0.9 recall@10.
    # memory_bytes is knob-independent, so the recall side follows the
    # standard ANN memory/recall protocol: measured UNFILTERED (mask=None)
    # at the cheapest search-time operating point that clears 0.9
    # recall@10.  The filtered static rows above show the same index
    # under predicate masks at its declared tiers.
    pq = eng.backend_set.backends["ivfpq"]
    from repro.index import l2_topk
    _, truth_unf = l2_topk(np.stack(qs), ds.vectors, K)
    truth_unf = list(np.asarray(truth_unf)[:, None, :])
    pq_unf = None
    for knobs in ({"nprobe": 64, "rerank": 256}, {"nprobe": 96, "rerank": 512},
                  {"nprobe": 128, "rerank": 1024}, {"nprobe": 256, "rerank": 2048}):
        t0 = time.perf_counter()
        _, pq_ids = pq.search_masked(np.stack(qs), None, K, knobs=knobs)
        dt = time.perf_counter() - t0
        pq_unf = {"knobs": knobs,
                  "recall": round(_recall(list(pq_ids), truth_unf), 4),
                  "mean_us": round(dt / len(qs) * 1e6, 1)}
        if pq_unf["recall"] >= 0.9:
            break
    pq_rec = max(r["recall"] for r in static_rows if r["config"].startswith("ivfpq"))
    mem_reduction = mem["flat"] / max(mem["ivfpq"], 1)

    out = {
        "n": n, "dataset": "arxiv", "k": K, "n_eval": N_EVAL,
        "recall_floor": RECALL_FLOOR,
        "memory_bytes": mem,
        "static": static_rows,
        "routed": routed_row,
        "equal_recall_bar": round(bar, 4),
        "best_static_at_equal_recall": best_static,
        "routed_speedup_vs_best_static": round(speedup, 3) if speedup else None,
        "ivfpq_mem_reduction_vs_flat": round(mem_reduction, 2),
        "ivfpq_best_filtered_recall": round(pq_rec, 4),
        "ivfpq_unfiltered": pq_unf,
    }
    if best_static:
        print(f"  best static at recall>={bar:.3f}: {best_static['config']} "
              f"{best_static['mean_us']:.1f} us/q -> routed speedup {speedup:.2f}x")
    print(f"  ivfpq memory reduction vs flat: {mem_reduction:.1f}x "
          f"(unfiltered recall {pq_unf['recall']:.3f} at {pq_unf['knobs']}, "
          f"best filtered {pq_rec:.3f})")

    name = "BENCH_backend.json" if n == 100_000 else f"BENCH_backend_n{n}.json"
    path = REPO_ROOT / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"  wrote {path}")
    return out


def run():
    """`benchmarks/run.py` adaptor: one row per config plus the headline."""
    out = main()
    rows = [
        {"config": r["config"], "mean_us": r["mean_us"], "recall": r["recall"]}
        for r in out["static"]
    ]
    rows.append({
        "config": "routed", "mean_us": out["routed"]["mean_us"],
        "recall": out["routed"]["recall"],
    })
    return rows


if __name__ == "__main__":
    os.environ.setdefault("REPRO_BENCH_SCALE", "reduced")   # 100k standalone
    main()
