"""Batched vs per-query serving throughput (the tentpole measurement).

For B in {1, 8, 64, 256}: run the same planned workload through the
per-query loop (``query()`` B times) and the batched pipeline
(``batch_query`` once), on both the flat and the sharded engine.  Reports
wall time and QPS per batch size and verifies the batched path returns
IDENTICAL ids and decisions to the per-query loop — the batched pipeline is
an execution-grouping optimisation, not an approximation.

The workload draws query vectors freely but cycles predicates from a pool
of ``N_PREDS`` distinct filters — the predicate-reuse regime production
batches exhibit (many users, few popular filters) and the one the batched
pre-filter group's mask/kernel sharing is designed for.  Per-query results
are workload-independent, so this only affects how much the batched path
gets to share.

Default fixture: 100k vectors (``REPRO_BENCH_SCALE=reduced``); override the
scale with the usual env var.  Acceptance target: batched >= 2x per-query
QPS at B=64.

Run: PYTHONPATH=src python -m benchmarks.batch_bench
"""
from __future__ import annotations

import os
import time

import numpy as np

os.environ.setdefault("REPRO_BENCH_SCALE", "reduced")   # 100k-vector fixture

from repro.serve import ShardedANNEngine

from .common import K, eval_queries, get_fixture

BATCH_SIZES = (1, 8, 64, 256)
DATASET = "sift"
N_PREDS = 16    # distinct predicates in the workload pool


def _check_exact(batched, singles, label):
    for i, (bq, sq) in enumerate(zip(batched, singles)):
        assert bq.decision == sq.decision, f"{label} row {i}: decision forked"
        assert np.array_equal(bq.result.ids, sq.result.ids), (
            f"{label} row {i}: batched ids differ from per-query ids"
        )


def _bench(engine, qs, preds, label):
    rows = []
    for b in BATCH_SIZES:
        reps = max(1, 256 // b)
        q = qs[np.arange(b) % qs.shape[0]]
        p = [preds[i % N_PREDS] for i in range(b)]
        # warm both paths (jit shapes) before timing
        singles = [engine.query(q[i], p[i], K) for i in range(b)]
        batched = engine.batch_query(q, p, K)
        _check_exact(batched, singles, f"{label} B={b}")

        t0 = time.perf_counter()
        for _ in range(reps):
            for i in range(b):
                engine.query(q[i], p[i], K)
        t_loop = (time.perf_counter() - t0) / reps

        t0 = time.perf_counter()
        for _ in range(reps):
            engine.batch_query(q, p, K)
        t_batch = (time.perf_counter() - t0) / reps

        rows.append({
            "engine": label, "B": b,
            "per_query_s": round(t_loop, 5), "batched_s": round(t_batch, 5),
            "per_query_qps": round(b / t_loop, 1),
            "batched_qps": round(b / t_batch, 1),
            "speedup": round(t_loop / t_batch, 2),
        })
    return rows


def run():
    ds, eng, _, timings = get_fixture(DATASET)
    print(f"# fixture: {DATASET} n={ds.vectors.shape[0]} "
          f"build={timings['build']:.1f}s fit={timings['fit']:.1f}s")
    qs, all_preds, _ = eval_queries(ds, n=64, sel_range=(0.01, 0.4), seed=7)
    preds = all_preds[:N_PREDS]
    _, decs, _ = eng.plan_batch(preds, K)
    print(f"# predicate pool: {N_PREDS} distinct "
          f"({int((decs == 0).sum())} pre / {int((decs == 1).sum())} post)")

    rows = _bench(eng, qs, preds, "flat")
    rows += _bench(ShardedANNEngine(eng, n_shards=4), qs, preds, "sharded")

    hdr = list(rows[0])
    print(" | ".join(f"{h:>13}" for h in hdr))
    for r in rows:
        print(" | ".join(f"{str(r[h]):>13}" for h in hdr))

    at64 = next(r for r in rows if r["engine"] == "flat" and r["B"] == 64)
    ok = at64["speedup"] >= 2.0
    print(f"\nB=64 flat speedup: {at64['speedup']}x "
          f"({'PASS' if ok else 'FAIL'}: target >= 2x)")
    return rows


if __name__ == "__main__":
    run()
