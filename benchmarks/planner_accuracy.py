"""Core-planner decision quality (paper §3.3: ROC-AUC objective).

Labels fresh evaluation queries with the oracle strategy (run both, compare
utility U = recall/time) and reports the planner's agreement, ROC-AUC, and
the utility regret of planner vs oracle vs fixed strategies.
"""
from __future__ import annotations

import numpy as np

from repro.core import recall_at_k
from repro.core.planner import roc_auc, PRE_FILTER, POST_FILTER

from .common import DATASETS, K, eval_queries, get_fixture


def run(n_queries=30):
    rows = []
    for name in DATASETS:
        ds, eng, _, _ = get_fixture(name)
        qs, preds, sels = eval_queries(ds, n=n_queries, sel_range=(0.005, 0.3), seed=31)
        y_true, scores, u_planner, u_oracle, u_pre, u_post = [], [], [], [], [], []
        for i, p in enumerate(preds):
            truth = eng.ground_truth(qs[i], p, K)
            r_pre = eng.pre_exec.search(qs[i][None], p, K)
            r_post = eng.post_exec.search(qs[i][None], p, K)
            up = recall_at_k(r_pre.ids, truth) / max(r_pre.elapsed, 1e-7)
            uq = recall_at_k(r_post.ids, truth) / max(r_post.elapsed, 1e-7)
            oracle = PRE_FILTER if up >= uq else POST_FILTER
            res = eng.query(qs[i], p, K)
            u_sel = recall_at_k(res.result.ids, truth) / max(res.result.elapsed, 1e-7)
            y_true.append(oracle)
            se = eng.estimator.estimate(p)
            scores.append(float(eng.planner.predict_proba(
                eng.feat.vector(p, se.sel, K, se.is_exact))[0]))
            u_planner.append(u_sel)
            u_oracle.append(max(up, uq))
            u_pre.append(up)
            u_post.append(uq)
        y_true = np.asarray(y_true)
        decisions = (np.asarray(scores) >= 0.5).astype(int)
        rows.append({
            "dataset": name,
            "auc": round(roc_auc(y_true, np.asarray(scores)), 3),
            "accuracy": round(float((decisions == y_true).mean()), 3),
            "utility_vs_oracle": round(float(np.mean(u_planner) / np.mean(u_oracle)), 3),
            "utility_vs_pre": round(float(np.mean(u_planner) / max(np.mean(u_pre), 1e-9)), 2),
            "utility_vs_post": round(float(np.mean(u_planner) / max(np.mean(u_post), 1e-9)), 2),
        })
    return rows


def main():
    print("dataset,auc,accuracy,utility_vs_oracle,utility_vs_pre,utility_vs_post")
    for r in run():
        print(f"{r['dataset']},{r['auc']},{r['accuracy']},{r['utility_vs_oracle']},"
              f"{r['utility_vs_pre']},{r['utility_vs_post']}")


if __name__ == "__main__":
    main()
