"""Bench regression gate: compare a bench JSON against committed tolerance
bands.

Each CI bench-smoke job writes a scale-suffixed ``BENCH_<name>_n<N>.json``;
this gate then checks the metrics named in ``benchmarks/tolerances.json``
against their bands and fails the job on any violation, so quality
regressions (recall, determinism counters, memory budgets) block the merge
instead of silently drifting in an uploaded artifact nobody reads.

    PYTHONPATH=src python -m benchmarks.check_regression BENCH_backend_n5000.json
    PYTHONPATH=src python -m benchmarks.check_regression --name runtime \
        BENCH_runtime_n5000.json

Tolerance spec (``benchmarks/tolerances.json``)::

    { "<bench>": { "<dotted.path>": {"min": x} | {"max": y} | {"equals": v}
                                    | {"min": x, "max": y} } }

Dotted paths index nested dicts and lists (integer segments index lists).
Wall-clock metrics deliberately get NO bands — CI machines are too noisy —
the gated set is the deterministic/quality ledger: recalls, counters,
memory budgets, probe coverage.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
TOLERANCES = Path(__file__).resolve().parent / "tolerances.json"


def bench_name(path: Path) -> str:
    """``BENCH_backend_n5000.json`` -> ``backend`` (scale suffix dropped)."""
    m = re.fullmatch(r"BENCH_([A-Za-z0-9_]+?)(?:_n\d+)?\.json", path.name)
    if not m:
        raise ValueError(
            f"cannot infer bench name from {path.name!r}; pass --name")
    return m.group(1)


def resolve(report, dotted: str):
    """Walk ``a.b.0.c`` through nested dicts/lists; KeyError when absent."""
    cur = report
    for seg in dotted.split("."):
        if isinstance(cur, list):
            cur = cur[int(seg)]
        elif isinstance(cur, dict):
            if seg not in cur:
                raise KeyError(dotted)
            cur = cur[seg]
        else:
            raise KeyError(dotted)
    return cur


def check_band(value, band: dict):
    """(ok, description) for one value against one band."""
    if "equals" in band:
        want = band["equals"]
        return value == want, f"equals {want!r}"
    parts = []
    ok = True
    if "min" in band:
        parts.append(f">= {band['min']}")
        ok = ok and value >= band["min"]
    if "max" in band:
        parts.append(f"<= {band['max']}")
        ok = ok and value <= band["max"]
    if not parts:
        raise ValueError(f"empty tolerance band: {band}")
    return ok, " and ".join(parts)


def check_report(report: dict, bands: dict, label: str) -> int:
    """Print one PASS/FAIL line per gated metric; return #failures."""
    failures = 0
    for dotted in sorted(bands):
        band = bands[dotted]
        try:
            value = resolve(report, dotted)
        except (KeyError, IndexError, ValueError):
            print(f"FAIL {label}:{dotted} = <missing> (want {band})")
            failures += 1
            continue
        ok, want = check_band(value, band)
        print(f"{'PASS' if ok else 'FAIL'} {label}:{dotted} = {value!r} "
              f"(want {want})")
        failures += 0 if ok else 1
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("reports", nargs="+", type=Path,
                    help="bench JSON file(s) to gate")
    ap.add_argument("--name", default=None,
                    help="tolerance key override (default: from filename)")
    ap.add_argument("--tolerances", type=Path, default=TOLERANCES)
    args = ap.parse_args(argv)

    bands_all = json.loads(args.tolerances.read_text())
    failures = 0
    for path in args.reports:
        name = args.name or bench_name(path)
        if name not in bands_all:
            print(f"FAIL {path.name}: no tolerance entry for bench "
                  f"{name!r} in {args.tolerances.name}")
            failures += 1
            continue
        report = json.loads(path.read_text())
        failures += check_report(report, bands_all[name], name)
    n = sum(len(bands_all.get(args.name or bench_name(p), {}))
            for p in args.reports)
    print(f"{n - failures}/{n} gated metrics within tolerance"
          + (f"; {failures} FAILED" if failures else ""))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
