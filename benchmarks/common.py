"""Shared benchmark fixtures: datasets, engines, query workloads.

Scale: ``REPRO_BENCH_SCALE`` env var — "small" (default; CPU-container
friendly) or an integer corpus size.  The paper-scale sizes (Table 1) remain
available via scale="full" at real-hardware budgets.
"""
from __future__ import annotations

import os
import time
from typing import Dict, Tuple

import numpy as np

from repro.core import EngineConfig, FilteredANNEngine, recall_at_k
from repro.core.trainer import gen_queries
from repro.data import make_dataset

DATASETS = ("sift", "glove200", "wolt", "arxiv")
K = 10

_cache: Dict[str, tuple] = {}


def bench_scale() -> str:
    return os.environ.get("REPRO_BENCH_SCALE", "small")


def corpus_n() -> int:
    s = bench_scale()
    if s == "small":
        return 30_000
    if s == "reduced":
        return 100_000
    return int(s)


def get_fixture(name: str, with_acorn: bool = False):
    """(dataset, engine, acorn_index|None, timings dict) — cached per run."""
    key = f"{name}_{with_acorn}"
    if key in _cache:
        return _cache[key]
    ds = make_dataset(name, scale=str(corpus_n()), seed=0)
    t0 = time.perf_counter()
    eng = FilteredANNEngine(ds.vectors, ds.cat, ds.num, EngineConfig(seed=0)).build()
    t_build = time.perf_counter() - t0

    tq, tp, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 60, kinds=ds.filter_kinds, seed=1
    )
    t0 = time.perf_counter()
    eng.fit(tq, tp, k=K)
    t_fit = time.perf_counter() - t0

    acorn = None
    t_acorn = 0.0
    if with_acorn:
        from repro.index import AcornIndex

        t0 = time.perf_counter()
        acorn = AcornIndex(ds.vectors, m=24, seed=0).build()
        t_acorn = time.perf_counter() - t0

    out = (ds, eng, acorn, {"build": t_build, "fit": t_fit, "acorn": t_acorn})
    _cache[key] = out
    return out


def eval_queries(ds, n=40, sel_range=(0.01, 0.2), seed=7):
    return gen_queries(
        ds.vectors, ds.cat, ds.num, n, kinds=ds.filter_kinds,
        sel_range=sel_range, seed=seed,
    )
