"""Multi-tenant fleet benchmark: noisy neighbor vs fair-share + admission.

The "millions of users" acceptance story (ROADMAP open item 1), written
to ``BENCH_fleet.json`` at the repo root:

1. **Noisy-neighbor isolation** — a fleet of tenants (quiet poisson
   traffic + one bursty tenant offered >= 8x its fair load) replays the
   SAME multi-tenant trace through two schedulers:

   * ``shared``  — the no-isolation baseline: one global
     deadline-sorted queue, no admission, no autoscaling.  The noisy
     tenant saturates the serial server and the quiet tenants' SLO
     hit-rate collapses.
   * ``fleet``   — deficit-round-robin fair share + per-tenant
     token-bucket admission + elastic autoscaling.  Quiet tenants must
     hold SLO hit-rate >= 0.95.

2. **Elasticity** — the autoscaler must emit at least one grow and one
   shrink event during the fleet run (idle tenants release capacity,
   the overloaded tenant borrows it through ``replan_mesh``).

3. **Replay determinism** — the fleet run executes TWICE; per-tenant
   batch compositions, result ids, and telemetry counters must be
   bit-identical.

Scale: ``REPRO_BENCH_SCALE`` rows are split evenly across the tenants
(the 100k headline = a 100k-row fleet).  Load levels derive from the
virtual cost model, so the SLO dynamics are scale-invariant; wall-clock
throughput is measured on the real engines.

    PYTHONPATH=src python -m benchmarks.fleet_bench              # 100k fleet
    REPRO_BENCH_SCALE=5000 PYTHONPATH=src python -m benchmarks.fleet_bench
"""
from __future__ import annotations

import json
import os
from pathlib import Path

import numpy as np

REPO_ROOT = Path(__file__).resolve().parent.parent
DATASET = "arxiv"
K = 10
BATCH = 64
NOISY_FACTOR = 8.0       # noisy tenant offered load vs its fair share
QUIET_TARGET = 0.95      # acceptance: quiet SLO hit-rate under fleet mode
SHARED_CEIL = 0.60       # acceptance: quiet SLO hit-rate under shared queue


def _tenant_specs(scale_n: int):
    """(name, tier_mix, kind, rate_frac_of_fair, duration_s) per tenant —
    2 tenants at smoke scales (<= 10k rows), 3 at the headline.  Request
    counts derive from rate x duration so every trace spans several burst
    cycles regardless of scale."""
    small = scale_n <= 10_000
    quiet_mix = {"standard": 0.9, "batch": 0.1}
    noisy_mix = {"standard": 1.0}
    tenants = [("checkout", quiet_mix, "poisson", 0.3, 0.30)]
    if not small:
        tenants.append(("catalog", quiet_mix, "poisson", 0.3, 0.30))
    tenants.append(("analytics", noisy_mix, "bursty", NOISY_FACTOR, 0.15))
    return tenants


def _per_tenant_batches(report, trace):
    tenant_of = {r.rid: r.tenant for r in trace}
    return [[(tenant_of[rid], rid) for rid in b] for b in report.batches]


def _ids_digest(report):
    import hashlib

    h = hashlib.sha256()
    for rid in sorted(report.results):
        h.update(np.ascontiguousarray(report.ids(rid)).tobytes())
    return h.hexdigest()


def main():
    from repro.core import EngineConfig
    from repro.core.trainer import gen_queries
    from repro.data import make_dataset
    from repro.fleet import (
        AdmissionController,
        AutoscaleConfig,
        CollectionSchema,
        Fleet,
        FleetConfig,
        FleetRuntime,
        FleetServiceModel,
    )
    from repro.runtime import TenantTraceSpec, multi_tenant_trace

    from .common import corpus_n

    n_fleet = corpus_n()
    tenants = _tenant_specs(n_fleet)
    n_each = n_fleet // len(tenants)
    print(f"fleet_bench: {DATASET} fleet_rows={n_fleet} "
          f"tenants={[t[0] for t in tenants]} rows_each={n_each}")

    # BEST-CASE capacity of the serial server (rows/s, virtual): full
    # batches of the cheapest plan on a 2-shard tenant.  Anchoring fair
    # share on the optimistic bound means "8x fair" genuinely overloads
    # the server no matter which plans the planner actually picks.
    svc = FleetServiceModel()
    best_batch_s = (svc.dispatch + BATCH * min(svc.per_row.values()) / 2
                    + svc.fanout * 2)
    capacity = BATCH / best_batch_s
    fair = capacity / len(tenants)
    print(f"  virtual capacity ~{capacity:.0f} rows/s, "
          f"fair share ~{fair:.0f} rows/s per tenant")

    fleet = Fleet(total_shards=8)
    specs = []
    for ti, (name, mix, kind, rate_frac, duration) in enumerate(tenants):
        ds = make_dataset(DATASET, scale=str(n_each), seed=ti)
        qs, preds, _ = gen_queries(
            ds.vectors, ds.cat, ds.num, 24, kinds=ds.filter_kinds,
            sel_range=(0.02, 0.3), seed=ti + 1,
        )
        noisy = kind == "bursty"
        rate = rate_frac * fair
        n_req = int(rate * duration)
        schema = CollectionSchema(
            name=name, dim=ds.vectors.shape[1],
            slo_tier="standard", weight=1.0,
            # the noisy tenant starts at 1 shard and must BORROW capacity
            # through the autoscaler; its admission budget is well under
            # its fair share, with a small burst allowance — everything
            # above is shed deterministically at arrival
            n_shards=1 if noisy else 2,
            admit_rate=0.6 * fair if noisy else None,
            admit_burst=0.3 * fair if noisy else None,
        )
        fleet.create(schema, ds.vectors, ds.cat, ds.num,
                     config=EngineConfig(seed=0))
        specs.append(TenantTraceSpec(
            name, qs, list(preds), n_req, rate, kind=kind, k=K,
            tier_mix=mix, burst_factor=8.0, burst_frac=0.25, cycle=0.05,
        ))
        print(f"  {name}: {n_each} rows, {kind} @ {rate:.0f} qps "
              f"({rate_frac:.1f}x fair, {n_req} reqs)")

    trace = multi_tenant_trace(specs, seed=42)
    quiet_names = [t[0] for t in tenants if t[2] == "poisson"]
    noisy_name = [t[0] for t in tenants if t[2] == "bursty"][0]

    out = {
        "dataset": DATASET,
        "fleet_rows": n_fleet,
        "n_requests": len(trace),
        "tenants": {
            t[0]: {"rows": n_each, "kind": t[2],
                   "offered_qps": round(t[3] * fair, 1),
                   "offered_vs_fair": t[3]}
            for t in tenants
        },
        "virtual_capacity_qps": round(capacity, 1),
        "fair_share_qps": round(fair, 1),
    }

    # ------------------------------------------------------------------
    # 1. shared-queue baseline: no isolation of any kind
    # ------------------------------------------------------------------
    shared_rt = FleetRuntime(fleet, FleetConfig(max_batch=BATCH, fair=False))
    shared = shared_rt.run_trace(trace)
    out["shared"] = {
        "slo_hit_rate": {n: round(shared.slo_hit_rate(n), 4)
                         for n in fleet.names()},
        "rejected": 0,
        "wall_qps": round(sum(
            t.n_completed for t in shared.telemetry.tenants.values()) /
            max(sum(t.wall_exec_s for t in shared.telemetry.tenants.values()),
                1e-9), 1),
    }
    quiet_shared = min(out["shared"]["slo_hit_rate"][n] for n in quiet_names)
    print(f"  shared-queue quiet SLO hit-rate: {quiet_shared:.3f} "
          f"(noisy {out['shared']['slo_hit_rate'][noisy_name]:.3f})")

    # ------------------------------------------------------------------
    # 2. fleet mode: fair share + admission + autoscale (run TWICE)
    # ------------------------------------------------------------------
    def fleet_run():
        rt = FleetRuntime(
            fleet, FleetConfig(max_batch=BATCH, fair=True),
            admission=AdmissionController.for_fleet(fleet),
            autoscale=AutoscaleConfig(
                eval_every=0.05, min_window=24, grow_miss_rate=0.15,
                shrink_miss_rate=0.02, cooldown=0.05),
        )
        return rt.run_trace(trace)

    rep1 = fleet_run()
    rep2 = fleet_run()

    batches1 = _per_tenant_batches(rep1, trace)
    replay_identical = (
        batches1 == _per_tenant_batches(rep2, trace)
        and rep1.rejected == rep2.rejected
        and rep1.telemetry.counters() == rep2.telemetry.counters()
        and _ids_digest(rep1) == _ids_digest(rep2)
    )
    grows = [e for e in rep1.scale_events if e.action == "grow"]
    shrinks = [e for e in rep1.scale_events if e.action == "shrink"]
    out["fleet"] = {
        "slo_hit_rate": {n: round(rep1.slo_hit_rate(n), 4)
                         for n in fleet.names()},
        "rejected": len(rep1.rejected),
        "rejected_by_tenant": dict(rep1.telemetry.rejects),
        "scale_events": [e.as_dict() for e in rep1.scale_events],
        "n_grow": len(grows),
        "n_shrink": len(shrinks),
        "wall_qps": round(sum(
            t.n_completed for t in rep1.telemetry.tenants.values()) /
            max(sum(t.wall_exec_s for t in rep1.telemetry.tenants.values()),
                1e-9), 1),
    }
    out["replay_identical"] = bool(replay_identical)
    quiet_fleet = min(out["fleet"]["slo_hit_rate"][n] for n in quiet_names)
    print(f"  fleet quiet SLO hit-rate: {quiet_fleet:.3f} "
          f"(noisy {out['fleet']['slo_hit_rate'][noisy_name]:.3f}, "
          f"{len(rep1.rejected)} shed, {len(grows)} grows, "
          f"{len(shrinks)} shrinks)")
    print(f"  replay bit-identical: {replay_identical}")

    out["acceptance"] = {
        "noisy_offered_vs_fair_ge_8x": NOISY_FACTOR >= 8.0,
        "quiet_slo_fleet_ge_0.95": quiet_fleet >= QUIET_TARGET,
        "quiet_slo_shared_lt_0.6": quiet_shared < SHARED_CEIL,
        "autoscale_event_fired": len(grows) + len(shrinks) >= 1,
        "replay_identical": bool(replay_identical),
    }
    ok = all(out["acceptance"].values())
    print(f"acceptance: {'PASS' if ok else 'FAIL'} {out['acceptance']}")

    # headline scale owns BENCH_fleet.json; other scales write a
    # scale-suffixed (gitignored) file so they can't clobber the
    # committed 100k record
    name = ("BENCH_fleet.json" if n_fleet == 100_000
            else f"BENCH_fleet_n{n_fleet}.json")
    path = REPO_ROOT / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {path}")
    return out


def run():
    """`benchmarks/run.py` adaptor: one row per serving mode."""
    out = main()
    quiet = [n for n, t in out["tenants"].items() if t["kind"] == "poisson"]
    return [
        {
            "name": mode,
            "quiet_slo": min(out[mode]["slo_hit_rate"][n] for n in quiet),
            "rejected": out[mode]["rejected"],
            "wall_qps": out[mode]["wall_qps"],
        }
        for mode in ("shared", "fleet")
    ]


if __name__ == "__main__":
    os.environ.setdefault("REPRO_BENCH_SCALE", "reduced")   # 100k fleet
    main()
