"""Ablation: GBM selectivity refinement (paper §3.2.1) vs independence
assumption, on >=2-conjunct predicates (mixed + multi-label) — the regime
the paper introduces the model for.
"""
from __future__ import annotations

import numpy as np

from repro.core import SelectivityEstimator
from repro.core.trainer import gen_queries

from .common import get_fixture


def run():
    rows = []
    for name in ("arxiv",):
        ds, eng, _, _ = get_fixture(name)
        qs, preds, sels = gen_queries(
            ds.vectors, ds.cat, ds.num, 80, kinds=("mixed", "label"), seed=41
        )
        tr_p, tr_s = preds[:50], sels[:50]
        te = [(p, s) for p, s in zip(preds[50:], sels[50:]) if p.n_labels + p.n_ranges >= 2]
        with_model = SelectivityEstimator(eng.dataset_stats).fit(tr_p, tr_s)
        without = SelectivityEstimator(eng.dataset_stats)  # never fit -> independence
        err_w = [abs(with_model.estimate(p).sel - s) for p, s in te]
        err_wo = [abs(without.estimate(p).sel - s) for p, s in te]
        rows.append({
            "dataset": name,
            "mae_with_gbm": round(float(np.mean(err_w)), 4),
            "mae_independence": round(float(np.mean(err_wo)), 4),
            "n_test": len(te),
        })
    return rows


def main():
    print("dataset,mae_with_gbm,mae_independence,n_test")
    for r in run():
        print(f"{r['dataset']},{r['mae_with_gbm']},{r['mae_independence']},{r['n_test']}")


if __name__ == "__main__":
    main()
