"""Serving-runtime benchmark: micro-batching vs the naive per-request loop.

Three measurements, written to ``BENCH_runtime.json`` at the repo root:

1. **Throughput/latency across load levels and trace shapes** — for each
   (trace shape, load factor): replay the trace through the deadline-aware
   micro-batcher and through a naive per-request ``query()`` loop; report
   measured execution throughput for both, plus the runtime's virtual p50
   / p99 latency and deadline-hit rates (a max_batch=1 runtime provides
   the naive *virtual* frame at the same arrival process).  Acceptance
   target: the micro-batcher sustains >= 2x the naive loop's steady-state
   throughput on the 100k fixture.
2. **Deterministic replay** — the canonical trace is replayed twice and
   the per-request result ids + batch compositions must match exactly;
   the ids land in the JSON, so two runs of this benchmark at the same
   seed produce identical ``results`` sections byte-for-byte.
3. **Online feedback recovery** — the planner is deliberately warped
   (refit on inverted labels), the trace is replayed with the feedback
   loop sampling + refitting online, and decision accuracy against
   freshly measured oracle labels must recover to >= the properly-fit
   baseline planner's accuracy.

    PYTHONPATH=src python -m benchmarks.runtime_bench            # 100k fixture
    REPRO_BENCH_SCALE=5000 REPRO_RUNTIME_REQUESTS=200 \
        PYTHONPATH=src python -m benchmarks.runtime_bench        # CI smoke
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

REPO_ROOT = Path(__file__).resolve().parent.parent
DATASET = "arxiv"
N_PREDS = 16
K = 10
LOADS = (0.5, 2.0, 8.0)        # x the naive single-server virtual capacity
SHAPES = ("poisson", "bursty")


def _n_requests() -> int:
    return int(os.environ.get("REPRO_RUNTIME_REQUESTS", 400))


def _bench_load(eng, qs, preds, shape: str, load: float, seed: int):
    from repro.runtime import (
        make_trace, OnlineRuntime, SchedulerConfig, ServiceModel,
    )

    service = ServiceModel()
    naive_capacity = 1.0 / service.estimate(1)      # virtual qps, batch of 1
    rate = load * naive_capacity
    trace = make_trace(shape, qs, preds, _n_requests(), rate, k=K, seed=seed)

    runtime = OnlineRuntime(eng, SchedulerConfig(max_batch=64, max_wait=0.005))
    report = runtime.run_trace(trace)
    snap = report.telemetry.snapshot(eng)

    # naive virtual frame: same arrivals, one-request "batches"
    naive_rt = OnlineRuntime(eng, SchedulerConfig(max_batch=1, max_wait=0.0))
    naive_snap = naive_rt.run_trace(trace).telemetry.snapshot()

    # naive measured wall: a plain per-request query loop
    t0 = time.perf_counter()
    for r in trace:
        eng.query(r.query, r.pred, r.k)
    naive_wall = time.perf_counter() - t0

    wall = snap["wall"]["exec_s"]
    n = len(trace)
    met = sum(snap["deadline_met"].values())
    naive_met = sum(naive_snap["deadline_met"].values())
    row = {
        "shape": shape,
        "load": load,
        "rate_qps": round(rate, 1),
        "runtime_qps": round(n / wall, 1),
        "naive_qps": round(n / naive_wall, 1),
        "speedup": round(naive_wall / wall, 2),
        "p50_virtual_ms": round(snap["latency_virtual"]["p50"] * 1e3, 3),
        "p99_virtual_ms": round(snap["latency_virtual"]["p99"] * 1e3, 3),
        "naive_p99_virtual_ms": round(
            naive_snap["latency_virtual"]["p99"] * 1e3, 3),
        "deadline_hit_rate": round(met / n, 4),
        "naive_deadline_hit_rate": round(naive_met / n, 4),
        "mean_batch": round(n / snap["n_batches"], 1),
    }
    print("  " + " ".join(f"{k}={v}" for k, v in row.items()))
    return row, report


def _replay_section(eng, qs, preds, seed: int):
    """Canonical-trace determinism: two fresh replays must agree exactly;
    the ids recorded here make cross-RUN determinism checkable by diffing
    BENCH_runtime.json."""
    from repro.runtime import make_trace, OnlineRuntime, SchedulerConfig

    trace = make_trace("poisson", qs, preds, _n_requests(), 2000.0, k=K, seed=seed)
    cfg = SchedulerConfig(max_batch=64, max_wait=0.005)
    a = OnlineRuntime(eng, cfg).run_trace(trace)
    b = OnlineRuntime(eng, cfg).run_trace(trace)
    assert a.batches == b.batches, "batch compositions forked across replays"
    assert a.telemetry.counters() == b.telemetry.counters(), "telemetry forked"
    for rid in a.results:
        assert np.array_equal(a.ids(rid), b.ids(rid)), f"ids forked for rid {rid}"
    print(f"  replay determinism: {len(trace)} requests, "
          f"{len(a.batches)} batches identical across two runs")
    return {
        "n_requests": len(trace),
        "batches": a.batches,
        "ids": {str(rid): a.ids(rid).tolist() for rid in sorted(a.results)},
        "counters": a.telemetry.counters(),
    }


# ----------------------------------------------------------------------
# feedback recovery
# ----------------------------------------------------------------------
def _oracle_labels(eng, qs, preds):
    """Measured ground-truth win labels — the engine's shared §3.1 rule."""
    return np.asarray(
        [eng.label_query(q, p, K).label for q, p in zip(qs, preds)], np.int32
    )


def _decision_accuracy(eng, planner, qs, preds, labels) -> float:
    """2-way accuracy of a head vs oracle labels (INDEXED_PRE folds into
    PRE — same executor family, the label the head was trained on)."""
    from repro.core import POST_FILTER, PRE_FILTER

    ok = 0
    for q, p, lbl in zip(qs, preds, labels):
        se = eng.estimator.estimate(p)
        d = int(planner.decide(eng.feat.vector(p, se.sel, K, se.is_exact))[0])
        d = POST_FILTER if d == POST_FILTER else PRE_FILTER
        ok += int(d == int(lbl))
    return ok / len(labels)


def _feedback_section(eng, ds, qs, preds, seed: int):
    from repro.core import CorePlanner
    from repro.core.trainer import gen_queries
    from repro.runtime import (
        FeedbackConfig, OnlineFeedback, OnlineRuntime, SchedulerConfig, make_trace,
    )

    baseline = eng.planner          # properly fit by the fixture

    # warp: refit the head on an inverted-threshold labelling — the "planner
    # trained on a warped offline distribution"
    feats, warped_labels = [], []
    for p in preds:
        se = eng.estimator.estimate(p)
        feats.append(eng.feat.vector(p, se.sel, K, se.is_exact))
        warped_labels.append(1 if se.sel < 0.05 else 0)    # backwards on purpose
    warped = CorePlanner(seed=seed + 13).fit(
        np.stack(feats), np.asarray(warped_labels, np.int32))

    # oracle eval set, disjoint from the serving pool
    eq, ep, _ = gen_queries(ds.vectors, ds.cat, ds.num, 32,
                            kinds=ds.filter_kinds, sel_range=(0.01, 0.4),
                            seed=seed + 100)
    oracle = _oracle_labels(eng, eq, ep)
    acc_baseline = _decision_accuracy(eng, baseline, eq, ep, oracle)
    acc_warped = _decision_accuracy(eng, warped, eq, ep, oracle)

    eng.swap_planner(warped)
    fb = OnlineFeedback(eng, FeedbackConfig(
        sample_rate=0.4, refit_every=48, min_examples=32, seed=seed))
    trace = make_trace("poisson", qs, preds, _n_requests(), 2000.0, k=K,
                       seed=seed + 7)
    OnlineRuntime(eng, SchedulerConfig(max_batch=64), feedback=fb).run_trace(trace)
    recovered = eng.planner
    acc_recovered = _decision_accuracy(eng, recovered, eq, ep, oracle)
    eng.swap_planner(baseline)      # leave the fixture as we found it

    ok = acc_recovered >= acc_baseline
    row = {
        "acc_baseline": round(acc_baseline, 4),
        "acc_warped": round(acc_warped, 4),
        "acc_recovered": round(acc_recovered, 4),
        "recovered_ge_baseline": bool(ok),
        **fb.stats(),
    }
    print(f"  feedback: baseline {acc_baseline:.3f}  warped {acc_warped:.3f}  "
          f"recovered {acc_recovered:.3f} "
          f"({'PASS' if ok else 'FAIL'}: target recovered >= baseline)")
    return row


def _dnf_feedback_section(eng, ds, qs, preds, seed: int):
    """Feedback recovery on DNF-heavy traffic: the serving pool is unions
    of the conjunctive pool, so every sampled request feeds the log one
    clause-level row per unique disjunct (the planner head only ever
    decides conjunctions).  A warped head must recover clause-decision
    accuracy — measured on a disjoint conjunctive eval set — from clause
    rows alone."""
    from repro.core import CorePlanner, Or
    from repro.core.trainer import gen_queries
    from repro.runtime import (
        FeedbackConfig, OnlineFeedback, OnlineRuntime, SchedulerConfig, make_trace,
    )

    baseline = eng.planner
    dnf_pool = [Or((a, b)) for a, b in zip(preds[::2], preds[1::2])]

    # clause-level oracle eval set, disjoint from the serving pool
    eq, ep, _ = gen_queries(ds.vectors, ds.cat, ds.num, 32,
                            kinds=ds.filter_kinds, sel_range=(0.01, 0.4),
                            seed=seed + 200)
    oracle = _oracle_labels(eng, eq, ep)

    feats, warped_labels = [], []
    for p in ep:
        se = eng.estimator.estimate(p)
        feats.append(eng.feat.vector(p, se.sel, K, se.is_exact))
        warped_labels.append(1 if se.sel < 0.05 else 0)    # backwards on purpose
    warped = CorePlanner(seed=seed + 17).fit(
        np.stack(feats), np.asarray(warped_labels, np.int32))

    acc_baseline = _decision_accuracy(eng, baseline, eq, ep, oracle)
    acc_warped = _decision_accuracy(eng, warped, eq, ep, oracle)

    eng.swap_planner(warped)
    fb = OnlineFeedback(eng, FeedbackConfig(
        sample_rate=0.5, refit_every=48, min_examples=32, seed=seed))
    trace = make_trace("poisson", qs, dnf_pool, _n_requests(), 2000.0, k=K,
                       seed=seed + 9)
    OnlineRuntime(eng, SchedulerConfig(max_batch=64), feedback=fb).run_trace(trace)
    acc_recovered = _decision_accuracy(eng, eng.planner, eq, ep, oracle)
    eng.swap_planner(baseline)      # leave the fixture as we found it

    improved = acc_recovered > acc_warped
    row = {
        "n_dnf_preds": len(dnf_pool),
        "acc_baseline": round(acc_baseline, 4),
        "acc_warped": round(acc_warped, 4),
        "acc_recovered": round(acc_recovered, 4),
        "improved": bool(improved),
        "clause_rows": len(fb.log),
        **fb.stats(),
    }
    print(f"  dnf feedback: warped {acc_warped:.3f} -> recovered "
          f"{acc_recovered:.3f} from {len(fb.log)} clause rows "
          f"({'PASS' if improved else 'FAIL'}: target recovered > warped; "
          f"baseline {acc_baseline:.3f})")
    return row


# ----------------------------------------------------------------------
# observability: live recall probe + traced span summary
# ----------------------------------------------------------------------
def _obs_section(eng, qs, preds, seed: int):
    """Replay the canonical trace with a rate-1.0 recall probe and a tracer
    attached: every served (plan, backend, knob) class must come out with
    an online recall estimate, and the span summary gives the measured
    where-does-the-time-go breakdown (acceptance: probe covers every
    served class)."""
    from repro.obs import RecallProbe, Tracer, span_summary
    from repro.runtime import make_trace, OnlineRuntime, SchedulerConfig

    trace = make_trace("poisson", qs, preds, _n_requests(), 2000.0, k=K,
                       seed=seed)
    tracer = Tracer()
    probe = RecallProbe(rate=1.0, seed=seed)
    rt = OnlineRuntime(eng, SchedulerConfig(max_batch=64, max_wait=0.005),
                       tracer=tracer, probe=probe)
    report = rt.run_trace(trace)
    eng.set_tracer(None)            # leave the shared fixture untraced

    served = {RecallProbe.class_key(r) for r in report.results.values()}
    est = probe.estimates()
    missing = sorted(served - set(est))
    ok = not missing
    print(f"  probe: {len(est)} served classes estimated "
          f"({'PASS' if ok else 'FAIL: missing ' + str(missing)}: "
          f"target every served class)")
    assert ok, f"recall probe missed served classes: {missing}"
    summary = span_summary(tracer)
    for row in summary[:4]:
        print(f"    {row['stage']}: count={row['count']} "
              f"self={row['self_s'] * 1e3:.1f}ms")
    return {
        "probe": est,
        "probe_counters": probe.counters(),
        "span_summary": summary,
    }


# ----------------------------------------------------------------------
def main():
    from .common import corpus_n, eval_queries, get_fixture

    print(f"runtime_bench: {DATASET} n={corpus_n()} "
          f"requests={_n_requests()} per trace")
    ds, eng, _, timings = get_fixture(DATASET)
    print(f"# fixture build={timings['build']:.1f}s fit={timings['fit']:.1f}s")
    qs, all_preds, _ = eval_queries(ds, n=64, sel_range=(0.01, 0.4), seed=7)
    preds = list(all_preds[:N_PREDS])

    out = {"n": int(ds.vectors.shape[0]), "dataset": DATASET,
           "n_requests": _n_requests(), "k": K, "loads": []}
    print("load sweep (micro-batcher vs naive loop):")
    for shape in SHAPES:
        for li, load in enumerate(LOADS):
            row, _ = _bench_load(eng, qs, preds, shape, load, seed=31 + li)
            out["loads"].append(row)

    steady = max(
        (r for r in out["loads"] if r["shape"] == "poisson"),
        key=lambda r: r["load"],
    )
    out["steady_state_speedup"] = steady["speedup"]
    ok = steady["speedup"] >= 2.0
    print(f"steady-state (poisson, load {steady['load']}x) speedup: "
          f"{steady['speedup']}x ({'PASS' if ok else 'FAIL'}: target >= 2x)")

    print("deterministic replay:")
    out["replay"] = _replay_section(eng, qs, preds, seed=57)

    print("online feedback recovery:")
    out["feedback"] = _feedback_section(eng, ds, qs, preds, seed=5)

    print("online feedback recovery on DNF-heavy traffic (clause rows):")
    out["dnf_feedback"] = _dnf_feedback_section(eng, ds, qs, preds, seed=5)

    print("observability (recall probe + span summary):")
    out["obs"] = _obs_section(eng, qs, preds, seed=57)

    # headline scale owns BENCH_runtime.json; other scales (CI smoke, small
    # run.py sweeps) write a scale-suffixed (gitignored) file so they can't
    # clobber the committed 100k record
    n = int(ds.vectors.shape[0])
    name = "BENCH_runtime.json" if n == 100_000 else f"BENCH_runtime_n{n}.json"
    path = REPO_ROOT / name
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {path}")
    return out


def run():
    """`benchmarks/run.py` adaptor: one CSV-able row per load point."""
    out = main()
    rows = [
        {
            "name": f"{r['shape']}_load{r['load']}",
            "p99_us": int(r["p99_virtual_ms"] * 1e3),
            "speedup": r["speedup"],
            "deadline_hit_rate": r["deadline_hit_rate"],
        }
        for r in out["loads"]
    ]
    rows.append({
        "name": "feedback_recovery", "p99_us": 0,
        "speedup": out["feedback"]["acc_recovered"],
        "deadline_hit_rate": out["feedback"]["acc_baseline"],
    })
    return rows


if __name__ == "__main__":
    os.environ.setdefault("REPRO_BENCH_SCALE", "reduced")   # 100k fixture
    main()
