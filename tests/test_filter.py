"""repro.filter: bitmap/range indexes, DNF compiler, cache, indexed pre-filter.

Two layers of coverage:

* deterministic randomized suites (always run) asserting compiled-bitmap
  evaluation ≡ naive ``eval`` over random DNF predicates — including
  NULL_CODE rows, empty intervals, full-true/full-false masks — plus
  popcount ≡ ``mask.sum()``, cache semantics, and executor equivalence
  (indexed pre-filter results identical to the scan-based pre-filter, flat
  AND sharded);
* a hypothesis property suite (skipped when hypothesis is absent) fuzzing
  the same invariant over arbitrary corpora/predicates.
"""
import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    FilteredANNEngine,
    INDEXED_PRE,
    LabelEq,
    Not,
    Or,
    POST_FILTER,
    PRE_FILTER,
    Predicate,
    RangePred,
)
from repro.core.trainer import gen_queries
from repro.data import make_dataset
from repro.filter import (
    AttributeIndex,
    PredicateCache,
    canonical_key,
    expand_words,
    pack_mask,
    popcount_words,
    words_from_ids,
)

K = 10


# ----------------------------------------------------------------------
# bitmap primitives
# ----------------------------------------------------------------------
def test_pack_expand_roundtrip_and_popcount():
    rng = np.random.default_rng(0)
    for n in (0, 1, 31, 32, 33, 1000, 4097):
        m = rng.random(n) < 0.3
        w = pack_mask(m)
        assert (expand_words(w, n) == m).all()
        assert popcount_words(w) == int(m.sum())
        # bit addressing agrees between the packer and the id-setter
        assert (words_from_ids(np.flatnonzero(m), n) == w).all()


# ----------------------------------------------------------------------
# random-DNF equivalence (deterministic)
# ----------------------------------------------------------------------
def _rand_corpus(rng, n):
    cat = rng.integers(-1, 6, size=(n, 3)).astype(np.int32)  # incl. NULL_CODE
    num = np.round(rng.normal(0, 5, size=(n, 2)), 1).astype(np.float32)  # many ties
    return cat, num


def _rand_leaf(rng):
    if rng.random() < 0.5:
        return LabelEq(int(rng.integers(3)), int(rng.integers(-1, 7)))
    attr = int(rng.integers(2))
    ivs = []
    for _ in range(int(rng.integers(1, 3))):
        lo = float(rng.normal(0, 5))
        hi = lo + float(rng.exponential(4)) - (2.0 if rng.random() < 0.2 else 0.0)
        ivs.append((lo, hi))   # sometimes empty (hi <= lo)
    return RangePred(attr, tuple(ivs))


def _rand_conj(rng):
    leaves = [_rand_leaf(rng) for _ in range(int(rng.integers(1, 4)))]
    return Predicate(
        labels=tuple(l for l in leaves if isinstance(l, LabelEq)),
        ranges=tuple(l for l in leaves if isinstance(l, RangePred)),
        nots=tuple(Not(_rand_leaf(rng)) for _ in range(int(rng.integers(0, 2)))),
    )


def _rand_dnf(rng):
    if rng.random() < 0.5:
        return _rand_conj(rng)
    return Or(tuple(_rand_conj(rng) for _ in range(int(rng.integers(0, 4)))))


def test_compiled_bitmap_equals_naive_eval():
    rng = np.random.default_rng(1)
    cat, num = _rand_corpus(rng, 4003)
    index = AttributeIndex.build(cat, num)
    cache = PredicateCache(capacity=64)
    pool = [_rand_dnf(rng) for _ in range(150)]
    pool += [Predicate(), Or(())]                  # full-true / full-false
    pool += [Predicate(ranges=(RangePred(0, ((1e9, 2e9),)),))]  # empty range
    for p in pool:
        ref = p.eval(cat, num)
        assert index.covers(p)
        c = cache.get_or_compile(p, index)
        assert (c.mask() == ref).all(), str(p)
        assert c.popcount == int(ref.sum()), str(p)
        assert c.selectivity == pytest.approx(float(ref.mean()), abs=0)


def test_compile_matches_on_null_and_negation():
    cat = np.array([[0], [1], [-1], [1], [-1]], np.int32)
    num = np.zeros((5, 1), np.float32)
    index = AttributeIndex.build(cat, num)
    # explicit NULL query and negation both include/exclude NULL rows exactly
    for p in (
        Predicate(labels=(LabelEq(0, -1),)),
        Predicate(nots=(Not(LabelEq(0, 1)),)),
        Predicate(nots=(Not(LabelEq(0, -1)),)),
        Predicate(labels=(LabelEq(0, 99),)),       # out-of-dictionary code
    ):
        assert (index.compile(p).mask() == p.eval(cat, num)).all(), str(p)


# ----------------------------------------------------------------------
# satellite regressions: interval merging + empty corpora
# ----------------------------------------------------------------------
def test_rangepred_merges_overlapping_intervals():
    r = RangePred(0, ((0.0, 10.0), (5.0, 15.0)))
    assert r.intervals == ((0.0, 15.0),)
    assert r.total_width == 15.0                   # was 20 before the merge fix
    # adjacency merges too (half-open intervals: [0,5) u [5,10) = [0,10))
    assert RangePred(0, ((5.0, 10.0), (0.0, 5.0))).intervals == ((0.0, 10.0),)
    # disjoint stays disjoint, sorted
    assert RangePred(0, ((8.0, 9.0), (1.0, 2.0))).intervals == ((1.0, 2.0), (8.0, 9.0))
    # empty intervals are dropped; an all-empty predicate matches nothing
    r = RangePred(0, ((3.0, 3.0), (7.0, 5.0)))
    assert r.intervals == () and r.total_width == 0.0 and r.midpoint == 0.0
    num = np.arange(10, dtype=np.float32)[:, None]
    assert not r.eval(np.zeros((10, 0), np.int32), num).any()


def test_eval_on_empty_and_degenerate_corpora():
    p_lbl = Predicate(labels=(LabelEq(0, 1),))
    p_rng = Predicate(ranges=(RangePred(0, ((0.0, 1.0),)),))
    # N = 0 with attribute columns
    cat0, num0 = np.zeros((0, 3), np.int32), np.zeros((0, 2), np.float32)
    for p in (Predicate(), p_lbl, p_rng, Or((p_lbl, p_rng))):
        m = p.eval(cat0, num0)
        assert m.shape == (0,) and m.dtype == bool
        assert p.selectivity(cat0, num0) == 0.0
    # N > 0 but zero-column cat AND a 1-D empty num (the old n-derivation
    # read num.shape[0] == 0 and returned a wrongly-shaped mask)
    cat = np.zeros((7, 0), np.int32)
    num = np.zeros((0,), np.float32)
    assert Predicate().eval(cat, num).shape == (7,)
    # and the mirrored case
    assert Predicate().eval(np.zeros((0,), np.int32), np.zeros((7, 0), np.float32)).shape == (7,)
    # fully empty corpus: shape (0,)
    assert Predicate().eval(np.zeros((0,), np.int32), np.zeros((0,), np.float32)).shape == (0,)


def test_float32_boundary_bounds_match_scan():
    """Regression: bounds that are not float32-representable must quantise
    exactly as the scan's weak promotion does.  x = float32(0.1) with
    lo = 0.1000000015 rounds DOWN to x in float32 — the scan includes the
    row, so the index must too (it compared in float64 before the fix)."""
    num = np.array([[0.1], [0.25], [0.5]], np.float32)
    cat = np.zeros((3, 0), np.int32)
    index = AttributeIndex.build(cat, num)
    for lo, hi in [(0.1000000015, 0.5000000001), (0.09999999999, 0.25000000001),
                   (0.1, 0.25), (-1e300, 1e300)]:
        p = Predicate(ranges=(RangePred(0, ((lo, hi),)),))
        assert (index.compile(p).mask() == p.eval(cat, num)).all(), (lo, hi)


def test_high_cardinality_column_left_unindexed():
    """An ID-like categorical column (more distinct codes than
    MAX_CODES_INDEXED) must not be bitmap-indexed — predicates touching it
    report uncovered and fall back to the scan, instead of the build
    allocating O(codes * N/8) bytes."""
    from repro.filter.bitmap import MAX_CODES_INDEXED

    n = MAX_CODES_INDEXED + 10
    cat = np.stack([np.arange(n, dtype=np.int32),          # all-unique IDs
                    np.zeros(n, np.int32)], axis=1)        # normal column
    num = np.zeros((n, 1), np.float32)
    index = AttributeIndex.build(cat, num)
    assert not index.labels.indexed(0) and index.labels.indexed(1)
    assert not index.covers(Predicate(labels=(LabelEq(0, 7),)))
    assert index.covers(Predicate(labels=(LabelEq(1, 0),)))
    # sparse code space: huge max code, few present codes -> still indexed
    sparse = np.zeros((100, 1), np.int32)
    sparse[1, 0] = 10**6
    idx2 = AttributeIndex.build(sparse, np.zeros((100, 1), np.float32))
    assert idx2.labels.indexed(0)
    p = Predicate(labels=(LabelEq(0, 10**6),))
    assert (idx2.compile(p).mask() == p.eval(sparse, np.zeros((100, 1), np.float32))).all()


def test_cache_mask_tier_bounded():
    """The expanded-mask tier holds at most mask_capacity entries; the
    compiled-words tier is unaffected by mask evictions."""
    rng = np.random.default_rng(3)
    cat, num = _rand_corpus(rng, 256)
    index = AttributeIndex.build(cat, num)
    cache = PredicateCache(capacity=16, mask_capacity=2)
    preds = [Predicate(labels=(LabelEq(0, c),)) for c in range(5)]
    for p in preds:
        m = cache.mask(p, index)
        assert (m == p.eval(cat, num)).all()
    s = cache.stats()
    assert s["masks"] == 2 and s["size"] == 5
    # re-expansion after mask eviction still agrees
    assert (cache.mask(preds[0], index) == preds[0].eval(cat, num)).all()


def test_attribute_index_on_empty_corpus():
    index = AttributeIndex.build(np.zeros((0, 2), np.int32), np.zeros((0, 1), np.float32))
    p = Predicate(labels=(LabelEq(0, 0),), ranges=(RangePred(0, ((0.0, 1.0),)),))
    c = index.compile(p)
    assert c.popcount == 0 and c.selectivity == 0.0 and c.mask().shape == (0,)


# ----------------------------------------------------------------------
# cache semantics
# ----------------------------------------------------------------------
def test_canonical_key_order_and_duplicates():
    a, b = LabelEq(0, 1), LabelEq(1, 2)
    assert canonical_key(Predicate(labels=(a, b))) == canonical_key(Predicate(labels=(b, a, a)))
    t1, t2 = Predicate(labels=(a,)), Predicate(labels=(b,))
    assert canonical_key(Or((t1, t2))) == canonical_key(Or((t2, t1, t1)))
    assert canonical_key(Predicate(labels=(a,))) != canonical_key(Predicate(nots=(Not(a),)))


def test_cache_hits_and_lru_eviction():
    rng = np.random.default_rng(2)
    cat, num = _rand_corpus(rng, 512)
    index = AttributeIndex.build(cat, num)
    cache = PredicateCache(capacity=2)
    p1 = Predicate(labels=(LabelEq(0, 1),))
    p2 = Predicate(labels=(LabelEq(0, 2),))
    p3 = Predicate(labels=(LabelEq(0, 3),))
    c1 = cache.get_or_compile(p1, index)
    assert cache.get_or_compile(p1, index) is c1          # hit, same object
    # logically-equal reconstruction hits the same line
    assert cache.get_or_compile(Predicate(labels=(LabelEq(0, 1), LabelEq(0, 1))), index) is c1
    cache.get_or_compile(p2, index)
    cache.get_or_compile(p1, index)                       # p1 now most recent
    cache.get_or_compile(p3, index)                       # evicts p2 (LRU)
    assert cache.get_or_compile(p1, index) is c1
    s = cache.stats()
    assert s["size"] == 2 and s["evictions"] == 1
    assert s["hits"] == 4 and s["misses"] == 3


# ----------------------------------------------------------------------
# executor + engine equivalence (the acceptance criterion)
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine():
    ds = make_dataset("arxiv", scale="4000", seed=0)
    eng = FilteredANNEngine(
        ds.vectors, ds.cat, ds.num, EngineConfig(n_lists=32, seed=0)
    ).build()
    return ds, eng


def _predicate_pool(ds, n=18):
    """Mixed pool spanning kinds and selectivities (incl. > FULL_SCAN_FRAC so
    the bitmap-masked full-corpus branch is exercised), plus DNF shapes."""
    _, preds, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, n, kinds=ds.filter_kinds,
        sel_range=(0.005, 0.5), seed=23,
    )
    x0 = ds.num[:, 0]
    wide = Predicate(ranges=(RangePred(0, ((float(x0.min()) - 1.0, float(np.quantile(x0, 0.8))),)),))
    dnf = Or((
        Predicate(labels=(LabelEq(0, 0),)),
        Predicate(ranges=(RangePred(1, ((float(np.quantile(ds.num[:, 1], 0.5)), float(np.quantile(ds.num[:, 1], 0.7))),)),),
                  nots=(Not(LabelEq(1, 0)),)),
    ))
    return list(preds) + [wide, dnf, Predicate(), Or(())]


def test_indexed_pre_identical_to_scan_pre_flat(engine):
    ds, eng = engine
    rng = np.random.default_rng(5)
    for i, p in enumerate(_predicate_pool(ds)):
        q = ds.vectors[rng.integers(ds.n)][None]
        a = eng.pre_exec.search(q, p, K)
        b = eng.ipre_exec.search(q, p, K)
        assert np.array_equal(a.ids, b.ids), f"pool[{i}] ids differ: {p}"
        assert np.array_equal(a.dists, b.dists), f"pool[{i}] dists differ: {p}"


def test_indexed_pre_identical_to_scan_pre_sharded(engine):
    ds, eng = engine
    rng = np.random.default_rng(7)
    shards = eng.shard_corpus(3)
    for p in _predicate_pool(ds, n=8):
        q = ds.vectors[rng.integers(ds.n)][None]
        for s in shards:
            a = s.search(q, p, K, PRE_FILTER)
            b = s.search(q, p, K, INDEXED_PRE)
            assert np.array_equal(a.ids, b.ids), f"shard {s.shard_id}: {p}"
            assert np.array_equal(a.dists, b.dists), f"shard {s.shard_id}: {p}"


def test_estimator_exact_path(engine):
    ds, eng = engine
    for p in _predicate_pool(ds, n=10):
        se = eng.estimator.estimate(p)
        assert se.is_exact
        assert se.sel == pytest.approx(p.selectivity(ds.cat, ds.num), abs=0)


def test_engine_three_way_plan_and_dnf_end_to_end(engine):
    ds, eng = engine
    qs, preds, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 12, kinds=ds.filter_kinds,
        sel_range=(0.005, 0.4), seed=31,
    )
    pool = list(preds) + [_predicate_pool(ds, n=4)[-3]]   # include the DNF
    q = np.stack([qs[i % len(qs)] for i in range(len(pool))])
    batched = eng.batch_query(q, pool, k=K)
    decisions = {r.decision for r in batched}
    # untrained planner falls back to the calibrated heuristic: covered
    # low-selectivity predicates run INDEXED_PRE, high selectivity POST;
    # plain PRE only appears for uncovered predicates (none here)
    assert INDEXED_PRE in decisions and POST_FILTER in decisions
    for i, r in enumerate(batched):
        single = eng.query(q[i], pool[i], k=K)
        assert single.decision == r.decision
        assert np.array_equal(single.result.ids, r.result.ids)
        ids = r.result.ids[r.result.ids >= 0]
        if ids.size:
            assert pool[i].eval(ds.cat[ids], ds.num[ids]).all()
        if r.decision == INDEXED_PRE:
            assert r.result.strategy == "ipre"


def test_estimator_fit_tolerates_dnf_and_wild_codes(engine):
    """Regression: a training pool containing Or predicates (which the GBM
    never serves) must not crash estimator.fit, and independence features
    must guard out-of-dictionary codes in negated leaves instead of
    indexing a neighbouring attribute's frequency span."""
    ds, eng = engine
    _, preds, sels = gen_queries(
        ds.vectors, ds.cat, ds.num, 12, kinds=("label", "mixed"), seed=41
    )
    pool = list(preds) + [Or((preds[0], preds[1]))]
    eng.estimator.fit(pool, list(sels) + [0.1])           # Or entry skipped
    wild = Predicate(nots=(Not(LabelEq(0, 9999)),))       # valid query: all-true
    assert eng.dataset_stats.independence_sel(wild) == 1.0        # was IndexError
    se = eng.estimator.estimate(wild)
    assert se.is_exact and se.sel == pytest.approx(wild.selectivity(ds.cat, ds.num), abs=0)


def test_engine_stats_exposes_cache_counters(engine):
    """Satellite: PredicateCache hit/miss/eviction stats are reachable
    through the public ``FilteredANNEngine.stats()`` accessor (they used to
    require poking ``eng.pred_cache`` internals), and serving traffic moves
    them: a repeated predicate must register cache hits."""
    ds, eng = engine
    st0 = eng.stats()
    assert {"planner_version", "pred_cache", "plan_cache"} <= set(st0)
    assert {"hits", "misses", "evictions", "size", "capacity"} <= set(st0["pred_cache"])
    # lowest-selectivity covered predicate => planned INDEXED_PRE, so the
    # executor consults the predicate cache on every repeat
    p = min(_predicate_pool(ds, n=8)[:8], key=lambda x: x.selectivity(ds.cat, ds.num))
    q = ds.vectors[:1]
    eng.query(q, p, K)
    mid = eng.stats()["pred_cache"]
    eng.query(q, p, K)
    eng.query(q, p, K)
    after = eng.stats()
    # the repeat queries hit both the compiled-predicate cache and the
    # memoised plan cache; nothing new was compiled
    assert after["pred_cache"]["hits"] > mid["hits"]
    assert after["pred_cache"]["misses"] == mid["misses"]
    assert after["plan_cache"]["hits"] >= 2
    assert after["pred_cache"]["evictions"] >= 0


def test_engine_without_attr_index_stays_two_way():
    ds = make_dataset("sift", scale="2000", seed=0)
    eng = FilteredANNEngine(
        ds.vectors, ds.cat, ds.num,
        EngineConfig(n_lists=16, seed=0, attr_index=False),
    ).build()
    assert eng.attr_index is None
    _, preds, _ = gen_queries(ds.vectors, ds.cat, ds.num, 6, kinds=("range",), seed=3)
    for p in preds:
        se = eng.estimator.estimate(p)
        assert not se.is_exact
        r = eng.query(ds.vectors[0], p, k=5)
        assert r.decision in (PRE_FILTER, POST_FILTER)


# ----------------------------------------------------------------------
# hypothesis property suite (the deterministic suites above always run;
# these fuzz the same invariants when hypothesis is installed)
# ----------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:               # container without hypothesis: skip below
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @st.composite
    def corpus_and_dnf(draw):
        n = draw(st.integers(0, 300))
        seed = draw(st.integers(0, 2**31 - 1))
        rng = np.random.default_rng(seed)
        cat, num = _rand_corpus(rng, n)
        pred = _rand_dnf(rng)
        return cat, num, pred

    @given(corpus_and_dnf())
    @settings(max_examples=60, deadline=None)
    def test_property_compiled_equals_eval(args):
        cat, num, pred = args
        index = AttributeIndex.build(cat, num)
        ref = pred.eval(cat, num)
        c = index.compile(pred)
        assert (c.mask() == ref).all()
        assert c.popcount == int(ref.sum())

    @given(
        ivs=st.lists(
            st.tuples(
                st.floats(-50, 50, allow_nan=False),
                st.floats(-50, 50, allow_nan=False),
            ),
            min_size=1, max_size=5,
        )
    )
    @settings(max_examples=60, deadline=None)
    def test_property_interval_merge_canonical(ivs):
        r = RangePred(0, tuple(ivs))
        # canonical: sorted, non-empty, pairwise disjoint and non-adjacent
        for (lo1, hi1), (lo2, hi2) in zip(r.intervals, r.intervals[1:]):
            assert lo1 < hi1 and lo2 < hi2 and hi1 < lo2
        # semantics preserved vs the raw union
        x = np.linspace(-60, 60, 997, dtype=np.float32)[:, None]
        cat = np.zeros((997, 0), np.int32)
        raw = np.zeros(997, bool)
        for lo, hi in ivs:
            raw |= (x[:, 0] >= lo) & (x[:, 0] < hi)
        assert (r.eval(cat, x) == raw).all()
        # width equals measure of the union (no double counting)
        assert r.total_width == pytest.approx(
            sum(hi - lo for lo, hi in r.intervals), abs=0
        )
else:  # keep a visible skip marker so CI reports the property suite's state
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_compiled_equals_eval():
        pass
