"""End-to-end engine tests: plan + execute, planner training, utility labels."""
import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    FilteredANNEngine,
    INDEXED_PRE,
    POST_FILTER,
    PRE_FILTER,
    recall_at_k,
)
from repro.core.trainer import gen_queries
from repro.data import make_dataset


@pytest.fixture(scope="module")
def engine():
    ds = make_dataset("sift", scale="8000", seed=0)
    eng = FilteredANNEngine(
        ds.vectors, ds.cat, ds.num, EngineConfig(n_lists=64, seed=0)
    ).build()
    tq, tp, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 60, kinds=("range", "mixed"), seed=1
    )
    eng.fit(tq, tp, k=10)
    return ds, eng


def test_engine_builds(engine):
    _, eng = engine
    assert eng.ivf.built and eng.planner.params is not None


def test_query_recall(engine):
    ds, eng = engine
    qs, preds, _ = gen_queries(ds.vectors, ds.cat, ds.num, 20, kinds=("range",), seed=7)
    recs = []
    for i, p in enumerate(preds):
        res = eng.query(qs[i], p, k=10)
        truth = eng.ground_truth(qs[i], p, k=10)
        recs.append(recall_at_k(res.result.ids, truth))
    assert float(np.mean(recs)) >= 0.9, f"planned recall {np.mean(recs)}"


def test_decisions_vary_with_selectivity(engine):
    """Planner should not be a constant function across the selectivity range
    (unless one strategy dominates everywhere, which the fixture avoids)."""
    ds, eng = engine
    qs, preds, sels = gen_queries(
        ds.vectors, ds.cat, ds.num, 30, kinds=("range",), sel_range=(0.005, 0.4), seed=9
    )
    decisions = [eng.query(qs[i], p, k=10).decision for i, p in enumerate(preds)]
    assert set(decisions) <= {PRE_FILTER, POST_FILTER, INDEXED_PRE}


def test_post_filter_expansion_fills_k(engine):
    ds, eng = engine
    qs, preds, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 5, kinds=("range",), sel_range=(0.02, 0.05), seed=11
    )
    for i, p in enumerate(preds):
        res = eng.post_exec.search(qs[i : i + 1], p, k=10)
        n_valid = (res.ids >= 0).sum()
        assert n_valid == 10, f"post-filter returned {n_valid} < k despite expansion"


def test_pre_filter_is_exact(engine):
    ds, eng = engine
    qs, preds, _ = gen_queries(ds.vectors, ds.cat, ds.num, 5, kinds=("range",), seed=13)
    for i, p in enumerate(preds):
        res = eng.pre_exec.search(qs[i : i + 1], p, k=10)
        truth = eng.ground_truth(qs[i], p, k=10)
        assert recall_at_k(res.ids, truth) == 1.0


def test_plan_overhead_small(engine):
    ds, eng = engine
    qs, preds, _ = gen_queries(ds.vectors, ds.cat, ds.num, 3, kinds=("range",), seed=17)
    r = eng.query(qs[0], preds[0], k=10)
    # paper claims "minimal inference overhead": planning must be a small
    # fraction of total end-to-end time on any non-trivial corpus
    assert r.plan_overhead < 0.05
