"""Checkpointing: round-trip, atomicity, retention, async, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import Checkpointer


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(0, 1, (8, 4)).astype(np.float32)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32)},
    }


def test_round_trip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(3, t)
    out = ck.restore(3, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        ck.save(s, _tree(s))
    assert ck.latest_step() == 4
    assert ck.steps() == [3, 4]  # keep=2


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(7, _tree())
    ck.wait()
    assert ck.latest_step() == 7


def test_atomic_no_torn_checkpoint(tmp_path):
    """A leftover .tmp directory must never be listed as a checkpoint."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert ck.steps() == [1]


def test_restore_missing_leaf_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        ck.restore(1, {"a": jax.ShapeDtypeStruct((3,), jnp.float32),
                       "b": jax.ShapeDtypeStruct((2,), jnp.float32)})


def test_elastic_restore_different_mesh(tmp_path):
    """Save under one mesh sharding, restore under another (elastic)."""
    from repro.dist.elastic import replan_mesh
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    n = len(jax.devices())
    ck = Checkpointer(str(tmp_path))
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, t)

    shape, axes = replan_mesh(n, model_parallel=1)
    mesh = jax.make_mesh(shape, axes)
    shardings = {"w": NamedSharding(mesh, P(None, None))}
    out = ck.restore(1, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t),
                     shardings=shardings)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))


def test_replan_mesh_shapes():
    from repro.dist.elastic import replan_mesh

    assert replan_mesh(512, 16) == ((32, 16), ("data", "model"))
    assert replan_mesh(480, 16) == ((30, 16), ("data", "model"))  # lost a host
    shape, axes = replan_mesh(512, 16, multi_pod=True)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    with pytest.raises(ValueError):
        replan_mesh(8, 16)
