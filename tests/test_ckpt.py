"""Checkpointing: round-trip, atomicity, retention, async, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import Checkpointer


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(0, 1, (8, 4)).astype(np.float32)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32)},
    }


def test_round_trip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(3, t)
    out = ck.restore(3, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        ck.save(s, _tree(s))
    assert ck.latest_step() == 4
    assert ck.steps() == [3, 4]  # keep=2


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(7, _tree())
    ck.wait()
    assert ck.latest_step() == 7


def test_atomic_no_torn_checkpoint(tmp_path):
    """A leftover .tmp directory must never be listed as a checkpoint."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert ck.steps() == [1]


def test_restore_missing_leaf_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        ck.restore(1, {"a": jax.ShapeDtypeStruct((3,), jnp.float32),
                       "b": jax.ShapeDtypeStruct((2,), jnp.float32)})


def test_elastic_restore_different_mesh(tmp_path):
    """Save under one mesh sharding, restore under another (elastic)."""
    from repro.dist.elastic import replan_mesh
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    n = len(jax.devices())
    ck = Checkpointer(str(tmp_path))
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, t)

    shape, axes = replan_mesh(n, model_parallel=1)
    mesh = jax.make_mesh(shape, axes)
    shardings = {"w": NamedSharding(mesh, P(None, None))}
    out = ck.restore(1, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t),
                     shardings=shardings)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))


def test_replan_mesh_shapes():
    from repro.dist.elastic import replan_mesh

    assert replan_mesh(512, 16) == ((32, 16), ("data", "model"))
    assert replan_mesh(480, 16) == ((30, 16), ("data", "model"))  # lost a host
    shape, axes = replan_mesh(512, 16, multi_pod=True)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    with pytest.raises(ValueError):
        replan_mesh(8, 16)


def test_planner_state_through_checkpointer(tmp_path):
    """The planner's state_dict — including the routing subtree with its
    byte-encoded class names — survives a Checkpointer save/restore, and a
    pre-routing checkpoint (no 'route' subtree) restores to a plan-only
    planner (backward compatibility with checkpoints written before the
    backend registry existed)."""
    from repro.core.planner import CorePlanner, PlannerFeatures

    F = PlannerFeatures.N_FEATURES
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (200, F)).astype(np.float32)
    y = (x[:, 3] > 0).astype(np.int32)
    classes = ("flat:exact", "ivf:fast", "ivfpq:precise")
    p = CorePlanner(n_features=F, seed=0).fit(x, y)
    legacy_state = p.state_dict()                      # plan-only
    p.fit_routing(x, np.minimum(y * 2, 2), classes)
    routed_state = p.state_dict()

    ck = Checkpointer(str(tmp_path))
    ck.save(1, legacy_state)
    ck.save(2, routed_state)

    def tmpl(tree):
        return jax.tree.map(
            lambda v: jax.ShapeDtypeStruct(np.asarray(v).shape, np.asarray(v).dtype),
            tree,
        )

    q = CorePlanner(n_features=F, seed=7).load_state(ck.restore(2, tmpl(routed_state)))
    assert q.route_classes == classes
    np.testing.assert_array_equal(q.route(x), p.route(x))
    np.testing.assert_allclose(q.predict_proba(x), p.predict_proba(x), atol=1e-6)

    r = CorePlanner(n_features=F, seed=7).load_state(ck.restore(1, tmpl(legacy_state)))
    assert r.route_classes is None and r.route(x) is None
    np.testing.assert_allclose(r.predict_proba(x), p.predict_proba(x), atol=1e-6)
