"""Per-disjunct DNF planning (the ExecutionPlan API).

The load-bearing guarantees:

* **exact-tier bit-identity** — when every clause of a DNF plan lands on
  an exact strategy (PRE/IPRE), the per-disjunct union is bit-identical
  to the whole-predicate compiled-bitmap path, flat AND sharded AND on a
  dirty live corpus;
* **cross-clause dedup** — a row matching two disjuncts appears once, at
  its best distance (composite-key merge, so ties break like the
  whole-predicate scan);
* **plan structure** — conjunctions plan as single-clause ``merge=none``
  plans (the legacy shape), ``Or`` plans per-disjunct with duplicate
  clauses collapsed, and logically-equal ``Or``s share one cache entry;
* **API surface** — ``SelEstimate`` carries per-clause estimates,
  ``QueryLabel`` is no longer a 4-tuple, ``explain`` renders the plan
  tree, and the feedback loop logs clause-level rows for DNF traffic.
"""
import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    ExecutionPlan,
    FilteredANNEngine,
    INDEXED_PRE,
    LabelEq,
    Or,
    PRE_FILTER,
    Predicate,
    RangePred,
    SelEstimate,
)
from repro.core.selectivity import SelectivityEstimator  # noqa: F401 (API)
from repro.core.trainer import gen_queries
from repro.data import make_dataset
from repro.runtime import (
    FeedbackConfig,
    OnlineFeedback,
    OnlineRuntime,
    RuntimeRequest,
    SchedulerConfig,
    poisson_trace,
)
from repro.serve import ShardedANNEngine

K = 10
EXACT = (PRE_FILTER, INDEXED_PRE)


@pytest.fixture(scope="module")
def ds():
    return make_dataset("arxiv", scale="4000", seed=0)


@pytest.fixture(scope="module")
def eng(ds):
    """Built but UNFITTED: the untrained-planner fallback is deterministic
    (est < 0.05 -> PRE/IPRE), so low-selectivity clauses provably land on
    exact strategies — what the bit-identity tests need."""
    return FilteredANNEngine(
        ds.vectors, ds.cat, ds.num, EngineConfig(n_lists=32, seed=0)
    ).build()


@pytest.fixture(scope="module")
def fitted(ds):
    """A second, trained engine; the fit workload includes DNF queries so
    the per-clause labelling/decomposition path is exercised."""
    e = FilteredANNEngine(
        ds.vectors, ds.cat, ds.num, EngineConfig(n_lists=32, seed=0)
    ).build()
    tq, tp, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 24, kinds=ds.filter_kinds, seed=1
    )
    preds = list(tp) + [Or((tp[0], tp[1])), Or((tp[2], tp[3], tp[2]))]
    qs = list(tq) + [tq[0], tq[2]]
    e.fit(qs, preds, k=K)
    return e


def _low_sel_conjunctions(ds, want=3, lo=0.001, hi=0.04):
    """Label-pair conjunctions with exact (bitmap-covered) selectivity in
    (lo, hi] — under the fallback planner these always plan exact."""
    out = []
    for a in np.unique(ds.cat[:, 0]):
        for b in np.unique(ds.cat[:, 1]):
            p = Predicate(labels=(LabelEq(0, int(a)), LabelEq(1, int(b))))
            if lo < p.selectivity(ds.cat, ds.num) <= hi:
                out.append(p)
                if len(out) == want:
                    return out
    raise RuntimeError("fixture corpus has no low-selectivity label pairs")


# ----------------------------------------------------------------------
# plan structure
# ----------------------------------------------------------------------
def test_conjunction_plans_single_clause(eng, ds):
    p = _low_sel_conjunctions(ds, want=1)[0]
    plan, _ = eng.make_plan(p, K)
    assert isinstance(plan, ExecutionPlan)
    assert plan.merge == "none" and not plan.is_dnf and plan.n_clauses == 1
    assert plan.strategy in ("pre", "post", "ipre")
    assert plan.decision == plan.clauses[0].decision


def test_or_plans_per_disjunct(eng, ds):
    a, b, c = _low_sel_conjunctions(ds, want=3)
    plan, _ = eng.make_plan(Or((a, b, c)), K)
    assert plan.is_dnf and plan.merge == "union" and plan.n_clauses == 3
    assert plan.strategy == "dnf" and plan.backend == "dnf"
    for cl in plan.clauses:
        assert cl.decision in EXACT and cl.sel_exact
    # duplicate disjuncts collapse to one clause; a single-disjunct Or is
    # still a union plan (executes as one clause row)
    dup, _ = eng.make_plan(Or((a, b, a)), K)
    assert dup.n_clauses == 2
    solo, _ = eng.make_plan(Or((a,)), K)
    assert solo.is_dnf and solo.n_clauses == 1
    empty, _ = eng.make_plan(Or(()), K)
    assert empty.is_dnf and empty.n_clauses == 0


def test_permuted_or_shares_cache_entry(eng, ds):
    a, b, c = _low_sel_conjunctions(ds, want=3)
    eng.plan_cache.clear()
    p1, _ = eng.make_plan(Or((a, b, c)), K)
    h0 = eng.plan_cache.stats()["hits"]
    p2, _ = eng.make_plan(Or((c, a, b)), K)   # same canonical key
    assert eng.plan_cache.stats()["hits"] == h0 + 1
    assert p1 is p2
    # execution still aligns terms to clause plans by key, not position
    q = ds.vectors[0]
    r1 = eng.query(q, Or((a, b, c)), K)
    r2 = eng.query(q, Or((c, a, b)), K)
    np.testing.assert_array_equal(r1.result.ids, r2.result.ids)


# ----------------------------------------------------------------------
# exact-tier bit-identity: flat, sharded, live
# ----------------------------------------------------------------------
def test_per_disjunct_bit_identical_flat(eng, ds):
    clauses = _low_sel_conjunctions(ds, want=3)
    dnf = Or(tuple(clauses))
    plan, _ = eng.make_plan(dnf, K)
    assert all(cl.decision in EXACT for cl in plan.clauses)
    rng = np.random.default_rng(7)
    for _ in range(6):
        q = ds.vectors[rng.integers(ds.vectors.shape[0])]
        out = eng.query(q, dnf, K)
        ref = eng.pre_exec.search(q[None], dnf, K)   # whole-predicate bitmap
        np.testing.assert_array_equal(out.result.ids, ref.ids)
        np.testing.assert_array_equal(out.result.dists, ref.dists)
        np.testing.assert_array_equal(out.result.ids, eng.ground_truth(q, dnf, K))


def test_cross_clause_dedup(eng, ds):
    """Overlapping disjuncts: one clause strictly contains the other, so
    every hit of the narrow clause also matches the wide one — each id must
    surface exactly once, and the union must equal the whole-predicate scan."""
    wide = _low_sel_conjunctions(ds, want=1, lo=0.01, hi=0.04)[0]
    x1 = ds.num[:, 1]
    narrow = Predicate(
        labels=wide.labels,
        ranges=(RangePred(1, ((float(np.quantile(x1, 0.1)),
                               float(np.quantile(x1, 0.9))),)),),
    )
    dnf = Or((wide, narrow))
    plan, _ = eng.make_plan(dnf, K)
    assert plan.n_clauses == 2
    assert all(cl.decision in EXACT for cl in plan.clauses)
    rng = np.random.default_rng(11)
    for _ in range(6):
        q = ds.vectors[rng.integers(ds.vectors.shape[0])]
        out = eng.query(q, dnf, K)
        row = out.result.ids[0]
        valid = row[row >= 0]
        assert len(set(valid.tolist())) == len(valid), "duplicate id surfaced"
        ref = eng.pre_exec.search(q[None], dnf, K)
        np.testing.assert_array_equal(out.result.ids, ref.ids)
        np.testing.assert_array_equal(out.result.dists, ref.dists)
    # a literal duplicate clause is the degenerate overlap: Or((p, p)) == p
    p = wide
    r_dup = eng.query(ds.vectors[3], Or((p, p)), K)
    r_solo = eng.query(ds.vectors[3], p, K)
    np.testing.assert_array_equal(r_dup.result.ids, r_solo.result.ids)
    np.testing.assert_array_equal(r_dup.result.dists, r_solo.result.dists)


def test_per_disjunct_bit_identical_sharded(eng, ds):
    clauses = _low_sel_conjunctions(ds, want=3)
    dnf = Or(tuple(clauses))
    sharded = ShardedANNEngine(eng, n_shards=3)
    rng = np.random.default_rng(13)
    qs = ds.vectors[rng.integers(ds.vectors.shape[0], size=4)]
    for q in qs:
        flat = eng.query(q, dnf, K)
        shd = sharded.query(q, dnf, K)
        np.testing.assert_array_equal(shd.result.ids, flat.result.ids)
        np.testing.assert_array_equal(shd.result.dists, flat.result.dists)
    # sharded batch path agrees row-for-row with per-query sharded calls
    mixed = [dnf, clauses[0], dnf, clauses[1]]
    batch = sharded.batch_query(qs, mixed, K)
    for i, r in enumerate(batch):
        solo = sharded.query(qs[i], mixed[i], K)
        np.testing.assert_array_equal(r.result.ids, solo.result.ids)


def test_per_disjunct_bit_identical_live(ds):
    """Dirty live corpus: upserts land in the append segment, deletes
    tombstone base rows — the per-disjunct union must still equal the exact
    live ground truth (label bitmaps stay exact through mutation)."""
    e = FilteredANNEngine(
        ds.vectors, ds.cat, ds.num, EngineConfig(n_lists=32, seed=0)
    ).build()
    clauses = _low_sel_conjunctions(ds, want=2)
    dnf = Or(tuple(clauses))
    rng = np.random.default_rng(17)
    rows = rng.choice(ds.vectors.shape[0], 40, replace=False)
    e.upsert(ds.vectors[rows], ds.cat[rows], ds.num[rows])
    e.delete(np.arange(25))
    assert e.live.dirty
    for i in range(4):
        q = ds.vectors[rng.integers(ds.vectors.shape[0])]
        out = e.query(q, dnf, K)
        np.testing.assert_array_equal(out.result.ids, e.ground_truth(q, dnf, K))


# ----------------------------------------------------------------------
# batch path: identity fast path + mixed-batch equivalence
# ----------------------------------------------------------------------
def test_batch_mixed_dnf_matches_per_query(eng, ds):
    clauses = _low_sel_conjunctions(ds, want=3)
    dnf = Or(tuple(clauses))
    preds = [clauses[0], dnf, clauses[1], Or((clauses[1], clauses[2])), clauses[2]]
    rng = np.random.default_rng(19)
    qs = ds.vectors[rng.integers(ds.vectors.shape[0], size=len(preds))]
    batch = eng.batch_query(qs, preds, K)
    assert len(batch) == len(preds)
    for i, r in enumerate(batch):
        solo = eng.query(qs[i], preds[i], K)
        np.testing.assert_array_equal(r.result.ids, solo.result.ids)
        np.testing.assert_array_equal(r.result.dists, solo.result.dists)
        assert r.plan.strategy == solo.plan.strategy
    assert batch[1].plan.is_dnf and not batch[0].plan.is_dnf
    # pure-conjunction batches take the identity fast path and stay
    # bit-identical to per-query serving (the PR 2 discipline)
    conj_batch = eng.batch_query(qs[:3], clauses, K)
    for i, r in enumerate(conj_batch):
        solo = eng.query(qs[i], clauses[i], K)
        np.testing.assert_array_equal(r.result.ids, solo.result.ids)


# ----------------------------------------------------------------------
# API surface: SelEstimate, QueryLabel, explain
# ----------------------------------------------------------------------
def test_sel_estimate_api(eng, ds):
    a, b, c = _low_sel_conjunctions(ds, want=3)
    se = eng.estimator.estimate(a)
    assert isinstance(se, SelEstimate)
    assert 0.0 <= se.sel <= 1.0 and se.is_exact and se.per_clause is None
    assert float(se) == se.sel
    # Or: per_clause aligns with pred.terms (duplicates included)
    orse = eng.estimator.estimate(Or((a, b, a, c)))
    assert len(orse.per_clause) == 4
    assert orse.per_clause[0].sel == orse.per_clause[2].sel == se.sel
    assert orse.sel == pytest.approx(Or((a, b, c)).selectivity(ds.cat, ds.num))
    # batch agrees with scalar, deprecated aliases agree with both
    ses = eng.estimator.estimate_batch([a, Or((a, b)), c])
    assert all(isinstance(s, SelEstimate) for s in ses)
    assert ses[0].sel == se.sel
    legacy_s, legacy_e = eng.estimator.estimate_ex(a)
    assert (legacy_s, legacy_e) == (se.sel, se.is_exact)
    bs, be = eng.estimator.estimate_batch_ex([a, c])
    assert bs[0] == se.sel and bool(be[0]) == se.is_exact


def test_query_label_no_longer_a_tuple(fitted, ds):
    p = _low_sel_conjunctions(ds, want=1)[0]
    lab = fitted.label_query(ds.vectors[0], p, K)
    with pytest.raises(TypeError):
        iter(lab)                      # the legacy 4-tuple shim is gone
    assert lab.clauses is None
    # DNF labels carry one per-clause race per UNIQUE disjunct
    a, b = _low_sel_conjunctions(ds, want=2)
    dlab = fitted.label_query(ds.vectors[0], Or((a, b, a)), K)
    assert dlab.clauses is not None and len(dlab.clauses) == 2
    assert all(cl.clauses is None for cl in dlab.clauses)


def test_explain_renders_plan_tree(fitted, ds):
    a, b = _low_sel_conjunctions(ds, want=2)
    text = fitted.explain(Or((a, b)), K)
    assert text.startswith("ExecutionPlan merge=union clauses=2")
    assert "clause[0]" in text and "clause[1]" in text
    assert "└─" in text
    conj = fitted.explain(a, K)
    assert conj.startswith("ExecutionPlan merge=none clauses=1")


# ----------------------------------------------------------------------
# runtime integration: telemetry "dnf" dimension + clause-level feedback
# ----------------------------------------------------------------------
def test_runtime_counts_dnf_plans(eng, ds):
    clauses = _low_sel_conjunctions(ds, want=2)
    dnf = Or(tuple(clauses))
    qs, _, _ = gen_queries(ds.vectors, ds.cat, ds.num, 8,
                           kinds=ds.filter_kinds, seed=23)
    trace = poisson_trace(qs, [dnf, clauses[0]], 40, 3000.0, k=K, seed=5)
    rep = OnlineRuntime(eng, SchedulerConfig(max_batch=8)).run_trace(trace)
    counts = rep.telemetry.counters()["plan_counts"]
    assert counts["dnf"] > 0
    assert sum(counts.values()) == 40


def test_feedback_logs_one_row_per_unique_clause(fitted, ds):
    a, b = _low_sel_conjunctions(ds, want=2)
    dnf = Or((a, b, a))
    fb = OnlineFeedback(fitted, FeedbackConfig(sample_rate=1.0, seed=0))
    q = ds.vectors[0]
    res = fitted.query(q, dnf, K)
    req = RuntimeRequest(rid=0, t_arrival=0.0, query=q, pred=dnf, k=K,
                         tier="standard", deadline=1.0)
    assert fb.observe(req, res)
    assert len(fb.log) == 2            # one per UNIQUE disjunct
    plan = res.plan
    by_key = {c.clause_key: c.decision for c in plan.clauses}
    logged = {e.decision for e in fb.log}
    assert logged <= set(by_key.values())
    # a conjunction request still logs exactly one whole-predicate row
    res2 = fitted.query(q, a, K)
    req2 = RuntimeRequest(rid=1, t_arrival=0.0, query=q, pred=a, k=K,
                          tier="standard", deadline=1.0)
    assert fb.observe(req2, res2)
    assert len(fb.log) == 3
