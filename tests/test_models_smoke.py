"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            ks[2], (B, cfg.frontend_len, cfg.d_model), jnp.float32
        )
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.frontend_len, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss NaN/inf"
    logits, _ = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_grad_step(arch):
    """One gradient step: finite grads, params change."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(loss))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grad norm"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode_step after prefill must reproduce teacher-forced logits."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    tokens = batch["tokens"]
    prefix = cfg.frontend_len if cfg.family == "vlm" else 0
    max_len = S + prefix + 8

    # teacher-forced full forward
    tf_logits, _ = model.forward(params, batch)

    # prefill on the first S-1 tokens, then decode token S-1
    pre_batch = dict(batch)
    pre_batch["tokens"] = tokens[:, : S - 1]
    logits_pre, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len)
    )(params, pre_batch)
    # prefill's last-position logits == teacher-forced logits at S-2
    np.testing.assert_allclose(
        np.asarray(logits_pre),
        np.asarray(tf_logits[:, S - 2]),
        rtol=2e-2, atol=2e-2,
    )

    lengths = jnp.full((B,), S - 1 + prefix, jnp.int32)
    logits_dec, cache = jax.jit(model.decode_step)(
        params, cache, tokens[:, S - 1], lengths
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec),
        np.asarray(tf_logits[:, S - 1]),
        rtol=2e-2, atol=2e-2,
    )


def test_param_counts_match_formula():
    """n_params() formula should be within 15% of the real param count on
    reduced configs (it drives MODEL_FLOPS in the roofline)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        real = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        approx = cfg.n_params()
        assert 0.5 < approx / real < 2.0, f"{arch}: formula {approx} vs real {real}"
