"""End-to-end behaviour tests for the paper's system.

The paper's headline claim, miniaturised: a learned per-query planner over
pre-/post-filtering executors achieves >= 90% recall while being no slower
than always picking one fixed strategy — and the whole pipeline
(stats -> estimator -> planner -> executor) holds together end to end.
"""
import numpy as np
import pytest

from repro.core import EngineConfig, FilteredANNEngine, recall_at_k
from repro.core.trainer import gen_queries
from repro.data import make_dataset

pytestmark = pytest.mark.slow  # module-scoped engine build + fit (~minutes)


@pytest.fixture(scope="module")
def system():
    ds = make_dataset("arxiv", scale="12000", seed=0)
    eng = FilteredANNEngine(
        ds.vectors, ds.cat, ds.num, EngineConfig(seed=0)
    ).build()
    tq, tp, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 50, kinds=ds.filter_kinds, seed=1
    )
    eng.fit(tq, tp, k=10)
    return ds, eng


def test_end_to_end_recall_at_90(system):
    """Paper claim: >= 90% recall with the learned planner."""
    ds, eng = system
    qs, preds, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 25, kinds=ds.filter_kinds, seed=5
    )
    recalls, times = [], []
    for i, p in enumerate(preds):
        out = eng.query(qs[i], p, k=10)
        truth = eng.ground_truth(qs[i], p, k=10)
        recalls.append(recall_at_k(out.result.ids, truth))
        times.append(out.result.elapsed)
    assert float(np.mean(recalls)) >= 0.9, f"recall {np.mean(recalls)}"


def test_planner_picks_measured_winner(system):
    """The paper's mechanism, stated contention-robustly: per query, the
    planner should select the strategy that a same-run measurement shows to
    be faster (at matched recall).  Wall-time *sums* are too noisy for CI
    (pre/post differ 5-100x per query, so the per-query winner is stable
    even under load, but absolute times are not)."""
    ds, eng = system
    qs, preds, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 20, kinds=ds.filter_kinds,
        sel_range=(0.01, 0.2), seed=9,
    )
    agree = total = 0
    for i, p in enumerate(preds):
        truth = eng.ground_truth(qs[i], p, k=10)
        out = eng.query(qs[i], p, k=10)
        r1 = eng.pre_exec.search(qs[i][None], p, 10)
        r2 = eng.post_exec.search(
            qs[i][None], p, 10, est_selectivity=out.est_selectivity
        )
        u1 = recall_at_k(r1.ids, truth) / max(r1.elapsed, 1e-7)
        u2 = recall_at_k(r2.ids, truth) / max(r2.elapsed, 1e-7)
        # only count queries where the winner is unambiguous (>=2x apart)
        if max(u1, u2) >= 2 * min(u1, u2):
            total += 1
            winner = 0 if u1 >= u2 else 1
            # INDEXED_PRE is the pre-filter strategy with a cheaper mask:
            # fold it into "pre" for the agreement score
            dec = 0 if out.decision in (0, 2) else 1
            agree += int(dec == winner)
    assert total >= 5, "workload degenerate — no clear winners to score"
    assert agree / total >= 0.6, f"planner agreed on {agree}/{total} clear queries"


def test_results_always_satisfy_predicate(system):
    ds, eng = system
    qs, preds, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 10, kinds=ds.filter_kinds, seed=13
    )
    for i, p in enumerate(preds):
        out = eng.query(qs[i], p, k=10)
        ids = out.result.ids[0]
        ids = ids[ids >= 0]
        assert p.eval(ds.cat[ids], ds.num[ids]).all(), "filter violated"


def test_estimates_track_truth(system):
    ds, eng = system
    qs, preds, sels = gen_queries(
        ds.vectors, ds.cat, ds.num, 20, kinds=ds.filter_kinds, seed=17
    )
    errs = [abs(eng.estimator.estimate(p).sel - s) for p, s in zip(preds, sels)]
    assert float(np.mean(errs)) < 0.05
