"""Distribution layer: sharding rules, compressed collectives, fault hooks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist import (
    FaultEvent,
    HeartbeatMonitor,
    StragglerMitigator,
    compressed_psum,
    param_spec,
    psum_with_error_feedback,
)


# ----------------------------------------------------------------------
# sharding rules
# ----------------------------------------------------------------------
def test_param_spec_column_parallel():
    s = param_spec("layers/attn/wq", (26, 512, 1024), ("data",), "model", 1)
    assert s == P(None, ("data",), "model")


def test_param_spec_row_parallel():
    s = param_spec("layers/attn/wo", (26, 1024, 512), ("data",), "model", 1)
    assert s == P(None, "model", ("data",))
    s = param_spec("layers/ffn/w_down", (26, 2048, 512), ("data",), "model", 1)
    assert s == P(None, "model", ("data",))


def test_param_spec_moe_expert_parallel():
    s = param_spec("layers/ffn/w_gate", (16, 64, 512, 1024), ("data",), "model", 1)
    assert s == P(None, "model", ("data",), None)


def test_param_spec_embed_and_norms():
    assert param_spec("embed", (50304, 512), ("data",), "model", 0) == P("model", None)
    assert param_spec("layers/ln1", (26, 512), ("data",), "model", 1) == P(None, None)
    assert param_spec("layers/mamba/conv", (26, 4, 512), ("data",), "model", 1) == P(
        None, None, None
    )


# ----------------------------------------------------------------------
# compressed collectives (shard_map over available devices)
# ----------------------------------------------------------------------
def _mesh1d():
    n = len(jax.devices())
    return jax.make_mesh((n,), ("d",))


def test_compressed_psum_close_to_exact():
    mesh = _mesh1d()
    n = len(jax.devices())
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (n, 64)).astype(np.float32)

    out = jax.jit(
        jax.shard_map(
            lambda v: compressed_psum(v[0], "d"),
            mesh=mesh, in_specs=P("d"), out_specs=P(),
        )
    )(jnp.asarray(x))
    exact = x.mean(0)
    err = np.abs(np.asarray(out) - exact).max()
    scale = np.abs(x).max() / 127
    assert err <= 2 * scale, f"quantised allreduce error {err} vs lsb {scale}"


def test_error_feedback_reduces_bias():
    """With error feedback, repeated reduction of the SAME gradient converges
    to the true mean (bias is carried, not lost)."""
    mesh = _mesh1d()
    n = len(jax.devices())
    rng = np.random.default_rng(1)
    g = rng.normal(0, 1, (n, 32)).astype(np.float32)
    exact = g.mean(0)

    def run(g, err):
        return psum_with_error_feedback(g[0], err[0], "d")

    f = jax.jit(
        jax.shard_map(run, mesh=mesh, in_specs=(P("d"), P("d")), out_specs=(P(), P("d")))
    )
    err = jnp.zeros((n, 32), jnp.float32)
    acc = np.zeros(32)
    for i in range(8):
        out, err = f(jnp.asarray(g), err)
        acc += np.asarray(out)
    # average of compressed reductions ~ exact mean
    assert np.abs(acc / 8 - exact).max() < 0.02


# ----------------------------------------------------------------------
# fault machinery
# ----------------------------------------------------------------------
def test_heartbeat_detects_dead_host():
    hb = HeartbeatMonitor(n_hosts=4, timeout=10.0)
    now = 1000.0
    for h in range(4):
        hb.beat(h, now=now)
    hb.beat(0, now=now + 50)
    hb.beat(1, now=now + 50)
    hb.beat(2, now=now + 50)
    events = hb.check(step=5, now=now + 50)
    assert [e.host for e in events] == [3]
    assert hb.alive == [0, 1, 2]


def test_straggler_flagging():
    sm = StragglerMitigator(n_hosts=4, threshold=2.0, min_observations=4)
    for step in range(8):
        for h in range(4):
            sm.record(h, 1.0 if h != 2 else 5.0)
    events = sm.check(step=8)
    assert [e.host for e in events] == [2]
    assert not sm.check(step=9)  # flagged once, not repeatedly
