"""int8 KV cache: decode matches the bf16-cache path within quantisation
tolerance, and the cache dtype actually halves."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model


@pytest.mark.parametrize("arch", ["qwen3-14b", "gemma2-2b", "hymba-1.5b"])
def test_int8_cache_decode_close_to_bf16(arch):
    cfg = get_config(arch).reduced()
    cfg8 = dataclasses.replace(cfg, kv_cache_int8=True)
    model = Model(cfg)
    model8 = Model(cfg8)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, : S - 1]}
    max_len = S + 8

    logits_a, cache_a = model.prefill(params, batch, max_len)
    logits_b, cache_b = model8.prefill(params, batch, max_len)
    assert cache_b["k"].dtype == jnp.int8
    assert "k_scale" in cache_b
    np.testing.assert_allclose(
        np.asarray(logits_a), np.asarray(logits_b), rtol=0.1, atol=0.1
    )

    lengths = jnp.full((B,), S - 1, jnp.int32)
    da, _ = model.decode_step(params, cache_a, tokens[:, S - 1], lengths)
    db, cb = model8.decode_step(params, cache_b, tokens[:, S - 1], lengths)
    assert cb["k"].dtype == jnp.int8
    # int8 KV perturbs logits slightly; argmax should survive for most rows
    np.testing.assert_allclose(np.asarray(da), np.asarray(db), rtol=0.2, atol=0.2)


def test_int8_quantize_roundtrip():
    from repro.models.layers import dequantize_kv, quantize_kv

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 2, (4, 8, 128)).astype(np.float32))
    q, s = quantize_kv(x)
    back = dequantize_kv(q, s)
    err = np.abs(np.asarray(back) - np.asarray(x)).max()
    lsb = float(np.abs(np.asarray(x)).max()) / 127
    assert err <= lsb + 1e-6
