"""Fast unit tests for the core paper components."""
import numpy as np
import pytest

from repro.core import (
    DatasetStats,
    GradientBoostingRegressor,
    LabelEq,
    Predicate,
    RangePred,
    SelectivityEstimator,
)
from repro.core.stats import Histogram
from repro.core.trainer import gen_queries
from repro.data import make_dataset


@pytest.fixture(scope="module")
def tiny():
    ds = make_dataset("arxiv", scale="4000", seed=1)
    stats = DatasetStats.build(ds.vectors, ds.cat, ds.num, sample_frac=0.05, seed=0)
    return ds, stats


def test_predicate_eval_shapes(tiny):
    ds, _ = tiny
    p = Predicate(labels=(LabelEq(0, 0),))
    m = p.eval(ds.cat, ds.num)
    assert m.shape == (ds.n,) and m.dtype == bool


def test_single_label_selectivity_exact(tiny):
    ds, stats = tiny
    for code in range(3):
        p = Predicate(labels=(LabelEq(0, code),))
        true = p.selectivity(ds.cat, ds.num)
        est = SelectivityEstimator(stats).estimate(p).sel
        assert abs(est - true) < 1e-9, "single-label lookup must be exact"


def test_pair_label_selectivity_exact(tiny):
    ds, stats = tiny
    p = Predicate(labels=(LabelEq(0, 0), LabelEq(1, 0)))
    true = p.selectivity(ds.cat, ds.num)
    est = SelectivityEstimator(stats).estimate(p).sel
    assert abs(est - true) < 1e-9, "two-label co-occurrence lookup must be exact"


def test_histogram_range_selectivity(tiny):
    ds, stats = tiny
    x = ds.num[:, 0]
    lo, hi = float(np.quantile(x, 0.3)), float(np.quantile(x, 0.5))
    p = Predicate(ranges=(RangePred(0, ((lo, hi),)),))
    true = p.selectivity(ds.cat, ds.num)
    est = SelectivityEstimator(stats).estimate(p).sel
    assert abs(est - true) < 0.02, f"hist est {est} vs true {true}"


def test_histogram_partial_bins():
    x = np.linspace(0.0, 1.0, 10_001)
    h = Histogram.build(x, bins=16)
    # a range covering exactly 1.5 bins starting mid-bin
    sel = h.selectivity([(1.0 / 32, 1.0 / 32 + 3.0 / 32)])
    assert abs(sel - 3.0 / 32) < 5e-3


def test_multi_range_union(tiny):
    ds, stats = tiny
    x = ds.num[:, 0]
    q = np.quantile(x, [0.1, 0.2, 0.6, 0.7])
    p = Predicate(ranges=(RangePred(0, ((float(q[0]), float(q[1])), (float(q[2]), float(q[3])))),))
    true = p.selectivity(ds.cat, ds.num)
    est = SelectivityEstimator(stats).estimate(p).sel
    assert abs(est - true) < 0.03


def test_gbm_learns_nonlinear():
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, size=(800, 3))
    y = x[:, 0] ** 2 + 0.5 * np.sin(3 * x[:, 1]) - 0.3 * x[:, 2]
    m = GradientBoostingRegressor(n_estimators=150).fit(x, y)
    pred = m.predict(x)
    mse = float(((pred - y) ** 2).mean())
    assert mse < 0.02, f"GBM underfit: mse={mse}"


def test_mixed_estimator_with_gbm(tiny):
    ds, stats = tiny
    qs, preds, sels = gen_queries(
        ds.vectors, ds.cat, ds.num, 120, kinds=("mixed", "label"), seed=3
    )
    est = SelectivityEstimator(stats).fit(preds[:100], sels[:100])
    errs = [abs(est.estimate(p).sel - s) for p, s in zip(preds[100:], sels[100:])]
    assert float(np.mean(errs)) < 0.08, f"mean abs err {np.mean(errs)}"


def test_pmi_sign(tiny):
    _, stats = tiny
    # PMI of a label with itself is strongly positive (P(x,x)=P(x) > P(x)^2)
    lbl = int(np.argmax(stats.label_freq))
    assert stats.pmi(lbl, lbl) > 0
