"""Sharded ANN serving path: fan-out/merge exactness vs the unsharded engine."""
import numpy as np
import pytest

from repro.core import (
    EngineConfig, FilteredANNEngine, LabelEq, Not, Or, Predicate, RangePred,
)
from repro.core.trainer import gen_queries
from repro.data import make_dataset
from repro.dist import merge_topk
from repro.serve import ShardedANNEngine


@pytest.fixture(scope="module")
def small_system():
    ds = make_dataset("arxiv", scale="2000", seed=0)
    eng = FilteredANNEngine(
        ds.vectors, ds.cat, ds.num, EngineConfig(seed=0)
    ).build()
    tq, tp, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 8, kinds=ds.filter_kinds, seed=1
    )
    return ds, eng, tq, tp


def test_merge_topk_matches_bruteforce():
    rng = np.random.default_rng(3)
    b, n, k, n_shards = 5, 512, 10, 4
    d_all = rng.normal(0, 1, (b, n)).astype(np.float32) ** 2
    parts = np.array_split(np.arange(n), n_shards)
    sd, si = [], []
    rows = np.arange(b)[:, None]
    for ids in parts:
        order = np.argsort(d_all[:, ids], axis=1)[:, :k]
        sd.append(d_all[:, ids][rows, order])
        si.append(ids[order].astype(np.int32))
    md, mi = merge_topk(np.stack(sd), np.stack(si), k)
    np.testing.assert_allclose(md, np.sort(d_all, axis=1)[:, :k])
    assert (mi >= 0).all()


def test_merge_topk_padding():
    # one shard fully padded, one with 2 valid of 3
    d = np.array([[[1.0, np.inf, np.inf]], [[np.inf, 2.0, 3.0]]], np.float32)
    i = np.array([[[7, -1, -1]], [[-1, 9, 11]]], np.int32)
    md, mi = merge_topk(d, i, 4)
    assert mi[0].tolist() == [7, 9, 11, -1]
    assert md[0][:3].tolist() == [1.0, 2.0, 3.0] and np.isinf(md[0][3])
    # k beyond the total candidate columns still returns (B, k), padded
    md, mi = merge_topk(d, i, 10)
    assert mi.shape == (1, 10) and md.shape == (1, 10)
    assert mi[0].tolist() == [7, 9, 11] + [-1] * 7


def test_sharded_matches_unsharded(small_system):
    ds, eng, tq, tp = small_system
    sharded = ShardedANNEngine(eng, n_shards=4)
    for i in range(len(tp)):
        r0 = eng.query(tq[i], tp[i], k=10)
        r1 = sharded.query(tq[i], tp[i], k=10)
        assert r0.decision == r1.decision
        gt = set(eng.ground_truth(tq[i], tp[i], k=10)[0].tolist()) - {-1}
        got = set(r1.result.ids[0].tolist()) - {-1}
        if r0.decision in (0, 2):
            # PRE_FILTER / INDEXED_PRE are exact on both paths: must equal
            # ground truth
            assert got == set(r0.result.ids[0].tolist()) - {-1} == gt
        else:
            # POST_FILTER probes different candidate sets per shard, so the
            # sets may legitimately differ from the unsharded path; require
            # strong ground-truth recall rather than id equality
            assert len(gt & got) >= 0.8 * len(gt)


def test_sharded_results_satisfy_predicate(small_system):
    ds, eng, tq, tp = small_system
    sharded = ShardedANNEngine(eng, n_shards=3)
    for i in range(len(tp)):
        ids = sharded.query(tq[i], tp[i], k=10).result.ids
        ids = ids[ids >= 0]
        assert tp[i].eval(ds.cat[ids], ds.num[ids]).all()


def test_sharded_dnf_smoke(small_system):
    """Satellite: the sharded path accepts the full DNF class
    (``AnyPredicate``) end-to-end — `Or` of conjunctions with a negated
    leaf plans once, fans out, merges, and every path agrees."""
    ds, eng, tq, tp = small_system
    lo = float(np.quantile(ds.num[:, 0], 0.3))
    hi = float(np.quantile(ds.num[:, 0], 0.6))
    dnf = Or((
        Predicate(labels=(LabelEq(0, int(ds.cat[0, 0])),)),
        Predicate(ranges=(RangePred(0, ((lo, hi),)),),
                  nots=(Not(LabelEq(1, int(ds.cat[1, 1]))),)),
    ))
    sharded = ShardedANNEngine(eng, n_shards=3)
    single = sharded.query(tq[0], dnf, k=10)
    flat = eng.query(tq[0], dnf, k=10)
    assert single.decision == flat.decision
    ids = single.result.ids[single.result.ids >= 0]
    assert ids.size > 0
    assert dnf.eval(ds.cat[ids], ds.num[ids]).all()
    if single.decision in (0, 2):       # exact plans: sharded == flat ids
        assert np.array_equal(single.result.ids, flat.result.ids)
    # batched sharded path agrees row-for-row with per-query sharded calls
    batch = sharded.batch_query(tq[:4], [dnf] * 4, k=10)
    for i, r in enumerate(batch):
        solo = sharded.query(tq[i], dnf, k=10)
        assert r.decision == solo.decision
        assert np.array_equal(r.result.ids, solo.result.ids)


def test_sharded_empty_predicate_and_tiny_shards(small_system):
    ds, eng, tq, tp = small_system
    nothing = Predicate(labels=(), ranges=(RangePred(attr=0, intervals=((1e9, 2e9),)),))
    sharded = ShardedANNEngine(eng, n_shards=2)
    r = sharded.query(tq[0], nothing, k=5)
    assert (r.result.ids == -1).all() and np.isinf(r.result.dists).all()
    # more shards than rows must not crash shard construction (empty shards
    # dropped, per-shard IVF lists clamped to the shard size); build_stats
    # is the planning-only path sharded deployments use
    few = FilteredANNEngine(
        ds.vectors[:10], ds.cat[:10], ds.num[:10],
        EngineConfig(seed=0, sample_frac=1.0),
    ).build_stats()
    tiny = ShardedANNEngine(few, n_shards=16)
    assert 0 < len(tiny.shards) <= 10
    assert sum(s.ids.size for s in tiny.shards) == 10
    r = tiny.query(tq[0], tp[0], k=3)
    assert r.result.ids.shape == (1, 3)


def test_dead_shard_detection_replans_and_merge_stays_exact(small_system):
    """Satellite: dist.fault + dist.elastic under the SERVING path.  A
    shard that stops heartbeating mid-trace is flagged by the monitor,
    ``replan_mesh`` validates the survivor mesh, ``reshard`` repartitions
    the live deployment — and the merged top-k over the survivors stays
    bit-identical to the flat engine for exact plans."""
    from repro.dist import HeartbeatMonitor, replan_mesh

    ds, eng, tq, tp = small_system
    sharded = ShardedANNEngine(eng, n_shards=4)
    exact = [(q, p, r) for q, p in zip(tq, tp)
             if (r := eng.query(q, p, k=10)).decision in (0, 2)]
    assert exact, "fixture must include at least one exact-plan query"

    hb = HeartbeatMonitor(n_hosts=4, timeout=0.05)
    dead_shard = 2
    now = 0.0
    events = []
    for step in range(12):                     # virtual serving loop
        now += 0.01
        for si in range(4):
            if si == dead_shard and step >= 4:
                continue                       # shard dies mid-trace
            hb.beat(si, now)
        events += hb.check(step, now)
        # the serving path keeps answering while the shard is dying
        q, p, _ = exact[step % len(exact)]
        sharded.query(q, p, k=10)
    assert [e.kind for e in events] == ["dead_host"]
    assert events[0].host == dead_shard

    survivors = len(hb.alive)
    assert survivors == 3
    shape, axes = replan_mesh(survivors, model_parallel=1)
    assert shape == (3, 1) and axes == ("data", "model")
    sharded.reshard(survivors)
    assert len(sharded.shards) == 3
    for q, p, flat in exact:
        merged = sharded.query(q, p, k=10)
        assert merged.decision == flat.decision
        assert np.array_equal(merged.result.ids, flat.result.ids)
