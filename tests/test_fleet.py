"""repro.fleet: collections, admission, fair-share batching, autoscaling.

Load-bearing guarantees:

* **replay determinism** — the virtual/real split survives multi-tenancy:
  same multi-tenant trace + seed => identical per-tenant batch
  compositions, result ids, telemetry counters, admission rejects, and
  scale events across runs;
* **isolation** — per-tenant engines mean partitioned predicate/plan
  caches; DRR batch formation honours fair-share weights no matter how
  deep a noisy tenant's backlog is;
* **admission** — over-budget queries shed deterministically by rid,
  tenants inside their budget are never rejected, writes always pass;
* **elasticity** — sustained SLO pressure grows a tenant's shard
  assignment through ``replan_mesh`` (and shrinks it back), dead shards
  recover onto the survivors, and results stay exact throughout.
"""
import numpy as np
import pytest

from repro.core import EngineConfig, FilteredANNEngine
from repro.core.trainer import gen_queries
from repro.data import make_dataset
from repro.fleet import (
    AdmissionController,
    AutoscaleConfig,
    CollectionSchema,
    FaultInjection,
    FieldSpec,
    Fleet,
    FleetConfig,
    FleetRuntime,
    TenantCollection,
    TokenBucket,
)
from repro.runtime import RuntimeRequest, TenantTraceSpec, multi_tenant_trace
from repro.runtime.queue import RequestQueue

K = 10
SCALE = "2000"


def _tenant_data(seed):
    ds = make_dataset("arxiv", scale=SCALE, seed=seed)
    qs, preds, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 8, kinds=ds.filter_kinds,
        sel_range=(0.01, 0.4), seed=seed + 1,
    )
    return ds, qs, list(preds)


@pytest.fixture(scope="module")
def fleet_system():
    """Two tenants with different schemas/tiers/weights + their workloads."""
    fleet = Fleet(total_shards=6)
    data = {}
    for name, tier, weight, n_shards, seed in [
        ("alpha", "interactive", 2.0, 1, 0),
        ("beta", "standard", 1.0, 2, 3),
    ]:
        ds, qs, preds = _tenant_data(seed)
        schema = CollectionSchema(
            name=name, dim=ds.vectors.shape[1], slo_tier=tier, weight=weight,
            n_shards=n_shards,
            fields=(FieldSpec("cat0", "tag"),) * ds.cat.shape[1]
            if ds.cat.shape[1] == 1 else (),
        )
        fleet.create(schema, ds.vectors, ds.cat, ds.num,
                     config=EngineConfig(n_lists=16, seed=0))
        data[name] = (ds, qs, preds)
    return fleet, data


def _specs(data, n=120, rates=(1500.0, 1500.0), kinds=("poisson", "bursty")):
    return [
        TenantTraceSpec(name, qs, preds, n, rate, kind=kind, k=K)
        for (name, (_, qs, preds)), rate, kind
        in zip(data.items(), rates, kinds)
    ]


# ----------------------------------------------------------------------
# schemas + collections
# ----------------------------------------------------------------------
def test_schema_validation():
    with pytest.raises(ValueError):
        CollectionSchema(name="", dim=8)
    with pytest.raises(ValueError):
        CollectionSchema(name="x", dim=8, slo_tier="platinum")
    with pytest.raises(ValueError):
        CollectionSchema(name="x", dim=8, weight=0.0)
    with pytest.raises(ValueError):
        CollectionSchema(name="x", dim=8, n_shards=0)
    with pytest.raises(ValueError):
        FieldSpec("f", "geo")


def test_schema_from_dict_redisvl_idiom():
    s = CollectionSchema.from_dict({
        "index": {"name": "products", "slo_tier": "interactive", "weight": 2.0},
        "fields": [
            {"name": "embedding", "type": "vector", "attrs": {"dims": 64}},
            {"name": "brand", "type": "tag"},
            {"name": "price", "type": "numeric"},
        ],
    })
    assert s.name == "products" and s.dim == 64
    assert s.tag_fields == ("brand",) and s.numeric_fields == ("price",)
    assert s.slo_tier == "interactive" and s.weight == 2.0


def test_schema_rejects_mismatched_corpus():
    ds, _, _ = _tenant_data(0)
    s = CollectionSchema(name="x", dim=ds.vectors.shape[1] + 1)
    with pytest.raises(ValueError):
        s.validate_rows(ds.vectors, ds.cat, ds.num)
    s2 = CollectionSchema(
        name="x", dim=ds.vectors.shape[1],
        fields=tuple(FieldSpec(f"t{i}", "tag") for i in range(ds.cat.shape[1] + 2)),
    )
    with pytest.raises(ValueError):
        s2.validate_rows(ds.vectors, ds.cat, ds.num)


def test_fleet_registry_and_budget(fleet_system):
    fleet, data = fleet_system
    assert fleet.names() == ["alpha", "beta"]
    assert "alpha" in fleet and len(fleet) == 2
    assert fleet.shards_in_use == 3
    ds, _, _ = _tenant_data(0)
    with pytest.raises(ValueError):      # duplicate name
        fleet.create(CollectionSchema(name="alpha", dim=ds.vectors.shape[1]),
                     ds.vectors, ds.cat, ds.num)
    with pytest.raises(ValueError):      # would exceed the shard budget
        fleet.create(
            CollectionSchema(name="gamma", dim=ds.vectors.shape[1], n_shards=4),
            ds.vectors, ds.cat, ds.num)


def test_partitioned_caches(fleet_system):
    """One tenant's traffic warms ONLY its own plan/predicate caches."""
    fleet, data = fleet_system
    _, qs, preds = data["alpha"]
    a0 = fleet["alpha"].stats()["plan_cache"]["hits"]
    b0 = fleet["beta"].stats()["plan_cache"]["hits"]
    for _ in range(3):
        fleet["alpha"].batch_query(qs[:4], preds[:4], k=K)
    assert fleet["alpha"].stats()["plan_cache"]["hits"] > a0
    assert fleet["beta"].stats()["plan_cache"]["hits"] == b0


# ----------------------------------------------------------------------
# multi-tenant traces
# ----------------------------------------------------------------------
def test_multi_tenant_trace_shape_and_determinism(fleet_system):
    _, data = fleet_system
    a = multi_tenant_trace(_specs(data), seed=7)
    b = multi_tenant_trace(_specs(data), seed=7)
    assert [r.rid for r in a] == list(range(len(a)))          # dense rids
    assert [(r.t_arrival, r.tenant) for r in a] == \
           [(r.t_arrival, r.tenant) for r in b]
    assert sorted(set(r.tenant for r in a)) == ["alpha", "beta"]
    ts = [r.t_arrival for r in a]
    assert ts == sorted(ts)
    c = multi_tenant_trace(_specs(data), seed=8)
    assert [r.t_arrival for r in a] != [r.t_arrival for r in c]
    with pytest.raises(ValueError):
        multi_tenant_trace([])
    dup = _specs(data)
    dup[1] = TenantTraceSpec("alpha", dup[1].queries, dup[1].preds, 10, 100.0)
    with pytest.raises(ValueError):
        multi_tenant_trace(dup)


# ----------------------------------------------------------------------
# admission control
# ----------------------------------------------------------------------
def test_token_bucket_refill_and_burst():
    b = TokenBucket(rate=10.0, burst=2.0)
    assert b.try_take(0.0) and b.try_take(0.0)
    assert not b.try_take(0.0)            # burst exhausted
    assert b.try_take(0.1)                # 0.1s * 10/s = 1 token back
    assert not b.try_take(0.1)
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0, burst=1.0)


def test_admission_sheds_deterministically_and_writes_pass():
    ctrl = AdmissionController({"noisy": (100.0, 5.0)})

    def q(rid, t, tenant):
        return RuntimeRequest(rid=rid, t_arrival=t, query=None, pred=None,
                              k=K, tenant=tenant)

    out1 = [ctrl.admit(q(i, i * 0.001, "noisy")) for i in range(50)]
    ctrl.reset()
    out2 = [ctrl.admit(q(i, i * 0.001, "noisy")) for i in range(50)]
    assert out1 == out2                   # pure function of the trace
    assert not all(out1) and any(out1)    # bucket bites past the burst
    ctrl.reset()
    # un-budgeted tenants and writes always pass
    assert ctrl.admit(q(0, 0.0, "quiet"))
    w = RuntimeRequest(rid=1, t_arrival=0.0, query=None, pred=None, k=K,
                       op="upsert", payload=(None,), tenant="noisy")
    for _ in range(20):
        assert ctrl.admit(w)
    assert ctrl.counters()["rejected"] == {}


# ----------------------------------------------------------------------
# fair-share batching
# ----------------------------------------------------------------------
def test_drr_honours_weights(fleet_system):
    """Saturated backlogs: batch slots split ~weight-proportionally
    (alpha weight 2.0 vs beta 1.0)."""
    fleet, data = fleet_system
    rt = FleetRuntime(fleet)
    queues = {n: RequestQueue() for n in fleet.names()}
    for name in fleet.names():
        _, qs, preds = data[name]
        for i in range(100):
            queues[name].push(RuntimeRequest(
                rid=i if name == "alpha" else 1000 + i, t_arrival=0.0,
                query=qs[i % len(qs)], pred=preds[i % len(preds)], k=K,
                tenant=name))
    deficit = {n: 0.0 for n in fleet.names()}
    batch = rt._drr_batch(queues, deficit, 30)
    share = {n: sum(r.tenant == n for r in batch) for n in fleet.names()}
    assert len(batch) == 30
    assert share["alpha"] == 20 and share["beta"] == 10


def test_drr_drains_fully_when_one_queue_empties(fleet_system):
    fleet, data = fleet_system
    rt = FleetRuntime(fleet)
    queues = {n: RequestQueue() for n in fleet.names()}
    _, qs, preds = data["beta"]
    for i in range(10):
        queues["beta"].push(RuntimeRequest(
            rid=i, t_arrival=0.0, query=qs[0], pred=preds[0], k=K,
            tenant="beta"))
    batch = rt._drr_batch(queues, {n: 0.0 for n in fleet.names()}, 32)
    assert len(batch) == 10               # no slots wasted on the empty queue


def test_fleet_replay_bit_identical(fleet_system):
    """The tentpole guarantee: admission + DRR + autoscale, two runs,
    identical batches / rejects / ids / counters / scale events."""
    fleet, data = fleet_system
    trace = multi_tenant_trace(_specs(data, n=150, rates=(2500.0, 2500.0)),
                               seed=11)
    adm = AdmissionController.for_fleet(fleet, default_rate=2000.0)
    rt = FleetRuntime(
        fleet, FleetConfig(max_batch=32), admission=adm,
        autoscale=AutoscaleConfig(eval_every=0.02, cooldown=0.05, min_window=8))
    r1 = rt.run_trace(trace)
    r2 = rt.run_trace(trace)
    assert r1.batches == r2.batches
    assert r1.rejected == r2.rejected
    assert r1.telemetry.counters() == r2.telemetry.counters()
    assert [e.as_dict() for e in r1.scale_events] == \
           [e.as_dict() for e in r2.scale_events]
    for rid in r1.results:
        assert (r1.ids(rid) == r2.ids(rid)).all()


def _assert_matches_flat(fleet, req, res):
    """The sharded-path contract: exact plans (PRE_FILTER/INDEXED_PRE)
    merge bit-identical to the tenant's flat engine; POST_FILTER probes
    per-shard candidate sets (recall legitimately varies with the live
    shard count), so require every returned id to satisfy the predicate."""
    eng = fleet[req.tenant].engine
    if res.decision in (0, 2):
        flat = eng.query(req.query, req.pred, k=req.k)
        assert (res.result.ids[0] == flat.result.ids[0]).all()
    else:
        ids = res.result.ids[res.result.ids >= 0]
        if ids.size:
            cat, num = eng.live.row_attrs(ids)
            assert req.pred.eval(cat, num).all()


def test_fleet_results_exact_vs_flat_engine(fleet_system):
    """Per-tenant serving (through any autoscale resharding) keeps the
    sharded exactness contract against each tenant's own flat engine."""
    fleet, data = fleet_system
    trace = multi_tenant_trace(_specs(data, n=80), seed=13)
    rt = FleetRuntime(fleet, FleetConfig(max_batch=32),
                      autoscale=AutoscaleConfig(eval_every=0.02, cooldown=0.05,
                                                min_window=8))
    rep = rt.run_trace(trace)
    by_rid = {r.rid: r for r in trace}
    for rid, res in rep.results.items():
        _assert_matches_flat(fleet, by_rid[rid], res)


def test_shared_baseline_differs_and_loses_isolation(fleet_system):
    fleet, data = fleet_system
    trace = multi_tenant_trace(_specs(data, n=150, rates=(4000.0, 800.0)),
                               seed=17)
    fair = FleetRuntime(fleet, FleetConfig(max_batch=32)).run_trace(trace)
    shared = FleetRuntime(
        fleet, FleetConfig(max_batch=32, fair=False)).run_trace(trace)
    # both replay deterministically, but compositions differ
    assert fair.batches != shared.batches
    assert shared.telemetry.counters() == FleetRuntime(
        fleet, FleetConfig(max_batch=32, fair=False)
    ).run_trace(trace).telemetry.counters()


# ----------------------------------------------------------------------
# autoscaling
# ----------------------------------------------------------------------
def test_autoscale_grow_under_overload_and_budget_cap(fleet_system):
    fleet, data = fleet_system
    _, qs, preds = data["alpha"]
    specs = [TenantTraceSpec("alpha", qs, preds, 400, 5000.0, k=K,
                             tier_mix={"interactive": 1.0})]
    trace = multi_tenant_trace(specs, seed=19)
    rt = FleetRuntime(
        fleet, FleetConfig(max_batch=32),
        autoscale=AutoscaleConfig(eval_every=0.01, cooldown=0.0, min_window=8,
                                  grow_miss_rate=0.1))
    rep = rt.run_trace(trace)
    grows = [e for e in rep.scale_events if e.action == "grow"]
    assert grows, "sustained interactive overload must trigger a grow"
    assert grows[0].tenant == "alpha"
    assert grows[0].to_shards == grows[0].from_shards + 1
    assert grows[0].mesh == (grows[0].to_shards, 1)       # replan_mesh shape
    # the fleet budget is a hard cap
    assert max(e.to_shards for e in grows) + fleet["beta"].schema.n_shards \
        <= fleet.total_shards
    fleet.reset_shards()


def test_autoscale_shrink_when_idle(fleet_system):
    fleet, data = fleet_system
    _, qs, preds = data["beta"]
    specs = [TenantTraceSpec("beta", qs, preds, 150, 400.0, k=K,
                             tier_mix={"batch": 1.0})]
    rt = FleetRuntime(
        fleet, FleetConfig(max_batch=32),
        autoscale=AutoscaleConfig(eval_every=0.02, cooldown=0.0, min_window=8))
    rep = rt.run_trace(multi_tenant_trace(specs, seed=23))
    shrinks = [e for e in rep.scale_events
               if e.action == "shrink" and e.tenant == "beta"]
    assert shrinks, "an idle 2-shard tenant must release capacity"
    assert shrinks[0].from_shards == 2 and shrinks[0].to_shards == 1
    fleet.reset_shards()


def test_dead_shard_recovery_keeps_results_exact(fleet_system):
    """FaultInjection kills a shard mid-trace: the heartbeat monitor flags
    it, the tenant reshards onto survivors via replan_mesh, and every
    result before AND after still matches the flat engine."""
    fleet, data = fleet_system
    _, qs, preds = data["beta"]
    specs = [TenantTraceSpec("beta", qs, preds, 200, 2000.0, k=K)]
    trace = multi_tenant_trace(specs, seed=29)
    t_mid = trace.requests[len(trace.requests) // 2].t_arrival
    rt = FleetRuntime(
        fleet, FleetConfig(max_batch=32),
        autoscale=AutoscaleConfig(eval_every=0.02, cooldown=0.0,
                                  min_window=10**9,        # SLO policy off:
                                  heartbeat_timeout=0.02),  # isolate recovery
        faults=[FaultInjection(t=t_mid, tenant="beta", shard=1)])
    rep = rt.run_trace(trace)
    recoveries = [e for e in rep.scale_events if e.action == "recover"]
    assert recoveries and recoveries[0].tenant == "beta"
    assert recoveries[0].to_shards == recoveries[0].from_shards - 1
    assert recoveries[0].mesh == (recoveries[0].to_shards, 1)
    assert recoveries[0].t > t_mid                        # flagged after death
    by_rid = {r.rid: r for r in trace}
    for rid, res in rep.results.items():
        _assert_matches_flat(fleet, by_rid[rid], res)
    fleet.reset_shards()


# ----------------------------------------------------------------------
# fleet manifest checkpointing
# ----------------------------------------------------------------------
def test_fleet_manifest_save_restore(tmp_path):
    from repro.ckpt import Checkpointer

    fleet = Fleet(total_shards=4)
    datasets = {}
    for name, seed in [("a", 0), ("b", 3)]:
        ds, qs, preds = _tenant_data(seed)
        datasets[name] = (ds, qs, preds)
        fleet.create(
            CollectionSchema(name=name, dim=ds.vectors.shape[1], n_shards=2),
            ds.vectors, ds.cat, ds.num, config=EngineConfig(n_lists=16, seed=0))
    # mutate tenant "a" only: upsert 5 rows, delete 3
    ds_a = datasets["a"][0]
    gids = fleet["a"].upsert(ds_a.vectors[:5], ds_a.cat[:5], ds_a.num[:5])
    fleet["a"].delete(np.asarray([1, 2, int(gids[0])]))
    fleet["a"].reshard(1)                 # manifest captures the live count
    ckpt = Checkpointer(str(tmp_path), keep=2)
    fleet.save(ckpt, step=7)

    meta = ckpt.latest_meta()["fleet"]
    assert meta["tenants"]["a"]["n_shards"] == 1
    assert meta["tenants"]["b"]["corpus_generation"] == 0
    assert meta["tenants"]["a"]["corpus_generation"] > 0

    # restore onto a freshly built fleet over the same base corpora
    fleet2 = Fleet(total_shards=4)
    for name in ("a", "b"):
        ds = datasets[name][0]
        fleet2.create(
            CollectionSchema(name=name, dim=ds.vectors.shape[1], n_shards=2),
            ds.vectors, ds.cat, ds.num, config=EngineConfig(n_lists=16, seed=0))
    fleet2.restore(ckpt)
    assert fleet2["a"].engine.live.n_total == fleet["a"].engine.live.n_total
    assert fleet2["a"].engine.live.live_count == fleet["a"].engine.live.live_count
    assert fleet2["a"].n_shards == 1      # manifest shard assignment reapplied
    _, qs, preds = datasets["a"]
    r1 = fleet["a"].query(qs[0], preds[0], k=K)
    r2 = fleet2["a"].query(qs[0], preds[0], k=K)
    assert (r1.result.ids[0] == r2.result.ids[0]).all()
    missing = Fleet(total_shards=4)
    ds, _, _ = _tenant_data(5)
    missing.create(CollectionSchema(name="zz", dim=ds.vectors.shape[1]),
                   ds.vectors, ds.cat, ds.num,
                   config=EngineConfig(n_lists=16, seed=0))
    with pytest.raises(ValueError):
        missing.restore(ckpt)


def test_reshard_preserves_live_state(fleet_system):
    """reshard() on a mutated engine re-places segment rows + tombstones."""
    _, data = fleet_system
    ds, qs, preds = data["beta"]
    eng = FilteredANNEngine(
        ds.vectors, ds.cat, ds.num,
        EngineConfig(n_lists=16, seed=0, max_tombstone_frac=0.9,
                     max_segment_frac=0.9),
    ).build()
    col = TenantCollection(
        CollectionSchema(name="solo", dim=ds.vectors.shape[1], n_shards=2), eng)
    gids = col.upsert(ds.vectors[:7], ds.cat[:7], ds.num[:7])
    col.delete(np.asarray([0, 5, int(gids[2])]))
    flat = [eng.query(q, p, k=K) for q, p in zip(qs, preds)]
    for n in (3, 1, 4):
        col.reshard(n)
        assert col.n_shards == n
        for q, p, f in zip(qs, preds, flat):
            got = col.query(q, p, k=K)
            if f.decision in (0, 2):    # exact plans: reshard is invisible
                assert (got.result.ids[0] == f.result.ids[0]).all()
            else:
                ids = got.result.ids[got.result.ids >= 0]
                if ids.size:
                    cat, num = eng.live.row_attrs(ids)
                    assert p.eval(cat, num).all()
    with pytest.raises(ValueError):
        col.reshard(0)
