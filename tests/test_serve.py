"""Serving engine + RAG retrieval integration tests."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import EngineConfig, FilteredANNEngine, Predicate, RangePred
from repro.core.trainer import gen_queries
from repro.data import make_dataset
from repro.models import Model
from repro.serve import Request, ServeEngine, RetrievalAugmentedServer


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen3-14b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_serve_engine_generates(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                max_new_tokens=5)
        for i in range(5)
    ]
    eng = ServeEngine(model, params, batch_slots=2, max_len=32)
    results = eng.run(reqs)
    assert set(results) == {0, 1, 2, 3, 4}
    assert all(len(v) == 5 for v in results.values())
    assert all(0 <= t < cfg.vocab_size for v in results.values() for t in v)


def test_serve_greedy_deterministic(small_model):
    cfg, model, params = small_model
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    out1 = ServeEngine(model, params, batch_slots=1, max_len=32).run(
        [Request(uid=0, prompt=prompt, max_new_tokens=6)]
    )
    out2 = ServeEngine(model, params, batch_slots=1, max_len=32).run(
        [Request(uid=0, prompt=prompt, max_new_tokens=6)]
    )
    assert out1[0] == out2[0]


def test_serve_matches_teacher_forced(small_model):
    """Greedy generation equals repeated argmax over teacher-forced logits."""
    import jax.numpy as jnp

    cfg, model, params = small_model
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    gen = ServeEngine(model, params, batch_slots=1, max_len=32).run(
        [Request(uid=0, prompt=prompt, max_new_tokens=4)]
    )[0]
    toks = list(prompt)
    for expected in gen:
        batch = {"tokens": jnp.asarray(np.asarray(toks, np.int32))[None]}
        logits, _ = model.forward(params, batch)
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == expected
        toks.append(nxt)


def test_rag_retrieval_respects_filter(small_model):
    cfg, model, params = small_model
    ds = make_dataset("sift", scale="4000", seed=0)
    ann = FilteredANNEngine(ds.vectors, ds.cat, ds.num, EngineConfig(seed=0)).build()
    tq, tp, _ = gen_queries(ds.vectors, ds.cat, ds.num, 25, kinds=("range",), seed=1)
    ann.fit(tq, tp, k=5)
    rag = RetrievalAugmentedServer(model, params, ann)
    lo = float(np.quantile(ds.num[:, 0], 0.4))
    hi = float(np.quantile(ds.num[:, 0], 0.8))
    pred = Predicate(ranges=(RangePred(0, ((lo, hi),)),))
    tokens = np.arange(16, dtype=np.int32).reshape(2, 8) % cfg.vocab_size
    outs = rag.retrieve(tokens, pred, k=5)
    assert len(outs) == 2
    for out in outs:
        ids = out.result.ids[0]
        ids = ids[ids >= 0]
        assert ids.size > 0
        assert pred.eval(ds.cat[ids], ds.num[ids]).all()
