"""Serving engine + RAG retrieval integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import EngineConfig, FilteredANNEngine, Predicate, RangePred
from repro.core.trainer import gen_queries
from repro.data import make_dataset
from repro.models import Model
from repro.serve import Request, ServeEngine, RetrievalAugmentedServer


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen3-14b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_serve_engine_generates(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                max_new_tokens=5)
        for i in range(5)
    ]
    eng = ServeEngine(model, params, batch_slots=2, max_len=32)
    results = eng.run(reqs)
    assert set(results) == {0, 1, 2, 3, 4}
    assert all(len(v) == 5 for v in results.values())
    assert all(0 <= t < cfg.vocab_size for v in results.values() for t in v)


def test_serve_greedy_deterministic(small_model):
    cfg, model, params = small_model
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    out1 = ServeEngine(model, params, batch_slots=1, max_len=32).run(
        [Request(uid=0, prompt=prompt, max_new_tokens=6)]
    )
    out2 = ServeEngine(model, params, batch_slots=1, max_len=32).run(
        [Request(uid=0, prompt=prompt, max_new_tokens=6)]
    )
    assert out1[0] == out2[0]


def test_serve_matches_teacher_forced(small_model):
    """Greedy generation equals repeated argmax over teacher-forced logits."""
    import jax.numpy as jnp

    cfg, model, params = small_model
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    gen = ServeEngine(model, params, batch_slots=1, max_len=32).run(
        [Request(uid=0, prompt=prompt, max_new_tokens=4)]
    )[0]
    toks = list(prompt)
    for expected in gen:
        batch = {"tokens": jnp.asarray(np.asarray(toks, np.int32))[None]}
        logits, _ = model.forward(params, batch)
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == expected
        toks.append(nxt)


def test_serve_unequal_length_prompts_match_solo(small_model):
    """Regression: a padded prefill batch must gather each row's logits at
    its TRUE last position (plens-1), not the batch max-length position —
    for shorter prompts that is a pad slot and the whole generation forks."""
    cfg, model, params = small_model
    rng = np.random.default_rng(3)
    prompts = [
        rng.integers(0, cfg.vocab_size, n).astype(np.int32) for n in (3, 8, 5)
    ]
    eng = ServeEngine(model, params, batch_slots=3, max_len=32)
    batched = eng.run(
        [Request(uid=i, prompt=p, max_new_tokens=4) for i, p in enumerate(prompts)]
    )
    for i, p in enumerate(prompts):
        solo = ServeEngine(model, params, batch_slots=1, max_len=32).run(
            [Request(uid=0, prompt=p, max_new_tokens=4)]
        )[0]
        assert batched[i][0] == solo[0], f"first token forked for prompt {i}"
        assert batched[i] == solo, f"generation forked for prompt {i}"


class _ForcedEosModel:
    """Stub model: first token is 2, every decode step then emits EOS=3."""

    vocab, eos = 8, 3

    def prefill(self, params, batch, max_len, lengths=None):
        b = batch["tokens"].shape[0]
        logits = jnp.zeros((b, self.vocab)).at[:, 2].set(5.0)
        return logits, {"step": jnp.zeros((b,), jnp.int32)}

    def decode_step(self, params, cache, tokens, lengths):
        b = tokens.shape[0]
        return jnp.zeros((b, self.vocab)).at[:, self.eos].set(5.0), cache


def test_serve_stops_decoding_after_all_eos():
    """Regression: once every slot is done the engine must stop dispatching
    jit'd decode steps instead of idling through max_new - 1 iterations."""
    stub = _ForcedEosModel()
    eng = ServeEngine(stub, None, batch_slots=2, max_len=16, eos_id=stub.eos)
    calls = {"n": 0}
    orig = eng._decode

    def counting(*args):
        calls["n"] += 1
        return orig(*args)

    eng._decode = counting
    out = eng.run([
        Request(uid=0, prompt=np.array([1, 2], np.int32), max_new_tokens=12),
        Request(uid=1, prompt=np.array([1], np.int32), max_new_tokens=12),
    ])
    assert out[0] == [2, stub.eos] and out[1] == [2, stub.eos]
    assert calls["n"] == 1, f"decode dispatched {calls['n']} times after EOS"


def test_supports_ragged_prefill_by_family():
    """The model-level capability flag is the single source of truth the
    serving guard consults: recurrent families must declare False."""
    from repro.configs import get_config
    from repro.models import Model

    assert Model(get_config("qwen3-14b").reduced()).supports_ragged_prefill
    assert not Model(get_config("xlstm-1.3b").reduced()).supports_ragged_prefill
    assert not Model(get_config("hymba-1.5b").reduced()).supports_ragged_prefill


def test_serve_rejects_unequal_lengths_for_recurrent_families():
    """Recurrent prefill folds pad steps into carried state, so the engine
    must refuse unequal-length batches rather than silently diverge."""
    stub = _ForcedEosModel()
    stub.supports_ragged_prefill = False
    eng = ServeEngine(stub, None, batch_slots=2, max_len=16, eos_id=stub.eos)
    with pytest.raises(ValueError, match="equal-length"):
        eng.run([
            Request(uid=0, prompt=np.array([1, 2, 3], np.int32), max_new_tokens=4),
            Request(uid=1, prompt=np.array([1], np.int32), max_new_tokens=4),
        ])
    # equal lengths stay served
    out = eng.run([
        Request(uid=0, prompt=np.array([1, 2], np.int32), max_new_tokens=4),
        Request(uid=1, prompt=np.array([3, 4], np.int32), max_new_tokens=4),
    ])
    assert out[0] == [2, stub.eos] and out[1] == [2, stub.eos]


def test_rag_retrieval_respects_filter(small_model):
    cfg, model, params = small_model
    ds = make_dataset("sift", scale="4000", seed=0)
    ann = FilteredANNEngine(ds.vectors, ds.cat, ds.num, EngineConfig(seed=0)).build()
    tq, tp, _ = gen_queries(ds.vectors, ds.cat, ds.num, 25, kinds=("range",), seed=1)
    ann.fit(tq, tp, k=5)
    rag = RetrievalAugmentedServer(model, params, ann)
    lo = float(np.quantile(ds.num[:, 0], 0.4))
    hi = float(np.quantile(ds.num[:, 0], 0.8))
    pred = Predicate(ranges=(RangePred(0, ((lo, hi),)),))
    tokens = np.arange(16, dtype=np.int32).reshape(2, 8) % cfg.vocab_size
    outs = rag.retrieve(tokens, pred, k=5)
    assert len(outs) == 2
    for out in outs:
        ids = out.result.ids[0]
        ids = ids[ids >= 0]
        assert ids.size > 0
        assert pred.eval(ds.cat[ids], ds.num[ids]).all()
