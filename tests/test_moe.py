"""MoE dispatch invariants (grouped sort-based path)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.layers import moe_ffn, moe_init, mlp


def _cfg(e=8, k=2, cap=8.0):
    base = get_config("olmoe-1b-7b").reduced()
    return dataclasses.replace(
        base, n_experts=e, top_k_experts=k, capacity_factor=cap, dtype="float32"
    )


def test_moe_matches_dense_reference():
    """With no capacity dropping, grouped dispatch == per-token dense sum of
    the selected experts' SwiGLU outputs."""
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    out, aux = moe_ffn(p, x, cfg)

    # dense reference
    b, s, d = x.shape
    xf = np.asarray(x).reshape(-1, d)
    logits = xf @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    topi = np.argsort(-probs, axis=-1)[:, : cfg.top_k_experts]
    ref = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        w = probs[t, topi[t]]
        w = w / w.sum()
        for j, e in enumerate(topi[t]):
            gate = xf[t] @ np.asarray(p["w_gate"][e])
            up = xf[t] @ np.asarray(p["w_up"][e])
            act = gate / (1 + np.exp(-gate)) * up            # silu(gate)*up
            ref[t] += w[j] * (act @ np.asarray(p["w_down"][e]))
    np.testing.assert_allclose(
        np.asarray(out).reshape(-1, d), ref, rtol=2e-3, atol=2e-3
    )
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """Tiny capacity must drop tokens (output smaller in norm), not crash."""
    cfg_full = _cfg(cap=8.0)
    cfg_tight = _cfg(cap=0.05)
    p = moe_init(jax.random.PRNGKey(0), cfg_full)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg_full.d_model), jnp.float32)
    out_full, _ = moe_ffn(p, x, cfg_full)
    out_tight, _ = moe_ffn(p, x, cfg_tight)
    assert float(jnp.linalg.norm(out_tight)) < float(jnp.linalg.norm(out_full))


def test_moe_grad_flows_to_all_parts():
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model), jnp.float32)

    def loss(p):
        out, aux = moe_ffn(p, x, cfg)
        return jnp.mean(out**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.abs(g[name]).max()) > 0, f"no grad into {name}"


def test_moe_shared_expert_added():
    cfg = dataclasses.replace(_cfg(), moe_shared_expert=True)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model), jnp.float32)
    out, _ = moe_ffn(p, x, cfg)
    # zeroing the shared expert must change the output
    p2 = dict(p)
    p2["shared"] = jax.tree.map(jnp.zeros_like, p["shared"])
    out2, _ = moe_ffn(p2, x, cfg)
    assert float(jnp.abs(out - out2).max()) > 1e-6
