"""Index-layer tests: flat vs chunked, IVF (both paths), ACORN recall."""
import numpy as np
import pytest

from repro.core import Predicate, RangePred, recall_at_k
from repro.index import AcornIndex, FlatIndex, IVFIndex, chunked_masked_topk, l2_topk


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0)
    centers = rng.normal(0, 1, (16, 32)).astype(np.float32)
    x = (centers[rng.choice(16, 5000)] + 0.3 * rng.normal(0, 1, (5000, 32))).astype(
        np.float32
    )
    q = x[rng.choice(5000, 20)] + 0.05 * rng.normal(0, 1, (20, 32)).astype(np.float32)
    return x, q.astype(np.float32)


def test_flat_exact_matches_numpy(corpus):
    x, q = corpus
    d, i = l2_topk(q, x, 5)
    d, i = np.asarray(d), np.asarray(i)
    # numpy oracle
    d2 = ((q[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    ref_i = np.argsort(d2, axis=1)[:, :5]
    ref_d = np.take_along_axis(d2, ref_i, 1)
    np.testing.assert_allclose(np.sort(d, 1), np.sort(ref_d, 1), rtol=1e-4, atol=1e-4)


def test_chunked_equals_full(corpus):
    x, q = corpus
    mask = np.zeros(x.shape[0], bool)
    mask[::3] = True
    d1, i1 = l2_topk(q, x, 8, mask)
    d2, i2 = chunked_masked_topk(q, x, 8, mask, chunk=512)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-4, atol=1e-5)
    assert (np.asarray(i1) == np.asarray(i2)).mean() > 0.95  # ties may reorder


def test_flat_mask_semantics(corpus):
    x, q = corpus
    mask = np.zeros(x.shape[0], bool)
    mask[:100] = True
    _, i = l2_topk(q, x, 5, mask)
    i = np.asarray(i)
    assert ((i < 100) | (i == -1)).all()


def test_ivf_recall(corpus):
    x, q = corpus
    idx = IVFIndex(x, n_lists=32, seed=0).build()
    _, truth = l2_topk(q, x, 10)
    _, got = idx.search(q, 10, nprobe=8)
    assert recall_at_k(got, np.asarray(truth)) > 0.8


def test_ivf_jax_matches_np(corpus):
    x, q = corpus
    idx = IVFIndex(x, n_lists=32, seed=0).build()
    import jax.numpy as jnp

    d_np, i_np = idx.search(q, 10, nprobe=4)
    d_j, i_j = idx.search_jax(jnp.asarray(q), 10, nprobe=4)
    # same probe lists -> same candidates -> same results
    np.testing.assert_allclose(d_np, np.asarray(d_j), rtol=1e-3, atol=1e-3)


def test_ivf_search_row_independent(corpus):
    """A row must return bit-identical results searched alone or inside any
    batch — the invariant the batched serving path's exactness rests on.
    Integer-ish corpora tie distances constantly, so this catches both BLAS
    shape-dependence and tie-handling that depends on batch padding."""
    x, q = corpus
    xi = np.round(x * 4).astype(np.float32)     # force frequent distance ties
    qi = np.round(q * 4).astype(np.float32)
    idx = IVFIndex(xi, n_lists=32, seed=0).build()
    for k, nprobe in ((10, 4), (100, 8), (5, 32)):
        db, ib = idx.search(qi, k, nprobe=nprobe)
        for i in range(qi.shape[0]):
            ds, is_ = idx.search(qi[i : i + 1], k, nprobe=nprobe)
            assert np.array_equal(ib[i], is_[0]), (k, nprobe, i)
            assert np.array_equal(db[i], ds[0]), (k, nprobe, i)
        d3, i3 = idx.search(qi[3:11], k, nprobe=nprobe)
        assert np.array_equal(i3, ib[3:11]) and np.array_equal(d3, db[3:11])


def test_ivf_masked(corpus):
    x, q = corpus
    idx = IVFIndex(x, n_lists=32, seed=0).build()
    mask = np.zeros(x.shape[0], bool)
    mask[::2] = True
    _, got = idx.search(q, 10, nprobe=32, mask=mask)
    assert ((got % 2 == 0) | (got == -1)).all()


def test_acorn_recall(corpus):
    x, q = corpus
    idx = AcornIndex(x, m=16, seed=0).build()
    _, truth = l2_topk(q, x, 10)
    _, got = idx.search(q, 10, ef=64)
    r = recall_at_k(got, np.asarray(truth))
    assert r > 0.75, f"acorn unfiltered recall {r}"


def test_acorn_filtered_recall(corpus):
    x, q = corpus
    idx = AcornIndex(x, m=16, seed=0).build()
    mask = np.zeros(x.shape[0], bool)
    mask[::4] = True
    _, truth = l2_topk(q, x, 10, mask)
    _, got = idx.search(q, 10, ef=96, mask=mask)
    assert ((got % 4 == 0) | (got == -1)).all()
    r = recall_at_k(got, np.asarray(truth))
    assert r > 0.6, f"acorn filtered recall {r}"


def test_acorn_jax_path(corpus):
    x, q = corpus
    idx = AcornIndex(x, m=16, seed=0).build()
    _, truth = l2_topk(q[:5], x, 5)
    _, got = idx.search_jax(q[:5], 5, ef=64, iters=48)
    r = recall_at_k(np.asarray(got), np.asarray(truth))
    assert r > 0.5, f"jax beam-search recall {r}"
