"""Per-kernel validation: shape/dtype sweeps vs. the pure-jnp oracles.

Kernels run in interpret mode on this CPU container (the TPU-target
BlockSpecs are exercised structurally either way).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    decode_attention,
    decode_attention_ref,
    masked_l2_topk,
    masked_l2_topk_ref,
)


def _rand(rng, shape, dtype=np.float32):
    return rng.normal(0, 1, shape).astype(dtype)


# ----------------------------------------------------------------------
# masked_l2 kernel
# ----------------------------------------------------------------------
@pytest.mark.parametrize("b,n,d", [(4, 600, 32), (128, 512, 128), (130, 1500, 200), (1, 512, 64)])
@pytest.mark.parametrize("k", [1, 10])
def test_masked_l2_shapes(b, n, d, k):
    rng = np.random.default_rng(b * 1000 + n + d + k)
    q = _rand(rng, (b, d))
    x = _rand(rng, (n, d))
    mask = rng.random(n) < 0.5
    d_k, i_k = masked_l2_topk(q, x, jnp.asarray(mask), k, interpret=True)
    d_r, i_r = masked_l2_topk_ref(jnp.asarray(q), jnp.asarray(x), jnp.asarray(mask), k)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=2e-4, atol=2e-4)
    # indices may differ on exact distance ties; compare via distances
    assert (np.asarray(i_k) >= -1).all()
    match = (np.asarray(i_k) == np.asarray(i_r)).mean()
    assert match > 0.95, f"index agreement {match}"


def test_masked_l2_all_masked_out():
    rng = np.random.default_rng(0)
    q = _rand(rng, (8, 64))
    x = _rand(rng, (700, 64))
    mask = np.zeros(700, bool)
    d_k, i_k = masked_l2_topk(q, x, jnp.asarray(mask), 5, interpret=True)
    assert (np.asarray(i_k) == -1).all()


def test_masked_l2_selective_mask_semantics():
    rng = np.random.default_rng(1)
    q = _rand(rng, (4, 32))
    x = _rand(rng, (1024, 32))
    mask = np.zeros(1024, bool)
    mask[100:200] = True
    _, i_k = masked_l2_topk(q, x, jnp.asarray(mask), 8, interpret=True)
    i_k = np.asarray(i_k)
    assert (((i_k >= 100) & (i_k < 200)) | (i_k == -1)).all()


def test_masked_l2_padding_never_returned():
    """Corpus padded to TN multiples — padding rows must never appear."""
    rng = np.random.default_rng(2)
    q = _rand(rng, (4, 48))
    x = _rand(rng, (513, 48))  # forces 1023-row pad
    mask = np.ones(513, bool)
    _, i_k = masked_l2_topk(q, x, jnp.asarray(mask), 10, interpret=True)
    assert (np.asarray(i_k) < 513).all()


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_masked_l2_dtypes(dtype):
    rng = np.random.default_rng(3)
    q = _rand(rng, (8, 64), dtype)
    x = _rand(rng, (600, 64), dtype)
    mask = np.ones(600, bool)
    d_k, _ = masked_l2_topk(q, x, jnp.asarray(mask), 4, interpret=True)
    d_r, _ = masked_l2_topk_ref(
        jnp.asarray(q, jnp.float32), jnp.asarray(x, jnp.float32), jnp.asarray(mask), 4
    )
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=2e-2, atol=2e-2)


# ----------------------------------------------------------------------
# decode_attention kernel
# ----------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,kv,gq,s,dh",
    [(2, 4, 2, 1024, 64), (1, 2, 8, 512, 128), (3, 1, 4, 1536, 64), (2, 8, 1, 512, 128)],
)
def test_decode_attention_shapes(b, kv, gq, s, dh):
    rng = np.random.default_rng(b + kv + gq + s)
    q = _rand(rng, (b, kv, gq, dh)) * 0.1
    k = _rand(rng, (b, kv, s, dh)) * 0.1
    v = _rand(rng, (b, kv, s, dh))
    length = rng.integers(1, s + 1, b).astype(np.int32)
    out_k = decode_attention(q, k, v, jnp.asarray(length), interpret=True)
    out_r = decode_attention_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(length)
    )
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=2e-4, atol=2e-4)


def test_decode_attention_unpadded_length():
    """S not a TS multiple: wrapper pads; padded positions must not leak."""
    rng = np.random.default_rng(9)
    b, kv, gq, s, dh = 2, 2, 2, 700, 64
    q = _rand(rng, (b, kv, gq, dh)) * 0.1
    k = _rand(rng, (b, kv, s, dh)) * 0.1
    v = _rand(rng, (b, kv, s, dh))
    length = np.array([700, 350], np.int32)
    out_k = decode_attention(q, k, v, jnp.asarray(length), interpret=True)
    out_r = decode_attention_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(length)
    )
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=2e-4, atol=2e-4)


def test_decode_attention_length_one():
    rng = np.random.default_rng(10)
    q = _rand(rng, (1, 2, 4, 64))
    k = _rand(rng, (1, 2, 512, 64))
    v = _rand(rng, (1, 2, 512, 64))
    length = np.array([1], np.int32)
    out = decode_attention(q, k, v, jnp.asarray(length), interpret=True)
    # attention over a single key = that key's value
    np.testing.assert_allclose(
        np.asarray(out)[0, :, :, :], np.broadcast_to(
            np.asarray(v)[0, :, 0:1, :], (2, 4, 64)
        ), rtol=1e-4, atol=1e-4,
    )


# ----------------------------------------------------------------------
# kernel vs. engine integration
# ----------------------------------------------------------------------
def test_kernel_matches_flat_index():
    from repro.index.flat import l2_topk

    rng = np.random.default_rng(11)
    q = _rand(rng, (16, 96))
    x = _rand(rng, (2048, 96))
    mask = rng.random(2048) < 0.3
    d_k, i_k = masked_l2_topk(q, x, jnp.asarray(mask), 10, interpret=True)
    d_f, i_f = l2_topk(jnp.asarray(q), jnp.asarray(x), 10, jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_f), rtol=2e-4, atol=2e-4)
