"""API-surface snapshot for the planning stack.

A CI tripwire, not a behaviour test: the public surface of
``core.planner``, ``core.selectivity``, and ``core.plan`` — plus the
``PlannedResult``/``QueryResult`` result envelope — is frozen here as
literal signatures.  Renaming a method, reordering dataclass fields, or
changing a default silently breaks downstream callers (the plan cache
pickles ``ExecutionPlan`` field order; the feedback log matches clause
plans by field); this test makes such a change an explicit, reviewed
diff instead of a surprise.

When an INTENTIONAL API change lands, update the snapshot in the same
commit and call the change out in the PR.
"""
import dataclasses
import inspect

from repro.core import engine, plan, planner, selectivity


def _sig(obj) -> str:
    return str(inspect.signature(obj))


def _fields(cls) -> list:
    return [f.name for f in dataclasses.fields(cls)]


def _methods(cls) -> dict:
    out = {}
    for name, m in sorted(vars(cls).items()):
        if name.startswith("_"):
            continue
        if inspect.isfunction(m):
            out[name] = _sig(m)
        elif isinstance(m, property):
            out[name] = "<property>"
    return out


# ----------------------------------------------------------------------
# core.planner
# ----------------------------------------------------------------------
def test_planner_surface():
    assert planner.PRE_FILTER == 0
    assert planner.POST_FILTER == 1
    assert planner.INDEXED_PRE == 2
    assert _sig(planner.CorePlanner.__init__) == \
        "(self, n_features: 'int' = 10, seed: 'int' = 0)"
    m = _methods(planner.CorePlanner)
    assert m["decide"] == "(self, features: 'np.ndarray') -> 'np.ndarray'"
    assert m["predict_proba"] == "(self, features: 'np.ndarray') -> 'np.ndarray'"
    assert m["fit"] == ("(self, features: 'np.ndarray', labels: 'np.ndarray', "
                        "l2_grid: 'Sequence[float]' = (0.0001, 0.001), "
                        "n_folds: 'int' = 2) -> \"'CorePlanner'\"")
    assert m["route"] == "(self, features: 'np.ndarray') -> 'Optional[np.ndarray]'"
    assert {"fit_routing", "state_dict", "load_state", "route_classes"} <= set(m)
    assert _fields(planner.PlannerFeatures) == ["stats"]
    assert _methods(planner.PlannerFeatures)["vector"] == (
        "(self, pred: 'Predicate', est_sel: 'float', k: 'int', "
        "sel_exact: 'bool' = False) -> 'np.ndarray'"
    )


# ----------------------------------------------------------------------
# core.selectivity — the SelEstimate API is the one estimator surface
# ----------------------------------------------------------------------
def test_selectivity_surface():
    assert _fields(selectivity.SelEstimate) == ["sel", "is_exact", "per_clause"]
    m = _methods(selectivity.SelectivityEstimator)
    assert m["estimate"] == "(self, pred) -> 'SelEstimate'"
    assert m["estimate_batch"] == \
        "(self, preds: 'Sequence') -> 'List[SelEstimate]'"
    # deprecated aliases stay until the next major cleanup — removing them
    # is an API change this snapshot forces into review
    assert m["estimate_ex"] == "(self, pred) -> 'Tuple[float, bool]'"
    assert m["estimate_batch_ex"] == \
        "(self, preds: 'Sequence') -> 'Tuple[np.ndarray, np.ndarray]'"
    assert m["fit"] == ("(self, preds: 'Sequence[Predicate]', "
                        "true_sel: 'Sequence[float]') -> "
                        "\"'SelectivityEstimator'\"")
    assert selectivity.__all__ == ["SelEstimate", "SelectivityEstimator",
                                   "N_FEATURES"]


# ----------------------------------------------------------------------
# core.plan — the ExecutionPlan tree
# ----------------------------------------------------------------------
def test_plan_surface():
    assert plan.NO_ROUTE == -1
    assert plan.STRATEGY_NAMES == {0: "pre", 1: "post", 2: "ipre"}
    # field ORDER is load-bearing: clause plans are constructed positionally
    assert _fields(plan.ClausePlan) == [
        "clause_key", "decision", "backend", "knob", "est", "route", "sel_exact",
    ]
    assert _fields(plan.ExecutionPlan) == ["clauses", "est", "sel_exact", "merge"]
    props = _methods(plan.ExecutionPlan)
    assert {"decision", "backend", "knob", "route", "strategy",
            "is_dnf", "n_clauses"} <= set(props)
    assert all(props[p] == "<property>" for p in
               ("decision", "backend", "knob", "route", "strategy"))
    assert _sig(plan.expand_for_execution) == \
        "(preds: 'Sequence', plans: 'Sequence[ExecutionPlan]')"
    assert _sig(plan.collapse_clause_results) == (
        "(d: 'np.ndarray', ids: 'np.ndarray', rounds: 'np.ndarray', "
        "row_map: 'List[List[int]]', k: 'int')"
    )
    assert _sig(plan.format_plan) == "(plan: 'ExecutionPlan', pred=None) -> 'str'"
    assert _sig(plan.default_route_name) == "(decision: 'int') -> 'Tuple[str, str]'"


# ----------------------------------------------------------------------
# result envelope
# ----------------------------------------------------------------------
def test_query_result_surface():
    assert engine.QueryResult is engine.PlannedResult
    assert _fields(engine.PlannedResult) == ["result", "plan", "plan_overhead"]
    props = _methods(engine.PlannedResult)
    assert props["decision"] == "<property>"
    assert props["est_selectivity"] == "<property>"
    # the legacy tuple protocol must NOT come back
    assert "__iter__" not in vars(engine.PlannedResult)
    assert "__iter__" not in vars(engine.QueryLabel)
