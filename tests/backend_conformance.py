"""Cross-backend conformance harness — the contract every registered ANN
backend must satisfy before the planner may route to it.

Parametrized over every backend in the registry (a fifth backend added via
``register_backend`` is automatically picked up).  The contract:

1. **Recall floors** — masked recall@10 against the exact masked oracle
   meets each declared :class:`KnobTier`'s ``recall_floor``.
2. **Row independence** — a query's (dists, ids) row is bit-identical
   whether it runs solo or inside any batch composition, on a corpus
   engineered to be full of distance ties (the PR 2 discipline).
3. **Mask safety** — no filtered-out id may ever surface, and no id is
   returned twice in one row.
4. **Edges** — empty corpus, tiny corpus (below ``TINY_N`` every backend
   degenerates to the exact scan), all-masked, |masked| <= k.
5. **Sharded ≡ unsharded** — per-shard masked top-k lists merged with
   ``merge_topk`` equal the whole-corpus answer for exact tiers, and meet
   the same recall floor for approximate tiers.

Plus registry mechanics: register/unregister of a custom toy backend, and
the IVF-PQ ≥4x memory-reduction acceptance gate vs flat.
"""
import numpy as np
import pytest

from repro.dist.collectives import merge_topk
from repro.index import BackendSet, make_backend, register_backend, unregister_backend
from repro.index.registry import (
    DEFAULT_BACKENDS,
    TINY_N,
    KnobTier,
    _exact_masked,
    backend_names,
)

K = 10


# ----------------------------------------------------------------------
# fixtures
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def corpus():
    """Clustered corpus (so IVF/PQ structure is meaningful) + near-duplicate
    queries, and a 50% mask."""
    rng = np.random.default_rng(0)
    centers = rng.normal(0, 1, (16, 32)).astype(np.float32)
    x = (centers[rng.choice(16, 5000)] + 0.3 * rng.normal(0, 1, (5000, 32))).astype(
        np.float32
    )
    q = (x[rng.choice(5000, 20)] + 0.05 * rng.normal(0, 1, (20, 32))).astype(np.float32)
    mask = rng.random(5000) < 0.5
    return x, q, mask


@pytest.fixture(scope="module")
def built(corpus):
    """One built instance per registered backend, shared across tests."""
    x, _, _ = corpus
    return {nm: make_backend(nm, x, seed=0) for nm in backend_names()}


def _oracle(x, q, mask, k=K):
    return _exact_masked(x, q, mask, k)


def _recall(ids, truth_ids):
    got = 0
    for row, t in zip(ids, truth_ids):
        ts = set(int(v) for v in t if v >= 0)
        if not ts:
            continue
        got += len(ts & set(int(v) for v in row if v >= 0)) / len(ts)
    return got / len(ids)


# ----------------------------------------------------------------------
# 1. recall floors at every declared tier
# ----------------------------------------------------------------------
@pytest.mark.parametrize("name", DEFAULT_BACKENDS)
def test_recall_floor_every_tier(built, corpus, name):
    x, q, mask = corpus
    b = built[name]
    _, truth = _oracle(x, q, mask)
    for tier in b.knob_grid():
        _, ids = b.search_masked(q, mask, K, knobs=tier.knobs)
        r = _recall(ids, truth)
        assert r >= tier.recall_floor, (
            f"{name}:{tier.name} recall {r:.3f} < declared floor "
            f"{tier.recall_floor} (knobs={dict(tier.knobs)})"
        )


# ----------------------------------------------------------------------
# 2. bit-stable row independence under batch recomposition
# ----------------------------------------------------------------------
@pytest.mark.parametrize("name", DEFAULT_BACKENDS)
def test_row_independence_with_ties(built, name):
    """Rounded coordinates force massive distance ties; every row must be
    bit-identical solo vs batched vs reversed-batch."""
    rng = np.random.default_rng(3)
    x = np.round(rng.normal(0, 1, (600, 16)).astype(np.float32) * 2) / 2
    q = np.round(rng.normal(0, 1, (9, 16)).astype(np.float32) * 2) / 2
    mask = rng.random(600) < 0.6
    b = make_backend(name, x, seed=0)
    for tier in b.knob_grid():
        bd, bi = b.search_masked(q, mask, K, knobs=tier.knobs)
        # solo
        for j in range(len(q)):
            sd, si = b.search_masked(q[j : j + 1], mask, K, knobs=tier.knobs)
            np.testing.assert_array_equal(si[0], bi[j], err_msg=f"{name}:{tier.name} solo row {j}")
            np.testing.assert_array_equal(sd[0], bd[j])
        # reversed batch
        rd, ri = b.search_masked(q[::-1].copy(), mask, K, knobs=tier.knobs)
        np.testing.assert_array_equal(ri[::-1], bi, err_msg=f"{name}:{tier.name} reversed")
        np.testing.assert_array_equal(rd[::-1], bd)


# ----------------------------------------------------------------------
# 3. mask / tombstone safety
# ----------------------------------------------------------------------
@pytest.mark.parametrize("name", DEFAULT_BACKENDS)
def test_mask_safety_and_no_duplicates(built, corpus, name):
    x, q, mask = corpus
    b = built[name]
    for tier in b.knob_grid():
        _, ids = b.search_masked(q, mask, K, knobs=tier.knobs)
        for row in ids:
            valid = row[row >= 0]
            assert mask[valid].all(), f"{name}:{tier.name} leaked a masked-out id"
            assert len(set(valid.tolist())) == len(valid), (
                f"{name}:{tier.name} returned a duplicate id"
            )


# ----------------------------------------------------------------------
# 4. edges: empty / tiny / all-masked / |masked| <= k
# ----------------------------------------------------------------------
@pytest.mark.parametrize("name", DEFAULT_BACKENDS)
def test_empty_corpus(name):
    b = make_backend(name, np.zeros((0, 8), np.float32), seed=0)
    q = np.random.default_rng(0).normal(0, 1, (3, 8)).astype(np.float32)
    d, i = b.search_masked(q, None, K)
    assert d.shape == (3, K) and i.shape == (3, K)
    assert (i == -1).all() and np.isinf(d).all()


@pytest.mark.parametrize("name", DEFAULT_BACKENDS)
def test_tiny_corpus_exact(name):
    """Below TINY_N every backend must answer exactly (all tiers)."""
    rng = np.random.default_rng(5)
    n = TINY_N - 10
    x = rng.normal(0, 1, (n, 12)).astype(np.float32)
    q = rng.normal(0, 1, (4, 12)).astype(np.float32)
    mask = rng.random(n) < 0.7
    want_d, want_i = _oracle(x, q, mask)
    b = make_backend(name, x, seed=0)
    for tier in b.knob_grid():
        d, i = b.search_masked(q, mask, K, knobs=tier.knobs)
        np.testing.assert_array_equal(i, want_i, err_msg=f"{name}:{tier.name}")
        np.testing.assert_allclose(d, want_d, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", DEFAULT_BACKENDS)
def test_all_masked(built, corpus, name):
    x, q, _ = corpus
    b = built[name]
    d, i = b.search_masked(q[:4], np.zeros(len(x), bool), K)
    assert (i == -1).all() and np.isinf(d).all()


@pytest.mark.parametrize("name", DEFAULT_BACKENDS)
def test_fewer_survivors_than_k(built, corpus, name):
    """When |masked| <= k, exact tiers (floor >= 0.99) must return exactly
    the survivor set; approximate tiers may miss survivors living in
    unprobed lists (that regime is the planner's pre-filter territory) but
    must still return ONLY survivors, -1/inf padded, no duplicates."""
    x, q, _ = corpus
    b = built[name]
    mask = np.zeros(len(x), bool)
    keep = np.random.default_rng(9).choice(len(x), 6, replace=False)
    mask[keep] = True
    keep_set = set(keep.tolist())
    for tier in b.knob_grid():
        d, ids = b.search_masked(q[:5], mask, K, knobs=tier.knobs)
        for dr, row in zip(d, ids):
            valid = [int(v) for v in row if v >= 0]
            assert set(valid) <= keep_set, f"{name}:{tier.name} leaked a non-survivor"
            assert len(set(valid)) == len(valid)
            assert np.isinf(dr[row == -1]).all()  # padding contract
            if tier.recall_floor >= 0.99:
                assert set(valid) == keep_set, (
                    f"{name}:{tier.name} (exact) missed a passing survivor"
                )


# ----------------------------------------------------------------------
# 5. sharded == unsharded merge identity
# ----------------------------------------------------------------------
@pytest.mark.parametrize("name", DEFAULT_BACKENDS)
def test_sharded_merge_identity(corpus, name):
    """Per-shard masked top-k + merge_topk vs the whole corpus.  Exact for
    tiers with recall_floor >= 0.99; approximate tiers keep the floor (any
    global top-k element lives in its own shard's top-k, so sharding can
    only help recall for exact scans)."""
    x, q, mask = corpus
    n_shards = 4
    bounds = np.linspace(0, len(x), n_shards + 1).astype(int)
    whole = make_backend(name, x, seed=0)
    _, truth = _oracle(x, q, mask)
    for tier in whole.knob_grid():
        wd, wi = whole.search_masked(q, mask, K, knobs=tier.knobs)
        ds_, is_ = [], []
        for s in range(n_shards):
            lo, hi = bounds[s], bounds[s + 1]
            shard = make_backend(name, x[lo:hi], seed=s)
            sd, si = shard.search_masked(q, mask[lo:hi], K, knobs=tier.knobs)
            si = np.where(si >= 0, si + lo, -1).astype(np.int32)
            ds_.append(sd)
            is_.append(si)
        md, mi = merge_topk(np.stack(ds_), np.stack(is_), K)
        if tier.recall_floor >= 0.99:
            np.testing.assert_array_equal(mi, wi, err_msg=f"{name}:{tier.name}")
            np.testing.assert_allclose(md, wd, rtol=1e-5, atol=1e-5)
        else:
            r = _recall(mi, truth)
            assert r >= tier.recall_floor, (
                f"sharded {name}:{tier.name} recall {r:.3f} < {tier.recall_floor}"
            )


# ----------------------------------------------------------------------
# 5b. per-disjunct DNF union == whole-predicate union-mask search
# ----------------------------------------------------------------------
@pytest.mark.parametrize("name", DEFAULT_BACKENDS)
def test_dnf_union_merge_identity(built, corpus, name):
    """Per-clause masked top-k merged with ``merge_topk_unique`` (the
    ExecutionPlan ``merge="union"`` collapse) vs one search over the OR of
    the clause masks.  The clause masks overlap, so the dedup path is
    genuinely exercised.  Exact tiers (floor >= 0.99) must be bit-identical
    — composite (dist, global-id) keys make the union reproduce the
    whole-predicate scan's tie-breaks; approximate tiers keep their floor
    against the union-mask oracle."""
    from repro.dist.collectives import merge_topk_unique

    x, q, _ = corpus
    rng = np.random.default_rng(21)
    clause_masks = [rng.random(len(x)) < 0.25 for _ in range(3)]
    union = clause_masks[0] | clause_masks[1] | clause_masks[2]
    overlap = (clause_masks[0] & clause_masks[1]).sum()
    assert overlap > 0, "degenerate fixture: clauses must overlap"
    b = built[name]
    _, truth = _oracle(x, q, union)
    for tier in b.knob_grid():
        wd, wi = b.search_masked(q, union, K, knobs=tier.knobs)
        per = [b.search_masked(q, cm, K, knobs=tier.knobs)
               for cm in clause_masks]
        md, mi = merge_topk_unique(
            np.stack([d for d, _ in per]), np.stack([i for _, i in per]), K
        )
        for row in mi:                      # dedup contract at every tier
            valid = row[row >= 0]
            assert len(set(valid.tolist())) == len(valid), (
                f"{name}:{tier.name} union merge returned a duplicate id"
            )
            assert union[valid].all()
        if tier.recall_floor >= 0.99:
            np.testing.assert_array_equal(mi, wi, err_msg=f"{name}:{tier.name}")
            np.testing.assert_allclose(md, wd, rtol=1e-5, atol=1e-5)
        else:
            r = _recall(mi, truth)
            assert r >= tier.recall_floor, (
                f"dnf-union {name}:{tier.name} recall {r:.3f} "
                f"< {tier.recall_floor}"
            )


# ----------------------------------------------------------------------
# registry mechanics + a custom backend passing the same gauntlet
# ----------------------------------------------------------------------
class _ToyExactBackend:
    """Minimal conforming backend: exact numpy scan with composite keys."""

    name = "toy"

    def __init__(self, seed: int = 0):
        self.seed = seed

    def build(self, corpus):
        self.vectors = np.ascontiguousarray(corpus, np.float32)
        return self

    def search_masked(self, queries, mask, k, knobs=None):
        return _exact_masked(self.vectors, queries, mask, k)

    def memory_bytes(self):
        return int(self.vectors.nbytes)

    def knob_grid(self):
        return (KnobTier("exact", {}, recall_floor=0.99),)


def test_register_unregister_custom_backend(corpus):
    x, q, mask = corpus
    register_backend("toy", _ToyExactBackend)
    try:
        assert "toy" in backend_names()
        # duplicate registration refused unless overwrite=True
        with pytest.raises(ValueError):
            register_backend("toy", _ToyExactBackend)
        register_backend("toy", _ToyExactBackend, overwrite=True)
        b = make_backend("toy", x, seed=0)
        want_d, want_i = _oracle(x, q, mask)
        d, i = b.search_masked(q, mask, K)
        np.testing.assert_array_equal(i, want_i)
        # a BackendSet over custom names enumerates classes in given order
        bs = BackendSet.build(x, names=("toy", "flat"), seed=0)
        assert bs.class_names() == ("toy:exact", "flat:exact")
        sd, si = bs.search_class(0, q, mask, K)
        np.testing.assert_array_equal(si, want_i)
    finally:
        unregister_backend("toy")
    assert "toy" not in backend_names()
    with pytest.raises(KeyError):
        make_backend("toy", x)


def test_backendset_memory_and_pq_reduction(built):
    """Acceptance gate: IVF-PQ's scan-resident footprint is >= 4x smaller
    than the flat baseline (the re-rank vectors are accounted separately,
    as the paper's PQ budget does)."""
    mem_flat = built["flat"].memory_bytes()
    mem_pq = built["ivfpq"].memory_bytes()
    assert mem_flat >= 4 * mem_pq, (
        f"ivfpq memory {mem_pq} not >=4x smaller than flat {mem_flat}"
    )
    assert built["ivfpq"].rerank_bytes > 0  # re-rank cost is declared, not hidden


# ----------------------------------------------------------------------
# 6. mutate-then-search: every backend serves a live corpus via LiveIndex
# ----------------------------------------------------------------------
def _live_over(x):
    from repro.core import LiveCorpus

    n = len(x)
    return LiveCorpus(x, np.zeros((n, 1), np.int32), np.zeros((n, 1), np.float32))


def _wrap_attrs(rows):
    b = len(np.atleast_2d(rows))
    return np.zeros((b, 1), np.int32), np.zeros((b, 1), np.float32)


@pytest.mark.parametrize("name", DEFAULT_BACKENDS)
def test_mutate_delete_excludes_tombstones(built, corpus, name):
    """After deleting the oracle's own top hits, no tier at any knob may
    ever surface a tombstoned id — fail-closed is the contract, recall is
    measured against the LIVE oracle."""
    from repro.index import LiveIndex

    x, q, mask = corpus
    live = _live_over(x)
    b = LiveIndex(built[name], live)
    _, truth = _oracle(x, q, mask)
    dead = np.unique(truth[truth >= 0])[:40]
    live.delete(dead)
    live_mask = mask.copy()
    live_mask[dead] = False
    _, live_truth = _oracle(x, q, live_mask)
    for tier in b.knob_grid():
        _, ids = b.search_masked(q, mask, K, knobs=tier.knobs)
        valid = ids[ids >= 0]
        assert not np.isin(valid, dead).any(), (
            f"{name}:{tier.name} surfaced a tombstoned id"
        )
        assert mask[valid].all()
        r = _recall(ids, live_truth)
        assert r >= tier.recall_floor, (
            f"{name}:{tier.name} live recall {r:.3f} < {tier.recall_floor}"
        )


@pytest.mark.parametrize("name", DEFAULT_BACKENDS)
def test_mutate_upsert_returns_new_ids(built, corpus, name):
    """A just-upserted row at distance zero from its query must surface at
    every tier: the append segment is exact-scanned regardless of how
    approximate the base backend is."""
    from repro.index import LiveIndex

    x, q, _ = corpus
    live = _live_over(x)
    b = LiveIndex(built[name], live)
    c, m = _wrap_attrs(q[:4])
    handles = live.upsert(q[:4], c, m)
    for tier in b.knob_grid():
        d, ids = b.search_masked(q[:4], None, K, knobs=tier.knobs)
        for j in range(4):
            assert handles[j] in ids[j], (
                f"{name}:{tier.name} missed the fresh upsert (row {j})"
            )


@pytest.mark.parametrize("name", DEFAULT_BACKENDS)
def test_mutate_compaction_id_stable(built, corpus, name):
    """Compaction folds segment + tombstones into a rebuilt corpus.  Exact
    tiers must be BIT-identical between the live view (translated through
    ``id_map``) and a fresh build over the compacted corpus; approximate
    tiers must clear their declared floor against the compacted oracle."""
    from repro.index import LiveIndex

    x, q, mask = corpus
    live = _live_over(x)
    b = LiveIndex(built[name], live)
    rng = np.random.default_rng(11)
    dead = rng.choice(len(x), 60, replace=False)
    live.delete(dead)
    new_rows = (q[:6] + 0.01 * rng.normal(0, 1, (6, x.shape[1]))).astype(np.float32)
    c, m = _wrap_attrs(new_rows)
    live.upsert(new_rows, c, m)
    # mask over the live handle space: base rows keep theirs, segment passes
    lm = np.concatenate([mask, np.ones(live.seg_n, bool)])
    cv, _, _, id_map = live.compacted()
    alive_h = np.nonzero(id_map >= 0)[0]
    fm = np.zeros(len(cv), bool)
    fm[id_map[alive_h]] = lm[alive_h]
    fresh = make_backend(name, cv, seed=0)
    _, ctruth = _oracle(cv, q, fm)
    for tier in b.knob_grid():
        ld, li = b.search_masked(q, lm, K, knobs=tier.knobs)
        tr = np.where(li >= 0, id_map[np.maximum(li, 0)], -1).astype(np.int32)
        if tier.recall_floor >= 0.99:
            fd, fi = fresh.search_masked(q, fm, K, knobs=tier.knobs)
            np.testing.assert_array_equal(tr, fi, err_msg=f"{name}:{tier.name}")
            np.testing.assert_allclose(ld, fd, rtol=1e-5, atol=1e-5)
        else:
            r = _recall(tr, ctruth)
            assert r >= tier.recall_floor, (
                f"{name}:{tier.name} post-compaction recall {r:.3f} "
                f"< {tier.recall_floor}"
            )
