"""repro.obs: tracing, metrics registry, recall probe, regression gate.

The load-bearing guarantees:

* **span-tree replay determinism** — the deterministic ledger of a traced
  run (span structure + attrs, never wall clock) is bit-identical across
  replays of the same trace + seed + engine cache state;
* **one registry, two views** — the legacy ``Telemetry`` counter shapes
  and the Prometheus/JSON exports read the SAME ``MetricsRegistry`` store,
  so they cannot disagree; a fleet shares one registry with tenant labels;
* **probe determinism** — per-rid seeded sampling is order-independent,
  and per-class online recall matches an injected oracle exactly;
* **the bench gate gates** — ``check_regression`` fails on out-of-band
  metrics and passes in-band ones.
"""
import json

import numpy as np
import pytest

from repro.core import EngineConfig, FilteredANNEngine
from repro.core.trainer import gen_queries
from repro.data import make_dataset
from repro.obs import (
    MetricsRegistry,
    NULL_TRACER,
    RecallProbe,
    Tracer,
    publish_kernel_budget,
    publish_kernel_dispatch,
    publish_stats,
    span_summary,
)
from repro.runtime import OnlineRuntime, SchedulerConfig, poisson_trace
from repro.runtime.telemetry import Telemetry

K = 10


@pytest.fixture(scope="module")
def system():
    ds = make_dataset("arxiv", scale="4000", seed=0)
    eng = FilteredANNEngine(
        ds.vectors, ds.cat, ds.num, EngineConfig(n_lists=32, seed=0)
    ).build()
    qs, preds, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 16, kinds=ds.filter_kinds,
        sel_range=(0.01, 0.4), seed=2,
    )
    return ds, eng, qs, list(preds)


def _trace(qs, preds, n=80, rate=3000.0, seed=5):
    return poisson_trace(qs, preds, n, rate, k=K, seed=seed)


def _traced_run(eng, trace, probe=None):
    """One traced replay from a cold cache state (span cache-delta attrs
    depend on cache contents, so determinism checks must reset them)."""
    eng.plan_cache.clear()
    eng.pred_cache.clear()
    tracer = Tracer()
    rt = OnlineRuntime(eng, SchedulerConfig(max_batch=16, max_wait=0.004),
                       tracer=tracer, probe=probe)
    report = rt.run_trace(trace)
    eng.set_tracer(None)
    return tracer, report


# ----------------------------------------------------------------------
# metrics registry
# ----------------------------------------------------------------------
def test_registry_counters_gauges_series():
    reg = MetricsRegistry()
    reg.inc("req_total", 0)                       # pre-create at zero
    reg.inc("req_total")
    reg.inc("req_total", 2)
    assert reg.value("req_total") == 3
    reg.inc("plan_total", plan="pre")
    reg.inc("plan_total", plan="post", tenant="a")
    # label kwarg order must not fork series identity
    reg.inc("plan_total", tenant="a", plan="post")
    assert reg.value("plan_total", plan="post", tenant="a") == 2
    assert reg.series("plan_total", match={"tenant": "a"}) == [
        ({"plan": "post", "tenant": "a"}, 2)
    ]
    with pytest.raises(ValueError):
        reg.inc("req_total", -1)                  # counters never decrease
    with pytest.raises(ValueError):
        reg.set_gauge("req_total", 5)             # kind mismatch
    reg.set_gauge("depth", 7.5)
    assert reg.value("depth") == 7.5


def test_registry_prometheus_golden():
    """Byte-exact exposition: sorted metrics, sorted label sets, cumulative
    histogram buckets."""
    reg = MetricsRegistry()
    reg.inc("repro_requests_total", 3, help="served requests")
    reg.inc("repro_plan_total", 2, plan="ipre")
    reg.inc("repro_plan_total", 1, plan="post")
    reg.observe("repro_lat_seconds", 0.002, buckets=(1e-3, 1e-2), tier="std")
    reg.observe("repro_lat_seconds", 0.2, buckets=(1e-3, 1e-2), tier="std")
    assert reg.prometheus_text() == (
        "# TYPE repro_lat_seconds histogram\n"
        'repro_lat_seconds_bucket{tier="std",le="0.001"} 0\n'
        'repro_lat_seconds_bucket{tier="std",le="0.01"} 1\n'
        'repro_lat_seconds_bucket{tier="std",le="+Inf"} 2\n'
        'repro_lat_seconds_sum{tier="std"} 0.202\n'
        'repro_lat_seconds_count{tier="std"} 2\n'
        "# TYPE repro_plan_total counter\n"
        'repro_plan_total{plan="ipre"} 2\n'
        'repro_plan_total{plan="post"} 1\n'
        "# HELP repro_requests_total served requests\n"
        "# TYPE repro_requests_total counter\n"
        "repro_requests_total 3\n"
    )


def test_registry_snapshot_deterministic():
    def build():
        reg = MetricsRegistry()
        reg.inc("b_total", 2, x="1")
        reg.inc("a_total")
        reg.observe("h_seconds", 0.03)
        return reg
    assert build().snapshot() == build().snapshot()
    assert list(build().snapshot()) == ["a_total", "b_total", "h_seconds"]


def test_publish_stats_flattens_numeric_leaves():
    reg = MetricsRegistry()
    publish_stats(reg, {"pred_cache": {"hits": 4, "ratio": 0.5},
                        "name": "skipped", "ok": True}, prefix="repro_engine")
    assert reg.value("repro_engine_pred_cache_hits") == 4
    assert reg.value("repro_engine_pred_cache_ratio") == 0.5
    assert reg.value("repro_engine_ok") == 1
    assert reg.series("repro_engine_name") == []


def test_publish_kernel_budget_gauges():
    reg = MetricsRegistry()
    publish_kernel_budget(reg)
    for d in (128, 256, 512):
        k = f"masked_l2_d{d}"
        assert reg.value("repro_kernel_vmem_bytes", kernel=k) > 0
        assert reg.value("repro_kernel_vmem_fits_16mib", kernel=k) == 1


# ----------------------------------------------------------------------
# telemetry on the registry: legacy shapes == registry store
# ----------------------------------------------------------------------
def test_telemetry_legacy_view_reads_registry(system):
    _, eng, qs, preds = system
    trace = _trace(qs, preds, n=60)
    rt = OnlineRuntime(eng, SchedulerConfig(max_batch=16, max_wait=0.004))
    report = rt.run_trace(trace)
    tel = report.telemetry
    c = tel.counters()
    assert c["n_completed"] == 60 == tel.n_completed
    assert c["n_completed"] == tel.registry.value("repro_requests_total")
    assert sum(c["plan_counts"].values()) == 60
    assert set(c["plan_counts"]) == {"pre", "post", "ipre", "dnf"}   # pre-created
    assert sum(c["batch_sizes"].values()) == c["n_batches"]
    met = {lbl["tier"]: v for lbl, v in
           tel.registry.series("repro_deadline_total", match={"outcome": "met"})}
    assert {t: int(v) for t, v in met.items() if v} \
        == {t: v for t, v in c["deadline_met"].items() if v}
    # histogram observed every completion
    text = tel.registry.prometheus_text()
    assert "repro_latency_virtual_seconds_count" in text


def test_fleet_registry_shared_with_tenant_labels():
    from repro.fleet.telemetry import FleetTelemetry

    ft = FleetTelemetry()
    ta, tb = ft.tenant("a"), ft.tenant("b")
    assert ta.registry is ft.registry is tb.registry
    ta._inc("repro_requests_total", 5)
    tb._inc("repro_requests_total", 2)
    assert ta.n_completed == 5 and tb.n_completed == 2     # label isolation
    ft.record_reject("b")
    assert ft.rejects == {"b": 1}
    assert 'repro_requests_total{tenant="a"} 5' in ft.registry.prometheus_text()


# ----------------------------------------------------------------------
# tracing: span trees, determinism, summary
# ----------------------------------------------------------------------
def test_null_tracer_is_inert():
    with NULL_TRACER.span("anything", x=1):
        NULL_TRACER.annotate(y=2)
        NULL_TRACER.add_wall("k", 0.5)
    assert not NULL_TRACER.enabled
    assert list(NULL_TRACER.spans()) == []


def test_span_tree_structure(system):
    _, eng, qs, preds = system
    tracer, _ = _traced_run(eng, _trace(qs, preds, n=40))
    names = {s.name for s in tracer.spans()}
    assert {"batch", "plan", "execute", "group"} <= names
    roots = tracer.roots
    assert all(s.name == "batch" for s in roots)
    plan = next(s for s in tracer.spans() if s.name == "plan")
    assert {"plan_cache_hits", "plan_cache_misses"} <= set(plan.attrs)
    comp = next(s for s in tracer.spans() if s.name == "predicate_compile")
    assert comp.attrs["bitmap_words"] > 0
    groups = [s for s in tracer.spans() if s.name == "group"]
    assert groups
    for g in groups:
        assert {"decision", "backend", "knob", "n_rows"} <= set(g.attrs)
    assert any("n_candidates" in g.attrs for g in groups)
    execs = [s for s in tracer.spans() if s.name == "execute"]
    assert any(any(k.startswith("kernel_") for k in e.attrs) for e in execs), \
        "execute spans must carry kernel dispatch deltas"


def test_span_tree_replay_bit_identical(system):
    """The tentpole guarantee: deterministic ledger identical across
    replays, wall clock excluded (and actually measured)."""
    _, eng, qs, preds = system
    trace = _trace(qs, preds, n=60)
    ta, _ = _traced_run(eng, trace)
    tb, _ = _traced_run(eng, trace)
    assert ta.deterministic_tree() == tb.deterministic_tree()
    assert sum(s.wall_s for s in ta.spans()) > 0.0


def test_span_summary_ranks_self_time(system):
    _, eng, qs, preds = system
    tracer, _ = _traced_run(eng, _trace(qs, preds, n=40))
    rows = span_summary(tracer)
    stages = [r["stage"] for r in rows]
    assert {"batch", "plan", "execute"} <= set(stages)
    assert any(s.startswith("kernel:") for s in stages)
    assert all(r["self_s"] <= r["wall_s"] + 1e-12 for r in rows)
    assert [r["self_s"] for r in rows] \
        == sorted((r["self_s"] for r in rows), reverse=True)


def test_trace_jsonl_export(system, tmp_path):
    _, eng, qs, preds = system
    tracer, _ = _traced_run(eng, _trace(qs, preds, n=24))
    path = tmp_path / "spans.jsonl"
    tracer.write_jsonl(path)
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert len(rows) == sum(1 for _ in tracer.spans())
    ids = {r["span_id"] for r in rows}
    assert all(r["parent_id"] in ids or r["parent_id"] == -1 for r in rows)
    assert all("wall" in r and "attrs" in r for r in rows)


def test_engine_stats_kernel_and_cache_ratios(system):
    from repro.kernels import ops

    _, eng, qs, preds = system
    eng.plan_cache.clear()
    eng.pred_cache.clear()
    ops.reset_dispatch_stats()
    eng.batch_query(np.stack(qs[:8]), preds[:8], K)
    counts = ops.dispatch_counts()
    assert counts.get("fused_masked_topk", 0) + counts.get("ivf_search", 0) > 0
    s = eng.stats()
    assert set(s["cache_hit_ratio"]) == {"pred_cache", "mask_tier", "plan_cache"}
    assert s["kernel_dispatch"] == counts
    reg = MetricsRegistry()
    publish_kernel_dispatch(reg)
    for name, n in counts.items():
        assert reg.value("repro_kernel_dispatch_total", kernel=name) == n


# ----------------------------------------------------------------------
# recall probe
# ----------------------------------------------------------------------
def test_probe_sampling_deterministic_and_order_free():
    p = RecallProbe(rate=0.3, seed=11)
    picks = {rid: p.should_sample(rid) for rid in range(200)}
    assert picks == {rid: p.should_sample(rid) for rid in reversed(range(200))}
    n = sum(picks.values())
    assert 0 < n < 200                       # actually samples a fraction
    assert RecallProbe(rate=1.0).should_sample(5)
    assert not RecallProbe(rate=0.0).should_sample(5)


def test_probe_recall_vs_injected_oracle(system):
    """Class recall must equal the analytic value for a known oracle: the
    truth_fn disagrees with the served ids on a known fraction of slots."""
    _, eng, qs, preds = system
    trace = _trace(qs, preds, n=40, seed=9)
    # oracle = actually-served ids with the last id replaced -> recall 0.9
    rt = OnlineRuntime(eng, SchedulerConfig(max_batch=16, max_wait=0.004))
    served_ids = rt.run_trace(trace).results
    # (query, pred) -> that request's served ids; duplicates collapse
    # safely because identical (query, pred, k) always serve identical ids
    by_key = {
        (r.query.tobytes(), id(r.pred)): served_ids[r.rid].result.ids[0]
        for r in trace.requests
    }

    def truth_fn(q, pred, k):
        t = by_key[(np.asarray(q[0], np.float32).tobytes(), id(pred))].copy()
        t[0] = 10**7             # planted miss: top-1 swapped for a fake id
        return t[None, :]

    probe = RecallProbe(backend=eng, rate=1.0, seed=0, truth_fn=truth_fn)
    report = OnlineRuntime(
        eng, SchedulerConfig(max_batch=16, max_wait=0.004), probe=probe,
    ).run_trace(trace)
    assert probe.n_seen == probe.n_sampled == 40
    est = probe.estimates()
    served_classes = {RecallProbe.class_key(r) for r in report.results.values()}
    assert set(est) == served_classes         # every served class estimated
    # expected recall per class: each request recovers all but the planted
    # miss of its n_valid true neighbours -> mean of (n_valid - 1)/n_valid
    want: dict = {}
    for res in report.results.values():
        n_valid = int((res.result.ids[0] >= 0).sum())
        want.setdefault(RecallProbe.class_key(res), []).append(
            (n_valid - 1) / n_valid)
    for key, row in est.items():
        assert row["recall"] == round(float(np.mean(want[key])), 6)
        assert row["recall"] < 1.0            # the planted miss registered
    assert probe.below(0.99) == {k: row["recall"] for k, row in est.items()}
    assert probe.below(0.5) == {}


def test_probe_replay_deterministic(system):
    _, eng, qs, preds = system
    trace = _trace(qs, preds, n=60)

    def run():
        probe = RecallProbe(rate=0.5, seed=3)
        OnlineRuntime(eng, SchedulerConfig(max_batch=16, max_wait=0.004),
                      probe=probe).run_trace(trace)
        return probe.counters()
    a, b = run(), run()
    assert a == b
    assert 0 < a["n_sampled"] < a["n_seen"] == 60


def test_probe_publish_gauges():
    probe = RecallProbe(rate=1.0, seed=0, truth_fn=lambda q, p, k: None)
    probe.n_seen, probe.n_sampled = 10, 10
    probe._sum["post/ivf:adapt"] = 9.0
    probe._count["post/ivf:adapt"] = 10
    reg = MetricsRegistry()
    probe.publish(reg, tenant="a")
    assert reg.value("repro_probe_recall", cls="post/ivf:adapt", tenant="a") == 0.9
    assert reg.value("repro_probe_seen_total", tenant="a") == 10


# ----------------------------------------------------------------------
# bench regression gate
# ----------------------------------------------------------------------
def test_check_regression_gate(tmp_path):
    from benchmarks.check_regression import main as gate

    tol = tmp_path / "tolerances.json"
    tol.write_text(json.dumps({
        "demo": {"recall": {"min": 0.9}, "counts.n": {"equals": 4},
                 "mem": {"max": 100}},
    }))
    good = tmp_path / "BENCH_demo_n5000.json"
    good.write_text(json.dumps({"recall": 0.95, "counts": {"n": 4}, "mem": 80}))
    assert gate([str(good), "--tolerances", str(tol)]) == 0
    bad = tmp_path / "BENCH_demo_n9000.json"
    bad.write_text(json.dumps({"recall": 0.85, "counts": {"n": 4}}))  # 2 bad
    assert gate([str(bad), "--tolerances", str(tol)]) == 1
    unknown = tmp_path / "BENCH_other_n5000.json"
    unknown.write_text("{}")
    assert gate([str(unknown), "--tolerances", str(tol)]) == 1


def test_committed_tolerances_cover_ci_benches():
    from benchmarks.check_regression import TOLERANCES

    bands = json.loads(TOLERANCES.read_text())
    assert {"backend", "mutation", "fleet", "runtime"} <= set(bands)
    for name, spec in bands.items():
        for path, band in spec.items():
            assert band and set(band) <= {"min", "max", "equals"}, (name, path)
