"""repro.runtime: traces, micro-batching scheduler, telemetry, feedback.

The load-bearing guarantees:

* **replay determinism** — same trace + seed => identical result ids,
  batch compositions, and telemetry counters across runs;
* **arrival-order invariance** — a request's result ids do not depend on
  which micro-batch it landed in (leans on the batched pipeline's
  bit-stability discipline: ``batch_query`` == per-query ``query``);
* **deadline-aware scheduling** — tight-SLO requests preempt batch
  formation and drain first;
* **guarded feedback** — the online refit loop recovers a warped planner
  and the drift guard refuses regressing candidates.
"""
import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    FilteredANNEngine,
    LabelEq,
    Or,
    POST_FILTER,
    PRE_FILTER,
    Predicate,
)
from repro.core.planner import CorePlanner
from repro.core.trainer import gen_queries
from repro.data import make_dataset
from repro.runtime import (
    FeedbackConfig,
    OnlineFeedback,
    OnlineRuntime,
    RuntimeRequest,
    SchedulerConfig,
    ServiceModel,
    SLO_TIERS,
    bursty_trace,
    poisson_trace,
)
from repro.serve import ShardedANNEngine

K = 10


@pytest.fixture(scope="module")
def system():
    ds = make_dataset("arxiv", scale="4000", seed=0)
    eng = FilteredANNEngine(
        ds.vectors, ds.cat, ds.num, EngineConfig(n_lists=32, seed=0)
    ).build()
    qs, preds, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 16, kinds=ds.filter_kinds,
        sel_range=(0.01, 0.4), seed=2,
    )
    return ds, eng, qs, list(preds)


def _trace(qs, preds, n=120, rate=3000.0, seed=5, kind="poisson"):
    gen = poisson_trace if kind == "poisson" else bursty_trace
    return gen(qs, preds, n, rate, k=K, seed=seed)


# ----------------------------------------------------------------------
# trace generators
# ----------------------------------------------------------------------
def test_trace_generators_deterministic_and_shaped(system):
    _, _, qs, preds = system
    a = poisson_trace(qs, preds, 200, 1000.0, seed=3)
    b = poisson_trace(qs, preds, 200, 1000.0, seed=3)
    assert [r.t_arrival for r in a] == [r.t_arrival for r in b]
    assert [r.tier for r in a] == [r.tier for r in b]
    assert all(x.pred is y.pred for x, y in zip(a, b))
    c = poisson_trace(qs, preds, 200, 1000.0, seed=4)
    assert [r.t_arrival for r in a] != [r.t_arrival for r in c]
    # mean rate lands near the target; deadlines are tier offsets
    span = a.requests[-1].t_arrival
    assert 0.5 * 200 / 1000.0 < span < 2.0 * 200 / 1000.0
    for r in a:
        assert r.deadline == pytest.approx(r.t_arrival + SLO_TIERS[r.tier])
    # bursty arrivals are burstier: higher inter-arrival coefficient of var
    burst = bursty_trace(qs, preds, 400, 1000.0, seed=3)
    pois = poisson_trace(qs, preds, 400, 1000.0, seed=3)
    def cv(t):
        gaps = np.diff([r.t_arrival for r in t])
        return gaps.std() / gaps.mean()
    assert cv(burst) > cv(pois)


def test_trace_generators_reject_bad_write_fracs(system):
    """Probabilities outside [0, 1] must fail loudly at construction —
    they used to silently degenerate the write mix."""
    _, _, qs, preds = system
    for gen in (poisson_trace, bursty_trace):
        for kw in ({"write_frac": 1.5}, {"write_frac": -0.1},
                   {"upsert_frac": 2.0}, {"upsert_frac": -1e-9}):
            with pytest.raises(ValueError):
                gen(qs, preds, 10, 100.0, seed=0, **kw)
        # the boundaries themselves are legal
        gen(qs, preds, 10, 100.0, seed=0, write_frac=0.0, upsert_frac=1.0)


def test_zipf_predicate_mix(system):
    """A few hot predicates dominate the trace (the cache-friendly regime)."""
    _, _, qs, preds = system
    t = poisson_trace(qs, preds, 600, 1000.0, zipf_a=1.2, seed=0)
    counts = {}
    for r in t:
        counts[id(r.pred)] = counts.get(id(r.pred), 0) + 1
    top = max(counts.values())
    assert top > 600 / len(preds) * 2          # far above uniform share


# ----------------------------------------------------------------------
# replay determinism + arrival-order invariance (the tentpole guarantees)
# ----------------------------------------------------------------------
def test_runtime_replay_deterministic(system):
    _, eng, qs, preds = system
    trace = _trace(qs, preds)
    cfg = SchedulerConfig(max_batch=16, max_wait=0.004)
    a = OnlineRuntime(eng, cfg).run_trace(trace)
    b = OnlineRuntime(eng, cfg).run_trace(trace)
    assert a.batches == b.batches
    assert a.telemetry.counters() == b.telemetry.counters()
    # virtual latency statistics are part of the deterministic ledger too
    sa, sb = a.telemetry.snapshot(), b.telemetry.snapshot()
    assert sa["latency_virtual"] == sb["latency_virtual"]
    assert sa["latency_by_tier"] == sb["latency_by_tier"]
    for rid in a.results:
        assert np.array_equal(a.ids(rid), b.ids(rid))


def test_runtime_ids_invariant_to_batch_composition(system):
    """Per-request ids must not depend on micro-batch composition: wildly
    different scheduler policies (and the per-request loop itself) agree."""
    _, eng, qs, preds = system
    trace = _trace(qs, preds, n=80)
    big = OnlineRuntime(eng, SchedulerConfig(max_batch=64, max_wait=0.02)).run_trace(trace)
    solo = OnlineRuntime(eng, SchedulerConfig(max_batch=1, max_wait=0.0)).run_trace(trace)
    assert big.batches != solo.batches          # compositions genuinely differ
    for r in trace:
        direct = eng.query(r.query, r.pred, r.k)
        assert np.array_equal(big.ids(r.rid), solo.ids(r.rid))
        assert np.array_equal(big.ids(r.rid), direct.result.ids[0])
        assert big.results[r.rid].decision == direct.decision


def test_runtime_every_request_answered_once(system):
    _, eng, qs, preds = system
    trace = _trace(qs, preds, n=100, kind="bursty")
    rep = OnlineRuntime(eng, SchedulerConfig(max_batch=8)).run_trace(trace)
    served = [rid for batch in rep.batches for rid in batch]
    assert sorted(served) == list(range(100))
    assert sorted(rep.results) == list(range(100))
    assert rep.telemetry.counters()["n_completed"] == 100


# ----------------------------------------------------------------------
# scheduler policy
# ----------------------------------------------------------------------
def _req(rid, t, q, pred, tier="standard", deadline=None):
    return RuntimeRequest(
        rid=rid, t_arrival=t, query=q, pred=pred, k=K, tier=tier,
        deadline=t + SLO_TIERS[tier] if deadline is None else deadline,
    )


def test_deadline_priority_preempts_batch_formation(system):
    """A tight-deadline arrival must (a) flush the forming batch before
    max_wait expires and (b) run at the head of that batch."""
    from repro.runtime.queue import ArrivalTrace

    _, eng, qs, preds = system
    q, p = qs[0], preds[0]
    service = ServiceModel()
    # three bulk requests trickle in, then an interactive one: with
    # max_wait=10s the only reason to flush early is deadline pressure
    reqs = [
        _req(0, 0.000, q, p, tier="batch"),
        _req(1, 0.001, q, p, tier="batch"),
        _req(2, 0.002, q, p, tier="batch"),
        _req(3, 0.003, q, p, tier="interactive"),
    ]
    trace = ArrivalTrace(reqs, "poisson", 1000.0, 0)
    rep = OnlineRuntime(
        eng, SchedulerConfig(max_batch=64, max_wait=10.0), service,
    ).run_trace(trace)
    assert len(rep.batches) == 1
    assert rep.batches[0][0] == 3               # tightest deadline drains first
    tel = rep.telemetry.counters()
    assert tel["deadline_flushes"] == 1
    assert tel["deadline_met"].get("interactive", 0) == 1
    # flush happened at SLO pressure, far before the 10 s max_wait
    snap = rep.telemetry.snapshot()
    assert snap["latency_virtual"]["max"] < 1.0


def test_max_wait_bounds_queue_age(system):
    """Without deadline pressure, the oldest request waits at most max_wait
    before its batch flushes."""
    _, eng, qs, preds = system
    trace = _trace(qs, preds, n=60, rate=500.0, seed=11)
    max_wait = 0.004
    rep = OnlineRuntime(
        eng, SchedulerConfig(max_batch=64, max_wait=max_wait)
    ).run_trace(trace)
    snap = rep.telemetry.snapshot()
    service_bound = ServiceModel().estimate(64)
    # wait-to-flush <= max_wait + service backlog of at most one batch
    assert snap["queue_wait_virtual"]["max"] <= max_wait + service_bound + 1e-9


def test_sharded_runtime_matches_sharded_query(system):
    _, eng, qs, preds = system
    sharded = ShardedANNEngine(eng, n_shards=3)
    trace = _trace(qs, preds, n=40, seed=8)
    rep = sharded.runtime(SchedulerConfig(max_batch=16)).run_trace(trace)
    for r in trace:
        direct = sharded.query(r.query, r.pred, r.k)
        assert np.array_equal(rep.ids(r.rid), direct.result.ids[0])
    # aggregated stats surface central + per-shard cache counters
    s = sharded.stats()
    assert s["shard_pred_cache"]["n_shards"] == 3
    assert s["plan_cache"]["hits"] > 0


# ----------------------------------------------------------------------
# telemetry
# ----------------------------------------------------------------------
def test_telemetry_counters_consistent(system):
    _, eng, qs, preds = system
    trace = _trace(qs, preds, n=90, seed=13)
    rep = OnlineRuntime(eng, SchedulerConfig(max_batch=16)).run_trace(trace)
    tel = rep.telemetry.counters()
    assert tel["n_completed"] == 90
    assert sum(tel["plan_counts"].values()) == 90
    assert sum(n * c for n, c in tel["batch_sizes"].items()) == 90
    met = sum(tel["deadline_met"].values())
    missed = sum(tel["deadline_missed"].values())
    assert met + missed == 90
    assert 0.0 <= tel["fill_rate"] <= 1.0
    snap = rep.telemetry.snapshot(eng)
    assert snap["engine"]["pred_cache"]["hits"] > 0       # hot Zipf predicates
    assert snap["engine"]["plan_cache"]["hits"] > 0
    assert snap["wall"]["exec_s"] > 0


# ----------------------------------------------------------------------
# plan cache semantics (engine-side hook the runtime leans on)
# ----------------------------------------------------------------------
def test_plan_cache_purity_and_invalidation(system):
    _, eng, qs, preds = system
    p = preds[0]
    eng.plan_cache.clear()
    est0, dec0, _ = eng.plan(p, K)
    h0 = eng.plan_cache.stats()["hits"]
    est1, dec1, _ = eng.plan(p, K)
    assert (est1, dec1) == (est0, dec0)
    assert eng.plan_cache.stats()["hits"] == h0 + 1
    # k is part of the key: a different k may plan differently
    eng.plan(p, K + 1)
    assert eng.plan_cache.stats()["size"] >= 2
    # batch path shares the same cache and returns identical values
    ests, decs, _ = eng.plan_batch([p, p], K)
    assert ests[0] == est0 and decs[0] == dec0
    # swapping the head invalidates memoised plans
    ver = eng.planner_version
    eng.swap_planner(CorePlanner(seed=1))
    assert eng.planner_version == ver + 1
    assert eng.plan_cache.stats()["size"] == 0
    # a DIRECT estimator refit (bypassing engine.fit) must also invalidate:
    # the epoch guard compares (planner_version, estimator.generation)
    ds = system[0]
    _, ps, sels = gen_queries(ds.vectors, ds.cat, ds.num, 8,
                              kinds=("label", "mixed"), seed=41)
    eng.plan(p, K)
    assert len(eng.plan_cache) >= 1
    eng.estimator.fit(list(ps), list(sels))
    est2, dec2, _ = eng.plan(p, K)
    cold = eng._plan_cold(p, K)
    assert (est2, dec2) == (cold.est, cold.decision)   # fresh, not the stale memo


def test_engine_stats_accessor_dnf(system):
    """Satellite: `stats()` is the public counter surface, and DNF predicates
    flow through the runtime like any conjunctive predicate."""
    _, eng, qs, preds = system
    dnf = Or((Predicate(labels=(LabelEq(0, 0),)), preds[0]))
    from repro.runtime.queue import ArrivalTrace

    reqs = [_req(i, 0.001 * i, qs[i % len(qs)], dnf) for i in range(6)]
    rep = OnlineRuntime(eng, SchedulerConfig(max_batch=8)).run_trace(
        ArrivalTrace(reqs, "poisson", 1000.0, 0))
    st = eng.stats()
    assert {"planner_version", "pred_cache", "plan_cache"} <= set(st)
    for r in rep.results.values():
        ids = r.result.ids[r.result.ids >= 0]
        ds = system[0]
        assert dnf.eval(ds.cat[ids], ds.num[ids]).all()


# ----------------------------------------------------------------------
# feedback loop
# ----------------------------------------------------------------------
def _threshold_labeler(eng, cut=0.08):
    """Deterministic oracle: post-filter wins above the selectivity cut."""
    def labeler(req):
        est = eng.estimator.estimate(req.pred).sel
        return POST_FILTER if est >= cut else PRE_FILTER
    return labeler


def _fold(d: int) -> int:
    return POST_FILTER if d == POST_FILTER else PRE_FILTER


def test_feedback_recovers_warped_planner(system):
    """A head fit on inverted labels must recover once the online log —
    labelled by a deterministic oracle here — is replayed through refit."""
    ds, eng, qs, preds = system
    labeler = _threshold_labeler(eng)
    # warp: train on the INVERTED oracle
    feats, bad = [], []
    for p in preds:
        se = eng.estimator.estimate(p)
        feats.append(eng.feat.vector(p, se.sel, K, se.is_exact))
        bad.append(PRE_FILTER if se.sel >= 0.08 else POST_FILTER)
    eng.swap_planner(CorePlanner(seed=3).fit(np.stack(feats), np.asarray(bad)))

    def acc():
        good = 0
        for p, fv in zip(preds, feats):
            want = labeler(RuntimeRequest(0, 0.0, qs[0], p, K))
            good += int(_fold(int(eng.planner.decide(fv)[0])) == want)
        return good / len(preds)

    acc_warped = acc()
    fb = OnlineFeedback(eng, FeedbackConfig(
        sample_rate=1.0, refit_every=60, min_examples=40, seed=0,
    ), labeler=labeler)
    trace = _trace(qs, preds, n=140, seed=17)
    OnlineRuntime(eng, SchedulerConfig(max_batch=32), feedback=fb).run_trace(trace)
    assert fb.n_swaps >= 1
    acc_rec = acc()
    assert acc_rec >= 0.85, f"recovered accuracy {acc_rec} (warped {acc_warped})"
    assert acc_rec > acc_warped
    st = fb.stats()
    assert st["sampled"] == st["observed"] == 140


def test_feedback_drift_guard_blocks_regressions(system):
    """An impossible AUC bar must keep the current head (guard wiring), and
    degenerate single-class logs must never trigger a refit."""
    ds, eng, qs, preds = system
    labeler = _threshold_labeler(eng)
    fb = OnlineFeedback(eng, FeedbackConfig(
        sample_rate=1.0, refit_every=10**9, min_examples=20,
        auc_slack=-10.0,            # candidate must beat current by 10 AUC
        seed=0,
    ), labeler=labeler)
    for r in _trace(qs, preds, n=60, seed=19):
        fb.observe(r, eng.query(r.query, r.pred, r.k))
    before = eng.planner
    ver = eng.planner_version
    assert fb.refit() is False
    assert eng.planner is before and eng.planner_version == ver
    # degenerate labels: refit declines without touching the head
    fb2 = OnlineFeedback(eng, FeedbackConfig(sample_rate=1.0, seed=0),
                         labeler=lambda req: PRE_FILTER)
    for r in _trace(qs, preds, n=40, seed=23):
        fb2.observe(r, eng.query(r.query, r.pred, r.k))
    assert fb2.refit() is False
    assert eng.planner is before


def test_feedback_requires_built_engine(system):
    ds, *_ = system
    stats_only = FilteredANNEngine(
        ds.vectors, ds.cat, ds.num, EngineConfig(seed=0)
    ).build_stats()
    with pytest.raises(ValueError, match="fully built"):
        OnlineFeedback(stats_only)


def test_feedback_sampling_is_seeded(system):
    _, eng, qs, preds = system
    labeler = _threshold_labeler(eng)
    trace = _trace(qs, preds, n=50, seed=29)
    picks = []
    for _ in range(2):
        fb = OnlineFeedback(eng, FeedbackConfig(
            sample_rate=0.3, refit_every=10**9, seed=7), labeler=labeler)
        res = [eng.query(r.query, r.pred, r.k) for r in trace]
        picks.append([fb.observe(r, x) for r, x in zip(trace, res)])
    assert picks[0] == picks[1]
    assert 0 < sum(picks[0]) < 50


# ----------------------------------------------------------------------
# routed runtime: replay determinism over the (plan, backend, knob) space
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def routed_system():
    """Engine with the full backend roster + fitted routing head."""
    ds = make_dataset("arxiv", scale="4000", seed=0)
    eng = FilteredANNEngine(
        ds.vectors, ds.cat, ds.num,
        EngineConfig(n_lists=32, seed=0, backends=("flat", "ivf", "ivfpq", "acorn")),
    ).build()
    tq, tp, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 24, kinds=ds.filter_kinds, seed=1,
    )
    eng.fit(tq, tp, k=K)
    qs, preds, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 16, kinds=ds.filter_kinds,
        sel_range=(0.01, 0.4), seed=2,
    )
    return ds, eng, qs, list(preds)


def test_routed_replay_deterministic(routed_system):
    """Same trace + seed => identical (plan, backend, knob) per request and
    identical result ids across two runs — routing is part of the
    deterministic ledger, not a new source of nondeterminism."""
    _, eng, qs, preds = routed_system
    assert eng.planner.route_classes is not None    # the head actually fitted
    trace = _trace(qs, preds)
    cfg = SchedulerConfig(max_batch=16, max_wait=0.004)
    a = OnlineRuntime(eng, cfg).run_trace(trace)
    b = OnlineRuntime(eng, cfg).run_trace(trace)
    assert a.batches == b.batches
    ca, cb = a.telemetry.counters(), b.telemetry.counters()
    assert ca == cb
    assert "backend_counts" in ca
    for rid in a.results:
        ra, rb = a.results[rid], b.results[rid]
        assert (ra.decision, ra.result.backend, ra.result.knob) == (
            rb.decision, rb.result.backend, rb.result.knob)
        assert np.array_equal(ra.result.ids, rb.result.ids)
    # every completed request carries a backend/knob name
    assert all(r.result.backend for r in a.results.values())


def test_routed_backend_mix_counter(routed_system):
    """The telemetry backend-mix counter sums to completions and only names
    registered (backend[:tier]) keys or plan names for un-routed rows."""
    _, eng, qs, preds = routed_system
    trace = _trace(qs, preds, n=80, seed=9)
    rep = OnlineRuntime(eng, SchedulerConfig(max_batch=8)).run_trace(trace)
    c = rep.telemetry.counters()
    mix = c["backend_counts"]
    assert sum(mix.values()) == c["n_completed"] == 80
    valid_backends = {"flat", "ivf", "ivfpq", "acorn", "pre", "post", "ipre"}
    for key in mix:
        assert key.split(":")[0] in valid_backends, key


def test_routed_feedback_refits_routing_head(routed_system):
    """The online refit fits a routing head on logged (label, route) pairs
    and the swapped-in candidate keeps serving the same class enumeration."""
    ds, eng, qs, preds = routed_system
    fb = OnlineFeedback(eng, FeedbackConfig(
        sample_rate=1.0, refit_every=32, min_examples=24, seed=3))
    for i in range(48):
        q, p = qs[i % len(qs)], preds[i % len(preds)]
        res = eng.query(q, p, K)
        fb.observe(RuntimeRequest(i, 0.0, q, p, K), res)
    assert any(e.route >= 0 for e in fb.log)       # shadow labels carry routes
    if fb.refit():                                 # guard may decline; if it
        assert eng.planner.route_classes == tuple(  # swaps, routing survives
            eng.backend_set.class_names())
