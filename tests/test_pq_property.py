"""Hypothesis property suite for the IVF-PQ quantizer (skips cleanly when
hypothesis is absent, like ``test_property.py``).

Two families of invariants from ``repro.index.pq``'s docstring contract:

* **encode/decode round trip** — per-subspace reconstruction error is
  bounded by the index's declared ``radius_sq`` for corpus points, and
  decode(encode(x)) is the nearest-codeword reconstruction (re-encoding a
  decoded point is a fixed point).
* **ADC vs exact** — the uint8 floor-quantized LUT distance only ever
  under-estimates the decoded distance, by less than the declared bound
  ``M * scale``; and on the re-rank candidate set the exact rescoring
  returns distances equal to a brute-force oracle (the ADC approximation
  only picks *which* candidates get rescored, never the reported numbers).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.index import IVFPQIndex  # noqa: E402


def _corpus(seed: int, n: int, d: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1, (8, d)).astype(np.float32)
    return (centers[rng.choice(8, n)] + 0.25 * rng.normal(0, 1, (n, d))).astype(
        np.float32
    )


@st.composite
def _index_params(draw):
    seed = draw(st.integers(0, 50))
    n = draw(st.integers(256, 700))
    d = draw(st.sampled_from([8, 16, 20, 32]))
    m = draw(st.sampled_from([None, 2, 4]))
    return seed, n, d, m


@given(params=_index_params())
@settings(max_examples=12, deadline=None)
def test_encode_decode_error_bounded_by_radius(params):
    seed, n, d, m = params
    x = _corpus(seed, n, d)
    ix = IVFPQIndex(x, n_lists=8, m=m, n_codes=32, seed=seed).build(iters=4)
    codes = ix.encode(x)
    rec = ix.decode(codes)
    # per-subspace squared reconstruction error <= declared radius for every
    # corpus point (radius_sq is the max over the corpus, by construction)
    dsub, M = ix.dsub, ix.m
    xp = ix._pad(x)
    rp = ix._pad(rec)
    for j in range(M):
        err = ((xp[:, j * dsub:(j + 1) * dsub] - rp[:, j * dsub:(j + 1) * dsub]) ** 2).sum(1)
        assert err.max() <= ix.radius_sq[j] + 1e-4
    # decode is a fixed point of the round trip
    np.testing.assert_array_equal(ix.encode(rec), codes)


@given(params=_index_params(), qseed=st.integers(0, 100))
@settings(max_examples=12, deadline=None)
def test_adc_underestimates_decoded_within_bound(params, qseed):
    seed, n, d, m = params
    x = _corpus(seed, n, d)
    ix = IVFPQIndex(x, n_lists=8, m=m, n_codes=32, seed=seed).build(iters=4)
    q = np.random.default_rng(qseed).normal(0, 1, d).astype(np.float32)
    ids = np.arange(min(128, n), dtype=np.int64)
    adc, bound = ix.adc_distances(q, ids)
    dec = ix.decode(ix.encode(x[ids]))
    qp, dp = ix._pad(q[None])[0], ix._pad(dec)
    exact_decoded = ((dp - qp[None]) ** 2).sum(1)
    diff = exact_decoded - adc.astype(np.float64)
    # floor quantization only ever under-estimates, by < M * scale
    assert diff.min() >= -1e-3
    assert diff.max() < bound + 1e-3


@given(params=_index_params(), qseed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_rerank_distances_match_bruteforce_oracle(params, qseed):
    """On whatever candidate set ADC picked, the returned distances are the
    EXACT L2 distances (monotone-consistent with a brute-force rescoring)."""
    seed, n, d, m = params
    x = _corpus(seed, n, d)
    ix = IVFPQIndex(x, n_lists=8, m=m, n_codes=32, seed=seed).build(iters=4)
    q = np.random.default_rng(qseed).normal(0, 1, d).astype(np.float32)
    dists, ids = ix.search(q[None], k=10, nprobe=4, rerank=32)
    got_d, got_i = dists[0], ids[0]
    valid = got_i >= 0
    oracle = ((x[got_i[valid]] - q[None]) ** 2).sum(1)
    np.testing.assert_allclose(got_d[valid], oracle, rtol=1e-5, atol=1e-5)
    # ascending by construction (composite keys sort on distance bits)
    assert (np.diff(got_d[valid]) >= -1e-6).all()
