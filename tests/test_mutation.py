"""Live-corpus mutation layer: upserts, deletes, tombstone-aware planning,
compaction, and the cross-layer invariants ISSUE 7 promises.

The headline invariant: for EXACT plans, a mutated engine must return ids
identical (modulo the compaction ``id_map`` translation) to an engine
freshly built from the equivalent post-mutation corpus — tombstones and the
append segment are a pure view change, never an accuracy change.
"""
import numpy as np
import pytest

from repro.core import (
    CompactionPolicy,
    EngineConfig,
    FilteredANNEngine,
    LabelEq,
    LiveCorpus,
    Predicate,
    RangePred,
)

K = 10


# ----------------------------------------------------------------------
# fixtures
# ----------------------------------------------------------------------
def _make_corpus(n=2500, d=16, seed=0):
    rng = np.random.default_rng(seed)
    v = rng.standard_normal((n, d)).astype(np.float32)
    cat = rng.integers(0, 5, (n, 2)).astype(np.int32)
    num = rng.standard_normal((n, 2)).astype(np.float32)
    return v, cat, num


def _build(v, cat, num, **cfg):
    return FilteredANNEngine(v, cat, num, EngineConfig(seed=0, **cfg)).build()


@pytest.fixture(scope="module")
def corpus():
    return _make_corpus()


PRED = Predicate(labels=(LabelEq(0, 2), LabelEq(1, 3)))
PRED_LABEL = Predicate(labels=(LabelEq(0, 1),))
PRED_RANGE = Predicate(ranges=(RangePred(0, ((-0.5, 0.5),)),))


def _mutate(eng, v, cat, seed=3):
    """A standard churn burst: delete matching + random rows, upsert a few
    rows matching PRED (two of them duplicating existing vectors)."""
    rng = np.random.default_rng(seed)
    match = np.nonzero((cat[:, 0] == 2) & (cat[:, 1] == 3))[0][:15]
    rand = rng.choice(len(v), 40, replace=False)
    eng.delete(np.concatenate([match, rand]))
    nv = np.concatenate([v[:2], rng.standard_normal((4, v.shape[1])).astype(np.float32)])
    nc = np.tile(np.array([[2, 3]], np.int32), (6, 1))
    nm = np.zeros((6, 2), np.float32)
    return eng.upsert(nv, nc, nm)


# ----------------------------------------------------------------------
# tentpole: post-mutation equivalence
# ----------------------------------------------------------------------
def test_exact_plan_bit_equality_vs_fresh_build(corpus):
    """Mutated engine == fresh build over the post-mutation corpus, for
    exact plans: ground truth AND the served exact-strategy ids translate
    bit-identically through the compaction id_map."""
    v, cat, num = corpus
    eng = _build(v, cat, num)
    handles = _mutate(eng, v, cat)
    q = v[:8]
    gt_live = eng.ground_truth(q, PRED, k=K)
    res = eng.batch_query(q, [PRED] * len(q), k=K)
    for i, pr in enumerate(res):
        if pr.result.backend in (None, "flat"):  # exact execution classes
            np.testing.assert_array_equal(pr.result.ids[0], gt_live[i])

    cv, cc, cm, id_map = eng.live.compacted()
    fresh = _build(cv, cc, cm)
    gt_fresh = fresh.ground_truth(q, PRED, k=K)
    tr = np.where(gt_live >= 0, id_map[np.maximum(gt_live, 0)], -1)
    np.testing.assert_array_equal(tr, gt_fresh)
    # a surviving upsert handle translates to a real row in the fresh corpus
    assert (id_map[handles] >= 0).all()


def test_compact_preserves_results_and_restores_planner(corpus):
    v, cat, num = corpus
    eng = _build(v, cat, num)
    _mutate(eng, v, cat)
    q = v[:6]
    gt_before = eng.ground_truth(q, PRED, k=K)
    gen_before = eng.corpus_generation
    id_map = eng.compact()
    assert eng.n_compactions == 1
    assert eng.corpus_generation == gen_before + 1  # monotone ACROSS compaction
    assert not eng.live.dirty
    gt_after = eng.ground_truth(q, PRED, k=K)
    tr = np.where(gt_before >= 0, id_map[np.maximum(gt_before, 0)], -1)
    np.testing.assert_array_equal(tr, gt_after)
    # the rebuilt engine serves immediately
    r = eng.query(q[0], PRED, k=K)
    assert (r.result.ids >= -1).all()
    assert "compaction" in eng.build_time_


def test_delete_excludes_tombstones_every_plan(corpus):
    """No strategy may surface a deleted id, including routed backends."""
    v, cat, num = corpus
    eng = _build(v, cat, num)
    match = np.nonzero(cat[:, 0] == 2)[0][:60]
    eng.delete(match)
    dead = set(match.tolist())
    for pred in (PRED, PRED_LABEL, Predicate(labels=(LabelEq(0, 2),))):
        res = eng.batch_query(v[:6], [pred] * 6, k=K)
        for pr in res:
            ids = pr.result.ids[0]
            assert not (set(ids[ids >= 0].tolist()) & dead), (
                f"{pr.result.strategy}/{pr.result.backend} leaked a tombstone"
            )


def test_upsert_of_existing_id_replaces(corpus):
    v, cat, num = corpus
    eng = _build(v, cat, num)
    # replace row 7 with a PRED-matching copy of itself
    h = eng.upsert(v[7:8], np.array([[2, 3]], np.int32), np.zeros((1, 2), np.float32),
                   ids=np.array([7]))
    assert eng.live.is_deleted(np.array([7]))[0]
    gt = eng.ground_truth(v[7], PRED, k=K)
    assert h[0] in gt[0] and 7 not in gt[0]


# ----------------------------------------------------------------------
# staleness-aware statistics (satellite 6 + sel demotion)
# ----------------------------------------------------------------------
def test_sel_is_exact_demotes_and_recovers(corpus):
    """Range buckets go stale on upsert (fail closed: covers() drops, the
    estimate demotes to non-exact); label bitmaps extend incrementally and
    STAY exact; compaction rebuilds everything back to exact."""
    v, cat, num = corpus
    eng = _build(v, cat, num)
    assert eng.attr_index.covers(PRED_RANGE)
    assert eng.estimator.estimate(PRED_RANGE).is_exact
    _mutate(eng, v, cat)
    # stale range index: fail closed out of the covered set
    assert not eng.attr_index.covers(PRED_RANGE)
    assert not eng.estimator.estimate(PRED_RANGE).is_exact
    # label bitmaps extended in place: still exact, and exact over LIVE rows
    se = eng.estimator.estimate(PRED_LABEL)
    assert se.is_exact
    alive = eng.live.alive_mask()
    m = np.concatenate([cat[:, 0] == 1, eng.live.seg_cat()[:, 0] == 1]) & alive
    assert se.sel == pytest.approx(m.sum() / alive.sum())
    eng.compact()
    assert eng.attr_index.covers(PRED_RANGE)
    assert eng.estimator.estimate(PRED_RANGE).is_exact


def test_stale_range_boundary_regression(corpus):
    """The boundary case: a range predicate whose matching rows are ONLY in
    the append segment.  A stale bucket bitmap would return zero matches if
    it still claimed coverage; fail-closed scanning must find them."""
    v, cat, num = corpus
    eng = _build(v, cat, num)
    # upsert rows with a numeric value far outside the built histogram
    nv = np.random.default_rng(5).standard_normal((3, v.shape[1])).astype(np.float32)
    nm = np.full((3, 2), 99.0, np.float32)
    h = eng.upsert(nv, np.zeros((3, 2), np.int32), nm)
    far = Predicate(ranges=(RangePred(0, ((98.0, 100.0),)),))
    assert not eng.attr_index.covers(far)      # stale -> out of covered set
    gt = eng.ground_truth(nv[0], far, k=K)
    got = set(gt[0][gt[0] >= 0].tolist())
    assert got == set(h.tolist())
    r = eng.query(nv[0], far, k=K)
    ids = r.result.ids[0]
    assert set(ids[ids >= 0].tolist()) == set(h.tolist())


# ----------------------------------------------------------------------
# satellite 1: cache invalidation / epoch counters in stats()
# ----------------------------------------------------------------------
def test_stats_exposes_invalidation_counters(corpus):
    v, cat, num = corpus
    eng = _build(v, cat, num)
    eng.query(v[0], PRED, k=K)
    st0 = eng.stats()
    assert st0["corpus_generation"] == 0
    assert st0["plan_cache"]["invalidations"] == 0

    eng.upsert(v[:1], np.array([[2, 3]], np.int32), np.zeros((1, 2), np.float32))
    eng.query(v[0], PRED, k=K)    # same pred: plan epoch mismatch on lookup
    st1 = eng.stats()
    assert st1["corpus_generation"] == 1
    assert st1["plan_cache"]["invalidations"] >= 1
    assert st1["pred_cache"]["invalidations"] >= 1   # upsert rewrites words
    assert st1["live"]["dirty"]

    # deletes keep compiled words valid: tombstones compose at query time
    pred_inval = st1["pred_cache"]["invalidations"]
    eng.delete(np.array([3]))
    assert eng.stats()["pred_cache"]["invalidations"] == pred_inval
    assert eng.stats()["corpus_generation"] == 2


# ----------------------------------------------------------------------
# satellite 2: merge under shards whose live count drops below k
# ----------------------------------------------------------------------
def test_merge_tolerates_starved_shard():
    from repro.dist.collectives import merge_topk

    # shard A has only 3 survivors, shard B a full k
    da = np.array([[0.1, 0.5, 0.9, np.inf, np.inf]], np.float32)
    ia = np.array([[4, 9, 2, -1, -1]], np.int32)
    db = np.array([[0.2, 0.3, 0.6, 0.7, 1.1]], np.float32)
    ib = np.array([[10, 11, 12, 13, 14]], np.int32)
    d, i = merge_topk(np.stack([da, db]), np.stack([ia, ib]), 5)
    np.testing.assert_array_equal(i[0], [4, 10, 11, 9, 12])
    # fewer total survivors than k: -1/inf padding, no garbage
    d, i = merge_topk(np.stack([da[:, :2], da[:, 3:]]),
                      np.stack([ia[:, :2], ia[:, 3:]]), 5)
    np.testing.assert_array_equal(i[0], [4, 9, -1, -1, -1])
    assert np.isinf(d[0][2:]).all()


def test_sharded_starved_shard_after_deletes(corpus):
    """Delete every PRED match on one shard; the sharded engine must still
    merge exactly (padded rows never poison the merge)."""
    from repro.serve.engine import ShardedANNEngine

    v, cat, num = corpus
    flat = _build(v, cat, num)
    sharded = ShardedANNEngine(_build(v, cat, num), n_shards=3)
    match = np.nonzero((cat[:, 0] == 2) & (cat[:, 1] == 3))[0]
    shard0 = sharded.shards[0].ids
    kill = match[np.isin(match, shard0)]
    flat.delete(kill)
    sharded.delete(kill)
    gt = flat.ground_truth(v[:5], PRED, k=K)
    res = sharded.batch_query(v[:5], [PRED] * 5, k=K)
    for i, pr in enumerate(res):
        if pr.result.backend in (None, "flat"):
            ids = pr.result.ids[0]
            np.testing.assert_array_equal(np.sort(ids), np.sort(gt[i]))
            assert not np.isin(ids[ids >= 0], kill).any()


def test_sharded_equals_flat_after_churn(corpus):
    from repro.serve.engine import ShardedANNEngine

    v, cat, num = corpus
    flat = _build(v, cat, num)
    base = _build(v, cat, num)
    sharded = ShardedANNEngine(base, n_shards=3)
    rng = np.random.default_rng(7)
    dead = rng.choice(len(v), 30, replace=False)
    flat.delete(dead)
    sharded.delete(dead)
    nv = rng.standard_normal((5, v.shape[1])).astype(np.float32)
    nc = np.tile(np.array([[2, 3]], np.int32), (5, 1))
    nm = np.zeros((5, 2), np.float32)
    hf = flat.upsert(nv, nc, nm)
    hs = sharded.upsert(nv, nc, nm)
    np.testing.assert_array_equal(hf, hs)
    gt = flat.ground_truth(v[:6], PRED, k=K)
    res = sharded.batch_query(v[:6], [PRED] * 6, k=K)
    for i, pr in enumerate(res):
        if pr.result.backend in (None, "flat"):
            np.testing.assert_array_equal(np.sort(pr.result.ids[0]), np.sort(gt[i]))
    # compaction re-shards; results keep translating through id_map
    id_map = sharded.compact()
    gt2 = sharded.engine.ground_truth(v[:6], PRED, k=K)
    tr = np.where(gt >= 0, id_map[np.maximum(gt, 0)], -1)
    np.testing.assert_array_equal(tr, gt2)


# ----------------------------------------------------------------------
# compaction policy
# ----------------------------------------------------------------------
def test_compaction_policy_thresholds():
    pol = CompactionPolicy(max_tombstone_frac=0.2, max_segment_frac=0.3,
                           max_list_drift=1.5)
    assert not pol.due(0.1, 0.1, 1.0)
    assert pol.due(0.25, 0.0, 1.0)
    assert pol.due(0.0, 0.35, 1.0)
    assert pol.due(0.0, 0.0, 2.0)


def test_maybe_compact_triggers_on_churn(corpus):
    v, cat, num = corpus
    eng = _build(v, cat, num, max_tombstone_frac=0.01)
    assert eng.maybe_compact() is None           # clean corpus: no-op
    eng.delete(np.arange(100))
    assert eng.needs_compaction()
    id_map = eng.maybe_compact()
    assert id_map is not None and eng.n_compactions == 1
    assert (id_map[:100] == -1).all()


# ----------------------------------------------------------------------
# runtime: interleaved writes, replay determinism
# ----------------------------------------------------------------------
def test_runtime_write_trace_replays_deterministically(corpus):
    from repro.runtime import OnlineRuntime
    from repro.runtime.queue import poisson_trace
    from repro.runtime.scheduler import SchedulerConfig

    v, cat, num = corpus
    preds = [Predicate(labels=(LabelEq(0, c),)) for c in range(4)]
    rng = np.random.default_rng(8)
    wv = rng.standard_normal((30, v.shape[1])).astype(np.float32)
    wc = rng.integers(0, 4, (30, 2)).astype(np.int32)
    wm = rng.standard_normal((30, 2)).astype(np.float32)
    trace = poisson_trace(v[:40], preds, 150, rate=600.0, seed=4,
                          write_frac=0.25, write_corpus=(wv, wc, wm),
                          delete_pool=np.arange(0, 300, 5))
    ops = [r.op for r in trace]
    assert "upsert" in ops and "delete" in ops and "query" in ops

    reports = []
    for _ in range(2):
        eng = _build(v, cat, num)
        rt = OnlineRuntime(eng, SchedulerConfig(max_batch=16))
        reports.append(rt.run_trace(trace))
    a, b = reports
    assert a.telemetry.counters() == b.telemetry.counters()
    assert a.batches == b.batches
    for rid in a.results:
        np.testing.assert_array_equal(a.ids(rid), b.ids(rid))
    c = a.telemetry.counters()
    assert c["n_upserts"] == ops.count("upsert")
    assert c["n_deletes"] == ops.count("delete")
    assert c["n_completed"] == ops.count("query")
    # writes cost virtual time through the service model
    from repro.runtime.scheduler import ServiceModel

    sm = ServiceModel()
    assert sm.time([], n_upsert_rows=2, n_delete_rows=1, n_compactions=1) == (
        pytest.approx(sm.dispatch + 2 * sm.upsert_row + sm.delete_row + sm.compaction)
    )


# ----------------------------------------------------------------------
# checkpoint: mutable state snapshot/restore
# ----------------------------------------------------------------------
def test_checkpoint_mutation_state_roundtrip(corpus, tmp_path):
    from repro.ckpt import Checkpointer

    v, cat, num = corpus
    eng = _build(v, cat, num)
    _mutate(eng, v, cat)
    ck = Checkpointer(str(tmp_path), keep=2)
    ck.save(1, eng.mutation_state(),
            meta={"corpus_generation": eng.corpus_generation})
    assert ck.read_meta(1) == {"corpus_generation": eng.corpus_generation}

    restored = ck.restore(1, eng.mutation_state())
    eng2 = _build(v, cat, num)
    eng2.load_mutation_state(
        {k: np.asarray(val) for k, val in restored.items()})
    assert eng2.live.n_total == eng.live.n_total
    assert eng2.live.live_count == eng.live.live_count
    gt_a = eng.ground_truth(v[:4], PRED, k=K)
    gt_b = eng2.ground_truth(v[:4], PRED, k=K)
    np.testing.assert_array_equal(gt_a, gt_b)
