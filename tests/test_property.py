"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import DatasetStats, LabelEq, Predicate, RangePred, SelectivityEstimator
from repro.core.stats import Histogram
from repro.index.flat import l2_topk
import jax.numpy as jnp


# ----------------------------------------------------------------------
# histogram invariants
# ----------------------------------------------------------------------
@given(
    data=st.lists(st.floats(-100, 100, allow_nan=False), min_size=16, max_size=400),
    lo=st.floats(-120, 120, allow_nan=False),
    width=st.floats(0.0, 250, allow_nan=False),
)
@settings(max_examples=60, deadline=None)
def test_histogram_selectivity_bounds(data, lo, width):
    h = Histogram.build(np.asarray(data), bins=64)
    s = h.selectivity([(lo, lo + width)])
    assert -1e-9 <= s <= 1.0 + 1e-9


@given(
    data=st.lists(st.floats(-50, 50, allow_nan=False), min_size=32, max_size=300),
)
@settings(max_examples=40, deadline=None)
def test_histogram_full_range_is_one(data):
    x = np.asarray(data)
    h = Histogram.build(x, bins=32)
    s = h.selectivity([(h.lo - 1, h.hi + 1)])
    assert abs(s - 1.0) < 1e-6


@given(
    seed=st.integers(0, 10_000),
    a=st.floats(0, 1), b=st.floats(0, 1), c=st.floats(0, 1),
)
@settings(max_examples=40, deadline=None)
def test_histogram_monotone_in_range(seed, a, b, c):
    """Wider range ⊇ narrower range ⇒ selectivity is monotone."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 10, 500)
    h = Histogram.build(x, bins=64)
    pts = sorted([h.lo + (h.hi - h.lo) * t for t in (a, b, c)])
    narrow = h.selectivity([(pts[1], pts[2])])
    wide = h.selectivity([(pts[0], pts[2])])
    assert wide >= narrow - 1e-9


# ----------------------------------------------------------------------
# selectivity-estimator invariants
# ----------------------------------------------------------------------
@given(seed=st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_estimates_in_unit_interval(seed):
    rng = np.random.default_rng(seed)
    n = 400
    vec = rng.normal(0, 1, (n, 8)).astype(np.float32)
    cat = rng.integers(0, 5, (n, 2)).astype(np.int32)
    num = rng.normal(0, 1, (n, 2)).astype(np.float32)
    stats = DatasetStats.build(vec, cat, num, sample_frac=0.05)
    est = SelectivityEstimator(stats)
    preds = [
        Predicate(labels=(LabelEq(0, 1),)),
        Predicate(labels=(LabelEq(0, 1), LabelEq(1, 2))),
        Predicate(ranges=(RangePred(0, ((-0.5, 0.5),)),)),
        Predicate(labels=(LabelEq(0, 0),), ranges=(RangePred(1, ((0.0, 2.0),)),)),
    ]
    for p in preds:
        s = est.estimate(p).sel
        assert 0.0 <= s <= 1.0


# ----------------------------------------------------------------------
# top-k invariants
# ----------------------------------------------------------------------
@given(
    seed=st.integers(0, 10_000),
    n=st.integers(20, 200),
    k=st.integers(1, 10),
)
@settings(max_examples=30, deadline=None)
def test_topk_sorted_and_exact(seed, n, k):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, 6)).astype(np.float32)
    q = rng.normal(0, 1, (2, 6)).astype(np.float32)
    d, i = l2_topk(jnp.asarray(q), jnp.asarray(x), k)
    d, i = np.asarray(d), np.asarray(i)
    assert (np.diff(d, axis=1) >= -1e-5).all(), "distances must be sorted"
    ref = np.sort(((q[:, None] - x[None]) ** 2).sum(-1), axis=1)[:, :k]
    np.testing.assert_allclose(d, ref, rtol=1e-3, atol=1e-4)


@given(seed=st.integers(0, 10_000), frac=st.floats(0.05, 0.9))
@settings(max_examples=30, deadline=None)
def test_topk_respects_mask(seed, frac):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (100, 4)).astype(np.float32)
    q = rng.normal(0, 1, (1, 4)).astype(np.float32)
    mask = rng.random(100) < frac
    _, i = l2_topk(jnp.asarray(q), jnp.asarray(x), 5, jnp.asarray(mask))
    i = np.asarray(i)[0]
    for idx in i:
        assert idx == -1 or mask[idx]


# ----------------------------------------------------------------------
# predicate-eval invariants
# ----------------------------------------------------------------------
@given(seed=st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_conjunction_is_intersection(seed):
    rng = np.random.default_rng(seed)
    n = 300
    cat = rng.integers(0, 4, (n, 2)).astype(np.int32)
    num = rng.normal(0, 1, (n, 1)).astype(np.float32)
    p1 = Predicate(labels=(LabelEq(0, 1),))
    p2 = Predicate(ranges=(RangePred(0, ((-0.3, 0.8),)),))
    both = Predicate(labels=p1.labels, ranges=p2.ranges)
    m = both.eval(cat, num)
    np.testing.assert_array_equal(m, p1.eval(cat, num) & p2.eval(cat, num))
