"""Batched plan->execute pipeline: exactness vs the per-query path.

The batched path groups queries by planner decision and shares planning,
mask evaluation, kernel dispatches and IVF scans across the batch — but it
must return IDENTICAL ids and decisions to N independent ``query()`` calls,
on both the flat and the sharded engine.

The module fixture swaps in a deterministic selectivity-threshold planner
(engine API unchanged) so the workload provably covers BOTH decision groups
at test scale — the learned planner is free to (correctly) pick one strategy
everywhere on a small corpus, which would leave one executor group untested.
The batched MLP dispatch itself is covered row-vs-batch in test_planner.
"""
import numpy as np
import pytest

from repro.core import (
    CorePlanner,
    EngineConfig,
    FilteredANNEngine,
    POST_FILTER,
    PRE_FILTER,
    Predicate,
    RangePred,
)
from repro.core.trainer import gen_queries
from repro.data import make_dataset
from repro.serve import ShardedANNEngine


class _ThresholdPlanner(CorePlanner):
    """Deterministic stand-in: post-filter above 5% estimated selectivity.
    Row-wise on the (B, F) feature matrix, like the real MLP."""

    def __init__(self):
        super().__init__()
        self.params = {"stub": True}          # truthy: engine takes the decide() path

    def decide(self, features):
        f = np.atleast_2d(np.asarray(features, np.float32))
        return (f[:, 3] > 0.05).astype(np.int32)   # column 3 = est. selectivity


@pytest.fixture(scope="module")
def system():
    ds = make_dataset("sift", scale="8000", seed=0)
    eng = FilteredANNEngine(
        ds.vectors, ds.cat, ds.num, EngineConfig(n_lists=64, seed=0)
    ).build()
    # train the GBM refinement (so estimate_batch's pooled-GBM route is
    # exercised) without the heavyweight dual-strategy planner fit
    _, preds, sels = gen_queries(
        ds.vectors, ds.cat, ds.num, 30, kinds=("label", "mixed"), seed=3
    )
    eng.estimator.fit(preds, sels)
    eng.planner = _ThresholdPlanner()
    # mixed workload spanning predicate kinds AND the selectivity range so
    # both decisions (and both executors) appear in the batch
    q, p, _ = gen_queries(
        ds.vectors, ds.cat, ds.num, 24, kinds=("label", "range", "mixed"),
        sel_range=(0.01, 0.5), seed=7,
    )
    return ds, eng, q, p


def _assert_equivalent(batched, singles):
    assert len(batched) == len(singles)
    for i, (bq, sq) in enumerate(zip(batched, singles)):
        assert bq.decision == sq.decision, f"row {i}: decision mismatch"
        assert np.array_equal(bq.result.ids, sq.result.ids), f"row {i}: ids differ"
        np.testing.assert_allclose(
            bq.result.dists, sq.result.dists, err_msg=f"row {i}"
        )
        assert bq.est_selectivity == pytest.approx(sq.est_selectivity, abs=1e-12)
        assert bq.result.n_expansions == sq.result.n_expansions


def test_flat_batch_matches_per_query(system):
    _, eng, q, p = system
    batched = eng.batch_query(q, p, k=10)
    singles = [eng.query(q[i], p[i], k=10) for i in range(len(p))]
    _assert_equivalent(batched, singles)


def test_sharded_batch_matches_per_query(system):
    _, eng, q, p = system
    sharded = ShardedANNEngine(eng, n_shards=4)
    batched = sharded.batch_query(q, p, k=10)
    singles = [sharded.query(q[i], p[i], k=10) for i in range(len(p))]
    _assert_equivalent(batched, singles)


def test_batch_exercises_both_decisions(system):
    """The fixture must actually cover both executor groups, or the
    equivalence assertions above are vacuous for one of them."""
    _, eng, q, p = system
    decisions = {r.decision for r in eng.batch_query(q, p, k=10)}
    assert decisions == {PRE_FILTER, POST_FILTER}


def test_plan_batch_matches_plan(system):
    _, eng, q, p = system
    ests, decisions, _ = eng.plan_batch(p, k=10)
    for i, pred in enumerate(p):
        est_i, dec_i, _ = eng.plan(pred, k=10)
        assert ests[i] == pytest.approx(est_i, abs=1e-12)
        assert decisions[i] == dec_i


def test_batch_results_satisfy_predicates(system):
    ds, eng, q, p = system
    for i, r in enumerate(eng.batch_query(q, p, k=10)):
        ids = r.result.ids[r.result.ids >= 0]
        assert ids.size > 0
        assert p[i].eval(ds.cat[ids], ds.num[ids]).all()


def test_post_filter_budget_scales_with_selectivity(system):
    """Bugfix: the initial candidate request must be ~alpha0*k/selectivity,
    not a flat alpha0*k — at low selectivity the flat budget loses most
    candidates to the filter and pays doubling rounds the sized budget
    avoids."""
    ds, eng, _, _ = system
    qs, ps, sels = gen_queries(
        ds.vectors, ds.cat, ds.num, 5, kinds=("range",),
        sel_range=(0.005, 0.02), seed=11,
    )
    for i in range(len(ps)):
        sized = eng.post_exec.search(qs[i : i + 1], ps[i], k=10,
                                     est_selectivity=float(sels[i]))
        flat = eng.post_exec.search(qs[i : i + 1], ps[i], k=10)
        assert sized.n_expansions < flat.n_expansions
        assert (sized.ids >= 0).sum() == 10
    # and the sizing formula itself: budget rises as selectivity falls
    w_low, _ = eng.post_exec.initial_params(10, 0.01)
    w_high, _ = eng.post_exec.initial_params(10, 0.5)
    assert w_low > w_high


def test_batch_query_single_row_and_empty_predicate(system):
    _, eng, q, p = system
    # B=1 degenerates to the per-query result
    r = eng.batch_query(q[:1], p[:1], k=10)
    assert len(r) == 1
    assert np.array_equal(r[0].result.ids, eng.query(q[0], p[0], k=10).result.ids)
    # a predicate matching nothing returns all-padding, no crash
    nothing = Predicate(ranges=(RangePred(0, ((1e9, 2e9),)),))
    out = eng.batch_query(q[:3], [nothing, p[0], nothing], k=5)
    assert (out[0].result.ids == -1).all() and (out[2].result.ids == -1).all()
    assert (out[1].result.ids >= 0).any()
